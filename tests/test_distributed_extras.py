"""Checkpoint/restore, elastic restart, hedging, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.distributed.fault import ElasticRunner, HedgedCalls, NodeFailure, RetryPolicy
from repro.optim import int8_compress_grads


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": [jnp.int32(7), jnp.ones(5)]}
    save_checkpoint(str(tmp_path), 3, tree)
    assert latest_step(str(tmp_path)) == 3
    out = restore_checkpoint(str(tmp_path), 3, tree)
    for x, y in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_atomic_overwrite(tmp_path):
    tree = {"w": jnp.zeros(4)}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 1, {"w": jnp.ones(4)})
    out = restore_checkpoint(str(tmp_path), 1, tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones(4))


def test_checkpoint_resharding_restore(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(8.0)}
    save_checkpoint(str(tmp_path), 5, tree)
    shard = {"w": NamedSharding(mesh, P("data"))}
    out = restore_checkpoint(str(tmp_path), 5, tree, shardings=shard)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8.0))
    assert out["w"].sharding == shard["w"]


def test_elastic_runner_failover(tmp_path):
    """Injected node loss at step 7 -> re-mesh + restore from step 5."""
    calls = []

    def make_mesh(level):
        return ("mesh", level)  # the state fn only needs a token

    def make_state(mesh):
        return {"x": jnp.zeros(3), "mesh_level": jnp.int32(mesh[1])}

    def step_fn(mesh, state, i):
        calls.append((mesh[1], i))
        return {**state, "x": state["x"] + 1}

    runner = ElasticRunner(
        make_mesh=make_mesh, make_state=make_state, step_fn=step_fn,
        ckpt_dir=str(tmp_path), ckpt_every=5,
    )
    state, log = runner.run(12, inject_failure_at=7)
    kinds = [e[0] for e in log]
    assert "failover" in kinds
    # resumed from the step-5 checkpoint and completed all 12 steps
    assert float(state["x"][0]) == 12.0
    # post-failover steps ran on the downgraded mesh
    assert any(lvl == 1 for lvl, _ in calls)


def test_retry_policy_bounded():
    n = {"count": 0}

    def flaky():
        n["count"] += 1
        raise NodeFailure("nope")

    with pytest.raises(NodeFailure):
        RetryPolicy(max_attempts=3).run(flaky)
    assert n["count"] == 3


def test_hedging_improves_p99():
    def heavy_tail(rng):
        return 0.001 + (rng.pareto(2.0)) * 0.002

    out = HedgedCalls(replicas=2, seed=1).simulate(4000, heavy_tail)
    assert out["p99_improvement"] > 1.3  # hedging must cut the tail


def test_int8_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)}
    deq, res = int8_compress_grads(g)
    err = float(jnp.abs(deq["w"] - g["w"]).max())
    scale = float(jnp.abs(g["w"]).max()) / 127
    assert err <= scale * 1.01  # quantization error bounded by one step
    # error feedback: residual equals what was lost
    np.testing.assert_allclose(
        np.asarray(res["w"]), np.asarray(g["w"] - deq["w"]), atol=1e-6
    )
    # applying residual next round recovers the signal in expectation
    deq2, res2 = int8_compress_grads(g, res)
    total = np.asarray(deq["w"] + deq2["w"])
    np.testing.assert_allclose(total, 2 * np.asarray(g["w"]), atol=2 * scale)
