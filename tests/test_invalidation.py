"""Write-around / write-through invalidation — the paper's Examples 1-5."""

import numpy as np

from conftest import (
    E_INCLUDES,
    L_LISTING,
    MISSING,
    P_ISACTIVE,
    P_STATUS,
    fig1_plan,
)
from repro.core import GraphEngine, run_grw_tx
from repro.core.oracle import HostStore, onehop_oracle
from repro.core.population import CachePopulator
from repro.graphstore import make_mutation_batch
from conftest import TPL_META


def _ids(row):
    return set(row[row >= 0].tolist())


def _warm(world, roots):
    """Run + populate so the cache is hot for fig1 over ``roots``."""
    eng = GraphEngine(world["espec"], fig1_plan(), use_cache=True)
    pop = CachePopulator(world["espec"], TPL_META)
    _, misses, _ = eng.run(world["store"], world["cache"], world["ttable"], roots)
    pop.queue.push(misses)
    cache = pop.drain(world["store"], world["store"], world["cache"], world["ttable"])
    _, _, m = eng.run(world["store"], cache, world["ttable"], roots)
    assert m["hits"] == len(roots)
    return eng, cache


def _check_consistent(world, eng, store, cache, roots):
    """Post-mutation results must equal the oracle regardless of hits."""
    res, _, _ = eng.run(store, cache, world["ttable"], roots)
    hs = HostStore(store)
    hop = fig1_plan().hops[0]
    for i, r in enumerate(roots):
        want = onehop_oracle(
            hs, hop.direction, hop.edge_label, hop.pr, hop.pe, hop.pl, int(r), hop.params
        )
        assert _ids(res[i]) == want, f"root {r}: {_ids(res[i])} != {want}"


def test_example2_delete_leaf_vertex(world, policy="write-around"):
    roots = np.array([0, 1], np.int32)
    eng, cache = _warm(world, roots)
    mb = make_mutation_batch(world["spec"], del_vertices=[6])
    store2, cache2, m = run_grw_tx(
        world["espec"], world["store"], cache, world["ttable"], mb, policy=policy
    )
    _check_consistent(world, eng, store2, cache2, roots)


def test_example3_update_leaf_status(world):
    roots = np.array([0, 1, 2, 3], np.int32)
    eng, cache = _warm(world, roots)
    mb = make_mutation_batch(world["spec"], set_vprops=[(7, P_STATUS, 1)])
    store2, cache2, m = run_grw_tx(
        world["espec"], world["store"], cache, world["ttable"], mb
    )
    _check_consistent(world, eng, store2, cache2, roots)


def test_example4_add_edge(world):
    roots = np.array([0], np.int32)
    eng, cache = _warm(world, roots)
    mb = make_mutation_batch(world["spec"], new_edges=[(0, 9, E_INCLUDES, [1])])
    store2, cache2, m = run_grw_tx(
        world["espec"], world["store"], cache, world["ttable"], mb
    )
    _check_consistent(world, eng, store2, cache2, roots)


def test_example5_update_edge_isactive(world):
    roots = np.array([0, 1], np.int32)
    eng, cache = _warm(world, roots)
    eid = int(np.asarray(world["store"].esrc[:16]).tolist().index(0))
    mb = make_mutation_batch(
        world["spec"],
        set_eprops=[(eid, P_ISACTIVE, 0)],
    )
    store2, cache2, m = run_grw_tx(
        world["espec"], world["store"], cache, world["ttable"], mb
    )
    assert m["impacted_keys"] >= 0
    _check_consistent(world, eng, store2, cache2, roots)


def test_example1_delete_root_vertex(world):
    roots = np.array([0, 1], np.int32)
    eng, cache = _warm(world, roots)
    mb = make_mutation_batch(world["spec"], del_vertices=[0])
    store2, cache2, m = run_grw_tx(
        world["espec"], world["store"], cache, world["ttable"], mb
    )
    assert m["impacted_keys"] >= 1  # the root's entry was swept
    _check_consistent(world, eng, store2, cache2, roots)


def test_unreferenced_prop_impacts_nothing(world):
    roots = np.array([0, 1], np.int32)
    eng, cache = _warm(world, roots)
    # ListingId is not referenced by any template predicate
    mb = make_mutation_batch(world["spec"], set_vprops=[(7, 1, 9999)])
    store2, cache2, m = run_grw_tx(
        world["espec"], world["store"], cache, world["ttable"], mb
    )
    assert m["impacted_keys"] == 0
    _, _, mm = eng.run(store2, cache2, world["ttable"], roots)
    assert mm["hits"] == len(roots)  # entries survived


def test_write_through_keeps_entries(world):
    roots = np.array([0], np.int32)
    eng, cache = _warm(world, roots)
    mb = make_mutation_batch(world["spec"], new_edges=[(0, 11, E_INCLUDES, [1])])
    store2, cache2, m = run_grw_tx(
        world["espec"], world["store"], cache, world["ttable"], mb, policy="write-through"
    )
    res, _, mm = eng.run(store2, cache2, world["ttable"], roots)
    _check_consistent(world, eng, store2, cache2, roots)
    # write-through should usually retain hits (entry updated in place);
    # fallback-to-delete is allowed only for full/multi-chunk entries
    hs = HostStore(store2)
    hop = fig1_plan().hops[0]
    want = onehop_oracle(hs, hop.direction, hop.edge_label, hop.pr, hop.pe, hop.pl, 0, hop.params)
    if len(want) < world["cspec"].max_leaves:
        assert mm["hits"] == 1


def test_write_through_examples_all_mutation_kinds(world):
    roots = np.array([0, 1, 2, 3], np.int32)
    eng, cache = _warm(world, roots)
    store = world["store"]
    muts = [
        make_mutation_batch(world["spec"], set_vprops=[(8, P_STATUS, 1)]),
        make_mutation_batch(world["spec"], del_vertices=[9]),
        make_mutation_batch(world["spec"], new_edges=[(2, 10, E_INCLUDES, [1])]),
        make_mutation_batch(world["spec"], set_eprops=[(0, P_ISACTIVE, 0)]),
    ]
    for mb in muts:
        store, cache, _ = run_grw_tx(
            world["espec"], store, cache, world["ttable"], mb, policy="write-through"
        )
        _check_consistent(world, eng, store, cache, roots)


def test_compacted_grw_step_matches_sink_reference(world):
    """The op-stream-compacted host gRW step (the sharded write path's
    design, backported) must leave the exact cache *contents* the
    sink-based sequential appliers produce, for both policies and a batch
    mixing every mutation kind. (Stats counters differ by design: the
    compacted step counts ``impacted`` as distinct entries removed.)"""
    import jax
    from repro.core import build_grw_step, cache_entries
    from repro.core.invalidation import (
        invalidate_write_around,
        write_through_update,
    )
    from repro.graphstore import apply_mutations

    roots = np.array([0, 1, 2, 3], np.int32)
    _, cache = _warm(world, roots)
    espec, store, ttable = world["espec"], world["store"], world["ttable"]
    mb = make_mutation_batch(
        world["spec"],
        new_edges=[(0, 11, E_INCLUDES, [1]), (2, 10, E_INCLUDES, [1])],
        del_edges=[1], del_vertices=[9],
        set_vprops=[(8, P_STATUS, 1), (7, P_STATUS, 0)],
        set_eprops=[(0, P_ISACTIVE, 0)],
    )
    store2_ref, applied = apply_mutations(world["spec"], store, mb)
    for policy, ref_fn in (
        ("write-around", invalidate_write_around),
        ("write-through", write_through_update),
    ):
        cache_ref = ref_fn(espec, store, store2_ref, cache, ttable, applied)
        store2, cache2, impacted, ovf = build_grw_step(espec, policy)(
            store, cache, ttable, mb
        )
        assert int(ovf) == 0
        for f in store2_ref._fields:
            assert np.array_equal(
                np.asarray(getattr(store2_ref, f)), np.asarray(getattr(store2, f))
            ), f"{policy}: store field {f}"
        assert cache_entries(world["cspec"], cache_ref) == cache_entries(
            world["cspec"], cache2
        ), policy
        # impacted == distinct logical entries the maintenance removed
        occ = lambda c: int(
            jax.numpy.sum((c.valid & (c.chunk == 0)).astype("int32"))
        )
        assert int(impacted) == occ(cache) - occ(cache2), policy
