"""Observability tier (``repro.obs``) — host-side unit tests.

Covers the streaming histogram's quantile accuracy and exact merge
algebra (property-tested; hypothesis-accelerated when the package is
present, seeded-random otherwise), the Span/Tracer accounting + JSONL
export + per-span overhead bound, the event-schema validators and the
``repro.obs.validate`` CLI, the owner-stage attribution math, and the
``ServeTelemetry`` aggregator end to end. The device side of the tier
(the owner-stage block riding the serving step's stacked all-reduce)
is exercised on the 8-device mesh in ``tests/test_sharded_collectives``.
"""

import json
import time

import numpy as np
import pytest

from repro.obs.histogram import LatencyHistogram
from repro.obs.metrics import (
    OWNER_STAGE_FIELDS,
    attribute_step_seconds,
    hit_locality,
    owner_stage_rows,
)
from repro.obs.schema import LATENCY_CLASSES, validate_event
from repro.obs.telemetry import ServeTelemetry
from repro.obs.trace import NULL_TRACER, JsonlTraceWriter, NullTracer, Tracer
from repro.obs.validate import main as validate_cli
from repro.obs.validate import validate_file

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container has no hypothesis — seeded fallback below
    HAVE_HYPOTHESIS = False


# ----------------------------------------------------------------- histogram
def test_histogram_quantile_within_one_bucket_of_sample_quantile():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-6.0, sigma=1.0, size=4000)  # ~ms scale
    h = LatencyHistogram()
    h.record_many(samples)
    res = h.resolution
    for q in (0.5, 0.95, 0.99):
        true = float(np.quantile(samples, q))
        est = h.quantile(q)
        assert true / res <= est <= true * res, (q, est, true)
    assert h.count == samples.size
    assert h.mean == pytest.approx(samples.mean())


def test_histogram_weighted_record_and_edges():
    h = LatencyHistogram()
    h.record(0.01, weight=0)  # non-positive weight is a no-op
    assert h.count == 0
    assert np.isnan(h.quantile(0.5))  # empty histogram
    h.record(0.01, weight=5)
    assert h.count == 5
    # out-of-range samples clamp into the edge buckets, never crash
    h.record(1e-12)
    h.record(1e6)
    assert h.count == 7
    with pytest.raises(ValueError):
        h.quantile(1.5)


def _merge_property(samples_a, samples_b):
    """merge(h_a, h_b) must equal the histogram of the concatenated
    stream exactly (counts), so its quantiles match the concat-sample
    quantiles within one bucket ratio."""
    h_a, h_b, h_cat = (LatencyHistogram() for _ in range(3))
    h_a.record_many(samples_a)
    h_b.record_many(samples_b)
    both = np.concatenate([samples_a, samples_b])
    h_cat.record_many(both)
    merged = h_a.merge(h_b)
    assert np.array_equal(merged.counts, h_cat.counts)
    assert merged.sum_seconds == pytest.approx(h_cat.sum_seconds)
    res = merged.resolution
    ordered = np.sort(both)
    for q in (0.5, 0.95, 0.99):
        # the histogram's inverted-CDF rule selects the bucket holding
        # the rank-ceil(q*n) sample; compare against that same sample
        # (numpy's default linear interpolation is a different estimator
        # and can legitimately land a bucket away at small n)
        rank = max(int(np.ceil(q * ordered.size)), 1) - 1
        true = float(ordered[rank])
        # clamp: samples beyond the bucket range can only be resolved to
        # the edge bucket, which the ratio bound cannot hold for
        if merged.lo * res <= true <= merged.hi / res:
            est = merged.quantile(q)
            assert true / res <= est <= true * res, (q, est, true)
    # in-place merge agrees with the pure one
    assert np.array_equal(h_a.merge_in(h_b).counts, merged.counts)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(
        st.lists(st.floats(1e-6, 50.0, allow_nan=False), min_size=1,
                 max_size=200),
        st.lists(st.floats(1e-6, 50.0, allow_nan=False), min_size=1,
                 max_size=200),
    )
    def test_histogram_merge_equals_concat(sa, sb):
        _merge_property(np.asarray(sa), np.asarray(sb))
else:
    @pytest.mark.parametrize("seed", range(10))
    def test_histogram_merge_equals_concat(seed):
        rng = np.random.default_rng(seed)
        sa = rng.lognormal(-5.0, 2.0, size=int(rng.integers(1, 400)))
        sb = rng.lognormal(-7.0, 1.5, size=int(rng.integers(1, 400)))
        _merge_property(sa, sb)


def test_histogram_merge_rejects_spec_mismatch():
    with pytest.raises(ValueError, match="bucket specs"):
        LatencyHistogram().merge(LatencyHistogram(lo=1e-6))
    with pytest.raises(ValueError, match="bucket specs"):
        LatencyHistogram().merge_in(LatencyHistogram(buckets_per_decade=8))


def test_histogram_dict_roundtrip():
    h = LatencyHistogram()
    h.record_many(np.random.default_rng(1).lognormal(-6, 1, 100))
    h2 = LatencyHistogram.from_dict(json.loads(json.dumps(h.to_dict())))
    assert np.array_equal(h.counts, h2.counts)
    assert h2.quantile(0.95) == h.quantile(0.95)
    bad = h.to_dict()
    bad["counts"] = bad["counts"][:-1]
    with pytest.raises(ValueError, match="counts length"):
        LatencyHistogram.from_dict(bad)


# -------------------------------------------------------------------- tracer
def test_tracer_accounting_and_jsonl_export(tmp_path):
    path = tmp_path / "t.jsonl"
    with JsonlTraceWriter(str(path)) as w:
        tr = Tracer(sink=w)
        for _ in range(3):
            with tr.span("phase_a", shard=1):
                pass
        with tr.span("phase_b"):
            time.sleep(0.002)
    snap = tr.snapshot()
    assert snap["phase_a"]["count"] == 3
    assert snap["phase_b"]["total_s"] >= 0.002
    assert set(snap["phase_a"]) >= {"count", "total_s", "p50", "p99"}
    assert tr.histogram("phase_a").count == 3
    events = [json.loads(l) for l in path.read_text().splitlines()]
    assert len(events) == 4 == w.events_written
    for ev in events:
        assert validate_event(ev) == "span"
    assert events[0]["attrs"] == {"shard": 1}


def test_null_tracer_is_shared_and_stateless():
    assert NullTracer().span("x") is NULL_TRACER.span("y")
    with NULL_TRACER.span("anything", k=1):
        pass
    NULL_TRACER.record("x", 1.0)
    assert NULL_TRACER.snapshot() == {}
    assert not NULL_TRACER.enabled


def test_span_overhead_bound():
    """The serve loop runs several spans per batch; pin the per-span cost
    far below a batch (bound is ~25x the measured ~1-2 us, to stay
    robust on loaded CI runners)."""
    tr = Tracer(sink=None)
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("hot"):
            pass
    per_span = (time.perf_counter() - t0) / n
    assert per_span < 50e-6, f"span overhead {per_span*1e6:.1f} us"
    t0 = time.perf_counter()
    for _ in range(n):
        with NULL_TRACER.span("hot"):
            pass
    per_null = (time.perf_counter() - t0) / n
    assert per_null < 10e-6, f"null-span overhead {per_null*1e6:.1f} us"


# ------------------------------------------------------- owner attribution
def test_attribute_step_seconds_balanced_and_skewed():
    n, S = 4, len(OWNER_STAGE_FIELDS)
    balanced = np.full((n, S), 10, dtype=np.int64)
    per = attribute_step_seconds(0.8, balanced)
    # balanced mesh reproduces the collective-step semantics: every owner
    # observes the full step wall-clock
    assert np.allclose(per, 0.8)
    skewed = np.zeros((n, S), dtype=np.int64)
    skewed[2, 0] = 30  # frontier_rows — all the work at owner 2
    per = attribute_step_seconds(0.8, skewed)
    assert per[2] == pytest.approx(0.8 * n)
    assert np.allclose(np.delete(per, 2), 0.0)
    assert per.sum() == pytest.approx(0.8 * n)  # conserved total
    # zero work anywhere: uniform fallback, never NaN
    assert np.allclose(
        attribute_step_seconds(0.5, np.zeros((n, S), np.int64)), 0.5)


def test_owner_stage_rows_and_hit_locality():
    n, S = 3, len(OWNER_STAGE_FIELDS)
    m = np.zeros((n, S), dtype=np.int64)
    hits = OWNER_STAGE_FIELDS.index("probe_hits")
    miss = OWNER_STAGE_FIELDS.index("miss_rows")
    m[0, hits], m[0, miss] = 9, 1
    m[1, hits], m[1, miss] = 0, 5
    rows = owner_stage_rows(m)
    assert [r["probe_hits"] for r in rows] == [9, 0, 0]
    assert set(rows[0]) == set(OWNER_STAGE_FIELDS)
    loc = hit_locality(m)
    assert loc[0] == pytest.approx(0.9)
    assert loc[1] == 0.0
    assert loc[2] == 0.0  # no probes at all: defined as 0, not NaN
    with pytest.raises(ValueError):
        attribute_step_seconds(1.0, np.zeros((n, S - 1), np.int64))


# ----------------------------------------------------- telemetry aggregator
def _synthetic_stage(n, rng):
    return rng.integers(0, 50, (n, len(OWNER_STAGE_FIELDS))).astype(np.int64)


def test_serve_telemetry_stream_is_schema_valid(tmp_path):
    path = tmp_path / "serve.jsonl"
    n = 4
    tel = ServeTelemetry(n, trace_path=str(path))
    rng = np.random.default_rng(0)
    # spans may fire before the first batch (journal startup checkpoint):
    # meta must still be the first event in the stream
    with tel.tracer.span("checkpoint"):
        pass
    for b in range(6):
        stage = _synthetic_stage(n, rng)
        per = tel.record_gr(
            0.01, {"hits": 3, "misses": 2, "requests": 5}, owner_stage=stage)
        assert per is not None and per.shape == (n,)
        tel.record_grw(0.02)
        tel.record_cp_drain(0.005)
        if b % 2 == 1:
            snap = tel.snapshot(b)
            assert validate_event(snap, shards=n) == "snapshot"
    rep = tel.report()
    assert validate_event(rep, shards=n) == "report"
    assert rep["batches"] == 6
    assert rep["counters"]["hits"] == 18
    for cls in LATENCY_CLASSES:
        assert rep["latency"][cls]["count"] > 0
    tel.close()
    counts = validate_file(str(path), expect_snapshots=3, expect_report=True)
    assert counts["snapshot"] == 3 and counts["report"] == 1
    assert validate_cli([str(path), "--expect-snapshots", "3",
                         "--expect-report"]) == 0


def test_serve_telemetry_without_device_attribution():
    tel = ServeTelemetry(2)  # no trace path: aggregate-only mode
    assert tel.record_gr(0.01, {"hits": 0, "misses": 4}) is None
    rep = tel.report()
    assert rep["latency"]["gr_uncached"]["count"] == 4
    assert rep["latency"]["gr_cached"]["count"] == 0
    assert rep["latency"]["gr_cached"]["p99"] is None  # empty class -> null
    assert validate_event(rep, shards=2) == "report"


def test_validate_cli_rejects_malformed_streams(tmp_path):
    # span before meta
    p1 = tmp_path / "bad1.jsonl"
    p1.write_text('{"type":"span","name":"x","dur_s":0.1,"ts":1.0}\n')
    with pytest.raises(ValueError, match="first event"):
        validate_file(str(p1))
    assert validate_cli([str(p1)]) == 1
    # owner_stage row count contradicting the meta shard count
    tel = ServeTelemetry(3)
    snap = tel.snapshot(0)
    snap["owner_stage"] = snap["owner_stage"][:-1]
    with pytest.raises(ValueError, match="owner rows"):
        validate_event(snap, shards=3)
    # negative counter inside an owner row
    snap2 = tel.snapshot(1)
    snap2["owner_stage"][0]["probe_hits"] = -1
    with pytest.raises(ValueError, match="non-negative"):
        validate_event(snap2, shards=3)
    # unknown event type
    with pytest.raises(ValueError, match="unknown event type"):
        validate_event({"type": "bogus"})
