"""Hot-vertex block migration, host-level semantics (graphstore.migration).

Single-device suite for the splice itself and the policy/engine around it:
``migrate_vertex_rows`` must move EVERY row of a vertex (both orientations,
live and tombstoned) into the destination's recent region in ascending-geid
order while leaving all other rows byte-untouched; the placement must be
reconstructible from store bytes alone (``infer_storage_exceptions`` — what
journal replay uses); the policy must trigger only on real skew; the engine
must journal before it moves and refuse to move during an outage. The
8-device serving-path integration (byte-identity vs the single-host engine,
zero recompiles) lives in test_routing_runtime.py.
"""

from __future__ import annotations

import tempfile

import numpy as np
import pytest

from conftest import build_world
from repro.distributed.routing import RoutingTableHost, base_owner
from repro.graphstore import WriteBehindJournal
from repro.graphstore.journal import REC_MIGRATE
from repro.graphstore.migration import (
    HotSetTracker,
    MigrationEngine,
    MigrationPolicy,
    infer_storage_exceptions,
    migrate_vertex_rows,
    select_migrations,
    vertex_row_counts,
)
from repro.graphstore.partition import default_pspec, partition_store

N = 4


@pytest.fixture(scope="module")
def world():
    spec, store = build_world()
    pspec = default_pspec(spec, N)
    return dict(spec=spec, store=store, pspec=pspec,
                pstore=partition_store(pspec, store))


def _rows(pspec, ps, orient):
    """Per-shard allocated rows of one orientation as comparable tuples:
    (shard, slot, key, other, label, alive, geid, props...)."""
    n, EB = pspec.n_shards, pspec.e_blk_cap
    blk = getattr(ps, orient)
    g = lambda a: np.asarray(a)
    key = g(blk.key).reshape(n, EB)
    other = g(blk.other).reshape(n, EB)
    label = g(blk.label).reshape(n, EB)
    alive = g(blk.alive).reshape(n, EB)
    geid = g(blk.geid).reshape(n, EB)
    props = g(blk.props).reshape(n, EB, -1)
    ln = g(blk.blk_len).astype(np.int64)
    out = []
    for s in range(n):
        for i in range(int(ln[s])):
            out.append((s, i, int(key[s, i]), int(other[s, i]),
                        int(label[s, i]), bool(alive[s, i]),
                        int(geid[s, i]), tuple(props[s, i].tolist())))
    return out


def _row_payload(rows):
    """Rows minus their (shard, slot) position — the migration-invariant."""
    return sorted(r[2:] for r in rows)


def test_migrate_moves_all_rows_to_dst_recent_region(world):
    pspec, ps = world["pspec"], world["pstore"]
    vid = 0  # native owner 0; has out- and in-edges in the fixture graph
    dst = 2
    before_out = _rows(pspec, ps, "out")
    before_inc = _rows(pspec, ps, "inc")
    assert int(vertex_row_counts(pspec, ps, [vid])[0]) > 0
    ps2 = migrate_vertex_rows(pspec, ps, [(vid, dst)])
    for orient, before in (("out", before_out), ("inc", before_inc)):
        after = _rows(pspec, ps2, orient)
        # the row payload is conserved exactly — a splice, not a rewrite
        assert _row_payload(after) == _row_payload(before)
        moved = [r for r in after if r[2] == vid]
        stayed_before = [r for r in before if r[2] != vid]
        stayed_after = [r for r in after if r[2] != vid]
        # untouched vertices keep their exact (shard, slot) positions
        assert stayed_before == stayed_after
        if not moved:
            continue
        csr = np.asarray(getattr(ps2, orient).csr_len).astype(np.int64)
        assert all(r[0] == dst for r in moved)
        # recent region only (slot >= csr_len), ascending geid
        assert all(r[1] >= int(csr[dst]) for r in moved)
        geids = [r[6] for r in sorted(moved, key=lambda r: r[1])]
        assert geids == sorted(geids)
    # placement is reconstructible from the bytes alone (replay's view)
    assert infer_storage_exceptions(pspec, ps2) == {vid: dst}


def test_migrate_round_trip_restores_native_placement(world):
    pspec, ps = world["pspec"], world["pstore"]
    vid, dst = 5, 0  # native owner 1
    assert int(base_owner(vid, N)) == 1
    ps2 = migrate_vertex_rows(pspec, ps, [(vid, dst)])
    assert infer_storage_exceptions(pspec, ps2) == {vid: dst}
    ps3 = migrate_vertex_rows(pspec, ps2, [(vid, 1)])
    assert infer_storage_exceptions(pspec, ps3) == {}
    # payload conserved across the round trip
    for orient in ("out", "inc"):
        assert _row_payload(_rows(pspec, ps3, orient)) == _row_payload(
            _rows(pspec, ps, orient)
        )


def test_multi_move_round_is_deterministic(world):
    pspec, ps = world["pspec"], world["pstore"]
    moves = [(0, 3), (5, 2)]
    a = migrate_vertex_rows(pspec, ps, moves)
    b = migrate_vertex_rows(pspec, ps, moves)
    for orient in ("out", "inc"):
        for f in a.out._fields:
            assert np.array_equal(
                np.asarray(getattr(getattr(a, orient), f)),
                np.asarray(getattr(getattr(b, orient), f)),
            ), (orient, f)
    assert infer_storage_exceptions(pspec, a) == {0: 3, 5: 2}


def test_hot_set_tracker_decays_and_bounds():
    tr = HotSetTracker(decay=0.5, cap=3)
    tr.observe([7, 7, 7, 2])
    assert tr.hottest(1)[0][0] == 7
    tr.observe([2, 2, 2, 2])          # 7 decays to 1.5, 2 rises to 4.5
    assert tr.hottest(1)[0][0] == 2
    tr.observe([1, 3, 4])             # cap=3 prunes the coldest
    assert len(tr.hottest(10)) == 3
    assert tr.heat(-1) == 0.0


def test_select_migrations_triggers_only_on_skew(world):
    pspec, ps = world["pspec"], world["pstore"]
    rhost = RoutingTableHost(N)
    tr = HotSetTracker()
    tr.observe([0] * 50)  # vertex 0 is hot; its owner is shard 0
    pol = MigrationPolicy(load_share_trigger=1.25, min_heat=1.0)
    # balanced load: no move
    assert select_migrations(pol, tr, rhost, pspec, ps, [10, 10, 10, 10]) == []
    # shard 0 hot: vertex 0 re-homes to the least-loaded owner
    moves = select_migrations(pol, tr, rhost, pspec, ps, [40, 10, 10, 5])
    assert moves == [(0, 3)]
    # zero load: no signal, no move
    assert select_migrations(pol, tr, rhost, pspec, ps, [0, 0, 0, 0]) == []
    # a full table refuses new exceptions
    tiny = RoutingTableHost(N, cap=1)
    tiny.set_storage_owner(9, 2)
    assert select_migrations(pol, tr, tiny, pspec, ps, [40, 10, 10, 5]) == []


class _FakeDetector:
    def __init__(self, down):
        self._down = np.asarray(down, bool)

    def down_mask(self):
        return self._down


def test_engine_journals_before_moving_and_defers_during_outage(world):
    pspec, ps = world["pspec"], world["pstore"]
    root = tempfile.mkdtemp(prefix="migration-journal-")
    j = WriteBehindJournal(root, N)
    rhost = RoutingTableHost(N)
    eng = MigrationEngine(
        pspec, rhost, journal=j,
        detector=_FakeDetector([False, True, False, False]),
    )
    eng.observe([0] * 50)
    # an outage defers the round entirely — no journal record, no move
    ps1, moves = eng.step(ps, [40, 10, 10, 5])
    assert moves == [] and eng.deferred_rounds == 1
    assert not rhost.has_exceptions()
    assert j.read_records() == [] and not j._pending
    # healthy: journal-first, then splice, then table update
    eng.detector = _FakeDetector([False] * N)
    ps2, moves = eng.step(ps1, [40, 10, 10, 5])
    assert moves == [(0, 3)]
    assert rhost.storage_owner(0) == 3
    assert infer_storage_exceptions(pspec, ps2) == {0: 3}
    j.flush()
    recs = [r for r in j.read_records() if r.rtype == REC_MIGRATE]
    assert len(recs) == 1
    m = eng.metrics()
    assert m["migration_rounds"] == 1 and m["migrated_vertices"] == 1
    assert m["migrated_rows"] > 0 and m["table_epoch"] == rhost.epoch
