"""Durability + hitless elasticity on the 8-virtual-device sharded runtime.

Two pins, run in subprocesses so XLA_FLAGS can create the host devices
before jax initializes (same pattern as ``test_maintenance_runtime``):

- **Crash/restart byte-identity** — a gR/gRW stream with on-device gated
  compaction (including a tombstone purge enabled behind the liveness
  epoch), a host-scheduled compaction, and a mid-stream capacity growth is
  journaled write-behind; after a simulated kill (fresh runtime + journal
  objects, torn bytes at the log tail), ``journal.replay`` reconstructs
  the partitioned store byte-for-byte and subsequent gR results/metrics
  are identical to the uninterrupted run.

- **Hitless hot-swap identity** — while the next capacity tier's steps
  compile on a background thread, serving continues on the current tier;
  the swap at a batch boundary changes no served byte vs a never-grown
  control run, the new tier's steps are compiled *before* the swap
  (double-buffered), and the outgoing tier's compiled steps survive it
  (tier-scoped invalidation).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CRASH_RECOVERY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    import numpy as np
    import jax
    from conftest import build_world, enabled_ttable, common_watchlist_plan
    from repro.core import CacheSpec, EngineSpec
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedTxnRuntime
    from repro.graphstore import (
        DeviceGate, WriteBehindJournal, make_mutation_batch, replay,
    )

    spec, store = build_world()
    cspec = CacheSpec(capacity=1024, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, sc, qp = enabled_ttable()
    mesh = flat_mesh(8)
    plan = common_watchlist_plan()
    root = os.path.join(tempfile.mkdtemp(), "journal")
    roots = np.array([0, 3, 5, 6, 7, 11], np.int32)
    gate = DeviceGate(recent_fill_frac=0.0)  # compact at every commit

    rt = ShardedTxnRuntime(espec, mesh, route_cap_factor=None, blk_slack=1.0)
    ps = rt.partition_store(store)
    cache = rt.empty_cache()
    j = WriteBehindJournal(root, rt.n)
    j.start(interval=0.001)  # async coalescing flusher behind the stream
    j.checkpoint(ps, e_blk_cap=rt.pspec.e_blk_cap,
                 recent_blk_cap=rt.pspec.recent_blk_cap, store_version=0)

    # batch 1: a pinned gR snapshot makes purge UNSAFE for the next commit
    pin = j.epochs.pin()
    rt.run_gr_tx_batch(ps, cache, ttable, plan, roots)
    mb1 = make_mutation_batch(
        spec, new_edges=[(0, 11, 0, [1]), (3, 6, 0, [0])],
        set_vprops=[(7, 0, 1)],
    )
    ps, cache, m1 = rt.run_grw_tx(ps, cache, ttable, mb1, gate=gate, journal=j)
    assert m1["device_compactions"] > 0, m1
    assert not j.epochs.safe_to_purge(j.epochs.current, j)
    j.epochs.release(pin)
    # still unsafe: the checkpoint doesn't cover the current version yet
    assert not j.epochs.safe_to_purge(j.epochs.current, j)
    j.checkpoint(ps, e_blk_cap=rt.pspec.e_blk_cap,
                 recent_blk_cap=rt.pspec.recent_blk_cap,
                 store_version=int(jax.device_get(ps.version)))
    assert j.epochs.safe_to_purge(j.epochs.current, j)

    # batch 2: tombstones + purge enabled behind the liveness epoch
    mb2 = make_mutation_batch(spec, del_edges=[2, 5], del_vertices=[9])
    ps, cache, m2 = rt.run_grw_tx(
        ps, cache, ttable, mb2, gate=gate._replace(purge=True), journal=j,
    )
    assert m2["device_compactions"] > 0, m2
    assert m2["journal_lag_batches"] <= 2, m2

    # mid-stream capacity growth, journaled at its point in commit order
    ps = rt.grow_blocks(ps, rt.pspec.e_blk_cap + 13)
    j.append_grow(rt.pspec.e_blk_cap, rt.pspec.recent_blk_cap)

    # batch 3: write-through traffic on the grown tier
    mb3 = make_mutation_batch(
        spec, new_edges=[(1, 12, 0, [1]), (2, 13, 0, [0])],
        set_eprops=[(1, 0, 0)],
    )
    ps, cache, m3 = rt.run_grw_tx(
        ps, cache, ttable, mb3, policy="write-through", gate=gate, journal=j,
    )
    j.stop(final_flush=True)
    # kill mid-write: garbage past the last durable frame (torn tail)
    with open(j.log_path, "ab") as f:
        f.write(b"GJL1" + b"\\x01" * 9)

    res_pre, miss_pre, met_pre = rt.run_gr_tx_batch(
        ps, rt.empty_cache(), ttable, plan, roots
    )

    # ---- crash: fresh runtime + journal objects over the same root
    rt2 = ShardedTxnRuntime(espec, mesh, route_cap_factor=None, blk_slack=1.0)
    j2 = WriteBehindJournal(root, rt2.n)
    ps2, last, info = replay(j2, rt2, ttable)
    assert info == {"replayed_commits": 2, "replayed_compactions": 0,
                    "replayed_growths": 1, "replayed_migrations": 0}, info
    assert rt2.pspec == rt.pspec, (rt2.pspec, rt.pspec)
    for a, b in zip(jax.tree_util.tree_leaves(ps2),
                    jax.tree_util.tree_leaves(ps)):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \\
            "replayed store diverges from the pre-crash store"
    res_post, miss_post, met_post = rt2.run_gr_tx_batch(
        ps2, rt2.empty_cache(), ttable, plan, roots
    )
    assert np.array_equal(res_pre, res_post)
    assert met_pre == met_post, (met_pre, met_post)
    key = lambda ms: sorted(
        (m.tpl_idx, m.root, tuple(m.params.tolist()), m.read_version)
        for m in ms
    )
    assert key(miss_pre) == key(miss_post)
    print("CRASH_RECOVERY_OK")
    """
)

HITLESS_SWAP = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from conftest import (
        build_world, enabled_ttable, common_watchlist_plan, TPL_META,
    )
    from repro.core import CacheSpec, EngineSpec, cache_entries
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import (
        ShardedMissDrain, ShardedTxnRuntime, _plan_key,
    )
    from repro.graphstore import DeviceGate, make_mutation_batch

    spec, store = build_world()
    cspec = CacheSpec(capacity=1024, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, sc, qp = enabled_ttable()
    mesh = flat_mesh(8)
    plan = common_watchlist_plan()
    gate = DeviceGate(recent_fill_frac=0.0)
    roots = np.array([5, 6, 7, 8, 9], np.int32)
    bucket = 8  # bucket_for(5 roots) on 8 shards

    class Run:
        def __init__(self, swap):
            self.rt = ShardedTxnRuntime(
                espec, mesh, route_cap_factor=None, blk_slack=1.0)
            self.ps = self.rt.partition_store(store)
            self.cache = self.rt.empty_cache()
            self.swap = swap
        def gr(self, r):
            return self.rt.run_gr_tx_batch(
                self.ps, self.cache, ttable, plan, r)
        def grw(self, mb):
            g = gate if self.swap else None
            self.ps, self.cache, m = self.rt.run_grw_tx(
                self.ps, self.cache, ttable, mb, gate=g)
            return m

    A, B = Run(True), Run(False)  # A hot-swaps mid-stream, B never grows

    def check_gr(r):
        ra, ma_, mta = A.gr(r)
        rb, mb_, mtb = B.gr(r)
        assert np.array_equal(ra, rb)
        assert mta == mtb, (mta, mtb)
        return ra

    def check_grw(mb):
        ma_, mb_ = A.grw(mb), B.grw(mb)
        assert ma_["impacted_keys"] == mb_["impacted_keys"], (ma_, mb_)
        assert cache_entries(cspec, A.cache) == cache_entries(cspec, B.cache)

    check_gr(roots)
    old_pspec = A.rt.pspec
    old_step = A.rt._gr(plan, bucket)

    # background pre-compile of the doubled tier; serving continues NOW
    h = A.rt.precompile_next_tier(
        old_pspec.e_blk_cap * 2, ttable,
        gr_plans=[(plan, bucket)],
        grw_policies=[("write-around", gate)],
        compact_purges=(False,),
        pop_steps=[(TPL_META, 0, 8), (TPL_META, 1, 8)],
    )
    mb1 = make_mutation_batch(
        spec, new_edges=[(0, 11, 0, [1]), (3, 6, 0, [0])],
        set_vprops=[(7, 0, 1)], del_edges=[2],
    )
    check_grw(mb1)  # during-precompile traffic, byte-identical
    check_gr(np.array([0, 3, 5, 6, 7, 11], np.int32))
    assert A.rt.pspec == old_pspec  # still serving the old tier
    h.ready.wait(1200)
    assert h.error is None, h.error
    assert h.compiled >= 6, h.compiled
    # double-buffered: the next tier's gR step exists BEFORE the swap
    # (cache keys are (pspec, plan, bucket, route_caps) — match the prefix)
    def gr_keys(ps_):
        return [k for k in A.rt._gr_fns
                if k[:3] == (ps_, _plan_key(plan), bucket)]
    nxt_keys = gr_keys(h.pspec)
    assert nxt_keys

    A.ps, info = A.rt.swap_to_next_tier(A.ps)
    assert A.rt.swap_events == 1
    assert A.rt.pspec.e_blk_cap == old_pspec.e_blk_cap * 2
    assert info["swap_seconds"] < info["precompile_seconds"], info
    # tier-scoped invalidation: the outgoing tier's compiled step survives
    assert [A.rt._gr_fns[k] for k in gr_keys(old_pspec)] == [old_step]
    # and the post-swap resolve returns the precompiled program (no retrace)
    assert A.rt._gr(plan, bucket) is A.rt._gr_fns[nxt_keys[0]]

    # post-swap traffic + CP population, still byte-identical to control
    check_grw(make_mutation_batch(
        spec, new_edges=[(1, 12, 0, [1]), (2, 13, 0, [0])]))
    missA = check_gr(np.array([1, 2, 5, 12, 13], np.int32))
    for r in (A, B):
        drain = ShardedMissDrain(r.rt, TPL_META)
        _, miss, _ = r.rt.run_gr_tx_batch(
            r.ps, r.rt.empty_cache(), ttable, plan, roots)
        drain.push(miss)
        r.cache = drain.drain(r.ps, r.ps, r.cache, ttable)
    assert cache_entries(cspec, A.cache) == cache_entries(cspec, B.cache)
    check_gr(roots)
    print("HITLESS_SWAP_OK")
    """
)


def _run(script, token, timeout=1800):
    env = dict(
        os.environ,
        PYTHONPATH=os.pathsep.join(
            [os.path.join(REPO, "src"), os.path.join(REPO, "tests")]
        ),
    )
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert token in out.stdout, out.stdout + out.stderr


def test_crash_restart_replay_is_byte_identical():
    _run(CRASH_RECOVERY, "CRASH_RECOVERY_OK")


def test_hot_swap_is_hitless_and_tier_scoped():
    _run(HITLESS_SWAP, "HITLESS_SWAP_OK")
