"""Shared fixtures: the watch-list/listing world of the paper's Figure 1."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import (
    ANY_LABEL,
    DIR_IN,
    DIR_OUT,
    OP_EQ,
    WILDCARD,
    CacheSpec,
    EngineSpec,
    Hop,
    QueryPlan,
    Template,
    empty_cache,
    make_pred,
    make_template_table,
    FINAL_IDS,
)
from repro.core.lifecycle import GraphQP, ServiceCoordinator
from repro.graphstore import StoreSpec, ingest
from repro.utils import PROP_MISSING

MISSING = int(PROP_MISSING)

# labels
L_WATCHLIST, L_LISTING = 0, 1
E_INCLUDES = 0
# props: vprop0 = Status (listings), vprop1 = user-visible ListingId (unique)
P_STATUS, P_LISTING_ID = 0, 1
# eprop0 = IsActive
P_ISACTIVE = 0


def build_world(n_watchlists=4, n_listings=12, seed=0, spec=None):
    """Random small watch-list world; returns (spec, store, numpy arrays)."""
    rng = np.random.default_rng(seed)
    spec = spec or StoreSpec(v_cap=64, e_cap=512, n_vprops=2, n_eprops=1, recent_cap=64)
    nv = n_watchlists + n_listings
    vlabels = np.array([L_WATCHLIST] * n_watchlists + [L_LISTING] * n_listings)
    vprops = np.full((nv, spec.n_vprops), MISSING, np.int64)
    listing_ids = np.arange(n_watchlists, nv)
    vprops[listing_ids, P_STATUS] = rng.integers(0, 2, n_listings)
    vprops[listing_ids, P_LISTING_ID] = 1000 + listing_ids  # unique
    es, ed, ep = [], [], []
    for w in range(n_watchlists):
        members = rng.choice(listing_ids, size=rng.integers(2, n_listings), replace=False)
        for m in members:
            es.append(w)
            ed.append(int(m))
            ep.append([int(rng.integers(0, 2))])
    elabels = [E_INCLUDES] * len(es)
    store = ingest(spec, vlabels, vprops, es, ed, elabels, np.array(ep))
    return spec, store


SQ1 = Template(  # watch-list -includes(IsActive=?)-> listing(Status=?)
    name="SQ1",
    direction=DIR_OUT,
    edge_label=E_INCLUDES,
    root=(L_WATCHLIST, []),
    edge=(ANY_LABEL, [(P_ISACTIVE, OP_EQ, WILDCARD)]),
    leaf=(L_LISTING, [(P_STATUS, OP_EQ, WILDCARD)]),
)
SQ2 = Template(  # listing <-includes(IsActive=?)- watch-list   (reverse hop)
    name="SQ2",
    direction=DIR_IN,
    edge_label=E_INCLUDES,
    root=(L_LISTING, []),
    edge=(ANY_LABEL, [(P_ISACTIVE, OP_EQ, WILDCARD)]),
    leaf=(L_WATCHLIST, []),
)
TEMPLATES = [SQ1, SQ2]
TPL_META = {0: (DIR_OUT, E_INCLUDES), 1: (DIR_IN, E_INCLUDES)}


def sq1_hop(is_active=1, status=0):
    return Hop(
        direction=DIR_OUT,
        edge_label=E_INCLUDES,
        pr=make_pred(L_WATCHLIST, []),
        pe=make_pred(ANY_LABEL, [(P_ISACTIVE, OP_EQ, WILDCARD)]),
        pl=make_pred(L_LISTING, [(P_STATUS, OP_EQ, WILDCARD)]),
        tpl_idx=0,
        params=np.array([is_active, MISSING, MISSING, status, MISSING, MISSING], np.int32),
    )


def sq2_hop(is_active=1):
    return Hop(
        direction=DIR_IN,
        edge_label=E_INCLUDES,
        pr=make_pred(L_LISTING, []),
        pe=make_pred(ANY_LABEL, [(P_ISACTIVE, OP_EQ, WILDCARD)]),
        pl=make_pred(L_WATCHLIST, []),
        tpl_idx=1,
        params=np.array([is_active, MISSING, MISSING, MISSING, MISSING, MISSING], np.int32),
    )


def enabled_ttable():
    ttable = make_template_table(TEMPLATES)
    qp = GraphQP("qp0")
    sc = ServiceCoordinator([qp])
    for t in range(len(TEMPLATES)):
        sc.register(t)
        sc.enable(t)
    assert sc.check_safety()
    return qp.ttable_masks(ttable, len(TEMPLATES)), sc, qp


@pytest.fixture
def world():
    spec, store = build_world()
    cspec = CacheSpec(capacity=1024, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, sc, qp = enabled_ttable()
    return dict(
        spec=spec,
        store=store,
        espec=espec,
        cspec=cspec,
        cache=empty_cache(cspec),
        ttable=ttable,
        sc=sc,
        qp=qp,
    )


def fig1_plan(is_active=1, status=0):
    """The paper's Figure 1 query."""
    return QueryPlan(hops=(sq1_hop(is_active, status),), final=FINAL_IDS)


def common_watchlist_plan():
    """§2's two-hop query: other active listings sharing a watch-list."""
    return QueryPlan(
        hops=(sq2_hop(1), sq1_hop(1, 0)),
        final=FINAL_IDS,
        post_filter=("prop_neq_root", P_LISTING_ID),
    )
