"""THE system invariant (hypothesis): strong consistency of the cache.

After *any* interleaving of gR-Txs, asynchronous cache population, and
gRW-Txs (write-around or write-through), every entry the cache will serve
must equal a fresh recomputation of its one-hop sub-query against the
current database state — the paper's "no stale or inconsistent results"
requirement. We enumerate the full reachable key space every step and
compare against the pure-python oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional test dep (requirements-test.txt)
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from conftest import (
    E_INCLUDES,
    L_LISTING,
    L_WATCHLIST,
    MISSING,
    P_ISACTIVE,
    P_STATUS,
    TPL_META,
    build_world,
    enabled_ttable,
    fig1_plan,
    sq2_hop,
)
from repro.core import (
    CacheSpec,
    EngineSpec,
    GraphEngine,
    QueryPlan,
    cache_lookup,
    empty_cache,
    run_grw_tx,
    FINAL_IDS,
)
from repro.core.keys import PARAM_LEN
from repro.core.oracle import HostStore, onehop_oracle
from repro.core.population import CachePopulator
from repro.graphstore import compact, make_mutation_batch

N_W, N_L = 3, 6
NV = N_W + N_L

op = st.one_of(
    st.tuples(st.just("query"), st.integers(0, NV - 1), st.integers(0, 1), st.integers(0, 1)),
    st.tuples(st.just("query2"), st.integers(N_W, NV - 1), st.integers(0, 1)),
    st.tuples(st.just("populate")),
    st.tuples(st.just("set_status"), st.integers(N_W, NV - 1), st.integers(0, 1)),
    st.tuples(st.just("set_isactive"), st.integers(0, 63), st.integers(0, 1)),
    st.tuples(st.just("add_edge"), st.integers(0, N_W - 1), st.integers(N_W, NV - 1), st.integers(0, 1)),
    st.tuples(st.just("del_edge"), st.integers(0, 63)),
    st.tuples(st.just("del_vertex"), st.integers(0, NV - 1)),
    st.tuples(st.just("compact")),
)


def _enumerate_keys(espec, cache, ttable, hs, v_cap):
    """Check every reachable cache key against the oracle."""
    from conftest import sq1_hop

    combos0 = [(ia, stt) for ia in (0, 1) for stt in (0, 1)]
    roots = np.arange(v_cap, dtype=np.int32)
    for ia, stt in combos0:
        params = np.full((v_cap, PARAM_LEN), MISSING, np.int32)
        params[:, 0] = ia
        params[:, 3] = stt
        hit, vals, lmask, _ = cache_lookup(
            espec.cache, cache, jnp.zeros(v_cap, jnp.int32), jnp.asarray(roots), jnp.asarray(params)
        )
        hit = np.asarray(hit)
        vals = np.asarray(vals)
        lmask = np.asarray(lmask)
        h = sq1_hop(ia, stt)
        for r in np.nonzero(hit)[0]:
            got = set(vals[r][lmask[r]].tolist())
            want = onehop_oracle(
                hs, h.direction, h.edge_label, h.pr, h.pe, h.pl, int(r), h.params
            )
            assert got == want, f"SQ1 root={r} ia={ia} st={stt}: cache {got} != db {want}"
    for ia in (0, 1):
        params = np.full((v_cap, PARAM_LEN), MISSING, np.int32)
        params[:, 0] = ia
        hit, vals, lmask, _ = cache_lookup(
            espec.cache, cache, jnp.ones(v_cap, jnp.int32), jnp.asarray(roots), jnp.asarray(params)
        )
        hit = np.asarray(hit)
        vals = np.asarray(vals)
        lmask = np.asarray(lmask)
        h = sq2_hop(ia)
        for r in np.nonzero(hit)[0]:
            got = set(vals[r][lmask[r]].tolist())
            want = onehop_oracle(
                hs, h.direction, h.edge_label, h.pr, h.pe, h.pl, int(r), h.params
            )
            assert got == want, f"SQ2 root={r} ia={ia}: cache {got} != db {want}"


@settings(
    max_examples=8,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
@given(
    seed=st.integers(0, 10_000),
    ops=st.lists(op, min_size=1, max_size=12),
    policy=st.sampled_from(["write-around", "write-through"]),
)
def test_cache_always_consistent(seed, ops, policy):
    spec, store = build_world(N_W, N_L, seed=seed)
    cspec = CacheSpec(capacity=512, probes=8, max_leaves=8, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=16, frontier=16)
    ttable, _, _ = enabled_ttable()
    cache = empty_cache(cspec)
    pop = CachePopulator(espec, TPL_META)
    engines = {}

    def engine(key, plan):
        if key not in engines:
            engines[key] = GraphEngine(espec, plan, use_cache=True)
        return engines[key]

    for o in ops:
        kind = o[0]
        if kind == "query":
            _, root, ia, stt = o
            eng = engine(("q1", ia, stt), fig1_plan(ia, stt))
            res, misses, _ = eng.run(store, cache, ttable, np.array([root], np.int32))
            pop.queue.push(misses)
            hs = HostStore(store)
            hop = fig1_plan(ia, stt).hops[0]
            want = onehop_oracle(
                hs, hop.direction, hop.edge_label, hop.pr, hop.pe, hop.pl, root, hop.params
            )
            got = set(res[0][res[0] >= 0].tolist())
            assert got == want
        elif kind == "query2":
            _, root, ia = o
            plan = QueryPlan(hops=(sq2_hop(ia),), final=FINAL_IDS)
            eng = engine(("q2", ia), plan)
            _, misses, _ = eng.run(store, cache, ttable, np.array([root], np.int32))
            pop.queue.push(misses)
        elif kind == "populate":
            cache = pop.drain(store, store, cache, ttable)
        elif kind == "set_status":
            mb = make_mutation_batch(spec, set_vprops=[(o[1], P_STATUS, o[2])])
            store, cache, _ = run_grw_tx(espec, store, cache, ttable, mb, policy=policy)
        elif kind == "set_isactive":
            eid = o[1] % max(1, int(store.e_len))
            mb = make_mutation_batch(spec, set_eprops=[(eid, P_ISACTIVE, o[2])])
            store, cache, _ = run_grw_tx(espec, store, cache, ttable, mb, policy=policy)
        elif kind == "add_edge":
            mb = make_mutation_batch(
                spec, new_edges=[(o[1], o[2], E_INCLUDES, [o[3]])]
            )
            store, cache, _ = run_grw_tx(espec, store, cache, ttable, mb, policy=policy)
        elif kind == "del_edge":
            eid = o[1] % max(1, int(store.e_len))
            mb = make_mutation_batch(spec, del_edges=[eid])
            store, cache, _ = run_grw_tx(espec, store, cache, ttable, mb, policy=policy)
        elif kind == "del_vertex":
            mb = make_mutation_batch(spec, del_vertices=[o[1]])
            store, cache, _ = run_grw_tx(espec, store, cache, ttable, mb, policy=policy)
        elif kind == "compact":
            store = compact(spec, store)
        # the invariant — after every single operation
        _enumerate_keys(espec, cache, ttable, HostStore(store), NV)
    # final drain + check
    cache = pop.drain(store, store, cache, ttable)
    _enumerate_keys(espec, cache, ttable, HostStore(store), NV)
