"""Per-architecture smoke tests: reduced config, one real step on CPU,
asserting output shapes and no NaNs. The FULL configs are exercised only by
the dry-run (launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as configs_pkg
from repro.optim import adamw

LM_ARCHS = ["glm4-9b", "yi-6b", "gemma3-4b", "kimi-k2-1t-a32b", "grok-1-314b"]
GNN_ARCHS = ["pna", "nequip", "gat-cora", "egnn"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_and_decode(arch):
    from repro.lm import (
        decode_step,
        init_kv_cache,
        init_params,
        loss_fn,
        train_step,
    )

    mod = configs_pkg.get_arch(arch)
    cfg = mod.SMOKE
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab)
    opt = adamw(1e-3)
    step = jax.jit(train_step(cfg, opt))
    p2, st2, m = step(params, opt.init(params), tokens, labels)
    assert np.isfinite(float(m["loss"])), arch
    # one decode step
    cache = init_kv_cache(cfg, B, S)
    nt, cache2 = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))(
        params, cache, tokens[:, :1], jnp.int32(0)
    )
    assert nt.shape == (B, 1) and int(nt.min()) >= 0
    assert cache2.k.shape == cache.k.shape


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train(arch):
    from repro.gnn import random_graph_batch
    from repro.gnn.models import train_step, init_params

    mod = configs_pkg.get_arch(arch)
    cfg = mod.SMOKE
    key = jax.random.PRNGKey(1)
    g = random_graph_batch(
        key, 48, 128, cfg.d_in, n_classes=cfg.n_classes,
        positions=cfg.needs_positions, n_graphs=4 if cfg.needs_positions else 1,
    )
    params = init_params(cfg, key)
    opt = adamw(1e-3)
    step = jax.jit(train_step(cfg, opt))
    targets = jnp.ones(4) if cfg.kind in ("egnn", "nequip") else None
    p2, st2, m = step(params, opt.init(params), g, targets)
    assert np.isfinite(float(m["loss"])), arch
    for leaf in jax.tree_util.tree_leaves(p2):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


def test_recsys_smoke_train_and_serve():
    from repro.recsys import TwoTowerConfig
    from repro.recsys.twotower import init_params, retrieval_step, serve_step, train_step

    mod = configs_pkg.get_arch("two-tower-retrieval")
    cfg = mod.SMOKE
    key = jax.random.PRNGKey(2)
    p = init_params(cfg, key)
    B, K = 8, cfg.bag_size
    batch = dict(
        user_bags=jax.random.randint(key, (B, cfg.user_fields, K), 0, cfg.user_vocab),
        user_mask=jnp.ones((B, cfg.user_fields, K), bool),
        item_bags=jax.random.randint(key, (B, cfg.item_fields, K), 0, cfg.item_vocab),
        item_mask=jnp.ones((B, cfg.item_fields, K), bool),
        item_logq=jnp.zeros(B),
    )
    opt = adamw(1e-2)
    step = jax.jit(train_step(cfg, opt))
    p2, _, m = step(p, opt.init(p), batch)
    assert np.isfinite(float(m["loss"]))
    emb = jax.random.normal(key, (B, 10, cfg.embed_dim))
    scores, best = serve_step(cfg, p2, batch["user_bags"], batch["user_mask"], emb)
    assert scores.shape == (B, 10) and bool(jnp.all(jnp.isfinite(scores)))
    corpus = jax.random.normal(key, (256, cfg.embed_dim))
    v, i = retrieval_step(cfg, p2, batch["user_bags"][:1], batch["user_mask"][:1], corpus, k=5)
    assert v.shape == (1, 5)


def test_graph_serve_smoke_single_shard():
    """The paper-arch serve cell on a 1-device mesh, with a known graph:
    the capacity config lowered onto the partitioned runtime end to end."""
    from repro.distributed.graph_serve import (
        ShardedTxnRuntime, config_espec, config_plan_and_ttable,
    )
    from repro.graphstore.store import ingest
    from repro.launch.mesh import make_debug_mesh

    mod = configs_pkg.get_arch("ecommerce-graph")
    cfg = mod.SMOKE
    mesh = make_debug_mesh(1, 1)
    espec = config_espec(cfg)
    plan, ttable = config_plan_and_ttable(cfg)
    V = cfg.v_total
    # vertex 0 -> leaves 1, 2, 3 (edge prop 1,1,0), leaf props 0, 1, 0
    vlabels = np.zeros(V, np.int32)
    vprops = np.zeros((V, cfg.n_vprops), np.int64)
    vprops[2, cfg.leaf_prop] = 1
    store = ingest(
        espec.store, vlabels, vprops, [0, 0, 0], [1, 2, 3], [0, 0, 0],
        np.array([[1], [1], [0]]),
    )
    rt = ShardedTxnRuntime(espec, mesh)
    pstore = rt.partition_store(store)
    cache = rt.empty_cache()
    roots = np.zeros(8, np.int32)  # all query vertex 0
    res, misses, met = rt.run_gr_tx_batch(pstore, cache, ttable, plan, roots)
    # expected leaves: edge prop==1 and leaf prop==0 -> only vertex 1
    for row in res:
        assert set(row[row >= 0].tolist()) == {1}, row
    assert met["hits"] == 0 and met["route_overflow"] == 0
    assert met["misses"] >= 1 and len(misses) >= 1
