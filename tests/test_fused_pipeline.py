"""Equivalence of the fused device hop pipeline with the legacy
host-orchestrated path, of the vectorized cache insert with the sequential
reference, and of the Pallas cache probe with its jnp oracle.

These are the guarantees that let the fused path be the default: everything
the engine returns — results, miss records, metrics — must be byte-identical
between the two execution strategies (only ``host_syncs`` may differ, by
design), and the cache write path must be indistinguishable from walking the
batch sequentially even under intra-batch collisions and evictions.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import (
    MISSING,
    P_LISTING_ID,
    TPL_META,
    build_world,
    common_watchlist_plan,
    enabled_ttable,
    fig1_plan,
)
from repro.core import (
    CacheSpec,
    EngineSpec,
    FINAL_COUNT,
    FINAL_VALUES,
    GraphEngine,
    cache_insert,
    cache_lookup,
    empty_cache,
    rewrite_plan,
)
from repro.core.cache import cache_insert_sequential
from repro.core.keys import PARAM_LEN
from repro.core.population import CachePopulator
from repro.kernels.cache_probe.ops import cache_probe
from repro.kernels.cache_probe.ref import cache_probe_ref
from repro.utils import segmented_dedup_merge, sort_dedup_masked


def _assert_runs_equal(out_fused, out_host, ctx=""):
    rf, mf, metf = out_fused
    rh, mh, meth = out_host
    assert np.array_equal(rf, rh), f"{ctx}: results differ"
    assert len(mf) == len(mh), f"{ctx}: miss counts differ"
    for a, b in zip(mf, mh):
        assert a.tpl_idx == b.tpl_idx and a.root == b.root, ctx
        assert np.array_equal(a.params, b.params), ctx
        assert a.read_version == b.read_version, ctx
    # host_syncs differs by design: 1 fused vs 2 + per-hop on the host path
    kf = {k: v for k, v in metf.items() if k != "host_syncs"}
    kh = {k: v for k, v in meth.items() if k != "host_syncs"}
    assert kf == kh, f"{ctx}: metrics differ: {kf} vs {kh}"
    assert metf["host_syncs"] == 1, ctx
    assert meth["host_syncs"] > metf["host_syncs"], ctx


def _engines(world, plan, use_cache=True):
    return (
        GraphEngine(world["espec"], plan, use_cache=use_cache, fused=True),
        GraphEngine(world["espec"], plan, use_cache=use_cache, fused=False),
    )


@pytest.mark.parametrize("use_cache", [True, False])
def test_fused_matches_host_cold_and_warm(world, use_cache):
    plan = fig1_plan()
    ef, eh = _engines(world, plan, use_cache)
    roots = np.array([0, 1, 2, 3], np.int32)
    args = (world["store"], world["cache"], world["ttable"], roots)
    out_f, out_h = ef.run(*args), eh.run(*args)
    _assert_runs_equal(out_f, out_h, f"cold use_cache={use_cache}")
    # warm the cache from the fused path's miss records and compare again
    pop = CachePopulator(world["espec"], TPL_META)
    pop.queue.push(out_f[1])
    cache = pop.drain(world["store"], world["store"], world["cache"], world["ttable"])
    warm = (world["store"], cache, world["ttable"], roots)
    out_f2, out_h2 = ef.run(*warm), eh.run(*warm)
    _assert_runs_equal(out_f2, out_h2, f"warm use_cache={use_cache}")
    if use_cache:
        assert out_f2[2]["hits"] == 4 and out_f2[2]["misses"] == 0


def test_fused_matches_host_multihop_and_finals(world):
    roots2 = np.array([5, 6], np.int32)
    plans = [
        ("two-hop prop_neq", common_watchlist_plan(), roots2),
        (
            "two-hop id_neq rewrite",
            rewrite_plan(common_watchlist_plan(), unique_props=frozenset({P_LISTING_ID})),
            roots2,
        ),
        ("count", fig1_plan()._replace(final=FINAL_COUNT), np.array([0, 2], np.int32)),
        (
            "values",
            fig1_plan()._replace(final=FINAL_VALUES, final_prop=P_LISTING_ID),
            np.array([1, 3], np.int32),
        ),
    ]
    for name, plan, roots in plans:
        ef, eh = _engines(world, plan)
        args = (world["store"], world["cache"], world["ttable"], roots)
        _assert_runs_equal(ef.run(*args), eh.run(*args), name)


def test_fused_matches_host_random_worlds():
    """Property-style sweep: random worlds + random roots, both paths."""
    for seed in range(4):
        spec, store = build_world(n_watchlists=5, n_listings=14, seed=seed)
        cspec = CacheSpec(capacity=512, probes=4, max_leaves=8, max_chunks=2)
        espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=16)
        ttable, _, _ = enabled_ttable()
        cache = empty_cache(cspec)
        rng = np.random.default_rng(seed)
        roots = rng.integers(0, 19, rng.integers(1, 7)).astype(np.int32)
        plan = common_watchlist_plan() if seed % 2 else fig1_plan()
        ef = GraphEngine(espec, plan, use_cache=True, fused=True)
        eh = GraphEngine(espec, plan, use_cache=True, fused=False)
        out_f = ef.run(store, cache, ttable, roots)
        out_h = eh.run(store, cache, ttable, roots)
        _assert_runs_equal(out_f, out_h, f"seed={seed}")
        # warm pass over the same roots
        pop = CachePopulator(espec, TPL_META)
        pop.queue.push(out_f[1])
        cache = pop.drain(store, store, cache, ttable)
        _assert_runs_equal(
            ef.run(store, cache, ttable, roots),
            eh.run(store, cache, ttable, roots),
            f"seed={seed} warm",
        )


# ------------------------------------------------------- vectorized insert
def _rand_insert_batch(rng, B, cspec, nroots=8):
    L, C = cspec.max_leaves, cspec.max_chunks
    tpl = rng.integers(0, 2, B).astype(np.int32)
    root = rng.integers(0, nroots, B).astype(np.int32)  # forces duplicate keys
    params = rng.integers(0, 3, (B, PARAM_LEN)).astype(np.int32)
    lens = rng.integers(0, L * C + 3, B).astype(np.int32)  # includes oversize
    leaves = rng.integers(0, 100, (B, L * C)).astype(np.int32)
    ver = rng.integers(1, 5, B).astype(np.int32)
    mask = rng.random(B) < 0.9
    return tuple(map(jnp.asarray, (tpl, root, params, leaves, lens, ver, mask)))


def test_vectorized_insert_matches_sequential():
    """Byte-identical final CacheState (values, metadata, AND stats) under
    duplicate keys, probe-window collisions, chunked values, oversize skips,
    and eviction pressure — the full sequential-semantics contract."""
    rng = np.random.default_rng(7)
    for trial in range(12):
        cap = int(rng.choice([8, 16, 64]))  # tiny capacities force evictions
        cspec = CacheSpec(
            capacity=cap,
            probes=int(rng.choice([2, 4])),
            max_leaves=4,
            max_chunks=int(rng.choice([1, 2, 3])),
        )
        c_vec = c_seq = empty_cache(cspec)
        for _ in range(3):  # stacked batches interact through the table
            batch = _rand_insert_batch(rng, int(rng.integers(1, 20)), cspec)
            c_vec = cache_insert(cspec, c_vec, *batch)
            c_seq = cache_insert_sequential(cspec, c_seq, *batch)
        for f in c_vec._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(c_vec, f)),
                np.asarray(getattr(c_seq, f)),
                err_msg=f"trial {trial}: field {f}",
            )


def test_vectorized_insert_duplicate_keys_last_writer_wins():
    cspec = CacheSpec(capacity=64, probes=4, max_leaves=4, max_chunks=1)
    cache = empty_cache(cspec)
    B = 3
    tpl = jnp.zeros(B, jnp.int32)
    root = jnp.full((B,), 9, jnp.int32)  # same key three times
    params = jnp.zeros((B, PARAM_LEN), jnp.int32)
    leaves = jnp.arange(B * 4, dtype=jnp.int32).reshape(B, 4) * 10
    cache = cache_insert(
        cspec, cache, tpl, root, params, leaves,
        jnp.full((B,), 2, jnp.int32), jnp.arange(B, dtype=jnp.int32), jnp.ones(B, bool),
    )
    hit, vals, lmask, ver = cache_lookup(cspec, cache, tpl[:1], root[:1], params[:1])
    assert bool(hit[0])
    got = np.asarray(vals[0])[np.asarray(lmask[0])]
    assert got.tolist() == [80, 90]  # the last row's leaves
    assert int(ver[0]) == 2  # and its commit version


# ------------------------------------------------------- pallas cache probe
def test_cache_probe_pallas_matches_ref_interpret():
    """The Pallas kernel must agree with ref.py under interpret=True,
    including at batch sizes that are not a multiple of the block."""
    rng = np.random.default_rng(3)
    for C, B, probes in [(256, 32, 4), (512, 37, 8), (1024, 300, 8)]:
        c_tpl = rng.integers(-1, 3, C).astype(np.int32)
        c_root = rng.integers(0, 64, C).astype(np.int32)
        c_fp = rng.integers(0, 2**32, C, dtype=np.uint32)
        c_valid = rng.random(C) < 0.5
        tpl = rng.integers(0, 3, B).astype(np.int32)
        root = rng.integers(0, 64, B).astype(np.int32)
        h = rng.integers(0, 2**32, B, dtype=np.uint32)
        fp = rng.integers(0, 2**32, B, dtype=np.uint32)
        planted = {}  # base slot -> query index (later plants overwrite)
        for i in range(0, B, 2):  # plant real hits in the base slot
            s = int(h[i] % C)
            c_tpl[s], c_root[s], c_fp[s], c_valid[s] = tpl[i], root[i], fp[i], True
            planted[s] = i
        args = tuple(map(jnp.asarray, (c_tpl, c_root, c_fp, c_valid, tpl, root, h, fp)))
        got_hit, got_slot = cache_probe(*args, probes=probes, interpret=True)
        ref_hit, ref_slot = cache_probe_ref(*args, probes=probes)
        np.testing.assert_array_equal(np.asarray(got_hit), np.asarray(ref_hit))
        np.testing.assert_array_equal(np.asarray(got_slot), np.asarray(ref_slot))
        surviving = list(planted.values())  # not overwritten by a later plant
        assert np.asarray(got_hit)[surviving].all()


def test_cache_lookup_pallas_matches_jnp(world):
    """End-to-end: a populated cache reads identically through the Pallas
    probe and the jnp fallback (chunked entries included)."""
    cspec = world["cspec"]
    rng = np.random.default_rng(5)
    B = 21
    tpl = rng.integers(0, 2, B).astype(np.int32)
    root = rng.integers(0, 16, B).astype(np.int32)
    params = rng.integers(0, 3, (B, PARAM_LEN)).astype(np.int32)
    lens = rng.integers(0, 2 * cspec.max_leaves, B).astype(np.int32)
    leaves = rng.integers(0, 64, (B, 2 * cspec.max_leaves)).astype(np.int32)
    cache = cache_insert(
        cspec, world["cache"], *map(jnp.asarray, (tpl, root, params, leaves, lens)),
        jnp.ones(B, jnp.int32), jnp.ones(B, bool),
    )
    jn = cache_lookup(cspec, cache, jnp.asarray(tpl), jnp.asarray(root),
                      jnp.asarray(params), use_pallas=False)
    pl = cache_lookup(cspec, cache, jnp.asarray(tpl), jnp.asarray(root),
                      jnp.asarray(params), use_pallas=True)
    for a, b, name in zip(jn, pl, ("hit", "leaves", "lmask", "version")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)
    assert np.asarray(jn[0]).any()


def _host_dedup(vals_row, width):
    seen, want = set(), []
    for v in vals_row.tolist():
        if v not in seen:
            seen.add(v)
            want.append(v)
    return want[:width]


def test_sort_dedup_matches_host_merge():
    """The sort-based device merge equals the legacy host-side semantics:
    first occurrence kept, original order, truncated to the output width."""
    rng = np.random.default_rng(11)
    for _ in range(20):
        B = int(rng.integers(1, 6))
        W = int(rng.integers(1, 40))
        width = int(rng.integers(1, 10))
        vals = rng.integers(0, 12, (B, W)).astype(np.int32)
        mask = rng.random((B, W)) < 0.6
        dv, dm = sort_dedup_masked(jnp.asarray(vals), jnp.asarray(mask), width)
        for b in range(B):
            want = _host_dedup(vals[b][mask[b]], width)
            got = np.asarray(dv[b])[np.asarray(dm[b])].tolist()
            assert got == want


def test_segmented_dedup_merge_matches_host_merge():
    """The occupancy-driven merge (left-packed segments, the fused engine's
    frontier shape) also matches the host semantics exactly."""
    rng = np.random.default_rng(13)
    for _ in range(20):
        B = int(rng.integers(1, 6))
        S = int(rng.integers(1, 6))
        W = int(rng.integers(1, 9))
        width = int(rng.integers(1, 10))
        counts = rng.integers(0, W + 1, (B, S)).astype(np.int32)
        vals = rng.integers(0, 10, (B, S, W)).astype(np.int32)
        mask = np.arange(W)[None, None, :] < counts[:, :, None]
        dv, dm = segmented_dedup_merge(jnp.asarray(vals), jnp.asarray(counts), width)
        for b in range(B):
            want = _host_dedup(vals[b].reshape(-1)[mask[b].reshape(-1)], width)
            got = np.asarray(dv[b])[np.asarray(dm[b])].tolist()
            assert got == want
