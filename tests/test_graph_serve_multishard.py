"""Multi-shard correctness of the distributed serving tier.

Runs in a subprocess so XLA_FLAGS can create host devices before jax
initializes; verifies that cross-shard routing over the *partitioned*
storage tier returns exactly the predicate-qualified leaves for roots owned
by *remote* shards, that starved routing buckets surface their drops in
``route_overflow`` instead of hiding them, and that the measured-skew
default ``route_cap_factor`` holds the overflow rate at zero across a
Zipfian batch stream (the production cap SLO).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    from conftest import build_world, enabled_ttable, fig1_plan
    from repro.core import CacheSpec, EngineSpec
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import (
        DEFAULT_ROUTE_CAP_FACTOR, ShardedTxnRuntime,
    )
    from repro.graphstore import StoreSpec, ingest

    # a known graph: watch-list 17 -> listings {3, 40, 50} with IsActive
    # 1,1,0 and Status 0,1,0 — only listing 3 qualifies for fig1(ia=1, st=0)
    spec = StoreSpec(v_cap=64, e_cap=256, n_vprops=2, n_eprops=1, recent_cap=32)
    vlabels = np.ones(64, np.int32)   # listings by default
    vlabels[17] = 0                   # the root watch-list
    vprops = np.full((64, 2), 1, np.int64)
    vprops[3, 0] = 0
    vprops[40, 0] = 1
    vprops[50, 0] = 0
    es, ed, ep = [17, 17, 17], [3, 40, 50], [[1], [1], [0]]
    store = ingest(spec, vlabels, vprops, es, ed, [0, 0, 0], np.array(ep))

    cspec = CacheSpec(capacity=256, probes=8, max_leaves=8, max_chunks=1)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=8, frontier=8)
    ttable, _, _ = enabled_ttable()
    mesh = flat_mesh(4)
    plan = fig1_plan()

    rt = ShardedTxnRuntime(espec, mesh)  # partitioned tier, measured cap
    pstore = rt.partition_store(store)
    cache = rt.empty_cache()
    # every shard's batch slice queries root 17 — owned by shard 17 % 4 = 1,
    # so three shards route their roots to a remote owner's edge block
    roots = np.full(8, 17, np.int32)
    res, _, met = rt.run_gr_tx_batch(pstore, cache, ttable, plan, roots)
    got = sorted(set(int(x) for x in res[0] if x >= 0))
    assert got == [3], got
    for row in res:
        assert sorted(set(int(x) for x in row if x >= 0)) == [3]
    assert met["route_overflow"] == 0, met

    # a starved routing bucket (cap factor 1, every root on one owner) must
    # surface its drops instead of silently degrading
    tiny = ShardedTxnRuntime(
        espec, mesh, route_cap_factor=1, e_blk_cap=rt.pspec.e_blk_cap
    )
    _, _, met2 = tiny.run_gr_tx_batch(pstore, cache, ttable, plan, roots)
    assert met2["route_overflow"] > 0, met2

    # overflow-rate SLO: the measured default cap factor absorbs Zipfian
    # root skew — zero overflow across a batch stream (rate SLO = 0 here;
    # production alarms on any nonzero route_overflow)
    rng = np.random.default_rng(0)
    wl = np.arange(0, 32)  # pretend watch-list id range
    overflowed = 0
    for _ in range(20):
        zipf = np.minimum(rng.zipf(1.3, size=16) - 1, len(wl) - 1)
        roots = wl[zipf].astype(np.int32)
        _, _, m = rt.run_gr_tx_batch(pstore, cache, ttable, plan, roots)
        overflowed += int(m["route_overflow"] > 0)
    assert overflowed == 0, f"{overflowed}/20 batches overflowed default caps"
    # hop-1 factor covers the measured p99.9 Zipfian-root ceiling; inner
    # hops route flatter leaf-derived frontiers and may sit lower
    assert DEFAULT_ROUTE_CAP_FACTOR[0] >= 4
    assert min(DEFAULT_ROUTE_CAP_FACTOR) >= 3

    print("MULTISHARD_OK")
    """
)


def test_graph_serve_routing_across_shards():
    env = dict(
        os.environ,
        PYTHONPATH=os.pathsep.join(
            [os.path.join(REPO, "src"), os.path.join(REPO, "tests")]
        ),
    )
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=900,
    )
    assert "MULTISHARD_OK" in out.stdout, out.stdout + out.stderr
