"""Multi-shard correctness of the distributed graph-serving engine.

Runs in a subprocess so XLA_FLAGS can create 4 host devices before jax
initializes; verifies cross-shard routing returns exactly the predicate-
qualified leaves for roots owned by *remote* shards.
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.distributed.graph_serve import GraphServeConfig, build_serve_step
    from repro.launch.mesh import make_debug_mesh

    cfg = GraphServeConfig(name="t", v_total=64, e_per_vertex=4, max_deg=8,
                           max_leaves=8, cache_slots_total=256)
    mesh = make_debug_mesh(2, 2)  # 4 shards
    V, E, C = cfg.v_total, cfg.e_total(), cfg.cache_slots_total
    n, Vloc, Eloc = 4, V // 4, E // 4
    deg = np.zeros(V, np.int32); start = np.zeros(V, np.int32)
    dst = np.zeros(E, np.int32); eprop = np.zeros(E, np.int32)
    # vertex 17 (shard 1) -> leaves 3, 40, 50 with eprops 1,1,0
    deg[17] = 3; start[17] = 5
    base = 1 * Eloc + 5
    dst[base:base+3] = [3, 40, 50]; eprop[base:base+3] = [1, 1, 0]
    vprop = np.ones(V, np.int32)  # nothing qualifies (leaf_val=0)...
    vprop[3] = 0                  # ...except vertex 3
    vprop[40] = 1
    state = dict(deg=jnp.asarray(deg), start=jnp.asarray(start),
                 dst=jnp.asarray(dst), eprop=jnp.asarray(eprop),
                 vprop=jnp.asarray(vprop),
                 c_root=jnp.full((C,), -1, jnp.int32),
                 c_fp=jnp.zeros((C,), jnp.uint32),
                 c_len=jnp.zeros((C,), jnp.int32),
                 c_vals=jnp.full((C, cfg.max_leaves), -1, jnp.int32),
                 c_valid=jnp.zeros((C,), bool))
    step = jax.jit(build_serve_step(cfg, mesh, use_cache=True, global_batch=8))
    roots = jnp.asarray(np.array([17] * 8, np.int32))  # all shards query 17
    res, stats = step(state, roots)
    got = sorted(set(int(x) for x in np.asarray(res[0]) if x >= 0))
    assert got == [3], got     # edge prop==1 AND leaf prop==0 -> only leaf 3
    assert int(stats["processed"]) >= 1
    # ample routing capacity: nothing may be silently dropped
    assert int(stats["route_overflow"]) == 0, stats

    # a starved routing bucket (cap 1 per peer, 2 queued roots per shard)
    # must surface its drops in route_overflow instead of hiding them
    import dataclasses
    tiny = dataclasses.replace(cfg, route_cap_factor=1)
    step2 = jax.jit(build_serve_step(tiny, mesh, use_cache=True, global_batch=8))
    _, stats2 = step2(state, roots)
    # 4 roots dropped in round 1 (2 queued per shard, bucket cap 1) plus 4
    # leaf fetches dropped in round 2 (4 surviving root copies x 2
    # qualifying edges against leaf-owner bucket cap 2)
    assert int(stats2["route_overflow"]) == 8, stats2
    print("MULTISHARD_OK")
    """
)


def test_graph_serve_routing_across_shards():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True,
        text=True, timeout=600,
    )
    assert "MULTISHARD_OK" in out.stdout, out.stdout + out.stderr
