"""Engine behaviour: hit/miss equality, multi-hop, rewrites, metrics."""

import numpy as np

from conftest import (
    MISSING,
    P_LISTING_ID,
    common_watchlist_plan,
    fig1_plan,
)
from repro.core import (
    FINAL_COUNT,
    FINAL_VALUES,
    GraphEngine,
    rewrite_plan,
)
from repro.core.oracle import HostStore, onehop_oracle
from repro.core.population import CachePopulator
from conftest import TPL_META


def _ids(row):
    return set(row[row >= 0].tolist())


def test_fig1_miss_then_hit_same_result(world):
    eng = GraphEngine(world["espec"], fig1_plan(), use_cache=True)
    roots = np.array([0, 1, 2, 3], np.int32)
    res1, misses, m1 = eng.run(world["store"], world["cache"], world["ttable"], roots)
    assert m1["misses"] == 4 and m1["hits"] == 0
    pop = CachePopulator(world["espec"], TPL_META)
    pop.queue.push(misses)
    cache = pop.drain(world["store"], world["store"], world["cache"], world["ttable"])
    res2, _, m2 = eng.run(world["store"], cache, world["ttable"], roots)
    assert m2["hits"] == 4 and m2["misses"] == 0
    for a, b in zip(res1, res2):
        assert _ids(a) == _ids(b)
    # hit path needs strictly fewer sequential phases
    assert m2["phases"] < m1["phases"]


def test_engine_matches_oracle(world):
    plan = fig1_plan()
    eng = GraphEngine(world["espec"], plan, use_cache=False)
    roots = np.array([0, 1, 2, 3], np.int32)
    res, _, _ = eng.run(world["store"], world["cache"], world["ttable"], roots)
    hs = HostStore(world["store"])
    hop = plan.hops[0]
    for i, r in enumerate(roots):
        want = onehop_oracle(
            hs, hop.direction, hop.edge_label, hop.pr, hop.pe, hop.pl, int(r), hop.params
        )
        assert _ids(res[i]) == want


def test_multihop_common_watchlists(world):
    plan = common_watchlist_plan()
    eng = GraphEngine(world["espec"], plan, use_cache=False)
    roots = np.array([5, 6], np.int32)  # listings
    res, _, metrics = eng.run(world["store"], world["cache"], world["ttable"], roots)
    # reference: manual two-hop via oracle
    hs = HostStore(world["store"])
    h1, h2 = plan.hops
    for i, r in enumerate(roots):
        wls = onehop_oracle(hs, h1.direction, h1.edge_label, h1.pr, h1.pe, h1.pl, int(r), h1.params)
        want = set()
        for w in wls:
            want |= onehop_oracle(hs, h2.direction, h2.edge_label, h2.pr, h2.pe, h2.pl, int(w), h2.params)
        # post filter: drop leaves with same ListingId as root (i.e. the root)
        want -= {int(r)}
        assert _ids(res[i]) == want


def test_rewrite_removes_phase(world):
    plan = common_watchlist_plan()
    rw = rewrite_plan(plan, unique_props=frozenset({P_LISTING_ID}))
    assert rw.post_filter == ("id_neq",)
    roots = np.array([5, 6], np.int32)
    e1 = GraphEngine(world["espec"], plan, use_cache=False)
    e2 = GraphEngine(world["espec"], rw, use_cache=False)
    r1, _, m1 = e1.run(world["store"], world["cache"], world["ttable"], roots)
    r2, _, m2 = e2.run(world["store"], world["cache"], world["ttable"], roots)
    for a, b in zip(r1, r2):
        assert _ids(a) == _ids(b)  # rewrite preserves semantics
    assert m2["phases"] == m1["phases"] - 1


def test_final_count_and_values(world):
    plan = fig1_plan()._replace(final=FINAL_COUNT)
    eng = GraphEngine(world["espec"], plan, use_cache=False)
    roots = np.array([0], np.int32)
    res, _, _ = eng.run(world["store"], world["cache"], world["ttable"], roots)
    planv = fig1_plan()._replace(final=FINAL_VALUES, final_prop=P_LISTING_ID)
    engv = GraphEngine(world["espec"], planv, use_cache=False)
    resv, _, _ = engv.run(world["store"], world["cache"], world["ttable"], roots)
    assert int(res[0]) == int((resv[0] >= 0).sum())
    got = resv[0][resv[0] >= 0]
    assert all(v >= 1000 for v in got.tolist())


def test_disabled_template_never_hits(world):
    import jax.numpy as jnp

    ttable = world["ttable"]._replace(read_enabled=jnp.zeros(2, bool))
    eng = GraphEngine(world["espec"], fig1_plan(), use_cache=True)
    roots = np.array([0], np.int32)
    _, misses, _ = eng.run(world["store"], world["cache"], ttable, roots)
    pop = CachePopulator(world["espec"], TPL_META)
    pop.queue.push(misses)
    cache = pop.drain(world["store"], world["store"], world["cache"], ttable)
    _, _, m = eng.run(world["store"], cache, ttable, roots)
    assert m["hits"] == 0  # reads disabled => no hits, and population skipped


def test_grw_step_cached_by_espec_and_policy(world):
    """``build_grw_step`` must return one shared compiled step per
    (espec, policy) — ``run_grw_tx`` used to re-trace on every call."""
    from repro.core import build_grw_step

    espec = world["espec"]
    assert build_grw_step(espec) is build_grw_step(espec)
    assert build_grw_step(espec, "write-through") is build_grw_step(
        espec, "write-through"
    )
    assert build_grw_step(espec) is not build_grw_step(espec, "write-through")
    # a different spec gets its own step
    espec2 = espec._replace(max_deg=espec.max_deg // 2)
    assert build_grw_step(espec2) is not build_grw_step(espec)
