"""Unit tests for the slotted CSR graph store."""

import jax.numpy as jnp
import numpy as np

from conftest import MISSING, build_world, L_WATCHLIST, L_LISTING
from repro.graphstore import (
    StoreSpec,
    apply_mutations,
    compact,
    empty_store,
    gather_in,
    gather_out,
    ingest,
    make_mutation_batch,
)
from repro.graphstore.txn import commit_with_conflict_check


def small():
    spec = StoreSpec(v_cap=32, e_cap=128, n_vprops=2, n_eprops=1, recent_cap=16)
    vl = [0, 1, 1, 1]
    vp = np.full((4, 2), MISSING)
    store = ingest(spec, vl, vp, [0, 0, 0], [1, 2, 3], [0, 0, 0], np.ones((3, 1)))
    return spec, store


def test_gather_out_basic():
    spec, store = small()
    eids, dst, mask, trunc = gather_out(spec, store, jnp.array([0, 1]), 8)
    assert sorted(np.asarray(dst[0])[np.asarray(mask[0])].tolist()) == [1, 2, 3]
    assert np.asarray(mask[1]).sum() == 0
    assert not np.asarray(trunc).any()


def test_gather_in_basic():
    spec, store = small()
    eids, src, mask, _ = gather_in(spec, store, jnp.array([2]), 8)
    assert np.asarray(src[0])[np.asarray(mask[0])].tolist() == [0]


def test_supernode_truncation_flag():
    spec, store = small()
    _, _, _, trunc = gather_out(spec, store, jnp.array([0]), 2)
    assert bool(np.asarray(trunc)[0])


def test_recent_region_visible_before_compaction():
    spec, store = small()
    mb = make_mutation_batch(spec, new_edges=[(1, 3, 0, [1])])
    store2, applied = apply_mutations(spec, store, mb)
    assert int(store2.csr_len) == 3  # CSR not rebuilt yet
    _, dst, mask, _ = gather_out(spec, store2, jnp.array([1]), 8)
    assert np.asarray(dst[0])[np.asarray(mask[0])].tolist() == [3]
    store3 = compact(spec, store2)
    _, dst, mask, _ = gather_out(spec, store3, jnp.array([1]), 8)
    assert np.asarray(dst[0])[np.asarray(mask[0])].tolist() == [3]
    assert int(store3.csr_len) == int(store3.e_len)


def test_delete_edge_and_vertex_masked():
    spec, store = small()
    mb = make_mutation_batch(spec, del_edges=[0], del_vertices=[3])
    store2, _ = apply_mutations(spec, store, mb)
    _, dst, mask, _ = gather_out(spec, store2, jnp.array([0]), 8)
    assert sorted(np.asarray(dst[0])[np.asarray(mask[0])].tolist()) == [2]


def test_version_bumps_on_touch():
    spec, store = small()
    v0 = int(store.version)
    mb = make_mutation_batch(spec, set_vprops=[(2, 0, 7)])
    store2, applied = apply_mutations(spec, store, mb)
    assert int(store2.version) == v0 + 1
    assert int(store2.vversion[2]) == v0 + 1
    assert int(store2.vversion[1]) == int(store.vversion[1])
    assert int(applied.sv_old[0]) == MISSING


def test_preimage_snapshots():
    spec, store = small()
    mb = make_mutation_batch(spec, del_edges=[1], set_eprops=[(2, 0, 0)])
    store2, ap = apply_mutations(spec, store, mb)
    assert int(ap.de_src[0]) == 0 and int(ap.de_dst[0]) == 2
    assert int(ap.se_old[0]) == 1  # IsActive was 1
    assert int(store2.eprops[2, 0]) == 0


def test_occ_commit_conflict():
    spec, store = small()
    mb = make_mutation_batch(spec, set_vprops=[(1, 0, 5)])
    store2, _ = apply_mutations(spec, store, mb)  # bumps v1
    read_set = jnp.array([1, 2])
    mask = jnp.array([True, True])
    bump = lambda s: s._replace(version=s.version + 1)
    merged, ok = commit_with_conflict_check(
        spec, store2, store.version, read_set, mask, bump
    )
    assert not bool(ok)  # v1 written after our read version
    merged, ok = commit_with_conflict_check(
        spec, store2, store2.version, read_set, mask, bump
    )
    assert bool(ok)
    assert int(merged.version) == int(store2.version) + 1


def test_new_vertex_then_edge_same_batch():
    spec, store = small()
    mb = make_mutation_batch(
        spec, new_vertices=[(1, [0, MISSING])], new_edges=[(0, 4, 0, [1])]
    )
    store2, ap = apply_mutations(spec, store, mb)
    assert int(ap.nv_vid[0]) == 4
    _, dst, mask, _ = gather_out(spec, store2, jnp.array([0]), 8)
    assert 4 in np.asarray(dst[0])[np.asarray(mask[0])].tolist()


def test_build_world_compiles():
    spec, store = build_world()
    assert int(store.v_len) == 16
    assert int(store.e_len) > 0
