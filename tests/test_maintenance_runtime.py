"""Block maintenance on the 8-virtual-device sharded runtime.

Two pins, run in subprocesses so XLA_FLAGS can create the host devices
before jax initializes (same pattern as ``test_partitioned_runtime``):

- **Identity under maintenance interleavings** — a gR/gRW sequence on the
  partitioned runtime with maintenance ticks (forced owner-local
  compactions and a mid-sequence capacity growth) interleaved at every
  step produces byte-identical gR results/metrics/miss records, identical
  gRW ``impacted_keys`` and logical cache state, and identical post-commit
  reads, vs BOTH the single-host engine and the no-maintenance sharded run.
  The ``ShardedMissDrain`` (CP-per-shard queues) must also populate exactly
  what the host-side populator populates. Per-hop ``route_cap_factor``
  tuples serve the same bytes with zero overflow.

- **Compile-cliff removal** — the indexed partitioned gRW apply lowers at
  the FULL capacity config's dry-run block capacity (2^30-row blocks, the
  scale at which the former O(K × e_blk_cap) broadcast-compare was
  intractable): ``graph_serve.config_grw_cell``.
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MAINTENANCE_IDENTITY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from conftest import (
        build_world, enabled_ttable, fig1_plan, common_watchlist_plan, TPL_META,
    )
    from repro.core import (
        CacheSpec, EngineSpec, GraphEngine, cache_entries, empty_cache,
        run_grw_tx,
    )
    from repro.core.population import CachePopulator
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedMissDrain, ShardedTxnRuntime
    from repro.graphstore import MaintenancePolicy, make_mutation_batch

    spec, store = build_world()
    cspec = CacheSpec(capacity=1024, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, sc, qp = enabled_ttable()
    mesh = flat_mesh(8)
    plan = common_watchlist_plan()
    plans = [("two_hop", plan), ("fig1", fig1_plan())]

    def miss_key(ms):
        return sorted(
            (m.tpl_idx, m.root, tuple(m.params.tolist()), m.read_version)
            for m in ms
        )

    # forced-compaction policy: every tick compacts, so maintenance
    # interleaves at every step of the sequence
    tick_policy = MaintenancePolicy(recent_fill_frac=0.0, mutation_rows=0)

    class HostRef:
        def __init__(self):
            self.store, self.cache = store, empty_cache(cspec)
            self.engines = {t: GraphEngine(espec, p, True, fused=True)
                            for t, p in plans}
        def gr(self, tag, roots):
            res, miss, met = self.engines[tag].run(
                self.store, self.cache, ttable, roots)
            return res, miss, met
        def grw(self, mb, policy):
            self.store, self.cache, m = run_grw_tx(
                espec, self.store, self.cache, ttable, mb, policy=policy)
            return m

    class ShardedRun:
        def __init__(self, maintain, route_cap_factor=None):
            self.rt = ShardedTxnRuntime(
                espec, mesh, route_cap_factor=route_cap_factor, blk_slack=1.0)
            self.ps = self.rt.partition_store(store)
            self.cache = self.rt.empty_cache()
            self.maintain = maintain
            self.ticks = 0
        def tick(self):
            if self.maintain:
                self.ps, info = self.rt.maintenance_tick(self.ps, tick_policy)
                assert info["compacted"], info
                self.ticks += 1
        def gr(self, tag, roots):
            p = dict(plans)[tag]
            out = self.rt.run_gr_tx_batch(self.ps, self.cache, ttable, p, roots)
            self.tick()
            return out
        def grw(self, mb, policy):
            self.ps, self.cache, m = self.rt.run_grw_tx(
                self.ps, self.cache, ttable, mb, policy=policy)
            self.tick()
            return m
        def grow(self, extra):
            if self.maintain:
                self.ps = self.rt.grow_blocks(
                    self.ps, self.rt.pspec.e_blk_cap + extra)

    host = HostRef()
    runs = [ShardedRun(False), ShardedRun(True), ShardedRun(True, (4, 8))]

    def check_gr(tag, roots):
        res_h, miss_h, met_h = host.gr(tag, roots)
        for i, r in enumerate(runs):
            res_s, miss_s, met_s = r.gr(tag, np.asarray(roots))
            assert met_s.pop("route_overflow") == 0, (tag, i)
            # routing-tier keys exist only on the sharded side; identity
            # runs use the implicit uniform table, so all must be zero
            assert met_s.pop("locality_routed") == 0, (tag, i)
            assert met_s.pop("route_cap_retries") == 0, (tag, i)
            assert met_s.pop("locality_retry_rows") == 0, (tag, i)
            assert np.array_equal(res_h, res_s), (tag, i)
            assert met_h == met_s, (tag, i, met_h, met_s)
            assert miss_key(miss_h) == miss_key(miss_s), (tag, i)
        return miss_h

    def check_grw(mb, policy="write-around"):
        m_h = host.grw(mb, policy)
        for i, r in enumerate(runs):
            m_s = r.grw(mb, policy)
            assert m_h["impacted_keys"] == m_s["impacted_keys"], (i, m_h, m_s)
            assert m_s["op_overflow"] == 0 and m_s["store_append_overflow"] == 0
            assert cache_entries(cspec, host.cache) == cache_entries(
                cspec, r.cache), ("grw cache", i)

    roots = np.array([5, 6, 7, 8, 9], np.int32)
    miss_h = check_gr("two_hop", roots)

    # populate: host FIFO vs the sharded per-owner CP drains
    pop_h = CachePopulator(espec, TPL_META)
    pop_h.queue.push(miss_h)
    host.cache = pop_h.drain(host.store, host.store, host.cache, ttable)
    for i, r in enumerate(runs):
        drain = ShardedMissDrain(r.rt, TPL_META)
        _, miss_s, _ = r.rt.run_gr_tx_batch(
            r.ps, r.rt.empty_cache(), ttable, plan, roots)
        drain.push(miss_s)
        r.cache = drain.drain(r.ps, r.ps, r.cache, ttable)
        assert (pop_h.committed, pop_h.aborted) == (drain.committed, drain.aborted), i
        assert cache_entries(cspec, host.cache) == cache_entries(cspec, r.cache), i

    check_gr("two_hop", roots)  # warm hits through maintained blocks

    mb1 = make_mutation_batch(
        spec, set_vprops=[(7, 0, 1), (8, 0, 0)], del_edges=[2],
        new_edges=[(0, 11, 0, [1]), (3, 6, 0, [0])], del_vertices=[9],
    )
    check_grw(mb1)
    check_gr("two_hop", np.array([0, 3, 5, 6, 7, 11], np.int32))
    check_gr("fig1", np.array([0, 1, 2, 3], np.int32))

    # capacity growth mid-sequence (maintaining runs only), then more traffic
    for r in runs:
        r.grow(13)
    mb2 = make_mutation_batch(
        spec, new_edges=[(1, 12, 0, [1]), (2, 13, 0, [0]), (0, 5, 0, [1])],
        set_eprops=[(1, 0, 0)],
    )
    check_grw(mb2, policy="write-through")
    check_gr("two_hop", np.array([1, 2, 5, 12, 13], np.int32))
    check_gr("fig1", np.array([0, 1, 2, 3], np.int32))

    assert runs[1].ticks >= 7 and runs[2].ticks >= 7
    assert runs[1].rt.pspec.e_blk_cap != runs[0].rt.pspec.e_blk_cap
    print("MAINTENANCE_IDENTITY_OK")
    """
)

GRW_LOWERS_AT_CAPACITY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.distributed.graph_serve import GraphServeConfig, config_grw_cell
    from repro.distributed.sharding import flat_mesh

    cfg = GraphServeConfig()  # FULL capacity config: 2^30 vertices
    step, shardings, args, rt = config_grw_cell(cfg, flat_mesh(8))
    assert rt.pspec.e_blk_cap >= 1 << 30, rt.pspec  # billion-row blocks
    lowered = jax.jit(step, in_shardings=shardings).lower(*args)
    assert lowered.as_text()  # lowering completed without a compile cliff
    print("GRW_LOWERS_OK", rt.pspec.e_blk_cap)
    """
)


def _run(script, token, timeout=1800):
    env = dict(
        os.environ,
        PYTHONPATH=os.pathsep.join(
            [os.path.join(REPO, "src"), os.path.join(REPO, "tests")]
        ),
    )
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=timeout,
    )
    assert token in out.stdout, out.stdout + out.stderr


def test_maintenance_ticks_preserve_identity():
    _run(MAINTENANCE_IDENTITY, "MAINTENANCE_IDENTITY_OK")


def test_indexed_grw_apply_lowers_at_dryrun_capacity():
    _run(GRW_LOWERS_AT_CAPACITY, "GRW_LOWERS_OK")
