"""The partitioned dual-CSR storage tier, host-level invariants.

Everything here runs on one device: per-shard behaviour is exercised by
slicing shard-local views out of the global partitioned layout (and, for
the collective-bearing partitioned commit, a ``vmap`` with a named axis —
the same program ``shard_map`` runs on the mesh). The full 8-virtual-device
byte-identity of the partitioned *runtime* lives in
``test_partitioned_runtime.py`` (sharded CI job).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_world, enabled_ttable, sq1_hop, sq2_hop
from repro.core import CacheSpec, EngineSpec, empty_cache
from repro.core.invalidation import (
    apply_op_stream,
    apply_op_stream_segmented,
    derive_cache_ops,
    derive_cache_ops_views,
)
from repro.core.runtime import onehop_exec, onehop_exec_view
from repro.core.templates import DIR_BOTH, DIR_IN, DIR_OUT
from repro.graphstore import make_mutation_batch
from repro.graphstore.mutations import apply_mutations
from repro.graphstore.partition import (
    BlockStoreView,
    EdgeBlock,
    PartitionedGraphStore,
    apply_mutations_partitioned,
    default_pspec,
    local_shard,
    partition_store,
    stack_blocks,
    store_bytes_report,
    unstack_blocks,
)

N = 4


@pytest.fixture(scope="module")
def world():
    spec, store = build_world()
    cspec = CacheSpec(capacity=1024, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, _, _ = enabled_ttable()
    pspec = default_pspec(spec, N)
    return dict(
        spec=spec, store=store, espec=espec, cspec=cspec, ttable=ttable,
        pspec=pspec, pstore=partition_store(pspec, store),
    )


def _own(pspec, roots, s):
    return np.mod(np.asarray(roots), pspec.n_shards) == s


@pytest.mark.parametrize("direction", [DIR_OUT, DIR_IN, DIR_BOTH])
def test_block_exec_matches_global(world, direction):
    """Owner-local miss execution is byte-identical to the full-store path:
    per owned row all outputs match, and per-batch scan metrics sum over
    shards to the global count."""
    espec, store = world["espec"], world["store"]
    pspec, pstore = world["pspec"], world["pstore"]
    hop = sq1_hop() if direction != DIR_IN else sq2_hop()
    hop = hop._replace(direction=direction)
    roots = np.array([0, 1, 2, 3, 5, 9, 15, 63, -1, 64], np.int32)
    rmask = np.array([True] * 8 + [False, True])
    params = jnp.broadcast_to(jnp.asarray(hop.params), (len(roots), 6))

    g_leaves, g_lmask, g_n, g_trunc, g_stats = onehop_exec(
        espec, store, direction, hop.edge_label, hop.pr, hop.pe, hop.pl,
        jnp.asarray(roots), params, jnp.asarray(rmask),
    )
    edges_sum = leaves_sum = 0
    for s in range(pspec.n_shards):
        view = BlockStoreView(pspec, local_shard(pspec, pstore, s), s)
        own = _own(pspec, roots, s)
        leaves, lmask, n_true, trunc, stats = onehop_exec_view(
            espec, view, direction, hop.edge_label, hop.pr, hop.pe, hop.pl,
            jnp.asarray(roots), params, jnp.asarray(rmask & own),
        )
        rows = np.nonzero(rmask & own)[0]
        assert np.array_equal(np.asarray(leaves)[rows], np.asarray(g_leaves)[rows])
        assert np.array_equal(np.asarray(lmask)[rows], np.asarray(g_lmask)[rows])
        assert np.array_equal(np.asarray(n_true)[rows], np.asarray(g_n)[rows])
        assert np.array_equal(np.asarray(trunc)[rows], np.asarray(g_trunc)[rows])
        edges_sum += int(stats["edges_scanned"])
        leaves_sum += int(stats["leaf_fetches"])
    assert edges_sum == int(g_stats["edges_scanned"])
    assert leaves_sum == int(g_stats["leaf_fetches"])


def test_store_bytes_scale_inverse_in_n(world):
    """Per-shard bytes of the partitioned tier are a small fraction of the
    replicated snapshot and scale as O(1/n): dual orientation stores each
    edge at two owners, so the edge term is ~2x the uniform share (plus the
    small replicated vertex tier) — far below a full replica per shard."""
    spec = world["spec"]
    for n, bound in ((4, 2.6), (8, 2.6)):
        rep = store_bytes_report(default_pspec(spec, n, slack=1.0))
        assert rep["per_shard_bytes"] < bound * rep["replicated_per_shard_bytes"] / n
        assert rep["ratio"] < 1.0  # strictly better than replication
    r4 = store_bytes_report(default_pspec(spec, 4, slack=1.0))
    r16 = store_bytes_report(default_pspec(spec, 16, slack=1.0))
    # quadrupling the mesh cuts per-shard block bytes ~4x (up to the
    # per-shard CSR indptr/scalar overhead, which shrinks sublinearly)
    assert abs(r16["per_shard_block_bytes"] * 4 - r4["per_shard_block_bytes"]) < (
        0.15 * r4["per_shard_block_bytes"]
    )


_stacked_local = stack_blocks

_BLK_AX = EdgeBlock(
    key=0, other=0, label=0, alive=0, props=0, geid=0, gperm=0, indptr=0,
    blk_len=0, csr_len=0,
)
_PS_AX = PartitionedGraphStore(
    vlabel=None, valive=None, vprops=None, vversion=None, out=_BLK_AX,
    inc=_BLK_AX, v_len=None, e_len=None, version=None,
)


def _restack(pspec, ps2):
    """Undo ``stack_blocks`` on a vmapped output (take shard 0's copy of
    the replicated leaves after asserting all copies agree)."""
    n = pspec.n_shards
    for f in ("vlabel", "valive", "vprops", "vversion", "v_len", "e_len", "version"):
        v = np.asarray(getattr(ps2, f))
        for s in range(1, n):
            assert np.array_equal(v[s], v[0]), f"replicated {f} diverged"
    return unstack_blocks(pspec, ps2._replace(
        vlabel=ps2.vlabel[0], valive=ps2.valive[0], vprops=ps2.vprops[0],
        vversion=ps2.vversion[0], v_len=ps2.v_len[0], e_len=ps2.e_len[0],
        version=ps2.version[0],
    ))


def _mutation_batch(spec):
    # every section type: new vertex + edges touching it, deletes, prop sets
    return make_mutation_batch(
        spec,
        new_vertices=[(1, [0, 1007])],
        new_edges=[(0, 11, 0, [1]), (2, 16, 0, [0]), (3, 5, 0, [1])],
        del_edges=[2, 5],
        del_vertices=[9],
        set_vprops=[(7, 0, 1), (8, 0, 0), (12, 1, 4242)],
        set_eprops=[(1, 0, 0), (4, 0, 1)],
    )


def test_partitioned_apply_matches_single_host(world):
    """``apply_mutations_partitioned`` (under a named-axis vmap — the same
    program shard_map runs) must land every section at its owner blocks
    such that the post-state equals the *partition of the single-host
    post-state*, and its psum-gathered ``AppliedMutations`` snapshot must
    be byte-identical to the single-host listener input."""
    spec, store = world["spec"], world["store"]
    pspec, pstore = world["pspec"], world["pstore"]
    mb = _mutation_batch(spec)

    store2, applied_h = apply_mutations(spec, store, mb)
    fn = jax.vmap(
        lambda ps, me: apply_mutations_partitioned(pspec, ps, mb, me, "sh"),
        axis_name="sh", in_axes=(_PS_AX, 0),
    )
    ps2_s, applied_s, ovf = fn(
        _stacked_local(pspec, pstore), jnp.arange(pspec.n_shards)
    )
    assert int(ovf[0]) == 0
    ps2 = _restack(pspec, ps2_s)

    expected = partition_store(pspec, store2)
    for f in PartitionedGraphStore._fields:
        a, b = getattr(ps2, f), getattr(expected, f)
        if isinstance(a, EdgeBlock):
            for bf in EdgeBlock._fields:
                assert np.array_equal(
                    np.asarray(getattr(a, bf)), np.asarray(getattr(b, bf))
                ), f"{f}.{bf} diverged from partition of single-host post-state"
        else:
            assert np.array_equal(np.asarray(a), np.asarray(b)), f

    for f in applied_h._fields:
        if f == "batch":
            continue
        ah = np.asarray(getattr(applied_h, f))
        as_ = np.asarray(getattr(applied_s, f))
        for s in range(pspec.n_shards):
            assert np.array_equal(as_[s], ah), f"applied.{f} shard {s}"


def _op_set(ops):
    ok = np.asarray(ops.ok)
    cols = [np.asarray(c)[ok] for c in (ops.order, ops.kind, ops.tpl, ops.root, ops.vid)]
    params = np.asarray(ops.params)[ok]
    return set(
        (*[int(c[i]) for c in cols], tuple(params[i].tolist()))
        for i in range(len(cols[0]))
    )


def _op_rows(ops):
    """(order, kind, tpl, root, vid, params) for every live op row."""
    ok = np.asarray(ops.ok)
    order = np.asarray(ops.order)[ok]
    kind, tpl = np.asarray(ops.kind)[ok], np.asarray(ops.tpl)[ok]
    root, vid = np.asarray(ops.root)[ok], np.asarray(ops.vid)[ok]
    params = np.asarray(ops.params)[ok]
    return [
        (int(order[i]), int(kind[i]), int(tpl[i]), int(root[i]), int(vid[i]),
         tuple(params[i].tolist()))
        for i in range(len(order))
    ]


def _key_sequences(rows):
    """Per-(tpl, root, params) op sequences in order-key order — exactly
    what the order-restoring apply consumes."""
    out = {}
    for (_, kind, tpl, root, vid, params) in sorted(rows):
        out.setdefault((tpl, root, params), []).append((kind, vid))
    return out


@pytest.mark.parametrize("through", [False, True])
def test_ownership_masked_listener_partitions_emissions(world, through):
    """Per-shard ownership-masked derivation over local blocks must emit
    the single-host op/sweep *multiset* (each emission instance at exactly
    one shard), with cross-shard order keys that restore the single-host
    per-key application order — the write-through invariant."""
    from collections import Counter

    spec, store = world["spec"], world["store"]
    espec, ttable = world["espec"], world["ttable"]
    pspec, pstore = world["pspec"], world["pstore"]
    mb = _mutation_batch(spec)
    store2, applied = apply_mutations(spec, store, mb)
    ps2 = partition_store(pspec, store2)

    g_ops, g_sweeps = derive_cache_ops(
        espec, store, store2, ttable, applied, through=through
    )
    g_rows = _op_rows(g_ops)
    full_count = Counter(r[1:] for r in g_rows)  # order keys are tier-local
    full_sw = Counter(
        (int(t), int(r))
        for t, r in zip(
            np.asarray(g_sweeps.tpl)[np.asarray(g_sweeps.ok)],
            np.asarray(g_sweeps.root)[np.asarray(g_sweeps.ok)],
        )
    )

    shard_rows, shard_count, shard_sw = [], Counter(), Counter()
    for s in range(pspec.n_shards):
        vp = BlockStoreView(pspec, local_shard(pspec, pstore, s), s)
        vq = BlockStoreView(pspec, local_shard(pspec, ps2, s), s)
        ops, sweeps = derive_cache_ops_views(
            espec, vp, vq, ttable, applied, through=through
        )
        rows = _op_rows(ops)
        # every emission the shard makes is rooted at a vertex whose ops it
        # was supposed to derive — no op the full run lacks
        assert Counter(r[1:] for r in rows) <= full_count, f"shard {s}"
        shard_rows += rows
        shard_count += Counter(r[1:] for r in rows)
        shard_sw += Counter(
            (int(t), int(r))
            for t, r in zip(
                np.asarray(sweeps.tpl)[np.asarray(sweeps.ok)],
                np.asarray(sweeps.root)[np.asarray(sweeps.ok)],
            )
        )
    # multiset partition: instances sum to exactly the single-host stream
    assert shard_count == full_count
    assert shard_sw == full_sw
    # merged cross-shard order restores the single-host per-key sequences
    assert _key_sequences(shard_rows) == _key_sequences(g_rows)


def test_segmented_apply_matches_sequential(world):
    """The key-segmented vectorized write-through apply is byte-identical
    to the sequential order-restored walk — including stats counters."""
    spec, store = world["spec"], world["store"]
    espec, cspec, ttable = world["espec"], world["cspec"], world["ttable"]
    from repro.core.population import CachePopulator
    from repro.core import GraphEngine
    from conftest import fig1_plan, TPL_META

    # warm a cache so value edits have entries to hit
    cache = empty_cache(cspec)
    eng = GraphEngine(espec, fig1_plan(), True)
    pop = CachePopulator(espec, TPL_META)
    _, misses, _ = eng.run(store, cache, ttable, np.arange(4, dtype=np.int32))
    pop.queue.push(misses)
    cache = pop.drain(store, store, cache, ttable)

    mb = _mutation_batch(spec)
    store2, applied = apply_mutations(spec, store, mb)
    ops, _ = derive_cache_ops(espec, store, store2, ttable, applied, through=True)
    seq = apply_op_stream(cspec, cache, ops)
    seg = apply_op_stream_segmented(cspec, cache, ops)
    for f in seq._fields:
        assert np.array_equal(
            np.asarray(getattr(seq, f)), np.asarray(getattr(seg, f))
        ), f"cache field {f} diverged"


@pytest.mark.parametrize("direction", [DIR_OUT, DIR_IN, DIR_BOTH])
def test_fused_block_exec_matches_view_after_mutations(world, direction):
    """The fused ``block_gather`` executor is byte-identical to
    ``onehop_exec_view`` on mutated blocks — i.e. with live RECENT regions
    (the mutation batch's new edges append past ``csr_len``), deletions,
    and re-propertied edges, across all hop directions. This is the
    tentpole's drop-in guarantee: the sharded serve loop swaps executors
    without moving a byte of output."""
    from repro.kernels.block_gather.ops import block_onehop_exec

    espec, spec = world["espec"], world["spec"]
    pspec, pstore = world["pspec"], world["pstore"]
    mb = _mutation_batch(spec)
    fn = jax.vmap(
        lambda ps, me: apply_mutations_partitioned(pspec, ps, mb, me, "sh"),
        axis_name="sh", in_axes=(_PS_AX, 0),
    )
    ps2_s, _, ovf = fn(_stacked_local(pspec, pstore), jnp.arange(N))
    assert int(ovf[0]) == 0
    ps2 = _restack(pspec, ps2_s)
    # the recent regions are actually live — the parity below covers them
    assert any(
        int(blk.blk_len[0]) > int(blk.csr_len[0])
        for blk in (ps2.out, ps2.inc)
    )

    hop = sq1_hop() if direction != DIR_IN else sq2_hop()
    hop = hop._replace(direction=direction)
    roots = np.array([0, 1, 2, 3, 5, 9, 11, 16, 63, -1, 64], np.int32)
    rmask = np.array([True] * 9 + [False, True])
    params = jnp.broadcast_to(jnp.asarray(hop.params), (len(roots), 6))
    for s in range(N):
        view = BlockStoreView(pspec, local_shard(pspec, ps2, s), s)
        m = jnp.asarray(rmask & _own(pspec, roots, s))
        a = onehop_exec_view(
            espec, view, direction, hop.edge_label, hop.pr, hop.pe, hop.pl,
            jnp.asarray(roots), params, m,
        )
        b = block_onehop_exec(
            espec, view, direction, hop.edge_label, hop.pr, hop.pe, hop.pl,
            jnp.asarray(roots), params, m,
        )
        for name, x, y in zip(("leaves", "lmask", "n_true", "trunc"), a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (s, name)
        for k in ("edges_scanned", "leaf_fetches", "scanned", "scanned_mask"):
            assert np.array_equal(
                np.asarray(a[4][k]), np.asarray(b[4][k])
            ), (s, k)
