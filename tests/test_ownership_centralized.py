"""Ownership is defined ONCE: the traced base rule ``partition.owner_of``
(+ its host twin ``routing.base_owner``) and the routing-table lookups
``storage_owner_of`` / ``cache_owner_of`` layered on top. This suite is the
grep-clean assertion the routing tier's satellite task calls for — a stray
hand-coded ``v % n`` anywhere else would silently diverge from the table
the moment a vertex migrates, so any new occurrence fails here with the
offending file:line.

Comment/docstring mentions are fine (they explain the rule); divisibility
checks (``% n == 0``) are not ownership; ``routing.py`` is the one module
allowed to spell out the modulo (it IS the rule).
"""

import os
import re

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# `something % n` where n is a shard count — the ownership-rule shape
_MOD = re.compile(r"%\s*(n|self\.n|rt\.n|rt2?\.n|n_shards|self\.n_shards)\b")

# the single module allowed to hand-code the base rule
_ALLOWED = {os.path.join("repro", "distributed", "routing.py")}


def _violations(root: str):
    out = []
    for dirpath, _dirs, files in os.walk(root):
        for fname in files:
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root)
            if rel in _ALLOWED:
                continue
            with open(path) as f:
                for i, line in enumerate(f, 1):
                    if not _MOD.search(line):
                        continue
                    s = line.strip()
                    if s.startswith("#"):
                        continue  # comment
                    if "`" in line:
                        continue  # docstring mention (``v % n`` prose)
                    if "== 0" in line:
                        continue  # divisibility check, not ownership
                    out.append(f"{rel}:{i}: {s}")
    return out


def test_no_stray_ownership_modulo_in_src():
    v = _violations(os.path.join(REPO, "src"))
    assert not v, (
        "hard-coded ownership modulo outside the routing tier — use "
        "partition.owner_of / routing.base_owner or a routing-table "
        "lookup:\n" + "\n".join(v)
    )


def test_no_stray_ownership_modulo_in_tests_and_benchmarks():
    v = []
    for d in ("tests", "benchmarks"):
        v += _violations(os.path.join(REPO, d))
    assert not v, (
        "hard-coded ownership modulo in test/bench code — import the "
        "routing-tier lookup instead:\n" + "\n".join(v)
    )


def test_base_rule_and_table_agree_when_empty():
    import numpy as np

    from repro.distributed.routing import (
        RoutingTableHost,
        base_owner,
        cache_owner_of,
        identity_table,
        storage_owner_of,
    )

    n = 8
    vids = np.arange(64, dtype=np.int32)
    expect = base_owner(vids, n)
    assert np.array_equal(np.asarray(storage_owner_of(None, vids, n)), expect)
    t = identity_table(n)
    assert np.array_equal(np.asarray(storage_owner_of(t, vids, n)), expect)
    assert np.array_equal(np.asarray(cache_owner_of(t, vids, n)), expect)
    rh = RoutingTableHost(n)
    assert np.array_equal(rh.storage_owner(vids), expect)
    assert np.array_equal(rh.cache_owner(vids), expect)
