"""Property pins for the journal's durability contract.

Two invariants the failover layer leans on:

- **Torn-tail recovery** — a crash can cut the log at ANY byte. Reopen
  must recover exactly the longest crc-valid record prefix: every frame
  fully contained in the surviving bytes, nothing after the cut, no
  half-parsed garbage. Tested exhaustively (every truncation offset of a
  multi-record log) plus a randomized corruption variant when
  ``hypothesis`` is available (the container may not ship it — skipped,
  not failed, in that case: the exhaustive loop is the load-bearing pin).
- **Incremental checkpoint composition** — ``incremental ∘ incremental``
  over a full base must restore byte-identically to both the live store
  and a fresh full snapshot of the same state; recovery correctness must
  not depend on checkpoint cadence or kind.
"""

import os

import numpy as np
import pytest

from conftest import build_world, enabled_ttable, fig1_plan
from repro.core import CacheSpec, EngineSpec
from repro.distributed import flat_mesh
from repro.distributed.graph_serve import ShardedTxnRuntime
from repro.graphstore import (
    WriteBehindJournal,
    make_mutation_batch,
    replay,
    restore_chain,
)
from repro.graphstore.journal import _HEADER


def _mb(spec, i=0):
    return make_mutation_batch(
        spec,
        new_edges=[(i % 4, 4 + (i % 8), 0, [1])],
        set_vprops=[(i % 4, 0, i % 2)],
    )


def _flushed_log(tmp_path, n_records=3):
    """A journal with ``n_records`` durable commits; returns the raw log
    bytes and the frame end offsets (prefix lengths at which the log is
    whole)."""
    spec, _ = build_world()
    j = WriteBehindJournal(str(tmp_path / "src"), 2)
    for i in range(n_records):
        j.append_commit(_mb(spec, i))
    j.flush()
    data = open(j.log_path, "rb").read()
    ends, off = [], 0
    while off < len(data):
        _, _, _, plen, _ = _HEADER.unpack_from(data, off)
        off += _HEADER.size + plen
        ends.append(off)
    assert len(ends) == n_records and ends[-1] == len(data)
    return data, ends


def _reopen_with_log(root, payload_bytes):
    """A fresh journal root holding only the (possibly torn) log — the
    post-crash worst case: no meta file survived, the log is ground
    truth."""
    os.makedirs(root, exist_ok=True)
    with open(os.path.join(root, "wal.log"), "wb") as f:
        f.write(payload_bytes)
    return WriteBehindJournal(root, 2)


def test_torn_tail_every_byte_offset(tmp_path):
    """Exhaustive crash-point sweep: truncate the flushed log at EVERY
    byte offset; reopen must recover exactly the frames that survived
    whole, and the next append must not reuse their seqs."""
    data, ends = _flushed_log(tmp_path)
    for cut in range(len(data) + 1):
        n_whole = sum(1 for e in ends if e <= cut)
        j = _reopen_with_log(str(tmp_path / f"cut{cut}"), data[:cut])
        recs = j.read_records()
        assert [r.seq for r in recs] == list(range(1, n_whole + 1)), (
            f"cut at byte {cut}: expected {n_whole} whole frames"
        )
        assert j.durable_seq == n_whole
        assert j.next_seq == n_whole + 1  # torn seqs are never resurrected


def test_torn_tail_reflush_truncates_garbage(tmp_path):
    """After a mid-frame cut, the next flush must overwrite the torn bytes
    (truncate-to-durable-offset), leaving a clean log: prefix + new record."""
    spec, _ = build_world()
    data, ends = _flushed_log(tmp_path)
    cut = ends[-1] - 3  # tear the last frame
    j = _reopen_with_log(str(tmp_path / "reflush"), data[:cut])
    j.append_commit(_mb(spec, 9))
    j.flush()
    assert [r.seq for r in j.read_records()] == [1, 2, 3]
    # byte-level: the surviving prefix is untouched, the tail is the new
    # frame only — no torn remnant between them
    newdata = open(j.log_path, "rb").read()
    assert newdata[: ends[-2]] == data[: ends[-2]]
    _, seq, _, plen, _ = _HEADER.unpack_from(newdata, ends[-2])
    assert seq == 3 and ends[-2] + _HEADER.size + plen == len(newdata)


def test_header_corruption_every_byte_offset(tmp_path):
    """Exhaustive single-byte header-damage sweep: flip each of the 21
    header bytes of each frame in turn. The crc covers the header fields
    (and the crc field guards itself by mismatching), so EVERY header byte
    offset must end the scan at the damaged frame — including a corrupted
    ``payload_len``, which under the old payload-only crc could silently
    mis-delimit the rest of the stream."""
    data, ends = _flushed_log(tmp_path)
    starts = [0] + ends[:-1]
    for frame, s in enumerate(starts):
        for rel in range(_HEADER.size):
            corrupted = bytearray(data)
            corrupted[s + rel] ^= 0x40
            j = _reopen_with_log(
                str(tmp_path / f"hdr{frame}_{rel}"), bytes(corrupted)
            )
            recs = j.read_records()
            assert [r.seq for r in recs] == list(range(1, frame + 1)), (
                f"frame {frame} header byte {rel}: damage not detected"
            )
            assert j.durable_seq == frame


def test_torn_tail_randomized_corruption(tmp_path):
    """Hypothesis variant: flip an arbitrary byte — header OR payload — of
    an arbitrary frame. The crc covers both (header bytes [0:17] + payload),
    so any single-byte change ends the scan at the damaged frame and
    recovery yields exactly the frames strictly before it."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    data, ends = _flushed_log(tmp_path)
    starts = [0] + ends[:-1]
    counter = [0]

    @hyp.settings(max_examples=30, deadline=None)
    @hyp.given(
        frame=st.integers(0, len(ends) - 1),
        rel=st.integers(0, min(e - s for s, e in zip(starts, ends)) - 1),
        flip=st.integers(1, 255),
    )
    def check(frame, rel, flip):
        corrupted = bytearray(data)
        corrupted[starts[frame] + rel] ^= flip
        counter[0] += 1
        j = _reopen_with_log(
            str(tmp_path / f"fuzz{counter[0]}"), bytes(corrupted)
        )
        # the damaged frame ends the scan; the prefix survives intact
        recs = j.read_records()
        assert [r.seq for r in recs] == list(range(1, frame + 1))
        for k, r in enumerate(recs):
            assert r.payload == data[starts[k] + _HEADER.size : ends[k]]
        assert j.durable_seq == frame

    check()


def test_incremental_compose_equals_full(tmp_path):
    """incremental ∘ incremental ≡ full: two stacked incremental overlays
    over a full base restore byte-identically to (a) the live store and
    (b) a fresh full snapshot of the same state — and the chain actually
    exercised composition (both tips are ``kind: incremental``)."""
    import jax

    spec, store = build_world()
    cspec = CacheSpec(capacity=256, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, _, _ = enabled_ttable()
    mesh = flat_mesh(1)

    rt = ShardedTxnRuntime(espec, mesh, route_cap_factor=None, blk_slack=1.0)
    ps = rt.partition_store(store)
    cache = rt.empty_cache()
    j = WriteBehindJournal(str(tmp_path / "j"), rt.n)

    def ckpt(fn):
        return fn(
            ps, e_blk_cap=rt.pspec.e_blk_cap,
            recent_blk_cap=rt.pspec.recent_blk_cap,
            store_version=int(jax.device_get(ps.version)),
        )

    ckpt(j.checkpoint)  # the full base
    for i in range(2):
        ps, cache, _ = rt.run_grw_tx(ps, cache, ttable, _mb(spec, i), journal=j)
    ckpt(j.checkpoint_incremental)  # overlay 1
    for i in range(2, 4):
        ps, cache, _ = rt.run_grw_tx(ps, cache, ttable, _mb(spec, i), journal=j)
    ckpt(j.checkpoint_incremental)  # overlay 2 — composes on overlay 1
    j.flush()

    # the chain is what we think it is: incremental -> incremental -> full
    tip_seq, tip_meta = j.latest_checkpoint()
    assert tip_meta["kind"] == "incremental"
    mid_meta = j.checkpoint_meta(tip_meta["base_seq"])
    assert mid_meta["kind"] == "incremental"
    assert j.checkpoint_meta(mid_meta["base_seq"])["kind"] == "full"

    live = [np.asarray(x) for x in
            jax.tree_util.tree_leaves(jax.device_get(ps))]

    # (a) chain restore == live store, byte for byte
    rt2 = ShardedTxnRuntime(espec, mesh, route_cap_factor=None, blk_slack=1.0)
    j2 = WriteBehindJournal(str(tmp_path / "j"), rt2.n)
    chain_ps, chain_seq, _ = restore_chain(j2, rt2)
    assert chain_seq == tip_seq
    chain = [np.asarray(x) for x in
             jax.tree_util.tree_leaves(jax.device_get(chain_ps))]
    for a, b in zip(chain, live):
        assert a.dtype == b.dtype and np.array_equal(a, b)

    # (b) == a fresh FULL snapshot of the same live state
    jf = WriteBehindJournal(str(tmp_path / "jf"), rt.n)
    jf.checkpoint(
        ps, e_blk_cap=rt.pspec.e_blk_cap,
        recent_blk_cap=rt.pspec.recent_blk_cap,
        store_version=int(jax.device_get(ps.version)),
    )
    full_ps, _, _ = restore_chain(WriteBehindJournal(str(tmp_path / "jf"),
                                                     rt.n), rt2)
    for a, b in zip(
        jax.tree_util.tree_leaves(jax.device_get(full_ps)), chain
    ):
        assert np.array_equal(np.asarray(a), b)


def test_incremental_chain_plus_tail_replay(tmp_path):
    """Records appended after the newest incremental checkpoint replay on
    top of the restored chain — the recovery path the failover controller
    runs (restore_chain + journal tail) reproduces the live store."""
    import jax

    spec, store = build_world()
    cspec = CacheSpec(capacity=256, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, _, _ = enabled_ttable()
    plan = fig1_plan()
    mesh = flat_mesh(1)

    rt = ShardedTxnRuntime(espec, mesh, route_cap_factor=None, blk_slack=1.0)
    ps = rt.partition_store(store)
    cache = rt.empty_cache()
    j = WriteBehindJournal(str(tmp_path / "j"), rt.n)
    j.checkpoint(ps, e_blk_cap=rt.pspec.e_blk_cap,
                 recent_blk_cap=rt.pspec.recent_blk_cap, store_version=0)
    ps, cache, _ = rt.run_grw_tx(ps, cache, ttable, _mb(spec, 0), journal=j)
    j.checkpoint_incremental(
        ps, e_blk_cap=rt.pspec.e_blk_cap,
        recent_blk_cap=rt.pspec.recent_blk_cap,
        store_version=int(jax.device_get(ps.version)),
    )
    # the journal tail past the checkpoint
    ps, cache, _ = rt.run_grw_tx(ps, cache, ttable, _mb(spec, 1), journal=j)
    ps, cache, _ = rt.run_grw_tx(ps, cache, ttable, _mb(spec, 2),
                                 policy="write-through", journal=j)
    j.stop(final_flush=True)

    rt2 = ShardedTxnRuntime(espec, mesh, route_cap_factor=None, blk_slack=1.0)
    j2 = WriteBehindJournal(str(tmp_path / "j"), rt2.n)
    ps2, last, info = replay(j2, rt2, ttable)
    assert info["replayed_commits"] == 2  # only the tail, not the chain
    for a, b in zip(
        jax.tree_util.tree_leaves(jax.device_get(ps2)),
        jax.tree_util.tree_leaves(jax.device_get(ps)),
    ):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    roots = np.array([0, 1, 2, 3], np.int32)
    res_a, _, _ = rt.run_gr_tx_batch(ps, rt.empty_cache(), ttable, plan, roots)
    res_b, _, _ = rt2.run_gr_tx_batch(ps2, rt2.empty_cache(), ttable, plan,
                                      roots)
    assert np.array_equal(res_a, res_b)
