"""Unit coverage for the replicated routing table (distributed.routing).

The table is the single source of vertex placement: a fixed-shape device
pytree (traced input of the serving step — never a recompile) mirrored by
a mutable host object. These tests pin the lookup semantics (base rule +
storage overlay + cache overlay), the epoch/caching discipline, and the
capacity guardrails, all host-side — the runtime integration lives in
test_routing_runtime.py / test_sharded_collectives.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.routing import (
    DEFAULT_TABLE_CAP,
    RoutingTableHost,
    base_owner,
    cache_owner_of,
    identity_table,
    storage_owner_of,
    storage_view,
)

N = 8


def owners(fn, rtable, vids):
    return np.asarray(fn(rtable, np.asarray(vids, np.int32), N))


def test_identity_table_is_the_base_rule():
    vids = np.arange(200, dtype=np.int32)
    expect = base_owner(vids, N)
    for t in (None, identity_table(N)):
        assert np.array_equal(owners(storage_owner_of, t, vids), expect)
        assert np.array_equal(owners(cache_owner_of, t, vids), expect)


def test_storage_exception_overrides_only_its_vid():
    rh = RoutingTableHost(N)
    rh.set_storage_owner(10, 5)  # native owner is 2
    t = rh.device_table()
    vids = np.arange(64, dtype=np.int32)
    got = owners(storage_owner_of, t, vids)
    expect = base_owner(vids, N).copy()
    expect[10] = 5
    assert np.array_equal(got, expect)
    # cache owner follows storage unless a cache exception re-points it
    assert np.array_equal(owners(cache_owner_of, t, vids), expect)
    # host lookups agree with the device table
    assert np.array_equal(rh.storage_owner(vids), expect)
    assert rh.storage_owner(10) == 5 and rh.storage_owner(11) == 3


def test_cache_exception_layers_on_storage():
    rh = RoutingTableHost(N)
    rh.set_storage_owner(10, 5)
    rh.set_cache_owner(10, 7)   # split vertex: rows at 5, cache home at 7
    rh.set_cache_owner(3, 0)    # unmigrated vertex with a locality home
    t = rh.device_table()
    vids = np.arange(16, dtype=np.int32)
    st = owners(storage_owner_of, t, vids)
    ca = owners(cache_owner_of, t, vids)
    assert st[10] == 5 and ca[10] == 7
    assert st[3] == 3 and ca[3] == 0
    assert np.array_equal(st[ca != st], np.asarray([3, 5]))
    assert rh.is_split(np.asarray([10, 3, 4]).astype(np.int32)).tolist() == [
        True, True, False,
    ]
    # storage_view strips cache overlays but keeps placement, and the
    # pytree structure is unchanged (same compiled program)
    sv = storage_view(t)
    assert np.array_equal(owners(cache_owner_of, sv, vids), st)
    assert jnp.asarray(sv.epoch).shape == jnp.asarray(t.epoch).shape
    sv2 = rh.storage_table()
    assert np.array_equal(owners(cache_owner_of, sv2, vids), st)


def test_moving_home_deletes_the_exception():
    rh = RoutingTableHost(N)
    rh.set_storage_owner(10, 5)
    assert rh.has_exceptions()
    rh.set_storage_owner(10, base_owner(10, N))  # back to native
    assert not rh.has_exceptions()
    assert rh.storage_exceptions == {}


def test_apply_moves_is_one_epoch_bump_and_clears_cache_overlay():
    rh = RoutingTableHost(N)
    rh.set_cache_owner(9, 4)
    e0 = rh.epoch
    rh.apply_moves([(9, 6), (17, 0)])
    assert rh.epoch == e0 + 1  # ONE bump for the whole round
    assert rh.storage_owner(9) == 6 and rh.storage_owner(17) == 0
    # the cache home follows the rows on migration
    assert rh.cache_exceptions == {}
    assert rh.cache_owner(9) == 6


def test_device_table_is_cached_per_epoch():
    rh = RoutingTableHost(N)
    rh.set_storage_owner(10, 5)
    t1 = rh.device_table()
    assert rh.device_table() is t1  # unchanged epoch → same stamp
    rh.set_storage_owner(11, 6)
    t2 = rh.device_table()
    assert t2 is not t1
    assert int(np.asarray(t2.epoch)) > int(np.asarray(t1.epoch))
    # the stamped epoch tracks the host epoch
    assert int(np.asarray(t2.epoch)) == rh.epoch


def test_capacity_overflow_raises_instead_of_recompiling():
    rh = RoutingTableHost(N, cap=2)
    rh.set_storage_owner(10, 5)
    rh.set_storage_owner(11, 5)
    with pytest.raises(ValueError, match="full"):
        rh.set_storage_owner(12, 5)
    with pytest.raises(ValueError, match="full"):
        rh.apply_moves([(13, 6)])  # 13's native owner is 5 — a real move
    # shapes are static: cap is a table property, not data-dependent
    assert identity_table(N, cap=2).cap == 2
    assert identity_table(N).cap == DEFAULT_TABLE_CAP


def test_owner_range_validated():
    rh = RoutingTableHost(N)
    with pytest.raises(ValueError, match="out of range"):
        rh.set_storage_owner(1, N)
    with pytest.raises(ValueError, match="out of range"):
        rh.set_cache_owner(1, -1)


def test_metrics_report_table_state():
    rh = RoutingTableHost(N)
    rh.set_storage_owner(10, 5)
    rh.set_cache_owner(3, 0)
    m = rh.metrics()
    assert m["table_epoch"] == rh.epoch
    assert m["storage_exceptions"] == 1
    assert m["cache_exceptions"] == 1
