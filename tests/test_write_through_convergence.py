"""Property: write-through-updated entries converge to fresh re-population.

After an arbitrary mutation batch commits under the write-through policy,
every cache entry that *survived* (was updated in place rather than deleted)
must hold exactly the leaf set a fresh CP re-population of the same key
would produce against the post-mutation store — in-place maintenance may
never be observably different from delete + repopulate (§3.2's correctness
bar for the policy the paper designed but did not implement).
"""

import numpy as np
import jax.numpy as jnp

from conftest import (
    E_INCLUDES,
    L_LISTING,
    P_STATUS,
    TPL_META,
    build_world,
    enabled_ttable,
    fig1_plan,
)
from repro.core import (
    CacheSpec,
    EngineSpec,
    GraphEngine,
    cache_lookup,
    empty_cache,
    run_grw_tx,
)
from repro.core.population import CachePopulator, populate_step
from repro.graphstore import make_mutation_batch


def _ids(leaves, lmask):
    return set(np.asarray(leaves)[np.asarray(lmask)].tolist())


def _random_batch(rng, spec, store, n_listings, lo_listing):
    """A random mixed mutation batch over live graph elements."""
    e_len = int(store.e_len)
    listings = lambda k: rng.integers(lo_listing, lo_listing + n_listings, k)
    new_edges = [
        (int(rng.integers(0, 4)), int(v), E_INCLUDES, [int(rng.integers(0, 2))])
        for v in listings(rng.integers(0, 3))
    ]
    del_edges = [int(e) for e in rng.choice(e_len, rng.integers(0, 3), replace=False)]
    set_vprops = [
        (int(v), P_STATUS, int(rng.integers(0, 2)))
        for v in listings(rng.integers(0, 4))
    ]
    del_vertices = [int(v) for v in listings(rng.integers(0, 2))]
    return make_mutation_batch(
        spec, new_edges=new_edges, del_edges=del_edges,
        set_vprops=set_vprops, del_vertices=del_vertices,
    )


def test_write_through_entries_equal_fresh_repopulation():
    for seed in range(4):
        spec, store = build_world(n_watchlists=5, n_listings=14, seed=seed)
        cspec = CacheSpec(capacity=1024, probes=8, max_leaves=8, max_chunks=2)
        espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=16)
        ttable, _, _ = enabled_ttable()
        rng = np.random.default_rng(100 + seed)

        # warm the cache for every watch-list root of the fig1 template
        plan = fig1_plan()
        eng = GraphEngine(espec, plan, use_cache=True)
        roots = np.arange(5, dtype=np.int32)
        _, misses, _ = eng.run(store, empty_cache(cspec), ttable, roots)
        pop = CachePopulator(espec, TPL_META)
        pop.queue.push(misses)
        cache = pop.drain(store, store, empty_cache(cspec), ttable)
        keys = sorted({(m.tpl_idx, m.root, tuple(m.params.tolist())) for m in misses})
        assert keys, "warm produced no cacheable keys"

        # one random write-through commit
        mb = _random_batch(rng, spec, store, 14, 5)
        store2, cache_wt, _ = run_grw_tx(
            espec, store, cache, ttable, mb, policy="write-through"
        )

        # freshly re-populate the same keys against the post-mutation store
        k_roots = jnp.asarray([k[1] for k in keys], jnp.int32)
        k_params = jnp.asarray([k[2] for k in keys], jnp.int32)
        hop = plan.hops[0]
        cache_re, _, _ = populate_step(
            espec, store2, store2, empty_cache(cspec), ttable,
            tpl_idx=0, direction=hop.direction, edge_label=hop.edge_label,
            roots=k_roots, params=k_params,
            mask=jnp.ones(len(keys), bool),
            read_versions=jnp.full(len(keys), int(store2.version), jnp.int32),
        )

        checked = 0
        for i, (tpl, root, params) in enumerate(keys):
            hit_wt, lv_wt, lm_wt, _ = cache_lookup(
                cspec, cache_wt, tpl, k_roots[i : i + 1], k_params[i : i + 1]
            )
            if not bool(hit_wt[0]):
                continue  # deleted (sweep / fallback) — repopulation's job
            hit_re, lv_re, lm_re, _ = cache_lookup(
                cspec, cache_re, tpl, k_roots[i : i + 1], k_params[i : i + 1]
            )
            assert bool(hit_re[0]), (
                f"seed {seed}: write-through kept ({tpl}, {root}) but fresh "
                "execution cannot cache it"
            )
            got, want = _ids(lv_wt[0], lm_wt[0]), _ids(lv_re[0], lm_re[0])
            assert got == want, f"seed {seed} key ({tpl}, {root}): {got} != {want}"
            # set semantics: the in-place edit must not have grown dups
            assert int(jnp.sum(lm_wt[0])) == len(got)
            checked += 1
        assert checked > 0, f"seed {seed}: no surviving entries were checked"
