"""Async population OCC semantics + SC lifecycle two-phase workflows."""

import numpy as np

from conftest import MISSING, P_STATUS, TEMPLATES, TPL_META, fig1_plan
from repro.core import CacheSpec, GraphEngine, cache_stats, empty_cache, make_template_table
from repro.core.lifecycle import GraphQP, ServiceCoordinator, TemplateState
from repro.core.population import CachePopulator
from repro.graphstore import apply_mutations, make_mutation_batch


def _neighbor_of(world, root):
    """Any vertex adjacent to ``root`` (guaranteed in the CP read set)."""
    esrc = np.asarray(world["store"].esrc[: int(world["store"].e_len)])
    edst = np.asarray(world["store"].edst[: int(world["store"].e_len)])
    return int(edst[esrc == root][0])


def test_populate_conflict_aborts_and_retries(world):
    eng = GraphEngine(world["espec"], fig1_plan(), use_cache=True)
    roots = np.array([0], np.int32)
    _, misses, _ = eng.run(world["store"], world["cache"], world["ttable"], roots)
    pop = CachePopulator(world["espec"], TPL_META, max_retries=3)
    pop.queue.push(misses)
    # interleave a conflicting write between CP read and CP commit:
    # store_exec = old snapshot; store_commit = post-write state
    leaf = _neighbor_of(world, 0)
    mb = make_mutation_batch(world["spec"], set_vprops=[(leaf, P_STATUS, 1)])
    store2, _ = apply_mutations(world["spec"], world["store"], mb)
    cache = pop.drain(world["store"], store2, world["cache"], world["ttable"])
    assert pop.aborted >= 1 and pop.committed == 0
    assert cache_stats(cache)["inserts"] == 0  # no stale entry installed
    # retry against the *current* snapshot commits cleanly
    cache = pop.drain(store2, store2, cache, world["ttable"])
    assert pop.committed == 1
    # and the retried entry matches the post-write world
    res, _, m = eng.run(store2, cache, world["ttable"], roots)
    assert m["hits"] == 1


def test_populate_retry_budget_discards(world):
    eng = GraphEngine(world["espec"], fig1_plan(), use_cache=True)
    roots = np.array([0], np.int32)
    _, misses, _ = eng.run(world["store"], world["cache"], world["ttable"], roots)
    pop = CachePopulator(world["espec"], TPL_META, max_retries=2)
    pop.queue.push(misses)
    store, cache = world["store"], world["cache"]
    leaf = _neighbor_of(world, 0)
    for i in range(3):
        # keep a conflicting write in flight every round
        mb = make_mutation_batch(world["spec"], set_vprops=[(leaf, P_STATUS, i % 2)])
        store2, _ = apply_mutations(world["spec"], store, mb)
        cache = pop.drain(store, store2, cache, world["ttable"])
        store = store2
        if len(pop.queue) == 0:
            break
    assert pop.queue.discarded == 1  # §4: bounded retries then discard
    assert pop.committed == 0


def test_queue_dedupes_inflight_misses(world):
    eng = GraphEngine(world["espec"], fig1_plan(), use_cache=True)
    roots = np.array([0], np.int32)
    _, misses, _ = eng.run(world["store"], world["cache"], world["ttable"], roots)
    pop = CachePopulator(world["espec"], TPL_META)
    pop.queue.push(misses)
    pop.queue.push(misses)  # same miss seen twice before population
    assert len(pop.queue) == 1


def test_lifecycle_two_phase_with_drops():
    qps = [GraphQP(f"qp{i}") for i in range(5)]
    sc = ServiceCoordinator(qps, seed=7, drop_prob=0.4)
    sc.register(0)
    sc.enable(0)
    assert sc.states[0] == TemplateState.ENABLED
    assert sc.messages_dropped > 0  # retries actually happened
    assert sc.check_safety()
    for qp in qps:
        assert 0 in qp.read_active and 0 in qp.write_active


def test_lifecycle_disable_clears_entries(world):
    # warm one entry
    eng = GraphEngine(world["espec"], fig1_plan(), use_cache=True)
    roots = np.array([0], np.int32)
    _, misses, _ = eng.run(world["store"], world["cache"], world["ttable"], roots)
    pop = CachePopulator(world["espec"], TPL_META)
    pop.queue.push(misses)
    cache = pop.drain(world["store"], world["store"], world["cache"], world["ttable"])
    assert cache_stats(cache)["occupancy"] == 1
    sc, qp = world["sc"], world["qp"]
    cache = sc.disable_and_remove(0, cache, world["cspec"])
    assert sc.states[0] == TemplateState.REMOVED
    assert cache_stats(cache)["occupancy"] == 0
    ttable = qp.ttable_masks(world["ttable"], len(TEMPLATES))
    _, _, m = eng.run(world["store"], cache, ttable, roots)
    assert m["hits"] == 0


def test_lifecycle_phase_order_never_violates_safety():
    # drive many enables/disables with message loss; safety must hold at
    # every observable point (we check after each workflow; the workflow
    # itself is atomic in the sim because _request_all retries to completion)
    qps = [GraphQP(f"qp{i}") for i in range(3)]
    sc = ServiceCoordinator(qps, seed=3, drop_prob=0.5)
    cspec = CacheSpec(capacity=64, probes=2, max_leaves=2, max_chunks=1)
    cache = empty_cache(cspec)
    for t in range(4):
        sc.register(t)
        sc.enable(t)
        assert sc.check_safety()
    for t in range(2):
        cache = sc.disable_and_remove(t, cache, cspec)
        assert sc.check_safety()
