"""Write-behind journal: record format, group-commit coalescing, dirty-owner
map, bounded-retry fault injection (no lost / no duplicated records), torn
tails, liveness epochs, and checkpoint+replay reconstruction on a 1-shard
mesh (the collective-free degenerate case tier-1 can run; the 8-device
crash/restart byte-identity pin lives in ``test_durability_runtime``)."""

import json
import os

import numpy as np
import pytest

from conftest import build_world, enabled_ttable, fig1_plan
from repro.core import CacheSpec, EngineSpec
from repro.distributed import flat_mesh
from repro.distributed.fault import RetryPolicy
from repro.distributed.graph_serve import ShardedTxnRuntime
from repro.graphstore import (
    DeviceGate,
    EpochRegistry,
    FlushError,
    WriteBehindJournal,
    make_mutation_batch,
    replay,
)
from repro.graphstore.journal import (
    REC_COMMIT,
    REC_COMPACT,
    REC_GROW,
    decode_commit,
    encode_commit,
)


def _mb(spec, i=0):
    return make_mutation_batch(
        spec,
        new_edges=[(i % 4, 4 + (i % 8), 0, [1])],
        set_vprops=[(i % 4, 0, i % 2)],
    )


def test_commit_record_roundtrip():
    spec, _ = build_world()
    mb = _mb(spec, 3)
    gate = DeviceGate(recent_fill_frac=0.25, purge=True)
    payload = encode_commit(mb, policy="write-through", gate=gate)
    mb2, policy, gate2 = decode_commit(payload)
    assert policy == "write-through"
    assert gate2 == gate
    for f in mb._fields:
        a, b = np.asarray(getattr(mb, f)), np.asarray(getattr(mb2, f))
        assert a.shape == b.shape and a.dtype == b.dtype, f
        assert np.array_equal(a, b), f
    # no gate/policy defaults survive a None-gate encode
    _, policy0, gate0 = decode_commit(encode_commit(mb))
    assert policy0 == "write-around" and gate0 is None


def test_group_commit_coalescing_and_metrics(tmp_path):
    spec, _ = build_world()
    j = WriteBehindJournal(str(tmp_path / "j"), 4)
    for i in range(5):
        j.append_commit(_mb(spec, i), commit_version=i + 1)
    j.append_compact(purge=False)
    j.append_grow(512, 64)
    m = j.metrics()
    assert m["journal_lag_batches"] == 7 and m["flush_queue_depth"] == 7
    # one flush cycle persists the whole queue: ONE group write, not 7
    assert j.flush() == 7
    m = j.metrics()
    assert m["flushes"] == 1 and m["flushed_records"] == 7
    assert m["journal_lag_batches"] == 0 and m["flush_queue_depth"] == 0
    recs = j.read_records()
    assert [r.seq for r in recs] == list(range(1, 8))
    assert [r.rtype for r in recs] == [REC_COMMIT] * 5 + [REC_COMPACT, REC_GROW]
    # records are never merged or reordered by coalescing
    for i, r in enumerate(recs[:5]):
        mb, _, _ = decode_commit(r.payload)
        ref = _mb(spec, i)
        assert np.array_equal(
            np.asarray(mb.ne_dst), np.asarray(ref.ne_dst)
        )


def test_dirty_owner_map(tmp_path):
    spec, _ = build_world()
    n = 4
    j = WriteBehindJournal(str(tmp_path / "j"), n)
    mb = make_mutation_batch(spec, new_edges=[(0, 5, 0, [1]), (4, 9, 0, [0])])
    j.append_commit(mb)
    # edge (0,5): owners 0 (src) and 1 (dst); edge (4,9): owners 0 and 1
    assert j.metrics()["dirty_owners"] == 2
    # delete sections can't resolve geid->owner host-side: conservative all
    j.append_commit(make_mutation_batch(spec, del_edges=[3]))
    assert j.metrics()["dirty_owners"] == n
    j.flush()
    assert j.metrics()["dirty_owners"] == 0


def test_torn_tail_is_ignored(tmp_path):
    spec, _ = build_world()
    j = WriteBehindJournal(str(tmp_path / "j"), 2)
    j.append_commit(_mb(spec, 0))
    j.append_commit(_mb(spec, 1))
    j.flush()
    with open(j.log_path, "ab") as f:
        f.write(b"GJL1" + b"\x07" * 11)  # short frame: a crashed writer
    assert [r.seq for r in j.read_records()] == [1, 2]
    # a corrupt payload (crc mismatch) also ends the scan cleanly
    j2 = WriteBehindJournal(str(tmp_path / "j2"), 2)
    j2.append_commit(_mb(spec, 0))
    j2.flush()
    data = bytearray(open(j2.log_path, "rb").read())
    data[-1] ^= 0xFF
    open(j2.log_path, "wb").write(bytes(data))
    assert j2.read_records() == []


def test_reopen_rescans_durable_tail(tmp_path):
    """The log (not the meta file) is the durability ground truth: a flush
    that landed but crashed before the meta rewrite keeps its seqs, and a
    torn tail is truncated by the next flush without reusing its seqs."""
    spec, _ = build_world()
    root = str(tmp_path / "j")
    j = WriteBehindJournal(root, 2)
    j.append_commit(_mb(spec, 0))
    j.append_commit(_mb(spec, 1))
    j.flush()
    os.remove(j.meta_path)  # crash between flush and meta publish
    with open(j.log_path, "ab") as f:
        f.write(b"\x00" * 9)  # torn tail from a mid-write crash
    j2 = WriteBehindJournal(root, 2)
    assert j2.durable_seq == 2 and j2.next_seq == 3
    j2.append_commit(_mb(spec, 2))
    j2.flush()
    assert [r.seq for r in j2.read_records()] == [1, 2, 3]


def test_flush_fault_bounded_retries_no_loss_no_dup(tmp_path):
    spec, _ = build_world()
    fails = {"n": 2}

    def fault(attempt):
        if attempt < fails["n"]:
            raise OSError(f"injected flush fault {attempt}")

    j = WriteBehindJournal(
        str(tmp_path / "j"), 2,
        retry=RetryPolicy(max_attempts=4, base_delay=0.0), flush_fault=fault,
    )
    for i in range(3):
        j.append_commit(_mb(spec, i))
    assert j.flush() == 3
    m = j.metrics()
    assert m["flush_retries"] == 2 and m["flush_failures"] == 0
    # the torn attempts left no partial frames and the retries no duplicates
    assert [r.seq for r in j.read_records()] == [1, 2, 3]

    # exhaustion: bounded, surfaced, records stay pending (nothing lost)
    fails["n"] = 10 ** 9
    j.append_commit(_mb(spec, 3))
    with pytest.raises(FlushError):
        j.flush()
    assert j.metrics()["flush_failures"] == 1
    assert j.metrics()["flush_queue_depth"] == 1
    assert [r.seq for r in j.read_records()] == [1, 2, 3]
    # fault clears -> the same record flushes exactly once
    fails["n"] = 0
    assert j.flush() == 1
    assert [r.seq for r in j.read_records()] == [1, 2, 3, 4]


def test_async_flusher_absorbs_faults(tmp_path):
    spec, _ = build_world()
    calls = []

    def fault(attempt):
        calls.append(attempt)
        if len(calls) == 1:
            raise OSError("injected")

    j = WriteBehindJournal(
        str(tmp_path / "j"), 2,
        retry=RetryPolicy(max_attempts=3, base_delay=0.0), flush_fault=fault,
    )
    j.start(interval=0.001)
    for i in range(4):
        j.append_commit(_mb(spec, i))
    deadline = 100
    while j.metrics()["flush_queue_depth"] and deadline:
        import time

        time.sleep(0.01)
        deadline -= 1
    j.stop()
    assert j.metrics()["journal_lag_batches"] == 0
    assert sorted(r.seq for r in j.read_records()) == [1, 2, 3, 4]


def test_epoch_registry_gates_purge(tmp_path):
    e = EpochRegistry()
    e.advance(5)
    assert e.min_pinned() == 5
    t1 = e.pin()  # reader at epoch 5
    e.advance(7)
    assert e.min_pinned() == 5
    assert not e.safe_to_purge(7)  # a reader may observe pre-images
    assert e.safe_to_purge(5)
    e.release(t1)
    assert e.safe_to_purge(7)
    # the journal checkpoint must also cover the store version: recovery
    # may not restore a pre-purge snapshot and replay across the purge
    j = WriteBehindJournal(str(tmp_path / "j"), 2)
    j.checkpoint_version = 6
    assert not j.epochs.safe_to_purge(7, j)
    j.checkpoint_version = 7
    j.epochs.advance(7)
    assert j.epochs.safe_to_purge(7, j)


def test_checkpoint_replay_reconstructs_store_1shard(tmp_path):
    """End-to-end recovery on the 1-shard degenerate mesh: checkpoint + a
    journal of gated COMMIT / COMPACT / GROW records replays to the exact
    pre-crash partitioned store, and the replayed store serves the same
    bytes."""
    spec, store = build_world()
    cspec = CacheSpec(capacity=256, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, _, _ = enabled_ttable()
    plan = fig1_plan()
    mesh = flat_mesh(1)
    gate = DeviceGate(recent_fill_frac=0.0)  # compact at every commit

    rt = ShardedTxnRuntime(espec, mesh, route_cap_factor=None, blk_slack=1.0)
    ps = rt.partition_store(store)
    cache = rt.empty_cache()
    j = WriteBehindJournal(str(tmp_path / "j"), rt.n)
    j.checkpoint(
        ps, e_blk_cap=rt.pspec.e_blk_cap,
        recent_blk_cap=rt.pspec.recent_blk_cap, store_version=0,
    )
    ps, cache, m1 = rt.run_grw_tx(
        ps, cache, ttable, _mb(spec, 0), gate=gate, journal=j
    )
    assert m1["device_compactions"] > 0
    # a host-scheduled compact + a capacity growth, journaled in order
    ps = rt.compact_step(False)(ps)
    j.append_compact(purge=False)
    ps = rt.grow_blocks(ps, rt.pspec.e_blk_cap + 7)
    j.append_grow(rt.pspec.e_blk_cap, rt.pspec.recent_blk_cap)
    ps, cache, _ = rt.run_grw_tx(
        ps, cache, ttable, _mb(spec, 1), policy="write-through",
        gate=gate, journal=j,
    )
    j.stop(final_flush=True)
    roots = np.array([0, 1, 2, 3], np.int32)
    res_pre, _, met_pre = rt.run_gr_tx_batch(ps, rt.empty_cache(), ttable,
                                             plan, roots)

    # crash: fresh runtime + journal objects over the same root
    rt2 = ShardedTxnRuntime(espec, mesh, route_cap_factor=None, blk_slack=1.0)
    j2 = WriteBehindJournal(str(tmp_path / "j"), rt2.n)
    ps2, last, info = replay(j2, rt2, ttable)
    assert info == {
        "replayed_commits": 2, "replayed_compactions": 1,
        "replayed_growths": 1, "replayed_migrations": 0,
    }
    assert rt2.pspec == rt.pspec
    for a, b in zip(
        jax_leaves(ps2), jax_leaves(ps)
    ):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    res_post, _, met_post = rt2.run_gr_tx_batch(
        ps2, rt2.empty_cache(), ttable, plan, roots
    )
    assert np.array_equal(res_pre, res_post)
    assert met_pre == met_post


def test_replay_requires_checkpoint(tmp_path):
    spec, store = build_world()
    j = WriteBehindJournal(str(tmp_path / "j"), 1)
    with pytest.raises(FileNotFoundError):
        replay(j, None, None)


def test_checkpoint_records_layout_spec(tmp_path):
    spec, store = build_world()
    cspec = CacheSpec(capacity=256, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    rt = ShardedTxnRuntime(espec, flat_mesh(1), route_cap_factor=None,
                           blk_slack=1.0)
    ps = rt.partition_store(store)
    j = WriteBehindJournal(str(tmp_path / "j"), 1)
    path = j.checkpoint(
        ps, e_blk_cap=rt.pspec.e_blk_cap,
        recent_blk_cap=rt.pspec.recent_blk_cap, store_version=3,
    )
    seq, meta = j.latest_checkpoint()
    assert seq == 0 and j.checkpoint_version == 3
    assert meta["e_blk_cap"] == rt.pspec.e_blk_cap
    assert json.load(open(os.path.join(path, "journal.json"))) == meta


def jax_leaves(tree):
    import jax

    return jax.tree_util.tree_leaves(tree)
