"""Property pins for the one-exchange hop protocol's packed wire format.

The sharded hop loop ships each routed frontier as ONE contiguous int32
frame per item — ``[root | flags | params]`` outbound, ``[vals | cnt]``
home — so route→exec→unroute costs a single all_to_all each direction
(see ``distributed.graph_serve``). Three invariants the exchange leans on:

- **pack ∘ unpack ≡ id** — framing is lossless for any int32 payload, so
  the packed exchange is byte-identical to the retired multi-collective
  chain by construction.
- **padding is never valid** — ``bucketize`` fills unrouted bucket slots
  with zeros; a zero flags lane decodes invalid (the VALID bit is set
  only by the sender), so a receiver can never execute a padding frame.
  This is why the fill is 0 and NOT ``NULL_ID``: ``(-1 & 1) == 1`` would
  light the VALID bit on every padding row.
- **overflow is surfaced, not silent** — routing more valid frames at one
  peer than its ``cap`` drops the excess AND counts every dropped frame
  in the returned overflow (the serve loop exposes it as the
  ``route_overflow`` metric and the bench asserts it is zero under the
  measured default caps); frames that do land are bit-exact.
"""

import numpy as np
import jax.numpy as jnp

from repro.core.keys import PARAM_LEN
from repro.core.runtime import (
    WIRE_FLAG_VALID,
    WIRE_QUERY_LANES,
    bucketize,
    pack_query_frame,
    pack_result_frame,
    route_plan,
    unpack_query_frame,
    unpack_result_frame,
)


def _rand_queries(rng, m):
    roots = rng.integers(-1, 1 << 20, size=m).astype(np.int32)
    flags = (rng.integers(0, 2, size=m) * WIRE_FLAG_VALID).astype(np.int32)
    params = rng.integers(-(1 << 15), 1 << 15,
                          size=(m, PARAM_LEN)).astype(np.int32)
    return roots, flags, params


def test_query_frame_roundtrip():
    rng = np.random.default_rng(0)
    roots, flags, params = _rand_queries(rng, 64)
    frame = pack_query_frame(
        jnp.asarray(roots), jnp.asarray(flags), jnp.asarray(params)
    )
    assert frame.shape == (64, WIRE_QUERY_LANES) and frame.dtype == jnp.int32
    r, f, p = unpack_query_frame(frame)
    assert np.array_equal(np.asarray(r), roots)
    assert np.array_equal(np.asarray(f), flags)
    assert np.array_equal(np.asarray(p), params)


def test_result_frame_roundtrip():
    rng = np.random.default_rng(1)
    vals = rng.integers(-1, 1 << 20, size=(32, 8)).astype(np.int32)
    # cnt's int32 lanes double as the hit/miss/deferred flag: -1 = deferred
    cnt = rng.integers(-1, 9, size=32).astype(np.int32)
    frame = pack_result_frame(jnp.asarray(vals), jnp.asarray(cnt))
    assert frame.shape == (32, 9)
    v, c = unpack_result_frame(frame)
    assert np.array_equal(np.asarray(v), vals)
    assert np.array_equal(np.asarray(c), cnt)


def test_bucketized_padding_frames_decode_invalid():
    """Route a batch into roomy buckets: every kept frame survives
    bit-exact at its assigned slot, and every OTHER bucket slot (the
    zero-filled padding) decodes flags == 0, i.e. invalid."""
    rng = np.random.default_rng(2)
    n, cap, m = 4, 8, 16
    roots, _, params = _rand_queries(rng, m)
    flags = np.full(m, WIRE_FLAG_VALID, np.int32)
    dest = rng.integers(-1, n, size=m).astype(np.int32)  # -1 rows = padding
    frame = pack_query_frame(
        jnp.asarray(roots), jnp.asarray(flags), jnp.asarray(params)
    )
    buckets, slot, kept, ovf = bucketize(frame, jnp.asarray(dest), n, cap,
                                         fill=0)
    assert int(ovf) == 0
    flat = np.asarray(buckets).reshape(n * cap, WIRE_QUERY_LANES)
    r, f, p = (np.asarray(x) for x in
               unpack_query_frame(jnp.asarray(flat)))
    valid = (f & WIRE_FLAG_VALID) == WIRE_FLAG_VALID
    slot, kept = np.asarray(slot), np.asarray(kept)
    assert np.array_equal(kept, dest >= 0)
    for i in np.flatnonzero(kept):
        s = slot[i]
        assert valid[s] and r[s] == roots[i]
        assert np.array_equal(p[s], params[i])
        assert s // cap == dest[i]  # landed at its peer's bucket
    # padding: every slot no kept item claimed is invalid — zero fill keeps
    # the VALID bit dark, so a receiver can never execute it
    claimed = set(slot[kept].tolist())
    for s in range(n * cap):
        if s not in claimed:
            assert not valid[s] and r[s] == 0


def test_route_overflow_counts_every_dropped_frame():
    """Aim 3x a bucket's cap at one peer: exactly (m - cap) valid frames
    must be dropped, all counted in overflow, and the cap that DID land is
    bit-exact — degradation is bounded and observable, never silent."""
    rng = np.random.default_rng(3)
    n, cap = 4, 4
    m = 3 * cap
    roots, _, params = _rand_queries(rng, m)
    flags = np.full(m, WIRE_FLAG_VALID, np.int32)
    dest = np.full(m, 2, np.int32)  # every frame at peer 2
    frame = pack_query_frame(
        jnp.asarray(roots), jnp.asarray(flags), jnp.asarray(params)
    )
    buckets, slot, kept, ovf = bucketize(frame, jnp.asarray(dest), n, cap,
                                         fill=0)
    assert int(ovf) == m - cap
    assert int(np.sum(np.asarray(kept))) == cap
    peer = np.asarray(buckets)[2]
    r, f, p = (np.asarray(x) for x in unpack_query_frame(jnp.asarray(peer)))
    assert np.all((f & WIRE_FLAG_VALID) == WIRE_FLAG_VALID)
    landed = sorted(r.tolist())
    expect = sorted(roots[np.asarray(kept)].tolist())
    assert landed == expect
    # the other peers saw nothing but invalid padding
    others = np.asarray(buckets)[[0, 1, 3]].reshape(-1, WIRE_QUERY_LANES)
    _, fo, _ = unpack_query_frame(jnp.asarray(others))
    assert not np.any(np.asarray(fo) & WIRE_FLAG_VALID)


def test_route_plan_padding_dest_not_counted_as_overflow():
    """Out-of-range destinations are padding by contract (masked rows
    route dest=-1): dropped, but never counted in overflow."""
    dest = jnp.asarray(np.array([-1, -1, 0, 1], np.int32))
    slot, kept, ovf = route_plan(dest, 2, 2)
    assert int(ovf) == 0
    assert np.asarray(kept).tolist() == [False, False, True, True]
