"""Byte-identity of the partitioned-storage sharded runtime with the
single-host engine, on an 8-virtual-device CPU mesh.

The partitioned tier keeps only owner-local dual-CSR edge blocks per shard
(out-CSR at src-owners, in-CSR at dst-owners) plus the small replicated
vertex-attribute tier. Everything observable must match the single-host
``fused=True`` engine: multi-hop gR-Tx results and metrics byte-for-byte in
*both* hop directions (``DIR_OUT`` and ``DIR_IN``), miss-record sets,
CP-population outcomes, and gRW-Tx post-states — where the partitioned
post-store must equal the *partition of the single-host post-store*
byte-for-byte (including the block recent regions new edges append to), and
the cache logically (``cache_entries``). Per-shard store bytes are asserted
a small, O(1/n)-scaling fraction of the replicated snapshot.

Runs in subprocesses so XLA_FLAGS can create the host devices before jax
initializes (same pattern as test_sharded_runtime).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PRELUDE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from conftest import (
        build_world, enabled_ttable, fig1_plan, common_watchlist_plan,
        sq1_hop, sq2_hop, TPL_META,
    )
    from repro.core import (
        CacheSpec, EngineSpec, GraphEngine, QueryPlan, cache_entries,
        empty_cache, run_grw_tx,
    )
    from repro.core.population import CachePopulator
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedTxnRuntime
    from repro.graphstore import make_mutation_batch
    from repro.graphstore.partition import (
        EdgeBlock, PartitionedGraphStore, partition_store,
    )

    spec, store = build_world()
    cspec = CacheSpec(capacity=1024, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, sc, qp = enabled_ttable()

    def miss_key(ms):
        return sorted(
            (m.tpl_idx, m.root, tuple(m.params.tolist()), m.read_version)
            for m in ms
        )

    def check_gr(rt, pstore, host_store, plan, roots, cache_h, cache_s, eng):
        res_h, miss_h, met_h = eng.run(host_store, cache_h, ttable, roots)
        res_s, miss_s, met_s = rt.run_gr_tx_batch(
            pstore, cache_s, ttable, plan, roots
        )
        assert np.array_equal(res_h, res_s), (res_h, res_s)
        assert met_s.pop("route_overflow") == 0
        # routing-tier keys exist only on the sharded side; identity runs
        # use the implicit uniform table, so all of them must be zero
        assert met_s.pop("locality_routed") == 0
        assert met_s.pop("route_cap_retries") == 0
        assert met_s.pop("locality_retry_rows") == 0
        assert met_h == met_s, (met_h, met_s)
        assert miss_key(miss_h) == miss_key(miss_s)
        return miss_h, miss_s, met_h

    def assert_store_partition_equal(pspec, host_store, pstore_s, tag):
        exp = partition_store(pspec, host_store)
        got = jax.device_get(pstore_s)
        for f in PartitionedGraphStore._fields:
            a, b = getattr(got, f), getattr(exp, f)
            if isinstance(a, EdgeBlock):
                for bf in EdgeBlock._fields:
                    assert np.array_equal(
                        np.asarray(getattr(a, bf)), np.asarray(getattr(b, bf))
                    ), f"{tag}: {f}.{bf}"
            else:
                assert np.array_equal(np.asarray(a), np.asarray(b)), f"{tag}: {f}"
    """
)

BOTH_DIRECTIONS = PRELUDE + textwrap.dedent(
    """
    mesh = flat_mesh(8)
    # identity requires the no-drop routing configuration; the measured
    # default cap trades memory for a bounded overflow SLO instead.
    # blk_slack=1.0: uniform-share block capacity (interleaved ownership
    # keeps this world balanced), so the bytes assertion measures layout,
    # not headroom.
    rt = ShardedTxnRuntime(espec, mesh, route_cap_factor=None, blk_slack=1.0)
    pstore = rt.partition_store(store)

    # per-shard storage: a small fraction of the replicated snapshot. The
    # sharded portion (edge blocks) scales as O(1/n) — bounded by ~2/n of
    # the replicated bytes, since each edge lives at exactly two owners
    # (fleet-wide 2E edge copies instead of nE).
    rep = rt.store_bytes()
    n = rep["n_shards"]
    assert rep["per_shard_bytes"] < 0.5 * rep["replicated_per_shard_bytes"]
    assert rep["per_shard_block_bytes"] < 2.6 * (
        rep["replicated_per_shard_bytes"] / n
    ), rep

    # 2-hop plans in both directions: IN->OUT (the paper's common-watchlist
    # query) and OUT->IN, plus the 1-hop fig1 shape
    plans = [
        ("in_out", common_watchlist_plan()),
        ("out_in", QueryPlan(hops=(sq1_hop(), sq2_hop()))),
        ("fig1", fig1_plan()),
    ]
    roots = np.array([5, 6, 7, 8, 9], np.int32)
    for tag, plan in plans:
        eng = GraphEngine(espec, plan, True, fused=True)
        cache_h, cache_s = empty_cache(cspec), rt.empty_cache()

        # cold: all misses execute at owner shards against local blocks
        miss_h, miss_s, met = check_gr(
            rt, pstore, store, plan, roots, cache_h, cache_s, eng
        )
        assert met["misses"] > 0, tag

        # populate both runtimes from the same miss stream
        pop_h = CachePopulator(espec, TPL_META); pop_h.queue.push(miss_h)
        cache_h = pop_h.drain(store, store, cache_h, ttable)
        pop_s = rt.populator(TPL_META); pop_s.queue.push(miss_s)
        cache_s = pop_s.drain(pstore, pstore, cache_s, ttable)
        assert (pop_h.committed, pop_h.aborted) == (pop_s.committed, pop_s.aborted)
        assert cache_entries(cspec, cache_h) == cache_entries(cspec, cache_s), tag

        # warm: hits serve from the co-partitioned cache blocks
        _, _, met2 = check_gr(
            rt, pstore, store, plan, roots, cache_h, cache_s, eng
        )
        assert met2["hits"] > 0 and met2["phases"] < met["phases"], tag

    # gRW-Tx: owner-local apply; partitioned post-store must equal the
    # partition of the single-host post-store byte-for-byte
    plan = common_watchlist_plan()
    eng = GraphEngine(espec, plan, True, fused=True)
    cache_h, cache_s = empty_cache(cspec), rt.empty_cache()
    miss_h, miss_s, _ = check_gr(rt, pstore, store, plan, roots, cache_h, cache_s, eng)
    pop_h = CachePopulator(espec, TPL_META); pop_h.queue.push(miss_h)
    cache_h = pop_h.drain(store, store, cache_h, ttable)
    pop_s = rt.populator(TPL_META); pop_s.queue.push(miss_s)
    cache_s = pop_s.drain(pstore, pstore, cache_s, ttable)

    mb = make_mutation_batch(
        spec, set_vprops=[(7, 0, 1), (8, 0, 0)], del_edges=[2],
        new_edges=[(0, 11, 0, [1]), (3, 6, 0, [0])], del_vertices=[9],
    )
    for policy in ("write-around", "write-through"):
        st_h, ch_h, m_h = run_grw_tx(espec, store, cache_h, ttable, mb, policy=policy)
        ps_s, ch_s, m_s = rt.run_grw_tx(pstore, cache_s, ttable, mb, policy=policy)
        assert m_h["impacted_keys"] == m_s["impacted_keys"], policy
        assert m_s["op_overflow"] == 0 and m_s["store_append_overflow"] == 0
        assert_store_partition_equal(rt.pspec, st_h, ps_s, policy)
        assert cache_entries(cspec, ch_h) == cache_entries(cspec, ch_s), policy

    # reads after the commit exercise the block recent regions (the new
    # edges) and the invalidated cache — still byte-identical
    st_h, ch_h, _ = run_grw_tx(espec, store, cache_h, ttable, mb)
    ps_s, ch_s, _ = rt.run_grw_tx(pstore, cache_s, ttable, mb)
    roots2 = np.array([0, 3, 5, 6, 7, 11], np.int32)
    for tag, plan2 in plans:
        eng2 = GraphEngine(espec, plan2, True, fused=True)
        check_gr(rt, ps_s, st_h, plan2, roots2, ch_h, ch_s, eng2)

    print("PARTITIONED_IDENTITY_OK")
    """
)

ONE_SHARD = PRELUDE + textwrap.dedent(
    """
    # the single-host engine is the 1-shard special case: one block pair
    # holds the whole graph and every collective degenerates
    mesh = flat_mesh(1)
    rt = ShardedTxnRuntime(espec, mesh, route_cap_factor=None)
    pstore = rt.partition_store(store)
    plan = fig1_plan()
    eng = GraphEngine(espec, plan, True, fused=True)
    roots = np.array([0, 1, 2, 3], np.int32)
    cache_h, cache_s = empty_cache(cspec), rt.empty_cache()
    check_gr(rt, pstore, store, plan, roots, cache_h, cache_s, eng)
    mb = make_mutation_batch(spec, set_vprops=[(7, 0, 1)])
    st_h, ch_h, _ = run_grw_tx(espec, store, cache_h, ttable, mb)
    ps_s, ch_s, m_s = rt.run_grw_tx(pstore, cache_s, ttable, mb)
    assert m_s["op_overflow"] == 0
    assert_store_partition_equal(rt.pspec, st_h, ps_s, "one-shard")
    assert cache_entries(cspec, ch_h) == cache_entries(cspec, ch_s)
    print("ONE_SHARD_OK")
    """
)


def _run(script, token):
    env = dict(
        os.environ,
        PYTHONPATH=os.pathsep.join(
            [os.path.join(REPO, "src"), os.path.join(REPO, "tests")]
        ),
    )
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=1800,
    )
    assert token in out.stdout, out.stdout + out.stderr


def test_partitioned_runtime_matches_single_host_both_directions():
    _run(BOTH_DIRECTIONS, "PARTITIONED_IDENTITY_OK")


def test_partitioned_one_shard_special_case():
    _run(ONE_SHARD, "ONE_SHARD_OK")
