"""Collective-count and overlap pins for the one-exchange hop protocol.

The sharded gR step's collective budget is part of its contract: ONE packed
all_to_all each direction per hop (route out, results home) and ONE
all-reduce for the whole step (the deferred metrics/phase psum) — see the
``distributed.graph_serve`` module docstring. These tests lower the actual
compiled serving program and count collectives in the optimized HLO with
``launch.hlo_analysis``, so a regression that sneaks an extra exchange into
the hop loop (e.g. un-deferring a psum, or splitting the query frame back
into per-field routes) fails loudly rather than silently tripling latency.

Also pins that ``overlap=True`` (double-buffered frontier streams) returns
row-identical results to the default schedule: the overlap knob may change
wall-clock and program shape, never bytes.

Runs in subprocesses so XLA_FLAGS can create the host devices before jax
initializes (same pattern as test_graph_serve_multishard).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PRELUDE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from conftest import (
        build_world, enabled_ttable, fig1_plan, common_watchlist_plan,
    )
    from repro.core import CacheSpec, EngineSpec
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedTxnRuntime
    from repro.launch.hlo_analysis import analyze

    spec, store = build_world()
    cspec = CacheSpec(capacity=1024, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, _, _ = enabled_ttable()
    mesh = flat_mesh(8)
    """
)


def _run(script: str, token: str) -> None:
    env = dict(
        os.environ,
        PYTHONPATH=os.pathsep.join(
            [os.path.join(REPO, "src"), os.path.join(REPO, "tests")]
        ),
    )
    out = subprocess.run(
        [sys.executable, "-c", PRELUDE + textwrap.dedent(script)],
        capture_output=True, text=True, env=env, cwd=REPO,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert token in out.stdout, out.stdout


def test_gr_step_collective_counts():
    """Exactly 2 all_to_alls per hop + 1 all-reduce per step, on both a
    1-hop and a 2-hop plan — and nothing else (no all-gathers, no
    collective-permutes smuggled in by the compiler)."""
    _run(
        """
        rt = ShardedTxnRuntime(espec, mesh)
        pstore = rt.partition_store(store)
        cache = rt.empty_cache()
        for plan in (fig1_plan(), common_watchlist_plan()):
            step = rt.serve_step(plan, 64)
            hlo = step.jitted.lower(
                pstore, cache, ttable, jnp.zeros(64, jnp.int32),
                jnp.ones(64, bool), rt._down_none(), rt._rtable_none(),
            ).compile().as_text()
            c = analyze(hlo)["counts"]
            h = len(plan.hops)
            assert c["all-to-all"] == 2 * h, (h, c)
            assert c["all-reduce"] == 1, (h, c)
            assert c["all-gather"] == 0 and c["collective-permute"] == 0, c
        print("COLLECTIVE_COUNTS_OK")
        """,
        "COLLECTIVE_COUNTS_OK",
    )


def test_routing_table_keeps_collective_budget_and_program():
    """The replicated routing table is a traced INPUT of the serving step,
    not a closure constant: a table with live exceptions must (a) reuse the
    exact compiled program the identity table compiled (zero recompiles —
    ``_cache_size() == 1``), and (b) add ZERO collectives — still 2
    all_to_alls per hop + 1 all-reduce, no all-gather / collective-permute
    smuggled in by the locality routing or the defer mask."""
    _run(
        """
        from repro.distributed.routing import RoutingTableHost

        rt = ShardedTxnRuntime(espec, mesh)
        pstore = rt.partition_store(store)
        cache = rt.empty_cache()
        rhost = RoutingTableHost(rt.n)
        rhost.set_cache_owner(5, 0)   # split root (native owner is 5)
        rhost.apply_moves([(9, 2)])   # migrated vertex (native owner is 1)
        for plan in (fig1_plan(), common_watchlist_plan()):
            h = len(plan.hops)
            step = rt.serve_step(plan, 64)
            roots = jnp.zeros(64, jnp.int32)
            bv = jnp.ones(64, bool)
            step(pstore, cache, ttable, roots, bv)
            step(pstore, cache, ttable, roots, bv,
                 rtable=rhost.device_table())
            assert step.jitted._cache_size() == 1, step.jitted._cache_size()
            hlo = step.jitted.lower(
                pstore, cache, ttable, roots, bv, rt._down_none(),
                rhost.device_table(),
            ).compile().as_text()
            c = analyze(hlo)["counts"]
            assert c["all-to-all"] == 2 * h, (h, c)
            assert c["all-reduce"] == 1, (h, c)
            assert c["all-gather"] == 0 and c["collective-permute"] == 0, c
        print("RTABLE_BUDGET_OK")
        """,
        "RTABLE_BUDGET_OK",
    )


def test_overlap_schedule_is_row_identical():
    """Double-buffered frontier streams (overlap=True) must return the
    same results, miss-record sets, and metrics as the default schedule
    for multi-hop plans over a mixed local/remote Zipf-ish batch."""
    _run(
        """
        rng = np.random.default_rng(7)
        roots = rng.integers(0, spec.v_cap, size=64).astype(np.int32)
        mkey = lambda ms: sorted(
            (m.tpl_idx, m.root, tuple(m.params.tolist()), m.read_version)
            for m in ms
        )
        base = ShardedTxnRuntime(espec, mesh)
        ov = ShardedTxnRuntime(
            espec, mesh, overlap=True, e_blk_cap=base.pspec.e_blk_cap
        )
        ps_b = base.partition_store(store)
        ps_o = ov.partition_store(store)
        for plan in (fig1_plan(), common_watchlist_plan()):
            ra, msa, ma = base.run_gr_tx_batch(
                ps_b, base.empty_cache(), ttable, plan, roots
            )
            rb, msb, mb = ov.run_gr_tx_batch(
                ps_o, ov.empty_cache(), ttable, plan, roots
            )
            assert np.array_equal(ra, rb)
            assert mkey(msa) == mkey(msb)
            for k in ma:
                assert ma[k] == mb[k], (k, ma[k], mb[k])
        print("OVERLAP_IDENTITY_OK")
        """,
        "OVERLAP_IDENTITY_OK",
    )


def test_telemetry_keeps_collective_budget_and_bytes():
    """The observability tier's per-owner stage block rides the step's
    existing stacked all-reduce: telemetry on vs off must compile to the
    SAME collective counts (2 all_to_alls per hop, 1 all-reduce, nothing
    else), return byte-identical results/misses/metrics, and the
    attributed owner_stage columns must sum exactly to the global
    metrics they decompose."""
    _run(
        """
        rng = np.random.default_rng(11)
        roots = rng.integers(0, spec.v_cap, size=64).astype(np.int32)
        mkey = lambda ms: sorted(
            (m.tpl_idx, m.root, tuple(m.params.tolist()), m.read_version)
            for m in ms
        )
        rt_t = ShardedTxnRuntime(espec, mesh)  # telemetry defaults on
        rt_p = ShardedTxnRuntime(
            espec, mesh, telemetry=False, e_blk_cap=rt_t.pspec.e_blk_cap
        )
        ps_t = rt_t.partition_store(store)
        ps_p = rt_p.partition_store(store)
        for plan in (fig1_plan(), common_watchlist_plan()):
            h = len(plan.hops)
            for rt, ps in ((rt_t, ps_t), (rt_p, ps_p)):
                step = rt.serve_step(plan, 64)
                hlo = step.jitted.lower(
                    ps, rt.empty_cache(), ttable, jnp.zeros(64, jnp.int32),
                    jnp.ones(64, bool), rt._down_none(), rt._rtable_none(),
                ).compile().as_text()
                c = analyze(hlo)["counts"]
                assert c["all-to-all"] == 2 * h, (h, c)
                assert c["all-reduce"] == 1, (h, c)
                assert c["all-gather"] == 0 and c["collective-permute"] == 0, c
            ra, msa, ma = rt_t.run_gr_tx_batch(
                ps_t, rt_t.empty_cache(), ttable, plan, roots
            )
            rb, msb, mb = rt_p.run_gr_tx_batch(
                ps_p, rt_p.empty_cache(), ttable, plan, roots
            )
            assert np.array_equal(ra, rb)
            assert mkey(msa) == mkey(msb)
            for k in ma:
                assert ma[k] == mb[k], (k, ma[k], mb[k])
            # attribution is a decomposition, not an estimate: per-owner
            # columns sum exactly to the step's global metrics
            stage = rt_t.last_owner_stage
            assert stage is not None and stage.shape[0] == 8
            assert rt_p.last_owner_stage is None
            from repro.obs.metrics import OWNER_STAGE_FIELDS
            col = {f: int(stage[:, i].sum())
                   for i, f in enumerate(OWNER_STAGE_FIELDS)}
            assert col["probe_hits"] == ma["hits"]
            assert col["miss_rows"] == ma["misses"]
            assert col["edges_scanned"] == ma["edges_scanned"]
            assert col["leaf_fetches"] == ma["leaf_fetches"]
            assert col["route_overflow"] == ma["route_overflow"]
            assert rt_t.last_step_owner_seconds.shape == (8,)
        print("TELEMETRY_BUDGET_OK")
        """,
        "TELEMETRY_BUDGET_OK",
    )
