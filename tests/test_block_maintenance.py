"""Owner-local block maintenance, host-level properties.

Per-shard behaviour is exercised on one device by slicing shard-local views
out of the global partitioned layout and by vmapping the partitioned apply
with a named axis (the same program ``shard_map`` runs on the mesh), exactly
like ``test_partitioned_store``. The 8-virtual-device identity of the
*runtime* under interleaved maintenance ticks lives in
``test_maintenance_runtime.py`` (sharded CI job).

Pinned properties:

- ``compact_block`` ≡ the single-host ``store.compact`` per block:
  compacting the partition of a post-commit store is byte-identical to
  partitioning the host-compacted post-commit store (tombstones keep their
  CSR lanes, recent regions merge in (key, geid) order, geid→slot indexes
  rebuild).
- compact ∘ apply ≡ apply ∘ compact on every read observable.
- tombstone purge preserves read results (dead lanes were masked anyway).
- the geid→slot index stays consistent across randomized mutation batches,
  including capacity growth, and the indexed probes match a brute-force
  broadcast-compare reference.
- ``grow_store`` ≡ ``partition_store`` under the grown spec, and elastic
  ingest replaces the bare shape assert with an actionable
  ``BlockCapacityError`` / automatic growth.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import build_world, enabled_ttable, sq1_hop, sq2_hop
from repro.core import CacheSpec, EngineSpec
from repro.core.runtime import onehop_exec_view
from repro.core.templates import DIR_IN, DIR_OUT
from repro.graphstore import make_mutation_batch
from repro.graphstore.maintenance import (
    MaintenancePolicy,
    block_occupancy,
    compact_block,
    compact_store,
    decide_maintenance,
    grow_store,
)
from repro.graphstore.mutations import apply_mutations
from repro.graphstore.partition import (
    BlockCapacityError,
    BlockStoreView,
    EdgeBlock,
    PartitionedGraphStore,
    apply_mutations_partitioned,
    default_pspec,
    geid_slot_lookup,
    local_shard,
    partition_store,
)
from repro.graphstore.store import compact
from test_partitioned_store import _PS_AX, _restack, _stacked_local

N = 4


@pytest.fixture(scope="module")
def world():
    spec, store = build_world()
    cspec = CacheSpec(capacity=1024, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, _, _ = enabled_ttable()
    pspec = default_pspec(spec, N)
    return dict(
        spec=spec, store=store, espec=espec, cspec=cspec, ttable=ttable,
        pspec=pspec, pstore=partition_store(pspec, store),
    )


def _mutation_batch(spec):
    return make_mutation_batch(
        spec,
        new_vertices=[(1, [0, 1007])],
        new_edges=[(0, 11, 0, [1]), (2, 16, 0, [0]), (3, 5, 0, [1])],
        del_edges=[2, 5],
        del_vertices=[9],
        set_vprops=[(7, 0, 1), (8, 0, 0)],
        set_eprops=[(1, 0, 0), (4, 0, 1)],
    )


def _apply_partitioned(pspec, pstore, mb):
    """The named-axis-vmap partitioned apply (the shard_map program)."""
    fn = jax.vmap(
        lambda ps, me: apply_mutations_partitioned(pspec, ps, mb, me, "sh"),
        axis_name="sh", in_axes=(_PS_AX, 0),
    )
    ps2, _, ovf = fn(_stacked_local(pspec, pstore), jnp.arange(pspec.n_shards))
    assert int(ovf[0]) == 0
    return _restack(pspec, ps2)


def _assert_pstores_equal(a, b, tag):
    for f in PartitionedGraphStore._fields:
        x, y = getattr(a, f), getattr(b, f)
        if isinstance(x, EdgeBlock):
            for bf in EdgeBlock._fields:
                assert np.array_equal(
                    np.asarray(getattr(x, bf)), np.asarray(getattr(y, bf))
                ), f"{tag}: {f}.{bf}"
        else:
            assert np.array_equal(np.asarray(x), np.asarray(y)), f"{tag}: {f}"


def _reads(world, pspec, pstore, roots, direction):
    """Per-shard owner-local read observables over the whole mesh."""
    espec = world["espec"]
    hop = sq1_hop() if direction != DIR_IN else sq2_hop()
    params = jnp.broadcast_to(jnp.asarray(hop.params), (len(roots), 6))
    rmask = np.ones(len(roots), bool)
    out = []
    for s in range(pspec.n_shards):
        view = BlockStoreView(pspec, local_shard(pspec, pstore, s), s)
        own = np.mod(np.asarray(roots), pspec.n_shards) == s
        leaves, lmask, n_true, trunc, stats = onehop_exec_view(
            espec, view, direction, hop.edge_label, hop.pr, hop.pe, hop.pl,
            jnp.asarray(roots), params, jnp.asarray(rmask & own),
        )
        rows = np.nonzero(own)[0]
        out.append((
            np.asarray(leaves)[rows], np.asarray(lmask)[rows],
            np.asarray(n_true)[rows], np.asarray(trunc)[rows],
            int(stats["edges_scanned"]), int(stats["leaf_fetches"]),
        ))
    return out


def _assert_reads_equal(ra, rb, tag):
    for s, (a, b) in enumerate(zip(ra, rb)):
        for i, (x, y) in enumerate(zip(a, b)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (tag, s, i)


def test_compact_block_matches_host_compact(world):
    """Compacting the partitioned post-commit blocks is byte-identical to
    partitioning the host-compacted post-commit store — the partitioned
    analogue of ``store.compact`` (tombstones keep CSR lanes, recent merges
    in (key, geid) order, csr_len == blk_len, index rebuilt)."""
    spec, store, pspec = world["spec"], world["store"], world["pspec"]
    store2, _ = apply_mutations(spec, store, _mutation_batch(spec))
    got = compact_store(pspec, partition_store(pspec, store2))
    exp = partition_store(pspec, compact(spec, store2))
    _assert_pstores_equal(got, exp, "compact vs host-compact partition")
    occ = block_occupancy(pspec, got)
    assert occ["max_recent_fill"] == 0  # recent regions drained


@pytest.mark.parametrize("direction", [DIR_OUT, DIR_IN])
def test_compact_apply_commute_on_reads(world, direction):
    """compact ∘ apply ≡ apply ∘ compact ≡ apply on every read observable
    (leaves, masks, cardinalities, truncation, scan metrics)."""
    spec, pspec, pstore = world["spec"], world["pspec"], world["pstore"]
    mb = _mutation_batch(spec)
    roots = np.array([0, 1, 2, 3, 5, 9, 11, 15], np.int32)

    applied = _apply_partitioned(pspec, pstore, mb)
    a_then_c = compact_store(pspec, applied)
    c_then_a = _apply_partitioned(pspec, compact_store(pspec, pstore), mb)

    base = _reads(world, pspec, applied, roots, direction)
    _assert_reads_equal(base, _reads(world, pspec, a_then_c, roots, direction),
                        "apply->compact")
    _assert_reads_equal(base, _reads(world, pspec, c_then_a, roots, direction),
                        "compact->apply")


@pytest.mark.parametrize("direction", [DIR_OUT, DIR_IN])
def test_purge_preserves_read_results(world, direction):
    """Tombstone purge reclaims dead-edge slots without changing any read
    observable (dead lanes were liveness-masked already)."""
    spec, pspec, pstore = world["spec"], world["pspec"], world["pstore"]
    mb = _mutation_batch(spec)  # includes del_edges + del_vertices
    applied = _apply_partitioned(pspec, pstore, mb)
    purged = compact_store(pspec, applied, purge=True)
    kept = compact_store(pspec, applied, purge=False)
    # purge really dropped the tombstones
    assert int(np.asarray(purged.out.blk_len).sum()) < int(
        np.asarray(kept.out.blk_len).sum()
    )
    roots = np.array([0, 1, 2, 3, 5, 11, 15], np.int32)
    _assert_reads_equal(
        _reads(world, pspec, kept, roots, direction),
        _reads(world, pspec, purged, roots, direction), "purged",
    )


def _lookup_reference(blk_geid, blk_len, eids, EB):
    """Brute-force [K, EB] broadcast-compare (the pre-index semantics)."""
    alloc = np.arange(EB) < blk_len
    m = (np.asarray(blk_geid)[None, :] == np.asarray(eids)[:, None]) & alloc[None, :]
    found = m.any(axis=1)
    slot = np.where(found, m.argmax(axis=1), 0)
    return slot, found


def _check_index(pspec, pstore, tag):
    EB = pspec.e_blk_cap
    rng = np.random.default_rng(0)
    for s in range(pspec.n_shards):
        ls = local_shard(pspec, pstore, s)
        for name, blk in (("out", ls.out), ("inc", ls.inc)):
            ln = int(blk.blk_len[0])
            gperm = np.asarray(blk.gperm)
            geid = np.asarray(blk.geid)
            # the sorted prefix indexes exactly the allocated slots,
            # ascending by geid
            assert sorted(gperm[:ln].tolist()) == list(range(ln)), (tag, s, name)
            sg = geid[gperm[:ln]]
            assert np.all(np.diff(sg) > 0), (tag, s, name)
            # indexed probes == broadcast-compare reference
            probes = np.concatenate([
                geid[:ln][rng.permutation(ln)][:16] if ln else np.zeros(0, np.int32),
                rng.integers(-3, 2 * EB, 16).astype(np.int32),
                np.array([-1, 2**31 - 1], np.int32),
            ])
            slot, found = geid_slot_lookup(
                EB, blk.geid, blk.gperm, blk.blk_len[0], jnp.asarray(probes)
            )
            rslot, rfound = _lookup_reference(geid, ln, probes, EB)
            assert np.array_equal(np.asarray(found), rfound & (probes >= 0)), (tag, s, name)
            ok = np.asarray(found)
            assert np.array_equal(np.asarray(slot)[ok], rslot[ok]), (tag, s, name)


def test_geid_index_randomized_mutations_and_growth(world):
    """The index stays consistent (permutation of the allocated prefix,
    ascending geids, probe-equivalent to broadcast-compare) across random
    mutation batches, a capacity growth, and compactions."""
    spec, store = world["spec"], world["store"]
    pspec = default_pspec(spec, N)
    pstore = partition_store(pspec, store)
    host = store
    rng = np.random.default_rng(42)
    _check_index(pspec, pstore, "initial")
    for step in range(6):
        e_len, v_len = int(host.e_len), int(host.v_len)
        ne = [
            (int(rng.integers(0, v_len)), int(rng.integers(0, v_len)), 0,
             [int(rng.integers(0, 2))])
            for _ in range(int(rng.integers(1, 6)))
        ]
        de = [int(e) for e in rng.integers(0, e_len, rng.integers(1, 4))]
        se = [(int(rng.integers(0, e_len)), 0, int(rng.integers(0, 2)))]
        mb = make_mutation_batch(spec, new_edges=ne, del_edges=de, set_eprops=se)
        host, _ = apply_mutations(spec, host, mb)
        pstore = _apply_partitioned(pspec, pstore, mb)
        _check_index(pspec, pstore, f"step{step}")
        if step == 2:
            pspec, pstore = grow_store(pspec, pstore, pspec.e_blk_cap + 29)
            _check_index(pspec, pstore, "grown")
        if step == 4:
            pstore = compact_store(pspec, pstore, purge=bool(step % 2))
            _check_index(pspec, pstore, "compacted")
    # the maintained store still equals the partition of the host post-state
    _assert_pstores_equal(
        compact_store(pspec, pstore),
        partition_store(pspec, compact(spec, host)), "final",
    )


def test_grow_store_equals_partition_under_grown_spec(world):
    spec, store, pspec = world["spec"], world["store"], world["pspec"]
    store2, _ = apply_mutations(spec, store, _mutation_batch(spec))
    ps2 = partition_store(pspec, store2)
    new_pspec, grown = grow_store(pspec, ps2, pspec.e_blk_cap + 37)
    assert new_pspec.e_blk_cap == pspec.e_blk_cap + 37
    _assert_pstores_equal(grown, partition_store(new_pspec, store2), "grown")


def test_block_capacity_error_is_actionable(world):
    spec, store = world["spec"], world["store"]
    pspec = default_pspec(spec, N)._replace(e_blk_cap=2, recent_blk_cap=2)
    with pytest.raises(BlockCapacityError) as ei:
        partition_store(pspec, store)
    assert ei.value.needed > 2
    assert "elastic=True" in str(ei.value)
    assert "e_blk_cap" in str(ei.value)


def test_elastic_partition_grows_runtime_spec(world):
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedTxnRuntime

    rt = ShardedTxnRuntime(
        world["espec"], flat_mesh(1), route_cap_factor=None, e_blk_cap=2
    )
    with pytest.raises(BlockCapacityError):
        rt.partition_store(world["store"])
    ps = rt.partition_store(world["store"], elastic=True)
    assert rt.pspec.e_blk_cap >= int(world["store"].e_len)
    # the elastically-grown layout serves the same reads
    _assert_pstores_equal(
        jax.device_get(ps), partition_store(rt.pspec, world["store"]), "elastic"
    )


def test_populator_steps_survive_capacity_growth(world):
    """A CachePopulator built before a capacity growth must populate
    correctly after it: its cached step adapters re-resolve the compiled
    program per call, so growth-invalidated programs recompile against the
    grown layout instead of silently gathering through a closure over the
    old ``e_blk_cap`` (which clamps slots below the pre-growth capacity —
    wrong reads for every edge appended past it)."""
    from conftest import TPL_META, fig1_plan
    from repro.core import GraphEngine, cache_entries, empty_cache, run_grw_tx
    from repro.core.population import CachePopulator
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedTxnRuntime

    spec, store = world["spec"], world["store"]
    espec, cspec, ttable = world["espec"], world["cspec"], world["ttable"]
    e0 = int(store.e_len)
    rt = ShardedTxnRuntime(
        espec, flat_mesh(1), route_cap_factor=None, e_blk_cap=e0 + 2,
        recent_blk_cap=32,
    )
    ps = rt.partition_store(store)
    plan = fig1_plan()
    eng = GraphEngine(espec, plan, True, fused=True)
    roots = np.array([0, 1, 2, 3], np.int32)

    # bake the pre-growth CP step into the populator's cache
    pop_s = rt.populator(TPL_META)
    pop_h = CachePopulator(espec, TPL_META)
    cache_h, cache_s = empty_cache(cspec), rt.empty_cache()
    _, miss_h, _ = eng.run(store, cache_h, ttable, roots)
    _, miss_s, _ = rt.run_gr_tx_batch(ps, cache_s, ttable, plan, roots)
    pop_h.queue.push(miss_h)
    pop_s.queue.push(miss_s)
    cache_h = pop_h.drain(store, store, cache_h, ttable)
    cache_s = pop_s.drain(ps, ps, cache_s, ttable)
    assert cache_entries(cspec, cache_h) == cache_entries(cspec, cache_s)

    # grow, then append edges that land past the pre-growth capacity
    ps = rt.grow_blocks(ps, e0 + 64)
    ne = [(int(r), 4 + i, 0, [1]) for i, r in enumerate(roots) for _ in (0,)]
    mb = make_mutation_batch(spec, new_edges=ne)
    store2, cache_h, _ = run_grw_tx(espec, store, cache_h, ttable, mb)
    ps, cache_s, m = rt.run_grw_tx(ps, cache_s, ttable, mb)
    assert m["store_append_overflow"] == 0
    assert int(np.asarray(ps.out.blk_len).max()) > e0 + 2  # past old cap

    # the SAME populators drain the post-growth misses
    _, miss_h2, _ = eng.run(store2, cache_h, ttable, roots)
    _, miss_s2, met = rt.run_gr_tx_batch(ps, cache_s, ttable, plan, roots)
    assert met["misses"] > 0
    pop_h.queue.push(miss_h2)
    pop_s.queue.push(miss_s2)
    cache_h = pop_h.drain(store2, store2, cache_h, ttable)
    cache_s = pop_s.drain(ps, ps, cache_s, ttable)
    assert (pop_h.committed, pop_h.aborted) == (pop_s.committed, pop_s.aborted)
    assert cache_entries(cspec, cache_h) == cache_entries(cspec, cache_s)


def test_decide_maintenance_thresholds(world):
    pspec = world["pspec"]
    policy = MaintenancePolicy(
        recent_fill_frac=0.5, mutation_rows=100, grow_occupancy_frac=0.8,
        growth_factor=2.0,
    )
    idle = dict(max_occupancy=0.1, max_recent_fill=0)
    d = decide_maintenance(pspec, idle, policy, mutation_rows=0)
    assert not d.compact and d.grow_to is None

    full_recent = dict(
        max_occupancy=0.1,
        max_recent_fill=int(0.5 * pspec.recent_blk_cap),
    )
    d = decide_maintenance(pspec, full_recent, policy)
    assert d.compact and d.grow_to is None and "recent fill" in d.reason

    d = decide_maintenance(pspec, idle, policy, mutation_rows=100)
    assert d.compact and "mutation rows" in d.reason

    hot = dict(max_occupancy=0.9, max_recent_fill=0)
    d = decide_maintenance(pspec, hot, policy)
    assert d.grow_to == 2 * pspec.e_blk_cap and "grow" in d.reason
