"""Byte-identity of the sharded transaction runtime with the single-host
engine, on an 8-virtual-device CPU mesh.

The sharded runtime executes the same fused hop kernels inside shard_map —
per-hop root routing to owner shards, co-partitioned cache probes, and a
two-phase sharded gRW-Tx commit. Everything observable must match the
single-host ``fused=True`` engine: multi-hop gR-Tx results and metrics
byte-for-byte, miss-record sets, CP-population outcomes, and gRW-Tx
post-states (store arrays exactly; cache contents logically — the sharded
layout hashes into per-shard blocks, so equality is over ``cache_entries``).

Runs in subprocesses so XLA_FLAGS can create the host devices before jax
initializes (same pattern as test_graph_serve_multishard).
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PRELUDE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from conftest import (
        build_world, enabled_ttable, fig1_plan, common_watchlist_plan, TPL_META,
    )
    from repro.core import (
        CacheSpec, EngineSpec, GraphEngine, cache_entries, empty_cache,
        run_grw_tx,
    )
    from repro.core.population import CachePopulator
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedTxnRuntime
    from repro.graphstore import make_mutation_batch

    spec, store = build_world()
    cspec = CacheSpec(capacity=1024, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, sc, qp = enabled_ttable()

    def miss_key(ms):
        return sorted(
            (m.tpl_idx, m.root, tuple(m.params.tolist()), m.read_version)
            for m in ms
        )

    def check_gr(rt, plan, roots, cache_h, cache_s, eng):
        res_h, miss_h, met_h = eng.run(store, cache_h, ttable, roots)
        res_s, miss_s, met_s = rt.run_gr_tx_batch(store, cache_s, ttable, plan, roots)
        assert np.array_equal(res_h, res_s), (res_h, res_s)
        assert met_s.pop("route_overflow") == 0
        # routing-tier keys exist only on the sharded side; identity runs
        # use the implicit uniform table, so all of them must be zero
        assert met_s.pop("locality_routed") == 0
        assert met_s.pop("route_cap_retries") == 0
        assert met_s.pop("locality_retry_rows") == 0
        assert met_h == met_s, (met_h, met_s)
        assert miss_key(miss_h) == miss_key(miss_s)
        return miss_h, miss_s, met_h
    """
)

TWO_HOP = PRELUDE + textwrap.dedent(
    """
    mesh = flat_mesh(8)
    # the replicated-snapshot tier (the PR 3 baseline); byte-identity needs
    # the no-drop routing configuration. The partitioned default tier has
    # its own identity suite in test_partitioned_runtime.py.
    rt = ShardedTxnRuntime(espec, mesh, store_tier="replicated",
                           route_cap_factor=None)
    plan = common_watchlist_plan()  # 2-hop + post filter
    eng = GraphEngine(espec, plan, True, fused=True)
    roots = np.array([5, 6, 7, 8, 9], np.int32)
    cache_h, cache_s = empty_cache(cspec), rt.empty_cache()

    # cold: all misses execute at the owner shards
    miss_h, miss_s, met = check_gr(rt, plan, roots, cache_h, cache_s, eng)
    assert met["misses"] > 0

    # populate both runtimes from the same miss stream
    pop_h = CachePopulator(espec, TPL_META); pop_h.queue.push(miss_h)
    cache_h = pop_h.drain(store, store, cache_h, ttable)
    pop_s = rt.populator(TPL_META); pop_s.queue.push(miss_s)
    cache_s = pop_s.drain(store, store, cache_s, ttable)
    assert (pop_h.committed, pop_h.aborted) == (pop_s.committed, pop_s.aborted)
    assert cache_entries(cspec, cache_h) == cache_entries(cspec, cache_s)

    # warm: hits are served from the co-partitioned cache shards
    _, _, met2 = check_gr(rt, plan, roots, cache_h, cache_s, eng)
    assert met2["hits"] > 0 and met2["phases"] < met["phases"]

    # sharded gRW-Tx: store arrays byte-identical, cache logically identical
    mb = make_mutation_batch(
        spec, set_vprops=[(7, 0, 1), (8, 0, 0)], del_edges=[2],
        new_edges=[(0, 11, 0, [1])], del_vertices=[9],
    )
    for policy in ("write-around", "write-through"):
        st_h, ch_h, m_h = run_grw_tx(espec, store, cache_h, ttable, mb, policy=policy)
        st_s, ch_s, m_s = rt.run_grw_tx(store, cache_s, ttable, mb, policy=policy)
        assert m_s["op_overflow"] == 0
        for f in st_h._fields:
            assert np.array_equal(
                np.asarray(getattr(st_h, f)), np.asarray(getattr(st_s, f))
            ), f"{policy}: store field {f}"
        assert cache_entries(cspec, ch_h) == cache_entries(cspec, ch_s), policy

    print("SHARDED_IDENTITY_OK")
    """
)

ONE_SHARD = PRELUDE + textwrap.dedent(
    """
    # the single-host engine is the 1-shard special case: every collective
    # degenerates and the runtime must still match exactly
    mesh = flat_mesh(1)
    rt = ShardedTxnRuntime(espec, mesh, store_tier="replicated",
                           route_cap_factor=None)
    plan = fig1_plan()
    eng = GraphEngine(espec, plan, True, fused=True)
    roots = np.array([0, 1, 2, 3], np.int32)
    cache_h, cache_s = empty_cache(cspec), rt.empty_cache()
    miss_h, miss_s, _ = check_gr(rt, plan, roots, cache_h, cache_s, eng)
    mb = make_mutation_batch(spec, set_vprops=[(7, 0, 1)])
    st_h, ch_h, _ = run_grw_tx(espec, store, cache_h, ttable, mb)
    st_s, ch_s, m_s = rt.run_grw_tx(store, cache_s, ttable, mb)
    assert m_s["op_overflow"] == 0
    for f in st_h._fields:
        assert np.array_equal(
            np.asarray(getattr(st_h, f)), np.asarray(getattr(st_s, f))
        ), f
    assert cache_entries(cspec, ch_h) == cache_entries(cspec, ch_s)
    print("ONE_SHARD_OK")
    """
)

OVERFLOW = PRELUDE + textwrap.dedent(
    """
    # a too-small per-peer routing bucket must *surface* dropped roots in
    # the metrics instead of silently degrading
    mesh = flat_mesh(8)
    rt = ShardedTxnRuntime(espec, mesh, store_tier="replicated",
                           route_cap_factor=1)
    plan = fig1_plan()
    roots = np.full(16, 1, np.int32)  # every shard routes to one owner
    cache_s = rt.empty_cache()
    _, _, met = rt.run_gr_tx_batch(store, cache_s, ttable, plan, roots)
    assert met["route_overflow"] > 0, met
    print("OVERFLOW_OK")
    """
)


def _run(script, token):
    env = dict(
        os.environ,
        PYTHONPATH=os.pathsep.join(
            [os.path.join(REPO, "src"), os.path.join(REPO, "tests")]
        ),
    )
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=1200,
    )
    assert token in out.stdout, out.stdout + out.stderr


def test_op_stream_order_keys_are_global():
    """Round-robin batch slicing must emit ops with the same *global* order
    keys the unsliced listener produces — the invariant that lets the
    routed write-through stream sort back into the exact single-host
    application order (a shard-local key would invert ops whose rows share
    a round-robin round but differ in gather lane)."""
    import jax.numpy as jnp
    import numpy as np

    from conftest import build_world, enabled_ttable
    from repro.core import CacheSpec, EngineSpec
    from repro.core.invalidation import derive_cache_ops
    from repro.graphstore import make_mutation_batch
    from repro.graphstore.mutations import apply_mutations, shard_mutation_rows

    spec, store = build_world()
    espec = EngineSpec(
        store=spec, cache=CacheSpec(capacity=1024, probes=8, max_leaves=16),
        max_deg=32, frontier=32,
    )
    ttable, _, _ = enabled_ttable()
    mb = make_mutation_batch(
        spec, set_vprops=[(6, 0, 1), (7, 0, 0), (8, 0, 1), (10, 0, 0)],
        del_edges=[1, 3], new_edges=[(0, 11, 0, [1])],
    )
    store2, applied = apply_mutations(spec, store, mb)

    def op_set(applied_slice, off, stride):
        ops, _ = derive_cache_ops(
            espec, store, store2, ttable, applied_slice, through=True,
            row_offset=off, row_stride=stride,
        )
        ok = np.asarray(ops.ok)
        cols = [np.asarray(c)[ok] for c in
                (ops.order, ops.kind, ops.tpl, ops.root, ops.vid)]
        return set(zip(*(c.tolist() for c in cols)))

    full = op_set(applied, 0, 1)
    n = 2
    sharded = set()
    for me in range(n):
        part = op_set(shard_mutation_rows(applied, n, jnp.int32(me)), me, n)
        assert part <= full, "shard emitted an order key the full run lacks"
        assert not (part & sharded), "shards emitted overlapping ops"
        sharded |= part
    assert sharded == full


def test_sharded_runtime_matches_single_host():
    _run(TWO_HOP, "SHARDED_IDENTITY_OK")


def test_one_shard_special_case():
    _run(ONE_SHARD, "ONE_SHARD_OK")


def test_route_overflow_is_surfaced():
    _run(OVERFLOW, "OVERFLOW_OK")
