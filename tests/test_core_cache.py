"""Unit tests for templates/keys/cache data structures."""

import jax.numpy as jnp
import numpy as np

from conftest import MISSING
from repro.core import (
    CacheSpec,
    cache_delete,
    cache_insert,
    cache_lookup,
    cache_stats,
    empty_cache,
    evaluate_pred,
    extract_wildcards,
    make_pred,
    sweep_root,
    sweep_template,
    OP_EQ,
    OP_GT,
    WILDCARD,
    ANY_LABEL,
)
from repro.core.keys import PARAM_LEN


def P(root, vals):
    params = np.full((len(root), PARAM_LEN), MISSING, np.int32)
    params[:, 0] = vals
    return jnp.asarray(params)


def test_pred_eval_and_wildcards():
    pred = make_pred(1, [(0, OP_EQ, WILDCARD), (1, OP_GT, 5)])
    labels = jnp.array([1, 1, 0, 1])
    props = jnp.array(
        [[3, 9], [3, 2], [3, 9], [MISSING, 9]], jnp.int32
    )
    ok = evaluate_pred(pred, labels, props)
    # row0 ok; row1 fails GT; row2 wrong label; row3 wildcard prop missing
    assert np.asarray(ok).tolist() == [True, False, False, False]
    bound = jnp.array([[3, 0, 0]], jnp.int32)
    okb = evaluate_pred(pred, labels, props, bound_vals=bound)
    assert np.asarray(okb).tolist() == [True, False, False, False]
    okb2 = evaluate_pred(pred, labels, props, bound_vals=jnp.array([[4, 0, 0]], jnp.int32))
    assert np.asarray(okb2).tolist() == [False, False, False, False]
    w = extract_wildcards(pred, props)
    assert int(w[0, 0]) == 3 and int(w[0, 1]) == MISSING


def test_cache_roundtrip_and_delete():
    cspec = CacheSpec(capacity=128, probes=4, max_leaves=4, max_chunks=2)
    cache = empty_cache(cspec)
    roots = jnp.array([5, 6])
    params = P(roots, [1, 1])
    leaves = jnp.array([[10, 11, -1, -1, -1, -1, -1, -1], [12, -1, -1, -1, -1, -1, -1, -1]], jnp.int32)
    lens = jnp.array([2, 1])
    tpl = jnp.array([0, 0])
    cache = cache_insert(cspec, cache, tpl, roots, params, leaves, lens, jnp.array([1, 1]), jnp.array([True, True]))
    hit, vals, lmask, ver = cache_lookup(cspec, cache, tpl, roots, params)
    assert np.asarray(hit).all()
    assert sorted(np.asarray(vals[0])[np.asarray(lmask[0])].tolist()) == [10, 11]
    # wrong params -> miss
    hit2, *_ = cache_lookup(cspec, cache, tpl, roots, P(roots, [0, 0]))
    assert not np.asarray(hit2).any()
    cache = cache_delete(cspec, cache, tpl[:1], roots[:1], params[:1], jnp.array([True]))
    hit3, *_ = cache_lookup(cspec, cache, tpl, roots, params)
    assert np.asarray(hit3).tolist() == [False, True]


def test_cache_empty_result_is_cacheable():
    cspec = CacheSpec(capacity=64, probes=4, max_leaves=4, max_chunks=1)
    cache = empty_cache(cspec)
    roots = jnp.array([3])
    cache = cache_insert(
        cspec, cache, jnp.array([0]), roots, P(roots, [1]),
        jnp.full((1, 4), -1, jnp.int32), jnp.array([0]), jnp.array([1]), jnp.array([True]),
    )
    hit, vals, lmask, _ = cache_lookup(cspec, cache, jnp.array([0]), roots, P(roots, [1]))
    assert bool(hit[0]) and int(lmask.sum()) == 0


def test_chunked_values():
    cspec = CacheSpec(capacity=128, probes=4, max_leaves=4, max_chunks=3)
    cache = empty_cache(cspec)
    roots = jnp.array([9])
    leaves = jnp.arange(12, dtype=jnp.int32).reshape(1, 12) + 100
    cache = cache_insert(
        cspec, cache, jnp.array([0]), roots, P(roots, [1]), leaves,
        jnp.array([10]), jnp.array([1]), jnp.array([True]),
    )
    hit, vals, lmask, _ = cache_lookup(cspec, cache, jnp.array([0]), roots, P(roots, [1]))
    assert bool(hit[0])
    got = np.asarray(vals[0])[np.asarray(lmask[0])]
    assert got.tolist() == (np.arange(10) + 100).tolist()


def test_oversize_skipped():
    cspec = CacheSpec(capacity=64, probes=4, max_leaves=2, max_chunks=2)
    cache = empty_cache(cspec)
    roots = jnp.array([1])
    leaves = jnp.arange(8, dtype=jnp.int32).reshape(1, 8)
    cache = cache_insert(
        cspec, cache, jnp.array([0]), roots, P(roots, [1]), leaves,
        jnp.array([8]), jnp.array([1]), jnp.array([True]),
    )
    assert cache_stats(cache)["oversize_skipped"] == 1
    hit, *_ = cache_lookup(cspec, cache, jnp.array([0]), roots, P(roots, [1]))
    assert not bool(hit[0])


def test_sweep_root_clears_all_params():
    cspec = CacheSpec(capacity=128, probes=4, max_leaves=4, max_chunks=1)
    cache = empty_cache(cspec)
    roots = jnp.array([7, 7, 8])
    params = P(roots, [0, 1, 0])
    tpl = jnp.array([0, 0, 0])
    leaves = jnp.full((3, 4), -1, jnp.int32)
    cache = cache_insert(cspec, cache, tpl, roots, params, leaves, jnp.array([0, 0, 0]), jnp.array([1, 1, 1]), jnp.array([True] * 3))
    cache = sweep_root(cspec, cache, jnp.array([0]), jnp.array([7]), jnp.array([True]))
    hit, *_ = cache_lookup(cspec, cache, tpl, roots, params)
    assert np.asarray(hit).tolist() == [False, False, True]


def test_sweep_template():
    cspec = CacheSpec(capacity=128, probes=4, max_leaves=4, max_chunks=1)
    cache = empty_cache(cspec)
    roots = jnp.array([1, 2])
    tpl = jnp.array([0, 1])
    leaves = jnp.full((2, 4), -1, jnp.int32)
    cache = cache_insert(cspec, cache, tpl, roots, P(roots, [1, 1]), leaves, jnp.array([0, 0]), jnp.array([1, 1]), jnp.array([True, True]))
    cache = sweep_template(cspec, cache, 0)
    hit, *_ = cache_lookup(cspec, cache, tpl, roots, P(roots, [1, 1]))
    assert np.asarray(hit).tolist() == [False, True]


def test_eviction_under_pressure():
    cspec = CacheSpec(capacity=8, probes=2, max_leaves=2, max_chunks=1)
    cache = empty_cache(cspec)
    n = 32
    roots = jnp.arange(n, dtype=jnp.int32)
    params = P(roots, [1] * n)
    leaves = jnp.full((n, 2), -1, jnp.int32)
    cache = cache_insert(
        cspec, cache, jnp.zeros(n, jnp.int32), roots, params, leaves,
        jnp.zeros(n, jnp.int32), jnp.ones(n, jnp.int32), jnp.ones(n, bool),
    )
    st = cache_stats(cache)
    assert st["evictions"] > 0
    assert st["occupancy"] <= cspec.capacity
    # whatever remains must still be exact
    hit, vals, lmask, _ = cache_lookup(cspec, cache, jnp.zeros(n, jnp.int32), roots, params)
    assert int(np.asarray(hit).sum()) == st["occupancy"]
