"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles, in interpret mode (kernel bodies execute on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an optional test dep (requirements-test.txt); only the
# property tests need it — the kernel sweeps must keep running without it.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.onehop_gather.ops import onehop_gather
from repro.kernels.onehop_gather.ref import onehop_gather_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.recsys.embedding import embedding_bag as embedding_bag_oracle
from repro.kernels.cache_probe.ops import cache_probe
from repro.kernels.cache_probe.ref import cache_probe_ref
from repro.kernels.segment_spmm.ops import segment_spmm
from repro.kernels.segment_spmm.ref import segment_spmm_ref


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Sq,Sk,d,bq,bk",
    [
        (1, 1, 32, 32, 16, 16, 16),
        (2, 3, 64, 64, 32, 16, 32),
        (1, 2, 48, 96, 64, 16, 48),  # cross-attention lengths
    ],
)
@pytest.mark.parametrize("causal,window", [(True, None), (True, 16), (False, None)])
def test_flash_attention_sweep(B, H, Sq, Sk, d, bq, bk, dtype, causal, window):
    if causal and Sq != Sk:
        pytest.skip("causal assumes aligned positions")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, d), dtype)
    k = jax.random.normal(ks[1], (B, H, Sk, d), dtype)
    v = jax.random.normal(ks[2], (B, H, Sk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ------------------------------------------------------------ onehop gather
@pytest.mark.parametrize("V,E,B,max_deg", [(64, 1024, 8, 16), (128, 4096, 32, 32)])
def test_onehop_gather_sweep(V, E, B, max_deg):
    rng = np.random.default_rng(0)
    deg = rng.integers(0, max_deg, V).astype(np.int32)
    start = np.zeros(V, np.int32)
    start[1:] = np.cumsum(deg)[:-1]
    total = int(deg.sum())
    assert total <= E, "test setup: edge capacity must hold all windows"
    dst = rng.integers(0, V, E).astype(np.int32)
    eprop = rng.integers(0, 2, E).astype(np.int32)
    vprop = rng.integers(0, 2, V).astype(np.int32)
    roots = rng.integers(0, V, B).astype(np.int32)
    args = tuple(map(jnp.asarray, (start, deg, dst, eprop, vprop, roots)))
    got_l, got_m = onehop_gather(*args, max_deg=max_deg, edge_val=1, leaf_val=0, block_b=8)
    ref_l, ref_m = onehop_gather_ref(*args, max_deg=max_deg, edge_val=1, leaf_val=0)
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(ref_m))
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(ref_l))


if HAS_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_onehop_gather_property(seed):
        rng = np.random.default_rng(seed)
        V, B, max_deg = 32, 8, 8
        E = V * max_deg  # capacity for every window
        deg = rng.integers(0, max_deg, V).astype(np.int32)
        start = np.concatenate([[0], np.cumsum(deg)[:-1]]).astype(np.int32)
        dst = rng.integers(0, V, E).astype(np.int32)
        eprop = rng.integers(0, 2, E).astype(np.int32)
        vprop = rng.integers(0, 2, V).astype(np.int32)
        roots = rng.integers(0, V, B).astype(np.int32)
        args = tuple(map(jnp.asarray, (start, deg, dst, eprop, vprop, roots)))
        got_l, got_m = onehop_gather(*args, max_deg=max_deg, edge_val=1, leaf_val=0, block_b=8)
        # semantic property: per root, the masked set equals the brute-force set
        for i, r in enumerate(roots):
            want = set()
            for e in range(start[r], start[r] + deg[r]):
                if eprop[e] == 1 and vprop[dst[e]] == 0:
                    want.add(int(dst[e]))
            got = set(np.asarray(got_l[i])[np.asarray(got_m[i])].tolist())
            assert got == want

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_onehop_gather_property():
        pass


# ------------------------------------------------------------ embedding bag
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("V,D,B,K,bb,bd", [(64, 32, 16, 4, 8, 16), (128, 64, 32, 8, 16, 64)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_sweep(V, D, B, K, bb, bd, dtype, mode):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    table = jax.random.normal(ks[0], (V, D), dtype)
    ids = jax.random.randint(ks[1], (B, K), 0, V)
    mask = jax.random.bernoulli(ks[2], 0.7, (B, K))
    got = embedding_bag(table, ids, mask, mode=mode, block_b=bb, block_d=bd)
    ref = embedding_bag_oracle(table, ids, mask, mode=mode)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ------------------------------------------------------------ cache probe
@pytest.mark.parametrize("C,B,probes", [(256, 32, 4), (1024, 64, 8)])
def test_cache_probe_sweep(C, B, probes):
    rng = np.random.default_rng(2)
    c_tpl = rng.integers(-1, 3, C).astype(np.int32)
    c_root = rng.integers(0, 64, C).astype(np.int32)
    c_fp = rng.integers(0, 2**32, C, dtype=np.uint32)
    c_valid = rng.random(C) < 0.5
    tpl = rng.integers(0, 3, B).astype(np.int32)
    root = rng.integers(0, 64, B).astype(np.int32)
    h = rng.integers(0, 2**32, B, dtype=np.uint32)
    # make half the queries real hits: copy metadata into their base slot
    for i in range(0, B, 2):
        s = int(h[i] % C)
        c_tpl[s], c_root[s], c_valid[s] = tpl[i], root[i], True
        c_fp[s] = np.uint32(i * 2654435761 % 2**32)
    fp = np.array([np.uint32(i * 2654435761 % 2**32) for i in range(B)], np.uint32)
    args = tuple(map(jnp.asarray, (c_tpl, c_root, c_fp, c_valid, tpl, root, h, fp)))
    got_hit, got_slot = cache_probe(*args, probes=probes, block_b=8)
    ref_hit, ref_slot = cache_probe_ref(*args, probes=probes)
    np.testing.assert_array_equal(np.asarray(got_hit), np.asarray(ref_hit))
    np.testing.assert_array_equal(np.asarray(got_slot), np.asarray(ref_slot))
    assert np.asarray(got_hit)[::2].all()  # the planted hits are found


# ------------------------------------------------------------ segment spmm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,E,D,bn,be", [(64, 256, 16, 16, 32), (128, 512, 32, 32, 64)])
def test_segment_spmm_sweep(N, E, D, bn, be, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (N, D), dtype)
    src = jax.random.randint(ks[1], (E,), 0, N)
    dst = jax.random.randint(ks[2], (E,), 0, N)
    got = segment_spmm(x, src, dst, block_n=bn, block_e=be, max_chunks=E // be + 1)
    ref = segment_spmm_ref(x, src, dst)
    tol = 1e-5 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ------------------------------------------------------------ block gather
from repro.core.templates import OP_EQ, OP_GT, OP_LE  # noqa: E402
from repro.kernels.block_gather.ops import (  # noqa: E402
    block_gather,
    first_occurrence_mask,
)
from repro.kernels.block_gather.ref import block_gather_filter_ref  # noqa: E402
from repro.utils import NULL_ID, PROP_MISSING, dedup_masked  # noqa: E402


def _block_gather_world(rng, B, *, v_loc=8, v_cap=32, EB=64, max_deg=4,
                        recent_cap=8):
    """Synthetic one-orientation operand bundle: a CSR region with one
    over-degree adjacency (trunc), junk bytes past ``csr_len``, and a live
    recent region whose keys hit a subset of the batch roots."""
    deg = np.array([0, 2, 4, 6, 1, 8, 3, 0], np.int32)[:v_loc]
    indptr = np.concatenate([[0], np.cumsum(deg)]).astype(np.int32)
    csr_len, blk_len = 40, 48
    key = rng.integers(0, v_cap, EB).astype(np.int32)
    other = rng.integers(-1, v_cap + 4, EB).astype(np.int32)  # some OOB
    label = rng.integers(0, 2, EB).astype(np.int32)
    alive = (rng.random(EB) < 0.8)
    props = rng.integers(0, 8, (EB, 2)).astype(np.int32)
    props[rng.random((EB, 2)) < 0.2] = int(PROP_MISSING)
    vlabel = rng.integers(0, 2, v_cap).astype(np.int32)
    valive = rng.random(v_cap) < 0.9
    vprops = rng.integers(0, 8, (v_cap, 2)).astype(np.int32)
    vprops[rng.random((v_cap, 2)) < 0.2] = int(PROP_MISSING)
    roots = rng.integers(0, v_cap, B).astype(np.int32)
    # recent region [csr_len, blk_len): keys match half the batch's roots
    key[csr_len:blk_len] = roots[rng.integers(0, B, blk_len - csr_len)]
    lroot = rng.integers(0, v_loc, B).astype(np.int32)
    rvalid = rng.random(B) < 0.8
    # without a routing table the CSR gate equals the ownership gate; a
    # dedicated case below exercises cvalid ⊂ rvalid (migrated-in roots)
    cvalid = rvalid
    rmask = rng.random(B) < 0.8
    r_ok = (rng.random(B) < 0.8) & rmask
    pe_bound = rng.integers(0, 8, (B, 3)).astype(np.int32)
    pl_bound = rng.integers(0, 8, (B, 3)).astype(np.int32)
    arrs = (indptr, key, other, label, alive, props, vlabel, valive, vprops,
            np.int32(csr_len), np.int32(blk_len), roots, lroot, rvalid,
            cvalid, rmask, r_ok, pe_bound, pl_bound)
    statics = dict(max_deg=max_deg, recent_cap=recent_cap, e_blk_cap=EB)
    return tuple(map(jnp.asarray, arrs)), statics


_PRED_CASES = [
    # any edge label, no conditions — the liveness chain alone
    (-1, (-1, ()), (-1, ())),
    # static label + literal conditions on both predicate stages
    (0, (-1, ((0, 0, OP_LE, 3, False),)), (1, ((1, 1, OP_GT, 2, False),))),
    # wildcard conditions reading the per-row bound params by lane
    (1, (-1, ((1, 0, OP_GT, 0, True),)), (0, ((0, 1, OP_EQ, 7, True),))),
    # mixed: literal + wildcard on the same predicate
    (0, (0, ((0, 0, OP_EQ, 1, False), (2, 1, OP_LE, 5, True))), (-1, ())),
]


@pytest.mark.parametrize("B,block_b", [(8, 8), (12, 8), (33, 16)])
@pytest.mark.parametrize("edge_label,pe,pl", _PRED_CASES)
def test_block_gather_interpret_parity(B, block_b, edge_label, pe, pl):
    """The Pallas kernel (interpret mode) must match the vectorized
    reference bit-exactly: CSR window, recent region, liveness chain, and
    the statically specialized predicate filters — including batches that
    need padding to whole kernel blocks."""
    rng = np.random.default_rng(B * 7 + len(pe[1]))
    args, statics = _block_gather_world(rng, B)
    statics.update(edge_label=edge_label, pe=pe, pl=pl)
    ref = block_gather_filter_ref(*args, **statics)
    got = block_gather(*args, **statics, block_b=block_b, use_pallas=True,
                       interpret=True)
    names = ("leaf", "scan", "emask", "qual", "trunc")
    for name, a, b in zip(names, ref, got):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=name
        )


def test_block_gather_empty_and_full_cap_frontier():
    """An all-masked (empty) frontier produces no observed lanes; a
    full-cap frontier (B == block_b, every row valid) stays bit-exact."""
    rng = np.random.default_rng(5)
    args, statics = _block_gather_world(rng, 16)
    statics.update(edge_label=-1, pe=(-1, ()), pl=(-1, ()))
    z = jnp.zeros(16, bool)
    empty = list(args)
    # rvalid, cvalid, rmask, r_ok
    empty[13], empty[14], empty[15], empty[16] = z, z, z, z
    leaf_e, scan_e, emask_e, qual_e, _ = block_gather(
        *empty, **statics, block_b=16, use_pallas=True, interpret=True
    )
    assert not (np.asarray(scan_e).any() or np.asarray(emask_e).any()
                or np.asarray(qual_e).any())
    o = jnp.ones(16, bool)
    full = list(args)
    full[13], full[14], full[15], full[16] = o, o, o, o
    ref = block_gather_filter_ref(*full, **statics)
    got = block_gather(*full, **statics, block_b=16, use_pallas=True,
                       interpret=True)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.asarray(got[1]).any()  # the full frontier observed lanes


def test_block_gather_cvalid_gates_csr_only():
    """With the split gate (migrated-in roots: cvalid ⊂ rvalid) the CSR
    window closes for non-native rows while the recent-region key scan
    still serves them — and the kernel stays bit-exact with the ref."""
    rng = np.random.default_rng(9)
    args, statics = _block_gather_world(rng, 16)
    statics.update(edge_label=-1, pe=(-1, ()), pl=(-1, ()))
    o = jnp.ones(16, bool)
    lst = list(args)
    cvalid = jnp.asarray(np.arange(16) % 2 == 0)  # half the rows native
    lst[13], lst[14], lst[15], lst[16] = o, cvalid, o, o
    ref = block_gather_filter_ref(*lst, **statics)
    got = block_gather(*lst, **statics, block_b=16, use_pallas=True,
                       interpret=True)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a closed CSR window means scan lanes for odd rows can only come from
    # the recent region (lanes >= max_deg in the concatenated layout)
    scan = np.asarray(ref[1])
    max_deg = statics["max_deg"]
    assert not scan[1::2, :max_deg].any()


def test_first_occurrence_mask_matches_dedup_masked():
    """The O(W log W) sort-based dedup must keep exactly the lanes the
    O(W^2) pairwise ``dedup_masked`` keeps, for any masked lane set free
    of NULL_ID (the liveness-masked block-lane invariant)."""
    rng = np.random.default_rng(11)
    for _ in range(20):
        vals = rng.integers(0, 12, (6, 24)).astype(np.int32)
        mask = rng.random((6, 24)) < 0.6
        a = dedup_masked(jnp.asarray(vals), jnp.asarray(mask))
        b = first_occurrence_mask(jnp.asarray(vals), jnp.asarray(mask))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # degenerate rows: fully masked, single lane, all-equal values
    vals = jnp.asarray(np.array([[3, 3, 3, 3], [7, 1, 7, 1]], np.int32))
    mask = jnp.asarray(np.array([[0, 0, 0, 0], [1, 1, 1, 1]], bool))
    np.testing.assert_array_equal(
        np.asarray(dedup_masked(vals, mask)),
        np.asarray(first_occurrence_mask(vals, mask)),
    )
