"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles, in interpret mode (kernel bodies execute on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an optional test dep (requirements-test.txt); only the
# property tests need it — the kernel sweeps must keep running without it.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.onehop_gather.ops import onehop_gather
from repro.kernels.onehop_gather.ref import onehop_gather_ref
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.recsys.embedding import embedding_bag as embedding_bag_oracle
from repro.kernels.cache_probe.ops import cache_probe
from repro.kernels.cache_probe.ref import cache_probe_ref
from repro.kernels.segment_spmm.ops import segment_spmm
from repro.kernels.segment_spmm.ref import segment_spmm_ref


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Sq,Sk,d,bq,bk",
    [
        (1, 1, 32, 32, 16, 16, 16),
        (2, 3, 64, 64, 32, 16, 32),
        (1, 2, 48, 96, 64, 16, 48),  # cross-attention lengths
    ],
)
@pytest.mark.parametrize("causal,window", [(True, None), (True, 16), (False, None)])
def test_flash_attention_sweep(B, H, Sq, Sk, d, bq, bk, dtype, causal, window):
    if causal and Sq != Sk:
        pytest.skip("causal assumes aligned positions")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, d), dtype)
    k = jax.random.normal(ks[1], (B, H, Sk, d), dtype)
    v = jax.random.normal(ks[2], (B, H, Sk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ------------------------------------------------------------ onehop gather
@pytest.mark.parametrize("V,E,B,max_deg", [(64, 1024, 8, 16), (128, 4096, 32, 32)])
def test_onehop_gather_sweep(V, E, B, max_deg):
    rng = np.random.default_rng(0)
    deg = rng.integers(0, max_deg, V).astype(np.int32)
    start = np.zeros(V, np.int32)
    start[1:] = np.cumsum(deg)[:-1]
    total = int(deg.sum())
    assert total <= E, "test setup: edge capacity must hold all windows"
    dst = rng.integers(0, V, E).astype(np.int32)
    eprop = rng.integers(0, 2, E).astype(np.int32)
    vprop = rng.integers(0, 2, V).astype(np.int32)
    roots = rng.integers(0, V, B).astype(np.int32)
    args = tuple(map(jnp.asarray, (start, deg, dst, eprop, vprop, roots)))
    got_l, got_m = onehop_gather(*args, max_deg=max_deg, edge_val=1, leaf_val=0, block_b=8)
    ref_l, ref_m = onehop_gather_ref(*args, max_deg=max_deg, edge_val=1, leaf_val=0)
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(ref_m))
    np.testing.assert_array_equal(np.asarray(got_l), np.asarray(ref_l))


if HAS_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_onehop_gather_property(seed):
        rng = np.random.default_rng(seed)
        V, B, max_deg = 32, 8, 8
        E = V * max_deg  # capacity for every window
        deg = rng.integers(0, max_deg, V).astype(np.int32)
        start = np.concatenate([[0], np.cumsum(deg)[:-1]]).astype(np.int32)
        dst = rng.integers(0, V, E).astype(np.int32)
        eprop = rng.integers(0, 2, E).astype(np.int32)
        vprop = rng.integers(0, 2, V).astype(np.int32)
        roots = rng.integers(0, V, B).astype(np.int32)
        args = tuple(map(jnp.asarray, (start, deg, dst, eprop, vprop, roots)))
        got_l, got_m = onehop_gather(*args, max_deg=max_deg, edge_val=1, leaf_val=0, block_b=8)
        # semantic property: per root, the masked set equals the brute-force set
        for i, r in enumerate(roots):
            want = set()
            for e in range(start[r], start[r] + deg[r]):
                if eprop[e] == 1 and vprop[dst[e]] == 0:
                    want.add(int(dst[e]))
            got = set(np.asarray(got_l[i])[np.asarray(got_m[i])].tolist())
            assert got == want

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_onehop_gather_property():
        pass


# ------------------------------------------------------------ embedding bag
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("V,D,B,K,bb,bd", [(64, 32, 16, 4, 8, 16), (128, 64, 32, 8, 16, 64)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_sweep(V, D, B, K, bb, bd, dtype, mode):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    table = jax.random.normal(ks[0], (V, D), dtype)
    ids = jax.random.randint(ks[1], (B, K), 0, V)
    mask = jax.random.bernoulli(ks[2], 0.7, (B, K))
    got = embedding_bag(table, ids, mask, mode=mode, block_b=bb, block_d=bd)
    ref = embedding_bag_oracle(table, ids, mask, mode=mode)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


# ------------------------------------------------------------ cache probe
@pytest.mark.parametrize("C,B,probes", [(256, 32, 4), (1024, 64, 8)])
def test_cache_probe_sweep(C, B, probes):
    rng = np.random.default_rng(2)
    c_tpl = rng.integers(-1, 3, C).astype(np.int32)
    c_root = rng.integers(0, 64, C).astype(np.int32)
    c_fp = rng.integers(0, 2**32, C, dtype=np.uint32)
    c_valid = rng.random(C) < 0.5
    tpl = rng.integers(0, 3, B).astype(np.int32)
    root = rng.integers(0, 64, B).astype(np.int32)
    h = rng.integers(0, 2**32, B, dtype=np.uint32)
    # make half the queries real hits: copy metadata into their base slot
    for i in range(0, B, 2):
        s = int(h[i] % C)
        c_tpl[s], c_root[s], c_valid[s] = tpl[i], root[i], True
        c_fp[s] = np.uint32(i * 2654435761 % 2**32)
    fp = np.array([np.uint32(i * 2654435761 % 2**32) for i in range(B)], np.uint32)
    args = tuple(map(jnp.asarray, (c_tpl, c_root, c_fp, c_valid, tpl, root, h, fp)))
    got_hit, got_slot = cache_probe(*args, probes=probes, block_b=8)
    ref_hit, ref_slot = cache_probe_ref(*args, probes=probes)
    np.testing.assert_array_equal(np.asarray(got_hit), np.asarray(ref_hit))
    np.testing.assert_array_equal(np.asarray(got_slot), np.asarray(ref_slot))
    assert np.asarray(got_hit)[::2].all()  # the planted hits are found


# ------------------------------------------------------------ segment spmm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("N,E,D,bn,be", [(64, 256, 16, 16, 32), (128, 512, 32, 32, 64)])
def test_segment_spmm_sweep(N, E, D, bn, be, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(ks[0], (N, D), dtype)
    src = jax.random.randint(ks[1], (E,), 0, N)
    dst = jax.random.randint(ks[2], (E,), 0, N)
    got = segment_spmm(x, src, dst, block_n=bn, block_e=be, max_chunks=E // be + 1)
    ref = segment_spmm_ref(x, src, dst)
    tol = 1e-5 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )
