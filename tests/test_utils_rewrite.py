"""Fast unit tests: tensor utils, query-rewrite rules, hashing."""

import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an optional test dep (requirements-test.txt); only the
# property tests need it — the rest of this module must keep running.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from conftest import MISSING, P_LISTING_ID, common_watchlist_plan, fig1_plan
from repro.core import FINAL_IDS, FINAL_VALUES, rewrite_plan
from repro.core.rewrite import rewrite_savings
from repro.utils import compact_masked, dedup_masked, hash_rows


def test_compact_masked_1d():
    vals = jnp.array([5, 6, 7, 8])
    mask = jnp.array([True, False, True, False])
    out, om = compact_masked(vals, mask, 3)
    assert out[:2].tolist() == [5, 7] and om.tolist() == [True, True, False]


def test_compact_masked_batched_truncates():
    vals = jnp.arange(12).reshape(2, 6)
    mask = jnp.ones((2, 6), bool)
    out, om = compact_masked(vals, mask, 4)
    assert out.shape == (2, 4)
    assert out[1].tolist() == [6, 7, 8, 9]


if HAS_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(0, 9), min_size=1, max_size=12))
    def test_dedup_masked_property(xs):
        vals = jnp.asarray(xs, jnp.int32)
        mask = jnp.ones(len(xs), bool)
        m2 = dedup_masked(vals, mask)
        kept = [int(v) for v, m in zip(xs, np.asarray(m2)) if m]
        # keeps exactly the first occurrence of each value, order-preserving
        seen, want = set(), []
        for v in xs:
            if v not in seen:
                seen.add(v)
                want.append(v)
        assert kept == want

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_dedup_masked_property():
        pass


def test_hash_rows_determinism_and_seed_independence():
    a = hash_rows([jnp.arange(8), jnp.arange(8) * 3], 1)
    b = hash_rows([jnp.arange(8), jnp.arange(8) * 3], 1)
    c = hash_rows([jnp.arange(8), jnp.arange(8) * 3], 2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (np.asarray(a) != np.asarray(c)).any()


def test_rewrite_rule1_and_savings():
    plan = common_watchlist_plan()
    rw = rewrite_plan(plan, unique_props=frozenset({P_LISTING_ID}))
    assert rw.post_filter == ("id_neq",)
    assert rewrite_savings(plan, rw)["phases_saved"] == 1


def test_rewrite_rule2_values_to_ids():
    plan = fig1_plan()._replace(final=FINAL_VALUES, final_prop=P_LISTING_ID)
    rw = rewrite_plan(plan, unique_props=frozenset({P_LISTING_ID}))
    assert rw.final == FINAL_IDS and rw.final_prop == -1


def test_rewrite_noop_without_unique_declaration():
    plan = common_watchlist_plan()
    rw = rewrite_plan(plan, unique_props=frozenset())
    assert rw.post_filter == plan.post_filter
