"""Chaos: crash at every point of the migration protocol; recovery must be
byte-identical to the pre- OR post-migration control — never a torn mix.

The live protocol is journal-first: ``append_migrate`` (durable MIGRATE
record) → deterministic ``migrate_vertex_rows`` splice → one-epoch
``apply_moves`` table publish (``MigrationEngine.step`` pins this order in
test_migration.py). This suite snapshots the journal directory at each
boundary of that sequence — plus a torn MIGRATE frame, the mid-write
crash — and replays each snapshot on a fresh runtime:

- crash BEFORE the record is durable (including the torn frame) recovers
  the pre-migration store byte-for-byte;
- crash anywhere AFTER the record is durable recovers the post-migration
  store byte-for-byte, whether or not the live splice or table publish
  ever ran;
- commits journaled after the migration replay through the reconstructed
  routing table, so the final store matches the live one byte-for-byte.

Runs in a subprocess so XLA_FLAGS can create the 8 host devices before jax
initializes (same pattern as test_sharded_runtime). The complementary
liveness rule — the engine refuses to START a round while the failure
detector reports an owner down — is pinned in test_migration.py.
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import shutil
    import tempfile
    import numpy as np
    import jax
    from conftest import build_world, enabled_ttable, common_watchlist_plan
    from repro.core import CacheSpec, EngineSpec
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedTxnRuntime
    from repro.distributed.routing import RoutingTableHost
    from repro.graphstore import WriteBehindJournal, make_mutation_batch, replay
    from repro.graphstore.migration import (
        infer_storage_exceptions, migrate_vertex_rows,
    )

    spec, store = build_world()
    cspec = CacheSpec(capacity=1024, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, sc, qp = enabled_ttable()
    mesh = flat_mesh(8)
    plan = common_watchlist_plan()

    def snapshot_bytes(ps):
        return [np.asarray(x).copy()
                for x in jax.tree_util.tree_leaves(jax.device_get(ps))]

    def assert_bytes(got, want, tag):
        assert len(got) == len(want), tag
        for a, b in zip(got, want):
            assert np.array_equal(a, b), tag

    base = tempfile.mkdtemp(prefix="migration-chaos-")
    live_root = os.path.join(base, "live")

    rt = ShardedTxnRuntime(espec, mesh, route_cap_factor=None, blk_slack=1.0)
    ps = rt.partition_store(store)
    rhost = RoutingTableHost(rt.n)
    rt.attach_routing(rhost)
    j = WriteBehindJournal(live_root, rt.n)
    j.checkpoint(ps, e_blk_cap=rt.pspec.e_blk_cap,
                 recent_blk_cap=rt.pspec.recent_blk_cap, store_version=0)

    # commit 1 (pre-migration traffic), durable
    mb1 = make_mutation_batch(
        spec, new_edges=[(1, 12, 0, [1])], set_vprops=[(7, 0, 1)],
    )
    ps, _, _ = rt.run_grw_tx(ps, rt.empty_cache(), ttable, mb1, journal=j)
    j.flush()
    pre_control = snapshot_bytes(ps)
    snaps = {}
    shutil.copytree(live_root, os.path.join(base, "p0"))   # before MIGRATE
    log_len_p0 = os.path.getsize(j.log_path)

    # the migration round, journal-first (MigrationEngine.step's order)
    moves = [(0, 7), (5, 2)]
    j.append_migrate(moves, epoch=rhost.epoch + 1)
    j.flush()
    shutil.copytree(live_root, os.path.join(base, "p1"))   # durable, no splice
    log_len_p1 = os.path.getsize(j.log_path)
    ps = jax.device_put(
        migrate_vertex_rows(rt.pspec, ps, moves), rt.store_sharding()
    )
    shutil.copytree(live_root, os.path.join(base, "p2"))   # spliced, unpublished
    rhost.apply_moves(moves)
    shutil.copytree(live_root, os.path.join(base, "p3"))   # published
    post_control = snapshot_bytes(ps)
    assert infer_storage_exceptions(rt.pspec, ps) == dict(moves)

    # commit 2 (post-migration traffic through the table), durable
    mb2 = make_mutation_batch(
        spec, new_edges=[(5, 11, 0, [0])], del_edges=[2],
    )
    ps, _, _ = rt.run_grw_tx(ps, rt.empty_cache(), ttable, mb2, journal=j)
    j.flush()
    shutil.copytree(live_root, os.path.join(base, "p4"))   # post-traffic
    final_control = snapshot_bytes(ps)

    # torn MIGRATE frame: the writer died mid-append — truncate the p1 log
    # halfway into the record's bytes
    torn = os.path.join(base, "torn")
    shutil.copytree(os.path.join(base, "p1"), torn)
    torn_log = os.path.join(torn, os.path.basename(j.log_path))
    with open(torn_log, "r+b") as f:
        f.truncate(log_len_p0 + (log_len_p1 - log_len_p0) // 2)

    cases = [
        ("p0", pre_control, 0, 1),    # crash before the record: pre state
        ("torn", pre_control, 0, 1),  # crash mid-append: pre state, clean
        ("p1", post_control, 1, 1),   # durable record, splice never ran
        ("p2", post_control, 1, 1),   # spliced, table never published
        ("p3", post_control, 1, 1),   # fully published
        ("p4", final_control, 1, 2),  # plus post-migration traffic
    ]
    for tag, want, n_migr, n_commits in cases:
        rt2 = ShardedTxnRuntime(
            espec, mesh, route_cap_factor=None, blk_slack=1.0
        )
        j2 = WriteBehindJournal(os.path.join(base, tag), rt2.n)
        ps_r, _, info = replay(j2, rt2, ttable)
        assert info["replayed_migrations"] == n_migr, (tag, info)
        assert info["replayed_commits"] == n_commits, (tag, info)
        assert_bytes(snapshot_bytes(ps_r), want, tag)
    print("MIGRATION_CHAOS_OK")
    """
)


def _run(script, token):
    env = dict(
        os.environ,
        PYTHONPATH=os.pathsep.join(
            [os.path.join(REPO, "src"), os.path.join(REPO, "tests")]
        ),
    )
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=1800,
    )
    assert token in out.stdout, out.stdout + out.stderr


def test_crash_at_every_migration_point_recovers_pre_or_post_never_torn():
    _run(SCRIPT, "MIGRATION_CHAOS_OK")
