"""Failure-detection, degraded-mode serving, and recovery-as-migration —
the tier-1 (host / 1-shard) half of the failover layer.

The fault library primitives (`RetryPolicy` validation + retryable
classification, `timed_call` bounds, `FailureDetector` thresholds,
`ShardFaultPlan` scripting, `HedgedCalls.call` racing, exception-safe
epoch pins) are pure host code and test directly. The serving state
machine runs end-to-end on the 1-shard degenerate mesh — crash the only
owner and the loop must detect, defer every miss (cache hits keep
serving), queue every commit, then recover byte-identically — the fast
crash/recover smoke; the 8-device chaos run with a *partial* outage is
``benchmarks/bench_failover.py`` in the sharded-runtime CI job."""

import time

import numpy as np
import pytest

from conftest import build_world, enabled_ttable, fig1_plan
from repro.core import CacheSpec, EngineSpec
from repro.distributed import flat_mesh
from repro.distributed.failover import FailoverController
from repro.distributed.fault import (
    CallTimeout,
    FailureDetector,
    HedgedCalls,
    NodeFailure,
    RetryPolicy,
    ShardFaultPlan,
    timed_call,
)
from repro.distributed.graph_serve import ShardedTxnRuntime
from repro.graphstore import (
    EpochRegistry,
    WriteBehindJournal,
    make_mutation_batch,
)


# --------------------------------------------------------------- RetryPolicy
def test_retry_policy_rejects_zero_attempts():
    # the old code fell through the loop and re-raised `last = None`
    # (TypeError); now the bad budget is rejected at construction
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=-3)


def test_retry_policy_retryable_predicate_short_circuits():
    calls = []

    def fn():
        calls.append(1)
        raise KeyError("not transient")

    rp = RetryPolicy(
        max_attempts=5, retryable=lambda e: not isinstance(e, KeyError)
    )
    with pytest.raises(KeyError):
        rp.run(fn)
    assert len(calls) == 1  # surfaced immediately, no burned retries

    calls.clear()
    rp2 = RetryPolicy(max_attempts=3, retryable=lambda e: isinstance(e, OSError))
    with pytest.raises(OSError):
        rp2.run(lambda: (calls.append(1), (_ for _ in ()).throw(OSError()))[1])
    assert len(calls) == 3  # transient per the predicate: full budget


def test_retry_policy_succeeds_mid_budget():
    state = {"n": 0}

    def flaky():
        state["n"] += 1
        if state["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert RetryPolicy(max_attempts=4).run(flaky) == "ok"
    assert state["n"] == 3


# ----------------------------------------------------------------- timed_call
def test_timed_call_inline_when_unbounded():
    assert timed_call(lambda x: x + 1, None, 2) == 3


def test_timed_call_times_out_and_propagates_errors():
    with pytest.raises(CallTimeout):
        timed_call(time.sleep, 0.02, 0.5)
    with pytest.raises(ZeroDivisionError):
        timed_call(lambda: 1 / 0, 1.0)
    assert timed_call(lambda: "fast", 1.0) == "fast"


# ----------------------------------------------------- detector + fault plan
def test_failure_detector_threshold_and_recovery():
    d = FailureDetector(n=4, fail_threshold=2)
    d.observe_failure(1)
    assert d.down() == frozenset()  # one blip does not flap the mesh
    d.observe_ok(1)
    d.observe_failure(1)
    assert d.down() == frozenset()  # consecutive counter reset by the ok
    d.observe_failure(1)
    d.observe_failure(1)
    assert d.down() == frozenset({1})
    assert d.detections == 1
    assert d.down_mask().tolist() == [False, True, False, False]
    d.mark_recovered(1)
    assert d.down() == frozenset() and d.recoveries == 1


def test_failure_detector_straggle_marking():
    d = FailureDetector(n=2, straggle_after=0.1)
    d.observe_ok(0, latency_s=0.5)
    assert d.straggling() == frozenset({0})
    d.observe_ok(0, latency_s=0.01)
    assert d.straggling() == frozenset()


def test_failure_detector_observe_step_heartbeat():
    """A measured collective-step latency feeds every live owner's
    heartbeat: slow steps mark the mesh straggling, a fast step clears it,
    and owners already down keep their state (no flap through observe_ok)."""
    d = FailureDetector(n=3, fail_threshold=1, straggle_after=0.1)
    d.observe_step(0.5)
    assert d.straggling() == frozenset({0, 1, 2})
    d.observe_step(0.01)
    assert d.straggling() == frozenset()
    d.observe_failure(2)
    assert d.down() == frozenset({2})
    d.observe_step(0.01)
    assert d.down() == frozenset({2})  # a step heartbeat never revives


def test_failure_detector_per_owner_marks_only_the_straggler():
    """The telemetry tier's work-attributed per-owner step latency must
    single out the slow owner: one straggler of eight is marked alone,
    while the aggregate fallback (no attribution) still marks the whole
    mesh — the collective-step semantics it preserves."""
    d = FailureDetector(n=8, fail_threshold=1, straggle_after=0.1)
    per = np.full(8, 0.02)
    per[5] = 0.5  # one slow owner; mesh-wide mean stays under threshold
    d.observe_step(float(per.mean()), per_owner=per)
    assert d.straggling() == frozenset({5})
    # a balanced follow-up step clears the mark
    d.observe_step(0.02, per_owner=np.full(8, 0.02))
    assert d.straggling() == frozenset()
    # down owners never flap through the per-owner heartbeat
    d.observe_failure(3)
    d.observe_step(0.02, per_owner=per)
    assert d.down() == frozenset({3})
    assert d.straggling() == frozenset({5})
    # same latencies through the aggregate fallback: everyone straggles
    d2 = FailureDetector(n=8, fail_threshold=1, straggle_after=0.1)
    d2.observe_step(0.5)
    assert d2.straggling() == frozenset(range(8))
    # attribution must cover every owner — a short vector is an error
    with pytest.raises(ValueError, match="owners"):
        d.observe_step(0.02, per_owner=np.full(4, 0.02))


def test_probe_uses_measured_step_timing_when_unscripted():
    """With no ShardFaultPlan the controller's probe must heartbeat from
    the runtime's real measured step wall-clock, so a live straggler trips
    ``straggle_after`` without any scripted fault."""

    class _Rt:
        n = 4
        last_step_seconds = 0.0

    rt = _Rt()
    det = FailureDetector(n=4, straggle_after=0.05)
    ctl = FailoverController(rt, None, None, detector=det)
    rt.last_step_seconds = 0.01
    assert ctl.probe(0) == frozenset()
    assert det.straggling() == frozenset()
    rt.last_step_seconds = 0.2  # a real slow step
    ctl.probe(1)
    assert det.straggling() == frozenset(range(4))
    rt.last_step_seconds = 0.01
    ctl.probe(2)
    assert det.straggling() == frozenset()


def test_shard_fault_plan_script():
    p = ShardFaultPlan(
        crash={2: 5}, hang={1: (3, 6, 0.2)}, torn_flush_attempts=(0,)
    )
    assert p.crashed_at(4) == frozenset()
    assert p.crashed_at(5) == frozenset({2})
    assert p.hang_delay(1, 2) == 0.0
    assert p.hang_delay(1, 4) == 0.2
    assert p.hang_delay(1, 6) == 0.0
    with pytest.raises(OSError):
        p.flush_fault(0)
    p.flush_fault(1)  # not scripted: no-op
    p.revive(2)
    assert p.crashed_at(99) == frozenset()


# ------------------------------------------------------------ hedged calls
def test_hedged_call_fast_primary_skips_hedge():
    h = HedgedCalls()
    r, from_hedge = h.call(lambda: "fast", lambda: "hedge", hedge_after=0.5)
    assert r == "fast" and not from_hedge
    assert h.issued == 1 and h.hedged == 0 and h.hedge_rate == 0.0


def test_hedged_call_slow_primary_loses_to_hedge():
    h = HedgedCalls()

    def slow():
        time.sleep(0.5)
        return "slow"

    r, from_hedge = h.call(slow, lambda: "hedge", hedge_after=0.01)
    assert r == "hedge" and from_hedge
    assert h.hedged == 1 and h.hedge_wins == 1 and h.hedge_rate == 1.0


def test_hedged_call_winner_error_propagates():
    h = HedgedCalls()

    def bad():
        raise RuntimeError("primary died")

    with pytest.raises(RuntimeError, match="primary died"):
        h.call(bad, lambda: "never-launched", hedge_after=5.0)


# -------------------------------------------------------- exception-safe pins
def test_pin_scope_releases_on_every_exit_path():
    reg = EpochRegistry()
    reg.advance(7)
    with reg.pin_scope():
        assert reg.open_pins() == 1
        assert reg.min_pinned() == 7
    assert reg.open_pins() == 0
    assert reg.leaked_releases == 0

    # the failure mode the scope exists for: a gR batch raising mid-flight
    # used to leak its pin and block tombstone purge forever
    with pytest.raises(NodeFailure):
        with reg.pin_scope():
            raise NodeFailure("owner lost mid-batch")
    assert reg.open_pins() == 0  # released, not leaked
    assert reg.leaked_releases == 1
    reg.advance(9)
    assert reg.safe_to_purge(9)  # purge is NOT wedged by the dead reader


# ------------------------------------------------- queued-commit watermark
def test_applied_watermark_freezes_for_queued_commits(tmp_path):
    spec, _ = build_world()
    j = WriteBehindJournal(str(tmp_path / "j"), 2)
    mb = make_mutation_batch(spec, new_edges=[(0, 5, 0, [1])])
    s1 = j.append_commit(mb, commit_version=1)
    assert j.applied_seq == s1
    s2 = j.append_commit(mb, applied=False)  # degraded mode: queued
    s3 = j.append_commit(mb, applied=False)
    assert j.applied_seq == s1  # frozen at the outage boundary
    m = j.metrics()
    assert m["queued_commits"] == 2 and m["applied_seq"] == s1
    j.flush()
    # the watermark is durable: a reopened journal (crashed process) still
    # knows which records were device-applied vs queued
    j2 = WriteBehindJournal(str(tmp_path / "j"), 2)
    assert j2.applied_seq == s1
    assert [r.seq for r in j2.read_records(after_seq=j2.applied_seq)] == [s2, s3]


def test_queued_commits_mark_owners_checkpoint_dirty(tmp_path):
    spec, _ = build_world()
    j = WriteBehindJournal(str(tmp_path / "j"), 4)
    mb = make_mutation_batch(spec, new_edges=[(1, 5, 0, [1])])
    j.append_commit(mb)
    assert j.metrics()["dirty_owners_since_ckpt"] > 0
    # flush clears the per-flush dirty map but NOT the checkpoint map
    j.flush()
    assert j.metrics()["dirty_owners"] == 0
    assert j.metrics()["dirty_owners_since_ckpt"] > 0
    # a gated commit that compacted on-device dirties every owner
    j.append_commit(mb, device_compactions=1)
    assert j.metrics()["dirty_owners_since_ckpt"] == 4


def test_journal_io_timeout_flush(tmp_path):
    """A hung flush write surfaces as a bounded-retry failure, not a hang."""
    from repro.graphstore import FlushError

    spec, _ = build_world()

    def hang_forever(attempt):
        time.sleep(10.0)

    j = WriteBehindJournal(
        str(tmp_path / "j"), 1, io_timeout=0.05,
        retry=RetryPolicy(max_attempts=2), flush_fault=hang_forever,
    )
    j.append_commit(make_mutation_batch(spec, new_edges=[(0, 5, 0, [1])]))
    t0 = time.perf_counter()
    with pytest.raises(FlushError):
        j.flush()
    assert time.perf_counter() - t0 < 5.0  # bounded, not wedged
    assert j.flush_failures == 1


# ------------------------------------- 1-shard crash/recover smoke (tier-1)
def _one_shard_world():
    spec, store = build_world()
    cspec = CacheSpec(capacity=256, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, _, _ = enabled_ttable()
    return spec, store, espec, ttable, fig1_plan()


def test_single_shard_crash_degrade_recover(tmp_path):
    """The full failover lifecycle on the 1-shard degenerate mesh: with the
    only owner down, every miss defers but cache hits keep serving; commits
    queue durably; recovery replays + drains back to byte-identity with an
    uninterrupted control run."""
    import jax

    spec, store, espec, ttable, plan = _one_shard_world()
    roots = np.array([0, 1, 2, 3], np.int32)
    mb1 = make_mutation_batch(spec, new_edges=[(0, 9, 0, [1])])
    mb2 = make_mutation_batch(spec, new_edges=[(1, 8, 0, [0])])

    # --- control: the same traffic, no fault
    rt_c = ShardedTxnRuntime(espec, flat_mesh(1), route_cap_factor=None,
                             blk_slack=1.0)
    ps_c = rt_c.partition_store(store)
    cache_c = rt_c.empty_cache()
    res_c0, _, _ = rt_c.run_gr_tx_batch(ps_c, cache_c, ttable, plan, roots)
    ps_c, cache_c, _ = rt_c.run_grw_tx(ps_c, cache_c, ttable, mb1)
    ps_c, cache_c, _ = rt_c.run_grw_tx(ps_c, cache_c, ttable, mb2)
    res_c1, _, _ = rt_c.run_gr_tx_batch(ps_c, cache_c, ttable, plan, roots)

    # --- chaos: owner 0 (the only owner) crashes at batch 1
    rt = ShardedTxnRuntime(espec, flat_mesh(1), route_cap_factor=None,
                           blk_slack=1.0)
    ps = rt.partition_store(store)
    cache = rt.empty_cache()
    j = WriteBehindJournal(str(tmp_path / "j"), rt.n)
    j.checkpoint(ps, e_blk_cap=rt.pspec.e_blk_cap,
                 recent_blk_cap=rt.pspec.recent_blk_cap, store_version=0)
    ctl = FailoverController(
        rt, j, ttable, plan=ShardFaultPlan(crash={0: 1}),
        detector=FailureDetector(n=1, fail_threshold=2),
    )

    # batch 0: healthy — same bytes as control
    ctl.probe(0)
    res0, d0, _, m0 = ctl.run_gr(ps, cache, plan, roots, 0)
    assert np.array_equal(res0, res_c0) and not d0.any()

    # batch 1: crash lands; one probe is below the threshold -> the gap
    ctl.probe(1)
    with pytest.raises(NodeFailure):
        ctl.run_gr(ps, cache, plan, roots, 1)
    assert ctl.failed_batches == 1

    # batch 2: detector trips -> degraded serving; on one shard EVERY miss
    # defers (nothing is cached: cold cache), no miss records escape
    ctl.probe(2)
    res2, d2, misses2, m2 = ctl.run_gr(ps, cache, plan, roots, 2)
    assert ctl.detector.down() == frozenset({0})
    assert d2.all() and m2["deferred_rows"] == len(roots)
    assert not misses2  # CP must not populate from lost blocks
    assert m2["hits"] == 0

    # degraded writes: both commits queue durably, the store doesn't move
    v_before = int(jax.device_get(ps.version))
    ps, cache, w1 = ctl.run_grw(ps, cache, mb1)
    ps, cache, w2 = ctl.run_grw(ps, cache, mb2)
    assert w1["queued"] == 1 and w2["queued"] == 1
    assert int(jax.device_get(ps.version)) == v_before
    assert j.metrics()["queued_commits"] == 2

    # recovery-as-migration: replay to the applied watermark, splice, drain
    ps, cache, rinfo = ctl.recover(ps, cache, 0)
    assert rinfo["drained_commits"] == 2
    assert ctl.detector.down() == frozenset()
    assert ctl.plan.crashed_at(99) == frozenset()

    # post-recovery: byte-identical store and results vs control
    for a, b in zip(
        jax.tree_util.tree_leaves(jax.device_get(ps)),
        jax.tree_util.tree_leaves(jax.device_get(ps_c)),
    ):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    ctl.probe(3)  # the revived owner now heartbeats healthy
    res3, d3, _, _ = ctl.run_gr(ps, cache, plan, roots, 3)
    assert not d3.any()
    assert np.array_equal(res3, res_c1)
    fm = ctl.metrics()
    assert fm["detections"] == 1 and fm["recoveries"] == 1


def test_hedged_read_path_masks_straggler(tmp_path):
    """A straggling-but-alive owner never enters degraded mode: the read
    path hedges the full batch against a masked call and the hedge's
    deferred rows are bounded to the straggler's segment (on 1 shard: all
    rows, making the outcome easy to pin)."""
    spec, store, espec, ttable, plan = _one_shard_world()
    roots = np.array([0, 1, 2, 3], np.int32)
    rt = ShardedTxnRuntime(espec, flat_mesh(1), route_cap_factor=None,
                           blk_slack=1.0)
    ps = rt.partition_store(store)
    cache = rt.empty_cache()
    j = WriteBehindJournal(str(tmp_path / "j"), rt.n)
    hedge = HedgedCalls()
    ctl = FailoverController(
        rt, j, ttable, plan=ShardFaultPlan(hang={0: (0, 10, 2.0)}),
        detector=FailureDetector(n=1, fail_threshold=2, straggle_after=1.0),
        hedge=hedge, hedge_after=0.05,
    )
    # warm the compiled step OUTSIDE the race so the hedge deadline
    # measures serving latency, not compile latency
    rt.run_gr_tx_batch(ps, cache, ttable, plan, roots)

    ctl.probe(0)
    assert ctl.detector.straggling() == frozenset({0})
    assert ctl.detector.down() == frozenset()  # alive: nothing is down
    res, deferred, _, m = ctl.run_gr(ps, cache, plan, roots, 0)
    assert m["hedged"] == 1 and hedge.hedge_wins == 1
    assert deferred.all()  # the masked hedge won; its rows are flagged
    assert hedge.hedge_rate == 1.0
