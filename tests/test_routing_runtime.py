"""Routing-tier integration on the 8-device mesh: byte-identity, locality
routing, migration, and replay (the ISSUE acceptance pins).

Two subprocess suites (XLA_FLAGS must create the host devices before jax
initializes — same pattern as test_sharded_runtime):

- **Locality**: an explicit identity table, ``rtable=None``, and an attached
  exception-free ``RoutingTableHost`` are the SAME program and the same
  bytes (``step.jitted._cache_size() == 1`` across all three table inputs);
  split vertices (cache home != storage owner) route to their cache home,
  defer misses back to the storage owner (one compiled-program retry, no
  recompile), populate at the cache home, and serve warm hits there —
  results always equal the single-host engine.
- **Migration**: the live protocol (journal-first MIGRATE, deterministic
  splice, one-epoch table publish at a batch boundary) preserves gR/gRW
  results vs the single-host engine, routes post-migration appends to the
  table owners, keeps the serving step at one compiled trace, and journal
  replay from the pre-migration checkpoint reconstructs the post-migration
  post-commit store byte-for-byte.
"""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PRELUDE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    import numpy as np
    import jax
    from conftest import (
        build_world, enabled_ttable, common_watchlist_plan, TPL_META,
    )
    from repro.core import (
        CacheSpec, EngineSpec, GraphEngine, cache_entries, empty_cache,
        run_grw_tx,
    )
    from repro.core.population import CachePopulator
    from repro.core.runtime import bucket_for
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedTxnRuntime
    from repro.distributed.routing import RoutingTableHost, identity_table
    from repro.graphstore import WriteBehindJournal, make_mutation_batch, replay
    from repro.graphstore.migration import (
        infer_storage_exceptions, migrate_vertex_rows, vertex_row_counts,
    )

    spec, store = build_world()
    cspec = CacheSpec(capacity=1024, probes=8, max_leaves=16, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=32, frontier=32)
    ttable, sc, qp = enabled_ttable()
    mesh = flat_mesh(8)
    plan = common_watchlist_plan()
    roots = np.array([0, 3, 5, 6, 7, 11], np.int32)

    rt = ShardedTxnRuntime(espec, mesh, route_cap_factor=None, blk_slack=1.0)
    pstore = rt.partition_store(store)
    eng = GraphEngine(espec, plan, True, fused=True)
    bucket = max(bucket_for(len(roots)), rt.n)
    step = rt.serve_step(plan, bucket)

    def miss_key(ms):
        return sorted(
            (m.tpl_idx, m.root, tuple(m.params.tolist()), m.read_version)
            for m in ms
        )

    def assert_tree_equal(a, b, tag):
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), tag
    """
)

LOCALITY = PRELUDE + textwrap.dedent(
    """
    cache_h = empty_cache(cspec)
    cache_s = rt.empty_cache()
    res_h, miss_h, met_h = eng.run(store, cache_h, ttable, roots)

    # --- identity: None, an explicit identity table, and an attached
    # exception-free host table are the same program and the same bytes
    runs = {}
    runs["none"] = rt.run_gr_tx_batch(pstore, cache_s, ttable, plan, roots)
    runs["identity"] = rt.run_gr_tx_batch(
        pstore, cache_s, ttable, plan, roots, rtable=identity_table(rt.n)
    )
    rhost = RoutingTableHost(rt.n)
    rt.attach_routing(rhost)
    runs["attached"] = rt.run_gr_tx_batch(pstore, cache_s, ttable, plan, roots)
    for tag, (res, miss, met) in runs.items():
        assert np.array_equal(res, res_h), tag
        assert miss_key(miss) == miss_key(miss_h), tag
        assert met == runs["none"][2], (tag, met)
        assert met["locality_routed"] == 0 and met["locality_retry_rows"] == 0
    # one compiled trace across all three table inputs: the routing table
    # is a traced input of the serving step, never a recompile
    assert step.jitted._cache_size() == 1, step.jitted._cache_size()

    # --- split vertices: cache home re-pointed away from the storage owner
    # (5 and 7 are their own native owners on 8 shards; 0 and 2 are not)
    rhost.set_cache_owner(5, 0)
    rhost.set_cache_owner(7, 2)
    res_c, miss_c, met_c = rt.run_gr_tx_batch(
        pstore, cache_s, ttable, plan, roots
    )
    # cold: routed to the cache home, probe misses defer back to the
    # storage owner through the table's storage view — results identical
    assert np.array_equal(res_c, res_h)
    assert miss_key(miss_c) == miss_key(miss_h)
    assert met_c["locality_routed"] > 0, met_c
    assert met_c["locality_retry_rows"] == 2, met_c
    assert met_c["host_syncs"] == 2, met_c

    # --- CP population lands split roots' entries at their cache home
    pop_h = CachePopulator(espec, TPL_META); pop_h.queue.push(miss_h)
    cache_h = pop_h.drain(store, store, cache_h, ttable)
    pop_s = rt.populator(TPL_META); pop_s.queue.push(miss_c)
    cache_s = pop_s.drain(pstore, pstore, cache_s, ttable)
    assert (pop_h.committed, pop_h.aborted) == (pop_s.committed, pop_s.aborted)
    assert cache_entries(cspec, cache_h) == cache_entries(cspec, cache_s)

    # --- warm: hits serve AT the cache home, no deferral, no retry
    res_w_h, _, met_w_h = eng.run(store, cache_h, ttable, roots)
    res_w, _, met_w = rt.run_gr_tx_batch(pstore, cache_s, ttable, plan, roots)
    assert np.array_equal(res_w, res_w_h)
    assert met_w_h["misses"] == 0 and met_w["misses"] == 0, (met_w_h, met_w)
    assert met_w["hits"] == met_w_h["hits"] and met_w["hits"] > 0
    assert met_w["locality_routed"] > 0, met_w
    assert met_w["locality_retry_rows"] == 0 and met_w["host_syncs"] == 1
    # still the one compiled trace after split-table + storage-view inputs
    assert step.jitted._cache_size() == 1, step.jitted._cache_size()
    print("ROUTING_LOCALITY_OK")
    """
)

MIGRATION = PRELUDE + textwrap.dedent(
    """
    rhost = RoutingTableHost(rt.n)
    rt.attach_routing(rhost)
    root_dir = os.path.join(tempfile.mkdtemp(), "journal")
    j = WriteBehindJournal(root_dir, rt.n)
    j.checkpoint(pstore, e_blk_cap=rt.pspec.e_blk_cap,
                 recent_blk_cap=rt.pspec.recent_blk_cap, store_version=0)

    # --- live migration protocol: journal-first, splice, one-epoch publish
    moves = [(0, 7), (5, 2)]  # native owners 0 and 5 — both real moves
    assert all(int(c) > 0 for c in
               vertex_row_counts(rt.pspec, pstore, [v for v, _ in moves]))
    j.append_migrate(moves, epoch=rhost.epoch + 1)
    pstore = jax.device_put(
        migrate_vertex_rows(rt.pspec, pstore, moves), rt.store_sharding()
    )
    rhost.apply_moves(moves)
    assert infer_storage_exceptions(rt.pspec, pstore) == dict(moves)

    # --- post-migration reads equal the single-host engine; migrated roots
    # are locality-routed (dest != native) but never deferred (cache home
    # follows the rows)
    res_h, miss_h, met_h = eng.run(store, empty_cache(cspec), ttable, roots)
    res_m, miss_m, met_m = rt.run_gr_tx_batch(
        pstore, rt.empty_cache(), ttable, plan, roots
    )
    assert np.array_equal(res_m, res_h)
    assert miss_key(miss_m) == miss_key(miss_h)
    assert met_m["locality_routed"] > 0, met_m
    assert met_m["locality_retry_rows"] == 0 and met_m["host_syncs"] == 1
    assert step.jitted._cache_size() == 1, step.jitted._cache_size()

    # --- gRW after migration: appends to migrated vertices land at their
    # TABLE owners' blocks, and the commit journals through the table
    mb = make_mutation_batch(
        spec, new_edges=[(5, 12, 0, [1]), (0, 11, 0, [0])],
        set_vprops=[(7, 0, 1)], del_edges=[2],
    )
    st_h, ch_h, m_h = run_grw_tx(
        espec, store, empty_cache(cspec), ttable, mb
    )
    ps2, cs2, m_s = rt.run_grw_tx(
        pstore, rt.empty_cache(), ttable, mb, journal=j
    )
    assert m_s["op_overflow"] == 0 and m_s["store_append_overflow"] == 0
    assert m_h["impacted_keys"] == m_s["impacted_keys"]
    # placement still reconstructible from bytes alone: the new rows for
    # the migrated vertices are at the table owners, not the native ones
    assert infer_storage_exceptions(rt.pspec, ps2) == dict(moves)
    res2_h, miss2_h, _ = eng.run(st_h, empty_cache(cspec), ttable, roots)
    res2_s, miss2_s, _ = rt.run_gr_tx_batch(
        ps2, rt.empty_cache(), ttable, plan, roots
    )
    assert np.array_equal(res2_s, res2_h)
    assert miss_key(miss2_s) == miss_key(miss2_h)
    assert step.jitted._cache_size() == 1, step.jitted._cache_size()
    j.flush()

    # --- crash: replay from the PRE-migration checkpoint reconstructs the
    # post-migration post-commit store byte-for-byte (MIGRATE record →
    # same deterministic splice; COMMIT → appends routed through the
    # reconstructed table)
    rt2 = ShardedTxnRuntime(espec, mesh, route_cap_factor=None, blk_slack=1.0)
    j2 = WriteBehindJournal(root_dir, rt2.n)
    ps_r, last, info = replay(j2, rt2, ttable)
    assert info["replayed_migrations"] == 1 and info["replayed_commits"] == 1
    assert_tree_equal(ps_r, ps2, "replayed store diverges from live")
    res3, miss3, _ = rt2.run_gr_tx_batch(
        ps_r, rt2.empty_cache(), ttable, plan, roots
    )
    assert np.array_equal(res3, res2_h)
    assert miss_key(miss3) == miss_key(miss2_h)
    print("ROUTING_MIGRATION_OK")
    """
)


def _run(script, token):
    env = dict(
        os.environ,
        PYTHONPATH=os.pathsep.join(
            [os.path.join(REPO, "src"), os.path.join(REPO, "tests")]
        ),
    )
    out = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=1800,
    )
    assert token in out.stdout, out.stdout + out.stderr


def test_locality_routing_matches_single_host_and_never_recompiles():
    _run(LOCALITY, "ROUTING_LOCALITY_OK")


def test_migration_preserves_results_and_replays_byte_identical():
    _run(MIGRATION, "ROUTING_MIGRATION_OK")
