"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps
with checkpointing + resume, on the synthetic token pipeline.

Defaults are CPU-budget friendly (~100M params, seq 64, batch 4); the loss
must drop monotonically-ish over the run. Pass --steps/--seq/--batch to
scale up on real hardware.

Run:  PYTHONPATH=src python examples/train_lm_100m.py --steps 200
"""

import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_mod
from repro.lm import LMConfig


def lm_100m() -> LMConfig:
    # ~100M params: 2*32768*512 embeddings + 14 layers (d=512, ff=2560)
    return LMConfig(
        name="lm-100m", n_layers=14, d_model=512, n_heads=8, n_kv_heads=4,
        d_ff=2560, vocab=32768, attn_q_chunk=64, attn_k_chunk=64,
        loss_chunk=64, remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/lm100m_ckpt")
    args = ap.parse_args()

    cfg = lm_100m()
    print(f"params: {cfg.param_count():,}")
    import jax

    opt, step = train_mod.build(cfg, 3e-4, args.steps, compress=False)
    key = jax.random.PRNGKey(0)
    from repro.lm import init_params

    params = init_params(cfg, key)
    opt_state = opt.init(params)
    data = train_mod.synthetic_batches(cfg.vocab, args.batch, args.seq)
    from repro.checkpoint import save_checkpoint
    import time

    losses = []
    t0 = time.time()
    for i in range(args.steps):
        tokens, labels = next(data)
        params, opt_state, m = step(params, opt_state, tokens, labels)
        losses.append(float(m["loss"]))
        if (i + 1) % 20 == 0:
            print(f"step {i+1}: loss={losses[-1]:.4f} "
                  f"({(time.time()-t0)/(i+1)*1e3:.0f} ms/step)")
        if (i + 1) % 100 == 0:
            save_checkpoint(args.ckpt, i + 1, (params, opt_state))
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")
    assert losses[-1] < losses[0], "training must make progress"


if __name__ == "__main__":
    main()
