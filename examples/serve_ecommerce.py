"""End-to-end serving driver (the paper's kind of system is a serving one):
replay a diurnal eCommerce workload against one Graph-QP with the cache and
async population on, interleaving gRW-Txs, and report hit rates + latency
percentiles per phase of day.

Run:  PYTHONPATH=src python examples/serve_ecommerce.py [--ops 200]
"""

import argparse
import time

import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.workload import MIXES, TPL_META, WRITE_MIX, build_world, make_write, query_plans
from repro.core import GraphEngine, build_grw_step, cache_stats, empty_cache
from repro.core.population import CachePopulator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ops", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    world = build_world(seed=args.seed)
    cache = empty_cache(world.espec.cache)
    pop = CachePopulator(world.espec, TPL_META)
    grw = build_grw_step(world.espec)
    plans = query_plans()
    engines = {n: GraphEngine(world.espec, p, use_cache=True) for (n, p, _, _, _) in plans}
    weights = np.array([w for (_, _, _, w, _) in plans])
    weights /= weights.sum()
    store = world.store

    kinds, wweights = zip(*WRITE_MIX)
    wweights = np.array(wweights) / sum(wweights)

    for mix_name, mix in MIXES.items():
        lat = []
        hits0 = pop.committed
        t_mix = time.perf_counter()
        world.rng = np.random.default_rng(args.seed + hash(mix_name) % 1000)
        h = m = 0
        for i in range(args.ops):
            if world.rng.random() < mix["read_frac"]:
                j = int(world.rng.choice(len(plans), p=weights))
                name, plan, label, _, _ = plans[j]
                lo, hi = world.vertex_range(label)
                roots = np.array([world.zipf_pick(lo, hi) for _ in range(8)], np.int32)
                t0 = time.perf_counter()
                _, misses, mm = engines[name].run(store, cache, world.ttable, roots)
                lat.append((time.perf_counter() - t0) / 8)
                pop.queue.push(misses)
                h += mm["hits"]; m += mm["misses"]
            else:
                wk = kinds[int(world.rng.choice(len(kinds), p=wweights))]
                _, mb = make_write(world, wk)
                if mb is not None:
                    store, cache, _, _ = grw(store, cache, world.ttable, mb)
            if i % 10 == 9:
                cache = pop.drain(store, store, cache, world.ttable, 256)
        lat_ms = np.array(lat) * 1e3
        print(
            f"{mix_name:6} ops={args.ops} "
            f"p50={np.percentile(lat_ms,50):6.2f}ms p95={np.percentile(lat_ms,95):6.2f}ms "
            f"p99={np.percentile(lat_ms,99):6.2f}ms hit_rate={h/max(h+m,1):.2%} "
            f"({time.perf_counter()-t_mix:.1f}s)"
        )
    print("cache:", cache_stats(cache))
    print("population: committed=%d aborted=%d discarded=%d" % (
        pop.committed, pop.aborted, pop.queue.discarded))


if __name__ == "__main__":
    main()
