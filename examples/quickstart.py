"""Quickstart: the paper's Figure 1 end to end in ~60 lines.

Builds the watch-list/listing world, registers the SQ1 template through the
Service Coordinator's two-phase workflow, then demonstrates:
  miss -> asynchronous population -> hit -> gRW-Tx write-around -> consistent.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    ANY_LABEL, DIR_OUT, FINAL_IDS, OP_EQ, WILDCARD,
    CacheSpec, EngineSpec, GraphEngine, Hop, QueryPlan, Template,
    cache_stats, empty_cache, make_pred, make_template_table,
)
from repro.core.lifecycle import GraphQP, ServiceCoordinator
from repro.core.population import CachePopulator
from repro.core.engine import run_grw_tx
from repro.graphstore import StoreSpec, ingest, make_mutation_batch
from repro.utils import PROP_MISSING

M = int(PROP_MISSING)
WATCHLIST, LISTING, INCLUDES = 0, 1, 0
STATUS, ISACTIVE = 0, 0

# --- a tiny graph: watch-list 0 includes listings 1..5 ----------------------
spec = StoreSpec(v_cap=64, e_cap=256, n_vprops=1, n_eprops=1, recent_cap=32)
vlabels = [WATCHLIST] + [LISTING] * 5
vprops = np.full((6, 1), M)
vprops[1:, STATUS] = [0, 0, 1, 0, 1]          # listings 1,2,4 are available
eprops = [[1], [1], [1], [0], [0]]            # edges to 1,2,3 are active
store = ingest(spec, vlabels, vprops, [0] * 5, [1, 2, 3, 4, 5], [INCLUDES] * 5,
               np.array(eprops))

# --- register + enable the SQ1 template (two-phase, all QPs) ----------------
SQ1 = Template("SQ1", DIR_OUT, (WATCHLIST, []),
               (ANY_LABEL, [(ISACTIVE, OP_EQ, WILDCARD)]),
               (LISTING, [(STATUS, OP_EQ, WILDCARD)]), edge_label=INCLUDES)
ttable = make_template_table([SQ1])
qp = GraphQP("qp0")
sc = ServiceCoordinator([qp])
sc.register(0)
sc.enable(0)
ttable = qp.ttable_masks(ttable, 1)
print("template SQ1 state:", sc.states[0].value, "| safety:", sc.check_safety())

# --- the Figure 1 gR-Tx ------------------------------------------------------
espec = EngineSpec(store=spec, cache=CacheSpec(capacity=256, max_leaves=8), max_deg=16, frontier=8)
fig1 = QueryPlan(hops=(Hop(
    DIR_OUT, INCLUDES, make_pred(WATCHLIST, []),
    make_pred(ANY_LABEL, [(ISACTIVE, OP_EQ, WILDCARD)]),
    make_pred(LISTING, [(STATUS, OP_EQ, WILDCARD)]),
    tpl_idx=0, params=np.array([1, M, M, 0, M, M], np.int32)),), final=FINAL_IDS)

cache = empty_cache(espec.cache)
engine = GraphEngine(espec, fig1, use_cache=True)
pop = CachePopulator(espec, {0: (DIR_OUT, INCLUDES)})

res, misses, m1 = engine.run(store, cache, ttable, np.array([0], np.int32))
print(f"1) miss:  result={sorted(res[0][res[0]>=0].tolist())}  "
      f"phases={m1['phases']} (the paper's n+2 storage requests)")

pop.queue.push(misses)
cache = pop.drain(store, store, cache, ttable)       # async CP transaction
print(f"2) populated asynchronously: {cache_stats(cache)['inserts']} entry")

res, _, m2 = engine.run(store, cache, ttable, np.array([0], np.int32))
print(f"3) hit:   result={sorted(res[0][res[0]>=0].tolist())}  "
      f"phases={m2['phases']} (n+2 -> 2)")

# --- a gRW-Tx flips listing 2's Status; write-around deletes the entry ------
mb = make_mutation_batch(spec, set_vprops=[(2, STATUS, 1)])
store, cache, mw = run_grw_tx(espec, store, cache, ttable, mb)
print(f"4) gRW-Tx impacted {mw['impacted_keys']} cache key(s)")

res, misses, m3 = engine.run(store, cache, ttable, np.array([0], np.int32))
print(f"5) fresh: result={sorted(res[0][res[0]>=0].tolist())}  "
      f"hits={m3['hits']} (stale entry was invalidated -> recomputed)")
assert sorted(res[0][res[0] >= 0].tolist()) == [1]
print("strong consistency held.")
