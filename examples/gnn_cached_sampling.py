"""The paper's technique applied to GNN training (DESIGN.md §4):

neighbor lists for fanout sampling are one-hop sub-query results — cache
them in the core cache over a *live* graphstore, populate asynchronously,
and let gRW-Txs write-around-invalidate so sampling stays consistent while
the graph mutates under training.

Run:  PYTHONPATH=src python examples/gnn_cached_sampling.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    ANY_LABEL, DIR_OUT, CacheSpec, EngineSpec, Template, empty_cache,
    make_pred, make_template_table, cache_stats,
)
from repro.core.engine import run_grw_tx
from repro.core.lifecycle import GraphQP, ServiceCoordinator
from repro.core.population import CachePopulator
from repro.gnn import GNNConfig, CachedNeighborSampler
from repro.gnn.models import init_params, train_step
from repro.graphstore import StoreSpec, ingest, make_mutation_batch
from repro.optim import adamw
from repro.utils import PROP_MISSING

M = int(PROP_MISSING)
rng = np.random.default_rng(0)

# --- a mutable graph in the transactional store -----------------------------
N, E_INIT, D_FEAT = 256, 1024, 16
spec = StoreSpec(v_cap=512, e_cap=4096, n_vprops=1, n_eprops=1, recent_cap=256)
src = rng.integers(0, N, E_INIT)
dst = rng.integers(0, N, E_INIT)
store = ingest(
    spec, [0] * N, np.full((N, 1), M), src, dst, [0] * E_INIT,
    np.full((E_INIT, 1), M),
)
feats = rng.normal(size=(N, D_FEAT)).astype(np.float32)
labels = rng.integers(0, 4, N).astype(np.int32)

# --- the "all out-neighbors" template (empty predicates) --------------------
NBR = Template("NBR", DIR_OUT, (ANY_LABEL, []), (ANY_LABEL, []), (ANY_LABEL, []))
ttable = make_template_table([NBR])
qp = GraphQP("qp0"); sc = ServiceCoordinator([qp]); sc.register(0); sc.enable(0)
ttable = qp.ttable_masks(ttable, 1)

espec = EngineSpec(
    store=spec, cache=CacheSpec(capacity=2048, max_leaves=32, max_chunks=2),
    max_deg=64, frontier=32,
)
cache = empty_cache(espec.cache)
pop = CachePopulator(espec, {0: (DIR_OUT, -1)})
sampler = CachedNeighborSampler(
    espec, store, cache, ttable, tpl_idx=0, populator=pop, fanouts=(5, 3),
)

cfg = GNNConfig(name="sage-demo", kind="pna", n_layers=2, d_hidden=16, d_in=D_FEAT, n_classes=4)
params = init_params(cfg, jax.random.PRNGKey(0))
opt = adamw(1e-3)
state = opt.init(params)
step = jax.jit(train_step(cfg, opt))

for epoch in range(4):
    seeds = rng.choice(N, size=16, replace=False)
    g = sampler.sample_store(seeds, feats, labels)
    params, state, m = step(params, state, g)
    sampler.populate()  # async CP drain between steps
    # a gRW-Tx mutates the graph: add + delete edges -> write-around
    mb = make_mutation_batch(
        spec,
        new_edges=[(int(rng.integers(0, N)), int(rng.integers(0, N)), 0, [M])],
        del_edges=[int(rng.integers(0, E_INIT))],
    )
    sampler.store, sampler.cache, mw = run_grw_tx(
        espec, sampler.store, sampler.cache, ttable, mb
    )
    print(
        f"epoch {epoch}: loss={float(m['loss']):.3f} "
        f"sampler hits={sampler.hits} misses={sampler.misses} "
        f"invalidated={mw['impacted_keys']}"
    )
print("cache:", cache_stats(sampler.cache))
assert sampler.hits > 0, "later epochs should hit the neighbor-list cache"
print("cached neighbor sampling stayed consistent under graph mutations.")
