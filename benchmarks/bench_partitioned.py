"""Partitioned dual-CSR storage tier vs replicated snapshots
(BENCH_partitioned_store.json).

Three questions, one warmed eCommerce world on an 8-virtual-device CPU mesh:

- **Memory**: per-shard store bytes of the partitioned tier (owner-local
  out/in edge blocks + replicated vertex-attribute tier) vs the full
  ``GraphStore`` replica every shard carried before — the O(E/n) vs O(E)
  claim, measured. Identity of results is asserted before anything is timed.
- **Throughput**: gR-Tx batches/sec of the 2-hop common-watchlist plan on
  the partitioned tier vs the replicated tier vs the single-host engine
  (cold + warm cache), and the partitioned gRW commit vs the (compacted)
  single-host commit.
- **Routing**: measured Zipfian route skew (per-owner share of the root
  frontier) and the cap factor it recommends — the source of
  ``DEFAULT_ROUTE_CAP_FACTOR`` — plus the observed overflow count under
  that default (must be 0).

Run via ``benchmarks/run.py --only partitioned_store`` (sets XLA_FLAGS for
the device mesh before jax initializes) or directly:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.bench_partitioned --json out.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

N_SHARDS = 8

if __name__ == "__main__" and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_SHARDS}"
    ).strip()

import numpy as np  # noqa: E402


def main(batch=256, iters=3, seed=7, json_path=None, cap_factor=None):
    import jax

    from benchmarks.workload import (
        TPL_META, build_world, measure_route_skew, query_plans,
    )
    from repro.core import GraphEngine, cache_entries, empty_cache, get_grw_step
    from repro.core.population import CachePopulator
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import (
        DEFAULT_ROUTE_CAP_FACTOR, ShardedTxnRuntime,
    )
    from repro.graphstore import make_mutation_batch

    n_dev = len(jax.devices())
    assert n_dev >= N_SHARDS, (
        f"need {N_SHARDS} devices (XLA_FLAGS=--xla_force_host_platform_"
        f"device_count={N_SHARDS}), got {n_dev}"
    )
    world = build_world(seed=seed, cache_capacity=1 << 15)
    espec, store, ttable = world.espec, world.store, world.ttable
    mesh = flat_mesh(N_SHARDS)
    # 1.25x uniform-share block capacity: measured ownership balance under
    # interleaved ownership leaves per-shard occupancy within ~5% of
    # uniform, so 25% headroom is generous (overflow asserted 0 below)
    # reduced-size smoke runs need proportionally more per-peer headroom:
    # the Zipfian hot share of a 64-row batch is relatively larger than of
    # a 256-row batch, so CI passes --cap-factor auto — caps derived from
    # the telemetry-measured per-owner frontier skew, with a no-drop
    # overflow-retry fallback (route_cap_retries) instead of hand-tuning
    rcf = ("auto" if cap_factor == "auto"
           else tuple(cap_factor) if cap_factor else DEFAULT_ROUTE_CAP_FACTOR)
    rt_p = ShardedTxnRuntime(espec, mesh, blk_slack=1.25, route_cap_factor=rcf)
    rt_r = ShardedTxnRuntime(
        espec, mesh, store_tier="replicated", route_cap_factor=rcf
    )
    pstore = rt_p.partition_store(store)

    # ---- memory: per-shard bytes vs the replicated snapshot -------------
    mem = rt_p.store_bytes()
    print(
        f"store bytes/shard: partitioned {mem['per_shard_bytes']/2**20:.2f} "
        f"MiB vs replicated {mem['replicated_per_shard_bytes']/2**20:.2f} "
        f"MiB  (ratio {mem['ratio']:.3f}, ideal 1/n {mem['ideal_ratio']:.3f})"
    )

    # ---- correctness gate before timing ---------------------------------
    name, plan, label, _, _ = query_plans()[1]  # q_common: 2-hop IN->OUT
    eng = GraphEngine(espec, plan, True)
    lo, hi = world.vertex_range(label)
    roots = np.array([world.zipf_pick(lo, hi) for _ in range(batch)], np.int32)
    cache_h = empty_cache(espec.cache)
    cache_p, cache_r = rt_p.empty_cache(), rt_r.empty_cache()
    res_h, miss_h, met_h = eng.run(store, cache_h, ttable, roots)
    res_p, miss_p, met_p = rt_p.run_gr_tx_batch(pstore, cache_p, ttable, plan, roots)
    res_r, miss_r, met_r = rt_r.run_gr_tx_batch(store, cache_r, ttable, plan, roots)
    assert np.array_equal(res_h, res_p) and np.array_equal(res_h, res_r)
    assert met_p["route_overflow"] == 0 and met_r["route_overflow"] == 0
    overflow_seen = met_p["route_overflow"]

    # warm all three caches from the same miss stream
    pops = [
        (CachePopulator(espec, TPL_META), store, store, "host"),
        (rt_p.populator(TPL_META), pstore, pstore, "partitioned"),
        (rt_r.populator(TPL_META), store, store, "replicated"),
    ]
    caches = {"host": cache_h, "partitioned": cache_p, "replicated": cache_r}
    for (pop, se, sc, tag), miss in zip(pops, (miss_h, miss_p, miss_r)):
        pop.queue.push(miss)
        caches[tag] = pop.drain(se, sc, caches[tag], ttable, 1024)
    assert cache_entries(espec.cache, caches["host"]) == cache_entries(
        espec.cache, caches["partitioned"]
    )

    # ---- gR throughput (warm cache, steady state) -----------------------
    def time_reads(fn):
        fn()  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn()
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters

    rng = np.random.default_rng(seed + 1)
    jroots = np.array([world.zipf_pick(lo, hi) for _ in range(batch)], np.int32)
    reads = {}
    step_p = rt_p.serve_step(plan, batch)
    step_r = rt_r.serve_step(plan, batch)
    from repro.core.runtime import pad_roots
    proots, bvalid = pad_roots(jroots, batch)
    import jax.numpy as jnp

    jp, jb = jnp.asarray(proots), jnp.asarray(bvalid)
    reads["host"] = time_reads(
        lambda: eng._fused_fn(store, caches["host"], ttable, jp, jb)
    )
    reads["partitioned"] = time_reads(
        lambda: step_p(pstore, caches["partitioned"], ttable, jp, jb)
    )
    reads["replicated"] = time_reads(
        lambda: step_r(store, caches["replicated"], ttable, jp, jb)
    )
    for k, dt in reads.items():
        print(f"gR {k}: {dt*1e3:.1f} ms/batch ({batch/dt:.0f} gR-Tx/s)")
    # observed overflow under the measured default caps on the warm Zipfian
    # batch (the timed loops above run the same program; this reads back
    # its route_overflow metric instead of assuming it)
    _, _, met_warm = rt_p.run_gr_tx_batch(
        pstore, caches["partitioned"], ttable, plan, jroots
    )
    overflow_seen += met_warm["route_overflow"]

    # ---- gRW commit: partitioned sharded vs compacted single host -------
    l0, l1 = world.vertex_range(1)
    svs = [(int(rng.integers(l0, l1)), 0, int(rng.integers(0, 2)))
           for _ in range(192)]
    dels = [int(e) for e in rng.choice(world.includes_eids, 32, replace=False)]
    mb = make_mutation_batch(
        world.spec, set_vprops=svs, del_edges=dels,
        caps=(8, 32, 32, 8, 192, 32),
    )
    host_grw = get_grw_step(espec)
    part_grw = rt_p.grw_step()
    out_h = host_grw(store, caches["host"], ttable, mb)
    out_p = part_grw(pstore, caches["partitioned"], ttable, mb)
    jax.block_until_ready((out_h, out_p))
    assert int(out_h[3]) == 0 and int(out_p[3]) == 0
    assert cache_entries(espec.cache, out_h[1]) == cache_entries(
        espec.cache, out_p[1]
    ), "gRW cache post-states diverged"
    writes = {}
    for tag, fn, st, cc in (
        ("host", host_grw, store, caches["host"]),
        ("partitioned", part_grw, pstore, caches["partitioned"]),
    ):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(st, cc, ttable, mb)
        jax.block_until_ready(out)
        writes[tag] = (time.perf_counter() - t0) / iters
        print(f"gRW {tag}: {writes[tag]*1e3:.1f} ms/commit")

    # ---- measured route skew (the DEFAULT_ROUTE_CAP_FACTOR source) ------
    skew = measure_route_skew(world, n_shards=N_SHARDS, batch=batch)
    print(f"route skew: {skew}")
    if rcf != "auto":
        assert skew["recommended_cap_factor"] <= max(rcf), skew
        assert all(
            r <= f
            for r, f in zip(
                skew["per_hop_recommended"],
                list(rcf) + [rcf[-1]] * len(skew["per_hop_recommended"]),
            )
        ), skew

    out = dict(
        n_shards=N_SHARDS, batch=batch,
        store_bytes=mem,
        gr_ms_per_batch={k: round(v * 1e3, 2) for k, v in reads.items()},
        gr_speedup_vs_replicated=round(reads["replicated"] / reads["partitioned"], 2),
        grw_ms_per_commit={k: round(v * 1e3, 2) for k, v in writes.items()},
        route_skew=skew,
        # measured per-hop factors: hop 1 routes Zipfian query roots, hops
        # >= 2 route leaf-derived frontiers (structural, flatter) — the
        # tuple ShardedTxnRuntime(route_cap_factor=...) accepts
        per_hop_route_cap_factors=skew["per_hop_recommended"],
        default_route_cap_factor=DEFAULT_ROUTE_CAP_FACTOR,
        route_cap_factor="auto" if rcf == "auto" else list(rcf),
        route_cap_retries=rt_p.route_cap_retries + rt_r.route_cap_retries,
        route_overflow_observed=overflow_seen,
        results_identical=True,
    )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--batch", type=int, default=256,
                    help="global gR batch rows (reduced for CI smoke runs)")
    ap.add_argument("--cap-factor", default=None,
                    help="per-hop route cap factors: comma-separated ints "
                         "(e.g. '4,4'), or 'auto' to derive them from the "
                         "telemetry-measured per-owner frontier skew with "
                         "overflow-retry fallback (default: "
                         "DEFAULT_ROUTE_CAP_FACTOR)")
    args = ap.parse_args()
    cf = ("auto" if args.cap_factor == "auto"
          else tuple(int(x) for x in args.cap_factor.split(","))
          if args.cap_factor else None)
    main(batch=args.batch, iters=args.iters, json_path=args.json,
         cap_factor=cf)
