"""Sharded vs single-host gRW-Tx commit throughput (BENCH_grw_invalidation.json).

Measures mutations/sec of the gRW-Tx write step — apply the mutation batch +
identify and delete the impacted cache entries — on the same warmed world:

- ``host``:    the single-host jitted commit (``get_grw_step``). Since the
  op-stream-compaction backport this baseline derives the impacted keys as
  tensor streams and applies only the compacted real ops (it used to probe
  the cache for every masked lane of every emission), so the sharded
  speedup below is measured against the *fixed* baseline.
- ``sharded``: ``ShardedTxnRuntime.grw_step`` on the replicated-snapshot
  store tier of a virtual CPU device mesh — phase A round-robins the
  batch's change sections across shards and derives a compacted
  impacted-key op stream, phase B routes each op to the shard owning its
  root and applies it against the local cache shard. (The partitioned
  storage tier's commit is benchmarked in bench_partitioned.py.)

Both post-states are asserted logically identical before timing. Run via
``benchmarks/run.py --only grw_invalidation`` (which sets XLA_FLAGS for the
device mesh before jax initializes) or directly:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.bench_grw --json out.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

N_SHARDS = 8

if __name__ == "__main__" and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_SHARDS}"
    ).strip()

import numpy as np  # noqa: E402


def _warm(world, rt, n_rounds=30, batch=16):
    """Warm the single-host cache and the sharded cache from the *same*
    miss stream, so the two write steps start from identical entries."""
    from benchmarks.workload import TPL_META, query_plans
    from repro.core import GraphEngine, empty_cache
    from repro.core.population import CachePopulator

    cache_h = empty_cache(world.espec.cache)
    cache_s = rt.empty_cache()
    pop_h = CachePopulator(world.espec, TPL_META)
    pop_s = rt.populator(TPL_META)
    plans = query_plans()
    engines = {n: GraphEngine(world.espec, p, True) for (n, p, _, _, _) in plans}
    for _ in range(n_rounds):
        name, plan, label, w, cls = plans[int(world.rng.integers(0, len(plans)))]
        lo, hi = world.vertex_range(label)
        roots = np.array([world.zipf_pick(lo, hi) for _ in range(batch)], np.int32)
        _, misses, _ = engines[name].run(world.store, cache_h, world.ttable, roots)
        pop_h.queue.push(misses)
        pop_s.queue.push(misses)
        cache_h = pop_h.drain(world.store, world.store, cache_h, world.ttable, 512)
        cache_s = pop_s.drain(world.store, world.store, cache_s, world.ttable, 512)
    return cache_h, cache_s


def main(batch_sv=256, batch_de=32, iters=6, seed=7, json_path=None):
    import jax

    from benchmarks.workload import build_world
    from repro.core import cache_entries, get_grw_step
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedTxnRuntime
    from repro.graphstore import make_mutation_batch

    n_dev = len(jax.devices())
    assert n_dev >= N_SHARDS, (
        f"need {N_SHARDS} devices (XLA_FLAGS=--xla_force_host_platform_"
        f"device_count={N_SHARDS}), got {n_dev}"
    )
    world = build_world(seed=seed, cache_capacity=1 << 15)
    espec, store, ttable = world.espec, world.store, world.ttable
    mesh = flat_mesh(N_SHARDS)
    rt = ShardedTxnRuntime(
        espec, mesh, store_tier="replicated", ops_cap=4096, sweep_cap=512,
        ops_route_cap=2048,
    )
    cache_h, cache_s = _warm(world, rt)
    occupancy = len(cache_entries(espec.cache, cache_h))
    assert cache_entries(espec.cache, cache_h) == cache_entries(espec.cache, cache_s)

    # the measured commit: listing-Status writes (Algorithm 2's expensive
    # DeleteKeysForLeaf reverse traversals) + includes-edge deletes
    rng = np.random.default_rng(seed)
    l0, l1 = world.vertex_range(1)
    svs = [(int(rng.integers(l0, l1)), 0, int(rng.integers(0, 2)))
           for _ in range(batch_sv)]
    dels = [int(e) for e in rng.choice(world.includes_eids, batch_de, replace=False)]
    mb = make_mutation_batch(
        world.spec, set_vprops=svs, del_edges=dels,
        caps=(8, 32, max(32, batch_de), 8, max(32, batch_sv), 32),
    )
    n_muts = batch_sv + batch_de

    host_step = get_grw_step(espec)
    shard_step = rt.grw_step()

    # compile + correctness: identical store, logically identical cache
    out_h = host_step(store, cache_h, ttable, mb)
    out_s = shard_step(store, cache_s, ttable, mb)
    jax.block_until_ready((out_h, out_s))
    assert int(out_h[3]) == 0, f"host op-stream overflow: {int(out_h[3])}"
    assert int(out_s[3]) == 0, f"op-stream overflow: {int(out_s[3])}"
    for f in out_h[0]._fields:
        assert np.array_equal(
            np.asarray(getattr(out_h[0], f)), np.asarray(getattr(out_s[0], f))
        ), f"store field {f} diverged"
    assert cache_entries(espec.cache, out_h[1]) == cache_entries(espec.cache, out_s[1]), (
        "cache post-states diverged"
    )

    res = {}
    for name, fn, cc in (("host", host_step, cache_h), ("sharded", shard_step, cache_s)):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(store, cc, ttable, mb)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / iters
        res[name] = dict(ms_per_commit=round(dt * 1e3, 1),
                         mutations_per_s=round(n_muts / dt, 1))
        print(f"{name}: {dt * 1e3:.1f} ms/commit, {n_muts / dt:.0f} mutations/s")

    speedup = res["sharded"]["mutations_per_s"] / res["host"]["mutations_per_s"]
    out = dict(
        batch_mutations=n_muts, n_shards=N_SHARDS,
        cache_capacity=espec.cache.capacity, cache_occupancy=occupancy,
        impacted_keys=int(out_s[2]), post_states_equal=True,
        host=res["host"], sharded=res["sharded"],
        speedup=round(speedup, 2),
    )
    print(f"speedup: {speedup:.2f}x")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    ap.add_argument("--iters", type=int, default=6)
    args = ap.parse_args()
    main(iters=args.iters, json_path=args.json)
