"""Live shard failover under sustained traffic (BENCH_failover.json).

Chaos harness for the failure-resilience layer: stream identical gR/gRW
traffic through the 8-shard partitioned runtime twice —

- **control** — no faults; the uninterrupted run that defines correct
  results and the correct final store.
- **chaos** — one owner shard's storage is lost mid-traffic
  (``ShardFaultPlan``). The serve loop keeps answering: until the
  ``FailureDetector`` trips, batches needing the dead owner raise
  ``NodeFailure`` (the unavailability window, bounded by the detector's
  ``fail_threshold``); after detection, gR executes with the dead owner's
  miss segments masked (rows flagged ``deferred``) and every gRW commit
  queues durably in the journal. Recovery-as-migration then rebuilds the
  dead owner from the incremental-checkpoint chain + journal replay,
  splices its blocks into the live store via the geid index, and drains
  the queued commits in journal order.

Measured: the unavailability window (failed batches + wall seconds from
fault injection to the first completed degraded batch), deferred-row
fraction across the degraded window, degraded-mode p50/p95/p99 batch
latency vs healthy, and recovery time. Asserted:

- degraded masking is surgical — non-deferred rows of every degraded
  batch are byte-identical to an unmasked execution on the same frozen
  state (gR is pure, so this isolates the mask's effect);
- the detection gap is bounded by ``fail_threshold`` batches;
- post-recovery gR results are byte-identical to the control run's
  (caches may diverge in hit/miss pattern, never in result bytes — the
  invalidation invariant);
- the final store is byte-identical to the uninterrupted run's: queueing
  commits during the outage and draining them in journal order is the
  same fold as applying them live.

Run via ``benchmarks/run.py --only failover`` or directly:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.bench_failover --json out.json
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

N_SHARDS = 8

if __name__ == "__main__" and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_SHARDS}"
    ).strip()

import numpy as np  # noqa: E402

GR_BATCH = 256
EDGES_PER_BATCH = 32
CAPS = (8, EDGES_PER_BATCH, 8, 8, 8, 8)
N_BATCHES = 24
WRITE_EVERY = 2      # a gRW commit rides every 2nd batch
CRASH_SHARD = 3
CRASH_BATCH = 8
RECOVER_AFTER = 4    # degraded batches before recovery runs
CKPT_EVERY = 3       # incremental checkpoint every N applied commits
FAIL_THRESHOLD = 2


def _make_traffic(world, rng):
    """Pre-generate the whole run's traffic so control and chaos consume
    byte-identical inputs."""
    from benchmarks.workload import query_plans

    from repro.graphstore import make_mutation_batch

    _, plan, label, _, _ = query_plans()[0]
    lo, hi = world.vertex_range(label)
    w0, w1 = world.vertex_range(0)
    # pre-crash batches draw from the lower 2/3 of the root space; from the
    # crash batch on, the full range opens up — so the degraded window sees
    # first-touch roots the cache cannot have: those owned by the dead
    # shard defer, the rest serve as surviving-owner misses (this is what
    # makes the deferred-row fraction land strictly inside (0, 1))
    mid = lo + max(1, ((hi - lo) * 2) // 3)
    roots = [rng.integers(lo, mid if b < CRASH_BATCH else hi,
                          GR_BATCH).astype(np.int32)
             for b in range(N_BATCHES)]
    writes = {}
    for b in range(N_BATCHES):
        if (b + 1) % WRITE_EVERY == 0:
            ne = [
                (world.zipf_pick(w0, w1), int(rng.integers(lo, hi)), 0,
                 [int(rng.integers(0, 2))])
                for _ in range(EDGES_PER_BATCH)
            ]
            writes[b] = make_mutation_batch(world.spec, new_edges=ne, caps=CAPS)
    return plan, roots, writes


def _run(tag, world, traffic, e_blk_cap, *, chaos: bool):
    import jax

    from repro.distributed import flat_mesh
    from repro.distributed.failover import FailoverController
    from repro.distributed.fault import (
        FailureDetector, NodeFailure, ShardFaultPlan,
    )
    from repro.distributed.graph_serve import (
        ShardedMissDrain, ShardedTxnRuntime,
    )
    from repro.graphstore import WriteBehindJournal

    espec, store, ttable = world.espec, world.store, world.ttable
    plan, roots_seq, writes = traffic
    rt = ShardedTxnRuntime(
        espec, flat_mesh(N_SHARDS), route_cap_factor=None,
        e_blk_cap=e_blk_cap,
    )
    pstore = rt.partition_store(store)
    cache = rt.empty_cache()
    journal = WriteBehindJournal(
        os.path.join(tempfile.mkdtemp(prefix=f"bench-failover-{tag}-"), "j"),
        rt.n,
    )
    journal.checkpoint(
        pstore, e_blk_cap=rt.pspec.e_blk_cap,
        recent_blk_cap=rt.pspec.recent_blk_cap, store_version=0,
    )
    journal.start()
    ctl = FailoverController(
        rt, journal, ttable,
        plan=ShardFaultPlan(crash={CRASH_SHARD: CRASH_BATCH}) if chaos else None,
        detector=FailureDetector(n=rt.n, fail_threshold=FAIL_THRESHOLD),
    )
    # per-owner CP drain: populating the cache is what lets hits keep
    # serving during the outage (hits never touch the dead owner's storage)
    tpl_meta = {0: (plan.hops[0].direction, plan.hops[0].edge_label)}
    drain_q = ShardedMissDrain(rt, tpl_meta)

    # warm the compiled steps on discarded calls (the masked/degraded call
    # is the SAME program — `down` is data, not a static arg — so one warm
    # call covers healthy and degraded serving alike)
    rt.run_gr_tx_batch(pstore, cache, ttable, plan, roots_seq[0])
    rt.run_grw_tx(pstore, cache, ttable, next(iter(writes.values())))

    results, lat, phase = {}, {}, {}
    commits = drained = 0
    unavailable = 0
    deferred_rows = 0
    degraded_rows = 0
    degraded_hits = 0
    fault_t0 = first_degraded_t = None
    recovery = {}
    for b, roots in enumerate(roots_seq):
        if chaos and b == CRASH_BATCH:
            fault_t0 = time.perf_counter()
        ctl.probe(b)
        down_now = bool(ctl.detector.down())
        t0 = time.perf_counter()
        try:
            res, deferred, misses, m = ctl.run_gr(pstore, cache, plan, roots, b)
        except NodeFailure:
            unavailable += 1
            phase[b] = "unavailable"
            continue
        lat[b] = time.perf_counter() - t0
        results[b] = np.asarray(res).copy()
        phase[b] = "degraded" if down_now else "healthy"
        if down_now:
            if first_degraded_t is None:
                first_degraded_t = time.perf_counter()
            ndef = int(deferred.sum())
            deferred_rows += ndef
            degraded_rows += len(roots)
            degraded_hits += int(m["hits"])
            # surgical masking: the same frozen state served unmasked must
            # agree on every non-deferred row (gR is pure — no state moved)
            full, _, _ = rt.run_gr_tx_batch(pstore, cache, ttable, plan, roots)
            ok = np.asarray(deferred) | (np.asarray(res) == np.asarray(full)).all(axis=1)
            assert ok.all(), f"masking leaked into non-deferred rows at batch {b}"
        drain_q.push(misses)
        cache = drain_q.drain(pstore, pstore, cache, ttable, 512)
        if b in writes:
            pstore, cache, wm = ctl.run_grw(pstore, cache, writes[b])
            if not wm.get("queued", 0):
                commits += 1
                if commits % CKPT_EVERY == 0:
                    journal.checkpoint_incremental(
                        pstore, e_blk_cap=rt.pspec.e_blk_cap,
                        recent_blk_cap=rt.pspec.recent_blk_cap,
                        store_version=int(jax.device_get(pstore.version)),
                    )
        if (chaos and CRASH_SHARD in ctl.detector.down()
                and b >= CRASH_BATCH + RECOVER_AFTER):
            pstore, cache, rinfo = ctl.recover(pstore, cache, CRASH_SHARD)
            drained = rinfo["drained_commits"]
            commits += drained
            recovery = {
                "recovery_seconds": round(rinfo["recovery_seconds"], 3),
                "replayed_commits": rinfo["replayed_commits"],
                "drained_commits": drained,
                "replayed_to_seq": rinfo["replayed_to_seq"],
            }
    journal.stop(final_flush=True)
    host_store = jax.tree_util.tree_map(
        np.asarray, jax.device_get(pstore)
    )
    healthy_lat = np.asarray(
        [v for b, v in lat.items() if phase[b] == "healthy" and b > 0]
    )
    degraded_lat = np.asarray(
        [v for b, v in lat.items() if phase[b] == "degraded"]
    )

    def pct(a, q):
        return round(float(np.percentile(a, q)) * 1e3, 2) if len(a) else None

    out = {
        "batches": N_BATCHES,
        "unavailable_batches": unavailable,
        "degraded_batches": int(len(degraded_lat)),
        "deferred_rows": deferred_rows,
        "deferred_fraction": (
            round(deferred_rows / degraded_rows, 4) if degraded_rows else 0.0
        ),
        "degraded_window_hits": degraded_hits,
        "commits_applied": commits,
        "healthy_p50_ms": pct(healthy_lat, 50),
        "healthy_p95_ms": pct(healthy_lat, 95),
        "healthy_p99_ms": pct(healthy_lat, 99),
        "degraded_p50_ms": pct(degraded_lat, 50),
        "degraded_p95_ms": pct(degraded_lat, 95),
        "degraded_p99_ms": pct(degraded_lat, 99),
        **recovery,
    }
    if chaos and fault_t0 is not None and first_degraded_t is not None:
        out["unavailability_window_s"] = round(first_degraded_t - fault_t0, 3)
    return out, results, phase, host_store


def main(seed=13, json_path=None):
    import jax

    from benchmarks.workload import build_world

    n_dev = len(jax.devices())
    assert n_dev >= N_SHARDS, (
        f"need {N_SHARDS} devices (XLA_FLAGS=--xla_force_host_platform_"
        f"device_count={N_SHARDS}), got {n_dev}"
    )
    world = build_world(
        n_users=80, n_watchlists=120, n_listings=600, seed=seed,
        cache_capacity=1 << 13,
    )
    store = world.store
    owned = max(
        int(np.bincount(
            np.asarray(store.esrc)[: int(store.e_len)] % N_SHARDS).max()),
        int(np.bincount(
            np.asarray(store.edst)[: int(store.e_len)] % N_SHARDS).max()),
    )
    # headroom for the full append stream landing on one unlucky owner
    n_commits = sum(1 for b in range(N_BATCHES) if (b + 1) % WRITE_EVERY == 0)
    e_blk_cap = int(np.ceil(owned * 1.2)) + n_commits * EDGES_PER_BATCH

    rng = np.random.default_rng(seed)
    traffic = _make_traffic(world, rng)

    control, c_results, _, c_store = _run(
        "control", world, traffic, e_blk_cap, chaos=False
    )
    print(f"[control] {json.dumps(control)}", flush=True)
    chaos, x_results, x_phase, x_store = _run(
        "chaos", world, traffic, e_blk_cap, chaos=True
    )
    print(f"[chaos] {json.dumps(chaos)}", flush=True)

    # --- acceptance: the loop kept answering, inside the detection bound
    assert chaos["unavailable_batches"] <= FAIL_THRESHOLD, chaos
    assert chaos["degraded_batches"] > 0, chaos
    assert 0.0 < chaos["deferred_fraction"] < 1.0, chaos
    assert chaos["drained_commits"] > 0, chaos

    # --- pre-crash and post-recovery results byte-identical to control;
    # deferred-window batches are excluded (control applied the window's
    # commits live, chaos deferred them — that staleness is the documented
    # degraded-mode concession, bounded by the queued-commit count)
    compared = 0
    for b, phase in x_phase.items():
        if phase == "healthy":
            assert np.array_equal(c_results[b], x_results[b]), (
                f"batch {b} ({phase}) diverged from control"
            )
            compared += 1
    assert compared >= N_BATCHES // 2, (compared, x_phase)

    # --- recovered store byte-identical to the uninterrupted run's
    mismatch = [
        i for i, (a, b) in enumerate(zip(
            jax.tree_util.tree_leaves(c_store), jax.tree_util.tree_leaves(x_store)
        )) if not np.array_equal(a, b)
    ]
    assert not mismatch, f"store leaves {mismatch} diverged post-recovery"

    out = {
        "n_shards": N_SHARDS,
        "gr_batch": GR_BATCH,
        "edges_per_commit": EDGES_PER_BATCH,
        "crash_shard": CRASH_SHARD,
        "crash_batch": CRASH_BATCH,
        "fail_threshold": FAIL_THRESHOLD,
        "control": control,
        "chaos": chaos,
        "post_recovery_results_identical": True,
        "final_store_identical": True,
        "healthy_batches_compared": compared,
    }
    print(json.dumps(out, indent=1))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    main(json_path=args.json)
