"""Tables 1/3/4/5/7/8: C±Q± percentile latency under the three workloads.

Method: per-query *service times* are measured wall-clock from the real
jitted engine paths (probe / miss exec / invalidation / population); tail
latency under load is then obtained with a discrete-event M/G/1 simulation
driven by those measured service times — the same mechanism that produces
the paper's load-dependent results (heavy load amplifies the cache's win;
Table 4). CP population runs on its own server (the paper's async CP
threads), never on the query path.

Reported per (config x workload): p50/p95/p99 for cached-template gR-Txs,
the aggregate (non-cached) gR-Tx, and gRW-Txs; hit rates; factors of
improvement vs C-Q-.
"""

from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.workload import (
    MIXES,
    TPL_META,
    WRITE_MIX,
    World,
    build_world,
    make_write,
    query_plans,
)
from repro.core import GraphEngine, build_grw_step, empty_cache, rewrite_plan
from repro.core.population import CachePopulator
from repro.core.rewrite import rewrite_savings
from repro.graphstore import compact

P_LISTING_ID = 1


class Runner:
    """One Graph-QP under a given (cache, rewrite) configuration."""

    def __init__(self, world: World, use_cache: bool, use_rewrite: bool,
                 batch: int = 8):
        self.world = world
        self.use_cache = use_cache
        self.store = world.store
        self.cache = empty_cache(world.espec.cache)
        self.pop = CachePopulator(world.espec, TPL_META)
        self.batch = batch
        plans = query_plans()
        if use_rewrite:
            plans = [
                (n, rewrite_plan(p, unique_props=frozenset({P_LISTING_ID})), lab, w, cls)
                for (n, p, lab, w, cls) in plans
            ]
        self.plans = plans
        self.engines = {
            n: GraphEngine(world.espec, p, use_cache=use_cache)
            for (n, p, _, _, _) in plans
        }
        self.grw = build_grw_step(world.espec)
        self.q_weights = np.array([w for (_, _, _, w, _) in plans])
        self.q_weights /= self.q_weights.sum()
        self.metrics = dict(hits=0, misses=0, cache_reads=0, phases=0)

    def pick_query(self):
        i = int(self.world.rng.choice(len(self.plans), p=self.q_weights))
        return self.plans[i]

    def run_query(self, name, plan, label):
        lo, hi = self.world.vertex_range(label)
        roots = np.array(
            [self.world.zipf_pick(lo, hi) for _ in range(self.batch)], np.int32
        )
        t0 = time.perf_counter()
        _, misses, m = self.engines[name].run(
            self.store, self.cache, self.world.ttable, roots
        )
        dt = (time.perf_counter() - t0) / self.batch
        self.pop.queue.push(misses)
        for k in ("hits", "misses", "cache_reads"):
            self.metrics[k] += m[k]
        self.metrics["phases"] += m["phases"]
        return dt, m

    def run_write(self, kind, mb):
        # C- systems still delete impacted entries (§5.2 third reason)
        if mb is None:
            return 1e-5, 0  # predicate no-op
        t0 = time.perf_counter()
        self.store, self.cache, impacted, ovf = self.grw(
            self.store, self.cache, self.world.ttable, mb
        )
        impacted = int(impacted)
        assert int(ovf) == 0, "maintenance op stream overflowed its cap"
        return time.perf_counter() - t0, impacted

    def run_populate(self, k=64):
        t0 = time.perf_counter()
        self.cache = self.pop.drain(self.store, self.store, self.cache, self.world.ttable, k)
        return time.perf_counter() - t0

    def maybe_compact(self):
        if int(self.store.e_len) - int(self.store.csr_len) > self.world.spec.recent_cap - 64:
            self.store = compact(self.world.spec, self.store)


def mg1_tail(service_times, arrival_rate, seed=0):
    """Single-server FIFO queue: arrival_rate in queries/sec against measured
    service times. Returns sojourn times (queueing + service)."""
    rng = np.random.default_rng(seed)
    n = len(service_times)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate, n))
    done = 0.0
    out = np.empty(n)
    for i, (a, s) in enumerate(zip(arrivals, service_times)):
        start = max(a, done)
        done = start + s
        out[i] = done - a
    return out


def run_config(world, use_cache, use_rewrite, mix, n_ops=400, warm_ops=200,
               seed=0, runner=None, rate=None):
    """Execute the mixed workload; returns per-class sojourn-time arrays.

    Pass ``runner`` to reuse jitted engines (and keep the cache warm) across
    mixes — one Runner per C±Q± configuration, as in the Test system.
    ``rate``: fixed arrival rate (queries/s). The production traffic is the
    SAME for every configuration of a mix — callers measure C-Q- first and
    pass its rate to the other configs (otherwise the queueing model would
    normalize the cache's throughput win away)."""
    world.rng = np.random.default_rng(seed)
    r = runner or Runner(world, use_cache, use_rewrite)
    r.metrics = dict(hits=0, misses=0, cache_reads=0, phases=0)
    read_frac = MIXES[mix]["read_frac"]
    # compile-warm every plan + the write/populate paths OUTSIDE the
    # measurement (jit compile times must not pollute service times)
    if not getattr(r, "_compile_warm", False):
        for name, plan, label, _, _ in r.plans:
            lo, hi = world.vertex_range(label)
            r.run_query(name, plan, label)
        for wk in ("upsert", "last_seen", "del_edges"):
            _, mb = make_write(world, wk)
            if mb is not None:
                r.run_write(wk, mb)
        r.run_populate(256)
        r.metrics = dict(hits=0, misses=0, cache_reads=0, phases=0)
        r._compile_warm = True
    classes = {"cached": [], "agg": [], "write": []}
    kinds, weights = zip(*WRITE_MIX)
    weights = np.array(weights) / sum(weights)
    # warm the cache (paper: two weeks of warm-up -> here: a warm pass,
    # skipped when this runner's cache is already warm from a prior mix)
    if use_cache and not getattr(r, "_warmed", False):
        for _ in range(warm_ops // 10):
            name, plan, label, _, cls = r.pick_query()
            r.run_query(name, plan, label)
            r.run_populate(256)
        r._warmed = True
    service, kinds_log, impacted_log = [], [], []
    for i in range(n_ops):
        if world.rng.random() < read_frac:
            name, plan, label, _, cls = r.pick_query()
            dt, m = r.run_query(name, plan, label)
            service.append(dt)
            kinds_log.append(cls)
        else:
            wk = kinds[int(world.rng.choice(len(kinds), p=weights))]
            dt, impacted = r.run_write(wk, make_write(world, wk)[1])
            service.append(dt)
            kinds_log.append("write")
            impacted_log.append((wk, impacted))
        if use_cache and i % 10 == 9:
            r.run_populate(256)  # async CP server drains off the query path
        r.maybe_compact()
    if rate is None:
        # baseline config: arrival rate making the mix ~80% utilized at the
        # C-Q- service rate (the paper's fixed production traffic level)
        mean_s = np.mean(service)
        rho = 0.8 * MIXES[mix]["load"]
        rate = rho / mean_s if mean_s > 0 else 1.0
    sojourn = mg1_tail(np.array(service), rate, seed)
    for k, s in zip(kinds_log, sojourn):
        classes[k].append(s)
    stats = r.metrics
    hitrate = stats["hits"] / max(stats["cache_reads"], 1)
    return classes, dict(hit_rate=hitrate, impacted=impacted_log, rate=rate)


def pct(a, q):
    return float(np.percentile(np.array(a) * 1e3, q)) if len(a) else float("nan")


def hop_pipeline(batch=512, hops=2, reps=5, seed=0):
    """Old (host-orchestrated) vs fused device hop pipeline on the cached
    eCommerce workload: hops/sec and host-sync counts per path.

    Warm procedure: run the plan once through each engine (jit compile),
    push the misses through the CP populator until the cache serves the
    whole frontier, then time ``reps`` repeats of the same cached batch —
    the paper's steady-state read path, where the engine overhead (not the
    storage gathers) dominates.
    """
    world = build_world(seed=seed)
    plans = query_plans()
    # first cached plan with at least `hops` hops (falls back to the
    # deepest available; multi-hop plans exercise the merge path hardest)
    eligible = [p for p in plans if len(p[1].hops) >= hops]
    name, plan, label, _, _ = (
        eligible[0] if eligible else max(plans, key=lambda p: len(p[1].hops))
    )
    n_hops = len(plan.hops)
    lo, hi = world.vertex_range(label)
    rng = np.random.default_rng(seed)
    roots = rng.integers(lo, hi, batch).astype(np.int32)
    cache = empty_cache(world.espec.cache)
    pop = CachePopulator(world.espec, TPL_META)
    engines = {
        "fused": GraphEngine(world.espec, plan, use_cache=True, fused=True),
        "host": GraphEngine(world.espec, plan, use_cache=True, fused=False),
    }
    # compile + warm the cache (drain until the miss stream dries up)
    for _ in range(6):
        _, misses, m = engines["fused"].run(world.store, cache, world.ttable, roots)
        pop.queue.push(misses)
        cache = pop.drain(world.store, world.store, cache, world.ttable, k=4096)
        if m["misses"] == 0:
            break
    out = {"batch": batch, "n_hops": n_hops, "plan": name, "reps": reps}
    for tag, eng in engines.items():
        eng.run(world.store, cache, world.ttable, roots)  # compile outside timing
        t0 = time.perf_counter()
        for _ in range(reps):
            _, _, m = eng.run(world.store, cache, world.ttable, roots)
        dt = time.perf_counter() - t0
        out[f"{tag}_hops_per_sec"] = n_hops * batch * reps / dt
        out[f"{tag}_ms_per_batch"] = dt / reps * 1e3
        out[f"{tag}_host_syncs"] = m["host_syncs"]
        out[f"{tag}_host_syncs_per_hop"] = m["host_syncs"] / n_hops
        out[f"{tag}_hit_rate"] = m["hits"] / max(m["cache_reads"], 1)
    out["speedup"] = out["fused_hops_per_sec"] / out["host_hops_per_sec"]
    print(
        f"hop_pipeline: batch={batch} hops={n_hops} "
        f"fused={out['fused_hops_per_sec']:.0f} hops/s "
        f"host={out['host_hops_per_sec']:.0f} hops/s "
        f"speedup={out['speedup']:.2f}x "
        f"syncs/hop fused={out['fused_host_syncs_per_hop']:.2f} "
        f"host={out['host_host_syncs_per_hop']:.2f}"
    )
    return out


def main(n_ops=300, seed=0, json_path=None):
    world = build_world(seed=seed)
    rows = []
    base = {}
    configs = [
        ((False, False), "C-Q-"), ((False, True), "C-Q+"),
        ((True, False), "C+Q-"), ((True, True), "C+Q+"),
    ]
    runners = {tag: Runner(world, c, r) for (c, r), tag in configs}
    for mix in MIXES:
        mix_rate = None  # set by the C-Q- baseline, fixed for the others
        for (cache, rew), tag in configs:
            classes, info = run_config(
                world, cache, rew, mix, n_ops=n_ops, seed=seed,
                runner=runners[tag], rate=mix_rate,
            )
            if tag == "C-Q-":
                mix_rate = info["rate"]
            row = dict(mix=mix, cfg=tag, hit_rate=round(info["hit_rate"], 3))
            for cls in ("cached", "agg", "write"):
                for q in (50, 95, 99):
                    row[f"{cls}_p{q}"] = round(pct(classes[cls], q), 2)
            rows.append(row)
            if tag == "C-Q-":
                base[mix] = row
    # factors of improvement vs C-Q-
    print("mix,cfg,hit_rate," + ",".join(
        f"{c}_p{q}" for c in ("cached", "agg", "write") for q in (50, 95, 99)
    ) + ",f_cached_p95,f_cached_p99,f_agg_p95,f_write_p95")
    for row in rows:
        b = base[row["mix"]]
        f = lambda k: round(b[k] / row[k], 2) if row[k] else float("nan")
        print(",".join(str(row[k]) for k in row) + f",{f('cached_p95')},{f('cached_p99')},{f('agg_p95')},{f('write_p95')}")
    if json_path:
        # persisted for the p99 regression guard (check_regression.py):
        # the run shape (n_ops, seed) rides along so a reduced CI smoke
        # is never compared row-for-row against a full baseline
        with open(json_path, "w") as fh:
            json.dump({"n_ops": n_ops, "seed": seed, "rows": rows},
                      fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"wrote {json_path}")
    return rows


if __name__ == "__main__":
    main()
