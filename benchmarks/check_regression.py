"""Bench-regression guard for the partitioned serving tier.

Compares a freshly measured ``BENCH_partitioned_store.json`` against the
committed baseline (``git show HEAD:BENCH_partitioned_store.json`` by
default, or any ``--baseline`` file) and fails — exit 1, with the numbers —
when either headline metric regresses more than ``--max-regress``
(default 10%):

- ``gr_speedup_vs_replicated`` — the tier's reason to exist; LOWER is a
  regression. This ratio divides out machine speed, so it is the stable
  signal on shared CI runners.
- ``gr_ms_per_batch.partitioned`` — absolute serving latency; HIGHER is a
  regression. Only compared when the fresh run used the same batch size
  and shard count as the baseline (a reduced-size CI smoke run is not
  comparable row-for-row; the guard says so and skips the wall-clock
  check rather than inventing a scale factor).

``results_identical`` must be true in the fresh run — a fast wrong answer
is not a benchmark result.

A second, independent guard covers the C±Q± tail-latency tables
(``BENCH_latency.json`` from ``bench_latency.main``): for every mix, the
C+Q+ configuration's ``cached_p99`` / ``agg_p99`` must stay under the
committed baseline times ``1 + --latency-max-regress``. The default slack
is deliberately generous (50%) — the numbers come from an M/G/1 sojourn
simulation over measured service times, which is noisy on shared runners;
the guard exists to catch order-of-magnitude tail blowups (e.g. telemetry
overhead landing on the query path), not single-digit drift. Runs whose
shape (``n_ops``, ``seed``) differs from the baseline are skipped, not
scaled.

Usage::

    python benchmarks/check_regression.py --fresh BENCH_partitioned_store.json
    python benchmarks/check_regression.py --fresh /tmp/b.json --baseline old.json
    python benchmarks/check_regression.py --latency-fresh BENCH_latency.json
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys

BASELINE_GIT_PATH = "BENCH_partitioned_store.json"
LATENCY_GIT_PATH = "BENCH_latency.json"
ROUTING_GIT_PATH = "BENCH_routing.json"


def load_baseline(path: str | None, git_path: str = BASELINE_GIT_PATH) -> dict:
    if path:
        with open(path) as f:
            return json.load(f)
    blob = subprocess.run(
        ["git", "show", f"HEAD:{git_path}"],
        capture_output=True, text=True, check=True,
    ).stdout
    return json.loads(blob)


def check(fresh: dict, base: dict, max_regress: float) -> list[str]:
    """Returns the list of failure messages (empty = pass)."""
    failures = []
    if not fresh.get("results_identical", False):
        failures.append(
            "results_identical is not true in the fresh run — the tiers "
            "diverged; latency numbers are meaningless"
        )

    sp_new = float(fresh["gr_speedup_vs_replicated"])
    sp_old = float(base["gr_speedup_vs_replicated"])
    floor = sp_old * (1.0 - max_regress)
    line = (f"gr_speedup_vs_replicated: {sp_new:.2f} vs baseline "
            f"{sp_old:.2f} (floor {floor:.2f})")
    if sp_new < floor:
        failures.append("REGRESSION " + line)
    else:
        print("ok  " + line)

    comparable = (fresh.get("batch") == base.get("batch")
                  and fresh.get("n_shards") == base.get("n_shards"))
    if not comparable:
        print(
            f"skip gr_ms_per_batch: fresh run shape "
            f"(batch={fresh.get('batch')}, n_shards={fresh.get('n_shards')}) "
            f"!= baseline (batch={base.get('batch')}, "
            f"n_shards={base.get('n_shards')}) — wall-clock not comparable"
        )
        return failures

    ms_new = float(fresh["gr_ms_per_batch"]["partitioned"])
    ms_old = float(base["gr_ms_per_batch"]["partitioned"])
    ceil = ms_old * (1.0 + max_regress)
    line = (f"gr_ms_per_batch.partitioned: {ms_new:.1f} vs baseline "
            f"{ms_old:.1f} (ceiling {ceil:.1f})")
    if ms_new > ceil:
        failures.append("REGRESSION " + line)
    else:
        print("ok  " + line)
    return failures


def check_latency(fresh: dict, base: dict, max_regress: float) -> list[str]:
    """p99 tail-latency ceiling over the C+Q+ rows of BENCH_latency.json.

    Returns the list of failure messages (empty = pass)."""
    failures = []
    fresh_shape = (fresh.get("n_ops"), fresh.get("seed"))
    base_shape = (base.get("n_ops"), base.get("seed"))
    if fresh_shape != base_shape:
        print(
            f"skip latency p99: fresh run shape (n_ops, seed)={fresh_shape} "
            f"!= baseline {base_shape} — tails not comparable"
        )
        return failures
    base_rows = {(r["mix"], r["cfg"]): r for r in base.get("rows", [])}
    for row in fresh.get("rows", []):
        if row.get("cfg") != "C+Q+":
            continue
        b = base_rows.get((row["mix"], row["cfg"]))
        if b is None:
            continue
        for key in ("cached_p99", "agg_p99"):
            new, old = float(row[key]), float(b[key])
            if new != new or old != old or old <= 0:
                continue  # NaN (empty class) or degenerate baseline
            ceil = old * (1.0 + max_regress)
            line = (f"latency {row['mix']}/C+Q+ {key}: {new:.2f} ms vs "
                    f"baseline {old:.2f} ms (ceiling {ceil:.2f})")
            if new > ceil:
                failures.append("REGRESSION " + line)
            else:
                print("ok  " + line)
    return failures


def check_routing(fresh: dict, base: dict, max_regress: float) -> list[str]:
    """Routing-tier guard over BENCH_routing.json: the hottest-owner load
    cut and the migrated-vs-static speedup are ratios (machine-speed
    independent), guarded with floors; ``results_identical`` and the
    zero-recompile pin are hard requirements of the fresh run.

    Returns the list of failure messages (empty = pass)."""
    failures = []
    if not fresh.get("results_identical", False):
        failures.append(
            "routing: results_identical is not true in the fresh run — "
            "locality routing / migration diverged from the single-host "
            "engine"
        )
    if fresh.get("migrated", {}).get("serve_compiles") != 1:
        failures.append(
            "routing: migrated phase compiled "
            f"{fresh.get('migrated', {}).get('serve_compiles')} serve "
            "programs — table updates must be input changes, never "
            "recompiles"
        )
    for key in ("hot_owner_load_cut", "gr_speedup_vs_static"):
        new, old = float(fresh[key]), float(base[key])
        floor = old * (1.0 - max_regress)
        line = f"routing {key}: {new:.2f} vs baseline {old:.2f} (floor {floor:.2f})"
        if new < floor:
            failures.append("REGRESSION " + line)
        else:
            print("ok  " + line)
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default=None,
                    help="freshly measured BENCH_partitioned_store.json")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline json (default: git show "
                         f"HEAD:{BASELINE_GIT_PATH})")
    ap.add_argument("--max-regress", type=float, default=0.10,
                    help="allowed fractional regression (default 0.10)")
    ap.add_argument("--latency-fresh", default=None,
                    help="freshly measured BENCH_latency.json")
    ap.add_argument("--latency-baseline", default=None,
                    help=f"latency baseline json (default: git show "
                         f"HEAD:{LATENCY_GIT_PATH})")
    ap.add_argument("--latency-max-regress", type=float, default=0.50,
                    help="allowed fractional p99 regression for the C+Q+ "
                         "latency tables (default 0.50 — M/G/1 tails are "
                         "noisy; this catches blowups, not drift)")
    ap.add_argument("--routing-fresh", default=None,
                    help="freshly measured BENCH_routing.json")
    ap.add_argument("--routing-baseline", default=None,
                    help=f"routing baseline json (default: git show "
                         f"HEAD:{ROUTING_GIT_PATH})")
    args = ap.parse_args()
    if (args.fresh is None and args.latency_fresh is None
            and args.routing_fresh is None):
        ap.error("pass --fresh, --latency-fresh, and/or --routing-fresh")
    failures = []
    if args.fresh is not None:
        with open(args.fresh) as f:
            fresh = json.load(f)
        base = load_baseline(args.baseline)
        failures += check(fresh, base, args.max_regress)
    if args.latency_fresh is not None:
        with open(args.latency_fresh) as f:
            lfresh = json.load(f)
        lbase = load_baseline(args.latency_baseline, LATENCY_GIT_PATH)
        failures += check_latency(lfresh, lbase, args.latency_max_regress)
    if args.routing_fresh is not None:
        with open(args.routing_fresh) as f:
            rfresh = json.load(f)
        rbase = load_baseline(args.routing_baseline, ROUTING_GIT_PATH)
        failures += check_routing(rfresh, rbase, args.max_regress)
    for msg in failures:
        print(msg, file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
