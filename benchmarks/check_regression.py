"""Bench-regression guard for the partitioned serving tier.

Compares a freshly measured ``BENCH_partitioned_store.json`` against the
committed baseline (``git show HEAD:BENCH_partitioned_store.json`` by
default, or any ``--baseline`` file) and fails — exit 1, with the numbers —
when either headline metric regresses more than ``--max-regress``
(default 10%):

- ``gr_speedup_vs_replicated`` — the tier's reason to exist; LOWER is a
  regression. This ratio divides out machine speed, so it is the stable
  signal on shared CI runners.
- ``gr_ms_per_batch.partitioned`` — absolute serving latency; HIGHER is a
  regression. Only compared when the fresh run used the same batch size
  and shard count as the baseline (a reduced-size CI smoke run is not
  comparable row-for-row; the guard says so and skips the wall-clock
  check rather than inventing a scale factor).

``results_identical`` must be true in the fresh run — a fast wrong answer
is not a benchmark result.

Usage::

    python benchmarks/check_regression.py --fresh BENCH_partitioned_store.json
    python benchmarks/check_regression.py --fresh /tmp/b.json --baseline old.json
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys

BASELINE_GIT_PATH = "BENCH_partitioned_store.json"


def load_baseline(path: str | None) -> dict:
    if path:
        with open(path) as f:
            return json.load(f)
    blob = subprocess.run(
        ["git", "show", f"HEAD:{BASELINE_GIT_PATH}"],
        capture_output=True, text=True, check=True,
    ).stdout
    return json.loads(blob)


def check(fresh: dict, base: dict, max_regress: float) -> list[str]:
    """Returns the list of failure messages (empty = pass)."""
    failures = []
    if not fresh.get("results_identical", False):
        failures.append(
            "results_identical is not true in the fresh run — the tiers "
            "diverged; latency numbers are meaningless"
        )

    sp_new = float(fresh["gr_speedup_vs_replicated"])
    sp_old = float(base["gr_speedup_vs_replicated"])
    floor = sp_old * (1.0 - max_regress)
    line = (f"gr_speedup_vs_replicated: {sp_new:.2f} vs baseline "
            f"{sp_old:.2f} (floor {floor:.2f})")
    if sp_new < floor:
        failures.append("REGRESSION " + line)
    else:
        print("ok  " + line)

    comparable = (fresh.get("batch") == base.get("batch")
                  and fresh.get("n_shards") == base.get("n_shards"))
    if not comparable:
        print(
            f"skip gr_ms_per_batch: fresh run shape "
            f"(batch={fresh.get('batch')}, n_shards={fresh.get('n_shards')}) "
            f"!= baseline (batch={base.get('batch')}, "
            f"n_shards={base.get('n_shards')}) — wall-clock not comparable"
        )
        return failures

    ms_new = float(fresh["gr_ms_per_batch"]["partitioned"])
    ms_old = float(base["gr_ms_per_batch"]["partitioned"])
    ceil = ms_old * (1.0 + max_regress)
    line = (f"gr_ms_per_batch.partitioned: {ms_new:.1f} vs baseline "
            f"{ms_old:.1f} (ceiling {ceil:.1f})")
    if ms_new > ceil:
        failures.append("REGRESSION " + line)
    else:
        print("ok  " + line)
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="freshly measured BENCH_partitioned_store.json")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline json (default: git show "
                         f"HEAD:{BASELINE_GIT_PATH})")
    ap.add_argument("--max-regress", type=float, default=0.10,
                    help="allowed fractional regression (default 0.10)")
    args = ap.parse_args()
    with open(args.fresh) as f:
        fresh = json.load(f)
    base = load_baseline(args.baseline)
    failures = check(fresh, base, args.max_regress)
    for msg in failures:
        print(msg, file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
