"""Hitless capacity growth vs blocking growth under live traffic
(BENCH_elasticity.json).

The elasticity question PR 5 left open: a capacity-tier growth is a shape
change, so every compiled serving step for the new tier must be rebuilt —
and before this PR that rebuild happened *inline*, stalling the serve loop
for the full recompile (measured at ~0.03x steady-state throughput across
the growth window in BENCH_block_maintenance.json). This benchmark streams
identical gR/gRW traffic through the 8-shard partitioned runtime — journal
attached, on-device maintenance gate active — in two growth modes:

- **hot_swap** — when commit metrics cross the occupancy high-water, the
  next tier's gR/gRW steps compile on a background thread
  (``precompile_next_tier``) while the current tier keeps serving; the
  store hot-swaps at the first batch boundary after the build finishes
  (``swap_to_next_tier``), so the growth pause is one device pad.
- **blocking** — the pre-PR-6 behaviour: grow at the trigger point and eat
  the new tier's compiles inline on the next batches.

Both modes run the same batch mix: an append-heavy warm-up that forces the
occupancy trigger, then an update-only window (the growth window — traffic
that must keep flowing while capacity changes), then an append tail on the
grown tier. Reported per mode: p50/p99 batch latency across the growth
event, steady-state vs during-growth mutation rows/s, swap pause, and
journal flush lag. The headline assertion is the PR's acceptance bar:
hot-swap growth-window throughput >= 0.8x steady-state, with the swap
pause bounded by one batch.

Run via ``benchmarks/run.py --only elasticity`` or directly:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.bench_elasticity --json out.json
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

N_SHARDS = 8

if __name__ == "__main__" and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_SHARDS}"
    ).strip()

import numpy as np  # noqa: E402

RECENT_BLK_CAP = 64
EDGES_PER_BATCH = 64
GR_BATCH = 256
# one gRW step shape for all phases; the vprop cap matches the edge cap so
# the growth window's update-only batches carry the SAME mutation-row count
# as the append batches (otherwise rows/s across phases is apples-to-oranges)
CAPS = (8, EDGES_PER_BATCH, 8, 8, EDGES_PER_BATCH, 8)  # (nv, ne, de, dv, sv, se)
N_APPEND = 10        # append-heavy batches that force the occupancy trigger
N_TAIL = 6           # post-growth batches on the grown tier
MAX_GROWTH_BATCHES = 5000  # safety bound on the during-compile window


def _append_batch(world, rng):
    from repro.graphstore import make_mutation_batch

    w0, w1 = world.vertex_range(0)
    l0, l1 = world.vertex_range(1)
    ne = [
        (world.zipf_pick(w0, w1), int(rng.integers(l0, l1)), 0,
         [int(rng.integers(0, 2))])
        for _ in range(EDGES_PER_BATCH)
    ]
    return make_mutation_batch(world.spec, new_edges=ne, caps=CAPS)


def _update_batch(world, rng):
    """Update-only traffic for the growth window: same compiled shape as
    the append batches but zero appended edges, so occupancy holds still
    while the next tier compiles (however long that takes)."""
    from repro.graphstore import make_mutation_batch

    l0, l1 = world.vertex_range(1)
    sv = [(int(rng.integers(l0, l1)), 0, int(rng.integers(0, 2)))
          for _ in range(EDGES_PER_BATCH)]
    return make_mutation_batch(world.spec, set_vprops=sv, caps=CAPS)


def _rows(mb):
    return int(mb.ne_n) + int(mb.sv_n)


def _run_mode(tag, world, e_blk_cap0, seed):
    import jax

    from benchmarks.workload import query_plans
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedTxnRuntime
    from repro.graphstore import (
        DeviceGate, MaintenancePolicy, WriteBehindJournal,
    )

    espec, store, ttable = world.espec, world.store, world.ttable
    _, plan, label, _, _ = query_plans()[0]
    lo, hi = world.vertex_range(label)
    rng = np.random.default_rng(seed)
    policy = MaintenancePolicy(
        recent_fill_frac=0.5, grow_occupancy_frac=0.75, growth_factor=2.0,
    )
    gate = DeviceGate(recent_fill_frac=policy.recent_fill_frac)

    rt = ShardedTxnRuntime(
        espec, flat_mesh(N_SHARDS), route_cap_factor=None,
        e_blk_cap=e_blk_cap0, recent_blk_cap=RECENT_BLK_CAP,
    )
    pstore = rt.partition_store(store)
    cache = rt.empty_cache()
    journal = WriteBehindJournal(
        os.path.join(tempfile.mkdtemp(prefix=f"bench-elas-{tag}-"), "j"),
        rt.n,
    )
    journal.checkpoint(
        pstore, e_blk_cap=rt.pspec.e_blk_cap,
        recent_blk_cap=rt.pspec.recent_blk_cap, store_version=0,
    )
    journal.start()

    # warm the initial tier's compiles on discarded calls
    rt.run_grw_tx(pstore, cache, ttable, _append_batch(world, rng), gate=gate)
    rt.run_gr_tx_batch(
        pstore, cache, ttable, plan,
        rng.integers(lo, hi, GR_BATCH).astype(np.int32),
    )
    rt.mutation_rows_since_compact = 0

    lat, rows, in_growth = [], [], []
    flush_lag_max = 0
    swap = None
    precompile_kicked = False
    blocking_recompiles_left = 0
    target_cap = int(np.ceil(e_blk_cap0 * policy.growth_factor))

    def step(mb, growth_flag):
        nonlocal flush_lag_max
        t0 = time.perf_counter()
        roots = rng.integers(lo, hi, GR_BATCH).astype(np.int32)
        pin = journal.epochs.pin()
        rt.run_gr_tx_batch(pstore, cache, ttable, plan, roots)
        journal.epochs.release(pin)
        ps2, c2, wm = rt.run_grw_tx(
            pstore, cache, ttable, mb, gate=gate, journal=journal
        )
        jax.block_until_ready(jax.tree_util.tree_leaves(ps2)[0])
        lat.append(time.perf_counter() - t0)
        rows.append(_rows(mb))
        in_growth.append(growth_flag)
        flush_lag_max = max(flush_lag_max, wm["journal_lag_batches"])
        return ps2, c2, wm

    # ---- phase 1: append-heavy stream until occupancy crosses high-water
    for _ in range(N_APPEND):
        pstore, cache, wm = step(_append_batch(world, rng), False)
        if wm["store_occupancy_max"] >= policy.grow_occupancy_frac:
            break
    assert wm["store_occupancy_max"] >= policy.grow_occupancy_frac, (
        "stream never hit the growth trigger; raise N_APPEND", wm
    )

    # ---- trigger: grow, the mode's way
    if tag == "hot_swap":
        rt.precompile_next_tier(
            target_cap, ttable,
            gr_plans=[(plan, max(GR_BATCH, rt.n))],
            grw_policies=[("write-around", gate)],
            grw_caps=CAPS,
        )
        precompile_kicked = True
    else:
        # pre-PR-6 behaviour: grow now; the next batches recompile inline.
        # gR and gRW are separate programs, so the stall spans two batches.
        pstore = rt.grow_blocks(pstore, target_cap)
        journal.append_grow(rt.pspec.e_blk_cap, rt.pspec.recent_blk_cap)
        blocking_recompiles_left = 2

    # ---- phase 2: the growth window — update-only traffic keeps flowing
    # while the tier changes under it
    while True:
        if tag == "hot_swap":
            if rt._next_tier is not None and rt._next_tier.ready.is_set():
                pstore, swap = rt.swap_to_next_tier(pstore)
                journal.append_grow(
                    rt.pspec.e_blk_cap, rt.pspec.recent_blk_cap
                )
                pstore, cache, _ = step(_update_batch(world, rng), True)
                break
            if len(lat) > MAX_GROWTH_BATCHES:
                raise AssertionError("pre-compile never became ready")
            pstore, cache, _ = step(_update_batch(world, rng), True)
        else:
            pstore, cache, _ = step(
                _update_batch(world, rng), blocking_recompiles_left > 0
            )
            blocking_recompiles_left -= 1
            if blocking_recompiles_left <= -2:  # a couple of settled batches
                break

    # ---- phase 3: steady tail on the grown tier
    for _ in range(N_TAIL):
        pstore, cache, _ = step(_append_batch(world, rng), False)

    journal.stop(final_flush=True)
    jm = journal.metrics()
    lat = np.asarray(lat)
    rows = np.asarray(rows, float)
    growth = np.asarray(in_growth)
    steady_rps = float(rows[~growth].sum() / lat[~growth].sum())
    growth_rps = float(rows[growth].sum() / lat[growth].sum())
    out = dict(
        batches=int(len(lat)),
        growth_window_batches=int(growth.sum()),
        p50_batch_ms=round(float(np.percentile(lat, 50)) * 1e3, 2),
        p99_batch_ms=round(float(np.percentile(lat, 99)) * 1e3, 2),
        max_batch_ms=round(float(lat.max()) * 1e3, 2),
        steady_rows_per_s=round(steady_rps, 1),
        during_growth_rows_per_s=round(growth_rps, 1),
        growth_over_steady=round(growth_rps / steady_rps, 3),
        e_blk_cap_final=rt.pspec.e_blk_cap,
        swap_events=rt.swap_events,
        journal_flush_lag_max_batches=int(flush_lag_max),
        journal_flushes=jm["flushes"],
        journal_flushed_records=jm["flushed_records"],
    )
    if swap is not None:
        out["swap_pause_ms"] = round(swap["swap_seconds"] * 1e3, 2)
        out["precompile_seconds"] = round(swap["precompile_seconds"], 1)
        out["precompiled_steps"] = swap["compiled_steps"]
        # swap pause <= 1 batch: the pad-and-flip costs less than a median
        # steady batch, so the swap consumes one batch boundary, not a stall
        out["swap_pause_le_one_batch"] = bool(
            swap["swap_seconds"] <= float(np.percentile(lat[~growth], 50))
        )
    assert precompile_kicked or tag == "blocking"
    return out, (rt, pstore)


def main(seed=11, json_path=None):
    import jax

    from benchmarks.workload import build_world

    n_dev = len(jax.devices())
    assert n_dev >= N_SHARDS, (
        f"need {N_SHARDS} devices (XLA_FLAGS=--xla_force_host_platform_"
        f"device_count={N_SHARDS}), got {n_dev}"
    )
    world = build_world(
        n_users=80, n_watchlists=120, n_listings=600, seed=seed,
        cache_capacity=1 << 13,
    )
    store = world.store
    owned = max(
        int(np.bincount(
            np.asarray(store.esrc)[: int(store.e_len)] % N_SHARDS).max()),
        int(np.bincount(
            np.asarray(store.edst)[: int(store.e_len)] % N_SHARDS).max()),
    )
    e_blk_cap0 = int(np.ceil(owned * 1.15))

    # (cross-mode result identity is NOT asserted here: the growth window
    # length is mode-dependent by design — hot_swap streams for as long as
    # the background compile takes — so the two runs apply different batch
    # counts. Growth-mechanics correctness is pinned byte-for-byte in
    # tests/test_durability_runtime.py instead.)
    mode = {}
    for tag in ("hot_swap", "blocking"):
        mode[tag], _ = _run_mode(tag, world, e_blk_cap0, seed)
        print(f"[{tag}] {json.dumps(mode[tag])}", flush=True)

    hs, bl = mode["hot_swap"], mode["blocking"]
    assert hs["swap_events"] == 1, hs
    # the acceptance bar: growth is hitless — the during-growth window
    # serves >= 0.8x steady-state throughput (blocking mode demonstrates
    # the stall this replaces)
    assert hs["growth_over_steady"] >= 0.8, hs
    assert hs["swap_pause_le_one_batch"], hs
    assert bl["growth_over_steady"] < 0.5, bl

    out = dict(
        n_shards=N_SHARDS,
        recent_blk_cap=RECENT_BLK_CAP,
        e_blk_cap_initial=e_blk_cap0,
        gr_batch=GR_BATCH,
        edges_per_append_batch=EDGES_PER_BATCH,
        hot_swap=hs,
        blocking=bl,
        hitless=hs["growth_over_steady"] >= 0.8,
    )
    print(json.dumps(out, indent=1))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    main(json_path=args.json)
