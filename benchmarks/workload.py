"""The eCommerce production workload (synthetic twin of §5).

World: users own watch-lists; watch-lists include listings (edge property
IsActive); listings are sold by users. Listing vertices carry Status (0/1),
a unique ListingId, and LastSeen. Access is Zipfian.

Six one-hop sub-query templates (the paper's production count) cover the
query mix; queries reference 1–4 one-hop sub-queries; one aggregate query
references none (Lesson 3's indirect beneficiary, ~14% of traffic). Write
mix follows Table 7: Upsert 44.85%, Update-LastSeen 43.94%, Delete-Edges
11.22%; >25% of upserts are predicate no-ops (Lesson 2).

Workload mixes (§5 Figure 4): R̂ 99% reads @ high load, Ŵ 62:38, Ř 94:6 @
low load.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import (
    ANY_LABEL,
    DIR_IN,
    DIR_OUT,
    FINAL_COUNT,
    FINAL_IDS,
    FINAL_VALUES,
    OP_EQ,
    WILDCARD,
    CacheSpec,
    EngineSpec,
    Hop,
    QueryPlan,
    Template,
    make_pred,
    make_template_table,
)
from repro.core.lifecycle import GraphQP, ServiceCoordinator
from repro.graphstore import StoreSpec, ingest, make_mutation_batch
from repro.utils import PROP_MISSING

MISSING = int(PROP_MISSING)

# labels
L_USER, L_WATCHLIST, L_LISTING = 2, 0, 1
E_INCLUDES, E_OWNS, E_SOLD_BY = 0, 1, 2
# vprops
P_STATUS, P_LISTING_ID, P_LAST_SEEN = 0, 1, 2
# eprops
P_ISACTIVE = 0

TEMPLATES = [
    Template("SQ1", DIR_OUT, (L_WATCHLIST, []),
             (ANY_LABEL, [(P_ISACTIVE, OP_EQ, WILDCARD)]),
             (L_LISTING, [(P_STATUS, OP_EQ, WILDCARD)]), edge_label=E_INCLUDES),
    Template("SQ2", DIR_IN, (L_LISTING, []),
             (ANY_LABEL, [(P_ISACTIVE, OP_EQ, WILDCARD)]),
             (L_WATCHLIST, []), edge_label=E_INCLUDES),
    Template("SQ3", DIR_OUT, (L_USER, []), (ANY_LABEL, []),
             (L_WATCHLIST, []), edge_label=E_OWNS),
    Template("SQ4", DIR_IN, (L_WATCHLIST, []), (ANY_LABEL, []),
             (L_USER, []), edge_label=E_OWNS),
    Template("SQ5", DIR_OUT, (L_LISTING, []), (ANY_LABEL, []),
             (L_USER, []), edge_label=E_SOLD_BY),
    Template("SQ6", DIR_IN, (L_USER, []), (ANY_LABEL, []),
             (L_LISTING, [(P_STATUS, OP_EQ, WILDCARD)]), edge_label=E_SOLD_BY),
]
TPL_META = {
    0: (DIR_OUT, E_INCLUDES), 1: (DIR_IN, E_INCLUDES), 2: (DIR_OUT, E_OWNS),
    3: (DIR_IN, E_OWNS), 4: (DIR_OUT, E_SOLD_BY), 5: (DIR_IN, E_SOLD_BY),
}


def _params(*pairs):
    p = np.full(6, MISSING, np.int32)
    for i, v in pairs:
        p[i] = v
    return p


def hops():
    """Hop factories bound to the registered templates."""
    sq1 = lambda ia=1, st=0: Hop(
        DIR_OUT, E_INCLUDES, make_pred(L_WATCHLIST, []),
        make_pred(ANY_LABEL, [(P_ISACTIVE, OP_EQ, WILDCARD)]),
        make_pred(L_LISTING, [(P_STATUS, OP_EQ, WILDCARD)]),
        0, _params((0, ia), (3, st)))
    sq2 = lambda ia=1: Hop(
        DIR_IN, E_INCLUDES, make_pred(L_LISTING, []),
        make_pred(ANY_LABEL, [(P_ISACTIVE, OP_EQ, WILDCARD)]),
        make_pred(L_WATCHLIST, []), 1, _params((0, ia)))
    sq3 = lambda: Hop(
        DIR_OUT, E_OWNS, make_pred(L_USER, []), make_pred(ANY_LABEL, []),
        make_pred(L_WATCHLIST, []), 2, _params())
    sq5 = lambda: Hop(
        DIR_OUT, E_SOLD_BY, make_pred(L_LISTING, []), make_pred(ANY_LABEL, []),
        make_pred(L_USER, []), 4, _params())
    sq6 = lambda st=0: Hop(
        DIR_IN, E_SOLD_BY, make_pred(L_USER, []), make_pred(ANY_LABEL, []),
        make_pred(L_LISTING, [(P_STATUS, OP_EQ, WILDCARD)]), 5,
        _params((3, st)))
    # the aggregate query's hop matches NO registered template (tpl_idx=-1):
    # it scans all includes edges regardless of IsActive
    agg = lambda: Hop(
        DIR_OUT, E_INCLUDES, make_pred(L_WATCHLIST, []),
        make_pred(ANY_LABEL, []), make_pred(L_LISTING, []), -1, _params())
    return dict(sq1=sq1, sq2=sq2, sq3=sq3, sq5=sq5, sq6=sq6, agg=agg)


def query_plans():
    """The query-template mix: (name, plan, root_label, weight, class)."""
    h = hops()
    plans = [
        # Figure 1: watch-list actives (1 one-hop) — the dominant query
        ("q_fig1", QueryPlan((h["sq1"](),), FINAL_IDS), L_WATCHLIST, 0.30, "cached"),
        # §2 two-hop: other listings sharing a watch-list (+ rewriteable filter)
        ("q_common", QueryPlan((h["sq2"](), h["sq1"]()), FINAL_IDS,
                               post_filter=("prop_neq_root", P_LISTING_ID)),
         L_LISTING, 0.18, "cached"),
        # user's active listings across their watch-lists (2 one-hops)
        ("q_user", QueryPlan((h["sq3"](), h["sq1"]()), FINAL_IDS),
         L_USER, 0.14, "cached"),
        # 4 one-hops: active listings sold by sellers of the user's watched items
        ("q_sellers", QueryPlan(
            (h["sq3"](), h["sq1"](), h["sq5"](), h["sq6"]()), FINAL_IDS),
         L_USER, 0.10, "cached"),
        # valueMap query (rewrite drops the fetch phase)
        ("q_values", QueryPlan((h["sq1"](),), FINAL_VALUES,
                               final_prop=P_LISTING_ID), L_WATCHLIST, 0.14, "cached"),
        # Lesson 3: the aggregate query — no one-hop template, no rewrite
        ("q_agg", QueryPlan((h["agg"](),), FINAL_COUNT, extra_phases=2),
         L_WATCHLIST, 0.14, "agg"),
    ]
    return plans


@dataclass
class World:
    spec: StoreSpec
    espec: EngineSpec
    store: object
    ttable: object
    sc: object
    qp: object
    n_users: int
    n_watchlists: int
    n_listings: int
    rng: np.random.Generator
    includes_eids: list = field(default_factory=list)

    def zipf_pick(self, lo, hi, a=1.3):
        n = hi - lo
        r = min(int(self.rng.zipf(a)) - 1, n - 1)
        return lo + r

    def vertex_range(self, label):
        if label == L_USER:
            return 0, self.n_users
        if label == L_WATCHLIST:
            return self.n_users, self.n_users + self.n_watchlists
        return self.n_users + self.n_watchlists, self.n_users + self.n_watchlists + self.n_listings


def build_world(
    n_users=200, n_watchlists=300, n_listings=2000, avg_wl_size=12,
    seed=0, cache_capacity=8192, max_deg=64,
) -> World:
    rng = np.random.default_rng(seed)
    nv = n_users + n_watchlists + n_listings
    spec = StoreSpec(
        v_cap=1 << (nv + 512).bit_length(), e_cap=1 << 16, n_vprops=3,
        n_eprops=1, recent_cap=512,
    )
    vlabels = np.array(
        [L_USER] * n_users + [L_WATCHLIST] * n_watchlists + [L_LISTING] * n_listings
    )
    vprops = np.full((nv, 3), MISSING, np.int64)
    l0 = n_users + n_watchlists
    vprops[l0:, P_STATUS] = rng.integers(0, 2, n_listings)
    vprops[l0:, P_LISTING_ID] = 10_000 + np.arange(n_listings)
    vprops[:, P_LAST_SEEN] = 0
    es, ed, el, ep = [], [], [], []
    # owns: each watch-list owned by a user
    for w in range(n_users, n_users + n_watchlists):
        es.append(int(rng.integers(0, n_users)))
        ed.append(w)
        el.append(E_OWNS)
        ep.append([MISSING])
    # includes: Zipf watch-list sizes
    for w in range(n_users, n_users + n_watchlists):
        size = min(int(rng.zipf(1.4) * avg_wl_size / 3) + 2, max_deg - 8)
        members = rng.choice(np.arange(l0, nv), size=min(size, n_listings), replace=False)
        for m in members:
            es.append(w)
            ed.append(int(m))
            el.append(E_INCLUDES)
            ep.append([int(rng.integers(0, 2))])
    # sold_by: each listing sold by one user
    for li in range(l0, nv):
        es.append(li)
        ed.append(int(rng.integers(0, n_users)))
        el.append(E_SOLD_BY)
        ep.append([MISSING])
    store = ingest(spec, vlabels, vprops, es, ed, el, np.array(ep))
    cspec = CacheSpec(capacity=cache_capacity, probes=8, max_leaves=32, max_chunks=2)
    espec = EngineSpec(store=spec, cache=cspec, max_deg=max_deg, frontier=32)
    ttable = make_template_table(TEMPLATES)
    qp = GraphQP("qp0")
    sc = ServiceCoordinator([qp])
    for t in range(len(TEMPLATES)):
        sc.register(t)
        sc.enable(t)
    ttable = qp.ttable_masks(ttable, len(TEMPLATES))
    includes = [i for i, lab in enumerate(el) if lab == E_INCLUDES]
    return World(
        spec=spec, espec=espec, store=store, ttable=ttable, sc=sc, qp=qp,
        n_users=n_users, n_watchlists=n_watchlists, n_listings=n_listings,
        rng=rng, includes_eids=includes,
    )


# --------------------------------------------------------------- write mix
def make_write(world: World, kind: str):
    """Returns (kind, MutationBatch | None). None = predicate no-op upsert."""
    rng = world.rng
    spec = world.spec
    l0, l1 = world.vertex_range(L_LISTING)
    w0, w1 = world.vertex_range(L_WATCHLIST)
    if kind == "upsert":
        # Type 1: upsert a sub-graph; ~30% are predicate no-ops (Lesson 2)
        if rng.random() < 0.3:
            return kind, None
        listing = world.zipf_pick(l0, l1)
        wl = world.zipf_pick(w0, w1)
        ops = dict(
            set_vprops=[(listing, P_STATUS, int(rng.integers(0, 2)))],
            new_edges=[(wl, listing, E_INCLUDES, [int(rng.integers(0, 2))])],
        )
        return kind, make_mutation_batch(spec, **ops)
    if kind == "last_seen":
        # Type 2: LastSeen is not referenced by any template predicate
        v = world.zipf_pick(l0, l1)
        return kind, make_mutation_batch(
            spec, set_vprops=[(v, P_LAST_SEEN, int(rng.integers(1, 1 << 30)))]
        )
    if kind == "del_edges":
        k = int(rng.integers(1, 4))
        eids = rng.choice(world.includes_eids, size=k, replace=False)
        return kind, make_mutation_batch(spec, del_edges=[int(e) for e in eids])
    raise ValueError(kind)


WRITE_MIX = [("upsert", 0.4485), ("last_seen", 0.4394), ("del_edges", 0.1122)]

# workload mixes: (name, read_fraction, arrival_rate_relative)
MIXES = {
    "R_hat": dict(read_frac=0.99, load=1.0),  # heavy read-dominated
    "W_hat": dict(read_frac=0.62, load=0.85),  # batch-write window
    "R_low": dict(read_frac=0.94, load=0.35),  # low load
}


# ----------------------------------------------------------- route skew
def _skew_stats(factors) -> dict:
    f = np.array(factors)
    return dict(
        mean=round(float(f.mean()), 3), p50=round(float(np.percentile(f, 50)), 3),
        p99=round(float(np.percentile(f, 99)), 3),
        p999=round(float(np.percentile(f, 99.9)), 3),
        max=round(float(f.max()), 3),
        recommended_cap_factor=int(np.ceil(np.percentile(f, 99.9))),
    )


def measure_route_skew(world: World, n_shards: int = 8, batch: int = 512,
                       n_batches: int = 200) -> dict:
    """Measure real per-owner routing skew of the production query mix.

    The sharded runtime routes each hop's frontier roots to their owner
    shards into per-peer buckets of ``route_cap_factor * rows / n`` slots;
    ``None`` sizes buckets for the worst case (every root on one owner).
    This measures what the Zipfian workload actually needs: for each query
    batch, the max per-owner share of the root frontier as a multiple of
    the uniform share (``batch / n``). The p99.9 of that multiplier is the
    cap factor that bounds the overflow rate at ~0.1%% of batches;
    ``DEFAULT_ROUTE_CAP_FACTOR`` in ``repro.distributed.graph_serve`` ships
    the ceiling of the measured value.

    Hops ≥ 2 route **leaf-derived** frontier roots, not query roots, so
    their skew is measured separately (``frontier`` sub-dict): for every
    multi-hop plan in the mix the hop-1 frontier is derived host-side from
    the first hop's adjacency (direction + edge label; per-root leaves
    deduped and capped at the engine's result width — the per-segment cap
    the frontier merge enforces), and the max per-owner share of the merged
    frontier is taken against *its* uniform share. ``per_hop_recommended``
    packages both as the tuple ``ShardedTxnRuntime(route_cap_factor=...)``
    accepts: Zipfian root skew concentrates on hot owners while structural
    leaf frontiers spread nearly uniformly, so the inner hops usually
    sustain tighter buckets than the root hop.
    """
    plans = query_plans()
    weights = np.array([w for (_, _, _, w, _) in plans])
    weights /= weights.sum()

    store = world.store
    e_len = int(store.e_len)
    esrc = np.asarray(store.esrc)[:e_len]
    edst = np.asarray(store.edst)[:e_len]
    elab = np.asarray(store.elabel)[:e_len]
    ealive = np.asarray(store.ealive)[:e_len]
    rw = int(world.espec.result_width)
    adj = {}

    def hop1_frontier(roots, direction, edge_label):
        key = (int(direction), int(edge_label))
        if key not in adj:
            k, o = (edst, esrc) if direction == DIR_IN else (esrc, edst)
            sel = ealive & ((edge_label < 0) | (elab == edge_label))
            order = np.argsort(k[sel], kind="stable")
            adj[key] = (k[sel][order], o[sel][order])
        ks, os_ = adj[key]
        lo = np.searchsorted(ks, roots, side="left")
        hi = np.searchsorted(ks, roots, side="right")
        parts = []
        for l, h in zip(lo, hi):
            if h > l:
                ls = os_[l:h]
                _, first = np.unique(ls, return_index=True)
                parts.append(ls[np.sort(first)][:rw])
        return np.concatenate(parts) if parts else np.zeros(0, np.int64)

    root_factors, frontier_factors = [], []
    for _ in range(n_batches):
        _, plan, label, _, _ = plans[int(world.rng.choice(len(plans), p=weights))]
        lo, hi = world.vertex_range(label)
        roots = np.array([world.zipf_pick(lo, hi) for _ in range(batch)])
        owners = np.mod(roots, n_shards)  # interleaved ownership
        counts = np.bincount(owners, minlength=n_shards)
        root_factors.append(counts.max() / (batch / n_shards))
        if len(plan.hops) > 1:
            fr = hop1_frontier(
                roots, plan.hops[0].direction, plan.hops[0].edge_label
            )
            if len(fr):
                c = np.bincount(np.mod(fr, n_shards), minlength=n_shards)
                frontier_factors.append(c.max() / (len(fr) / n_shards))
    out = dict(n_shards=n_shards, batch=batch, n_batches=n_batches)
    out.update(_skew_stats(root_factors))
    out["frontier"] = (
        dict(_skew_stats(frontier_factors), n_batches=len(frontier_factors))
        if frontier_factors else None
    )
    out["per_hop_recommended"] = [out["recommended_cap_factor"]] + (
        [out["frontier"]["recommended_cap_factor"]] if out["frontier"] else []
    )
    return out
