"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows summarizing each benchmark,
then each benchmark's own detailed table. Reduced op counts keep the whole
run CPU-friendly; pass --full for the EXPERIMENTS.md-scale runs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# self-sufficient invocation: python benchmarks/run.py [...]
for _p in (REPO_ROOT, os.path.join(REPO_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _timed(name, fn, *a, **kw):
    t0 = time.perf_counter()
    out = fn(*a, **kw)
    dt = time.perf_counter() - t0
    return name, dt, out


def _bench_subprocess(module: str, out_name: str, n_shards: int):
    """Run a mesh benchmark in a subprocess so XLA can create the virtual
    device mesh before jax initializes; persists its JSON at the repo root."""
    import subprocess

    path = os.path.join(REPO_ROOT, out_name)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_shards}"
    ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO_ROOT, "src"), REPO_ROOT,
                    env.get("PYTHONPATH")) if p
    )
    subprocess.run(
        [sys.executable, "-m", module, "--json", path],
        check=True, env=env, cwd=REPO_ROOT,
    )
    with open(path) as f:
        out = json.load(f)
    print(f"wrote {path}")
    return out


def _bench_grw_invalidation():
    """Sharded vs single-host gRW-Tx commit throughput
    (BENCH_grw_invalidation.json)."""
    from benchmarks import bench_grw

    return _bench_subprocess(
        "benchmarks.bench_grw", "BENCH_grw_invalidation.json",
        bench_grw.N_SHARDS,
    )


def _bench_partitioned_store():
    """Partitioned dual-CSR tier vs replicated snapshots: memory, gR/gRW
    throughput, measured route skew (BENCH_partitioned_store.json)."""
    from benchmarks import bench_partitioned

    return _bench_subprocess(
        "benchmarks.bench_partitioned", "BENCH_partitioned_store.json",
        bench_partitioned.N_SHARDS,
    )


def _bench_block_maintenance():
    """Sustained gRW traffic through the owner-local maintenance tier:
    compaction + capacity elasticity vs the no-maintenance baseline
    (BENCH_block_maintenance.json)."""
    from benchmarks import bench_maintenance

    return _bench_subprocess(
        "benchmarks.bench_maintenance", "BENCH_block_maintenance.json",
        bench_maintenance.N_SHARDS,
    )


def _bench_elasticity():
    """Hitless hot-swap capacity growth vs blocking inline recompile:
    p50/p99 batch latency across the growth event, steady vs during-growth
    rows/s, journal flush lag (BENCH_elasticity.json)."""
    from benchmarks import bench_elasticity

    return _bench_subprocess(
        "benchmarks.bench_elasticity", "BENCH_elasticity.json",
        bench_elasticity.N_SHARDS,
    )


def _bench_failover():
    """Live shard failover chaos run: crash one owner mid-traffic, measure
    the unavailability window, deferred-row fraction, degraded p95/p99, and
    post-recovery byte-identity vs the uninterrupted run
    (BENCH_failover.json)."""
    from benchmarks import bench_failover

    return _bench_subprocess(
        "benchmarks.bench_failover", "BENCH_failover.json",
        bench_failover.N_SHARDS,
    )


def _bench_routing():
    """Cache-locality routing + hot-vertex migration vs the static modulo
    layout on a colliding Zipfian hot set: hottest-owner load share cut,
    warm gR speedup, zero-recompile pin (BENCH_routing.json)."""
    from benchmarks import bench_routing

    return _bench_subprocess(
        "benchmarks.bench_routing", "BENCH_routing.json",
        bench_routing.N_SHARDS,
    )


def _bench_hop_pipeline(batch=512):
    """Old vs fused hop pipeline; persists BENCH_hop_pipeline.json at the
    repo root so the perf trajectory is tracked across PRs."""
    from benchmarks import bench_latency

    out = bench_latency.hop_pipeline(batch=batch)
    path = os.path.join(REPO_ROOT, "BENCH_hop_pipeline.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    n = 300 if args.full else 60

    from benchmarks import bench_codec, bench_errors, bench_invalidation, bench_latency
    from benchmarks import roofline

    benches = {
        # fused vs host-orchestrated hop pipeline (BENCH_hop_pipeline.json)
        "hop_pipeline": lambda: _bench_hop_pipeline(batch=512),
        # sharded vs host gRW-Tx commit (BENCH_grw_invalidation.json)
        "grw_invalidation": _bench_grw_invalidation,
        # partitioned storage tier: memory / throughput / route skew
        # (BENCH_partitioned_store.json)
        "partitioned_store": _bench_partitioned_store,
        # block maintenance: sustained gRW appends with compaction +
        # capacity elasticity (BENCH_block_maintenance.json)
        "block_maintenance": _bench_block_maintenance,
        # durability + hitless growth: hot-swap vs blocking recompile
        # across a live growth event (BENCH_elasticity.json)
        "elasticity": _bench_elasticity,
        # live shard failover: detection, degraded serving, journal-replay
        # recovery/migration under traffic (BENCH_failover.json)
        "failover": _bench_failover,
        # routing tier: static modulo vs locality routing + hot-vertex
        # migration on a colliding hot set (BENCH_routing.json)
        "routing": _bench_routing,
        # Table 1 + 3 + 4 + 5 + 7 + 8 (C±Q± latency percentiles, per class;
        # BENCH_latency.json feeds the p99 regression guard)
        "latency_tables_1_3_5": lambda: bench_latency.main(
            n_ops=n, json_path=os.path.join(REPO_ROOT, "BENCH_latency.json")),
        # Table 2 + 6 (impacted keys per write type)
        "invalidation_tables_2_6": lambda: bench_invalidation.main(n_writes=n),
        # Table 9 (error rates)
        "errors_table_9": lambda: bench_errors.main(n_ops=max(n // 2, 40)),
        # §4 codec micro-benchmark
        "codec_zstd": bench_codec.main,
        # §Roofline summary from the dry-run artifacts
        "roofline": roofline.main,
    }
    rows = []
    for name, fn in benches.items():
        if args.only and args.only not in name:
            continue
        print(f"\n=== {name} ===", flush=True)
        try:
            nm, dt, out = _timed(name, fn)
            derived = len(out) if isinstance(out, list) else 1
            rows.append((nm, dt * 1e6, derived))
        except FileNotFoundError as e:
            print(f"skipped ({e})")
    print("\nname,us_per_call,derived")
    for nm, us, d in rows:
        print(f"{nm},{us:.0f},{d}")


if __name__ == "__main__":
    main()
