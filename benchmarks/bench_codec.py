"""§4's codec choice: zstd vs zlib/lzma on serialized cache values.

The paper reports zstd wins on both speed and ratio for their value
payloads (lists of int64 vertex ids); this micro-benchmark reproduces that
comparison on our serialized leaf-id arrays.
"""

from __future__ import annotations

import lzma
import time
import zlib

import numpy as np

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover
    zstd = None


def payloads(n=64, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        k = int(rng.integers(5, 2000))
        ids = rng.choice(10_000_000, size=k, replace=False).astype(np.int64)
        out.append(np.sort(ids).tobytes())
    return out


def bench(name, comp, decomp, data):
    t0 = time.perf_counter()
    cs = [comp(d) for d in data]
    t_c = time.perf_counter() - t0
    t0 = time.perf_counter()
    for c, d in zip(cs, data):
        assert decomp(c) == d
    t_d = time.perf_counter() - t0
    ratio = sum(map(len, data)) / sum(map(len, cs))
    n = len(data)
    return dict(codec=name, ratio=round(ratio, 2),
                comp_us=round(t_c / n * 1e6, 1), decomp_us=round(t_d / n * 1e6, 1))


def main():
    data = payloads()
    rows = []
    if zstd is not None:
        c = zstd.ZstdCompressor(level=3)
        d = zstd.ZstdDecompressor()
        rows.append(bench("zstd", c.compress, d.decompress, data))
    rows.append(bench("zlib", lambda b: zlib.compress(b, 6), zlib.decompress, data))
    rows.append(bench("lzma", lambda b: lzma.compress(b, preset=1), lzma.decompress, data))
    print("codec,ratio,comp_us,decomp_us")
    for r in rows:
        print(",".join(str(r[k]) for k in r))
    return rows


if __name__ == "__main__":
    main()
