"""Owner-local block maintenance under sustained gRW traffic
(BENCH_block_maintenance.json).

The question the maintenance tier answers: can shards absorb an *unbounded*
stream of gRW commits — appends landing in the bounded block recent regions
— without a host-side repartition? The stream here pushes **≥ 10× the
recent-region capacity** of new edges through the partitioned runtime on an
8-virtual-device mesh, in two configurations:

- **policy enabled** — ``maintenance_tick`` between commits: owner-local
  compaction merges recent regions into the sorted CSR bodies once fill
  crosses the policy threshold, and block capacity grows (re-pad + index
  extension) when occupancy crosses the high-water mark. Expected: zero
  append overflow, recent fill bounded by the policy, final reads
  byte-identical to the host engine over the identically-mutated (and
  host-compacted) single-host store, sustained mutation throughput.
- **no maintenance (baseline)** — the pre-PR-5 behaviour: recent regions
  only ever grow. Expected: recent fill blows past ``recent_blk_cap`` (reads
  silently fall off the bounded append-scan window — measured as divergent
  result rows vs the host reference) and appends eventually overflow the
  fixed block capacity.

Run via ``benchmarks/run.py --only block_maintenance`` or directly:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.bench_maintenance --json out.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

N_SHARDS = 8

if __name__ == "__main__" and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_SHARDS}"
    ).strip()

import numpy as np  # noqa: E402

RECENT_BLK_CAP = 64
EDGES_PER_BATCH = 64
N_BATCHES = 12  # 768 appended edges = 12x the recent-region capacity


def _edge_stream(world, rng, n_batches, per_batch):
    """Zipfian watch-list → listing upsert bursts (the Table 7 write mix's
    append-heavy half), fixed up front so both runs apply the same stream."""
    from repro.graphstore import make_mutation_batch

    w0, w1 = world.vertex_range(0)  # L_WATCHLIST
    l0, l1 = world.vertex_range(1)  # L_LISTING
    batches = []
    for _ in range(n_batches):
        ne = [
            (world.zipf_pick(w0, w1), int(rng.integers(l0, l1)), 0,
             [int(rng.integers(0, 2))])
            for _ in range(per_batch)
        ]
        sv = [(int(rng.integers(l0, l1)), 0, int(rng.integers(0, 2)))
              for _ in range(8)]
        batches.append(make_mutation_batch(
            world.spec, new_edges=ne, set_vprops=sv,
            caps=(8, per_batch, 8, 8, 8, 8),
        ))
    return batches


def main(iters=1, seed=11, json_path=None):
    import jax

    from benchmarks.workload import build_world, query_plans
    from repro.core import GraphEngine, empty_cache
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedTxnRuntime
    from repro.graphstore import MaintenancePolicy
    from repro.graphstore.store import compact

    n_dev = len(jax.devices())
    assert n_dev >= N_SHARDS, (
        f"need {N_SHARDS} devices (XLA_FLAGS=--xla_force_host_platform_"
        f"device_count={N_SHARDS}), got {n_dev}"
    )
    world = build_world(
        n_users=80, n_watchlists=120, n_listings=600, seed=seed,
        cache_capacity=1 << 13,
    )
    espec, store, ttable = world.espec, world.store, world.ttable
    rng = np.random.default_rng(seed)
    stream = _edge_stream(world, rng, N_BATCHES, EDGES_PER_BATCH)
    total_rows = sum(int(b.ne_n) + int(b.sv_n) for b in stream)
    total_edges = N_BATCHES * EDGES_PER_BATCH
    ratio = total_edges / RECENT_BLK_CAP
    assert ratio >= 10, ratio

    # block capacity: just enough headroom over initial occupancy that the
    # stream must outgrow it — elasticity, not ingest-time worst-casing
    owned = max(
        int(np.bincount(np.asarray(store.esrc)[: int(store.e_len)] % N_SHARDS).max()),
        int(np.bincount(np.asarray(store.edst)[: int(store.e_len)] % N_SHARDS).max()),
    )
    e_blk_cap0 = int(np.ceil(owned * 1.15))

    mesh = flat_mesh(N_SHARDS)
    mode = {}
    policy = MaintenancePolicy(
        recent_fill_frac=0.5, grow_occupancy_frac=0.75, growth_factor=2.0,
    )
    for tag in ("policy", "baseline"):
        rt = ShardedTxnRuntime(
            espec, mesh, route_cap_factor=None, e_blk_cap=e_blk_cap0,
            recent_blk_cap=RECENT_BLK_CAP,
        )
        pstore = rt.partition_store(store)
        cache = rt.empty_cache()
        # discarded calls warm the initial commit + compaction compiles;
        # the mid-stream growth recompiles stay in the t_growth bucket —
        # they ARE the elasticity cost the policy amortizes
        rt.run_grw_tx(pstore, cache, ttable, stream[0])
        rt.mutation_rows_since_compact = 0
        if tag == "policy":
            rt.compact_step(policy.purge)(pstore)
        overflow = compactions = growths = 0
        peak_recent = 0
        t_growth = 0.0
        t0 = time.perf_counter()
        for mb in stream:
            pstore, cache, m = rt.run_grw_tx(pstore, cache, ttable, mb)
            overflow += m["store_append_overflow"]
            peak_recent = max(peak_recent, m["store_recent_fill_max"])
            if tag == "policy":
                tg = time.perf_counter()
                # the commit metrics already carry this pstore's occupancy
                # signals — reuse them instead of re-reading block scalars
                pstore, tick = rt.maintenance_tick(pstore, policy, occupancy=dict(
                    max_occupancy=m["store_occupancy_max"],
                    max_recent_fill=m["store_recent_fill_max"],
                ))
                compactions += int(tick["compacted"])
                if tick["grown_to"] is not None:
                    # growth is a shape change: the tick re-pads the blocks
                    # and invalidates the compiled steps. Re-warm the commit
                    # step on a discarded batch so the one-off recompile —
                    # the elasticity event's real cost, amortized over the
                    # rest of the stream — lands in this bucket, not in the
                    # steady-state throughput
                    growths += 1
                    rows_before = rt.mutation_rows_since_compact
                    rt.run_grw_tx(pstore, cache, ttable, stream[0])
                    rt.mutation_rows_since_compact = rows_before
                    if not tick["compacted"]:
                        # growth invalidated the compaction program too;
                        # re-warm it here so a later compaction's recompile
                        # doesn't leak into the steady-state window
                        rt.compact_step(policy.purge)(pstore)
                    jax.block_until_ready(jax.tree_util.tree_leaves(pstore)[0])
                    t_growth += time.perf_counter() - tg
        if tag == "policy":
            # flush: quiesce-point compaction so the final state is fully
            # range-readable (the host reference compacts too)
            pstore, _ = rt.maintenance_tick(
                pstore, policy._replace(recent_fill_frac=0.0)
            )
            compactions += 1
        jax.block_until_ready(jax.tree_util.tree_leaves(pstore)[0])
        dt = time.perf_counter() - t0
        occ = rt.store_occupancy(pstore)
        steady = dt - t_growth
        mode[tag] = dict(
            seconds=round(dt, 3),
            growth_recompile_seconds=round(t_growth, 3),
            mutation_rows_per_s=round(total_rows / dt, 1),
            steady_state_rows_per_s=round(total_rows / steady, 1),
            append_overflow=int(overflow),
            compactions=compactions,
            growths=growths,
            e_blk_cap_final=rt.pspec.e_blk_cap,
            peak_recent_fill=int(peak_recent),
            final_recent_fill_max=occ["max_recent_fill"],
            final_occupancy_max=occ["max_occupancy"],
        )
        mode[tag]["_state"] = (rt, pstore, cache)

    # ---- correctness: policy-maintained reads == host reference ---------
    # the host analogue of the sustained stream is apply-then-compact (the
    # single-host store's recent region would itself overflow recent_cap)
    host = store
    from repro.graphstore.mutations import apply_mutations
    for mb in stream:
        host, _ = apply_mutations(world.spec, host, mb)
    host = compact(world.spec, host)
    _, plan, label, _, _ = query_plans()[0]  # q_fig1 over watch-lists
    lo, hi = world.vertex_range(label)
    roots = rng.integers(lo, hi, 256).astype(np.int32)
    eng = GraphEngine(espec, plan, True, fused=True)
    res_h, _, _ = eng.run(host, empty_cache(espec.cache), ttable, roots)

    divergent = {}
    for tag in ("policy", "baseline"):
        rt, pstore, _ = mode[tag].pop("_state")
        res_s, _, _ = rt.run_gr_tx_batch(
            pstore, rt.empty_cache(), ttable, plan, roots
        )
        divergent[tag] = int(np.sum(np.any(res_h != res_s, axis=1)))
    assert divergent["policy"] == 0, divergent
    assert mode["policy"]["append_overflow"] == 0, mode["policy"]
    assert mode["policy"]["compactions"] > 0
    # the baseline must visibly degrade: blown recent window (divergent
    # reads) and/or append overflow once the fixed capacity fills
    assert (
        mode["baseline"]["append_overflow"] > 0
        or divergent["baseline"] > 0
        or mode["baseline"]["final_recent_fill_max"] > RECENT_BLK_CAP
    ), (mode["baseline"], divergent)

    out = dict(
        n_shards=N_SHARDS,
        recent_blk_cap=RECENT_BLK_CAP,
        e_blk_cap_initial=e_blk_cap0,
        mutation_batches=N_BATCHES,
        edges_appended=total_edges,
        mutation_rows=total_rows,
        appended_over_recent_cap=round(ratio, 1),
        policy=mode["policy"],
        baseline=mode["baseline"],
        divergent_read_rows=divergent,
        results_identical_with_policy=divergent["policy"] == 0,
    )
    print(json.dumps(out, indent=1))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    main(json_path=args.json)
