"""Tables 2 & 6: impacted cache keys per write type.

Table 2 (analytic bounds per template) is checked empirically: each write
type's measured impacted-key count must respect the bound. Table 6 reports
the distribution (mean/p50/p95/p99/max) of impacted keys per write type
under the Ŵ write mix, with a warmed cache.
"""

from __future__ import annotations

import numpy as np

from benchmarks.workload import TPL_META, WRITE_MIX, build_world, make_write, query_plans
from repro.core import GraphEngine, build_grw_step, empty_cache
from repro.core.population import CachePopulator


def warm(world, n=150):
    cache = empty_cache(world.espec.cache)
    pop = CachePopulator(world.espec, TPL_META)
    plans = query_plans()
    engines = {n_: GraphEngine(world.espec, p, True) for (n_, p, _, _, _) in plans}
    for _ in range(n):
        name, plan, label, w, cls = plans[int(world.rng.integers(0, len(plans)))]
        lo, hi = world.vertex_range(label)
        roots = np.array([world.zipf_pick(lo, hi) for _ in range(8)], np.int32)
        _, misses, _ = engines[name].run(world.store, cache, world.ttable, roots)
        pop.queue.push(misses)
        cache = pop.drain(world.store, world.store, cache, world.ttable, 512)
    return cache


def main(n_writes=150, seed=1):
    world = build_world(seed=seed)
    cache = warm(world)
    grw = build_grw_step(world.espec)
    store = world.store
    per_kind = {k: [] for k, _ in WRITE_MIX}
    kinds, weights = zip(*WRITE_MIX)
    weights = np.array(weights) / sum(weights)
    for _ in range(n_writes):
        wk = kinds[int(world.rng.choice(len(kinds), p=weights))]
        _, mb = make_write(world, wk)
        if mb is None:
            per_kind[wk].append(0)
            continue
        store, cache, impacted, ovf = grw(store, cache, world.ttable, mb)
        assert int(ovf) == 0, "maintenance op stream overflowed its cap"
        per_kind[wk].append(int(impacted))
    print("write_type,n,mean,p50,p95,p99,max")
    rows = []
    for k, vals in per_kind.items():
        v = np.array(vals or [0])
        row = dict(
            write_type=k, n=len(v), mean=round(float(v.mean()), 2),
            p50=int(np.percentile(v, 50)), p95=int(np.percentile(v, 95)),
            p99=int(np.percentile(v, 99)), max=int(v.max()),
        )
        rows.append(row)
        print(",".join(str(row[c]) for c in row))
    # Table 2 bound checks (per template: T=6 registered templates)
    # add/delete edge: <= 2 keys per template -> <= 12; last_seen: 0
    ls = per_kind.get("last_seen", [0])
    assert max(ls) == 0, "LastSeen is unreferenced; must impact 0 keys"
    return rows


if __name__ == "__main__":
    main()
