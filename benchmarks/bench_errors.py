"""Table 9: the cache + rewriting reduce error (timeout/conflict) rates.

Timeouts: a query whose simulated sojourn time exceeds the deadline (the
FDB 5-second limit scaled to the simulation's time base). Conflicts: real
OCC aborts measured from CP-population transactions racing the write mix.
"""

from __future__ import annotations

import numpy as np

from benchmarks.bench_latency import run_config
from benchmarks.workload import MIXES, build_world


def main(n_ops=250, seed=2, deadline_ms=50.0):
    from benchmarks.bench_latency import Runner

    world = build_world(seed=seed)
    print("mix,cfg,timeout_pct,improvement_vs_C-Q-")
    rows = []
    configs = [
        ((False, False), "C-Q-"), ((True, False), "C+Q-"),
        ((False, True), "C-Q+"), ((True, True), "C+Q+"),
    ]
    runners = {tag: Runner(world, c, r) for (c, r), tag in configs}
    for mix in MIXES:
        base = None
        mix_rate = None
        for (cache, rew), tag in configs:
            classes, info = run_config(
                world, cache, rew, mix, n_ops=n_ops, seed=seed,
                runner=runners[tag], rate=mix_rate,
            )
            if tag == "C-Q-":
                mix_rate = info["rate"]
            all_sojourn = np.array(
                classes["cached"] + classes["agg"] + classes["write"]
            ) * 1e3
            pct_err = float((all_sojourn > deadline_ms).mean() * 100)
            if tag == "C-Q-":
                base = max(pct_err, 1e-6)
            rows.append(dict(mix=mix, cfg=tag, timeout_pct=round(pct_err, 3),
                             improvement=round(base / max(pct_err, 1e-6), 2)))
            print(f"{mix},{tag},{rows[-1]['timeout_pct']},{rows[-1]['improvement']}")
    return rows


if __name__ == "__main__":
    main()
