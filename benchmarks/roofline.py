"""§Roofline: three-term roofline per (arch x shape x mesh).

Terms (seconds per global step, per chip):

  compute    = FLOPs / (chips * 197e12)         [bf16 v5e peak]
  memory     = HBM traffic / (chips * 819e9)
  collective = trip-weighted collective bytes per device / 50e9 [ICI]

Methodology (full discussion in EXPERIMENTS.md §Roofline):
- XLA's cost_analysis counts a while-loop body ONCE, so for scan-over-layers
  models (the LM family) HLO flops/bytes are lower bounds; for those cells
  compute/memory use transparent analytic formulas (functions below), and
  the HLO numbers are reported as the cross-check columns.
- GNN / recsys / graph-serve cells have loop-free HLO: their compute/memory
  terms come directly from the compiled dry-run (cost_analysis is per-device
  for the SPMD module; global = x chips).
- The collective term always comes from the compiled HLO with *exact*
  per-computation trip weighting (launch/hlo_analysis): collectives inside
  scan bodies are multiplied by their true trip counts.
- MODEL_FLOPS = 6*N_active*tokens (train) / 2*N_active*tokens (inference);
  useful_ratio = MODEL_FLOPS / FLOPs_used flags redundancy/remat waste.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


# ------------------------------------------------------------------ analytic
def _lm_terms(cfg, info):
    """(flops, hbm_bytes) global per step, transparent formulas."""
    L, D, H, KV, dh = cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    P_total = cfg.param_count()
    P_active = cfg.active_param_count()
    B = info["global_batch"]
    S = info["seq_len"]
    kind = info["kind"]
    if kind == "train":
        T = B * S
        flops = 6.0 * P_active * T           # dense matmuls fwd+bwd
        flops += 6.0 * B * S * S * H * dh    # causal attention (QK^T + PV, bwd x2)
        # HBM traffic: weights fwd+bwd reads + grad write (bf16) + Adam
        # moments read+write (fp32 m,v or bf16 for >300B) + activations
        mom = 8 if P_total > 3e11 else 16
        wbytes = P_total * (2 * 2 + 2 + mom)
        act = L * B * S * (18 * D + 4 * H * dh) * 2  # saved + remat re-reads
        return flops, wbytes + act
    if kind == "prefill":
        T = B * S
        flops = 2.0 * P_active * T + 2.0 * B * S * S * H * dh
        act = L * B * S * (10 * D) * 2
        kv = L * B * S * KV * dh * 2 * 2
        return flops, P_total * 2 + act + kv
    # decode: one token per sequence, full KV read per layer
    flops = 2.0 * P_active * B + 4.0 * B * S * H * dh
    kv_read = 2 * L * B * S * KV * dh * 2
    if cfg.sliding_window and cfg.local_global_pattern:
        # local layers only read the window
        n_glob = L // (cfg.local_global_pattern + 1)
        n_loc = L - n_glob
        kv_read = 2 * B * KV * dh * 2 * (
            n_glob * S + n_loc * min(S, cfg.sliding_window)
        )
    return flops, P_active * 2 + kv_read  # active params + KV traffic


def model_flops_per_step(arch: str, shape: str) -> float:
    from repro import configs as configs_pkg

    mod = configs_pkg.get_arch(arch)
    info = mod.SHAPES[shape]
    if mod.FAMILY == "lm":
        cfg = mod.FULL
        na = cfg.active_param_count()
        if info["kind"] == "train":
            return 6.0 * na * info["seq_len"] * info["global_batch"]
        if info["kind"] == "prefill":
            return 2.0 * na * info["seq_len"] * info["global_batch"]
        return 2.0 * na * info["global_batch"]
    if mod.FAMILY == "gnn":
        cfg = mod.FULL
        E, N, d = info["n_edges"], info["n_nodes"], cfg.d_hidden
        return 3.0 * (E * 4 * d * d + N * 8 * d * d)
    if mod.FAMILY == "recsys":
        cfg = mod.FULL
        d = cfg.embed_dim
        mlp = 0
        for fields in (cfg.user_fields, cfg.item_fields):
            last = fields * d
            for h in cfg.tower_mlp:
                mlp += last * h
                last = h
        B = info["batch"]
        if info["kind"] == "rec_train":
            return 3.0 * (2.0 * B * mlp + 2.0 * B * B * d)
        return 2.0 * B * mlp / 2 + 2.0 * B * info.get("n_candidates", 1) * d
    if mod.FAMILY == "graph":
        cfg = mod.FULL
        return float(info["batch"] * cfg.max_deg * 8)
    return 0.0


def cell_terms(d: dict) -> dict:
    """Compute the three terms for one dry-run record."""
    from repro import configs as configs_pkg

    mod = configs_pkg.get_arch(d["arch"])
    info = mod.SHAPES[d["shape"]]
    n_chips = 512 if d["mesh"] == "multipod" else 256
    la = d.get("loop_analysis") or {}
    coll_w = la.get("collectives_weighted") or {
        k: v for k, v in d["collectives"].items() if k != "counts"
    }
    coll_bytes_dev = sum(coll_w.values())

    if mod.FAMILY == "lm":
        flops_g, bytes_g = _lm_terms(mod.FULL, info)
        source = "analytic"
    else:
        # loop-free HLO: per-device numbers from the compiled module
        flops_g = d["cost"]["flops"] * n_chips
        bytes_g = d["cost"]["bytes_accessed"] * n_chips
        source = "hlo"
    compute_t = flops_g / n_chips / PEAK_FLOPS
    memory_t = bytes_g / n_chips / HBM_BW
    coll_t = coll_bytes_dev / ICI_BW
    mf = model_flops_per_step(d["arch"], d["shape"])
    bound = max(compute_t, memory_t, coll_t)
    dominant = ["compute", "memory", "collective"][
        [compute_t, memory_t, coll_t].index(bound)
    ]
    return dict(
        arch=d["arch"], shape=d["shape"], mesh=d["mesh"], chips=n_chips,
        source=source,
        flops_global=flops_g, hbm_bytes_global=bytes_g,
        coll_bytes_dev=coll_bytes_dev,
        hlo_flops_dev=d["cost"]["flops"],
        hlo_bytes_dev=d["cost"]["bytes_accessed"],
        compute_s=compute_t, memory_s=memory_t, collective_s=coll_t,
        dominant=dominant,
        model_flops=mf,
        useful_ratio=mf / flops_g if flops_g else float("nan"),
        roofline_frac=(mf / n_chips / PEAK_FLOPS) / bound if bound else float("nan"),
        temp_bytes_dev=d["memory"]["temp_bytes"],
        arg_bytes_dev=d["memory"]["argument_bytes"],
    )


def load_cells(dryrun_dir=None):
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir or DRYRUN_DIR, "*.json"))):
        d = json.load(open(f))
        if d.get("skipped") or not d.get("ok"):
            continue
        rows.append(cell_terms(d))
    return rows


def main():
    rows = load_cells()
    cols = ["arch", "shape", "mesh", "source", "compute_s", "memory_s",
            "collective_s", "dominant", "useful_ratio", "roofline_frac"]
    print(",".join(cols))
    for r in rows:
        print(",".join(
            f"{r[c]:.3e}" if isinstance(r[c], float) else str(r[c]) for c in cols
        ))
    out = os.path.join(DRYRUN_DIR, "..", "roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {os.path.abspath(out)} ({len(rows)} cells)")
    return rows


if __name__ == "__main__":
    main()
