"""Cache-locality gR routing + hot-vertex migration vs the static modulo
layout (BENCH_routing.json).

The adversarial case the compiled-in ``v % n`` layout cannot fix: a
Zipfian-hot root set whose members all hash to the SAME owner shard.
Static-modulo serving bounds throughput at that owner — its route buckets
must be sized for the hot share (``route_cap_factor="auto"`` ratchets the
compiled caps up to the measured skew, and under-sized batches pay the
overflow-retry double dispatch). The routing tier's answer is measured in
two phases over the SAME pre-generated query batches:

- **static**: no routing table — the modulo layout, auto caps ratcheted to
  the hot-owner skew.
- **migrated**: a ``MigrationEngine`` loop (observe → skew trigger →
  journal-less round: splice + one-epoch table publish) re-homes the hot
  vertices across owners, then a fresh runtime serves the migrated store
  with caps ratcheted only to the *balanced* residual skew.

On the SPMD mesh every shard executes identical padded shapes, so the
throughput lever is the COMPILED route-bucket size: static serving must
provision buckets for the hot-owner skew (caps ~9x), the migrated layout
only for the balanced residual (the 4,3 floor). The default batch (1024)
sits in the regime where bucket width dominates the hop wall-clock.

Reported and asserted (the routing tier's acceptance):

- hottest-owner load share (``obs.metrics.owner_load_share`` over the
  measured batches' per-owner frontier rows) cut >= 1.5x;
- warm gR throughput >= 1.3x the static layout (smaller compiled route
  buckets + no hot-owner serialization);
- the serving step stays ONE compiled trace across table updates
  (``step.jitted._cache_size() == 1`` — routing is an input, never a
  recompile);
- results stay byte-identical to the single-host engine in both phases.

Run via ``benchmarks/run.py --only routing`` or directly:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=.:src python -m benchmarks.bench_routing --json out.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

N_SHARDS = 8

if __name__ == "__main__" and "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={N_SHARDS}"
    ).strip()

import numpy as np  # noqa: E402

HOT_OWNER = 3
HOT_SET = 16
HOT_FRAC = 0.8


def main(batch=1024, n_batches=6, iters=2, seed=11, json_path=None):
    import jax

    from benchmarks.workload import TPL_META, build_world, query_plans
    from repro.core import GraphEngine, empty_cache
    from repro.core.runtime import bucket_for
    from repro.distributed import flat_mesh
    from repro.distributed.graph_serve import ShardedTxnRuntime
    from repro.distributed.routing import RoutingTableHost
    from repro.graphstore.migration import (
        HotSetTracker, MigrationEngine, MigrationPolicy,
    )
    from repro.obs.metrics import OWNER_STAGE_FIELDS, owner_load_share

    n_dev = len(jax.devices())
    assert n_dev >= N_SHARDS, (
        f"need {N_SHARDS} devices (XLA_FLAGS=--xla_force_host_platform_"
        f"device_count={N_SHARDS}), got {n_dev}"
    )
    world = build_world(seed=seed, cache_capacity=1 << 15)
    espec, store, ttable = world.espec, world.store, world.ttable
    mesh = flat_mesh(N_SHARDS)
    _, plan, label, _, _ = query_plans()[0]  # q_fig1: the dominant query
    eng_h = GraphEngine(espec, plan, True, fused=True)
    rng = np.random.default_rng(seed)
    lo, hi = world.vertex_range(label)

    # the adversarial hot set: Zipfian-hot roots that ALL live at one owner
    # under the modulo layout (hot keys colliding on a shard is the normal
    # case the static layout has no answer to)
    hot = np.array(
        [v for v in range(lo, hi) if v % N_SHARDS == HOT_OWNER][:HOT_SET],
        np.int64,
    )
    assert len(hot) == HOT_SET

    def make_batch():
        zipf = np.minimum(rng.zipf(1.2, batch) - 1, len(hot) - 1)
        tail = rng.integers(lo, hi, batch)
        pick = rng.random(batch) < HOT_FRAC
        return np.where(pick, hot[zipf], tail).astype(np.int32)

    batches = [make_batch() for _ in range(n_batches)]
    bucket = max(bucket_for(batch), N_SHARDS)
    FR = OWNER_STAGE_FIELDS.index("frontier_rows")

    def measure(rt, ps):
        """Warm the cache + auto caps over all batches, then time warm
        steady-state passes over the same batches. Returns the phase dict."""
        cache = rt.empty_cache()
        pop = rt.populator(TPL_META)
        for b in batches:
            _, miss, _ = rt.run_gr_tx_batch(ps, cache, ttable, plan, b)
            pop.queue.push(miss)
            cache = pop.drain(ps, ps, cache, ttable)
        # one settled batch so the steady-state program variant exists,
        # then pin it: the measured loop must never trace again
        rt.run_gr_tx_batch(ps, cache, ttable, plan, batches[0])
        step = rt.serve_step(plan, bucket)
        compiles0 = step.jitted._cache_size()
        stage = np.zeros((rt.n, len(OWNER_STAGE_FIELDS)), np.int64)
        retries0 = rt.route_cap_retries
        t0 = time.perf_counter()
        for _ in range(iters):
            for b in batches:
                rt.run_gr_tx_batch(ps, cache, ttable, plan, b)
                stage += rt.last_owner_stage
        dt = (time.perf_counter() - t0) / (iters * len(batches))
        share = owner_load_share(stage)
        # identity probe: cold sharded run vs the single-host engine
        res_h, _, _ = eng_h.run(
            store, empty_cache(espec.cache), ttable, batches[-1]
        )
        res_s, _, _ = rt.run_gr_tx_batch(
            ps, rt.empty_cache(), ttable, plan, batches[-1]
        )
        return dict(
            ms_per_batch=dt * 1e3,
            qps=batch / dt,
            owner_load_share=[round(float(s), 4) for s in share],
            hot_owner_share=float(share[HOT_OWNER]),
            max_owner_share=float(share.max()),
            skew_factor=float(share.max() * rt.n),
            route_cap_factor=list(rt._effective_cap_factor()),
            measured_route_cap_retries=rt.route_cap_retries - retries0,
            serve_compiles=step.jitted._cache_size() - compiles0 + 1,
            results_identical=bool(np.array_equal(res_h, res_s)),
            _step=step,
        )

    # ---- phase 1: static modulo layout -----------------------------------
    rt_s = ShardedTxnRuntime(espec, mesh, route_cap_factor="auto")
    ps_s = rt_s.partition_store(store)
    static = measure(rt_s, ps_s)
    print(
        f"static:   {static['ms_per_batch']:.1f} ms/batch "
        f"({static['qps']:.0f} gR-Tx/s), hot-owner share "
        f"{static['hot_owner_share']:.3f}, caps {static['route_cap_factor']}"
    )

    # ---- migration discovery loop (not timed) ----------------------------
    rt_d = ShardedTxnRuntime(espec, mesh, route_cap_factor="auto")
    ps = rt_d.partition_store(store)
    rhost = RoutingTableHost(rt_d.n)
    rt_d.attach_routing(rhost)
    engine = MigrationEngine(
        rt_d.pspec, rhost,
        policy=MigrationPolicy(max_moves_per_round=4),
        tracker=HotSetTracker(),
    )
    cache_d = rt_d.empty_cache()
    all_moves, dry, rounds = [], 0, 0
    while dry < 2 and rounds < 12:
        b = batches[rounds % n_batches]
        rt_d.run_gr_tx_batch(ps, cache_d, ttable, plan, b)
        engine.observe(b)
        ps2, moves = engine.step(ps, rt_d.last_owner_stage[:, FR])
        if moves:
            # install the spliced store and the new table at the batch
            # boundary (the epoch protocol: the table is a traced input,
            # so in-flight batches saw exactly one value)
            ps = jax.device_put(ps2, rt_d.store_sharding())
            all_moves += [[int(v), int(d)] for v, d in moves]
            dry = 0
        else:
            dry += 1
        rounds += 1
    mig_metrics = engine.metrics()
    assert mig_metrics["migration_rounds"] >= 1, mig_metrics
    print(f"migration: {mig_metrics} moves={all_moves}")

    # ---- phase 2: migrated layout (fresh runtime, balanced auto caps) ----
    rt_m = ShardedTxnRuntime(espec, mesh, route_cap_factor="auto")
    rt_m.attach_routing(rhost)
    ps_m = jax.device_put(jax.device_get(ps), rt_m.store_sharding())
    migrated = measure(rt_m, ps_m)
    print(
        f"migrated: {migrated['ms_per_batch']:.1f} ms/batch "
        f"({migrated['qps']:.0f} gR-Tx/s), hot-owner share "
        f"{migrated['hot_owner_share']:.3f}, caps {migrated['route_cap_factor']}"
    )

    # table updates are INPUT changes: bump the epoch live (a locality
    # override on a cold vertex, then clear it) and serve — still one trace
    step = migrated.pop("_step")
    static.pop("_step")
    cold = int(lo + 1)
    rhost.set_cache_owner(cold, (cold + 1) % N_SHARDS)
    rt_m.run_gr_tx_batch(ps_m, rt_m.empty_cache(), ttable, plan, batches[0])
    rhost.clear_cache_owner(cold)
    assert step.jitted._cache_size() == 1, step.jitted._cache_size()
    assert migrated["serve_compiles"] == 1, migrated

    # the cut is measured on the HOTTEST owner either side (post-migration
    # the residual bottleneck may be whichever owner received the top
    # vertex, not the original hot shard)
    cut = static["max_owner_share"] / max(migrated["max_owner_share"], 1e-9)
    speedup = static["ms_per_batch"] / migrated["ms_per_batch"]
    print(f"hot-owner load cut {cut:.2f}x, warm gR speedup {speedup:.2f}x")
    assert static["results_identical"] and migrated["results_identical"]
    assert cut >= 1.5, (static["owner_load_share"], migrated["owner_load_share"])
    assert speedup >= 1.3, (static["ms_per_batch"], migrated["ms_per_batch"])

    out = dict(
        n_shards=N_SHARDS, batch=batch, n_batches=n_batches, iters=iters,
        hot_owner=HOT_OWNER, hot_set=HOT_SET, hot_fraction=HOT_FRAC,
        static={k: v for k, v in static.items()},
        migrated={k: v for k, v in migrated.items()},
        hot_owner_load_cut=round(cut, 2),
        gr_speedup_vs_static=round(speedup, 2),
        migration=dict(mig_metrics, moves=all_moves,
                       discovery_rounds=rounds),
        results_identical=bool(
            static["results_identical"] and migrated["results_identical"]
        ),
    )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {json_path}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--batches", type=int, default=6)
    ap.add_argument("--iters", type=int, default=2)
    args = ap.parse_args()
    main(batch=args.batch, n_batches=args.batches, iters=args.iters,
         json_path=args.json)
