"""The partitioned dual-CSR storage tier: owner-local edge blocks.

``PartitionedGraphStore`` is the sharded layout of a ``GraphStore``: edge
storage is split into *owner-local blocks* so that a one-hop scan reads only
arrays resident at the shard that owns the hop's root vertex:

- the **out block** of shard ``s`` holds (a copy of) every edge whose *src*
  vertex is owned by ``s``, CSR-ordered by src — a ``DIR_OUT`` hop routed to
  the root's owner scans purely local arrays;
- the **in block** of shard ``s`` holds every edge whose *dst* vertex is
  owned by ``s``, CSR-ordered by dst — a ``DIR_IN`` hop routes to dst-owners
  instead of scanning a replicated snapshot.

This is the dual-orientation analogue of LiveGraph's sequential adjacency
blocks (Zhu et al.) combined with the decoupled routing of *On Smart Query
Routing* (Khan et al.): route the sub-query to the shard owning the
adjacency list, then scan sequentially. Each edge is stored exactly twice
fleet-wide (once per orientation) instead of once *per shard*, so per-shard
edge bytes drop from O(E) to O(E/n).

Blocks are stored *physically CSR-sorted* (no permutation index): the CSR
region of a block is its edges sorted by (owner-side key, global edge id),
and appends land in the block's *recent region* tail — the same
write-buffer-in-front-of-index design as the single-host store, but per
block. Gathers therefore reproduce the single-host ``_gather`` lane order
exactly (CSR lanes ascend by global edge id within a root, recent lanes
ascend by id), which is what makes the partitioned engine byte-identical to
the single-host engine.

The vertex **attribute** tier (labels, liveness, properties, versions) stays
replicated across shards, like an FDB storage replica: it is a few percent
of store bytes (edge records + CSR indexes dominate), every shard needs leaf
attributes of arbitrary vertices during miss execution, and the OCC conflict
check needs arbitrary vertex versions at commit. Partitioning vertex
attributes behind denormalized adjacency records is a recorded follow-on
(it trades ~60%% more edge-block bytes for the O(V) residual).

Scalars ``v_len`` / ``e_len`` / ``version`` are replicated: every shard
applies the (replicated) mutation batch's section counts identically, so
global id assignment needs no coordination.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.routing import storage_owner_of
from repro.graphstore.store import INT32_MAX, GraphStore, StoreSpec
from repro.utils import PROP_MISSING, take_along0


class PartitionedStoreSpec(NamedTuple):
    """Static layout of a partitioned store (hashable; safe as a closure).

    ``e_blk_cap`` bounds edges per block (per orientation, per shard);
    ``recent_blk_cap`` is the per-block append-scan window (the analogue of
    ``StoreSpec.recent_cap``). Skewed ownership needs headroom over the
    uniform ``e_cap / n`` — size it from measured skew, not worst case.
    """

    base: StoreSpec
    n_shards: int
    e_blk_cap: int
    recent_blk_cap: int

    @property
    def v_loc(self) -> int:
        return self.base.v_cap // self.n_shards


def owner_of(vids, n: int):
    """Vertex ownership is *interleaved* (round-robin): shard ``v % n``
    owns vertex ``v``, whose local index is ``v // n``. Interleaving
    stripes label-clustered id ranges across the whole mesh — with range
    partitioning, a workload whose roots share a label (the common case:
    every SQ1 root is a watch-list) routes its entire frontier to the one
    or two shards owning that label's id block, forcing worst-case routing
    buckets; measured on the eCommerce mix the max per-owner share was the
    full batch. Modulo ownership leaves only genuine hot-key (Zipf) skew,
    which measured caps can bound. Any int (including out-of-range ids)
    maps to exactly one shard; callers mask negatives where they mean
    padding."""
    return jnp.mod(jnp.asarray(vids, jnp.int32), n)


def local_of(vids, n: int):
    """Owner-local vertex index under interleaved ownership."""
    return jnp.asarray(vids, jnp.int32) // n


def default_pspec(spec: StoreSpec, n_shards: int, *, slack: float = 2.0,
                  recent_blk_cap: int | None = None) -> PartitionedStoreSpec:
    """Block capacities for a given shard count: ``slack``x the uniform
    share (ownership skew headroom), recent window defaulting to the base
    store's (appends are not sharded-down in the worst case)."""
    assert spec.v_cap % n_shards == 0, "v_cap must divide over shards"
    eb = int(np.ceil(spec.e_cap * slack / n_shards))
    rb = min(spec.recent_cap if recent_blk_cap is None else recent_blk_cap, eb)
    return PartitionedStoreSpec(spec, n_shards, eb, rb)


class BlockCapacityError(ValueError):
    """A shard's owner-local block cannot hold the edges it owns.

    ``needed`` carries the max per-shard edge count of the failing
    orientation, so elastic callers can grow ``e_blk_cap`` and retry
    (``ShardedTxnRuntime.partition_store(..., elastic=True)``) instead of
    dying on a shape assert deep inside block packing.
    """

    def __init__(self, msg: str, needed: int):
        super().__init__(msg)
        self.needed = needed


class EdgeBlock(NamedTuple):
    """One orientation's owner-local edge copies, all shards stacked.

    Arrays carry the global layout ``[n * e_blk_cap, ...]`` (shard ``s``
    owns rows ``[s*e_blk_cap, (s+1)*e_blk_cap)``); inside ``shard_map`` each
    shard sees its ``[e_blk_cap, ...]`` slice. ``key`` is the owner-side
    endpoint (src for the out block, dst for the in block), ``other`` the
    opposite endpoint, ``geid`` the immutable global edge id (the handle
    mutation sections use to find their local copies). The CSR region
    ``[0, csr_len)`` is physically sorted by (key, geid); ``[csr_len, len)``
    is the recent append region.

    ``gperm`` is the block's **sorted geid→slot index**: the geid column is
    CSR-ordered by key (not monotone), so a permutation array keeps
    ``geid[gperm[:blk_len]]`` ascending and the tail ``gperm[blk_len:]`` the
    unallocated slots in ascending order. Edge-copy location
    (``geid_slot_lookup``) is then an O(log e_blk_cap) ``searchsorted``
    probe instead of the former O(K × e_blk_cap) broadcast-compare — the
    compile cliff before billion-edge blocks. Appends keep it incrementally
    correct for free (new geids exceed all existing ones, so the sorted
    position of an appended slot is the slot itself); compaction and growth
    rebuild it (``maintenance.compact_block`` / ``rebuild_geid_index``).
    """

    key: jax.Array  # int32 [n*EB]
    other: jax.Array  # int32 [n*EB]
    label: jax.Array  # int32 [n*EB]
    alive: jax.Array  # bool  [n*EB]
    props: jax.Array  # int32 [n*EB, n_eprops]
    geid: jax.Array  # int32 [n*EB]
    gperm: jax.Array  # int32 [n*EB] sorted-geid rank -> block slot
    indptr: jax.Array  # int32 [n*(v_loc+1)] CSR row offsets (local vertex)
    blk_len: jax.Array  # int32 [n] edges in the block
    csr_len: jax.Array  # int32 [n] CSR region length


class PartitionedGraphStore(NamedTuple):
    """Pytree of the sharded storage tier. See module docstring."""

    # replicated vertex-attribute tier (identical on every shard)
    vlabel: jax.Array  # int32 [v_cap]
    valive: jax.Array  # bool  [v_cap]
    vprops: jax.Array  # int32 [v_cap, n_vprops]
    vversion: jax.Array  # int32 [v_cap]
    # owner-local dual-CSR edge blocks
    out: EdgeBlock
    inc: EdgeBlock
    # replicated scalars
    v_len: jax.Array
    e_len: jax.Array
    version: jax.Array


# ------------------------------------------------------------------ build
def _build_block(pspec: PartitionedStoreSpec, keyside, otherside, elabel,
                 ealive, eprops, e_len: int, csr_len: int):
    """Host-side construction of one orientation's blocks (numpy)."""
    spec, n = pspec.base, pspec.n_shards
    EB, Vloc = pspec.e_blk_cap, pspec.v_loc
    nep = spec.n_eprops
    key = np.full((n * EB,), INT32_MAX, np.int32)
    other = np.full((n * EB,), -1, np.int32)
    label = np.full((n * EB,), -1, np.int32)
    alive = np.zeros((n * EB,), bool)
    props = np.full((n * EB, nep), np.int32(-(2**31) + 1), np.int32)
    geid = np.full((n * EB,), -1, np.int32)
    gperm = np.zeros((n * EB,), np.int32)
    indptr = np.zeros((n * (Vloc + 1),), np.int32)
    blk_len = np.zeros((n,), np.int32)
    csr_blk = np.zeros((n,), np.int32)

    slots = np.arange(e_len)
    owner = np.mod(keyside[slots], n)
    counts = np.bincount(owner, minlength=n) if e_len else np.zeros(n, np.int64)
    if counts.max(initial=0) > EB:
        worst = int(counts.argmax())
        raise BlockCapacityError(
            f"shard {worst} owns {int(counts.max())} edges of this "
            f"orientation > e_blk_cap={EB}. Raise e_blk_cap / blk_slack, or "
            f"partition with ShardedTxnRuntime.partition_store(..., "
            f"elastic=True) to grow block capacity automatically.",
            needed=int(counts.max()),
        )
    for s in range(n):
        mine = slots[owner == s]
        csr_mine = mine[mine < csr_len]
        rec_mine = mine[mine >= csr_len]
        # CSR region: stable sort by owner-side key; ties keep global-slot
        # order, matching the single-host stable argsort lane order exactly
        order = np.argsort(keyside[csr_mine], kind="stable")
        csr_sorted = csr_mine[order]
        local = np.concatenate([csr_sorted, rec_mine])
        m = len(local)
        base = s * EB
        key[base : base + m] = keyside[local]
        other[base : base + m] = otherside[local]
        label[base : base + m] = elabel[local]
        alive[base : base + m] = ealive[local]
        props[base : base + m] = eprops[local]
        geid[base : base + m] = local
        blk_len[s] = m
        csr_blk[s] = len(csr_sorted)
        # sorted geid->slot index: allocated slots by ascending geid, then
        # the unallocated tail in slot order (stable ties on the sentinel)
        masked = np.where(
            np.arange(EB) < m, geid[base : base + EB].astype(np.int64),
            np.int64(INT32_MAX),
        )
        gperm[base : base + EB] = np.argsort(masked, kind="stable")
        lk = keyside[csr_sorted] // n  # interleaved: local index = v // n
        indptr[s * (Vloc + 1) : (s + 1) * (Vloc + 1)] = np.searchsorted(
            lk, np.arange(Vloc + 1), side="left"
        )
    return EdgeBlock(
        key=jnp.asarray(key), other=jnp.asarray(other), label=jnp.asarray(label),
        alive=jnp.asarray(alive), props=jnp.asarray(props),
        geid=jnp.asarray(geid), gperm=jnp.asarray(gperm),
        indptr=jnp.asarray(indptr),
        blk_len=jnp.asarray(blk_len), csr_len=jnp.asarray(csr_blk),
    )


def partition_store(pspec: PartitionedStoreSpec, store: GraphStore) -> PartitionedGraphStore:
    """Partition a (host or device) ``GraphStore`` into owner-local blocks.

    Pure layout change: the partitioned store serves byte-identical reads.
    Dead-but-allocated edges keep their CSR lanes (they are masked at read
    time, exactly like the single-host store), so per-root CSR degrees — and
    therefore truncation flags and scan metrics — match the source store.
    """
    e_len, csr_len = int(store.e_len), int(store.csr_len)
    esrc = np.asarray(store.esrc)
    edst = np.asarray(store.edst)
    elabel = np.asarray(store.elabel)
    ealive = np.asarray(store.ealive)
    eprops = np.asarray(store.eprops)
    out = _build_block(pspec, esrc, edst, elabel, ealive, eprops, e_len, csr_len)
    inc = _build_block(pspec, edst, esrc, elabel, ealive, eprops, e_len, csr_len)
    return PartitionedGraphStore(
        vlabel=store.vlabel, valive=store.valive, vprops=store.vprops,
        vversion=store.vversion, out=out, inc=inc,
        v_len=store.v_len, e_len=store.e_len, version=store.version,
    )


def abstract_partitioned_store(pspec: PartitionedStoreSpec):
    """ShapeDtypeStructs of a partitioned store (dry-run / AOT inputs)."""
    spec, n = pspec.base, pspec.n_shards
    EB, Vloc = pspec.e_blk_cap, pspec.v_loc
    sds, i32 = jax.ShapeDtypeStruct, jnp.int32

    def blk():
        return EdgeBlock(
            key=sds((n * EB,), i32), other=sds((n * EB,), i32),
            label=sds((n * EB,), i32), alive=sds((n * EB,), jnp.bool_),
            props=sds((n * EB, spec.n_eprops), i32), geid=sds((n * EB,), i32),
            gperm=sds((n * EB,), i32),
            indptr=sds((n * (Vloc + 1),), i32), blk_len=sds((n,), i32),
            csr_len=sds((n,), i32),
        )

    return PartitionedGraphStore(
        vlabel=sds((spec.v_cap,), i32), valive=sds((spec.v_cap,), jnp.bool_),
        vprops=sds((spec.v_cap, spec.n_vprops), i32),
        vversion=sds((spec.v_cap,), i32), out=blk(), inc=blk(),
        v_len=sds((), i32), e_len=sds((), i32), version=sds((), i32),
    )


# ------------------------------------------------------------------ bytes
def tree_nbytes(tree) -> int:
    """Total array bytes of a pytree (ShapeDtypeStructs count too)."""
    return int(sum(
        int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
        for l in jax.tree_util.tree_leaves(tree)
    ))


def store_bytes_report(pspec: PartitionedStoreSpec, pstore=None) -> dict:
    """Per-shard bytes of the partitioned tier vs the replicated snapshot.

    ``per_shard`` counts one shard's edge blocks + its copy of the
    replicated vertex/scalar tier; ``replicated_per_shard`` is the full
    single-host ``GraphStore`` every shard used to carry. ``ratio`` is their
    quotient (ideal ``1/n`` for the sharded part; each edge appears at two
    owners, so the edge term floors at ``~2/n`` of the replicated edge+CSR
    bytes — measured, not hidden).
    """
    from repro.graphstore.store import empty_store

    n = pspec.n_shards
    pstore = pstore if pstore is not None else abstract_partitioned_store(pspec)
    blocks = tree_nbytes((pstore.out, pstore.inc))
    repl = tree_nbytes(
        (pstore.vlabel, pstore.valive, pstore.vprops, pstore.vversion,
         pstore.v_len, pstore.e_len, pstore.version)
    )
    per_shard = blocks // n + repl
    baseline = tree_nbytes(jax.eval_shape(lambda: empty_store(pspec.base)))
    return dict(
        n_shards=n,
        per_shard_bytes=per_shard,
        per_shard_block_bytes=blocks // n,
        per_shard_replicated_bytes=repl,
        replicated_per_shard_bytes=baseline,
        ratio=per_shard / baseline,
        ideal_ratio=1.0 / n,
    )


# ------------------------------------------------------------------ reads
def gather_block(pspec: PartitionedStoreSpec, ps: PartitionedGraphStore,
                 roots: jax.Array, max_deg: int, *, incoming: bool, me,
                 rtable=None):
    """Owner-local padded adjacency gather (one shard's view).

    Shard-local mirror of ``store._gather``: CSR lanes from the physically
    sorted block region plus a bounded recent-region scan. Returns
    ``(slots [B, W], other [B, W], mask [B, W], truncated [B])`` with
    ``W = max_deg + recent_blk_cap``; ``slots`` index the *local block*
    arrays (label/props reads), ``other`` carries global leaf ids. Roots not
    owned by this shard (or out of range) come back fully masked — the same
    observable as the single-host gather for an invalid root.

    ``rtable`` (a ``distributed.routing.RoutingTable``) makes ownership
    table-driven: a migrated-in root is valid here even though ``v % n``
    says otherwise. Its rows live in the *recent region* (migration appends
    them there) and match by global key; the CSR window is native-only —
    a foreign root's local index ``v // n`` would alias a native vertex's
    CSR rows — so both the CSR mask and the truncation flag gate on
    nativeness when a table is in play. ``rtable=None`` is byte-identical
    to the historical modulo-only gather.
    """
    spec, n = pspec.base, pspec.n_shards
    EB, Vloc, R = pspec.e_blk_cap, pspec.v_loc, pspec.recent_blk_cap
    blk = ps.inc if incoming else ps.out

    roots = roots.astype(jnp.int32)
    me = jnp.asarray(me, jnp.int32)
    local = local_of(roots, n)
    rvalid = (storage_owner_of(rtable, roots, n) == me) & (roots >= 0) \
        & (roots < spec.v_cap)
    if rtable is None:
        cvalid = rvalid
    else:
        native = owner_of(roots, n) == me
        cvalid = rvalid & native
    lc = jnp.clip(local, 0, Vloc - 1)
    start = blk.indptr[lc]
    deg = blk.indptr[lc + 1] - start
    truncated = deg > max_deg
    if rtable is not None:
        truncated &= native
    pos = start[:, None] + jnp.arange(max_deg, dtype=jnp.int32)[None, :]
    csr_mask = (jnp.arange(max_deg)[None, :] < deg[:, None]) & cvalid[:, None]
    slot_csr = jnp.clip(pos, 0, EB - 1)

    # recent region of this block: [csr_len, blk_len) within a bounded window
    clb = blk.csr_len[0]
    lb = blk.blk_len[0]
    roff = jnp.clip(clb, 0, EB - R)
    key_r = jax.lax.dynamic_slice(blk.key, (roff,), (R,))
    sid = roff + jnp.arange(R, dtype=jnp.int32)
    in_region = (sid >= clb) & (sid < lb)
    rec_mask = (key_r[None, :] == roots[:, None]) & in_region[None, :]
    rec_mask &= rvalid[:, None]
    slot_rec = jnp.broadcast_to(sid[None, :], (roots.shape[0], R))

    slots = jnp.concatenate([slot_csr, slot_rec], axis=1)
    mask = jnp.concatenate([csr_mask, rec_mask], axis=1)
    # liveness chain identical to the single-host gather: edge alive, both
    # endpoints alive (leaf via the replicated vertex tier)
    mask &= take_along0(blk.alive, slots)
    other = take_along0(blk.other, slots)
    mask &= take_along0(ps.valive, other)
    mask &= take_along0(ps.valive, jnp.broadcast_to(roots[:, None], slots.shape))
    return slots, other, mask, truncated


class BlockStoreView:
    """One shard's storage view over its owner-local blocks.

    Same interface as ``store.GlobalStoreView`` — vertex attributes come
    from the replicated tier, adjacency from the local dual-CSR blocks, and
    ``own`` reports which vertices route here (clamped like the serve tier's
    owner routing, so out-of-range ids resolve to exactly one shard).
    Intended to be constructed *inside* ``shard_map`` (or a vmap with a
    named axis) where ``ps`` holds the local block slices. ``rtable`` makes
    ownership table-driven (``None`` = the compiled-in modulo, exactly).
    """

    def __init__(self, pspec: PartitionedStoreSpec, ps: PartitionedGraphStore,
                 me, rtable=None):
        self.pspec = pspec
        self.ps = ps
        self.me = jnp.asarray(me, jnp.int32)
        self.rtable = rtable

    @property
    def vlabel(self):
        return self.ps.vlabel

    @property
    def vprops(self):
        return self.ps.vprops

    @property
    def valive(self):
        return self.ps.valive

    def own(self, vids):
        return storage_owner_of(self.rtable, vids, self.pspec.n_shards) == self.me

    def adjacency(self, roots: jax.Array, max_deg: int, *, incoming: bool):
        slots, other, mask, trunc = gather_block(
            self.pspec, self.ps, roots, max_deg, incoming=incoming, me=self.me,
            rtable=self.rtable,
        )
        blk = self.ps.inc if incoming else self.ps.out
        elab = take_along0(blk.label, slots)
        ep = take_along0(blk.props, slots)
        return other, mask, trunc, elab, ep

    def kernel_operands(self, *, incoming: bool) -> "BlockGatherOperands":
        """Flat per-orientation operand bundle for ``kernels/block_gather``
        (the fused scan+filter executor): the local block arrays, the
        replicated vertex-attribute tier, and the block fill scalars —
        exactly the arrays the kernel streams, in its argument order."""
        blk = self.ps.inc if incoming else self.ps.out
        return BlockGatherOperands(
            indptr=blk.indptr, key=blk.key, other=blk.other, label=blk.label,
            alive=blk.alive, props=blk.props,
            vlabel=self.ps.vlabel, valive=self.ps.valive,
            vprops=self.ps.vprops,
            csr_len=blk.csr_len[0], blk_len=blk.blk_len[0],
        )


class BlockGatherOperands(NamedTuple):
    """Kernel-friendly view of one orientation's owner-local block: the
    positional operands of ``kernels/block_gather`` (see that package for
    the layout contract). Built inside ``shard_map`` from the local slices
    via ``BlockStoreView.kernel_operands``."""

    indptr: jax.Array   # int32 [v_loc + 1] CSR row index (local vertex ids)
    key: jax.Array      # int32 [e_blk_cap] owner-side key per edge record
    other: jax.Array    # int32 [e_blk_cap] global leaf id per edge record
    label: jax.Array    # int32 [e_blk_cap] edge label
    alive: jax.Array    # bool  [e_blk_cap] edge liveness
    props: jax.Array    # int32 [e_blk_cap, NEP] edge properties
    vlabel: jax.Array   # int32 [v_cap] replicated vertex labels
    valive: jax.Array   # bool  [v_cap] replicated vertex liveness
    vprops: jax.Array   # int32 [v_cap, NVP] replicated vertex properties
    csr_len: jax.Array  # int32 [] sorted-region length of this block
    blk_len: jax.Array  # int32 [] allocated length (recent = [csr, blk))


# ------------------------------------------------------------- geid index
def rebuild_geid_index(blk_len, geid) -> jax.Array:
    """Recompute one block's sorted geid→slot permutation from scratch.

    Allocated slots (``< blk_len``) sort by ascending geid; the unallocated
    tail keeps ascending slot order (stable ties on the sentinel), matching
    the host-side ``_build_block`` construction byte-for-byte. Used at
    compaction / growth; appends maintain the index incrementally instead.
    """
    lanes = jnp.arange(geid.shape[0], dtype=jnp.int32)
    masked = jnp.where(lanes < blk_len, geid, INT32_MAX)
    return jnp.argsort(masked, stable=True).astype(jnp.int32)


def sorted_geid_view(EB: int, geid, gperm, blk_len):
    """The index's ascending geid view: one O(EB) gather, shareable across
    every probe batch against the same block state."""
    lanes = jnp.arange(EB, dtype=jnp.int32)
    return jnp.where(lanes < blk_len, take_along0(geid, gperm), INT32_MAX)


def geid_slot_lookup(EB: int, geid, gperm, blk_len, eids, skey=None):
    """Locate global edge ids in one block via the sorted geid→slot index.

    ``searchsorted`` over the index's ascending geid view: O(log EB) per
    probe plus one linear gather to materialize the view (pass a shared
    ``sorted_geid_view`` as ``skey`` to amortize it across probe batches;
    the gather is the same order as the functional scatter updates the
    apply already pays). The former [K, e_blk_cap] broadcast-compare was
    O(K × EB) — the compile cliff before billion-edge blocks. Returns
    ``(slot [K], found [K])``; ``slot`` is only meaningful where ``found``
    (callers scatter with OOB-drop otherwise).
    """
    if skey is None:
        skey = sorted_geid_view(EB, geid, gperm, blk_len)
    eids = jnp.asarray(eids, jnp.int32)
    pos = jnp.searchsorted(skey, eids, side="left").astype(jnp.int32)
    posc = jnp.clip(pos, 0, EB - 1)
    slot = take_along0(gperm, posc)
    found = (pos < blk_len) & (skey[posc] == eids) & (eids >= 0)
    return slot, found


# ----------------------------------------------------------------- writes
def _lookup_block(pspec: PartitionedStoreSpec, blk: EdgeBlock, eids, psum,
                  skey=None):
    """Locate global edge ids in one shard's block and psum-replicate their
    records. Exactly one shard holds an edge's copy per orientation, so the
    sum over shards *is* that owner's contribution. Returns ``(found, key,
    other, label, props)`` replicated across the mesh. The per-block match
    is an indexed ``geid_slot_lookup`` probe (``skey`` shares the sorted
    view across lookups against the same block state)."""
    EB = pspec.e_blk_cap
    sl, found_l = geid_slot_lookup(
        EB, blk.geid, blk.gperm, blk.blk_len[0], eids, skey=skey
    )
    contrib = lambda a: jnp.where(found_l, a[sl], 0)
    found = psum(found_l.astype(jnp.int32)) > 0
    key = psum(contrib(blk.key))
    other = psum(contrib(blk.other))
    label = psum(contrib(blk.label))
    props = psum(jnp.where(found_l[:, None], blk.props[sl], 0))
    return found, key, other, label, props


def apply_mutations_partitioned(pspec: PartitionedStoreSpec,
                                ps: PartitionedGraphStore, batch, me, axes,
                                rtable=None):
    """Apply one gRW commit to the partitioned tier (per shard, inside
    ``shard_map`` — or a vmap with a named axis for host testing).

    Each mutation section lands only at the partitions it touches: new /
    deleted / re-propertied edges at their src-owner's out block and
    dst-owner's in block (located by global edge id; new edges append to
    the block recent regions), vertex sections on the replicated attribute
    tier (every shard applies them identically — no coordination, the batch
    is replicated). Deleted-edge and edge-prop pre-images — which the
    single host reads from its slot arrays — are psum-gathered from the
    src-owners, so the returned ``AppliedMutations`` snapshot is replicated
    and byte-identical to the single-host listener input.

    Returns ``(store', applied, append_overflow)``; a nonzero overflow
    means a block's capacity dropped new edges (raise ``e_blk_cap``).

    ``rtable`` routes new-edge appends to their *table* owner: edges of a
    migrated vertex land in the block that now serves it (the recent
    region matches by key, so they are readable there immediately). The
    de/se sections locate their copies by geid, which is
    placement-agnostic. ``rtable=None`` is the historical modulo routing.
    """
    from repro.graphstore.mutations import AppliedMutations, _sec_mask

    spec, n = pspec.base, pspec.n_shards
    Vloc, EB = pspec.v_loc, pspec.e_blk_cap
    nvp, nep = spec.n_vprops, spec.n_eprops
    b = batch
    me = jnp.asarray(me, jnp.int32)
    psum = lambda x: jax.lax.psum(x, axes)
    owner = lambda v: storage_owner_of(rtable, v, n)
    new_version = ps.version + 1

    nv_mask = _sec_mask(b.nv_label, b.nv_n)
    ne_mask = _sec_mask(b.ne_src, b.ne_n)
    de_mask = _sec_mask(b.de_eid, b.de_n)
    dv_mask = _sec_mask(b.dv_vid, b.dv_n)
    sv_mask = _sec_mask(b.sv_vid, b.sv_n)
    se_mask = _sec_mask(b.se_eid, b.se_n)

    # ---- pre-images (pre-state blocks; defaults mirror empty slot arrays;
    # the de/se lookups share one sorted view of the pre-state out block)
    skey_pre = sorted_geid_view(EB, ps.out.geid, ps.out.gperm, ps.out.blk_len[0])
    f_de, de_src_g, de_dst_g, de_lab_g, de_props_g = _lookup_block(
        pspec, ps.out, b.de_eid, psum, skey=skey_pre
    )
    de_src = jnp.where(de_mask, jnp.where(f_de, de_src_g, INT32_MAX), -1)
    de_dst = jnp.where(de_mask, jnp.where(f_de, de_dst_g, -1), -1)
    de_label = jnp.where(de_mask, jnp.where(f_de, de_lab_g, -1), -1)
    de_props = jnp.where(
        de_mask[:, None],
        jnp.where(f_de[:, None], de_props_g, PROP_MISSING), PROP_MISSING,
    )
    f_se, se_src_g, se_dst_g, se_lab_g, se_props_g = _lookup_block(
        pspec, ps.out, b.se_eid, psum, skey=skey_pre
    )
    se_src = jnp.where(se_mask, jnp.where(f_se, se_src_g, INT32_MAX), -1)
    se_dst = jnp.where(se_mask, jnp.where(f_se, se_dst_g, -1), -1)
    se_label = jnp.where(se_mask, jnp.where(f_se, se_lab_g, -1), -1)
    se_pre_rows = jnp.where(f_se[:, None], se_props_g, PROP_MISSING)
    se_old = jnp.where(
        se_mask,
        jnp.take_along_axis(
            se_pre_rows, jnp.clip(b.se_pid, 0, nep - 1)[:, None], axis=1
        )[:, 0],
        PROP_MISSING,
    )
    sv_rows = take_along0(ps.vprops, b.sv_vid)
    sv_old = jnp.where(
        sv_mask,
        jnp.take_along_axis(
            sv_rows, jnp.clip(b.sv_pid, 0, nvp - 1)[:, None], axis=1
        )[:, 0],
        PROP_MISSING,
    )

    # ---- id assignment from the replicated scalars (no coordination)
    knv, kne = b.nv_label.shape[0], b.ne_src.shape[0]
    nv_vid = jnp.where(nv_mask, ps.v_len + jnp.arange(knv, dtype=jnp.int32), -1)
    ne_eid = jnp.where(ne_mask, ps.e_len + jnp.arange(kne, dtype=jnp.int32), -1)

    # ---- replicated vertex-attribute tier (identical scatter on all shards)
    nv_idx = jnp.where(nv_mask, nv_vid, spec.v_cap)
    vlabel = ps.vlabel.at[nv_idx].set(b.nv_label, mode="drop")
    valive = ps.valive.at[nv_idx].set(True, mode="drop")
    vprops = ps.vprops.at[nv_idx].set(b.nv_props, mode="drop")
    sv_idx = jnp.where(sv_mask, b.sv_vid, spec.v_cap)
    vprops = vprops.at[sv_idx, jnp.clip(b.sv_pid, 0, nvp - 1)].set(
        b.sv_val, mode="drop"
    )
    dv_idx = jnp.where(dv_mask, b.dv_vid, spec.v_cap)
    valive = valive.at[dv_idx].set(False, mode="drop")
    vversion = ps.vversion
    for vid, m in (
        (b.ne_src, ne_mask),
        (b.ne_dst, ne_mask),
        (de_src, de_mask),
        (de_dst, de_mask),
        (b.sv_vid, sv_mask),
        (se_src, se_mask),
        (se_dst, se_mask),
        (b.dv_vid, dv_mask),
        (nv_vid, nv_mask),
    ):
        vversion = vversion.at[jnp.where(m, vid, spec.v_cap)].set(
            new_version, mode="drop"
        )

    # ---- owner-local edge blocks
    def apply_block(blk: EdgeBlock, keysel, othersel):
        own_ne = ne_mask & (owner(keysel) == me)
        rank = jnp.cumsum(own_ne.astype(jnp.int32)) - 1
        pos = jnp.where(own_ne, blk.blk_len[0] + rank, EB)
        ovf = jnp.sum((own_ne & (pos >= EB)).astype(jnp.int32))
        blk = blk._replace(
            key=blk.key.at[pos].set(keysel, mode="drop"),
            other=blk.other.at[pos].set(othersel, mode="drop"),
            label=blk.label.at[pos].set(b.ne_label, mode="drop"),
            alive=blk.alive.at[pos].set(True, mode="drop"),
            props=blk.props.at[pos].set(b.ne_props, mode="drop"),
            geid=blk.geid.at[pos].set(ne_eid, mode="drop"),
            # sorted geid->slot index, maintained incrementally: appended
            # geids exceed every existing geid (e_len only grows), so an
            # appended slot's sorted rank *is* the slot index
            gperm=blk.gperm.at[pos].set(pos.astype(jnp.int32), mode="drop"),
        )
        new_len = blk.blk_len[0] + jnp.sum(
            (own_ne & (pos < EB)).astype(jnp.int32)
        )
        # edge-prop edits / deletes locate their local copy through the
        # index (post-append, so same-batch new edges are editable); both
        # probe batches share one sorted view of the post-append state
        skey = sorted_geid_view(EB, blk.geid, blk.gperm, new_len)
        sl_se, f_se = geid_slot_lookup(
            EB, blk.geid, blk.gperm, new_len, b.se_eid, skey=skey
        )
        tgt = jnp.where(f_se & se_mask, sl_se, EB)
        props = blk.props.at[tgt, jnp.clip(b.se_pid, 0, nep - 1)].set(
            b.se_val, mode="drop"
        )
        sl_de, f_de = geid_slot_lookup(
            EB, blk.geid, blk.gperm, new_len, b.de_eid, skey=skey
        )
        kt = jnp.where(f_de & de_mask, sl_de, EB)
        alive = blk.alive.at[kt].set(False, mode="drop")
        return blk._replace(
            props=props, alive=alive, blk_len=jnp.reshape(new_len, (1,))
        ), ovf

    out2, ovf_o = apply_block(ps.out, b.ne_src, b.ne_dst)
    inc2, ovf_i = apply_block(ps.inc, b.ne_dst, b.ne_src)

    ps2 = ps._replace(
        vlabel=vlabel, valive=valive, vprops=vprops, vversion=vversion,
        out=out2, inc=inc2,
        v_len=ps.v_len + b.nv_n, e_len=ps.e_len + b.ne_n,
        version=new_version,
    )
    # post-change edge-prop rows (for key calc), from the post-state blocks
    f_sp, _, _, _, se_post_rows = _lookup_block(pspec, ps2.out, b.se_eid, psum)
    se_props_new = jnp.where(
        se_mask[:, None],
        jnp.where(f_sp[:, None], se_post_rows, PROP_MISSING), PROP_MISSING,
    )
    applied = AppliedMutations(
        batch=batch, ne_eid=ne_eid, nv_vid=nv_vid,
        de_src=de_src, de_dst=de_dst, de_label=de_label, de_props=de_props,
        sv_old=sv_old, se_old=se_old, se_src=se_src, se_dst=se_dst,
        se_label=se_label, se_props=se_props_new,
        commit_version=new_version,
    )
    return ps2, applied, psum(ovf_o + ovf_i)


def local_shard(pspec: PartitionedStoreSpec, ps: PartitionedGraphStore, s: int):
    """Slice shard ``s``'s local view out of a global partitioned store
    (host-side; inside ``shard_map`` the runtime sees this shape directly)."""
    EB, Vloc, n = pspec.e_blk_cap, pspec.v_loc, pspec.n_shards

    def blk(b: EdgeBlock) -> EdgeBlock:
        return EdgeBlock(
            key=b.key[s * EB : (s + 1) * EB],
            other=b.other[s * EB : (s + 1) * EB],
            label=b.label[s * EB : (s + 1) * EB],
            alive=b.alive[s * EB : (s + 1) * EB],
            props=b.props[s * EB : (s + 1) * EB],
            geid=b.geid[s * EB : (s + 1) * EB],
            gperm=b.gperm[s * EB : (s + 1) * EB],
            indptr=b.indptr[s * (Vloc + 1) : (s + 1) * (Vloc + 1)],
            blk_len=b.blk_len[s : s + 1],
            csr_len=b.csr_len[s : s + 1],
        )

    return ps._replace(out=blk(ps.out), inc=blk(ps.inc))


def stack_blocks(pspec: PartitionedStoreSpec, ps: PartitionedGraphStore):
    """Reshape a global-layout store's blocks to a leading shard axis
    ``[n, ...]`` — the per-shard view a named-axis vmap (or host-side
    per-shard pass) consumes. Inverse of ``unstack_blocks``; the replicated
    vertex tier and scalars pass through unchanged."""
    n, EB, Vloc = pspec.n_shards, pspec.e_blk_cap, pspec.v_loc

    def blk(b: EdgeBlock) -> EdgeBlock:
        return EdgeBlock(
            key=b.key.reshape(n, EB), other=b.other.reshape(n, EB),
            label=b.label.reshape(n, EB), alive=b.alive.reshape(n, EB),
            props=b.props.reshape(n, EB, -1), geid=b.geid.reshape(n, EB),
            gperm=b.gperm.reshape(n, EB), indptr=b.indptr.reshape(n, Vloc + 1),
            blk_len=b.blk_len.reshape(n, 1), csr_len=b.csr_len.reshape(n, 1),
        )

    return ps._replace(out=blk(ps.out), inc=blk(ps.inc))


def splice_owner_blocks(pspec: PartitionedStoreSpec,
                        dst: PartitionedGraphStore,
                        src: PartitionedGraphStore,
                        owner: int) -> PartitionedGraphStore:
    """Graft owner ``owner``'s out/inc block rows from ``src`` into ``dst``
    (host-side, numpy). This is the recovery-as-migration transport: ``src``
    is the dead shard's reconstructed store (incremental checkpoint +
    journal replay), ``dst`` the live store that kept serving in degraded
    mode — only the lost owner's block region moves, everything else stays
    the live tier's bytes. The replicated vertex tier and global scalars are
    taken from ``src`` as well: during the outage every gRW commit queued in
    the journal unapplied, so the replayed store *is* the durable global
    state (``v_len``/``e_len``/``version`` included) and the live store's
    copy is identical by construction.

    The geid index makes the splice sufficient: ``gperm`` (the sorted
    geid→slot probe permutation) lives inside the block rows and travels
    with them, so the spliced store is immediately servable — no host
    re-sort, no re-index pass."""
    EB, Vloc, s = pspec.e_blk_cap, pspec.v_loc, int(owner)

    def blk(d: EdgeBlock, r: EdgeBlock) -> EdgeBlock:
        def row(dv, rv):
            out = np.asarray(dv).copy()
            out[s * EB:(s + 1) * EB] = np.asarray(rv)[s * EB:(s + 1) * EB]
            return out

        indptr = np.asarray(d.indptr).copy()
        indptr[s * (Vloc + 1):(s + 1) * (Vloc + 1)] = (
            np.asarray(r.indptr)[s * (Vloc + 1):(s + 1) * (Vloc + 1)]
        )
        blk_len = np.asarray(d.blk_len).copy()
        blk_len[s] = np.asarray(r.blk_len)[s]
        csr_len = np.asarray(d.csr_len).copy()
        csr_len[s] = np.asarray(r.csr_len)[s]
        return EdgeBlock(
            key=row(d.key, r.key), other=row(d.other, r.other),
            label=row(d.label, r.label), alive=row(d.alive, r.alive),
            props=row(d.props, r.props), geid=row(d.geid, r.geid),
            gperm=row(d.gperm, r.gperm), indptr=indptr,
            blk_len=blk_len, csr_len=csr_len,
        )

    return src._replace(
        out=blk(dst.out, src.out), inc=blk(dst.inc, src.inc),
    )


def unstack_blocks(pspec: PartitionedStoreSpec, ps: PartitionedGraphStore):
    """Flatten shard-stacked blocks back to the global layout."""
    n, EB = pspec.n_shards, pspec.e_blk_cap

    def blk(b: EdgeBlock) -> EdgeBlock:
        return EdgeBlock(
            key=b.key.reshape(-1), other=b.other.reshape(-1),
            label=b.label.reshape(-1), alive=b.alive.reshape(-1),
            props=b.props.reshape(n * EB, -1), geid=b.geid.reshape(-1),
            gperm=b.gperm.reshape(-1), indptr=b.indptr.reshape(-1),
            blk_len=b.blk_len.reshape(-1), csr_len=b.csr_len.reshape(-1),
        )

    return ps._replace(out=blk(ps.out), inc=blk(ps.inc))
