"""Tensorized transactional property-graph store (the FDB/JanusGraph analogue).

Layout (DESIGN.md §2): slotted vertex/edge arrays + CSR indexes over the
compacted prefix, with a linearly-scanned *recent region* for post-compaction
edge inserts — an LSM expressed in fixed-shape tensors. Per-vertex version
counters provide FDB-style optimistic conflict detection at vertex
granularity.
"""

from repro.graphstore.store import (
    GraphStore,
    StoreSpec,
    compact,
    empty_store,
    gather_in,
    gather_out,
    ingest,
)
from repro.graphstore.mutations import (
    AppliedMutations,
    MutationBatch,
    apply_mutations,
    make_mutation_batch,
)
from repro.graphstore.txn import TxnError, commit_with_conflict_check

__all__ = [
    "GraphStore",
    "StoreSpec",
    "empty_store",
    "ingest",
    "gather_out",
    "gather_in",
    "compact",
    "MutationBatch",
    "AppliedMutations",
    "make_mutation_batch",
    "apply_mutations",
    "commit_with_conflict_check",
    "TxnError",
]
