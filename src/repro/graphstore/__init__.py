"""Tensorized transactional property-graph store (the FDB/JanusGraph analogue).

Layout (DESIGN.md §2): slotted vertex/edge arrays + CSR indexes over the
compacted prefix, with a linearly-scanned *recent region* for post-compaction
edge inserts — an LSM expressed in fixed-shape tensors. Per-vertex version
counters provide FDB-style optimistic conflict detection at vertex
granularity.
"""

from repro.graphstore.store import (
    GlobalStoreView,
    GraphStore,
    StoreSpec,
    compact,
    empty_store,
    gather_in,
    gather_out,
    ingest,
)
from repro.graphstore.partition import (
    BlockCapacityError,
    BlockStoreView,
    EdgeBlock,
    PartitionedGraphStore,
    PartitionedStoreSpec,
    apply_mutations_partitioned,
    default_pspec,
    geid_slot_lookup,
    local_of,
    owner_of,
    partition_store,
    rebuild_geid_index,
    splice_owner_blocks,
    store_bytes_report,
)
from repro.graphstore.maintenance import (
    DeviceGate,
    MaintenancePolicy,
    block_occupancy,
    compact_block,
    compact_store,
    decide_maintenance,
    grow_block_local,
    grow_store,
)
from repro.graphstore.journal import (
    EpochRegistry,
    FlushError,
    WriteBehindJournal,
    drain_queued,
    replay,
    replay_to_owner,
    restore_chain,
)
from repro.graphstore.migration import (
    HotSetTracker,
    MigrationEngine,
    MigrationPolicy,
    infer_storage_exceptions,
    migrate_vertex_rows,
    select_migrations,
    vertex_row_counts,
)
from repro.graphstore.mutations import (
    AppliedMutations,
    MutationBatch,
    apply_mutations,
    make_mutation_batch,
)
from repro.graphstore.txn import TxnError, commit_with_conflict_check

__all__ = [
    "GraphStore",
    "GlobalStoreView",
    "StoreSpec",
    "empty_store",
    "ingest",
    "gather_out",
    "gather_in",
    "compact",
    "PartitionedStoreSpec",
    "PartitionedGraphStore",
    "EdgeBlock",
    "BlockStoreView",
    "partition_store",
    "apply_mutations_partitioned",
    "default_pspec",
    "owner_of",
    "local_of",
    "store_bytes_report",
    "BlockCapacityError",
    "geid_slot_lookup",
    "rebuild_geid_index",
    "splice_owner_blocks",
    "MaintenancePolicy",
    "DeviceGate",
    "block_occupancy",
    "compact_block",
    "compact_store",
    "decide_maintenance",
    "grow_block_local",
    "grow_store",
    "WriteBehindJournal",
    "EpochRegistry",
    "FlushError",
    "replay",
    "replay_to_owner",
    "restore_chain",
    "drain_queued",
    "MigrationEngine",
    "MigrationPolicy",
    "HotSetTracker",
    "migrate_vertex_rows",
    "infer_storage_exceptions",
    "select_migrations",
    "vertex_row_counts",
    "MutationBatch",
    "AppliedMutations",
    "make_mutation_batch",
    "apply_mutations",
    "commit_with_conflict_check",
    "TxnError",
]
