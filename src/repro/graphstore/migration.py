"""Hot-vertex block migration for the partitioned dual-CSR storage tier.

The static interleave ``owner_of(v) = v % n`` fixes forever which shard
serves vertex v's misses — under a Zipfian root distribution the shard that
happens to own the hot set becomes the throughput ceiling while its peers
idle. Smart query routing work (see PAPERS.md) moves the *query* to the
data or the *data* to the query; this module is the latter half: a
background engine that physically moves the hottest vertices' dual-CSR
rows between owners, records each move in the write-behind journal as a
``MIGRATE`` record, and publishes the new placement through the replicated
routing table (``distributed.routing``) at a batch boundary — the serving
step never recompiles, because placement is a traced table input.

Mechanics (all host-side, deterministic numpy — the same discipline as
``splice_owner_blocks``, so journal replay reconstructs the post-migration
store byte-for-byte):

- ``migrate_vertex_rows`` moves **all** allocated rows of a vertex (live
  and tombstoned — dead rows keep their geid pre-image exactly as
  compaction without purge does) out of whichever shard currently holds
  them, compacts the source block in slot order, and appends them to the
  destination block's *recent region* in ascending-geid order. At the
  destination the rows are foreign (``key % n != dst``): the CSR window
  cannot index them (local ids alias native vertices), but the
  recent-region key-compare scan serves them exactly like freshly
  appended edges, and the native-aware compaction
  (``maintenance.compact_block(me=...)``) keeps them in the recent region
  across maintenance. Moving a vertex *home* appends to its native
  shard's recent region, where the next compaction folds the rows back
  into the CSR body.
- ``HotSetTracker`` keeps exponentially decayed per-root heat from the
  frontier the serve loop already materializes — no new device work.
- ``select_migrations`` turns (heat, per-owner load, table state) into a
  bounded move list: hottest roots of the most-loaded owner, moved to the
  least-loaded owner, gated by destination recent-window headroom (a
  migrated vertex lives in that window permanently) and routing-table
  capacity.
- ``MigrationEngine`` sequences a round: queue behind any detected outage
  (a move touching a down shard's blocks would fork from the journal's
  replay order), journal first, then move rows, then publish the table.
  The caller swaps the returned store/table in at the next batch
  boundary under the epoch protocol.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphstore.partition import (
    BlockCapacityError,
    EdgeBlock,
    PartitionedGraphStore,
    PartitionedStoreSpec,
)
from repro.distributed.routing import base_owner
from repro.graphstore.store import INT32_MAX
from repro.utils import PROP_MISSING

_PROP_MISSING = np.int32(int(PROP_MISSING))


# ------------------------------------------------------------ row movement
def _np_gperm(EB: int, geid: np.ndarray, blk_len: int) -> np.ndarray:
    """Numpy twin of ``partition.rebuild_geid_index`` (byte-identical)."""
    masked = np.where(np.arange(EB) < blk_len, geid, INT32_MAX)
    return np.argsort(masked, kind="stable").astype(np.int32)


def _np_indptr(keys: np.ndarray, n: int, v_loc: int) -> np.ndarray:
    """CSR row offsets over a sorted native key prefix (``key // n``)."""
    return np.searchsorted(
        keys // n, np.arange(v_loc + 1), side="left"
    ).astype(np.int32)


def _migrate_block(pspec: PartitionedStoreSpec, blk: EdgeBlock,
                   moves: Sequence[Tuple[int, int]]) -> EdgeBlock:
    """One orientation: move every allocated row keyed by each ``vid`` to
    its ``dst`` shard's recent region. Pure numpy, deterministic."""
    n, EB, Vloc = pspec.n_shards, pspec.e_blk_cap, pspec.v_loc
    cols = {
        "key": (np.asarray(jax.device_get(blk.key)).reshape(n, EB).copy(),
                INT32_MAX),
        "other": (np.asarray(jax.device_get(blk.other)).reshape(n, EB).copy(),
                  np.int32(-1)),
        "label": (np.asarray(jax.device_get(blk.label)).reshape(n, EB).copy(),
                  np.int32(-1)),
        "alive": (np.asarray(jax.device_get(blk.alive)).reshape(n, EB).copy(),
                  False),
        "props": (np.asarray(jax.device_get(blk.props)).reshape(
            n, EB, -1).copy(), _PROP_MISSING),
        "geid": (np.asarray(jax.device_get(blk.geid)).reshape(n, EB).copy(),
                 np.int32(-1)),
    }
    blk_len = np.asarray(jax.device_get(blk.blk_len)).astype(np.int64).copy()
    csr_len = np.asarray(jax.device_get(blk.csr_len)).astype(np.int64).copy()
    indptr = np.asarray(jax.device_get(blk.indptr)).reshape(
        n, Vloc + 1).copy()
    touched: set[int] = set()

    for vid, dst in moves:
        vid, dst = int(vid), int(dst)
        for s in range(n):
            if s == dst:
                continue
            L = int(blk_len[s])
            sel = np.nonzero(cols["key"][0][s, :L] == vid)[0]
            if sel.size == 0:
                continue
            k = int(sel.size)
            if blk_len[dst] + k > EB:
                raise BlockCapacityError(
                    f"migration of v{vid} needs {k} rows at shard {dst} "
                    f"({int(blk_len[dst])}/{EB} used)",
                    needed=int(blk_len[dst]) + k,
                )
            # ascending-geid order for the appended run: deterministic and
            # independent of the source block's physical layout
            order = sel[np.argsort(cols["geid"][0][s, sel], kind="stable")]
            keep = np.ones(L, bool)
            keep[sel] = False
            kept = np.nonzero(keep)[0]
            pos = int(blk_len[dst])
            for arr, fill in cols.values():
                moved = arr[s, order].copy()
                arr[s, : kept.size] = arr[s, kept]
                arr[s, kept.size:L] = fill
                arr[dst, pos: pos + k] = moved
            removed_csr = int((sel < csr_len[s]).sum())
            csr_len[s] -= removed_csr
            blk_len[s] = kept.size
            blk_len[dst] += k
            indptr[s] = _np_indptr(
                cols["key"][0][s, : int(csr_len[s])], n, Vloc
            )
            touched.add(s)
            touched.add(dst)
            break  # a vertex's rows live on exactly one shard

    gperm = np.asarray(jax.device_get(blk.gperm)).reshape(n, EB).copy()
    for s in sorted(touched):
        gperm[s] = _np_gperm(EB, cols["geid"][0][s], int(blk_len[s]))
    return EdgeBlock(
        key=jnp.asarray(cols["key"][0].reshape(-1)),
        other=jnp.asarray(cols["other"][0].reshape(-1)),
        label=jnp.asarray(cols["label"][0].reshape(-1)),
        alive=jnp.asarray(cols["alive"][0].reshape(-1)),
        props=jnp.asarray(cols["props"][0].reshape(n * EB, -1)),
        geid=jnp.asarray(cols["geid"][0].reshape(-1)),
        gperm=jnp.asarray(gperm.reshape(-1)),
        indptr=jnp.asarray(indptr.reshape(-1).astype(np.int32)),
        blk_len=jnp.asarray(blk_len.astype(np.int32)),
        csr_len=jnp.asarray(csr_len.astype(np.int32)),
    )


def migrate_vertex_rows(pspec: PartitionedStoreSpec,
                        ps: PartitionedGraphStore,
                        moves: Sequence[Tuple[int, int]],
                        ) -> PartitionedGraphStore:
    """Move each ``(vid, dst)``'s dual-CSR rows (both orientations, live
    and dead) to shard ``dst``'s recent region. Host-side, deterministic —
    journal replay of the same MIGRATE record reconstructs the same bytes.
    Raises ``BlockCapacityError`` if a destination block cannot hold the
    rows (the engine's policy pre-checks headroom, so this is a logic
    error, not an operating condition). The replicated vertex tier and
    global scalars pass through unchanged: migration moves copies, never
    content."""
    if not moves:
        return ps
    return ps._replace(
        out=_migrate_block(pspec, ps.out, moves),
        inc=_migrate_block(pspec, ps.inc, moves),
    )


def infer_storage_exceptions(pspec: PartitionedStoreSpec,
                             ps: PartitionedGraphStore) -> dict:
    """Reconstruct the routing table's storage exceptions from store bytes.

    A vertex's rows live at their table owner, so any allocated row whose
    key is foreign to its shard (``key % n != s``) names an exception
    ``vid -> s``. This is how journal replay resumes the table trajectory
    from a checkpoint taken *after* migrations: the placement is derivable
    from the restored bytes alone, no table snapshot needed."""
    n, EB = pspec.n_shards, pspec.e_blk_cap
    exc: dict[int, int] = {}
    for blk in (ps.out, ps.inc):
        key = np.asarray(jax.device_get(blk.key)).reshape(n, EB)
        ln = np.asarray(jax.device_get(blk.blk_len)).astype(np.int64)
        for s in range(n):
            k = key[s, : int(ln[s])]
            for v in np.unique(k[base_owner(k, n) != s]).tolist():
                exc[int(v)] = s
    return exc


def vertex_row_counts(pspec: PartitionedStoreSpec, ps: PartitionedGraphStore,
                      vids: Sequence[int]) -> np.ndarray:
    """Allocated rows (live + dead, out + inc) keyed by each vid — the
    migration cost of a vertex."""
    n, EB = pspec.n_shards, pspec.e_blk_cap
    out = np.zeros(len(vids), np.int64)
    for blk in (ps.out, ps.inc):
        key = np.asarray(jax.device_get(blk.key)).reshape(n, EB)
        ln = np.asarray(jax.device_get(blk.blk_len)).astype(np.int64)
        alloc = np.arange(EB)[None, :] < ln[:, None]
        for i, v in enumerate(vids):
            out[i] += int(((key == int(v)) & alloc).sum())
    return out


# ------------------------------------------------------------- heat signal
class HotSetTracker:
    """Exponentially decayed per-root heat from served frontiers.

    ``observe(roots)`` decays all heat by ``decay`` and adds one unit per
    root occurrence (host numpy — the serve loop already has the root ids
    on host for routing). The map is pruned to ``cap`` entries by heat, so
    memory stays bounded under arbitrary workloads.
    """

    def __init__(self, decay: float = 0.9, cap: int = 4096):
        self.decay = float(decay)
        self.cap = int(cap)
        self._heat: dict[int, float] = {}

    def observe(self, roots) -> None:
        r = np.asarray(roots).reshape(-1)
        r = r[r >= 0]
        if self.decay < 1.0 and self._heat:
            self._heat = {v: h * self.decay for v, h in self._heat.items()}
        vals, cnt = np.unique(r, return_counts=True)
        for v, c in zip(vals.tolist(), cnt.tolist()):
            self._heat[int(v)] = self._heat.get(int(v), 0.0) + float(c)
        if len(self._heat) > self.cap:
            keep = sorted(self._heat.items(), key=lambda kv: -kv[1])
            self._heat = dict(keep[: self.cap])

    def hottest(self, k: int) -> list:
        """Top-k ``(vid, heat)`` pairs, hottest first (ties by vid)."""
        return sorted(
            self._heat.items(), key=lambda kv: (-kv[1], kv[0])
        )[: int(k)]

    def heat(self, vid: int) -> float:
        return self._heat.get(int(vid), 0.0)

    def total_heat(self) -> float:
        return float(sum(self._heat.values()))


# ------------------------------------------------------------------ policy
class MigrationPolicy(NamedTuple):
    """When and what to migrate.

    ``load_share_trigger`` — act only when the hottest owner's share of
    frontier rows exceeds this multiple of the fair share ``1/n``.
    ``max_moves_per_round`` — move-list bound per engine step (each move
    is a journal record and a host splice; keep rounds small).
    ``min_heat`` — ignore roots colder than this (heat units ≈ decayed
    request counts).
    ``max_rows_per_vertex`` — skip vertices whose dual-CSR rows exceed
    this (they must fit — and keep fitting — inside the destination's
    bounded recent-scan window).
    ``dst_recent_headroom_frac`` — keep the destination's recent fill
    (existing + migrated rows) under this fraction of
    ``recent_blk_cap``: a migrated vertex occupies the window
    permanently, and appends falling off the window silently vanish
    from reads.
    ``move_cooldown_rounds`` — a vertex the engine just moved is not a
    candidate again for this many rounds: a hot vertex whose load alone
    exceeds the fair share would otherwise ping-pong between owners
    every round (each bounce a journal record and a splice) without the
    balance ever improving.
    """

    load_share_trigger: float = 1.25
    max_moves_per_round: int = 4
    min_heat: float = 1.0
    max_rows_per_vertex: int = 64
    dst_recent_headroom_frac: float = 0.5
    move_cooldown_rounds: int = 8


def select_migrations(policy: MigrationPolicy, tracker: HotSetTracker,
                      rhost, pspec: PartitionedStoreSpec,
                      ps: PartitionedGraphStore,
                      owner_rows, *, cooldown=frozenset()) -> list:
    """Choose this round's moves from (heat, per-owner load, table state).

    ``owner_rows`` is the per-owner frontier-row load ([n], e.g. the
    ``frontier_rows`` column of ``obs.owner_stage_rows``). Returns
    ``[(vid, dst), ...]`` — hottest vertices currently served by the
    most-loaded owner, spread across the least-loaded owners, subject to
    the policy's fit bounds and the routing table's exception capacity.

    Destinations are chosen greedily against a working copy of the load
    vector: each move's load estimate (the vertex's share of tracked
    heat, capped at the hot owner's excess over fair share) lands on the
    projected-coldest owner, and a move is only taken when the projected
    destination stays strictly below the hot owner's current load —
    dumping the whole hot set on one cold shard would just relocate the
    bottleneck. ``cooldown`` vertices are skipped (see
    ``MigrationPolicy.move_cooldown_rounds``).
    """
    n = pspec.n_shards
    rows = np.asarray(owner_rows, np.float64).reshape(-1).copy()
    assert rows.shape[0] == n, (rows.shape, n)
    total = float(rows.sum())
    if total <= 0:
        return []
    hot_owner = int(rows.argmax())
    trigger = policy.load_share_trigger * total / n
    if float(rows[hot_owner]) < trigger:
        return []
    table_room = rhost.cap - len(rhost.storage_exceptions)
    budget = min(policy.max_moves_per_round, max(table_room, 0))
    if budget <= 0:
        return []

    # per-destination recent-window headroom (max fill across orientations
    # — both blocks receive the vertex's rows)
    cap = int(policy.dst_recent_headroom_frac * pspec.recent_blk_cap)
    fill = np.zeros(n, np.int64)
    for blk in (ps.out, ps.inc):
        ln = np.asarray(jax.device_get(blk.blk_len)).astype(np.int64)
        cs = np.asarray(jax.device_get(blk.csr_len)).astype(np.int64)
        fill = np.maximum(fill, ln - cs)
    headroom = cap - fill

    total_heat = max(tracker.total_heat(), 1e-12)
    moves = []
    for vid, heat in tracker.hottest(4 * policy.max_moves_per_round):
        if heat < policy.min_heat or len(moves) >= budget:
            break
        if float(rows[hot_owner]) < trigger:
            break  # balanced enough — don't churn the tail
        if int(vid) in cooldown or rhost.storage_owner(vid) != hot_owner:
            continue
        cost = int(vertex_row_counts(pspec, ps, [vid])[0])
        if cost == 0 or cost > policy.max_rows_per_vertex:
            continue
        excess = float(rows[hot_owner]) - total / n
        est = min(heat / total_heat * total, excess)
        order = np.argsort(rows, kind="stable")
        dst = next(
            (int(o) for o in order
             if int(o) != hot_owner and headroom[int(o)] >= cost
             and float(rows[int(o)]) + est < float(rows[hot_owner])),
            None,
        )
        if dst is None:
            continue
        headroom[dst] -= cost
        rows[hot_owner] -= est
        rows[dst] += est
        moves.append((int(vid), dst))
    return moves


# ------------------------------------------------------------------ engine
class MigrationEngine:
    """Background migration sequencer: journal → move → publish.

    One ``step`` call runs at most one migration round. It refuses to act
    while ``detector`` reports any shard down (recovery replays the
    journal in commit order; a migration interleaved with an outage would
    have to replay against a store the dead shard never saw) — the round
    simply waits for the next step after recovery. The caller installs
    the returned store and re-stamps ``rhost.device_table()`` at the next
    batch boundary; in-flight epoch-pinned readers finished against the
    old placement because the table they traced was an input of their
    batch.
    """

    def __init__(self, pspec: PartitionedStoreSpec, rhost, *,
                 policy: Optional[MigrationPolicy] = None,
                 tracker: Optional[HotSetTracker] = None,
                 journal=None, detector=None):
        self.pspec = pspec
        self.rhost = rhost
        self.policy = policy or MigrationPolicy()
        self.tracker = tracker or HotSetTracker()
        self.journal = journal
        self.detector = detector
        self.rounds = 0
        self.moved_vertices = 0
        self.moved_rows = 0
        self.deferred_rounds = 0
        self._steps = 0
        self._cooldown: dict = {}  # vid -> step index the cooldown expires at

    def observe(self, roots) -> None:
        self.tracker.observe(roots)

    def step(self, ps: PartitionedGraphStore, owner_rows):
        """Maybe run one migration round. Returns ``(store, moves)`` —
        the (possibly unchanged) store and the applied move list."""
        if self.detector is not None and bool(
            np.asarray(self.detector.down_mask()).any()
        ):
            self.deferred_rounds += 1
            return ps, []
        self._steps += 1
        self._cooldown = {
            v: e for v, e in self._cooldown.items() if e > self._steps
        }
        moves = select_migrations(
            self.policy, self.tracker, self.rhost, self.pspec, ps,
            owner_rows, cooldown=self._cooldown.keys(),
        )
        if not moves:
            return ps, []
        for v, _ in moves:
            self._cooldown[v] = self._steps + self.policy.move_cooldown_rounds
        rows = int(
            vertex_row_counts(self.pspec, ps, [v for v, _ in moves]).sum()
        )
        # journal first: a crash after the append but before the in-memory
        # apply replays the move; a crash before the append replays none
        # of it — either way the recovered store is one of the two control
        # states, never torn
        if self.journal is not None:
            self.journal.append_migrate(moves)
        ps = migrate_vertex_rows(self.pspec, ps, moves)
        self.rhost.apply_moves(moves)
        self.rounds += 1
        self.moved_vertices += len(moves)
        self.moved_rows += rows
        return ps, moves

    def metrics(self) -> dict:
        return {
            "migration_rounds": self.rounds,
            "migrated_vertices": self.moved_vertices,
            "migrated_rows": self.moved_rows,
            "migration_deferred_rounds": self.deferred_rounds,
            **self.rhost.metrics(),
        }
