"""Batched graph mutations (the write half of gRW-Txs).

A ``MutationBatch`` is a structure-of-arrays with one fixed-capacity section
per change type from §3.2 of the paper. ``apply_mutations`` applies the whole
batch as one commit: it snapshots the *old* state the paper's mutation
listener needs (Algorithms 1–9 take both old and new values), applies the
writes functionally, and bumps per-vertex versions — the write-conflict
ranges used by optimistic CP-population commits.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphstore.store import GraphStore, StoreSpec
from repro.utils import PROP_MISSING, take_along0


class MutationBatch(NamedTuple):
    """Padded change sections. ``*_n`` is the live count per section."""

    # add vertices
    nv_label: jax.Array  # int32 [KNV]
    nv_props: jax.Array  # int32 [KNV, n_vprops]
    nv_n: jax.Array
    # add edges
    ne_src: jax.Array  # int32 [KNE]
    ne_dst: jax.Array
    ne_label: jax.Array
    ne_props: jax.Array  # int32 [KNE, n_eprops]
    ne_n: jax.Array
    # delete edges
    de_eid: jax.Array  # int32 [KDE]
    de_n: jax.Array
    # delete vertices
    dv_vid: jax.Array  # int32 [KDV]
    dv_n: jax.Array
    # set/del vertex property (val == PROP_MISSING deletes the property)
    sv_vid: jax.Array  # int32 [KSV]
    sv_pid: jax.Array
    sv_val: jax.Array
    sv_n: jax.Array
    # set/del edge property
    se_eid: jax.Array  # int32 [KSE]
    se_pid: jax.Array
    se_val: jax.Array
    se_n: jax.Array


class AppliedMutations(NamedTuple):
    """Old-state snapshots captured at apply time, consumed by invalidation."""

    batch: MutationBatch
    ne_eid: jax.Array  # assigned edge slots [KNE]
    nv_vid: jax.Array  # assigned vertex slots [KNV]
    # deleted-edge pre-images
    de_src: jax.Array
    de_dst: jax.Array
    de_label: jax.Array
    de_props: jax.Array  # [KDE, n_eprops]
    # vertex-prop pre-images
    sv_old: jax.Array  # [KSV]
    # edge-prop pre-images and the (immutable) edge identity
    se_old: jax.Array  # [KSE]
    se_src: jax.Array
    se_dst: jax.Array
    se_label: jax.Array
    se_props: jax.Array  # [KSE, n_eprops] post-change props (for key calc)
    commit_version: jax.Array  # int32 scalar


def _pad(arr, cap, fill=0, dtype=jnp.int32):
    a = np.asarray(arr, dtype=np.int32).reshape(len(arr), *np.shape(arr)[1:])
    out = np.full((cap,) + a.shape[1:], fill, dtype=np.int32)
    out[: len(a)] = a
    return jnp.asarray(out, dtype)


def make_mutation_batch(
    spec: StoreSpec,
    *,
    new_vertices: Sequence = (),  # (label, props[n_vprops])
    new_edges: Sequence = (),  # (src, dst, label, props[n_eprops])
    del_edges: Sequence = (),  # eid
    del_vertices: Sequence = (),  # vid
    set_vprops: Sequence = (),  # (vid, pid, val)
    set_eprops: Sequence = (),  # (eid, pid, val)
    caps: tuple = (8, 32, 32, 8, 32, 32),
) -> MutationBatch:
    """Host-side builder: pads python change lists into a MutationBatch."""
    knv, kne, kde, kdv, ksv, kse = caps
    assert len(new_vertices) <= knv and len(new_edges) <= kne
    assert len(del_edges) <= kde and len(del_vertices) <= kdv
    assert len(set_vprops) <= ksv and len(set_eprops) <= kse
    nv_label = _pad([v[0] for v in new_vertices], knv, -1)
    nv_props = _pad(
        [v[1] for v in new_vertices] or np.zeros((0, spec.n_vprops)),
        knv,
        int(PROP_MISSING),
    ).reshape(knv, spec.n_vprops)
    ne = list(new_edges)
    ne_props = _pad(
        [e[3] for e in ne] or np.zeros((0, spec.n_eprops)), kne, int(PROP_MISSING)
    ).reshape(kne, spec.n_eprops)
    sv = list(set_vprops)
    se = list(set_eprops)
    return MutationBatch(
        nv_label=nv_label,
        nv_props=nv_props,
        nv_n=jnp.int32(len(new_vertices)),
        ne_src=_pad([e[0] for e in ne], kne, -1),
        ne_dst=_pad([e[1] for e in ne], kne, -1),
        ne_label=_pad([e[2] for e in ne], kne, -1),
        ne_props=ne_props,
        ne_n=jnp.int32(len(ne)),
        de_eid=_pad(list(del_edges), kde, -1),
        de_n=jnp.int32(len(del_edges)),
        dv_vid=_pad(list(del_vertices), kdv, -1),
        dv_n=jnp.int32(len(del_vertices)),
        sv_vid=_pad([x[0] for x in sv], ksv, -1),
        sv_pid=_pad([x[1] for x in sv], ksv, 0),
        sv_val=_pad([x[2] for x in sv], ksv, int(PROP_MISSING)),
        sv_n=jnp.int32(len(sv)),
        se_eid=_pad([x[0] for x in se], kse, -1),
        se_pid=_pad([x[1] for x in se], kse, 0),
        se_val=_pad([x[2] for x in se], kse, int(PROP_MISSING)),
        se_n=jnp.int32(len(se)),
    )


def _sec_mask(ids, n):
    return jnp.arange(ids.shape[0]) < n


def shard_mutation_rows(applied: AppliedMutations, n: int, me) -> AppliedMutations:
    """Round-robin slice of every change section for shard ``me`` of ``n``.

    The sharded write path's phase A: each shard runs the mutation listener
    over rows ``me, me+n, me+2n, ...`` of every section (both the batch
    arrays and the listener's pre-image snapshots), so the impact-derivation
    work — the expensive reverse traversals of Algorithm 7 — is split across
    the mesh instead of replicated. Section live counts are recomputed for
    the slice. Local row ``j`` of shard ``me`` is global row ``me + n*j``
    (``row_offset``/``row_stride`` of ``derive_cache_ops``), which keeps the
    cross-shard op stream totally ordered. ``me`` may be a traced
    ``axis_index`` — slicing is gather-based, shapes stay static.
    """
    me = jnp.asarray(me, jnp.int32)

    def sl(count, *arrs):
        K = arrs[0].shape[0]
        idx = me + n * jnp.arange(-(-K // n), dtype=jnp.int32)
        out = [take_along0(a, idx) for a in arrs]
        local_n = jnp.sum((idx < count).astype(jnp.int32))
        return [local_n] + out

    b = applied.batch
    nv_n, nv_label, nv_props, nv_vid = sl(b.nv_n, b.nv_label, b.nv_props, applied.nv_vid)
    ne_n, ne_src, ne_dst, ne_label, ne_props, ne_eid = sl(
        b.ne_n, b.ne_src, b.ne_dst, b.ne_label, b.ne_props, applied.ne_eid
    )
    de_n, de_eid, de_src, de_dst, de_label, de_props = sl(
        b.de_n, b.de_eid, applied.de_src, applied.de_dst, applied.de_label,
        applied.de_props,
    )
    dv_n, dv_vid = sl(b.dv_n, b.dv_vid)
    sv_n, sv_vid, sv_pid, sv_val, sv_old = sl(
        b.sv_n, b.sv_vid, b.sv_pid, b.sv_val, applied.sv_old
    )
    se_n, se_eid, se_pid, se_val, se_old, se_src, se_dst, se_label, se_props = sl(
        b.se_n, b.se_eid, b.se_pid, b.se_val, applied.se_old, applied.se_src,
        applied.se_dst, applied.se_label, applied.se_props,
    )
    batch = MutationBatch(
        nv_label=nv_label, nv_props=nv_props, nv_n=nv_n,
        ne_src=ne_src, ne_dst=ne_dst, ne_label=ne_label, ne_props=ne_props,
        ne_n=ne_n,
        de_eid=de_eid, de_n=de_n,
        dv_vid=dv_vid, dv_n=dv_n,
        sv_vid=sv_vid, sv_pid=sv_pid, sv_val=sv_val, sv_n=sv_n,
        se_eid=se_eid, se_pid=se_pid, se_val=se_val, se_n=se_n,
    )
    return AppliedMutations(
        batch=batch, ne_eid=ne_eid, nv_vid=nv_vid,
        de_src=de_src, de_dst=de_dst, de_label=de_label, de_props=de_props,
        sv_old=sv_old, se_old=se_old, se_src=se_src, se_dst=se_dst,
        se_label=se_label, se_props=se_props,
        commit_version=applied.commit_version,
    )


def apply_mutations(
    spec: StoreSpec, store: GraphStore, batch: MutationBatch
) -> tuple[GraphStore, AppliedMutations]:
    """Apply one commit. Returns the new store and the listener snapshot."""
    new_version = store.version + 1

    # ---- pre-images (captured against the pre-state) -----------------------
    de_mask = _sec_mask(batch.de_eid, batch.de_n)
    de_src = jnp.where(de_mask, take_along0(store.esrc, batch.de_eid), -1)
    de_dst = jnp.where(de_mask, take_along0(store.edst, batch.de_eid), -1)
    de_label = jnp.where(de_mask, take_along0(store.elabel, batch.de_eid), -1)
    de_props = jnp.where(
        de_mask[:, None], take_along0(store.eprops, batch.de_eid), PROP_MISSING
    )
    sv_mask = _sec_mask(batch.sv_vid, batch.sv_n)
    sv_rows = take_along0(store.vprops, batch.sv_vid)
    sv_old = jnp.where(
        sv_mask,
        jnp.take_along_axis(
            sv_rows, jnp.clip(batch.sv_pid, 0, spec.n_vprops - 1)[:, None], axis=1
        )[:, 0],
        PROP_MISSING,
    )
    se_mask = _sec_mask(batch.se_eid, batch.se_n)
    se_rows = take_along0(store.eprops, batch.se_eid)
    se_old = jnp.where(
        se_mask,
        jnp.take_along_axis(
            se_rows, jnp.clip(batch.se_pid, 0, spec.n_eprops - 1)[:, None], axis=1
        )[:, 0],
        PROP_MISSING,
    )
    se_src = jnp.where(se_mask, take_along0(store.esrc, batch.se_eid), -1)
    se_dst = jnp.where(se_mask, take_along0(store.edst, batch.se_eid), -1)
    se_label = jnp.where(se_mask, take_along0(store.elabel, batch.se_eid), -1)

    # ---- allocate new vertex / edge slots ----------------------------------
    knv = batch.nv_label.shape[0]
    kne = batch.ne_src.shape[0]
    nv_mask = _sec_mask(batch.nv_label, batch.nv_n)
    ne_mask = _sec_mask(batch.ne_src, batch.ne_n)
    nv_vid = jnp.where(nv_mask, store.v_len + jnp.arange(knv, dtype=jnp.int32), -1)
    ne_eid = jnp.where(ne_mask, store.e_len + jnp.arange(kne, dtype=jnp.int32), -1)
    nv_idx = jnp.where(nv_mask, nv_vid, spec.v_cap)  # OOB -> scatter-drop
    ne_idx = jnp.where(ne_mask, ne_eid, spec.e_cap)

    vlabel = store.vlabel.at[nv_idx].set(batch.nv_label, mode="drop")
    valive = store.valive.at[nv_idx].set(True, mode="drop")
    vprops = store.vprops.at[nv_idx].set(batch.nv_props, mode="drop")
    esrc = store.esrc.at[ne_idx].set(batch.ne_src, mode="drop")
    edst = store.edst.at[ne_idx].set(batch.ne_dst, mode="drop")
    elabel = store.elabel.at[ne_idx].set(batch.ne_label, mode="drop")
    ealive = store.ealive.at[ne_idx].set(True, mode="drop")
    eprops = store.eprops.at[ne_idx].set(batch.ne_props, mode="drop")

    # ---- property writes ----------------------------------------------------
    sv_idx = jnp.where(sv_mask, batch.sv_vid, spec.v_cap)
    vprops = vprops.at[sv_idx, jnp.clip(batch.sv_pid, 0, spec.n_vprops - 1)].set(
        batch.sv_val, mode="drop"
    )
    se_idx = jnp.where(se_mask, batch.se_eid, spec.e_cap)
    eprops = eprops.at[se_idx, jnp.clip(batch.se_pid, 0, spec.n_eprops - 1)].set(
        batch.se_val, mode="drop"
    )
    se_props_new = jnp.where(se_mask[:, None], take_along0(eprops, batch.se_eid), PROP_MISSING)

    # ---- deletes -------------------------------------------------------------
    de_idx = jnp.where(de_mask, batch.de_eid, spec.e_cap)
    ealive = ealive.at[de_idx].set(False, mode="drop")
    dv_mask = _sec_mask(batch.dv_vid, batch.dv_n)
    dv_idx = jnp.where(dv_mask, batch.dv_vid, spec.v_cap)
    valive = valive.at[dv_idx].set(False, mode="drop")

    # ---- version bumps (write-conflict ranges at vertex granularity) -------
    vversion = store.vversion
    for vid, m in (
        (batch.ne_src, ne_mask),
        (batch.ne_dst, ne_mask),
        (de_src, de_mask),
        (de_dst, de_mask),
        (batch.sv_vid, sv_mask),
        (se_src, se_mask),
        (se_dst, se_mask),
        (batch.dv_vid, dv_mask),
        (nv_vid, nv_mask),
    ):
        vversion = vversion.at[jnp.where(m, vid, spec.v_cap)].set(
            new_version, mode="drop"
        )

    new_store = store._replace(
        vlabel=vlabel,
        valive=valive,
        vprops=vprops,
        vversion=vversion,
        esrc=esrc,
        edst=edst,
        elabel=elabel,
        ealive=ealive,
        eprops=eprops,
        v_len=store.v_len + batch.nv_n,
        e_len=store.e_len + batch.ne_n,
        version=new_version,
    )
    applied = AppliedMutations(
        batch=batch,
        ne_eid=ne_eid,
        nv_vid=nv_vid,
        de_src=de_src,
        de_dst=de_dst,
        de_label=de_label,
        de_props=de_props,
        sv_old=sv_old,
        se_old=se_old,
        se_src=se_src,
        se_dst=se_dst,
        se_label=se_label,
        se_props=se_props_new,
        commit_version=new_version,
    )
    return new_store, applied
