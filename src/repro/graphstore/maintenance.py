"""Owner-local block maintenance: incremental compaction, index rebuilds,
and capacity elasticity for the partitioned dual-CSR storage tier.

The paper's cache (§4) sits in front of a storage manager that keeps serving
reads while writes land transactionally in the background — FDB's B-tree
plus its in-memory write buffer. Our partitioned tier reproduces the *read*
half of that split (physically CSR-sorted block bodies + per-block recent
append regions, ``partition.EdgeBlock``), and this module supplies the
*write-path background* half: the block-local analogue of the single-host
``store.compact`` plus the policy machinery that decides when shards run it.
Besta et al. (Demystifying Graph Databases) frame the design point exactly:
sorted-CSR read performance requires a dynamic-adjacency write buffer *and*
periodic compaction — without it, recent regions grow until reads silently
fall off the bounded append-scan window and blocks overflow at append time,
forcing a full host-side repartition.

Three pieces, all owner-local (no collectives — each shard maintains its own
blocks independently, exactly like an FDB storage server compacting its own
B-tree while the commit pipeline keeps running):

- ``compact_block`` — a jittable pass merging a block's recent region into
  the physically sorted CSR body: stable re-sort by (key, geid), indptr
  rebuild over the merged body, geid→slot index rebuild, and (opt-in)
  tombstone purge. Read results are byte-identical before/after — CSR lanes
  ascend by geid within a root and recent geids exceed all CSR geids, so the
  merged lane order per root is exactly the pre-compaction gather order.
  With ``purge=False`` (the default) the compacted block equals the
  ``partition_store`` of the host-compacted store byte-for-byte, which is
  the identity the property tests pin. ``purge=True`` additionally reclaims
  dead-edge slots; reads are unaffected (dead lanes were masked anyway), but
  a later mutation section naming a purged geid resolves to "not found"
  instead of the host's slot-array pre-image, so purge is an explicit opt-in
  for deployments whose write stream never re-references deleted edges.

- ``grow_store`` — capacity elasticity: re-pad every block to a larger
  ``e_blk_cap`` (fills mirror ``partition_store``'s empty lanes, the
  geid→slot index extends in place) instead of asserting at ingest or
  overflowing at append time. Growing is a shape change, so callers must
  recompile anything closed over the old spec
  (``ShardedTxnRuntime.grow_blocks`` handles the cache invalidation).

- ``MaintenancePolicy`` / ``decide_maintenance`` — when to do either:
  compact when any block's recent fill crosses a fraction of its append-scan
  window (the read-correctness bound) or after a mutation-row budget (the
  latency-amortization bound); grow when occupancy crosses a high-water
  fraction. ``block_occupancy`` surfaces the inputs (per-shard occupancy and
  recent fill) for runtime metrics and serve-loop telemetry.

``ShardedTxnRuntime.maintenance_tick`` schedules all of this between
transaction batches, which is what lets shards run indefinitely under gRW
traffic without a host round-trip.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphstore.partition import (
    EdgeBlock,
    PartitionedGraphStore,
    PartitionedStoreSpec,
    rebuild_geid_index,
    stack_blocks,
    unstack_blocks,
)
from repro.graphstore.store import INT32_MAX
from repro.utils import PROP_MISSING, take_along0


# ------------------------------------------------------------- compaction
class DeviceGate(NamedTuple):
    """Static config of the on-device maintenance gate compiled into a gRW
    step (``ShardedTxnRuntime.grw_step(gate=...)``): each shard compacts
    its own blocks inside the commit program (``lax.cond``) once a block's
    recent fill crosses ``recent_fill_frac`` of the append-scan window —
    no per-batch host round-trip, and the decision is a pure function of
    (store, batch, gate), so journal replay of the same commits through the
    same gated step reproduces block layout deterministically. ``purge``
    additionally reclaims tombstone lanes (enable it per batch only when
    ``journal.EpochRegistry.safe_to_purge`` says so)."""

    recent_fill_frac: float = 0.5
    purge: bool = False


def compact_block(pspec: PartitionedStoreSpec, blk: EdgeBlock, *,
                  purge: bool = False, me=None) -> EdgeBlock:
    """Merge one shard's block recent region into its sorted CSR body.

    Operates on a *local* block view (shapes ``[e_blk_cap]``, the slice a
    shard sees inside ``shard_map``; host callers slice via ``local_shard``
    or vmap with ``compact_store``). Jittable, owner-local, no collectives.

    The merged body is the stable (key, geid) sort of every allocated edge —
    recent geids exceed all CSR geids, so per-root lane order (and therefore
    every gather observable) is unchanged; afterwards the recent region is
    empty (``csr_len == blk_len``) and every edge is range-readable. With
    ``purge=False`` dead-but-allocated edges keep CSR lanes exactly like the
    single-host ``store.compact``, making the result byte-identical to
    ``partition_store(compact(host_store))``; ``purge=True`` drops them and
    reclaims their slots (see module docstring for the pre-image caveat).

    ``me`` (this shard's index — ``lax.axis_index`` inside ``shard_map``,
    or the block row under ``compact_store``'s vmap) makes the merge
    *native-aware* for blocks holding migrated-in rows
    (``graphstore.migration``): only native rows (``key % n == me``) join
    the CSR body — a foreign row merged into CSR would be unreachable,
    because the CSR window indexes by aliased local id. Foreign live rows
    instead form a sorted prefix of the recent region (``[csr_len,
    blk_len)``), where the key-compare scan keeps serving them. On a block
    with no foreign rows the result is byte-identical to ``me=None`` — the
    extra sort tier is constant over kept lanes — so passing ``me``
    unconditionally is safe.
    """
    EB, Vloc, n = pspec.e_blk_cap, pspec.v_loc, pspec.n_shards
    lanes = jnp.arange(EB, dtype=jnp.int32)
    keep = lanes < blk.blk_len[0]
    if purge:
        keep &= blk.alive
    if me is None:
        native = keep
    else:
        native = keep & (jnp.mod(blk.key, n) == me)
    # three-tier lexicographic (tier, key, geid) stable sort: native live
    # rows form the CSR body, foreign live rows the recent region, dropped
    # lanes sink to the end in slot order (mirroring the host-side block
    # construction)
    tier = jnp.where(native, 0, jnp.where(keep, 1, 2)).astype(jnp.int32)
    skey = jnp.where(keep, blk.key, INT32_MAX)
    sgeid = jnp.where(keep, blk.geid, INT32_MAX)
    perm = jnp.argsort(sgeid, stable=True)
    perm = perm[jnp.argsort(skey[perm], stable=True)]
    perm = perm[jnp.argsort(tier[perm], stable=True)]
    new_len = jnp.sum(keep.astype(jnp.int32))
    csr_len = jnp.sum(native.astype(jnp.int32))
    live = lanes < new_len

    def take(a, fill):
        g = take_along0(a, perm)
        m = live if g.ndim == 1 else live[:, None]
        return jnp.where(m, g, jnp.asarray(fill, a.dtype))

    key = take(blk.key, INT32_MAX)
    other = take(blk.other, -1)
    label = take(blk.label, -1)
    alive = take(blk.alive, False)
    props = take(blk.props, PROP_MISSING)
    geid = take(blk.geid, -1)
    # CSR row offsets over the *native* prefix (interleaved: local =
    # key // n); lanes past csr_len — foreign rows and fills — are masked
    # to INT32_MAX so they sort past every local index
    indptr = jnp.searchsorted(
        jnp.where(lanes < csr_len, key // n, INT32_MAX),
        jnp.arange(Vloc + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    return EdgeBlock(
        key=key, other=other, label=label, alive=alive, props=props,
        geid=geid, gperm=rebuild_geid_index(new_len, geid), indptr=indptr,
        blk_len=jnp.reshape(new_len, (1,)),
        csr_len=jnp.reshape(csr_len, (1,)),
    )


def compact_store(pspec: PartitionedStoreSpec, ps: PartitionedGraphStore, *,
                  purge: bool = False, native_only: bool = False,
                  tracer=None) -> PartitionedGraphStore:
    """Compact every shard's blocks of a *global-layout* partitioned store
    (host-side helper; the runtime runs ``compact_block`` inside shard_map
    instead). The replicated vertex tier and scalars pass through.
    ``native_only`` threads each block's shard index as ``me`` so
    migrated-in foreign rows stay in the recent region (required once any
    migration has run; byte-identical to the default on unmigrated stores).
    ``tracer`` (a ``repro.obs.trace.Tracer``) records the pass as a
    ``compact_store`` span; default is the no-op tracer."""
    if tracer is None:
        from repro.obs.trace import NULL_TRACER

        tracer = NULL_TRACER
    with tracer.span("compact_store"):
        if native_only:
            fn = jax.vmap(
                lambda blk, m: compact_block(pspec, blk, purge=purge, me=m),
                in_axes=(0, 0),
            )
            mes = jnp.arange(pspec.n_shards, dtype=jnp.int32)
            run = lambda b: fn(b, mes)
        else:
            run = jax.vmap(lambda blk: compact_block(pspec, blk, purge=purge))
        stacked = stack_blocks(pspec, ps)
        return unstack_blocks(
            pspec, stacked._replace(out=run(stacked.out), inc=run(stacked.inc))
        )


# ------------------------------------------------------------- elasticity
def grow_store(pspec: PartitionedStoreSpec, ps: PartitionedGraphStore,
               e_blk_cap: int, *, recent_blk_cap: int | None = None):
    """Re-pad every block to a larger ``e_blk_cap`` (host-side).

    Returns ``(new_pspec, new_store)``. Per shard, existing rows keep their
    slots, new tail lanes carry the same fills as freshly partitioned empty
    lanes, and the geid→slot index extends in place (allocated slots are a
    block prefix, so the index tail is the ascending unallocated slots — the
    grown result is byte-identical to ``partition_store`` under the grown
    spec). ``indptr`` / ``blk_len`` / ``csr_len`` are per-vertex/per-shard
    and unchanged. Callers owning compiled programs closed over the old spec
    must invalidate them (``ShardedTxnRuntime.grow_blocks`` does).
    """
    n, EB = pspec.n_shards, pspec.e_blk_cap
    assert e_blk_cap >= EB, (e_blk_cap, EB)
    rb = pspec.recent_blk_cap if recent_blk_cap is None else recent_blk_cap
    new_pspec = pspec._replace(
        e_blk_cap=e_blk_cap, recent_blk_cap=min(rb, e_blk_cap)
    )

    def blk(b: EdgeBlock) -> EdgeBlock:
        def pad(a, fill):
            x = np.asarray(a).reshape(n, EB, *np.shape(a)[1:])
            out = np.full((n, e_blk_cap) + x.shape[2:], fill, x.dtype)
            out[:, :EB] = x
            return jnp.asarray(out.reshape((n * e_blk_cap,) + x.shape[2:]))

        gp = np.tile(np.arange(e_blk_cap, dtype=np.int32), (n, 1))
        gp[:, :EB] = np.asarray(b.gperm).reshape(n, EB)
        return EdgeBlock(
            key=pad(b.key, INT32_MAX), other=pad(b.other, -1),
            label=pad(b.label, -1), alive=pad(b.alive, False),
            props=pad(b.props, np.int32(int(PROP_MISSING))),
            geid=pad(b.geid, -1), gperm=jnp.asarray(gp.reshape(-1)),
            indptr=jnp.asarray(np.asarray(b.indptr)),
            blk_len=jnp.asarray(np.asarray(b.blk_len)),
            csr_len=jnp.asarray(np.asarray(b.csr_len)),
        )

    return new_pspec, ps._replace(out=blk(ps.out), inc=blk(ps.inc))


def grow_block_local(pspec: PartitionedStoreSpec,
                     new_pspec: PartitionedStoreSpec,
                     blk: EdgeBlock) -> EdgeBlock:
    """Device-resident single-shard grow: pad one *local* block view (the
    slice a shard sees inside ``shard_map``) from ``pspec.e_blk_cap`` to
    ``new_pspec.e_blk_cap``. Jittable, owner-local, no collectives — this is
    the hot-swap pause: with the next tier's steps precompiled, swapping
    capacity costs one run of this pad program instead of a host re-pad +
    recompile. Fills match ``grow_store`` exactly (existing rows keep their
    slots; the geid→slot index extends with the ascending new tail, legal
    because allocated slots are a block prefix), so the result is
    byte-identical to the host path / ``partition_store`` under the grown
    spec."""
    EB, NE = pspec.e_blk_cap, new_pspec.e_blk_cap
    assert NE >= EB, (NE, EB)
    ext = NE - EB

    def pad(a, fill):
        tail = jnp.full((ext,) + a.shape[1:], fill, a.dtype)
        return jnp.concatenate([a, tail], axis=0)

    return EdgeBlock(
        key=pad(blk.key, INT32_MAX), other=pad(blk.other, -1),
        label=pad(blk.label, -1), alive=pad(blk.alive, False),
        props=pad(blk.props, np.int32(int(PROP_MISSING))),
        geid=pad(blk.geid, -1),
        gperm=jnp.concatenate(
            [blk.gperm, jnp.arange(EB, NE, dtype=jnp.int32)]
        ),
        indptr=blk.indptr, blk_len=blk.blk_len, csr_len=blk.csr_len,
    )


# ---------------------------------------------------------------- metrics
def block_occupancy(pspec: PartitionedStoreSpec, ps: PartitionedGraphStore) -> dict:
    """Per-shard/per-orientation occupancy and recent fill (host-side).

    Reads only the tiny ``[n]`` block-length scalars. ``occupancy`` is
    ``blk_len / e_blk_cap`` (the growth signal), ``recent_fill`` is
    ``blk_len - csr_len`` in rows (the compaction signal: reads silently
    miss appended edges once it exceeds ``recent_blk_cap``).
    """
    EB, R = pspec.e_blk_cap, pspec.recent_blk_cap
    out = dict(e_blk_cap=EB, recent_blk_cap=R)
    max_occ, max_rec = 0.0, 0
    for name, b in (("out", ps.out), ("inc", ps.inc)):
        ln = np.asarray(jax.device_get(b.blk_len)).reshape(-1)
        cs = np.asarray(jax.device_get(b.csr_len)).reshape(-1)
        rec = (ln - cs).astype(int)
        occ = (ln / EB).astype(float)
        out[name] = dict(
            blk_len=[int(x) for x in ln],
            recent_fill=[int(x) for x in rec],
            occupancy=[round(float(x), 4) for x in occ],
        )
        max_occ = max(max_occ, float(occ.max(initial=0.0)))
        max_rec = max(max_rec, int(rec.max(initial=0)))
    out["max_occupancy"] = round(max_occ, 4)
    out["max_recent_fill"] = max_rec
    out["recent_fill_frac"] = round(max_rec / R, 4) if R else 0.0
    return out


# ----------------------------------------------------------------- policy
class MaintenancePolicy(NamedTuple):
    """When shards compact and when blocks grow.

    ``recent_fill_frac`` — compact once any block's recent fill exceeds this
    fraction of ``recent_blk_cap`` (1.0 is the hard correctness edge: beyond
    it, reads fall off the bounded append-scan window). ``mutation_rows`` —
    also compact after this many applied mutation rows since the last
    compaction, bounding recent-scan latency even under low fill.
    ``grow_occupancy_frac`` / ``growth_factor`` — grow ``e_blk_cap`` by the
    factor once any block's occupancy crosses the high-water fraction (a
    recompile; keep it rare). ``purge`` — reclaim tombstone slots at
    compaction (see ``compact_block`` for the pre-image caveat).
    """

    recent_fill_frac: float = 0.5
    mutation_rows: int = 4096
    grow_occupancy_frac: float = 0.85
    growth_factor: float = 2.0
    purge: bool = False


class MaintenanceDecision(NamedTuple):
    compact: bool
    grow_to: int | None
    reason: str


def decide_maintenance(pspec: PartitionedStoreSpec, occ: dict,
                       policy: MaintenancePolicy,
                       mutation_rows: int = 0) -> MaintenanceDecision:
    """Pure scheduling decision from an occupancy report (host-side)."""
    reasons = []
    grow_to = None
    if occ["max_occupancy"] >= policy.grow_occupancy_frac:
        grow_to = max(
            int(np.ceil(pspec.e_blk_cap * policy.growth_factor)),
            pspec.e_blk_cap + 1,
        )
        reasons.append(
            f"occupancy {occ['max_occupancy']:.2f} >= "
            f"{policy.grow_occupancy_frac:.2f}: grow to {grow_to}"
        )
    compact = occ["max_recent_fill"] >= policy.recent_fill_frac * pspec.recent_blk_cap
    if compact:
        reasons.append(
            f"recent fill {occ['max_recent_fill']} >= "
            f"{policy.recent_fill_frac:.2f} x {pspec.recent_blk_cap}"
        )
    elif mutation_rows >= policy.mutation_rows:
        compact = True
        reasons.append(
            f"{mutation_rows} mutation rows >= budget {policy.mutation_rows}"
        )
    return MaintenanceDecision(compact, grow_to, "; ".join(reasons))
