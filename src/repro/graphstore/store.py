"""The tensor property-graph store.

Vertices and edges live in fixed-capacity *slot arrays*; the slot index is
the immutable id (the paper requires immutable vertex ids for cache keys).
Out-/in-edge adjacency is served by CSR permutation indexes built at
*compaction* time over slots ``[0, csr_len)``; edges appended after the last
compaction sit in the *recent region* ``[csr_len, e_len)`` and are found by a
bounded linear scan (capacity ``recent_cap``), mirroring FDB's in-memory
write buffer in front of its on-disk B-tree.

All reads are masked by liveness (``ealive`` and both endpoint ``valive``),
so deletes are O(1) scatter writes and never require index maintenance.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import PROP_MISSING, take_along0

INT32_MAX = np.int32(2**31 - 1)


class StoreSpec(NamedTuple):
    """Static shape/capacity configuration (hashable; safe as a closure)."""

    v_cap: int = 1024
    e_cap: int = 8192
    n_vprops: int = 4
    n_eprops: int = 2
    recent_cap: int = 256


class GraphStore(NamedTuple):
    """Pytree of device arrays. See module docstring for the layout."""

    # vertex slots
    vlabel: jax.Array  # int32 [v_cap]
    valive: jax.Array  # bool  [v_cap]
    vprops: jax.Array  # int32 [v_cap, n_vprops]
    vversion: jax.Array  # int32 [v_cap]  (FDB-style conflict ranges)
    # edge slots
    esrc: jax.Array  # int32 [e_cap]
    edst: jax.Array  # int32 [e_cap]
    elabel: jax.Array  # int32 [e_cap]
    ealive: jax.Array  # bool  [e_cap]
    eprops: jax.Array  # int32 [e_cap, n_eprops]
    # CSR indexes over [0, csr_len)
    out_indptr: jax.Array  # int32 [v_cap + 1]
    out_perm: jax.Array  # int32 [e_cap]  (CSR position -> edge slot)
    in_indptr: jax.Array  # int32 [v_cap + 1]
    in_perm: jax.Array  # int32 [e_cap]
    # scalars (0-d int32 arrays)
    v_len: jax.Array
    e_len: jax.Array
    csr_len: jax.Array
    version: jax.Array  # global commit version


def empty_store(spec: StoreSpec) -> GraphStore:
    i32 = jnp.int32
    return GraphStore(
        vlabel=jnp.full((spec.v_cap,), -1, i32),
        valive=jnp.zeros((spec.v_cap,), bool),
        vprops=jnp.full((spec.v_cap, spec.n_vprops), PROP_MISSING, i32),
        vversion=jnp.zeros((spec.v_cap,), i32),
        esrc=jnp.full((spec.e_cap,), INT32_MAX, i32),
        edst=jnp.full((spec.e_cap,), -1, i32),
        elabel=jnp.full((spec.e_cap,), -1, i32),
        ealive=jnp.zeros((spec.e_cap,), bool),
        eprops=jnp.full((spec.e_cap, spec.n_eprops), PROP_MISSING, i32),
        out_indptr=jnp.zeros((spec.v_cap + 1,), i32),
        out_perm=jnp.zeros((spec.e_cap,), i32),
        in_indptr=jnp.zeros((spec.v_cap + 1,), i32),
        in_perm=jnp.zeros((spec.e_cap,), i32),
        v_len=jnp.int32(0),
        e_len=jnp.int32(0),
        csr_len=jnp.int32(0),
        version=jnp.int32(0),
    )


def ingest(
    spec: StoreSpec,
    vlabels: np.ndarray,
    vprops: np.ndarray,
    esrc: np.ndarray,
    edst: np.ndarray,
    elabels: np.ndarray,
    eprops: np.ndarray,
) -> GraphStore:
    """Bulk-load a graph (host-side, used by data generators) and compact."""
    store = empty_store(spec)
    nv, ne = len(vlabels), len(esrc)
    assert nv <= spec.v_cap and ne <= spec.e_cap
    store = store._replace(
        vlabel=store.vlabel.at[:nv].set(jnp.asarray(vlabels, jnp.int32)),
        valive=store.valive.at[:nv].set(True),
        vprops=store.vprops.at[:nv].set(jnp.asarray(vprops, jnp.int32)),
        esrc=store.esrc.at[:ne].set(jnp.asarray(esrc, jnp.int32)),
        edst=store.edst.at[:ne].set(jnp.asarray(edst, jnp.int32)),
        elabel=store.elabel.at[:ne].set(jnp.asarray(elabels, jnp.int32)),
        ealive=store.ealive.at[:ne].set(True),
        eprops=store.eprops.at[:ne].set(jnp.asarray(eprops, jnp.int32)),
        v_len=jnp.int32(nv),
        e_len=jnp.int32(ne),
    )
    return compact(spec, store)


def compact(spec: StoreSpec, store: GraphStore) -> GraphStore:
    """Rebuild both CSR indexes over all allocated edge slots.

    Sort-based (O(E log E) on device); dead edges keep their slots but are
    masked at read time. The analogue of an LSM compaction: afterwards the
    recent region is empty and every edge is range-readable.
    """
    idx = jnp.arange(spec.e_cap, dtype=jnp.int32)
    allocated = idx < store.e_len
    # unallocated slots sort to the end; dead-but-allocated stay indexed
    okey = jnp.where(allocated, store.esrc, INT32_MAX)
    operm = jnp.argsort(okey, stable=True).astype(jnp.int32)
    osorted = okey[operm]
    out_indptr = jnp.searchsorted(
        osorted, jnp.arange(spec.v_cap + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    ikey = jnp.where(allocated, store.edst, INT32_MAX)
    iperm = jnp.argsort(ikey, stable=True).astype(jnp.int32)
    isorted = ikey[iperm]
    in_indptr = jnp.searchsorted(
        isorted, jnp.arange(spec.v_cap + 1, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    return store._replace(
        out_indptr=out_indptr,
        out_perm=operm,
        in_indptr=in_indptr,
        in_perm=iperm,
        csr_len=store.e_len,
    )


def _gather(
    spec: StoreSpec,
    store: GraphStore,
    roots: jax.Array,
    max_deg: int,
    *,
    incoming: bool,
):
    """Padded adjacency gather: CSR rows + recent-region scan.

    Returns (eids [B, W], other [B, W], mask [B, W], truncated [B]) where
    W = max_deg + recent_cap and ``other`` is the opposite endpoint.
    ``truncated`` flags supernode rows whose CSR degree exceeded max_deg —
    the paper's supernode-detection hook (§4, timeout/retry discussion).
    """
    indptr = store.in_indptr if incoming else store.out_indptr
    perm = store.in_perm if incoming else store.out_perm
    key_side = store.edst if incoming else store.esrc
    other_side = store.esrc if incoming else store.edst

    roots = roots.astype(jnp.int32)
    rvalid = (roots >= 0) & (roots < spec.v_cap)
    rc = jnp.clip(roots, 0, spec.v_cap - 1)
    start = indptr[rc]
    deg = indptr[rc + 1] - start
    truncated = deg > max_deg
    pos = start[:, None] + jnp.arange(max_deg, dtype=jnp.int32)[None, :]
    csr_mask = (jnp.arange(max_deg)[None, :] < deg[:, None]) & rvalid[:, None]
    eid_csr = take_along0(perm, pos)

    # recent region: dynamic slice [csr_len, csr_len + recent_cap)
    roff = jnp.clip(store.csr_len, 0, spec.e_cap - spec.recent_cap)
    key_r = jax.lax.dynamic_slice(key_side, (roff,), (spec.recent_cap,))
    eid_r = roff + jnp.arange(spec.recent_cap, dtype=jnp.int32)
    in_region = (eid_r >= store.csr_len) & (eid_r < store.e_len)
    rec_mask = (key_r[None, :] == roots[:, None]) & in_region[None, :]
    rec_mask &= rvalid[:, None]
    eid_rec = jnp.broadcast_to(eid_r[None, :], (roots.shape[0], spec.recent_cap))

    eids = jnp.concatenate([eid_csr, eid_rec], axis=1)
    mask = jnp.concatenate([csr_mask, rec_mask], axis=1)
    # liveness: edge alive, both endpoints alive, key side really matches
    # (CSR may be stale only in that dead edges remain; src never mutates)
    mask &= take_along0(store.ealive, eids)
    other = take_along0(other_side, eids)
    mask &= take_along0(store.valive, other)
    mask &= take_along0(store.valive, jnp.broadcast_to(roots[:, None], eids.shape))
    return eids, other, mask, truncated


def gather_out(spec: StoreSpec, store: GraphStore, roots: jax.Array, max_deg: int):
    """Outgoing edges of each root. See ``_gather``."""
    return _gather(spec, store, roots, max_deg, incoming=False)


def gather_in(spec: StoreSpec, store: GraphStore, roots: jax.Array, max_deg: int):
    """Incoming edges of each root. See ``_gather``."""
    return _gather(spec, store, roots, max_deg, incoming=True)


class GlobalStoreView:
    """Storage view of a full (replicated) ``GraphStore``.

    The storage hook consumed by the shared hop driver and the mutation
    listener (``repro.core.runtime`` / ``repro.core.invalidation``): vertex
    attribute arrays plus a padded adjacency gather that also resolves each
    scanned edge's label/properties. The partitioned tier provides the same
    interface over owner-local blocks (``partition.BlockStoreView``); both
    views return identical values for identical logical stores, which is the
    structural basis of the engines' byte-identity.

    ``own`` is ``None``: a single host owns every vertex, and the listener
    skips ownership gating entirely (keeping its traced graph unchanged).
    """

    own = None

    def __init__(self, spec: StoreSpec, store: GraphStore):
        self.spec = spec
        self.store = store

    @property
    def vlabel(self):
        return self.store.vlabel

    @property
    def vprops(self):
        return self.store.vprops

    @property
    def valive(self):
        return self.store.valive

    def adjacency(self, roots: jax.Array, max_deg: int, *, incoming: bool):
        """Returns ``(other [B, W], mask, truncated [B], elabel, eprops)``."""
        eids, other, mask, trunc = _gather(
            self.spec, self.store, roots, max_deg, incoming=incoming
        )
        elab = take_along0(self.store.elabel, eids)
        ep = take_along0(self.store.eprops, eids)
        return other, mask, trunc, elab, ep
