"""Write-behind durability for the partitioned store: an append-only
journal of committed gRW mutation batches, a coalescing async flusher, and
checkpoint/replay that reconstructs a crashed shard's blocks byte-for-byte.

The paper's cache sits on a storage manager that acknowledges commits from
an in-memory write path and persists them asynchronously (FDB's resolver →
storage-server pipeline); our partitioned tier so far had only the
in-memory half — a restart lost every block. This module is the durability
layer of SNIPPETS.md's write-behind pattern: commits land in the device
store immediately, a **dirty-owner map** + **write queue** absorb the
burst, and a **flusher** persists them behind the serve loop with bounded
retry/backoff (``distributed.fault.RetryPolicy``), so durability is off
the commit critical path but never lost once flushed.

Record format
=============

The journal is a sequence of self-delimiting frames::

    MAGIC(4s) | seq(u64 LE) | rtype(u8) | payload_len(u32 LE) |
    crc32(header[0:17] + payload)(u32 LE) | payload

The crc covers the header fields (magic, seq, rtype, payload_len) *and*
the payload, so a flipped bit anywhere in a frame — including a corrupted
length that would mis-delimit the rest of the stream — fails verification
at that frame (``GJL1`` crc'd only the payload; the magic bump to ``GJL2``
keeps old logs from being half-verified).

- ``rtype=COMMIT`` — one committed gRW ``MutationBatch``. The payload is a
  JSON spec (field names, shapes, dtypes, plus the commit's *effective
  step config*: write policy and on-device maintenance gate) followed by
  the concatenated raw array bytes. The step config is recorded because
  replay must re-run each commit through the **same compiled step** the
  live run used: the on-device compaction gate (``DeviceGate``) makes
  block-layout changes part of the commit program, and they are a pure
  function of (store, batch, gate) — recording the gate makes replay a
  deterministic re-execution, byte-identical including layout.
- ``rtype=COMPACT`` — a host-scheduled compaction tick (purge flag in the
  payload). Journaled so replay reproduces block layout *and* purge
  reclamation at exactly the recorded point in the commit order.
- ``rtype=GROW`` — a capacity change (new ``e_blk_cap`` /
  ``recent_blk_cap``). Journaled so replay grows at the same point.

A **torn tail** (crashed writer) is detected by a short frame or a crc
mismatch and cleanly ignored: every complete frame before it replays, the
partial one is discarded — exactly the un-flushed window the write-behind
trade-off already concedes (bounded by ``journal_lag_batches``).

Coalescing rules
================

The flusher is a **group-commit** coalescer: each flush cycle drains the
whole pending queue and persists it as ONE write+fsync, so k bursty
commits cost one I/O round-trip instead of k. Records are **never merged
or reordered** — replay fidelity requires the exact commit order — so
"coalescing" here means batching I/O (and clearing the dirty-owner map
wholesale), not collapsing updates to the same key the way a KV
write-behind cache may. A flush that fails mid-write leaves garbage past
the last durable offset; the retry (bounded, exponential backoff)
truncates back to the durable offset and rewrites the whole group, so a
record is never lost and never persisted twice (idempotent replay needs no
dedup — but replay *also* filters ``seq <= checkpoint seq``, which makes a
crash between checkpoint-publish and journal-truncate harmless).

Epoch / purge invariants
========================

``compact_block(purge=True)`` reclaims tombstone lanes; a later mutation
naming a purged geid then resolves to "not found" instead of the slot
pre-image. ``EpochRegistry`` makes purge safe to enable in the serve loop:

- the registry's epoch is the store's commit version; readers (in-flight
  gR snapshots, checkpoint writers) **pin** the epoch they read at;
- purge is allowed only when ``min pinned epoch >= store version`` (no
  reader holds a snapshot that could still observe a pre-image) **and**
  the journal's checkpoint covers the store version (recovery never
  restores a pre-purge snapshot and replays across the purge from state
  the purge already mutated away);
- tombstones are created by commits, so every tombstone's epoch is at
  most the store version — gating on the store version purges exactly the
  tombstones older than the min pinned epoch + checkpoint, at whole-block
  granularity.

Purge events that do run are journaled (COMPACT records / COMMIT gate
configs), so recovery reproduces them deterministically and the
crash/restart byte-identity pin holds with purge enabled.
"""

from __future__ import annotations

import contextlib
import json
import os
import struct
import threading
import zlib
from typing import Callable, NamedTuple, Optional

import numpy as np

from repro.distributed.fault import RetryPolicy, timed_call
from repro.graphstore.maintenance import DeviceGate
from repro.graphstore.mutations import MutationBatch

_MAGIC = b"GJL2"
_HEADER = struct.Struct("<4sQBII")  # magic, seq, rtype, payload_len, crc32
# the crc32 field covers header bytes [0, _CRC_OFFSET) *plus* the payload —
# a flipped bit anywhere in a frame (magic, seq, rtype, length, or body) is
# detected, not just payload corruption. GJL1 frames crc'd the payload only,
# so e.g. a corrupted payload_len could mis-delimit the stream while every
# frame still "checksummed"; the magic bump makes old logs torn-tail at
# frame 0 instead of silently half-verified.
_CRC_OFFSET = _HEADER.size - 4  # 17: crc is the trailing u32 of the header


def _frame_crc(header: bytes, offset: int, payload: bytes) -> int:
    """crc32 over the frame's covered bytes: header (sans the crc field
    itself) followed by the payload."""
    crc = zlib.crc32(header[offset : offset + _CRC_OFFSET])
    return zlib.crc32(payload, crc) & 0xFFFFFFFF

REC_COMMIT = 1
REC_COMPACT = 2
REC_GROW = 3
REC_MIGRATE = 4


class FlushError(RuntimeError):
    """The flusher exhausted its bounded retries; records stay pending."""


def _serialize_arrays(fields: dict, meta: dict) -> bytes:
    """JSON spec + concatenated raw bytes for a dict of numpy arrays."""
    spec, blobs = [], []
    for name, arr in fields.items():
        a = np.asarray(arr)
        spec.append({"name": name, "shape": list(a.shape), "dtype": str(a.dtype)})
        # note ascontiguousarray AFTER recording the shape: it promotes 0-d
        # scalars (the batch count fields) to 1-d
        blobs.append(np.ascontiguousarray(a).tobytes())
    head = json.dumps({"fields": spec, "meta": meta}).encode()
    return struct.pack("<I", len(head)) + head + b"".join(blobs)


def _deserialize_arrays(payload: bytes):
    (hlen,) = struct.unpack_from("<I", payload, 0)
    head = json.loads(payload[4 : 4 + hlen].decode())
    off = 4 + hlen
    fields = {}
    for f in head["fields"]:
        dt = np.dtype(f["dtype"])
        n = int(np.prod(f["shape"], dtype=np.int64)) * dt.itemsize
        fields[f["name"]] = np.frombuffer(
            payload[off : off + n], dtype=dt
        ).reshape(f["shape"])
        off += n
    return fields, head["meta"]


def encode_commit(batch: MutationBatch, *, policy: str = "write-around",
                  gate: Optional[DeviceGate] = None) -> bytes:
    """Payload of a COMMIT record: the batch arrays + effective step config."""
    fields = {f: np.asarray(getattr(batch, f)) for f in MutationBatch._fields}
    meta = {"policy": policy}
    if gate is not None:
        meta["gate"] = [float(gate.recent_fill_frac), bool(gate.purge)]
    return _serialize_arrays(fields, meta)


def decode_commit(payload: bytes):
    """Inverse of ``encode_commit`` → ``(MutationBatch, policy, gate)``."""
    import jax.numpy as jnp

    fields, meta = _deserialize_arrays(payload)
    batch = MutationBatch(**{
        f: jnp.asarray(fields[f]) for f in MutationBatch._fields
    })
    gate = meta.get("gate")
    if gate is not None:
        gate = DeviceGate(recent_fill_frac=gate[0], purge=bool(gate[1]))
    return batch, meta["policy"], gate


class JournalRecord(NamedTuple):
    seq: int
    rtype: int
    payload: bytes


class EpochRegistry:
    """Geid liveness epochs: readers pin the store version they read at;
    purge reclaims only behind the min pinned epoch + journal checkpoint
    (module docstring). Thread-safe — the flusher thread and serve loop
    both touch it."""

    def __init__(self):
        self._lock = threading.Lock()
        self._pins: dict[int, int] = {}
        self._next_token = 0
        self.current = 0
        self.leaked_releases = 0

    def advance(self, epoch: int) -> None:
        """Record a new committed store version (monotone)."""
        with self._lock:
            self.current = max(self.current, int(epoch))

    def pin(self, epoch: Optional[int] = None) -> int:
        """Pin an epoch (default: current); returns a release token."""
        with self._lock:
            tok = self._next_token
            self._next_token += 1
            self._pins[tok] = self.current if epoch is None else int(epoch)
            return tok

    def release(self, token: int) -> None:
        with self._lock:
            self._pins.pop(token, None)

    @contextlib.contextmanager
    def pin_scope(self, epoch: Optional[int] = None):
        """Exception-safe pin: ``with epochs.pin_scope(): ...`` releases on
        every exit path. A gR batch that raises mid-flight (a crashed owner
        surfacing as ``NodeFailure``) would otherwise leak its pin and block
        tombstone purge forever; ``leaked_releases`` counts the pins this
        scope recovered from an exception unwind (the serve loop surfaces it
        as the leaked-pin metric)."""
        tok = self.pin(epoch)
        try:
            yield tok
        except BaseException:
            with self._lock:
                self.leaked_releases += 1
            raise
        finally:
            self.release(tok)

    def open_pins(self) -> int:
        """Currently-held pin count (0 in a quiesced serve loop)."""
        with self._lock:
            return len(self._pins)

    def min_pinned(self) -> int:
        """The oldest live snapshot's epoch (current epoch when none)."""
        with self._lock:
            return min(self._pins.values(), default=self.current)

    def safe_to_purge(self, store_version: int,
                      journal: Optional["WriteBehindJournal"] = None) -> bool:
        """True iff every tombstone (epoch <= store_version) is older than
        the min pinned epoch and covered by the journal checkpoint."""
        if self.min_pinned() < int(store_version):
            return False
        if journal is not None and journal.checkpoint_version < int(store_version):
            return False
        return True


class WriteBehindJournal:
    """Append-only write-behind journal + coalescing flusher + checkpoints.

    ``append_commit`` is the write-behind acceptance point: it enqueues the
    record and marks the touched owners dirty, O(batch) host work and no
    I/O. ``flush`` (or the background thread started by ``start``) is the
    coalescing drainer; ``checkpoint``/``recover`` bound replay time.

    ``flush_fault`` is the fault-injection hook: called with the attempt
    index *after* the group's bytes are staged but before they become
    durable — raising simulates a torn flush (partial bytes on disk), which
    the bounded-retry path must absorb without losing or duplicating
    records.
    """

    def __init__(self, root: str, n_shards: int, *,
                 retry: Optional[RetryPolicy] = None,
                 flush_fault: Optional[Callable[[int], None]] = None,
                 io_timeout: Optional[float] = None, tracer=None):
        self.root = root
        self.n = n_shards
        self.retry = retry if retry is not None else RetryPolicy(max_attempts=4)
        self.flush_fault = flush_fault
        # observability: flush and checkpoint wall-clock report through
        # tracer spans ("journal_flush" / "checkpoint"); the tracer must be
        # thread-safe — the async flusher records from its own thread.
        # Default NULL_TRACER is a no-op.
        from repro.obs.trace import NULL_TRACER

        self.tracer = tracer if tracer is not None else NULL_TRACER
        # wall-clock bound on each flush write / checkpoint save attempt: a
        # hung filesystem surfaces as CallTimeout (retried like any flush
        # failure) instead of freezing the serve loop. None = unbounded.
        self.io_timeout = io_timeout
        os.makedirs(root, exist_ok=True)
        self.log_path = os.path.join(root, "wal.log")
        self.meta_path = os.path.join(root, "journal_meta.json")
        self.ckpt_dir = os.path.join(root, "ckpt")
        self._lock = threading.Lock()
        self._flush_lock = threading.Lock()  # one flusher at a time
        self._pending: list[JournalRecord] = []
        self._dirty_owners: set[int] = set()
        # owners whose blocks changed since the last checkpoint — unlike
        # _dirty_owners (cleared per flush) this accumulates across flushes
        # and is cleared only by checkpoint/checkpoint_incremental; it is
        # what makes incremental checkpoints sound (they persist exactly
        # these owners' block rows).
        self._dirty_since_ckpt: set[int] = set()
        self._queued_commits = 0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.epochs = EpochRegistry()
        # monotone counters (guarded by _lock where racy)
        self.next_seq = 1
        self.durable_seq = 0
        # highest seq applied to the LIVE device store. In healthy operation
        # it tracks next_seq - 1; during an owner outage gRW commits are
        # journaled with applied=False (queued) and the watermark freezes —
        # recovery replays records <= applied_seq to rebuild the pre-outage
        # store and drain_queued applies the rest against the live cache.
        self.applied_seq = 0
        self._durable_offset = 0
        self.checkpoint_seq = 0
        self.checkpoint_version = 0
        self.flushes = 0
        self.flush_retries = 0
        self.flush_failures = 0
        self.flushed_records = 0
        self.flushed_bytes = 0
        self._load_meta()

    # ------------------------------------------------------------- appends
    def _append(self, rtype: int, payload: bytes) -> int:
        with self._lock:
            seq = self.next_seq
            self.next_seq += 1
            self._pending.append(JournalRecord(seq, rtype, payload))
            return seq

    def append_commit(self, batch: MutationBatch, *, policy: str = "write-around",
                      gate: Optional[DeviceGate] = None,
                      commit_version: Optional[int] = None,
                      device_compactions: int = 0,
                      applied: bool = True,
                      route: Optional[Callable] = None) -> int:
        """Accept one committed gRW batch into the write-behind queue and
        mark the owners its mutation sections touch dirty.

        ``applied=False`` queues the record without advancing the
        applied-store watermark — degraded mode's write path: the batch is
        durable (it flushes like any record) but was NOT applied to the
        live device store; ``drain_queued`` re-executes it after recovery.
        ``device_compactions`` (the gated step's on-device compaction
        count) conservatively marks every owner checkpoint-dirty: the gate
        may rewrite any over-threshold block's layout, not just the owners
        the batch's ids name. ``route`` maps new-edge endpoint ids to
        their *table* owners for the dirty map (pass
        ``RoutingTableHost.storage_owner`` once migrations have run;
        default is the static ``v % n``)."""
        seq = self._append(REC_COMMIT, encode_commit(batch, policy=policy, gate=gate))
        owners = set()
        for ids, cnt in (
            (batch.ne_src, batch.ne_n), (batch.ne_dst, batch.ne_n),
            (batch.de_eid, batch.de_n), (batch.se_eid, batch.se_n),
        ):
            k = int(cnt)
            if k:
                # edge sections touch owner blocks; eids proxy via geid % n
                # is unknowable host-side for de/se without a lookup, so the
                # dirty map is conservative there (all owners dirty)
                vals = np.asarray(ids)[:k]
                if ids is batch.de_eid or ids is batch.se_eid:
                    owners.update(range(self.n))
                else:
                    if route is None:
                        from repro.distributed.routing import base_owner

                        route = lambda v: base_owner(v, self.n)  # noqa: E731
                    dest = route(vals)
                    owners.update(int(o) for o in np.unique(np.asarray(dest)))
        if int(device_compactions) > 0:
            owners.update(range(self.n))
        with self._lock:
            self._dirty_owners |= owners
            self._dirty_since_ckpt |= owners
            if applied:
                self.applied_seq = max(self.applied_seq, seq)
            else:
                self._queued_commits += 1
        if commit_version is not None:
            self.epochs.advance(commit_version)
        return seq

    def append_compact(self, *, purge: bool = False) -> int:
        """Journal a host-scheduled compaction tick (layout + purge replay).
        Compaction rewrites every owner's block in place, so all owners go
        checkpoint-dirty."""
        payload = json.dumps({"purge": bool(purge)}).encode()
        seq = self._append(REC_COMPACT, payload)
        with self._lock:
            self._dirty_since_ckpt.update(range(self.n))
            self.applied_seq = max(self.applied_seq, seq)
        return seq

    def append_grow(self, e_blk_cap: int, recent_blk_cap: int) -> int:
        """Journal a capacity change (replayed at the same point). Growth
        re-pads every block, so all owners go checkpoint-dirty."""
        payload = json.dumps({
            "e_blk_cap": int(e_blk_cap), "recent_blk_cap": int(recent_blk_cap),
        }).encode()
        seq = self._append(REC_GROW, payload)
        with self._lock:
            self._dirty_since_ckpt.update(range(self.n))
            self.applied_seq = max(self.applied_seq, seq)
        return seq

    def append_migrate(self, moves, epoch: Optional[int] = None) -> int:
        """Journal a hot-vertex migration round (``graphstore.migration``):
        the move list ``[(vid, dst), ...]`` plus the routing-table epoch it
        produces. Replayed through the same deterministic
        ``migrate_vertex_rows`` splice, so the post-migration store is
        byte-reconstructible; source and destination blocks are both
        rewritten, so all owners go checkpoint-dirty (the source is not
        recorded — it is whatever shard held the rows at replay time)."""
        payload = json.dumps({
            "moves": [[int(v), int(d)] for v, d in moves],
            "epoch": None if epoch is None else int(epoch),
        }).encode()
        seq = self._append(REC_MIGRATE, payload)
        with self._lock:
            self._dirty_since_ckpt.update(range(self.n))
            self.applied_seq = max(self.applied_seq, seq)
        return seq

    # ------------------------------------------------------------- flusher
    def _frame(self, rec: JournalRecord) -> bytes:
        head = _HEADER.pack(_MAGIC, rec.seq, rec.rtype, len(rec.payload), 0)
        crc = _frame_crc(head, 0, rec.payload)
        return head[:_CRC_OFFSET] + struct.pack("<I", crc) + rec.payload

    def flush(self) -> int:
        """Group-commit the pending queue: one write+fsync for the whole
        group, bounded-retry on injected/real failures (truncate to the
        durable offset, rewrite the group — no loss, no duplicates).
        Returns the number of records made durable."""
        with self._flush_lock:
            with self.tracer.span("journal_flush"):
                return self._flush_locked()

    def _flush_locked(self) -> int:
        with self._lock:
            group = list(self._pending)
        if not group:
            return 0
        buf = b"".join(self._frame(r) for r in group)
        attempt_box = [0]

        def write_group():
            attempt = attempt_box[0]
            attempt_box[0] += 1
            with open(self.log_path, "ab") as f:
                # discard any torn bytes a failed attempt left behind
                f.truncate(self._durable_offset)
                f.seek(self._durable_offset)
                half = len(buf) // 2
                f.write(buf[:half])
                f.flush()
                if self.flush_fault is not None:
                    self.flush_fault(attempt)  # may raise: torn flush
                f.write(buf[half:])
                f.flush()
                os.fsync(f.fileno())

        def on_retry(attempt, exc):
            self.flush_retries += 1

        try:
            # each attempt is wall-clock bounded (io_timeout): a hung write
            # becomes CallTimeout and burns one retry instead of wedging the
            # flusher; the next attempt truncates to the durable offset, so
            # a late background completion cannot corrupt the rewrite's
            # prefix property (replay stops at the first bad frame anyway)
            self.retry.run(
                lambda: timed_call(write_group, self.io_timeout),
                on_retry=on_retry,
            )
        except Exception as e:  # noqa: BLE001 — surfaced as flusher state
            self.flush_failures += 1
            raise FlushError(
                f"flush failed after {self.retry.max_attempts} attempts: {e}"
            ) from e
        with self._lock:
            self._durable_offset += len(buf)
            self.durable_seq = group[-1].seq
            # records appended while we were writing stay pending
            self._pending = self._pending[len(group):]
            if not self._pending:
                self._dirty_owners.clear()
            self.flushes += 1
            self.flushed_records += len(group)
            self.flushed_bytes += len(buf)
        self._save_meta()
        return len(group)

    def start(self, interval: float = 0.005) -> None:
        """Start the async flusher thread (idempotent)."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                try:
                    if self._pending:
                        self.flush()
                except FlushError:
                    pass  # counted; records stay pending for the next cycle
                self._stop.wait(interval)

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self, *, final_flush: bool = True) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=10.0)
            self._thread = None
        if final_flush and self._pending:
            self.flush()

    # ------------------------------------------------------------- metrics
    def metrics(self) -> dict:
        with self._lock:
            pending = len(self._pending)
            dirty = len(self._dirty_owners)
            dirty_ckpt = len(self._dirty_since_ckpt)
            queued = self._queued_commits
            applied = self.applied_seq
        return {
            "journal_lag_batches": (self.next_seq - 1) - self.durable_seq,
            "flush_queue_depth": pending,
            "dirty_owners": dirty,
            "dirty_owners_since_ckpt": dirty_ckpt,
            "applied_seq": applied,
            "queued_commits": queued,
            "open_pins": self.epochs.open_pins(),
            "leaked_pin_releases": self.epochs.leaked_releases,
            "flushes": self.flushes,
            "flush_retries": self.flush_retries,
            "flush_failures": self.flush_failures,
            "flushed_records": self.flushed_records,
            "flushed_bytes": self.flushed_bytes,
            "durable_seq": self.durable_seq,
            "checkpoint_seq": self.checkpoint_seq,
            "pinned_epoch_min": self.epochs.min_pinned(),
        }

    # -------------------------------------------------------- meta durable
    def _save_meta(self) -> None:
        tmp = self.meta_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({
                "durable_seq": self.durable_seq,
                "durable_offset": self._durable_offset,
                "checkpoint_seq": self.checkpoint_seq,
                "checkpoint_version": self.checkpoint_version,
                "applied_seq": self.applied_seq,
            }, f)
        os.replace(tmp, self.meta_path)

    def _load_meta(self) -> None:
        meta_applied = None
        if os.path.exists(self.meta_path):
            with open(self.meta_path) as f:
                m = json.load(f)
            self.checkpoint_seq = int(m.get("checkpoint_seq", 0))
            self.checkpoint_version = int(m.get("checkpoint_version", 0))
            if "applied_seq" in m:
                meta_applied = int(m["applied_seq"])
        # the log itself is the durability ground truth: a flush that landed
        # but crashed before the meta rewrite must keep its seqs (replay
        # reads them), and a torn group's complete prefix frames stay valid
        off, seq = 0, 0
        if os.path.exists(self.log_path):
            with open(self.log_path, "rb") as f:
                data = f.read()
            while off + _HEADER.size <= len(data):
                magic, s, _rt, plen, crc = _HEADER.unpack_from(data, off)
                end = off + _HEADER.size + plen
                if magic != _MAGIC or end > len(data):
                    break
                body = data[off + _HEADER.size : end]
                if _frame_crc(data, off, body) != crc:
                    break
                seq, off = s, end
        self.durable_seq, self._durable_offset = seq, off
        self.next_seq = seq + 1
        # applied watermark on reopen: records the meta knew were applied,
        # clamped to what actually survived on the log (a torn tail may have
        # eaten applied-but-unflushed frames — those are the conceded
        # write-behind window). Legacy metas (no applied_seq) predate
        # degraded mode: everything durable was applied.
        self.applied_seq = seq if meta_applied is None else min(meta_applied, seq)

    # ----------------------------------------------------------- read path
    def read_records(self, *, after_seq: int = 0) -> list[JournalRecord]:
        """Scan every complete frame with ``seq > after_seq``; a torn tail
        (short frame / crc mismatch) ends the scan cleanly."""
        out: list[JournalRecord] = []
        if not os.path.exists(self.log_path):
            return out
        with open(self.log_path, "rb") as f:
            data = f.read()
        off = 0
        while off + _HEADER.size <= len(data):
            magic, seq, rtype, plen, crc = _HEADER.unpack_from(data, off)
            if magic != _MAGIC or off + _HEADER.size + plen > len(data):
                break  # torn tail
            payload = data[off + _HEADER.size : off + _HEADER.size + plen]
            if _frame_crc(data, off, payload) != crc:
                break  # torn tail
            if seq > after_seq:
                out.append(JournalRecord(seq, rtype, bytes(payload)))
            off += _HEADER.size + plen
        return out

    # --------------------------------------------------- checkpoint/replay
    def checkpoint(self, pstore, *, e_blk_cap: int, recent_blk_cap: int,
                   store_version: int) -> str:
        """Snapshot the partitioned store (``checkpoint.ckpt`` atomic +
        compressed) covering every appended record, then advance the
        checkpoint watermark. The block-layout spec at snapshot time is
        recorded so recovery rebuilds the right shapes before replaying
        (a later GROW record changes them again at the recorded point)."""
        from repro.checkpoint import save_checkpoint

        with self.tracer.span("checkpoint"):
            self.flush()
            with self._lock:
                seq = self.next_seq - 1
            path = timed_call(save_checkpoint, self.io_timeout,
                              self.ckpt_dir, seq, pstore)
            spec_meta = {
                "kind": "full",
                "e_blk_cap": int(e_blk_cap),
                "recent_blk_cap": int(recent_blk_cap),
                "store_version": int(store_version),
            }
            with open(os.path.join(path, "journal.json"), "w") as f:
                json.dump(spec_meta, f)
            with self._lock:
                self._dirty_since_ckpt.clear()
            self.checkpoint_seq = seq
            self.checkpoint_version = int(store_version)
            self._save_meta()
        return path

    def checkpoint_incremental(self, pstore, *, e_blk_cap: int,
                               recent_blk_cap: int, store_version: int) -> str:
        """Snapshot only the journal's checkpoint-dirty owners' block rows
        (plus the replicated vertex tier and global scalars, which every
        commit touches) on top of the previous checkpoint — recovery cost
        scales with write rate, not store size. Falls back to a full
        ``checkpoint`` when there is no base to build on or the block
        layout changed since (a GROW re-shapes every block, so an overlay
        across it cannot splice).

        Restore walks the base chain (full → incremental*) and splices each
        overlay's owner rows forward; ``incremental ∘ incremental`` composes
        to the same bytes as a full snapshot (tested)."""
        import jax

        base = self.latest_checkpoint()
        if base is None:
            return self.checkpoint(
                pstore, e_blk_cap=e_blk_cap, recent_blk_cap=recent_blk_cap,
                store_version=store_version,
            )
        base_seq, base_meta = base
        if (int(base_meta["e_blk_cap"]) != int(e_blk_cap)
                or int(base_meta["recent_blk_cap"]) != int(recent_blk_cap)):
            return self.checkpoint(
                pstore, e_blk_cap=e_blk_cap, recent_blk_cap=recent_blk_cap,
                store_version=store_version,
            )
        from repro.checkpoint import save_checkpoint

        with self.tracer.span("checkpoint"):
            self.flush()
            with self._lock:
                seq = self.next_seq - 1
                owners = sorted(self._dirty_since_ckpt)
            host = jax.device_get(pstore)
            tree = _incremental_tree(host, owners, self.n, int(e_blk_cap))
            path = timed_call(save_checkpoint, self.io_timeout,
                              self.ckpt_dir, seq, tree)
            spec_meta = {
                "kind": "incremental", "base_seq": int(base_seq),
                "owners": [int(o) for o in owners],
                "e_blk_cap": int(e_blk_cap),
                "recent_blk_cap": int(recent_blk_cap),
                "store_version": int(store_version),
            }
            with open(os.path.join(path, "journal.json"), "w") as f:
                json.dump(spec_meta, f)
            with self._lock:
                self._dirty_since_ckpt.clear()
            self.checkpoint_seq = seq
            self.checkpoint_version = int(store_version)
            self._save_meta()
        return path

    def latest_checkpoint(self):
        """``(seq, spec_meta)`` of the newest checkpoint, or ``None``."""
        from repro.checkpoint import latest_step

        seq = latest_step(self.ckpt_dir)
        if seq is None:
            return None
        return seq, self.checkpoint_meta(seq)

    def checkpoint_meta(self, seq: int) -> dict:
        with open(os.path.join(self.ckpt_dir, f"step_{seq}", "journal.json")) as f:
            return json.load(f)


def _incremental_tree(host_pstore, owners, n: int, e_blk_cap: int) -> dict:
    """The overlay pytree an incremental checkpoint persists: replicated
    vertex tier + global scalars whole (every commit touches them, and they
    are small next to the blocks), plus the listed owners' block-row slices
    for both orientations. Plain dict-of-dicts so save/restore flattening
    is deterministic (sorted keys)."""
    EB, k = int(e_blk_cap), len(owners)
    idx = np.asarray(owners, np.int64)

    def blk_slices(b) -> dict:
        key = np.asarray(b.key)
        Vloc1 = np.asarray(b.indptr).shape[0] // n
        return {
            "key": key.reshape(n, EB)[idx],
            "other": np.asarray(b.other).reshape(n, EB)[idx],
            "label": np.asarray(b.label).reshape(n, EB)[idx],
            "alive": np.asarray(b.alive).reshape(n, EB)[idx],
            "props": np.asarray(b.props).reshape(n, EB, -1)[idx],
            "geid": np.asarray(b.geid).reshape(n, EB)[idx],
            "gperm": np.asarray(b.gperm).reshape(n, EB)[idx],
            "indptr": np.asarray(b.indptr).reshape(n, Vloc1)[idx],
            "blk_len": np.asarray(b.blk_len)[idx],
            "csr_len": np.asarray(b.csr_len)[idx],
        }

    return {
        "vertex": {
            "vlabel": np.asarray(host_pstore.vlabel),
            "valive": np.asarray(host_pstore.valive),
            "vprops": np.asarray(host_pstore.vprops),
            "vversion": np.asarray(host_pstore.vversion),
        },
        "scalars": {
            "v_len": np.asarray(host_pstore.v_len),
            "e_len": np.asarray(host_pstore.e_len),
            "version": np.asarray(host_pstore.version),
        },
        "out": blk_slices(host_pstore.out),
        "inc": blk_slices(host_pstore.inc),
    }


def _apply_overlay(host_pstore, tree: dict, owners, n: int):
    """Splice an incremental overlay's owner rows (and the whole vertex
    tier + scalars) into a host-side store. Inverse of
    ``_incremental_tree``; returns a new ``PartitionedGraphStore``."""
    idx = np.asarray(owners, np.int64)

    def blk(b, t: dict):
        def row(cur, new):
            cur = np.asarray(cur)
            out = cur.reshape((n,) + new.shape[1:]).copy()
            out[idx] = new
            return out.reshape(cur.shape)

        return b._replace(
            key=row(b.key, t["key"]), other=row(b.other, t["other"]),
            label=row(b.label, t["label"]), alive=row(b.alive, t["alive"]),
            props=row(b.props, t["props"]), geid=row(b.geid, t["geid"]),
            gperm=row(b.gperm, t["gperm"]), indptr=row(b.indptr, t["indptr"]),
            blk_len=row(b.blk_len, t["blk_len"]),
            csr_len=row(b.csr_len, t["csr_len"]),
        )

    return host_pstore._replace(
        vlabel=tree["vertex"]["vlabel"], valive=tree["vertex"]["valive"],
        vprops=tree["vertex"]["vprops"], vversion=tree["vertex"]["vversion"],
        v_len=tree["scalars"]["v_len"], e_len=tree["scalars"]["e_len"],
        version=tree["scalars"]["version"],
        out=blk(host_pstore.out, tree["out"]),
        inc=blk(host_pstore.inc, tree["inc"]),
    )


def _overlay_template(pspec, owners) -> dict:
    """ShapeDtypeStruct tree matching ``_incremental_tree`` for restore."""
    import jax

    sds = jax.ShapeDtypeStruct
    n, EB, Vloc = pspec.n_shards, pspec.e_blk_cap, pspec.v_loc
    k = len(owners)
    base = pspec.base
    nep, nvp = base.n_eprops, base.n_vprops

    def blk() -> dict:
        return {
            "key": sds((k, EB), np.int32), "other": sds((k, EB), np.int32),
            "label": sds((k, EB), np.int32), "alive": sds((k, EB), np.bool_),
            "props": sds((k, EB, nep), np.int32),
            "geid": sds((k, EB), np.int32), "gperm": sds((k, EB), np.int32),
            "indptr": sds((k, Vloc + 1), np.int32),
            "blk_len": sds((k,), np.int32), "csr_len": sds((k,), np.int32),
        }

    return {
        "vertex": {
            "vlabel": sds((base.v_cap,), np.int32),
            "valive": sds((base.v_cap,), np.bool_),
            "vprops": sds((base.v_cap, nvp), np.int32),
            "vversion": sds((base.v_cap,), np.int32),
        },
        "scalars": {
            "v_len": sds((), np.int32), "e_len": sds((), np.int32),
            "version": sds((), np.int32),
        },
        "out": blk(), "inc": blk(),
    }


def restore_chain(journal: WriteBehindJournal, rt):
    """Restore the newest checkpoint, walking its incremental base chain.

    Finds the latest checkpoint, follows ``base_seq`` links back to the
    most recent FULL snapshot, restores it, then splices each incremental
    overlay forward in order (oldest → newest). The whole chain shares one
    block layout (``checkpoint_incremental`` falls back to full across a
    GROW), so the runtime adopts the chain's capacity once up front.
    Returns ``(pstore, seq, spec_meta)`` with ``pstore`` device-resident
    under the runtime's store sharding."""
    import jax

    from repro.checkpoint import restore_checkpoint
    from repro.graphstore.partition import abstract_partitioned_store

    ck = journal.latest_checkpoint()
    if ck is None:
        raise FileNotFoundError(
            f"no checkpoint under {journal.ckpt_dir}; recovery needs at "
            f"least one (journal records only deltas)"
        )
    seq, spec_meta = ck
    rt.set_block_capacity(
        spec_meta["e_blk_cap"], recent_blk_cap=spec_meta["recent_blk_cap"]
    )
    chain = []  # (seq, meta) of incrementals, newest first
    cur_seq, cur_meta = seq, spec_meta
    while cur_meta.get("kind", "full") == "incremental":
        chain.append((cur_seq, cur_meta))
        cur_seq = int(cur_meta["base_seq"])
        cur_meta = journal.checkpoint_meta(cur_seq)
    template = abstract_partitioned_store(rt.pspec)
    pstore = restore_checkpoint(journal.ckpt_dir, cur_seq, template)
    pstore = jax.tree_util.tree_map(np.asarray, pstore)
    for inc_seq, inc_meta in reversed(chain):
        owners = [int(o) for o in inc_meta["owners"]]
        tree = restore_checkpoint(
            journal.ckpt_dir, inc_seq, _overlay_template(rt.pspec, owners)
        )
        tree = jax.tree_util.tree_map(np.asarray, tree)
        pstore = _apply_overlay(pstore, tree, owners, rt.n)
    pstore = jax.device_put(pstore, rt.store_sharding())
    return pstore, seq, spec_meta


def replay(journal: WriteBehindJournal, rt, ttable, *,
           default_policy: str = "write-around",
           upto_seq: Optional[int] = None):
    """Reconstruct the partitioned store of a crashed shard group:
    ``restore(latest checkpoint)`` (via ``restore_chain`` — the newest
    snapshot may be an incremental overlay stack) then re-apply every
    durable journal record after it, each through the same runtime step
    family the live run used (COMMIT → the recorded (policy, gate) gRW
    step; COMPACT → the compaction pass; GROW → capacity growth). The
    store path of the gRW step is independent of cache state, so replay
    against an empty cache reproduces the pre-crash
    ``PartitionedGraphStore`` byte-for-byte — ``replay(checkpoint,
    journal) ≡ pre-crash store``.

    ``upto_seq`` stops replay at a watermark (exclusive above): recovery
    from a live outage replays only records the dead store had applied
    (``journal.applied_seq``) — the queued remainder is ``drain_queued``'s
    job, applied against the live cache after the block splice.

    MIGRATE records replay through the same deterministic
    ``migrate_vertex_rows`` splice the live engine used, and replay
    maintains the routing-table trajectory they imply: the restored
    checkpoint's placement is *inferred from its bytes* (foreign rows name
    their table owner — ``migration.infer_storage_exceptions``), each
    MIGRATE advances it, and every replayed COMMIT routes its appends
    through the table as of that point in the log. Post-migration stores
    therefore reconstruct byte-for-byte.

    Returns ``(pstore, last_seq, info)``.
    """
    import jax

    from repro.distributed.routing import RoutingTableHost
    from repro.graphstore.migration import (
        infer_storage_exceptions,
        migrate_vertex_rows,
    )

    info = {"replayed_commits": 0, "replayed_compactions": 0,
            "replayed_growths": 0, "replayed_migrations": 0}
    pstore, seq, _spec_meta = restore_chain(journal, rt)
    cache = rt.empty_cache()
    exc = infer_storage_exceptions(rt.pspec, pstore)
    rhost = RoutingTableHost(rt.n, cap=max(64, len(exc)))
    if exc:
        rhost.apply_moves(sorted(exc.items()))
    last = seq
    for rec in journal.read_records(after_seq=seq):
        if upto_seq is not None and rec.seq > upto_seq:
            break
        if rec.rtype == REC_COMMIT:
            batch, policy, gate = decode_commit(rec.payload)
            pstore, _, _ = rt.run_grw_tx(
                pstore, cache, ttable, batch,
                policy=policy or default_policy, gate=gate,
                occupancy_metrics=False,
                rtable=rhost.device_table() if rhost.has_exceptions() else None,
            )
            info["replayed_commits"] += 1
        elif rec.rtype == REC_COMPACT:
            purge = json.loads(rec.payload.decode())["purge"]
            pstore = rt.compact_step(purge)(pstore)
            info["replayed_compactions"] += 1
        elif rec.rtype == REC_GROW:
            m = json.loads(rec.payload.decode())
            pstore = rt.grow_blocks(
                pstore, m["e_blk_cap"], recent_blk_cap=m["recent_blk_cap"]
            )
            info["replayed_growths"] += 1
        elif rec.rtype == REC_MIGRATE:
            moves = [
                (int(v), int(d))
                for v, d in json.loads(rec.payload.decode())["moves"]
            ]
            pstore = jax.device_put(
                migrate_vertex_rows(rt.pspec, pstore, moves),
                rt.store_sharding(),
            )
            rhost.apply_moves(moves)
            info["replayed_migrations"] += 1
        last = rec.seq
    journal.epochs.advance(int(np.asarray(pstore.version)))
    # attach the reconstructed placement: serving a migrated store without
    # its table would route moved vertices to owners that no longer hold
    # their rows. A live runtime that already carries a host table keeps
    # it (the cache overlay is not inferable from store bytes).
    if (rhost.has_exceptions() and hasattr(rt, "attach_routing")
            and getattr(rt, "rhost", None) is None):
        rt.attach_routing(rhost)
    return pstore, last, info


def replay_to_owner(journal: WriteBehindJournal, rt, ttable, *,
                    live_pstore, owner: int,
                    default_policy: str = "write-around"):
    """Recovery-as-migration: rebuild a dead owner's blocks from durable
    state and graft them into the live store that kept serving in degraded
    mode.

    1. ``replay(upto_seq=journal.applied_seq)`` reconstructs the
       pre-outage store byte-for-byte (incremental-checkpoint chain +
       journal replay — PR 6's byte-identity pin, bounded here at the
       applied watermark so queued-during-outage commits are excluded).
    2. ``splice_owner_blocks`` moves ONLY the dead owner's out/inc block
       rows into the live store; the geid→slot permutation (``gperm``)
       lives inside those rows, so the spliced store is immediately
       servable with no re-index pass. The replacement owner is whichever
       device holds that shard of the re-``device_put`` store — migration
       and restart-in-place are the same code path.

    The caller then runs ``drain_queued`` to apply the outage window's
    queued commits (against the LIVE cache, so maintenance listeners see
    them) and finally marks the owner healthy. Returns ``(pstore, info)``.
    """
    import jax

    replayed, last, info = replay(
        journal, rt, ttable, default_policy=default_policy,
        upto_seq=journal.applied_seq,
    )
    from repro.graphstore.partition import splice_owner_blocks

    live_host = jax.tree_util.tree_map(np.asarray, jax.device_get(live_pstore))
    dead_host = jax.tree_util.tree_map(np.asarray, jax.device_get(replayed))
    spliced = splice_owner_blocks(rt.pspec, live_host, dead_host, owner)
    pstore = jax.device_put(spliced, rt.store_sharding())
    info.update(recovered_owner=int(owner), replayed_to_seq=int(last))
    return pstore, info


def drain_queued(journal: WriteBehindJournal, rt, ttable, pstore, cache, *,
                 after_seq: Optional[int] = None,
                 default_policy: str = "write-around",
                 rhost=None):
    """Apply the commits that queued (durable but unapplied) during an
    outage, in journal order, through the normal gRW step against the LIVE
    store and cache — write policies and maintenance listeners observe them
    exactly as if they had committed late, which they did. Advances
    ``journal.applied_seq`` per record and clears the queued counter.
    ``rhost`` (the live ``RoutingTableHost``) routes drained appends and
    absorbs any drained MIGRATE records; omit it on unmigrated
    deployments. Returns ``(pstore, cache, info)``."""
    import jax

    from repro.graphstore.migration import migrate_vertex_rows

    journal.flush()
    after = journal.applied_seq if after_seq is None else int(after_seq)
    info = {"drained_commits": 0, "drained_compactions": 0,
            "drained_growths": 0, "drained_migrations": 0}
    for rec in journal.read_records(after_seq=after):
        if rec.rtype == REC_COMMIT:
            batch, policy, gate = decode_commit(rec.payload)
            pstore, cache, _ = rt.run_grw_tx(
                pstore, cache, ttable, batch,
                policy=policy or default_policy, gate=gate,
                occupancy_metrics=False,
                rtable=(
                    rhost.device_table()
                    if rhost is not None and rhost.has_exceptions() else None
                ),
            )
            info["drained_commits"] += 1
        elif rec.rtype == REC_COMPACT:
            purge = json.loads(rec.payload.decode())["purge"]
            pstore = rt.compact_step(purge)(pstore)
            info["drained_compactions"] += 1
        elif rec.rtype == REC_GROW:
            m = json.loads(rec.payload.decode())
            pstore = rt.grow_blocks(
                pstore, m["e_blk_cap"], recent_blk_cap=m["recent_blk_cap"]
            )
            info["drained_growths"] += 1
        elif rec.rtype == REC_MIGRATE:
            moves = [
                (int(v), int(d))
                for v, d in json.loads(rec.payload.decode())["moves"]
            ]
            pstore = jax.device_put(
                migrate_vertex_rows(rt.pspec, pstore, moves),
                rt.store_sharding(),
            )
            if rhost is not None:
                rhost.apply_moves(moves)
            info["drained_migrations"] += 1
        with journal._lock:
            journal.applied_seq = max(journal.applied_seq, rec.seq)
    with journal._lock:
        journal._queued_commits = 0
    journal.epochs.advance(int(np.asarray(jax.device_get(pstore.version))))
    return pstore, cache, info
