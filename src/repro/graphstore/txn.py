"""Optimistic commit protocol (the FDB OCC analogue, DESIGN.md §2).

A transaction reads at a snapshot version and records the vertices whose
state its result depends on (its read-conflict set). Commit succeeds only if
none of those vertices was written after the snapshot — exactly FDB's
key-range conflict check, at vertex granularity. Used by the asynchronous
cache-population path (core/population.py) so that a CP transaction racing a
gRW-Tx aborts instead of installing a stale cache entry.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.graphstore.store import GraphStore, StoreSpec
from repro.utils import take_along0


class TxnError(Exception):
    """Raised (host-side) when a transaction exceeds its retry budget."""


def conflicts(
    spec: StoreSpec,
    store: GraphStore,
    read_version,
    read_set,
    read_mask,
    axis=None,
):
    """True iff any vertex in ``read_set`` was written after ``read_version``.

    ``axis=None`` collapses the whole read set to one verdict (a single
    transaction); ``axis=1`` checks a [B, W] batch of per-transaction read
    sets independently (the CP population path, single-host and sharded).
    """
    ver = take_along0(store.vversion, read_set)
    return jnp.any(read_mask & (ver > read_version), axis=axis)


def commit_with_conflict_check(
    spec: StoreSpec,
    store: GraphStore,
    read_version,
    read_set,
    read_mask,
    apply_fn,
):
    """Functionally commit ``apply_fn(store)`` iff the read set is clean.

    Returns (store', committed: bool array). ``apply_fn`` must be pure.
    """
    bad = conflicts(spec, store, read_version, read_set, read_mask)
    new_store = apply_fn(store)
    import jax

    merged = jax.tree_util.tree_map(
        lambda a, b: jnp.where(bad, a, b), store, new_store
    )
    return merged, ~bad
