"""Optimizers and distributed-optimization tricks (AdamW, ZeRO-1 sharding,
int8 error-feedback gradient compression, clipping, schedules)."""

from repro.optim.adamw import GradientTransform, adamw, clip_by_global_norm, chain
from repro.optim.compression import int8_compress_grads
from repro.optim.schedule import cosine_schedule

__all__ = [
    "GradientTransform",
    "adamw",
    "chain",
    "clip_by_global_norm",
    "cosine_schedule",
    "int8_compress_grads",
]
