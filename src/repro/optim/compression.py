"""Int8 gradient compression with error feedback (beyond-paper extra).

On a 1000+-node data-parallel job the DP all-reduce of bf16 gradients can
dominate the step; quantizing to int8 with a per-block scale cuts the
collective bytes 2x (vs bf16) while error feedback keeps the optimizer
unbiased in the long run (residuals are re-added next step).

Usage: wrap the grads before psum / before the optimizer:
    grads_q, new_residual = int8_compress_grads(grads, residual)
The roundtrip (quantize -> dequantize) happens around the collective; under
GSPMD we express it as quantize -> psum(int32) -> dequantize when
``psum_axis`` is given inside shard_map, else as a pure roundtrip whose
collective savings show up in the lowered HLO bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quant_one(g, r):
    g32 = g.astype(jnp.float32) + (r.astype(jnp.float32) if r is not None else 0.0)
    flat = g32.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    deq = deq[: g32.size].reshape(g32.shape)
    residual = g32 - deq
    return deq.astype(g.dtype), residual.astype(jnp.float32)


def int8_compress_grads(grads, residuals=None):
    """Per-block int8 quantization roundtrip + error feedback residuals."""
    if residuals is None:
        residuals = jax.tree_util.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [_quant_one(g, r) for g, r in zip(flat_g, flat_r)]
    deq = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    res = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return deq, res
