"""Minimal optax-style gradient transforms (no external deps).

AdamW keeps fp32 moments regardless of param dtype; with ZeRO-1 the moment
pytrees are sharded by ``repro.distributed.add_data_axis`` at the jit
boundary (see launch/train.py) — the transform itself is sharding-agnostic.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class GradientTransform(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def chain(*transforms: GradientTransform) -> GradientTransform:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransform(init, update)


def clip_by_global_norm(max_norm: float) -> GradientTransform:
    def init(params):
        return ()

    def update(grads, state, params):
        leaves = jax.tree_util.tree_leaves(grads)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
        return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), state

    return GradientTransform(init, update)


class AdamWState(NamedTuple):
    step: jax.Array
    m: object  # pytree like params, fp32
    v: object


def adamw(
    lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0, moment_dtype=jnp.float32
) -> GradientTransform:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return AdamWState(
            step=jnp.int32(0),
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params),
        )

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = (b1 * m.astype(jnp.float32) + (1 - b1) * g32).astype(moment_dtype)
            v = (b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)).astype(moment_dtype)
            mhat = m.astype(jnp.float32) / (1 - b1**step.astype(jnp.float32))
            vhat = v.astype(jnp.float32) / (1 - b2**step.astype(jnp.float32))
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u, m, v

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
        return updates, AdamWState(step=step, m=new_m, v=new_v)

    return GradientTransform(init, update)
