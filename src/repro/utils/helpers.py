"""Low-level tensor helpers used across the graph store, cache, and engine.

Everything here is pure-functional, fixed-shape, and jit/vmap/shard_map
friendly. We deliberately stay in 32-bit: slot-selection and fingerprint
hashes are two *independently seeded* 32-bit multiplicative mixes, which
together give 64 effective bits — the collision budget is documented in
DESIGN.md §2.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Sentinel for a missing property value (paper: a predicate on a missing
# property never qualifies; wildcards require presence — Algorithm 7 line 2).
PROP_MISSING = jnp.int32(-(2**31) + 1)
# Sentinel for an absent id (padding in frontiers, values, probe results).
NULL_ID = jnp.int32(-1)

_GOLDEN = jnp.uint32(0x9E3779B9)
_MIX1 = jnp.uint32(0x85EBCA6B)
_MIX2 = jnp.uint32(0xC2B2AE35)


def hash_mix(h, x):
    """One round of a murmur3-style 32-bit mix: fold ``x`` into state ``h``."""
    h = jnp.asarray(h, jnp.uint32)
    x = jnp.asarray(x, jnp.uint32)
    x = x * _GOLDEN
    x = (x << 15) | (x >> 17)
    x = x * _MIX1
    h = h ^ x
    h = (h << 13) | (h >> 19)
    return h * jnp.uint32(5) + jnp.uint32(0xE6546B64)


def _finalize(h):
    h = h ^ (h >> 16)
    h = h * _MIX1
    h = h ^ (h >> 13)
    h = h * _MIX2
    return h ^ (h >> 16)


def hash_rows(cols, seed: int):
    """Hash a sequence of int32 arrays (same shape) element-wise into uint32.

    ``cols`` is a list/tuple of broadcast-compatible int32 arrays; each array
    contributes one mix round. Different ``seed`` values give independent
    hash families (slot hash vs fingerprint).
    """
    h = jnp.uint32(seed)
    for c in cols:
        h = hash_mix(h, jnp.asarray(c).astype(jnp.uint32))
    return _finalize(h)


def compact_masked(vals, mask, out_width: int, fill=NULL_ID):
    """Stream-compact ``vals`` where ``mask`` along the last axis.

    Works on [..., W] inputs; returns ([..., out_width] vals, [..., out_width]
    mask). Order-preserving. Entries beyond ``out_width`` are dropped (the
    caller sees the returned count saturate).
    """
    mask = mask.astype(bool)
    idx = jnp.cumsum(mask, axis=-1) - 1  # destination slot per kept element
    dest = jnp.where(mask, idx, out_width)  # dropped -> OOB, scatter-drop
    out = jnp.full(vals.shape[:-1] + (out_width,), fill, vals.dtype)
    if vals.ndim == 1:
        out = out.at[dest].set(vals, mode="drop")
        n = jnp.minimum(jnp.sum(mask, -1), out_width)
        omask = jnp.arange(out_width) < n
        return out, omask
    # batched: scatter along last axis with explicit leading index grid
    flat_vals = vals.reshape(-1, vals.shape[-1])
    flat_dest = dest.reshape(-1, vals.shape[-1])
    flat_out = jnp.full((flat_vals.shape[0], out_width), fill, vals.dtype)
    rows = jnp.arange(flat_vals.shape[0])[:, None]
    flat_out = flat_out.at[rows, flat_dest].set(flat_vals, mode="drop")
    out = flat_out.reshape(vals.shape[:-1] + (out_width,))
    n = jnp.minimum(jnp.sum(mask, -1), out_width)
    omask = jnp.arange(out_width) < n[..., None]
    return out, omask


def sort_dedup_masked(vals, mask, out_width: int, fill=NULL_ID):
    """Sort-based per-row dedup + order-preserving compaction (device).

    Semantically identical to the host-side frontier merge: keep the first
    occurrence of each distinct masked value in *original* order, compact
    left, truncate to ``out_width``, pad with ``fill``. Unlike
    ``dedup_masked`` this is O(W log W) per row (a stable sort + an adjacent
    compare) instead of O(W^2), so it scales to frontier-merge widths
    (F * result_width) inside one jitted hop program.
    """
    mask = mask.astype(bool)
    big = jnp.int32(2**31 - 1)  # sorts after every valid id
    keyed = jnp.where(mask, vals, big)
    order = jnp.argsort(keyed, axis=-1, stable=True)
    sv = jnp.take_along_axis(keyed, order, axis=-1)
    first = jnp.concatenate(
        [jnp.ones(sv.shape[:-1] + (1,), bool), sv[..., 1:] != sv[..., :-1]],
        axis=-1,
    )
    keep_sorted = first & (sv != big)
    inv = jnp.argsort(order, axis=-1)  # invert the permutation
    keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
    return compact_masked(vals, keep, out_width, fill)


def segmented_dedup_merge(vals, counts, out_width: int, fill=NULL_ID):
    """Frontier merge specialized for *left-packed* segments (device).

    ``vals``: [B, S, W] where each segment row holds ``counts[b, s]`` valid
    entries left-packed at offsets [0, counts). Equivalent to running
    ``sort_dedup_masked`` on the flattened [B, S*W] row with the prefix
    masks — first occurrence kept, original order, truncated to
    ``out_width`` — but touches only ``out_width``-sized windows per round:
    global ranks are mapped to (segment, offset) by binary search over the
    per-segment prefix sums, so there is no full-width sort, scatter, or
    cumsum. Cost per round is O(B·F·(F + log S)); rows finish in
    ceil(n_valid / F) rounds, which the cached hop pipeline keeps at 1-2.
    """
    B, S, W = vals.shape
    F = out_width
    counts = jnp.asarray(counts, jnp.int32)
    cum = jnp.cumsum(counts, axis=1)  # [B, S] tiny
    n_valid = cum[:, -1]
    vflat = vals.reshape(B, S * W)
    rows = jnp.arange(B)[:, None]
    tril = jnp.tril(jnp.ones((F, F), bool), k=-1)
    nwin = -(-(S * W) // F)
    n_steps = max(S.bit_length() + 1, 1)

    def rank_positions(targets):  # 1-based ranks [B, F] -> flat positions
        lo = jnp.zeros(targets.shape, jnp.int32)
        hi = jnp.full(targets.shape, S - 1, jnp.int32)

        def step(_, lohi):  # first segment s with cum[s] >= target
            lo, hi = lohi
            mid = (lo + hi) // 2
            ge = cum[rows, mid] >= targets
            return jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi)

        seg, _ = jax.lax.fori_loop(0, n_steps, step, (lo, hi))
        seg = jnp.clip(seg, 0, S - 1)
        prev = jnp.where(seg > 0, cum[rows, jnp.maximum(seg - 1, 0)], 0)
        return seg * W + (targets - 1 - prev)

    def cond(state):
        win, _, acc_n = state
        return (win < nwin) & jnp.any((acc_n < F) & (win * F < n_valid))

    def body(state):
        win, acc_vals, acc_n = state
        targets = win * F + 1 + jnp.arange(F, dtype=jnp.int32)[None, :]
        wm = targets <= n_valid[:, None]
        pos = rank_positions(jnp.minimum(targets, jnp.maximum(n_valid[:, None], 1)))
        wv = jnp.where(wm, vflat[rows, jnp.clip(pos, 0, S * W - 1)], fill)
        dup_acc = jnp.any(
            (wv[:, :, None] == acc_vals[:, None, :])
            & (jnp.arange(F)[None, None, :] < acc_n[:, None, None]),
            axis=2,
        )
        dup_win = jnp.any((wv[:, :, None] == wv[:, None, :]) & tril[None], axis=2)
        keep = wm & ~dup_acc & ~dup_win
        dest = acc_n[:, None] + jnp.cumsum(keep.astype(jnp.int32), axis=1) - 1
        dest = jnp.where(keep & (dest < F), dest, F)  # OOB -> drop
        acc_vals = acc_vals.at[rows, dest].set(wv, mode="drop")
        acc_n = jnp.minimum(acc_n + jnp.sum(keep.astype(jnp.int32), axis=1), F)
        return win + 1, acc_vals, acc_n

    acc_vals = jnp.full((B, F), fill, vals.dtype)
    acc_n = jnp.zeros((B,), jnp.int32)
    _, acc_vals, acc_n = jax.lax.while_loop(
        cond, body, (jnp.int32(0), acc_vals, acc_n)
    )
    omask = jnp.arange(F)[None, :] < acc_n[:, None]
    return jnp.where(omask, acc_vals, fill), omask


def dedup_masked(vals, mask):
    """Mask out duplicate values along the last axis (keeps first occurrence).

    O(W^2) pairwise compare — W is a small static frontier width.
    """
    v = jnp.where(mask, vals, NULL_ID)
    eq = v[..., :, None] == v[..., None, :]  # [..., W, W]
    earlier = jnp.tril(jnp.ones(eq.shape[-2:], bool), k=-1)
    dup = jnp.any(eq & earlier, axis=-1)
    return mask & ~dup


def take_along0(table, idx):
    """``table[idx]`` with idx clipped to valid range (caller masks)."""
    return jnp.take(table, jnp.clip(idx, 0, table.shape[0] - 1), axis=0)
