"""Low-level tensor helpers used across the graph store, cache, and engine.

Everything here is pure-functional, fixed-shape, and jit/vmap/shard_map
friendly. We deliberately stay in 32-bit: slot-selection and fingerprint
hashes are two *independently seeded* 32-bit multiplicative mixes, which
together give 64 effective bits — the collision budget is documented in
DESIGN.md §2.
"""

from __future__ import annotations

import jax.numpy as jnp

# Sentinel for a missing property value (paper: a predicate on a missing
# property never qualifies; wildcards require presence — Algorithm 7 line 2).
PROP_MISSING = jnp.int32(-(2**31) + 1)
# Sentinel for an absent id (padding in frontiers, values, probe results).
NULL_ID = jnp.int32(-1)

_GOLDEN = jnp.uint32(0x9E3779B9)
_MIX1 = jnp.uint32(0x85EBCA6B)
_MIX2 = jnp.uint32(0xC2B2AE35)


def hash_mix(h, x):
    """One round of a murmur3-style 32-bit mix: fold ``x`` into state ``h``."""
    h = jnp.asarray(h, jnp.uint32)
    x = jnp.asarray(x, jnp.uint32)
    x = x * _GOLDEN
    x = (x << 15) | (x >> 17)
    x = x * _MIX1
    h = h ^ x
    h = (h << 13) | (h >> 19)
    return h * jnp.uint32(5) + jnp.uint32(0xE6546B64)


def _finalize(h):
    h = h ^ (h >> 16)
    h = h * _MIX1
    h = h ^ (h >> 13)
    h = h * _MIX2
    return h ^ (h >> 16)


def hash_rows(cols, seed: int):
    """Hash a sequence of int32 arrays (same shape) element-wise into uint32.

    ``cols`` is a list/tuple of broadcast-compatible int32 arrays; each array
    contributes one mix round. Different ``seed`` values give independent
    hash families (slot hash vs fingerprint).
    """
    h = jnp.uint32(seed)
    for c in cols:
        h = hash_mix(h, jnp.asarray(c).astype(jnp.uint32))
    return _finalize(h)


def compact_masked(vals, mask, out_width: int, fill=NULL_ID):
    """Stream-compact ``vals`` where ``mask`` along the last axis.

    Works on [..., W] inputs; returns ([..., out_width] vals, [..., out_width]
    mask). Order-preserving. Entries beyond ``out_width`` are dropped (the
    caller sees the returned count saturate).
    """
    mask = mask.astype(bool)
    idx = jnp.cumsum(mask, axis=-1) - 1  # destination slot per kept element
    dest = jnp.where(mask, idx, out_width)  # dropped -> OOB, scatter-drop
    out = jnp.full(vals.shape[:-1] + (out_width,), fill, vals.dtype)
    if vals.ndim == 1:
        out = out.at[dest].set(vals, mode="drop")
        n = jnp.minimum(jnp.sum(mask, -1), out_width)
        omask = jnp.arange(out_width) < n
        return out, omask
    # batched: scatter along last axis with explicit leading index grid
    flat_vals = vals.reshape(-1, vals.shape[-1])
    flat_dest = dest.reshape(-1, vals.shape[-1])
    flat_out = jnp.full((flat_vals.shape[0], out_width), fill, vals.dtype)
    rows = jnp.arange(flat_vals.shape[0])[:, None]
    flat_out = flat_out.at[rows, flat_dest].set(flat_vals, mode="drop")
    out = flat_out.reshape(vals.shape[:-1] + (out_width,))
    n = jnp.minimum(jnp.sum(mask, -1), out_width)
    omask = jnp.arange(out_width) < n[..., None]
    return out, omask


def dedup_masked(vals, mask):
    """Mask out duplicate values along the last axis (keeps first occurrence).

    O(W^2) pairwise compare — W is a small static frontier width.
    """
    v = jnp.where(mask, vals, NULL_ID)
    eq = v[..., :, None] == v[..., None, :]  # [..., W, W]
    earlier = jnp.tril(jnp.ones(eq.shape[-2:], bool), k=-1)
    dup = jnp.any(eq & earlier, axis=-1)
    return mask & ~dup


def take_along0(table, idx):
    """``table[idx]`` with idx clipped to valid range (caller masks)."""
    return jnp.take(table, jnp.clip(idx, 0, table.shape[0] - 1), axis=0)
