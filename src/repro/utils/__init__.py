"""Shared tensor utilities: sentinels, hashing, masked stream compaction."""

from repro.utils.helpers import (
    PROP_MISSING,
    NULL_ID,
    compact_masked,
    dedup_masked,
    hash_mix,
    hash_rows,
    segmented_dedup_merge,
    sort_dedup_masked,
    take_along0,
)

__all__ = [
    "PROP_MISSING",
    "NULL_ID",
    "compact_masked",
    "dedup_masked",
    "hash_mix",
    "hash_rows",
    "segmented_dedup_merge",
    "sort_dedup_masked",
    "take_along0",
]
