"""LM architecture configuration."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None  # defaults to d_model // n_heads
    # MoE (n_experts == 0 -> dense FFN)
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0  # DeepSeek/Kimi-style always-on experts
    capacity_factor: float = 1.25
    # attention
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # local layers' window
    local_global_pattern: int = 0  # N -> N local layers per 1 global (0 = all global)
    # numerics / memory
    dtype: str = "bfloat16"
    remat: bool = True
    attn_q_chunk: int = 256
    attn_k_chunk: int = 256
    loss_chunk: int = 512
    # distribution hints (axes dropped automatically when indivisible)
    shard_experts_over: str = "model"

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_is_local(self, i: int) -> bool:
        """gemma3-style 5:1 pattern: layers 0..4 local, 5 global, ..."""
        if self.local_global_pattern <= 0 or self.sliding_window is None:
            return False
        return (i % (self.local_global_pattern + 1)) != self.local_global_pattern

    def param_count(self) -> int:
        """Total parameters (embedding + unembedding included)."""
        d, dh = self.d_model, self.head_dim
        attn = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        if self.is_moe:
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            ffn += self.n_shared_experts * 3 * d * self.d_ff
        else:
            ffn = 3 * d * self.d_ff
        norms = 2 * d
        per_layer = attn + ffn + norms
        return self.n_layers * per_layer + 2 * self.vocab * d + d

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k + shared experts)."""
        if not self.is_moe:
            return self.param_count()
        d, dh = self.d_model, self.head_dim
        attn = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        ffn = (self.top_k + self.n_shared_experts) * 3 * d * self.d_ff
        ffn += d * self.n_experts  # router
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d
