"""Transformer LM substrate: GQA + RoPE, dense & MoE FFN, local:global
attention hybrids, flash-style chunked attention, KV-cache decode."""

from repro.lm.config import LMConfig
from repro.lm.model import (
    abstract_params,
    decode_step,
    forward,
    init_kv_cache,
    init_params,
    loss_fn,
    prefill_step,
    train_step,
)

__all__ = [
    "LMConfig",
    "abstract_params",
    "init_params",
    "forward",
    "loss_fn",
    "train_step",
    "decode_step",
    "prefill_step",
    "init_kv_cache",
]
