"""Transformer layer pieces: RMSNorm, RoPE, SwiGLU FFN, sort-based MoE."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, w, eps=1e-6):
    # square in the input dtype, accumulate the mean in f32: numerically the
    # f32 accumulation is what matters, and keeping x's consumers bf16 stops
    # XLA hoisting a bf16->f32 convert above the TP partial-sum all-reduce
    # that feeds the residual (2x collective bytes; §Perf iteration 4).
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w


def rope(x, positions, theta: float):
    """Rotary embedding. x: [B, S, H, dh], positions: [S] or [B, S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [S, half]
        ang = ang[None, :, None, :]
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
        ang = ang[:, :, None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w1, w3, w2):
    """x @ w1 -> silu, gate x @ w3, down w2. Shapes: [.., D]x[D,F]."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def moe_ffn(x, router_w, we1, we3, we2, *, top_k: int, capacity_factor: float):
    """Token-choice top-k MoE with sort-based dispatch and capacity drop.

    x: [N, D] tokens; router_w: [D, E]; we*: [E, D, F] / [E, F, D].
    Returns [N, D]. The dispatch is fully static-shape: tokens sort by
    expert, take a rank within their expert group, and tokens past the
    capacity C = ceil(N * top_k / E * capacity_factor) are dropped (standard
    GShard/Switch semantics).
    """
    N, D = x.shape
    E = router_w.shape[-1]
    F = we1.shape[-1]
    C = max(1, int(N * top_k / E * capacity_factor))

    logits = (x @ router_w).astype(jnp.float32)  # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, top_k)  # [N, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_expert = expert.reshape(-1)  # [N*k]
    flat_token = jnp.repeat(jnp.arange(N), top_k)
    flat_gate = gate.reshape(-1)

    order = jnp.argsort(flat_expert, stable=True)
    se, stok, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # rank of each routed pair within its expert group
    offsets = jnp.searchsorted(se, jnp.arange(E), side="left")
    rank = jnp.arange(N * top_k) - offsets[se]
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)  # OOB -> dropped

    # dispatch: xe[e, c] = x[token assigned to that slot]
    slot_token = jnp.zeros(E * C, jnp.int32).at[slot].set(stok.astype(jnp.int32), mode="drop")
    slot_used = jnp.zeros(E * C, bool).at[slot].set(keep, mode="drop")
    xe = x[slot_token] * slot_used[:, None].astype(x.dtype)
    xe = xe.reshape(E, C, D)
    # (§Perf: explicit expert-parallel pins on xe/ye were REFUTED — forcing
    # (E-model, C-batch) layouts made the partitioner reshard the dispatch
    # buffers per layer, 464GB -> 47TB on kimi. Propagation from the
    # E-sharded expert weights alone is the measured best.)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, we1))
    h = h * jnp.einsum("ecd,edf->ecf", xe, we3)
    ye = jnp.einsum("ecf,efd->ecd", h, we2).reshape(E * C, D)

    # combine: gather each routed pair's expert output (dropped -> zeros)
    # and scatter-add back to its token, weighted by the gate
    y_pair = jnp.where(
        keep[:, None], ye[jnp.clip(slot, 0, E * C - 1)], 0.0
    )
    out = jnp.zeros((N, D), x.dtype)
    out = out.at[stok].add((y_pair * sg[:, None]).astype(x.dtype), mode="drop")
    aux = _load_balance_loss(probs, expert, E)
    return out, aux


def _load_balance_loss(probs, expert, E):
    """Switch-style auxiliary load-balancing loss."""
    N, k = expert.shape
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    ce = jnp.zeros(E, jnp.float32).at[expert.reshape(-1)].add(1.0) / (N * k)
    return E * jnp.sum(me * ce)
