"""LM forward/training/decode with scan-over-layers and logical sharding.

Everything shape-critical is expressed with ``jax.lax.scan`` over a stacked
layer pytree so the lowered HLO is O(1) in depth, and all sharding is
expressed through ``repro.distributed.constrain`` logical specs — the same
code compiles on one CPU device, the (16,16) pod mesh, and the (2,16,16)
multi-pod mesh.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.lm.attention import decode_attention, flash_attention
from repro.lm.config import LMConfig
from repro.lm.layers import moe_ffn, rms_norm, rope, swiglu

BATCH = ("pod", "data")  # logical batch axes
TP = "model"


def _layer_shapes(cfg: LMConfig) -> dict:
    D, H, KV, dh, F = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
    L = cfg.n_layers
    shapes = {
        "attn_norm": (L, D),
        "mlp_norm": (L, D),
        "wq": (L, D, H * dh),
        "wk": (L, D, KV * dh),
        "wv": (L, D, KV * dh),
        "wo": (L, H * dh, D),
    }
    if cfg.is_moe:
        shapes.update(
            router=(L, D, cfg.n_experts),
            we1=(L, cfg.n_experts, D, F),
            we3=(L, cfg.n_experts, D, F),
            we2=(L, cfg.n_experts, F, D),
        )
        if cfg.n_shared_experts:
            Fs = F * cfg.n_shared_experts
            shapes.update(ws1=(L, D, Fs), ws3=(L, D, Fs), ws2=(L, Fs, D))
    else:
        shapes.update(w1=(L, D, F), w3=(L, D, F), w2=(L, F, D))
    return shapes


def param_shapes(cfg: LMConfig) -> dict:
    return {
        "embed": (cfg.vocab, cfg.d_model),
        "unembed": (cfg.d_model, cfg.vocab),
        "final_norm": (cfg.d_model,),
        "layers": _layer_shapes(cfg),
    }


def abstract_params(cfg: LMConfig):
    """ShapeDtypeStruct pytree — the dry-run's zero-allocation stand-in."""
    dt = jnp.dtype(cfg.dtype)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s, dt), param_shapes(cfg),
        is_leaf=lambda x: isinstance(x, tuple),
    )


def init_params(cfg: LMConfig, key):
    """Real initialization (smoke tests / the 100M example run)."""
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten(
        shapes, is_leaf=lambda x: isinstance(x, tuple)
    )
    keys = jax.random.split(key, len(flat))
    dt = jnp.dtype(cfg.dtype)

    def init_one(k, shape):
        if len(shape) == 1 or shape[-1] == 1:
            return jnp.ones(shape, dt)  # norms
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return (jax.random.normal(k, shape, jnp.float32) * fan_in**-0.5).astype(dt)

    return jax.tree_util.tree_unflatten(treedef, [init_one(k, s) for k, s in zip(keys, flat)])


def param_spec_rule(cfg: LMConfig):
    """Logical PartitionSpecs by param name (validated/dropped per mesh)."""

    def rule(path: str, leaf):
        if "embed'" in path or path.endswith("embed']"):
            return ("model", None) if "unembed" not in path else (None, "model")
        if "unembed" in path:
            return (None, "model")
        if "norm" in path:
            return (None,)
        if any(k in path for k in ("wq", "wk", "wv", "w1", "w3", "ws1", "ws3")):
            return (None, None, "model")
        if any(k in path for k in ("wo", "w2", "ws2")):
            return (None, "model", None)
        if "router" in path:
            return (None, None, None)
        if any(k in path for k in ("we1", "we3")):
            # expert parallel over 'model' when divisible, else TP on F
            if cfg.n_experts % 16 == 0:
                return (None, "model", None, None)
            return (None, None, None, "model")
        if "we2" in path:
            if cfg.n_experts % 16 == 0:
                return (None, "model", None, None)
            return (None, None, "model", None)
        return ()

    return rule


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _block(cfg: LMConfig, x, lp, is_local, positions, layer_aux):
    """One transformer block (scanned). x: [B, S, D].

    Layout (§Perf iteration 5 — sequence parallel + context parallel):
    the residual stream, norms and FFN run sharded along S over 'model'
    (so every elementwise/norm op and its remat recompute touch 1/TP of
    the activations); attention keeps q S-sharded while k/v gather to
    full S (GQA KV heads are small), so scores never reshard inside the
    flash scans. Iterations 1-4 (head-sharded activations / no
    constraints / bf16-norm) were all refuted — see EXPERIMENTS.md §Perf.
    """
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    # MoE blocks keep the batch-sharded residual: the [B,S,D]->[B*S,D]
    # dispatch reshape cannot carry an S-sharding (GSPMD gathers), so SP
    # only pays off for dense blocks (measured: kimi/grok regressed 2x
    # under SP; glm4/yi/gemma3 improved 2.8-4.5x).
    seq_par = not cfg.is_moe
    res_spec = (BATCH, TP, None) if seq_par else (BATCH, None, None)
    x = constrain(x, *res_spec)
    h = rms_norm(x, lp["attn_norm"])
    q = (h @ lp["wq"]).reshape(B, S, H, dh)
    k = (h @ lp["wk"]).reshape(B, S, KV, dh)
    v = (h @ lp["wv"]).reshape(B, S, KV, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if seq_par:
        q = constrain(q, BATCH, TP, None, None)  # context parallel q
        k = constrain(k, BATCH, None, None, None)  # full-S KV (GQA: small)
        v = constrain(v, BATCH, None, None, None)
    else:
        q = constrain(q, BATCH, None, TP, None)  # head-TP (baseline layout)
        k = constrain(k, BATCH, None, TP, None)
    window = None
    if cfg.sliding_window is not None:
        # traced per-layer selector: 0 disables the band mask
        window = jnp.where(is_local, cfg.sliding_window, 0)
    # §Perf iteration 6: under sequence parallelism the outer q-scan's
    # dynamic-slice walks a sharded axis (re-gathering q per block); with
    # S-sharded q each device's rows are one chunk — skip the q-scan and
    # let the k-scan bound memory.
    from repro.distributed import active_mesh

    q_chunk = S if (seq_par and active_mesh() is not None) else cfg.attn_q_chunk
    attn = flash_attention(
        q, k, v, causal=True, window=window,
        q_chunk=q_chunk, k_chunk=cfg.attn_k_chunk,
    )
    if seq_par:
        attn = constrain(attn, BATCH, TP, None, None)
    x = x + constrain(attn.reshape(B, S, H * dh) @ lp["wo"], *res_spec)

    h = rms_norm(x, lp["mlp_norm"])
    if cfg.is_moe:
        flat = h.reshape(B * S, D)
        y, aux = moe_ffn(
            flat, lp["router"], lp["we1"], lp["we3"], lp["we2"],
            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
        )
        if cfg.n_shared_experts:
            y = y + swiglu(flat, lp["ws1"], lp["ws3"], lp["ws2"])
        y = y.reshape(B, S, D)
        layer_aux = layer_aux + aux
    else:
        y = swiglu(h, lp["w1"], lp["w3"], lp["w2"])
    x = x + constrain(y, *res_spec)
    # pin the scan carry's sharding so the while-loop body has a
    # consistent fixed point
    x = constrain(x, *res_spec)
    return x, layer_aux


def forward(cfg: LMConfig, params, tokens, positions=None):
    """tokens [B, S] -> final hidden states [B, S, D] (+ MoE aux loss)."""
    B, S = tokens.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, BATCH, None, None)
    is_local = jnp.asarray(
        [cfg.layer_is_local(i) for i in range(cfg.n_layers)], bool
    )

    def body(carry, xs):
        x, aux = carry
        lp, loc = xs
        fn = _block
        if cfg.remat:
            fn = jax.checkpoint(
                functools.partial(_block, cfg),
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )
            x, aux = fn(x, lp, loc, positions, aux)
        else:
            x, aux = fn(cfg, x, lp, loc, positions, aux)
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)), (params["layers"], is_local))
    return rms_norm(x, params["final_norm"]), aux


def loss_fn(cfg: LMConfig, params, tokens, labels):
    """Chunked softmax cross-entropy (never materializes [B, S, V])."""
    h, aux = forward(cfg, params, tokens)
    B, S, D = h.shape
    C = min(cfg.loss_chunk, S)
    assert S % C == 0
    n = S // C

    def chunk(carry, i):
        hs = jax.lax.dynamic_slice_in_dim(h, i * C, C, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, i * C, C, axis=1)
        logits = hs @ params["unembed"]
        logits = constrain(logits, BATCH, None, TP).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(chunk, jnp.float32(0), jnp.arange(n))
    loss = total / (B * S)
    if cfg.is_moe:
        loss = loss + 0.01 * aux / cfg.n_layers
    return loss


def train_step(cfg: LMConfig, optimizer):
    """Build the jit-able (params, opt_state, batch) -> (params', state',
    metrics) step. ``optimizer`` is a repro.optim GradientTransform."""

    def step(params, opt_state, tokens, labels):
        loss, grads = jax.value_and_grad(functools.partial(loss_fn, cfg, tokens=tokens, labels=labels))(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(grads))
        )
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array  # [L, B, S, KV, dh]
    v: jax.Array


def init_kv_cache(cfg: LMConfig, batch: int, max_len: int, abstract=False):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    dt = jnp.dtype(cfg.dtype)
    if abstract:
        return KVCache(jax.ShapeDtypeStruct(shape, dt), jax.ShapeDtypeStruct(shape, dt))
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def kv_cache_spec_rule(cfg: LMConfig):
    """KV cache sharding: batch over (pod,data); heads over model when they
    divide, else the sequence axis (context parallelism for long KV)."""

    def rule(path: str, leaf):
        if cfg.n_kv_heads % 16 == 0:
            return (None, BATCH, None, "model", None)
        return (None, BATCH, "model", None, None)

    return rule


def decode_step(cfg: LMConfig, params, cache: KVCache, tokens, pos):
    """One token for every sequence. tokens [B, 1]; pos scalar int32 =
    current position (cache valid for [0, pos)). Returns (next_logits_argmax
    [B, 1], cache')."""
    B = tokens.shape[0]
    H, KV, dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))  # [B, 1, D]
    positions = jnp.full((B, 1), pos, jnp.int32)
    is_local = jnp.asarray([cfg.layer_is_local(i) for i in range(cfg.n_layers)], bool)

    def body(x, xs):
        lp, kc, vc, loc = xs
        h = rms_norm(x, lp["attn_norm"])
        q = rope((h @ lp["wq"]).reshape(B, 1, H, dh), positions, cfg.rope_theta)
        k = rope((h @ lp["wk"]).reshape(B, 1, KV, dh), positions, cfg.rope_theta)
        v = (h @ lp["wv"]).reshape(B, 1, KV, dh)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
        window = None
        if cfg.sliding_window is not None:
            window = jnp.where(loc, cfg.sliding_window, 0)  # 0 = unwindowed
        attn = decode_attention(q, kc, vc, pos + 1, window=window)
        x = x + attn.reshape(B, 1, H * dh) @ lp["wo"]
        h2 = rms_norm(x, lp["mlp_norm"])
        if cfg.is_moe:
            flat = h2.reshape(B, D)
            y, _ = moe_ffn(
                flat, lp["router"], lp["we1"], lp["we3"], lp["we2"],
                top_k=cfg.top_k, capacity_factor=4.0,
            )
            if cfg.n_shared_experts:
                y = y + swiglu(flat, lp["ws1"], lp["ws3"], lp["ws2"])
            y = y.reshape(B, 1, D)
        else:
            y = swiglu(h2, lp["w1"], lp["w3"], lp["w2"])
        return x + y, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache.k, cache.v, is_local)
    )
    h = rms_norm(x, params["final_norm"])
    logits = (h @ params["unembed"]).astype(jnp.float32)
    logits = constrain(logits, BATCH, None, TP)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tok, KVCache(k_new, v_new)


def prefill_step(cfg: LMConfig, params, tokens):
    """Prefill: full forward over the prompt, returning last-position logits
    argmax and the populated KV cache (built layer-by-layer in the scan)."""
    B, S = tokens.shape
    H, KV, dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    positions = jnp.arange(S, dtype=jnp.int32)
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, BATCH, None, None)
    is_local = jnp.asarray([cfg.layer_is_local(i) for i in range(cfg.n_layers)], bool)

    def body(x, xs):
        lp, loc = xs
        h = rms_norm(x, lp["attn_norm"])
        q = rope((h @ lp["wq"]).reshape(B, S, H, dh), positions, cfg.rope_theta)
        k = rope((h @ lp["wk"]).reshape(B, S, KV, dh), positions, cfg.rope_theta)
        v = (h @ lp["wv"]).reshape(B, S, KV, dh)
        window = None
        if cfg.sliding_window is not None:
            window = jnp.where(loc, cfg.sliding_window, 0)
        attn = flash_attention(
            q, k, v, causal=True, window=window,
            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk,
        )
        x = x + attn.reshape(B, S, H * dh) @ lp["wo"]
        h2 = rms_norm(x, lp["mlp_norm"])
        if cfg.is_moe:
            flat = h2.reshape(B * S, D)
            y, _ = moe_ffn(
                flat, lp["router"], lp["we1"], lp["we3"], lp["we2"],
                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            )
            if cfg.n_shared_experts:
                y = y + swiglu(flat, lp["ws1"], lp["ws3"], lp["ws2"])
            y = y.reshape(B, S, D)
        else:
            y = swiglu(h2, lp["w1"], lp["w3"], lp["w2"])
        return x + y, (k.astype(x.dtype), v.astype(x.dtype))

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], is_local))
    h = rms_norm(x[:, -1:], params["final_norm"])
    logits = (h @ params["unembed"]).astype(jnp.float32)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tok, KVCache(ks, vs)
