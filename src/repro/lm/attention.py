"""Attention: flash-style chunked causal attention (train/prefill) and
KV-cache decode attention. Pure JAX with nested scans so the lowered HLO
stays compact and the VMEM-resident working set is O(q_chunk x k_chunk) —
the same blocking the Pallas kernel (kernels/flash_attention) uses; that
kernel's ref.py oracle is this function.

GQA is expressed by reshaping queries to [B, S, KV, G, dh] so K/V never
materialize repeated heads. Sliding-window (local) layers apply a band mask.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _band_mask(qi, ki, causal: bool, window):
    """qi, ki: absolute positions [cq], [ck] -> allowed [cq, ck].

    ``window`` may be a traced scalar; window <= 0 means unwindowed (used to
    mix local/global layers inside one scan)."""
    m = jnp.ones((qi.shape[0], ki.shape[0]), bool)
    if causal:
        m &= ki[None, :] <= qi[:, None]
    if window is not None:
        w = jnp.asarray(window)
        m &= (w <= 0) | (ki[None, :] > qi[:, None] - w)
    return m


def flash_attention(
    q: jax.Array,  # [B, Sq, H, dh]
    k: jax.Array,  # [B, Sk, KV, dh]
    v: jax.Array,  # [B, Sk, KV, dh]
    *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 256,
    k_chunk: int = 256,
    q_offset: int = 0,
) -> jax.Array:
    """Online-softmax blocked attention. Returns [B, Sq, H, dh]."""
    B, Sq, H, dh = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % k_chunk == 0
    nq, nk = Sq // q_chunk, Sk // k_chunk
    scale = dh**-0.5

    qg = q.reshape(B, Sq, KV, G, dh) * scale

    def q_body(_carry, qi):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=1)
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def k_body(carry, ki):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * k_chunk, k_chunk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * k_chunk, k_chunk, axis=1)
            kpos = ki * k_chunk + jnp.arange(k_chunk)
            # scores: [B, KV, G, cq, ck]
            s = jnp.einsum("bqkgd,bckd->bkgqc", q_blk, k_blk, preferred_element_type=jnp.float32)
            allowed = _band_mask(qpos, kpos, causal, window)
            s = jnp.where(allowed[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(k_body, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # [B, KV, G, cq, dh] -> [B, cq, H, dh]
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, dh)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, jnp.arange(nq))
    # outs: [nq, B, q_chunk, H, dh] -> [B, Sq, H, dh]
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, dh)


def decode_attention(
    q: jax.Array,  # [B, 1, H, dh]
    k_cache: jax.Array,  # [B, S, KV, dh]
    v_cache: jax.Array,  # [B, S, KV, dh]
    pos,  # int32 scalar: number of valid cache positions (inclusive of current)
    *,
    window: int | None = None,
) -> jax.Array:
    """Single-token attention against the KV cache. Returns [B, 1, H, dh]."""
    B, _, H, dh = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = dh**-0.5
    qg = (q[:, 0] * scale).reshape(B, KV, G, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32)
    kpos = jnp.arange(S)
    ok = kpos[None] < pos
    if window is not None:
        w = jnp.asarray(window)
        ok &= (w <= 0) | (kpos[None] > pos - 1 - w)
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dh).astype(q.dtype)
