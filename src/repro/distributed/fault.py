"""Fault tolerance & elasticity for 1000+-node operation.

- ``ElasticRunner``: wraps a train loop in checkpoint/restart semantics.
  On a (possibly injected) node failure it rebuilds a *smaller* mesh,
  re-restores the last checkpoint with the new shardings, and resumes —
  the single-controller analogue of a coordinator-driven elastic restart.
- ``HedgedCalls``: serve-path straggler mitigation — issue the same request
  to r replicas, take the first completion (tail-latency hedging). In this
  offline harness replica latencies come from a provided sampler so the
  p99-vs-cost tradeoff is measurable and testable.
- ``RetryPolicy``: bounded exponential-backoff retries (the same policy the
  Service Coordinator and the CP population threads use).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class RetryPolicy:
    max_attempts: int = 3
    base_delay: float = 0.0  # seconds (0 in simulations)

    def run(self, fn: Callable, *args, on_retry: Optional[Callable] = None):
        last = None
        for attempt in range(self.max_attempts):
            try:
                return fn(*args)
            except Exception as e:  # noqa: BLE001
                last = e
                if on_retry:
                    on_retry(attempt, e)
                if self.base_delay:
                    time.sleep(self.base_delay * (2**attempt))
        raise last


class NodeFailure(RuntimeError):
    """Raised (or injected) when a worker/node is lost mid-step."""


@dataclass
class ElasticRunner:
    """Checkpoint/restart + elastic re-mesh driver.

    ``make_state(mesh) -> state``, ``step_fn(mesh, state, step_idx) ->
    state``; ``meshes`` is the downgrade ladder (e.g. [(16,16), (15,16)...]
    — here debug-sized). ``save`` / ``restore`` adapt the state pytree.
    """

    make_mesh: Callable  # level -> mesh (level 0 = full fleet)
    make_state: Callable
    step_fn: Callable
    ckpt_dir: str
    ckpt_every: int = 10
    max_mesh_level: int = 2
    failures_tolerated: int = field(default=8)

    def run(self, n_steps: int, inject_failure_at: Optional[int] = None):
        from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint

        level = 0
        mesh = self.make_mesh(level)
        state = self.make_state(mesh)
        step = 0
        failures = 0
        log = []
        while step < n_steps:
            try:
                if inject_failure_at is not None and step == inject_failure_at and failures == 0:
                    raise NodeFailure(f"injected node loss at step {step}")
                state = self.step_fn(mesh, state, step)
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    save_checkpoint(self.ckpt_dir, step, state)
                    log.append(("ckpt", step, level))
            except NodeFailure as e:
                failures += 1
                if failures > self.failures_tolerated:
                    raise
                level = min(level + 1, self.max_mesh_level)
                mesh = self.make_mesh(level)  # elastic downgrade
                last = latest_step(self.ckpt_dir)
                log.append(("failover", step, level, str(e)))
                if last is None:
                    state = self.make_state(mesh)
                    step = 0
                else:
                    template = self.make_state(mesh)
                    state = restore_checkpoint(self.ckpt_dir, last, template)
                    step = last
        return state, log


@dataclass
class HedgedCalls:
    """Tail-latency hedging: take the fastest of r replicas.

    ``latency_sampler(rng) -> seconds`` models one replica's service time
    (in production this is the real backend call)."""

    replicas: int = 2
    seed: int = 0

    def simulate(self, n_requests: int, latency_sampler) -> dict:
        rng = np.random.default_rng(self.seed)
        solo = np.array([latency_sampler(rng) for _ in range(n_requests)])
        hedged = np.array([
            min(latency_sampler(rng) for _ in range(self.replicas))
            for _ in range(n_requests)
        ])
        return {
            "solo_p99": float(np.percentile(solo, 99)),
            "hedged_p99": float(np.percentile(hedged, 99)),
            "p99_improvement": float(np.percentile(solo, 99) / np.percentile(hedged, 99)),
            "extra_work": float(self.replicas - 1),
        }
