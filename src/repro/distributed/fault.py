"""Fault tolerance & elasticity for 1000+-node operation.

- ``ElasticRunner``: wraps a train loop in checkpoint/restart semantics.
  On a (possibly injected) node failure it rebuilds a *smaller* mesh,
  re-restores the last checkpoint with the new shardings, and resumes —
  the single-controller analogue of a coordinator-driven elastic restart.
- ``HedgedCalls``: serve-path straggler mitigation — issue the same request
  to r replicas, take the first completion (tail-latency hedging). ``call``
  hedges two real callables against the wall clock; ``simulate`` keeps the
  offline sampler harness so the p99-vs-cost tradeoff stays measurable.
- ``RetryPolicy``: bounded exponential-backoff retries with a
  ``retryable`` predicate (the same policy the Service Coordinator, the CP
  population threads, and the journal flusher use).
- ``FailureDetector`` / ``ShardFaultPlan``: the serve loop's per-batch
  failure model — scripted crash/hang/torn-flush injection and the
  consecutive-failure heartbeat detector that turns probe outcomes into a
  ``down`` owner set (degraded-mode serving masks those owners' miss
  segments; see ``distributed.failover``).
- ``timed_call``: a bounded-wall-clock wrapper for journal flush and
  checkpoint I/O — a hung filesystem surfaces as ``CallTimeout`` instead
  of freezing the serve loop.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


@dataclass
class RetryPolicy:
    """Bounded retries with exponential backoff.

    ``retryable(exc) -> bool`` classifies failures: a non-transient error
    (e.g. ``BlockCapacityError`` — retrying cannot change the capacity)
    surfaces immediately instead of burning the attempt budget. ``None``
    retries everything (the historical behaviour).
    """

    max_attempts: int = 3
    base_delay: float = 0.0  # seconds (0 in simulations)
    retryable: Optional[Callable[[Exception], bool]] = None

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}"
            )

    def run(self, fn: Callable, *args, on_retry: Optional[Callable] = None):
        for attempt in range(self.max_attempts):
            try:
                return fn(*args)
            except Exception as e:  # noqa: BLE001
                if self.retryable is not None and not self.retryable(e):
                    raise
                if attempt == self.max_attempts - 1:
                    raise
                if on_retry:
                    on_retry(attempt, e)
                if self.base_delay:
                    time.sleep(self.base_delay * (2**attempt))


class NodeFailure(RuntimeError):
    """Raised (or injected) when a worker/node is lost mid-step."""


class CallTimeout(RuntimeError):
    """A bounded-wall-clock call (``timed_call``) exceeded its budget."""


def timed_call(fn: Callable, timeout: Optional[float], *args, **kwargs):
    """Run ``fn(*args, **kwargs)`` with a wall-clock bound.

    ``timeout=None`` calls inline (zero overhead). Otherwise the call runs
    on a worker thread and ``CallTimeout`` is raised if it does not finish
    in time — the worker is left to finish in the background (Python
    threads cannot be killed), which is the right trade for the I/O calls
    this wraps: a hung fsync must not freeze the serve loop, and a late
    completion is harmless because the caller's retry path truncates back
    to the last durable offset before rewriting.
    """
    if timeout is None:
        return fn(*args, **kwargs)
    box: dict = {}
    done = threading.Event()

    def work():
        try:
            box["ok"] = fn(*args, **kwargs)
        except Exception as e:  # noqa: BLE001 — re-raised on the caller
            box["err"] = e
        finally:
            done.set()

    t = threading.Thread(target=work, daemon=True)
    t.start()
    if not done.wait(timeout):
        raise CallTimeout(
            f"{getattr(fn, '__name__', fn)!s} exceeded {timeout:.3f}s"
        )
    if "err" in box:
        raise box["err"]
    return box["ok"]


@dataclass
class ShardFaultPlan:
    """A scripted per-batch fault schedule for chaos runs.

    - ``crash[shard] = batch``: the shard's storage is lost from that batch
      on (heartbeats fail, unmasked executions raise ``NodeFailure``) until
      ``revive`` — recovery-as-migration rebuilds its blocks.
    - ``hang[shard] = (from_batch, to_batch, delay_s)``: the shard is alive
      but straggling in ``[from_batch, to_batch)`` — probes succeed with
      ``delay_s`` latency, which the detector's straggle threshold and the
      hedged read path react to.
    - ``torn_flush_attempts``: journal flush attempt indices to tear
      (compose with ``WriteBehindJournal(flush_fault=plan.flush_fault)``).
    """

    crash: dict = field(default_factory=dict)  # shard -> batch idx
    hang: dict = field(default_factory=dict)  # shard -> (from, to, delay_s)
    torn_flush_attempts: tuple = ()

    def crashed_at(self, batch: int) -> frozenset:
        """Shards whose storage is gone as of ``batch``."""
        return frozenset(
            s for s, b in self.crash.items() if batch >= b
        )

    def hang_delay(self, shard: int, batch: int) -> float:
        ent = self.hang.get(shard)
        if ent is None:
            return 0.0
        lo, hi, delay = ent
        return float(delay) if lo <= batch < hi else 0.0

    def revive(self, shard: int) -> None:
        """Recovery finished: the (replacement) owner serves again."""
        self.crash.pop(shard, None)

    def flush_fault(self, attempt: int) -> None:
        """``WriteBehindJournal`` fault hook: tear the listed attempts."""
        if attempt in self.torn_flush_attempts:
            raise OSError(f"injected torn flush at attempt {attempt}")


@dataclass
class FailureDetector:
    """Heartbeat-driven failure detection over ``n`` owner shards.

    The serve loop probes each shard once per batch (``observe_ok`` /
    ``observe_failure``); ``fail_threshold`` consecutive failures mark a
    shard down (single blips don't flap the mesh into degraded mode), and
    ``straggle_after`` seconds of probe latency mark it straggling —
    alive, so nothing defers, but the hedged read path races a degraded
    call against it. ``mark_recovered`` clears both states after
    recovery-as-migration completes.
    """

    n: int
    fail_threshold: int = 2
    straggle_after: Optional[float] = None
    _consecutive: dict = field(default_factory=dict)
    _down: set = field(default_factory=set)
    _straggling: set = field(default_factory=set)
    detections: int = 0
    recoveries: int = 0

    def observe_ok(self, shard: int, latency_s: float = 0.0) -> None:
        self._consecutive[shard] = 0
        if self.straggle_after is not None:
            if latency_s >= self.straggle_after:
                self._straggling.add(shard)
            else:
                self._straggling.discard(shard)

    def observe_step(self, latency_s: float, per_owner=None) -> None:
        """Feed one measured serving-step wall-clock to the live owners.

        ``per_owner`` (float[n], seconds) is the telemetry tier's
        work-attributed per-owner step latency
        (``ShardedTxnRuntime.last_step_owner_seconds``): each live owner
        observes *its own* attributed share, so a single straggling owner
        trips ``straggle_after`` alone instead of marking the whole mesh
        straggling (the ROADMAP's per-owner attribution item).

        Without attribution (``per_owner=None`` — telemetry off, or no
        step has run yet) the aggregate fallback keeps the old semantics:
        the sharded step is a collective program — every owner
        participates in the same all_to_all exchanges — so the one
        measured step latency is fed to every live owner, and a straggler
        inflates it for the whole mesh. Either way a crashed owner
        surfaces through ``observe_failure``, not timing; owners already
        marked down keep their state until recovery."""
        if per_owner is not None:
            per = np.asarray(per_owner, dtype=np.float64).reshape(-1)
            if per.shape[0] != self.n:
                raise ValueError(
                    f"per_owner has {per.shape[0]} entries for {self.n} "
                    f"owners")
            for s in range(self.n):
                if s not in self._down:
                    self.observe_ok(s, latency_s=float(per[s]))
            return
        for s in range(self.n):
            if s not in self._down:
                self.observe_ok(s, latency_s=latency_s)

    def observe_failure(self, shard: int) -> None:
        c = self._consecutive.get(shard, 0) + 1
        self._consecutive[shard] = c
        if c >= self.fail_threshold and shard not in self._down:
            self._down.add(shard)
            self._straggling.discard(shard)
            self.detections += 1

    def down(self) -> frozenset:
        return frozenset(self._down)

    def straggling(self) -> frozenset:
        return frozenset(self._straggling)

    def mark_recovered(self, shard: int) -> None:
        if shard in self._down:
            self.recoveries += 1
        self._down.discard(shard)
        self._straggling.discard(shard)
        self._consecutive[shard] = 0

    def down_mask(self) -> np.ndarray:
        """The serve step's ``down`` input: bool[n], True = owner down."""
        m = np.zeros((self.n,), bool)
        for s in self._down:
            m[s] = True
        return m


@dataclass
class ElasticRunner:
    """Checkpoint/restart + elastic re-mesh driver.

    ``make_state(mesh) -> state``, ``step_fn(mesh, state, step_idx) ->
    state``; ``meshes`` is the downgrade ladder (e.g. [(16,16), (15,16)...]
    — here debug-sized). ``save`` / ``restore`` adapt the state pytree.
    """

    make_mesh: Callable  # level -> mesh (level 0 = full fleet)
    make_state: Callable
    step_fn: Callable
    ckpt_dir: str
    ckpt_every: int = 10
    max_mesh_level: int = 2
    failures_tolerated: int = field(default=8)

    def run(self, n_steps: int, inject_failure_at: Optional[int] = None):
        from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint

        level = 0
        mesh = self.make_mesh(level)
        state = self.make_state(mesh)
        step = 0
        failures = 0
        log = []
        while step < n_steps:
            try:
                if inject_failure_at is not None and step == inject_failure_at and failures == 0:
                    raise NodeFailure(f"injected node loss at step {step}")
                state = self.step_fn(mesh, state, step)
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    save_checkpoint(self.ckpt_dir, step, state)
                    log.append(("ckpt", step, level))
            except NodeFailure as e:
                failures += 1
                if failures > self.failures_tolerated:
                    raise
                level = min(level + 1, self.max_mesh_level)
                mesh = self.make_mesh(level)  # elastic downgrade
                last = latest_step(self.ckpt_dir)
                log.append(("failover", step, level, str(e)))
                if last is None:
                    state = self.make_state(mesh)
                    step = 0
                else:
                    template = self.make_state(mesh)
                    state = restore_checkpoint(self.ckpt_dir, last, template)
                    step = last
        return state, log


@dataclass
class HedgedCalls:
    """Tail-latency hedging: take the fastest of r replicas.

    ``call`` is the live serve-path API: run ``primary``, and if it has not
    completed within ``hedge_after`` seconds launch ``hedge`` and return
    whichever finishes first. The gR read path uses it when the detector
    reports a straggling-but-alive owner — the primary is the full batch,
    the hedge is the degraded call with the straggler's miss segment
    masked, so the batch's tail is bounded by the hedge latency instead of
    the straggler's. ``issued`` / ``hedged`` / ``hedge_wins`` make the
    hedge rate a serve metric.

    ``latency_sampler(rng) -> seconds`` models one replica's service time
    for the offline ``simulate`` harness (in production this is the real
    backend call)."""

    replicas: int = 2
    seed: int = 0
    issued: int = 0
    hedged: int = 0
    hedge_wins: int = 0

    def call(self, primary: Callable, hedge: Callable, hedge_after: float):
        """Race ``primary`` against a delayed ``hedge``; first result wins.

        Returns ``(result, from_hedge)``. If the winner raised, its
        exception propagates; the loser (either way) is left to finish on
        its daemon thread — both callables must therefore be pure
        functions of their inputs (the jitted serve steps are).
        """
        self.issued += 1
        lock = threading.Lock()
        first: dict = {}
        done = threading.Event()

        def run(tag: str, fn: Callable):
            try:
                r = fn()
                err = None
            except Exception as e:  # noqa: BLE001 — re-raised if it won
                r, err = None, e
            with lock:
                if not first:
                    first["tag"], first["r"], first["err"] = tag, r, err
                    done.set()

        tp = threading.Thread(
            target=run, args=("primary", primary), daemon=True
        )
        tp.start()
        if not done.wait(hedge_after):
            self.hedged += 1
            threading.Thread(
                target=run, args=("hedge", hedge), daemon=True
            ).start()
        done.wait()
        won_hedge = first["tag"] == "hedge"
        self.hedge_wins += int(won_hedge)
        if first["err"] is not None:
            raise first["err"]
        return first["r"], won_hedge

    @property
    def hedge_rate(self) -> float:
        return self.hedged / self.issued if self.issued else 0.0

    def simulate(self, n_requests: int, latency_sampler) -> dict:
        rng = np.random.default_rng(self.seed)
        solo = np.array([latency_sampler(rng) for _ in range(n_requests)])
        hedged = np.array([
            min(latency_sampler(rng) for _ in range(self.replicas))
            for _ in range(n_requests)
        ])
        return {
            "solo_p99": float(np.percentile(solo, 99)),
            "hedged_p99": float(np.percentile(hedged, 99)),
            "p99_improvement": float(np.percentile(solo, 99) / np.percentile(hedged, 99)),
            "extra_work": float(self.replicas - 1),
        }
