"""Distribution utilities: mesh-aware sharding rules, collectives helpers,
fault tolerance and elasticity (see repro.distributed.fault), and the
sharded transaction runtime (graph_serve.ShardedTxnRuntime)."""

from repro.distributed.sharding import (
    active_mesh,
    add_data_axis,
    constrain,
    flat_mesh,
    maybe_spec,
    set_mesh,
    tree_shardings,
)

__all__ = [
    "set_mesh",
    "active_mesh",
    "constrain",
    "flat_mesh",
    "maybe_spec",
    "add_data_axis",
    "tree_shardings",
    "ShardedTxnRuntime",
]


def __getattr__(name):
    # lazy: graph_serve pulls in the whole core engine stack
    if name == "ShardedTxnRuntime":
        from repro.distributed.graph_serve import ShardedTxnRuntime

        return ShardedTxnRuntime
    raise AttributeError(name)
