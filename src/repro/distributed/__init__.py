"""Distribution utilities: mesh-aware sharding rules, collectives helpers,
fault tolerance and elasticity (see repro.distributed.fault), and the
sharded transaction runtime (graph_serve.ShardedTxnRuntime)."""

from repro.distributed.sharding import (
    active_mesh,
    add_data_axis,
    constrain,
    flat_mesh,
    maybe_spec,
    set_mesh,
    tree_shardings,
)

__all__ = [
    "set_mesh",
    "active_mesh",
    "constrain",
    "flat_mesh",
    "maybe_spec",
    "add_data_axis",
    "tree_shardings",
    "ShardedTxnRuntime",
    "ShardedMissDrain",
    "FailoverController",
    "RoutingTable",
    "RoutingTableHost",
    "identity_table",
    "storage_owner_of",
    "cache_owner_of",
]

_ROUTING = (
    "RoutingTable", "RoutingTableHost", "identity_table",
    "storage_owner_of", "cache_owner_of",
)


def __getattr__(name):
    # lazy: graph_serve pulls in the whole core engine stack
    if name in ("ShardedTxnRuntime", "ShardedMissDrain"):
        from repro.distributed import graph_serve

        return getattr(graph_serve, name)
    if name == "FailoverController":
        from repro.distributed import failover

        return failover.FailoverController
    if name in _ROUTING:
        from repro.distributed import routing

        return getattr(routing, name)
    raise AttributeError(name)
