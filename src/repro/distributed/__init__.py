"""Distribution utilities: mesh-aware sharding rules, collectives helpers,
fault tolerance and elasticity (see repro.distributed.fault)."""

from repro.distributed.sharding import (
    active_mesh,
    add_data_axis,
    constrain,
    maybe_spec,
    set_mesh,
    tree_shardings,
)

__all__ = [
    "set_mesh",
    "active_mesh",
    "constrain",
    "maybe_spec",
    "add_data_axis",
    "tree_shardings",
]
