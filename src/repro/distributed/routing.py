"""Replicated, versioned vertex-routing table for the partitioned tier.

Ownership so far was the *compiled-in* modulo ``owner_of(v) = v % n``:
cheap, but frozen — the hottest owner under a Zipfian root distribution
bounds throughput forever because no table exists to move a vertex. This
module promotes ownership to data, the way Smart Query Routing decouples
"who stores v" from "where a query for v is cheapest to serve":

- ``RoutingTable`` is a tiny replicated pytree threaded through the
  serving step as a **traced input** (exactly like the failover tier's
  ``down`` mask): fixed shapes, so updating the table — a migration, a
  locality override — is an *input* change, never a recompile. The
  ``epoch`` scalar versions the table; the epoch protocol is the batch
  boundary: the host swaps the device table only between dispatches, and
  in-flight epoch-pinned readers (``EpochRegistry``) always ran against
  exactly one table value because the whole batch traced it as one input.
- The base rule stays ``v % n`` (interleaved ids — see
  ``partition.owner_of``); the table stores **exceptions** as two small
  sorted overlays:

  * ``svid/sowner`` — *storage* exceptions: vertex v's dual-CSR rows were
    physically migrated to ``sowner`` (``graphstore.migration``). Reads
    and writes for v must go there.
  * ``cvid/cowner`` — *cache* exceptions: v's cache entries live at
    ``cowner`` even though its rows did not move. gR routes v there — a
    hit is served entirely at the caching shard and never touches the
    storage owner (the paper's cheapest request); a miss comes back
    ``deferred`` and the host re-dispatches it through the storage view
    of the same table (``storage_only`` — same compiled program, new
    table input).

  An empty table routes every vertex exactly like ``owner_of`` —
  byte-identity with the static-modulo tier is the degenerate case, not a
  separate code path.

Lookups are O(log M) ``searchsorted`` probes over the M-entry overlays
(M = ``cap``, default 64, a static shape: raising it is the one change
that does recompile). ``RoutingTableHost`` owns the mutable host mirror
and stamps a fresh device table per change; the serve loop hands
``.device_table()`` to the runtime at each batch boundary.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

# sorts after every real vertex id (ids are < v_cap << 2**31-1) — the
# overlay fill value, so searchsorted never matches a real root
_FILL = np.int32(2**31 - 1)

DEFAULT_TABLE_CAP = 64


class RoutingTable(NamedTuple):
    """Device-resident replicated routing state (all shapes static).

    ``epoch``  int32 []      — table version, bumped per host mutation
    ``svid``   int32 [M]     — sorted storage-exception vids (fill 2^31-1)
    ``sowner`` int32 [M]     — owner per storage exception (fill -1)
    ``cvid``   int32 [M]     — sorted cache-exception vids (fill 2^31-1)
    ``cowner`` int32 [M]     — owner per cache exception (fill -1)
    """

    epoch: jnp.ndarray
    svid: jnp.ndarray
    sowner: jnp.ndarray
    cvid: jnp.ndarray
    cowner: jnp.ndarray

    @property
    def cap(self) -> int:
        return self.svid.shape[0]


def _overlay_lookup(vid_sorted, owner, v, base):
    """Override ``base`` where ``v`` appears in the sorted overlay."""
    pos = jnp.searchsorted(vid_sorted, v)
    posc = jnp.clip(pos, 0, vid_sorted.shape[0] - 1)
    hit = vid_sorted[posc] == v
    return jnp.where(hit, owner[posc], base)


def storage_owner_of(rtable: Optional[RoutingTable], vids, n: int):
    """Where vertex ``vids``' dual-CSR rows physically live.

    ``rtable=None`` (or an empty table) is exactly ``partition.owner_of``:
    the interleaved modulo layout. Negative / out-of-range ids fall through
    to the modulo of their value, matching ``owner_of``'s behaviour — the
    callers gate validity separately, as they always have.
    """
    v = jnp.asarray(vids, jnp.int32)
    base = jnp.mod(v, n)
    if rtable is None:
        return base
    return _overlay_lookup(rtable.svid, rtable.sowner, v, base)


def cache_owner_of(rtable: Optional[RoutingTable], vids, n: int):
    """Where vertex ``vids``' cache entries live — the gR routing rule.

    Cache exceptions override storage exceptions override the modulo:
    a storage migration moves v's cache home along with its rows (CP
    repopulates at the new owner), and a cache-locality override on top
    of that redirects only the read path.
    """
    v = jnp.asarray(vids, jnp.int32)
    base = storage_owner_of(rtable, vids, n)
    if rtable is None:
        return base
    return _overlay_lookup(rtable.cvid, rtable.cowner, v, base)


def base_owner(vids, n: int):
    """The base ownership rule on the host (numpy twin of the traced
    ``partition.owner_of``): interleaved ``v mod n``. Every host path that
    needs native ownership goes through this or a ``RoutingTableHost``
    lookup — nothing else hand-codes the modulo (pinned by
    ``tests/test_ownership_centralized.py``)."""
    return np.asarray(vids) % n


def identity_table(n_shards: int, cap: int = DEFAULT_TABLE_CAP) -> RoutingTable:
    """The empty table: routes exactly like ``owner_of(v, n)``."""
    del n_shards  # the base rule needs n only at lookup time
    return RoutingTable(
        epoch=jnp.zeros((), jnp.int32),
        svid=jnp.full((cap,), _FILL, jnp.int32),
        sowner=jnp.full((cap,), -1, jnp.int32),
        cvid=jnp.full((cap,), _FILL, jnp.int32),
        cowner=jnp.full((cap,), -1, jnp.int32),
    )


def storage_view(rtable: RoutingTable) -> RoutingTable:
    """The same table with cache exceptions stripped: routes every vertex
    to its *storage* owner. The host's retry table for locality-deferred
    rows — identical pytree structure, so it feeds the same compiled
    step."""
    return rtable._replace(
        cvid=jnp.full_like(rtable.cvid, _FILL),
        cowner=jnp.full_like(rtable.cowner, -1),
    )


class RoutingTableHost:
    """Host-side mutable mirror of the device table.

    The host owns the truth (numpy dicts), stamps immutable device tables
    on demand, and answers the host-side lookups the drain/journal paths
    need (``storage_owner`` / ``cache_owner`` over numpy ids). Every
    mutation bumps ``epoch``; ``device_table()`` caches the stamped device
    pytree until the next mutation, so the per-batch cost of an unchanged
    table is a dict hit.

    Capacity ``cap`` is a static shape — exceeding it raises rather than
    silently recompiling the serve step with a larger table.
    """

    def __init__(self, n_shards: int, cap: int = DEFAULT_TABLE_CAP):
        self.n = int(n_shards)
        self.cap = int(cap)
        self.epoch = 0
        self._storage: dict[int, int] = {}
        self._cache: dict[int, int] = {}
        self._device: Optional[RoutingTable] = None
        self._device_storage_only: Optional[RoutingTable] = None

    # ------------------------------------------------------------ mutation
    def _bump(self) -> None:
        self.epoch += 1
        self._device = None
        self._device_storage_only = None

    def set_storage_owner(self, vid: int, owner: int) -> None:
        """Record that ``vid``'s rows now live at ``owner``. Moving a
        vertex back to its native ``vid % n`` owner deletes the exception
        (the table stores only deviations from the modulo)."""
        vid, owner = int(vid), int(owner)
        if not (0 <= owner < self.n):
            raise ValueError(f"owner {owner} out of range [0, {self.n})")
        if owner == vid % self.n:
            self._storage.pop(vid, None)
        else:
            if vid not in self._storage and len(self._storage) >= self.cap:
                raise ValueError(
                    f"routing table full ({self.cap} storage exceptions); "
                    f"raise cap (recompiles) or migrate a vertex home first"
                )
            self._storage[vid] = owner
        self._bump()

    def set_cache_owner(self, vid: int, owner: int) -> None:
        """Redirect ``vid``'s cache home (locality routing) without moving
        its rows. Setting it to the current storage owner clears the
        exception."""
        vid, owner = int(vid), int(owner)
        if not (0 <= owner < self.n):
            raise ValueError(f"owner {owner} out of range [0, {self.n})")
        if owner == self.storage_owner(vid):
            self._cache.pop(vid, None)
        else:
            if vid not in self._cache and len(self._cache) >= self.cap:
                raise ValueError(
                    f"routing table full ({self.cap} cache exceptions)"
                )
            self._cache[vid] = owner
        self._bump()

    def clear_cache_owner(self, vid: int) -> None:
        if self._cache.pop(int(vid), None) is not None:
            self._bump()

    def apply_moves(self, moves) -> None:
        """Apply a batch of storage moves ``[(vid, dst), ...]`` as ONE
        epoch bump — the journal's MIGRATE record replays through here."""
        for vid, dst in moves:
            vid, dst = int(vid), int(dst)
            if dst == vid % self.n:
                self._storage.pop(vid, None)
            else:
                if vid not in self._storage and len(self._storage) >= self.cap:
                    raise ValueError(
                        f"routing table full ({self.cap} storage exceptions)"
                    )
                self._storage[vid] = dst
            # the cache home follows the rows unless a locality override
            # re-points it afterwards
            self._cache.pop(vid, None)
        self._bump()

    # ------------------------------------------------------------- lookups
    def storage_owner(self, vids):
        """Vectorized host lookup (numpy). Scalar in → python int out."""
        v = np.asarray(vids)
        base = np.mod(v, self.n)
        if self._storage:
            sv = np.fromiter(self._storage.keys(), np.int64, len(self._storage))
            so = np.fromiter(self._storage.values(), np.int64, len(self._storage))
            order = np.argsort(sv)
            sv, so = sv[order], so[order]
            pos = np.clip(np.searchsorted(sv, v), 0, len(sv) - 1)
            base = np.where(sv[pos] == v, so[pos], base)
        return int(base) if np.ndim(vids) == 0 else base.astype(np.int32)

    def cache_owner(self, vids):
        v = np.asarray(vids)
        base = np.asarray(self.storage_owner(v))
        if self._cache:
            cv = np.fromiter(self._cache.keys(), np.int64, len(self._cache))
            co = np.fromiter(self._cache.values(), np.int64, len(self._cache))
            order = np.argsort(cv)
            cv, co = cv[order], co[order]
            pos = np.clip(np.searchsorted(cv, v), 0, len(cv) - 1)
            base = np.where(cv[pos] == v, co[pos], base)
        return int(base) if np.ndim(vids) == 0 else base.astype(np.int32)

    def is_split(self, vids):
        """True where the cache home differs from the storage home — the
        rows whose misses come back locality-deferred and must be retried
        through ``storage_table()``."""
        return np.asarray(self.cache_owner(vids)) != np.asarray(
            self.storage_owner(vids)
        )

    @property
    def storage_exceptions(self) -> dict:
        return dict(self._storage)

    @property
    def cache_exceptions(self) -> dict:
        return dict(self._cache)

    def has_exceptions(self) -> bool:
        return bool(self._storage or self._cache)

    # ------------------------------------------------------- device tables
    def _stamp(self, include_cache: bool) -> RoutingTable:
        svid = np.full((self.cap,), _FILL, np.int32)
        sown = np.full((self.cap,), -1, np.int32)
        if self._storage:
            items = sorted(self._storage.items())
            svid[: len(items)] = [v for v, _ in items]
            sown[: len(items)] = [o for _, o in items]
        cvid = np.full((self.cap,), _FILL, np.int32)
        cown = np.full((self.cap,), -1, np.int32)
        if include_cache and self._cache:
            items = sorted(self._cache.items())
            cvid[: len(items)] = [v for v, _ in items]
            cown[: len(items)] = [o for _, o in items]
        return RoutingTable(
            epoch=jnp.asarray(self.epoch, jnp.int32),
            svid=jnp.asarray(svid), sowner=jnp.asarray(sown),
            cvid=jnp.asarray(cvid), cowner=jnp.asarray(cown),
        )

    def device_table(self) -> RoutingTable:
        """The full table (storage + cache overlays), cached per epoch."""
        if self._device is None:
            self._device = self._stamp(include_cache=True)
        return self._device

    def storage_table(self) -> RoutingTable:
        """The cache-stripped table for locality-deferred retries."""
        if self._device_storage_only is None:
            self._device_storage_only = self._stamp(include_cache=False)
        return self._device_storage_only

    def metrics(self) -> dict:
        return {
            "table_epoch": self.epoch,
            "storage_exceptions": len(self._storage),
            "cache_exceptions": len(self._cache),
        }
