"""Mesh-aware sharding helpers.

Model code calls ``constrain(x, spec)`` with *logical* PartitionSpecs; when
no mesh is active (CPU smoke tests) the call is a no-op, and axes that don't
divide the corresponding dimension are dropped automatically — this is what
lets one model definition compile unmodified on (16,16), (2,16,16) and a
single CPU device. The same validation backs the jit in_shardings built by
``tree_shardings``.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def flat_mesh(n: Optional[int] = None, axis: str = "shard") -> Mesh:
    """A 1-axis mesh over ``n`` devices (default: all local devices) — the
    canonical layout for the sharded transaction runtime, whose vertex
    ownership, owner-local dual-CSR edge blocks (the partitioned storage
    tier), and cache blocks all partition over a single flattened axis."""
    devs = jax.devices()
    n = len(devs) if n is None else n
    return jax.make_mesh((n,), (axis,), devices=devs[:n])


def set_mesh(mesh: Optional[Mesh]):
    """Install the process-wide mesh used by ``constrain``/``tree_shardings``."""
    global _MESH
    _MESH = mesh


def active_mesh() -> Optional[Mesh]:
    return _MESH


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def maybe_spec(shape, spec, mesh: Optional[Mesh] = None) -> P:
    """Validate a PartitionSpec against a shape: drop axes that are absent
    from the mesh or do not divide the dimension."""
    mesh = mesh or _MESH
    if mesh is None:
        return P()
    out = []
    spec = tuple(spec)[: len(shape)]  # rank-0 leaves (e.g. step counters)
    for d, axis in enumerate(spec + (None,) * (len(shape) - len(spec))):
        if axis is None:
            out.append(None)
            continue
        axes = axis if isinstance(axis, (tuple, list)) else (axis,)
        axes = tuple(a for a in axes if a in mesh.shape)
        if not axes:
            out.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if size <= 1 or shape[d] % size != 0:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def constrain(x, *spec):
    """``with_sharding_constraint`` against the active mesh; no-op without
    one. Axes are validated per ``maybe_spec``."""
    mesh = _MESH
    if mesh is None:
        return x
    s = maybe_spec(x.shape, spec, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))


def add_data_axis(spec: P, shape, mesh: Optional[Mesh] = None, axes=("data",)) -> P:
    """ZeRO-1: extend a param spec with the data axis on the largest
    still-replicated, divisible dimension (optimizer-state sharding)."""
    mesh = mesh or _MESH
    if mesh is None:
        return spec
    size = int(np.prod([mesh.shape[a] for a in axes if a in mesh.shape]))
    if size <= 1:
        return spec
    entries = list(tuple(spec) + (None,) * (len(shape) - len(spec)))
    order = sorted(range(len(shape)), key=lambda d: -shape[d])
    for d in order:
        if entries[d] is None and shape[d] % size == 0:
            entries[d] = axes if len(axes) > 1 else axes[0]
            return P(*entries)
    return P(*entries)


def tree_shardings(tree, rule, mesh: Optional[Mesh] = None):
    """Build a NamedSharding pytree: ``rule(path, leaf) -> spec tuple``."""
    mesh = mesh or _MESH

    def leaf_fn(path, leaf):
        spec = rule(jax.tree_util.keystr(path), leaf)
        s = maybe_spec(leaf.shape, spec, mesh)
        return NamedSharding(mesh, s)

    return jax.tree_util.tree_map_with_path(leaf_fn, tree)
