"""Distributed graph-query serving — the paper's production architecture
mapped onto a TPU mesh with shard_map.

``ShardedTxnRuntime`` is the sharded instantiation of the shared transaction
runtime (``repro.core.runtime``). Vertex *ownership* is interleaved over the
mesh (shard ``v % n`` owns vertex ``v`` — round-robin striping; see
``partition.owner_of`` for why range partitioning forces worst-case routing
buckets) and the one-hop result cache is **co-partitioned with it**: the
cache shard for a key lives on the shard owning the key's root vertex, so a
probe is always local to the owner.

Two storage tiers back it:

- ``store_tier="partitioned"`` (default) — the ``PartitionedGraphStore``
  dual-CSR tier: each shard holds only the out-CSR block of the edges it
  src-owns and the in-CSR block of the edges it dst-owns (plus the small
  replicated vertex-attribute tier), so per-shard store bytes are O(E/n)
  instead of O(E). A hop's miss execution — in *either* direction — reads
  purely owner-local arrays after root routing.
- ``store_tier="replicated"`` — the PR 3 design: a full read-snapshot
  ``GraphStore`` replica per shard. Kept as the memory/throughput baseline
  the partitioned tier is benchmarked against.

- gR-Txs (``serve_step`` / ``run_gr_tx_batch``): arbitrary multi-hop
  ``QueryPlan``s execute the fused probe→miss-exec→frontier-merge pipeline
  *inside* ``shard_map`` via the shared hop driver (``runtime.make_plan_fn``)
  with a mesh tier. The hop protocol is **collective-lean**: exactly ONE
  all_to_all each direction per hop, with everything a hop needs packed
  into one contiguous int32 frame (``runtime.pack_query_frame`` /
  ``pack_result_frame``):

  * **route** — each frontier root ships as ``[root | flags | params]``
    (``WIRE_QUERY_LANES`` lanes; bit 0 of flags = VALID, padding slots are
    zero-filled so they can never decode valid) into per-peer buckets of
    ``cap = ceil(route_cap_factor[hop] * rows / n)`` rows, then one
    tiled all_to_all scatters every peer's bucket to its owner.
  * **exec** — the owner probes its co-partitioned cache block and runs the
    fused ``kernels/block_gather`` executor (one pass: CSR window + recent
    region + liveness + statically specialized predicates, sort-based
    set-dedup) over its owner-local blocks. ``fused_gather=False`` keeps
    the legacy multi-op ``onehop_exec_view`` path for A/B.
  * **unroute** — results return as ``[vals x RW | cnt]`` frames (the cnt
    lane doubles as the hit/miss/deferred flag, -1 = deferred) in the
    mirror all_to_all; the querying shard unpacks into the on-device
    ``segmented_dedup_merge``.

  Per-hop metric/phase globalization is DEFERRED into a single concatenated
  psum after the hop loop (commutative sums, so totals are unchanged), so a
  whole gR step costs ``2 * n_hops`` all_to_alls + 1 all-reduce — pinned by
  the HLO collective-count test in ``tests/test_sharded_collectives.py``.
  With ``overlap=True`` the batch splits into two row streams software-
  pipelined one hop apart (stream B's route exchange issues while stream
  A's owner-local exec runs), overlapping communication with compute under
  async collectives; off by default (row-identical results, but it changes
  the program shape and per-stream route caps halve). Results, per-hop miss
  arrays, and the reduced metrics come back in one device→host transfer,
  byte-identical to the single-host fused engine.

- gRW-Txs (``run_grw_tx``): two phases inside one jitted step. On the
  partitioned tier, phase A applies the commit to owner-local storage
  (``apply_mutations_partitioned``) and runs the mutation listener
  (Algorithms 1–9) as *ownership-masked op derivation*: reverse traversals
  happen at the leaf's owner against its local blocks, edge-change emissions
  at the root side's owner, sweeps at the swept root's owner — the union
  over shards is exactly the single-host emission set. Phase B compacts the
  op stream (only real ops survive) and routes each op to the shard owning
  its root, which applies it against the local cache block — batched for
  write-around (deletes commute), key-segmented vectorized for
  write-through (``apply_op_stream_segmented``; same-key runs stay ordered,
  distinct keys apply in parallel rounds). On the replicated tier, phase A
  round-robins the batch rows instead (every shard can traverse the full
  replica). Store and cache post-states are byte-/logically identical to
  the single-host commit.

- CP population: ``populator()`` returns the standard ``CachePopulator``
  wired with a shard_map step that executes each miss at its owner shard
  (against owner-local blocks on the partitioned tier) and inserts at the
  owner's cache block.

Every routing round reports an **overflow count** (valid items dropped
because a peer bucket or op-stream capacity filled up) in the step metrics;
an overflow means silently degraded results/maintenance and should alarm.
``DEFAULT_ROUTE_CAP_FACTOR`` holds the measured production default (see
``benchmarks/workload.measure_route_skew``); pass ``route_cap_factor=None``
for worst-case no-drop buckets (the byte-identity tests do).

``GraphServeConfig`` (bottom) is the capacity-planning description of the
production deployment; ``config_cell`` lowers it onto the runtime for the
roofline/dry-run tooling. The legacy fixed-template ``build_serve_step``
serving cell was retired in favour of ``ShardedTxnRuntime.serve_step``.

Observability
-------------

With ``telemetry=True`` (the default) the serving step additionally
assembles a per-owner/per-stage counter block
(``repro.obs.metrics.OWNER_STAGE_FIELDS``: frontier occupancy, probe hits,
miss rows, edges scanned, leaf fetches, route overflow, deferred rows)
that rides the SAME stacked metrics all-reduce: each shard one-hot
scatters its *pre-reduction local* stage counters at its own row of an
``[n, S]`` int32 block, the block flattens onto the existing concatenated
psum vector, and the sum across shards assembles the full matrix on every
shard — the per-step collective budget (2 all_to_alls per hop + 1
all-reduce) is unchanged, pinned by ``tests/test_sharded_collectives.py``.
The host wrapper pops the block into ``last_owner_stage`` before building
the metrics dict, so host-visible results and metrics are byte-identical
to ``telemetry=False``, and work-attributes the measured step wall-clock
into ``last_step_owner_seconds`` (``obs.metrics.attribute_step_seconds``)
— the per-owner heartbeat ``FailureDetector.observe_step`` consumes so one
straggler no longer marks every owner straggling. Host-side phases
(gr_dispatch / gr_sync / gr_unpack, grw_step, journal_flush, checkpoint,
compaction_tick, hot_swap_pause) are wrapped in ``tracer.span(...)``
(``repro.obs.trace``; the zero-cost ``NULL_TRACER`` unless a tracer is
injected), and ``launch/serve.py`` aggregates everything into streaming
latency histograms and a schema-validated JSONL trace — format and
reading guide in ``docs/OBSERVABILITY.md``.
"""

from __future__ import annotations

import functools
import threading
import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.cache import CacheState, empty_cache
from repro.core.invalidation import (
    CacheOpStream,
    SweepStream,
    apply_op_stream_batched,
    apply_op_stream_segmented,
    apply_sweeps,
    derive_cache_ops,
    derive_cache_ops_views,
)
from repro.core.runtime import (
    WIRE_FLAG_VALID,
    bucket_for,
    bucketize,
    compact_rows,
    decode_miss_records,
    make_plan_fn,
    onehop_exec_view,
    pack_query_frame,
    pack_result_frame,
    pad_roots,
    route_plan,
    route_scatter,
    unpack_query_frame,
    unpack_result_frame,
)
from repro.kernels.block_gather.ops import block_onehop_exec
from repro.graphstore.maintenance import (
    DeviceGate,
    MaintenancePolicy,
    block_occupancy,
    compact_block,
    decide_maintenance,
    grow_block_local,
)
from repro.graphstore.mutations import (
    apply_mutations,
    make_mutation_batch,
    shard_mutation_rows,
)
from repro.graphstore.partition import (
    BlockCapacityError,
    BlockStoreView,
    EdgeBlock,
    PartitionedGraphStore,
    abstract_partitioned_store,
    apply_mutations_partitioned,
    default_pspec,
    owner_of,
    partition_store,
    store_bytes_report,
)
from repro.distributed.routing import (
    RoutingTable,
    RoutingTableHost,
    base_owner,
    cache_owner_of,
    identity_table,
    storage_owner_of,
)
from repro.obs.metrics import OWNER_STAGE_FIELDS, attribute_step_seconds
from repro.obs.trace import NULL_TRACER
from repro.utils import NULL_ID

_STAT_FIELDS = ("n_hit", "n_miss", "n_insert", "n_evict", "n_delete", "n_oversize")
_ADDITIVE_METRICS = (
    "requests", "hits", "misses", "truncated", "leaf_fetches",
    "edges_scanned", "cache_reads", "route_overflow", "deferred",
    "locality_routed",
)

# Measured default per-peer routing capacity multipliers, per hop: sized
# from the Zipfian (a=1.3) eCommerce workload's owner skew on an 8-shard
# mesh (benchmarks/workload.measure_route_skew; recorded in
# BENCH_partitioned_store.json, per_hop_recommended = [3, 3]). Hop 1 routes
# the raw Zipfian query roots and keeps 4x headroom over the uniform share
# (p99.9 root skew ≈ 3.4x); hops ≥ 2 route leaf-derived frontier roots whose
# measured skew is flatter, so 3x suffices. Both make the measured overflow
# rate 0 on the production mix while bounding bucket memory at factor/n of
# the worst case.
DEFAULT_ROUTE_CAP_FACTOR = (4, 3)


def _plan_key(plan):
    """Structural hash key for a QueryPlan: equal-but-distinct plan objects
    (hops hold numpy params, so plans aren't hashable) share one compiled
    serve step instead of re-tracing per object identity."""
    def pred(p):
        return tuple(np.asarray(getattr(p, f)).tobytes() for f in p._fields)

    hops = tuple(
        (h.direction, h.edge_label, h.tpl_idx,
         np.asarray(h.params, np.int32).tobytes(),
         pred(h.pr), pred(h.pe), pred(h.pl))
        for h in plan.hops
    )
    return (hops, plan.final, plan.final_prop, plan.post_filter, plan.extra_phases)


def _replicate_stats(before: CacheState, after: CacheState, axes):
    """Rebuild the cache's 0-d stats counters as replicated global values:
    input stats are replicated, so each shard adds the psum of all local
    deltas — every shard then stores the same global counter."""
    reps = {}
    for f in _STAT_FIELDS:
        b, a = getattr(before, f), getattr(after, f)
        reps[f] = b + jax.lax.psum(a - b, axes)
    return after._replace(**reps)


class _MeshTier:
    """The sharded instantiation of the shared hop driver's hooks: per-hop
    owner routing over ``all_to_all``, psum'd batch-global gates, and (on
    the partitioned store tier) owner-local block execution."""

    routed = True
    # stateful serving inputs: the plan fn takes TWO extra traced inputs —
    # the ``down: bool[n]`` owner mask and the replicated ``RoutingTable``
    # (both fixed-shape). All-False / identity-table are the healthy fast
    # path and trace byte-identically, so flipping an owner down or moving
    # a vertex (migration, locality override) is an *input* change, not a
    # recompile.
    extra_inputs = 2

    def __init__(self, rt: "ShardedTxnRuntime", caps, pspec):
        # pspec is captured at BUILD time (not read off rt at trace time):
        # a background pre-compile builds next-tier programs while the
        # runtime still serves the current tier
        self.rt = rt
        self.caps = caps
        self.pspec = pspec
        self.axes, self.n = rt.axes, rt.n
        self.fused_gather = rt.fused_gather
        # telemetry: the hop driver accumulates owner-side frontier
        # occupancy (stage_rows) and reduce_metrics folds the per-owner
        # stage block into the existing stacked all-reduce
        self.telemetry = rt.telemetry
        self.stage_rows = rt.telemetry
        self._down = None
        self._rtable = None
        self._locality = None

    def bind(self, down, rtable):
        self._down = down
        self._rtable = rtable
        # per-trace accumulator: rows routed away from their static-modulo
        # home by the table (folded into the metrics psum)
        self._locality = jnp.int32(0)

    def defer_fn(self):
        if self.pspec is None:
            # the replicated tier keeps a full snapshot per shard: losing
            # an owner's storage loses nothing, and every shard can execute
            # any miss, so nothing ever defers
            return None

        def defer(roots_flat):
            # a miss defers where this shard cannot execute it: the owner's
            # storage blocks are down, or the row was routed here for its
            # *cache* home (locality routing) while its dual-CSR rows live
            # at another shard — the host re-dispatches those through the
            # storage view of the same table (same compiled program).
            # Cache hits still serve either way.
            me = jax.lax.axis_index(self.axes)
            split = storage_owner_of(self._rtable, roots_flat, self.n) != me
            return self._down[me] | split

        return defer

    def exec_fn(self, hop):
        if self.pspec is None:
            return None  # replicated snapshot: the default full-store exec
        pspec, espec, axes = self.pspec, self.rt.lspec, self.axes

        if self.fused_gather:
            def exec_fn(store, roots_f, params, miss_m, hop=hop):
                me = jax.lax.axis_index(axes)
                view = BlockStoreView(pspec, store, me, rtable=self._rtable)
                return block_onehop_exec(
                    espec, view, hop.direction, hop.edge_label,
                    hop.pr, hop.pe, hop.pl, roots_f, params, miss_m,
                )
        else:
            def exec_fn(store, roots_f, params, miss_m, hop=hop):
                me = jax.lax.axis_index(axes)
                view = BlockStoreView(pspec, store, me, rtable=self._rtable)
                return onehop_exec_view(
                    espec, view, hop.direction, hop.edge_label,
                    hop.pr, hop.pe, hop.pl, roots_f, params, miss_m,
                )

        return exec_fn

    def route(self, hop_idx, A, roots_flat, rmask_flat, params_row):
        # interleaved ownership maps any id (even past v_cap) to exactly
        # one shard, where an out-of-range root is processed and comes back
        # empty exactly like on the single host; negative ids are
        # indistinguishable from frontier padding.
        #
        # ONE exchange: root id + valid flag + bound predicate params
        # travel together as a packed query frame (runtime wire format)
        # instead of separate per-field collectives. Bucket padding is
        # zero-filled, so padded rows decode as flags=0 (invalid) — their
        # root lane 0 is never observed (every owner-side output is gated
        # by the decoded row mask, and home-side gathers are kept-masked).
        n, cap = self.n, self.caps[hop_idx]
        rvals = jnp.where(rmask_flat, roots_flat, NULL_ID)
        ok = rmask_flat & (roots_flat >= 0)
        # gR routes by the *cache* owner (Smart Query Routing): a hit is
        # served entirely at the caching shard; a locality-split miss comes
        # back deferred and the host retries at the storage owner. The
        # identity table reduces this to exactly owner_of.
        dest = cache_owner_of(self._rtable, roots_flat, n)
        owner = jnp.where(ok, dest, -1)
        self._locality = self._locality + jnp.sum(
            (ok & (dest != owner_of(roots_flat, n))).astype(jnp.int32)
        )
        flags = rmask_flat.astype(jnp.int32) * WIRE_FLAG_VALID
        params = jnp.broadcast_to(
            params_row[None, :], (roots_flat.shape[0], params_row.shape[0])
        )
        frame = pack_query_frame(rvals, flags, params)
        send, slot, kept, ovf = bucketize(frame, owner, n, cap, fill=0)
        recv = jax.lax.all_to_all(
            send, self.axes, split_axis=0, concat_axis=0, tiled=True
        )
        q, qflags, qparams = unpack_query_frame(recv.reshape(n * cap, -1))
        qmask = (qflags & WIRE_FLAG_VALID) == WIRE_FLAG_VALID
        return q, qmask, qparams, (slot, kept, cap), ovf

    def unroute(self, ctx, vals, cnt):
        # ONE exchange home: the RW leaf lanes and the count lane (which
        # doubles as the hit/deferred flag, cnt = -1 deferred) ride one
        # packed result frame
        slot, kept, cap = ctx
        n, axes = self.n, self.axes
        RW = vals.shape[-1]
        frame = pack_result_frame(vals, cnt)
        back = jax.lax.all_to_all(
            frame.reshape(n, cap, RW + 1), axes,
            split_axis=0, concat_axis=0, tiled=True,
        ).reshape(n * cap, RW + 1)
        back_v, back_c = unpack_result_frame(back)
        sl = jnp.clip(slot, 0, n * cap - 1)
        return (
            jnp.where(kept[:, None], back_v[sl], NULL_ID),
            jnp.where(kept, back_c[sl], 0),
        )

    def psum(self, x):
        return jax.lax.psum(x, self.axes)

    def pack_count(self, nrec):
        return nrec[None]  # one independently-counted miss segment per shard

    def reduce_metrics(self, m):
        # ONE all-reduce for the whole plan: the additive scalars and the
        # per-hop miss-count vector (the deferred phase gate) globalize as
        # a single concatenated psum instead of one psum per metric per plan
        # plus one gate psum per hop
        m["locality_routed"] = self._locality
        keys = [k for k in _ADDITIVE_METRICS if k in m]
        hop_k = m["_hop_k"]
        parts = [jnp.stack([m[k] for k in keys]).astype(jnp.int32), hop_k]
        S = len(OWNER_STAGE_FIELDS)
        if self.telemetry:
            # per-owner stage attribution rides the SAME psum: before the
            # reduction every metric value is this shard's local count, so
            # one-hot scattering the locals at our own row of an [n, S]
            # block and summing across shards assembles the full matrix on
            # every shard — zero extra collectives. Field order is the
            # OWNER_STAGE_FIELDS contract (repro.obs.metrics). hits/misses/
            # edges/leaves and frontier occupancy accumulate owner-side
            # (post-route); route_overflow and deferred accumulate at the
            # origin shard.
            local_src = {
                "frontier_rows": m.pop("_frontier_rows"),
                "probe_hits": m["hits"],
                "miss_rows": m["misses"],
                "edges_scanned": m["edges_scanned"],
                "leaf_fetches": m["leaf_fetches"],
                "route_overflow": m["route_overflow"],
                "deferred_rows": m["deferred"],
            }
            local = jnp.stack(
                [local_src[f] for f in OWNER_STAGE_FIELDS]
            ).astype(jnp.int32)
            block = jnp.zeros((self.n, S), jnp.int32).at[
                jax.lax.axis_index(self.axes)
            ].set(local)
            parts.append(block.reshape(-1))
        g = jax.lax.psum(jnp.concatenate(parts), self.axes)
        for i, k in enumerate(keys):
            m[k] = g[i]
        nk, nh = len(keys), hop_k.shape[0]
        m["_hop_k"] = g[nk:nk + nh]
        if self.telemetry:
            m["owner_stage"] = g[nk + nh:].reshape(self.n, S)
        return m


class _NextTier:
    """Handle for a background capacity pre-compile: the double-buffered
    next-tier spec plus completion state. ``ready`` fires when every
    requested step (and the grow-pad swap program) is compiled; ``error``
    carries a worker failure to surface at swap time."""

    def __init__(self, pspec):
        self.pspec = pspec
        self.ready = threading.Event()
        self.error: Exception | None = None
        self.compiled = 0
        self.seconds = 0.0


class ShardedTxnRuntime:
    """One transaction runtime spread over a device mesh.

    ``espec`` is the *global* spec: ``espec.cache.capacity`` is the fleet
    cache capacity, sharded into ``n`` co-partitioned blocks of
    ``capacity // n`` slots (each a power of two); vertex ownership is
    interleaved (``partition.owner_of``). On a 1-device mesh every
    collective degenerates and the runtime is the single-host engine.

    ``store_tier`` selects the storage layout: ``"partitioned"`` (default)
    keeps only owner-local dual-CSR edge blocks per shard (O(E/n) bytes;
    build state with ``partition_store``); ``"replicated"`` keeps a full
    ``GraphStore`` snapshot per shard (the PR 3 baseline).

    ``route_cap_factor`` / ``ops_route_cap`` bound per-peer routing buckets;
    the default is the measured-skew production cap
    (``DEFAULT_ROUTE_CAP_FACTOR``) — ``None`` sizes them for the worst case
    (no overflow possible, byte-identity-test configuration). Smaller
    values trade memory/traffic for a nonzero ``route_overflow`` risk,
    which the step metrics surface. A tuple gives **per-hop** factors (hop
    ``i`` uses entry ``min(i, last)``): hop ≥ 2 routes *leaf-derived*
    frontier roots whose skew is measured separately from root skew
    (``workload.measure_route_skew``), so a mix whose frontiers are flatter
    than its Zipfian roots can run tighter buckets on the inner hops.
    ``"auto"`` sizes buckets from the telemetry tier's *measured* per-owner
    frontier skew (starting at the production default), ratcheting up as
    skew is observed; a batch that still overflows re-dispatches once on
    the worst-case-caps program variant instead of dropping rows
    (``route_cap_retries`` in the step metrics) — this retires hand-tuned
    CI cap factors.

    ``attach_routing(rhost)`` threads a live ``RoutingTableHost`` through
    every step (serving, commits, CP population, miss-drain queueing) as a
    replicated traced input: table updates — hot-vertex migrations, cache
    locality overrides — are input changes at batch boundaries, never
    recompiles. See ``repro.distributed.routing`` and ``docs/ROUTING.md``.

    ``maintenance_tick`` (between transaction batches) keeps the
    partitioned tier healthy under sustained gRW traffic: owner-local block
    compaction once recent regions fill and capacity growth instead of
    append overflow — see ``repro.graphstore.maintenance``.
    """

    def __init__(self, espec, mesh: Mesh, *, use_cache: bool = True,
                 store_tier: str = "partitioned",
                 route_cap_factor: int | tuple | None = DEFAULT_ROUTE_CAP_FACTOR,
                 ops_cap: int = 4096, sweep_cap: int = 512,
                 ops_route_cap: int | None = None,
                 blk_slack: float = 2.0, e_blk_cap: int | None = None,
                 recent_blk_cap: int | None = None,
                 fused_gather: bool = True, overlap: bool = False,
                 telemetry: bool = True, tracer=None):
        assert store_tier in ("partitioned", "replicated"), store_tier
        self.axes = tuple(mesh.axis_names)
        # spec spelling for device_put shardings: a single mesh axis must
        # be the bare name, not a 1-tuple. P(("shard",)) and P("shard")
        # compare equal, but the jit fastpath keys on the concrete layout
        # string and shard_map outputs normalize to the bare-name form —
        # mixing the spellings makes a second executable-cache entry for
        # the same program (pinned by the zero-recompile tests)
        self._ax = self.axes[0] if len(self.axes) == 1 else self.axes
        self.n = int(np.prod([mesh.shape[a] for a in self.axes]))
        n = self.n
        assert n & (n - 1) == 0, "shard count must be a power of two"
        C = espec.cache.capacity
        Cloc = C // n
        assert C % n == 0 and Cloc & (Cloc - 1) == 0, (
            "global cache capacity must shard into power-of-two blocks"
        )
        assert espec.store.v_cap % n == 0, "v_cap must divide over shards"
        self.mesh = mesh
        self.espec = espec
        self.lspec = espec._replace(cache=espec.cache._replace(capacity=Cloc))
        self.use_cache = use_cache
        self.store_tier = store_tier
        if store_tier == "partitioned":
            pspec = default_pspec(
                espec.store, n, slack=blk_slack, recent_blk_cap=recent_blk_cap
            )
            if e_blk_cap is not None:
                pspec = pspec._replace(
                    e_blk_cap=e_blk_cap,
                    recent_blk_cap=min(pspec.recent_blk_cap, e_blk_cap),
                )
            self.pspec = pspec
        else:
            self.pspec = None
        if isinstance(route_cap_factor, (list, tuple)):
            route_cap_factor = tuple(route_cap_factor)
            assert route_cap_factor and all(
                isinstance(f, int) for f in route_cap_factor
            ), "per-hop route_cap_factor entries must be ints"
        elif isinstance(route_cap_factor, str):
            assert route_cap_factor == "auto", route_cap_factor
        self.route_cap_factor = route_cap_factor
        # fused_gather selects the kernels/block_gather owner-local miss
        # executor (sort-based dedup + static-specialized predicates) on
        # the partitioned tier; False keeps the PR 4 multi-op
        # gather_block + onehop_exec_view path for A/B comparison.
        self.fused_gather = fused_gather
        # overlap double-buffers the hop-loop frontier (two row streams,
        # one-stage pipeline skew) so exchanges overlap owner-local exec
        # under async collectives — see runtime.make_plan_fn(overlap=...)
        self.overlap = overlap
        # telemetry: when on (default), serving steps assemble the
        # per-owner stage block on-device (riding the existing stacked
        # all-reduce — see the module docstring's Observability section)
        # and host wrappers wrap their phases in tracer spans. ``tracer``
        # defaults to the zero-cost NULL_TRACER.
        self.telemetry = bool(telemetry)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # wall-clock of the latest executed serving step (blocking sync
        # included) — the unscripted FailoverController probe's heartbeat
        self.last_step_seconds = 0.0
        # the latest step's per-owner stage counters ([n, S] int64, field
        # order OWNER_STAGE_FIELDS) and work-attributed per-owner step
        # seconds — None until a telemetry-on step runs
        self.last_owner_stage = None
        self.last_step_owner_seconds = None
        self.ops_cap = ops_cap
        self.sweep_cap = sweep_cap
        self.ops_route_cap = ops_route_cap if ops_route_cap is not None else ops_cap
        # compiled-step caches, every key TIER-SCOPED (leading element is the
        # pspec the program closed over) so a capacity swap invalidates only
        # the tiers it retires — see _set_pspec
        self._gr_fns: dict = {}
        self._grw_fns: dict = {}
        self._pop_fns: dict = {}
        self._maint_fns: dict = {}
        self._grow_fns: dict = {}
        # applied mutation rows since the last compaction tick (one input to
        # MaintenancePolicy's latency-amortization bound)
        self.mutation_rows_since_compact = 0
        # hitless elasticity: the in-flight background pre-compile handle and
        # the count of completed hot-swaps (serve-loop metric)
        self._next_tier: _NextTier | None = None
        self.swap_events = 0
        # stateful routing: the attached host routing table (None = the
        # compiled-in modulo layout — identity-table input, byte-identical),
        # the peak measured owner frontier skew (feeds "auto" route caps),
        # and the host-side retry counters the serve loop reports
        self.rhost: RoutingTableHost | None = None
        self._route_skew_seen: float | None = None
        self.route_cap_retries = 0
        self.locality_retries = 0

    # ------------------------------------------------------------ sharding
    def cache_sharding(self):
        # vals (2D) deliberately shares s1 = P(ax), not P(ax, None): the
        # trailing None is the same placement but shard_map outputs drop
        # it, and a spelling mismatch is a fresh executable-cache entry
        # (see the _ax note in __init__) — a device_put under the other
        # spelling would recompile the serve step on the first post-drain
        # batch (pinned by the zero-recompile test in test_routing_runtime)
        s1 = NamedSharding(self.mesh, P(self._ax))
        s2 = s1
        s0 = NamedSharding(self.mesh, P())
        return CacheState(
            tpl=s1, root=s1, fp=s1, chunk=s1, total_len=s1, vals=s2,
            version=s1, valid=s1,
            n_hit=s0, n_miss=s0, n_insert=s0, n_evict=s0, n_delete=s0,
            n_oversize=s0,
        )

    def _cache_specs(self):
        a = self.axes
        return CacheState(
            tpl=P(a), root=P(a), fp=P(a), chunk=P(a), total_len=P(a),
            vals=P(a, None), version=P(a), valid=P(a),
            n_hit=P(), n_miss=P(), n_insert=P(), n_evict=P(), n_delete=P(),
            n_oversize=P(),
        )

    def _store_specs(self):
        """shard_map PartitionSpecs for the storage tier."""
        if self.pspec is None:
            return P()  # replicated snapshot
        a = self._ax
        blk = EdgeBlock(
            key=P(a), other=P(a), label=P(a), alive=P(a), props=P(a),
            geid=P(a), gperm=P(a), indptr=P(a), blk_len=P(a), csr_len=P(a),
        )
        return PartitionedGraphStore(
            vlabel=P(), valive=P(), vprops=P(), vversion=P(),
            out=blk, inc=blk, v_len=P(), e_len=P(), version=P(),
        )

    def store_sharding(self):
        """NamedShardings laying the storage tier over the mesh."""
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), self._store_specs(),
            is_leaf=lambda x: isinstance(x, P),
        )

    def partition_store(self, store, *, elastic: bool = False) -> PartitionedGraphStore:
        """Partition a full ``GraphStore`` into this runtime's owner-local
        blocks and lay it over the mesh (partitioned tier only).

        With ``elastic=True`` an over-capacity orientation grows
        ``e_blk_cap`` (25% headroom over the reported need) and retries
        instead of raising ``BlockCapacityError`` — the ingest-time half of
        capacity elasticity; ``maintenance_tick`` handles the online half.
        """
        assert self.pspec is not None, "replicated tier keeps full snapshots"
        while True:
            try:
                ps = partition_store(self.pspec, store)
                break
            except BlockCapacityError as e:
                if not elastic:
                    raise
                self._set_pspec(self.pspec._replace(
                    e_blk_cap=max(
                        int(np.ceil(e.needed * 1.25)), self.pspec.e_blk_cap + 1
                    ),
                ))
        return jax.device_put(ps, self.store_sharding())

    def store_bytes(self, pstore=None) -> dict:
        """Per-shard bytes vs the replicated snapshot (partitioned tier)."""
        assert self.pspec is not None
        return store_bytes_report(self.pspec, pstore)

    # ---------------------------------------------------- block maintenance
    def _set_pspec(self, pspec):
        """Swap the block layout spec. Invalidation is **tier-scoped**:
        every compiled-step cache key leads with the pspec the program
        closed over, so programs of the incoming tier (a background
        pre-compile populated them) and the outgoing tier (in-flight
        batches may still reference it) survive the swap — only strictly
        older tiers are pruned. Unaffected plans keep their compiled steps
        across a swap instead of recompiling from scratch."""
        keep = {self.pspec, pspec}
        self.pspec = pspec
        for cache in (self._gr_fns, self._grw_fns, self._pop_fns,
                      self._maint_fns, self._grow_fns):
            for k in [k for k in cache if k[0] not in keep]:
                del cache[k]

    def set_block_capacity(self, e_blk_cap: int, *,
                           recent_blk_cap: int | None = None):
        """Adopt a block-layout spec without a store in hand — the recovery
        path: ``journal.replay`` restores a checkpoint whose blocks were
        snapshotted under a recorded capacity, so the runtime must speak
        that layout before the restore."""
        assert self.pspec is not None
        rb = (self.pspec.recent_blk_cap if recent_blk_cap is None
              else int(recent_blk_cap))
        self._set_pspec(self.pspec._replace(
            e_blk_cap=int(e_blk_cap), recent_blk_cap=min(rb, int(e_blk_cap)),
        ))

    def store_occupancy(self, pstore) -> dict:
        """Per-shard/per-block occupancy + recent fill (partitioned tier)."""
        assert self.pspec is not None
        return block_occupancy(self.pspec, pstore)

    def compact_step(self, purge: bool = False, *, pspec=None):
        """The jitted owner-local compaction pass: every shard merges its
        block recent regions into the sorted CSR bodies and rebuilds its
        geid→slot indexes, with no collectives (cached per tier + ``purge``)."""
        assert self.pspec is not None
        pspec = self.pspec if pspec is None else pspec
        key = (pspec, purge)
        if key not in self._maint_fns:
            def local_compact(ps):
                return ps._replace(
                    out=compact_block(pspec, ps.out, purge=purge),
                    inc=compact_block(pspec, ps.inc, purge=purge),
                )

            sm = shard_map(
                local_compact, mesh=self.mesh,
                in_specs=(self._store_specs(),),
                out_specs=self._store_specs(), check_rep=False,
            )
            self._maint_fns[key] = jax.jit(sm)
        return self._maint_fns[key]

    def _grow_step(self, new_pspec, *, pspec=None):
        """The jitted device-resident capacity-grow program (cached per
        tier pair): each shard pads its own blocks from ``pspec`` to
        ``new_pspec`` shapes in place on device — owner-local, no
        collectives, no host round-trip. With the target tier's serving
        steps precompiled (``precompile_next_tier``), one run of this pad
        is the entire hot-swap pause."""
        pspec = self.pspec if pspec is None else pspec
        key = (pspec, new_pspec)
        if key not in self._grow_fns:
            def local_grow(ps):
                return ps._replace(
                    out=grow_block_local(pspec, new_pspec, ps.out),
                    inc=grow_block_local(pspec, new_pspec, ps.inc),
                )

            sm = shard_map(
                local_grow, mesh=self.mesh,
                in_specs=(self._store_specs(),),
                out_specs=self._store_specs(), check_rep=False,
            )
            self._grow_fns[key] = jax.jit(sm)
        return self._grow_fns[key]

    def grow_blocks(self, pstore, e_blk_cap: int, *,
                    recent_blk_cap: int | None = None):
        """Grow every block to ``e_blk_cap`` (device-resident pad, byte-
        identical to the host ``grow_store``) and swap the spec.
        Invalidation is tier-scoped (``_set_pspec``): old-tier steps are
        retained for the previous tier only, and steps for the NEW tier
        compile lazily on first use unless ``precompile_next_tier`` built
        them in the background first — the hitless path is
        ``precompile_next_tier`` + ``swap_to_next_tier``. Step handles
        fetched *directly* (``serve_step`` / ``grw_step`` /
        ``compact_step``) before a growth are stale and must be
        re-acquired; the ``run_*`` wrappers re-resolve per call."""
        assert self.pspec is not None
        rb = (self.pspec.recent_blk_cap if recent_blk_cap is None
              else int(recent_blk_cap))
        new_pspec = self.pspec._replace(
            e_blk_cap=int(e_blk_cap), recent_blk_cap=min(rb, int(e_blk_cap)),
        )
        assert new_pspec.e_blk_cap >= self.pspec.e_blk_cap
        grown = self._grow_step(new_pspec)(pstore)
        self._set_pspec(new_pspec)
        return grown

    # ------------------------------------------------- hitless elasticity
    def precompile_next_tier(self, e_blk_cap: int, ttable, *,
                             recent_blk_cap: int | None = None,
                             gr_plans=(), grw_policies=(),
                             grw_caps: tuple = (8, 32, 32, 8, 32, 32),
                             compact_purges=(), pop_steps=(),
                             background: bool = True):
        """Compile the NEXT capacity tier's serving programs off the serve
        critical path (the background half of hitless elasticity).

        A worker thread warm-calls each requested step on owner-sharded
        dummy inputs at the next tier's shapes — warm calls, because they
        populate the jit dispatch caches under the new tier's key
        (``.lower().compile()`` would not) — plus the device grow-pad
        program that performs the swap itself. The serve loop keeps running
        on the current tier the whole time (compiled-step caches are
        tier-scoped, nothing it uses is touched); when the returned
        handle's ``ready`` event fires, ``swap_to_next_tier`` flips the
        store at a batch boundary with every post-swap step already
        compiled. The dummy next-tier store transiently costs one extra
        store's worth of device memory.

        - ``gr_plans`` — ``(plan, global_batch_bucket)`` pairs to warm.
        - ``grw_policies`` — ``(policy, gate)`` pairs (``gate`` a
          ``DeviceGate`` or None) at mutation caps ``grw_caps``.
        - ``compact_purges`` — purge flags to warm ``compact_step`` for.
        - ``pop_steps`` — ``(templates_meta, tpl_idx, bucket)`` CP steps.
        """
        assert self.pspec is not None
        cur = self.pspec
        rb = cur.recent_blk_cap if recent_blk_cap is None else int(recent_blk_cap)
        nxt = cur._replace(
            e_blk_cap=int(e_blk_cap), recent_blk_cap=min(rb, int(e_blk_cap)),
        )
        assert nxt.e_blk_cap > cur.e_blk_cap, (nxt.e_blk_cap, cur.e_blk_cap)
        handle = _NextTier(nxt)
        self._next_tier = handle

        def work():
            t0 = time.perf_counter()
            try:
                def zeros_for(pspec):
                    tmpl = abstract_partitioned_store(pspec)
                    z = jax.tree_util.tree_map(
                        lambda s: jnp.zeros(s.shape, s.dtype), tmpl
                    )
                    return jax.device_put(z, self.store_sharding())

                store0 = zeros_for(nxt)
                cache0 = self.empty_cache()
                # the swap pad itself (current -> next tier)
                out = self._grow_step(nxt, pspec=cur)(zeros_for(cur))
                jax.block_until_ready(out)
                handle.compiled += 1
                for plan, bucket in gr_plans:
                    fn = self._gr(plan, bucket, pspec=nxt)
                    roots = jnp.zeros((bucket,), jnp.int32)
                    bvalid = jnp.zeros((bucket,), jnp.bool_)
                    jax.block_until_ready(
                        fn(store0, cache0, ttable, roots, bvalid)
                    )
                    handle.compiled += 1
                for pol, gate in grw_policies:
                    fn = self._grw(pol, gate, pspec=nxt)
                    mb = make_mutation_batch(self.espec.store, caps=grw_caps)
                    jax.block_until_ready(fn(store0, cache0, ttable, mb))
                    handle.compiled += 1
                for purge in compact_purges:
                    jax.block_until_ready(
                        self.compact_step(purge, pspec=nxt)(store0)
                    )
                    handle.compiled += 1
                for templates_meta, tpl_idx, bucket in pop_steps:
                    from repro.core.keys import PARAM_LEN

                    fn = self._pop_compiled(
                        templates_meta, tpl_idx, bucket, pspec=nxt
                    )
                    jax.block_until_ready(fn(
                        store0, store0, cache0, ttable,
                        jnp.full((bucket,), -1, jnp.int32),
                        jnp.zeros((bucket, PARAM_LEN), jnp.int32),
                        jnp.zeros((bucket,), jnp.bool_),
                        jnp.zeros((bucket,), jnp.int32),
                        self._rtable_none(),
                    ))
                    handle.compiled += 1
            except Exception as e:  # noqa: BLE001 — surfaced at swap time
                handle.error = e
            finally:
                handle.seconds = time.perf_counter() - t0
                handle.ready.set()

        if background:
            threading.Thread(
                target=work, name="tier-precompile", daemon=True
            ).start()
        else:
            work()
        return handle

    def swap_to_next_tier(self, pstore):
        """Hot-swap the store and compiled steps to the precompiled next
        tier at a batch boundary: run the (pre-warmed) device grow-pad,
        flip the spec, prune strictly-older tiers. Blocks until the
        background pre-compile finishes if it has not (callers wanting a
        pause-free swap check ``handle.ready`` first). Returns
        ``(pstore', info)``."""
        h = self._next_tier
        assert h is not None, "no next tier: call precompile_next_tier first"
        h.ready.wait()
        if h.error is not None:
            self._next_tier = None
            raise RuntimeError("next-tier precompile failed") from h.error
        t0 = time.perf_counter()
        with self.tracer.span("hot_swap_pause"):
            grown = self._grow_step(h.pspec)(pstore)
            jax.block_until_ready(grown)
        swap_s = time.perf_counter() - t0
        self._set_pspec(h.pspec)
        self.swap_events += 1
        self._next_tier = None
        return grown, dict(
            swap_seconds=swap_s, e_blk_cap=h.pspec.e_blk_cap,
            recent_blk_cap=h.pspec.recent_blk_cap,
            precompile_seconds=h.seconds, compiled_steps=h.compiled,
        )

    def maintenance_tick(self, pstore, policy: MaintenancePolicy | None = None,
                         *, occupancy: dict | None = None, journal=None):
        """Run due maintenance between transaction batches.

        Reads only the tiny block-length scalars, then (per the policy)
        grows capacity and/or runs the owner-local compaction pass. Returns
        ``(pstore', info)`` where ``info`` reports what ran and the
        occupancy/recent-fill signals that drove it.

        ``occupancy`` lets a caller that just committed reuse the report its
        ``run_grw_tx`` metrics were derived from (any dict carrying
        ``max_occupancy`` / ``max_recent_fill`` for *this* ``pstore``)
        instead of re-reading the block scalars inside a timed loop.

        ``journal`` (a ``graphstore.journal.WriteBehindJournal``) records
        every maintenance event that runs (GROW / COMPACT), so recovery
        replays layout changes at the same point in the commit order.
        Host-scheduled ticks are the fallback path — the gated gRW step
        (``grw_step(gate=...)``) compacts on-device without any of this.
        """
        assert self.pspec is not None, "maintenance targets the partitioned tier"
        with self.tracer.span("compaction_tick"):
            policy = MaintenancePolicy() if policy is None else policy
            occ = (self.store_occupancy(pstore) if occupancy is None
                   else occupancy)
            dec = decide_maintenance(
                self.pspec, occ, policy, self.mutation_rows_since_compact
            )
            info = dict(
                compacted=False, grown_to=None, reason=dec.reason,
                max_occupancy=occ["max_occupancy"],
                max_recent_fill=occ["max_recent_fill"],
            )
            if dec.grow_to is not None:
                pstore = self.grow_blocks(pstore, dec.grow_to)
                if journal is not None:
                    journal.append_grow(
                        self.pspec.e_blk_cap, self.pspec.recent_blk_cap
                    )
                info["grown_to"] = dec.grow_to
            if dec.compact:
                pstore = self.compact_step(policy.purge)(pstore)
                if journal is not None:
                    journal.append_compact(purge=policy.purge)
                self.mutation_rows_since_compact = 0
                info["compacted"] = True
        return pstore, info

    def empty_cache(self) -> CacheState:
        """Global-capacity empty cache, device_put over the mesh: block s of
        every slot array is shard s's local cache (all blocks empty)."""
        return jax.device_put(empty_cache(self.espec.cache), self.cache_sharding())

    def shard_cache(self, cache: CacheState) -> CacheState:
        """Lay an existing global CacheState out over the mesh. Note the
        slot *layout* is reinterpreted (each block probes with the local
        capacity), so only caches whose entries were inserted through this
        runtime probe correctly — use ``empty_cache`` + population for new
        state."""
        return jax.device_put(cache, self.cache_sharding())

    # ---------------------------------------------------- stateful routing
    def attach_routing(self, rhost: RoutingTableHost | None):
        """Attach the host routing table. Once attached, every serving /
        commit / CP step resolves ``rhost.device_table()`` at dispatch time
        (cached per epoch, so an unchanged table costs a dict hit), and
        ``ShardedMissDrain`` queues misses at each root's *cache* owner.
        ``None`` detaches — back to the compiled-in modulo layout."""
        if rhost is not None:
            assert rhost.n == self.n, (rhost.n, self.n)
        self.rhost = rhost
        return rhost

    def _rtable_none(self) -> RoutingTable:
        """The identity table (routes exactly like ``owner_of``) — the
        serve step's default ``rtable`` input, cached so steady-state
        batches reuse one device constant instead of re-transferring."""
        if getattr(self, "_rtable_id", None) is None:
            self._rtable_id = identity_table(self.n)
        return self._rtable_id

    def _resolve_rtable(self, rtable) -> RoutingTable:
        """Resolve a step's table input: an explicit device ``RoutingTable``
        passes through, a ``RoutingTableHost`` stamps its current device
        table, ``None`` falls back to the attached ``rhost`` (or the
        identity table)."""
        if rtable is None:
            return (self.rhost.device_table() if self.rhost is not None
                    else self._rtable_none())
        if isinstance(rtable, RoutingTableHost):
            return rtable.device_table()
        return rtable

    # --------------------------------------------------------- gR-Tx path
    def _effective_cap_factor(self, worst_case: bool = False):
        """The cap factor a program variant compiles with. ``"auto"``
        derives the factor from measured telemetry (the peak per-owner
        frontier-row share, 25% headroom, floor 2) and starts at the
        measured production default before any step has run; the factor
        only ever grows (monotone max), so adaptation recompiles a bounded
        number of times. ``worst_case=True`` is the no-drop fallback
        variant the overflow retry dispatches."""
        if worst_case:
            return None
        rcf = self.route_cap_factor
        if rcf == "auto":
            if self._route_skew_seen is None:
                return DEFAULT_ROUTE_CAP_FACTOR
            f = max(2, int(np.ceil(self._route_skew_seen * 1.25)))
            return (max(f, DEFAULT_ROUTE_CAP_FACTOR[0]),
                    max(f, DEFAULT_ROUTE_CAP_FACTOR[1]))
        return rcf

    def _hop_route_caps(self, plan, Bloc, *, worst_case: bool = False):
        """Per-hop per-peer routing capacity (worst case unless bounded).

        A scalar ``route_cap_factor`` applies to every hop; a tuple supplies
        per-hop factors (hop 1 routes query roots, hops ≥ 2 route
        leaf-derived frontier roots with separately measured skew);
        ``"auto"`` derives them from the telemetry tier's measured owner
        skew (``_effective_cap_factor``)."""
        caps, A = [], 1
        F, RW = self.espec.frontier, self.espec.result_width
        rcf = self._effective_cap_factor(worst_case)
        for i, _ in enumerate(plan.hops):
            rows = Bloc * A
            f = rcf[min(i, len(rcf) - 1)] if isinstance(rcf, tuple) else rcf
            if f is None:
                caps.append(max(1, rows))
            else:
                caps.append(max(1, -(-f * rows // self.n)))
            A = min(F, A * RW)
        return caps

    def _down_none(self):
        """The healthy owner mask (all-False) — the serve step's default
        ``down`` input, cached so steady-state batches reuse one device
        constant instead of re-transferring per call."""
        if getattr(self, "_down_zeros", None) is None:
            self._down_zeros = jnp.zeros((self.n,), jnp.bool_)
        return self._down_zeros

    def _gr_fn(self, plan, bucket: int, *, pspec=None,
               worst_case: bool = False):
        """The un-jitted shard_map serving program (AOT lowering hook).
        ``pspec`` defaults to the current tier; the background pre-compiler
        passes the next tier's spec to build double-buffered programs.
        ``worst_case`` sizes route buckets for no-drop (the overflow-retry
        fallback variant)."""
        n = self.n
        assert bucket % n == 0, "global batch bucket must divide over shards"
        pspec = self.pspec if pspec is None else pspec
        Bloc = bucket // n
        # double-buffering needs an even per-shard batch to split into two
        # row streams; route caps are sized for the half-batch each stream
        # actually routes
        overlap = self.overlap and Bloc % 2 == 0 and Bloc >= 2
        caps = self._hop_route_caps(
            plan, Bloc // 2 if overlap else Bloc, worst_case=worst_case
        )
        fused = make_plan_fn(
            self.lspec, plan, self.use_cache, _MeshTier(self, caps, pspec),
            overlap=overlap,
        )
        return shard_map(
            fused,
            mesh=self.mesh,
            in_specs=(
                self._store_specs(), self._cache_specs(), P(),
                P(self.axes), P(self.axes), P(), P(),
            ),
            out_specs=(
                P(self.axes), P(self.axes), P(self.axes), P(self.axes),
                P(), P(),
            ),
            check_rep=False,
        )

    def _gr(self, plan, bucket: int, *, pspec=None, worst_case: bool = False):
        pspec = self.pspec if pspec is None else pspec
        # the caps are part of the key: "auto" mode re-derives the factor
        # from telemetry, and a grown factor is a new program variant (the
        # worst-case retry variant keys the same way)
        Bloc = bucket // self.n
        overlap = self.overlap and Bloc % 2 == 0 and Bloc >= 2
        caps = tuple(self._hop_route_caps(
            plan, Bloc // 2 if overlap else Bloc, worst_case=worst_case
        ))
        key = (pspec, _plan_key(plan), bucket, caps)
        if key not in self._gr_fns:
            jitted = jax.jit(self._gr_fn(
                plan, bucket, pspec=pspec, worst_case=worst_case
            ))

            def step(store, cache, ttable, roots, bvalid, down=None,
                     rtable=None, _fn=jitted):
                return _fn(
                    store, cache, ttable, roots, bvalid,
                    self._down_none() if down is None else jnp.asarray(down),
                    self._resolve_rtable(rtable),
                )

            step.jitted = jitted
            self._gr_fns[key] = step
        return self._gr_fns[key]

    def serve_step(self, plan, global_batch: int):
        """The jitted serving step for one ``QueryPlan`` (any hop count) —
        ``step(store, cache, ttable, roots [global_batch], bvalid,
        down=None, rtable=None) -> (results, deferred, miss_roots,
        miss_counts, metrics, read_version)``. ``down`` is the
        degraded-mode owner mask (bool[n], default all-healthy);
        ``rtable`` the replicated routing table (``RoutingTable`` /
        ``RoutingTableHost``; default: the attached ``rhost`` or the
        identity table — byte-identical to the static modulo layout);
        ``deferred`` flags rows whose miss segments were masked at a down
        owner (bounded-stale) or locality-routed away from their storage
        owner (retry through ``RoutingTableHost.storage_table()``)."""
        return self._gr(plan, global_batch)

    def run_gr_tx_batch(self, store, cache, ttable, plan, roots, *,
                        down=None, rtable=None,
                        return_deferred: bool = False):
        """Host wrapper: pad, execute, decode misses. Same contract as
        ``GraphEngine.run`` — one blocking device→host transfer on the
        healthy path.

        ``down`` (bool[n]) masks the named owners' miss segments
        (degraded-mode serving); ``rtable`` threads the routing table (see
        ``serve_step``). Two host-side retry loops wrap the step, both
        re-dispatching through compiled program variants (never a
        recompile on the serving path):

        - **locality retry** — rows deferred because they hit a *split*
          vertex's cache home (cache owner ≠ storage owner) re-dispatch
          once through the table's storage view
          (``RoutingTableHost.storage_table()`` — the same compiled
          program, a different table input). Needs a host table (a
          ``RoutingTableHost`` argument or the attached ``rhost``).
        - **overflow retry** (``route_cap_factor="auto"`` only) — a batch
          that overflowed the telemetry-derived buckets re-dispatches on
          the worst-case-caps variant, and the measured skew ratchets up
          so future plans compile with wider buckets
          (``route_cap_retries`` counts the fallbacks).

        With ``return_deferred=True`` the per-query deferred flags come
        back as a fourth element."""
        B = len(roots)
        bucket = max(bucket_for(B), self.n)
        proots, bvalid = pad_roots(roots, bucket)
        proots, bvalid = jnp.asarray(proots), jnp.asarray(bvalid)
        rhost = rtable if isinstance(rtable, RoutingTableHost) else (
            self.rhost if rtable is None else None
        )
        tr = self.tracer
        t0 = time.perf_counter()
        with tr.span("gr_dispatch"):
            out = self._gr(plan, bucket)(
                store, cache, ttable, proots, bvalid, down, rtable,
            )
        with tr.span("gr_sync"):
            result, deferred, miss_roots, miss_counts, m, version = (
                jax.device_get(out)
            )
        # measured per-step wall-clock (device_get above is the blocking
        # sync): the live heartbeat FailoverController feeds the
        # FailureDetector when no scripted ShardFaultPlan is driving it
        self.last_step_seconds = time.perf_counter() - t0
        with tr.span("gr_unpack"):
            # pop the per-owner stage block BEFORE building the host
            # metrics dict, keeping it byte-identical to telemetry=False
            owner_stage = m.pop("owner_stage", None)
            metrics = {k: int(v) for k, v in m.items()}
            metrics["host_syncs"] = 1
            misses = decode_miss_records(
                plan, self.use_cache, miss_roots, miss_counts, int(version)
            )
        if owner_stage is not None:
            self.last_owner_stage = np.asarray(owner_stage, dtype=np.int64)
            self.last_step_owner_seconds = attribute_step_seconds(
                self.last_step_seconds, self.last_owner_stage
            )
            # feed the auto-cap sizer: peak owner share of routed frontier
            # rows this step (ratcheted max, so factors only ever grow)
            fr = self.last_owner_stage[
                :, OWNER_STAGE_FIELDS.index("frontier_rows")
            ].astype(np.float64)
            if fr.sum() > 0:
                skew = float(fr.max() * self.n / fr.sum())
                self._route_skew_seen = (
                    skew if self._route_skew_seen is None
                    else max(self._route_skew_seen, skew)
                )
        else:
            self.last_owner_stage = None
            self.last_step_owner_seconds = None
        metrics["route_cap_retries"] = 0
        if self.route_cap_factor == "auto" and metrics["route_overflow"] > 0:
            with tr.span("gr_dispatch"):
                out = self._gr(plan, bucket, worst_case=True)(
                    store, cache, ttable, proots, bvalid, down, rtable,
                )
            with tr.span("gr_sync"):
                result, deferred, miss_roots, miss_counts, m2, version = (
                    jax.device_get(out)
                )
            m2.pop("owner_stage", None)
            syncs = metrics["host_syncs"] + 1
            metrics = {k: int(v) for k, v in m2.items()}
            metrics["host_syncs"] = syncs
            metrics["route_cap_retries"] = 1
            self.route_cap_retries += 1
            misses = decode_miss_records(
                plan, self.use_cache, miss_roots, miss_counts, int(version)
            )
        result = np.asarray(result)
        deferred = np.asarray(deferred)
        metrics["locality_retry_rows"] = 0
        if rhost is not None and rhost.cache_exceptions and deferred[:B].any():
            split = np.asarray(rhost.is_split(np.asarray(roots, np.int64)))
            idx = np.flatnonzero(deferred[:B] & split)
            if idx.size:
                r2, mis2, m2, d2 = self.run_gr_tx_batch(
                    store, cache, ttable, plan,
                    np.asarray(roots, np.int32)[idx],
                    down=down, rtable=rhost.storage_table(),
                    return_deferred=True,
                )
                # device_get buffers are read-only; copy to merge into
                result, deferred = result.copy(), deferred.copy()
                result[idx] = r2
                deferred[idx] = d2
                misses = list(misses) + list(mis2)
                for k, v in m2.items():
                    if k in metrics:
                        metrics[k] += int(v)
                metrics["locality_retry_rows"] = int(idx.size)
                self.locality_retries += 1
        if return_deferred:
            return result[:B], misses, metrics, deferred[:B]
        return result[:B], misses, metrics

    # -------------------------------------------------------- gRW-Tx path
    def _route_and_apply_ops(self, cache, ops, sweeps, through, local_sweeps,
                             rtable=None):
        """Shared phase B: compact the derived op stream, route each op to
        the shard holding its root's *cache* entries (``cache_owner_of``
        under ``rtable``; the identity table is exactly ``owner_of``), and
        apply against the local cache block. ``local_sweeps`` marks sweeps
        as already owner-local; otherwise they are all_gathered and every
        shard applies the full stream (non-matching sweeps no-op, so this
        is correct wherever a root's entries live — the partitioned tier
        uses it because a migrated/split root's cache home may differ from
        the storage shard that derived the sweep).

        Returns (cache', occupancy_delta, overflow)."""
        lcspec = self.lspec.cache
        n, axes = self.n, self.axes
        ops_cap, sweep_cap = self.ops_cap, self.sweep_cap
        ops_route_cap = self.ops_route_cap

        # compact: only real ops are routed/applied — the pre-compaction
        # path instead probed every masked lane of the stream
        (okind, otpl, oroot, oparams, ovid, oorder), _, ovf_c = compact_rows(
            ops.ok, ops_cap,
            (ops.kind, ops.tpl, ops.root, ops.params, ops.vid, ops.order),
            (0, -1, NULL_ID, 0, NULL_ID, 0),
        )
        # route each op to the shard whose local cache block holds the
        # impacted entry (the root's cache home under the routing table)
        dest = jnp.where(
            oroot != NULL_ID, cache_owner_of(rtable, oroot, n), -1
        )
        slot, kept, ovf_r = route_plan(dest, n, ops_route_cap)

        def a2a(x, fill):
            return jax.lax.all_to_all(
                route_scatter(x, slot, n, ops_route_cap, fill), axes,
                split_axis=0, concat_axis=0, tiled=True,
            ).reshape((n * ops_route_cap,) + x.shape[1:])

        rroot = a2a(oroot, NULL_ID)
        rops = CacheOpStream(
            kind=a2a(okind, 0), tpl=a2a(otpl, -1), root=rroot,
            params=a2a(oparams, 0), vid=a2a(ovid, NULL_ID),
            order=a2a(oorder, 0), ok=rroot != NULL_ID,
        )
        (stpl, sroot), _, ovf_s = compact_rows(
            sweeps.ok, sweep_cap, (sweeps.tpl, sweeps.root), (-1, NULL_ID)
        )
        if local_sweeps:
            # ownership-masked phase A already emitted each sweep at the
            # shard whose cache block holds the swept root's entries
            gsw = SweepStream(tpl=stpl, root=sroot, ok=sroot != NULL_ID)
        else:
            g = jax.lax.all_gather(
                jnp.stack([stpl, sroot], axis=1), axes, axis=0, tiled=True
            )
            gsw = SweepStream(tpl=g[:, 0], root=g[:, 1], ok=g[:, 1] != NULL_ID)

        # impacted counts *distinct logical keys removed*: chunk-0
        # occupancy delta. Counting raw ops would over-count a key hit by
        # several routed ops, and counting all slots would over-count
        # multi-chunk chains.
        head = lambda c: jnp.sum((c.valid & (c.chunk == 0)).astype(jnp.int32))
        occ0 = head(cache)
        cache2 = apply_sweeps(lcspec, cache, gsw)
        if through:
            # value edits are order-sensitive per key; distinct keys
            # commute — the segmented apply vectorizes across them
            cache2 = apply_op_stream_segmented(lcspec, cache2, rops)
        else:
            # deletes commute: one batched pass
            cache2 = apply_op_stream_batched(lcspec, cache2, rops)
        occ_delta = occ0 - head(cache2)
        cache2 = cache2._replace(n_delete=cache.n_delete + occ_delta)
        return cache2, occ_delta, ovf_c + ovf_r + ovf_s

    def _grw_fn(self, policy: str, gate: DeviceGate | None = None, *,
                pspec=None):
        """The un-jitted shard_map gRW commit (AOT lowering hook).

        With ``gate`` (a ``DeviceGate``) the step carries the maintenance
        decision **on-device**: after the owner-local apply + listener,
        each shard checks its own blocks' recent fill against the gate
        threshold and compacts them inside a ``lax.cond`` — no per-batch
        host round-trip of block scalars, no separate compaction dispatch.
        The post-maintenance capacity signals (max block occupancy /
        recent fill, pmax-reduced) and the number of shard-blocks compacted
        come back as step outputs, so the host reads them from the commit's
        one transfer instead of a follow-up occupancy read."""
        espec = self.espec
        lspec = self.lspec
        pspec = self.pspec if pspec is None else pspec
        n, axes = self.n, self.axes
        through = policy != "write-around"

        if pspec is not None:
            # static per-block threshold: gate decisions are a pure function
            # of (store, batch, gate), which journal replay relies on
            thresh = (
                max(int(np.ceil(gate.recent_fill_frac * pspec.recent_blk_cap)), 0)
                if gate is not None else 0
            )

            def local_grw(store, cache, ttable, batch, rtable):
                me = jax.lax.axis_index(axes)
                # phase A: commit to owner-local storage; the listener
                # derives ops where the storage lives (ownership masks,
                # table-aware: a migrated vertex's rows commit and derive
                # at its table owner)
                store2, applied, store_ovf = apply_mutations_partitioned(
                    pspec, store, batch, me, axes, rtable=rtable
                )
                ops, sweeps = derive_cache_ops_views(
                    lspec, BlockStoreView(pspec, store, me, rtable=rtable),
                    BlockStoreView(pspec, store2, me, rtable=rtable),
                    ttable, applied, through=through,
                )
                if gate is not None:
                    # on-device maintenance gate — ops were derived above,
                    # so the layout change cannot perturb this commit's
                    # invalidation; compact_block is collective-free, so a
                    # per-shard lax.cond is legal under check_rep=False
                    def maybe_compact(blk):
                        rec = blk.blk_len[0] - blk.csr_len[0]
                        hit = rec >= thresh
                        return jax.lax.cond(
                            hit,
                            lambda b: compact_block(
                                pspec, b, purge=gate.purge, me=me
                            ),
                            lambda b: b,
                            blk,
                        ), hit
                    out_b, hit_o = maybe_compact(store2.out)
                    inc_b, hit_i = maybe_compact(store2.inc)
                    store2 = store2._replace(out=out_b, inc=inc_b)
                    ncomp = jax.lax.psum(
                        hit_o.astype(jnp.int32) + hit_i.astype(jnp.int32),
                        axes,
                    )
                else:
                    ncomp = jnp.int32(0)
                # sweeps gather (local_sweeps=False): the listener derives
                # each sweep at the swept root's STORAGE shard, but under a
                # routing table the root's cache entries may live elsewhere
                # — every shard applies the full gathered stream, and
                # non-matching sweeps no-op (byte-identical to the old
                # owner-local apply when the table is the identity)
                cache2, occ_delta, ovf = self._route_and_apply_ops(
                    cache, ops, sweeps, through, local_sweeps=False,
                    rtable=rtable,
                )
                impacted = jax.lax.psum(occ_delta, axes)
                cache2 = _replicate_stats(cache, cache2, axes)
                overflow = jax.lax.psum(ovf, axes)
                # post-maintenance capacity signals, reduced on-device
                blk_max = jax.lax.pmax(jnp.maximum(
                    store2.out.blk_len[0], store2.inc.blk_len[0]
                ), axes)
                rec_max = jax.lax.pmax(jnp.maximum(
                    store2.out.blk_len[0] - store2.out.csr_len[0],
                    store2.inc.blk_len[0] - store2.inc.csr_len[0],
                ), axes)
                return (store2, cache2, impacted, overflow, store_ovf,
                        blk_max, rec_max, ncomp)
        else:
            assert gate is None, "the device gate targets the partitioned tier"

            def local_grw(store, cache, ttable, batch, rtable):
                me = jax.lax.axis_index(axes)
                # every replica applies the same commit (deterministic)
                store2, applied = apply_mutations(espec.store, store, batch)
                # phase A: derive impacted keys from this shard's slice
                # of the mutation batch (round-robin rows)
                part = shard_mutation_rows(applied, n, me)
                ops, sweeps = derive_cache_ops(
                    espec, store, store2, ttable, part, through=through,
                    row_offset=me, row_stride=n,
                )
                cache2, occ_delta, ovf = self._route_and_apply_ops(
                    cache, ops, sweeps, through, local_sweeps=False,
                    rtable=rtable,
                )
                impacted = jax.lax.psum(occ_delta, axes)
                cache2 = _replicate_stats(cache, cache2, axes)
                overflow = jax.lax.psum(ovf, axes)
                z = jnp.int32(0)
                return store2, cache2, impacted, overflow, z, z, z, z

        return shard_map(
            local_grw,
            mesh=self.mesh,
            in_specs=(self._store_specs(), self._cache_specs(), P(), P(),
                      P()),
            out_specs=(
                self._store_specs(), self._cache_specs(), P(), P(), P(),
                P(), P(), P(),
            ),
            check_rep=False,
        )

    def _grw(self, policy: str, gate: DeviceGate | None = None, *,
             pspec=None):
        pspec = self.pspec if pspec is None else pspec
        key = (pspec, policy, gate)
        if key not in self._grw_fns:
            jitted = jax.jit(self._grw_fn(policy, gate, pspec=pspec))

            def step(store, cache, ttable, batch, rtable=None, _fn=jitted):
                return _fn(
                    store, cache, ttable, batch,
                    self._resolve_rtable(rtable),
                )

            step.jitted = jitted
            self._grw_fns[key] = step
        return self._grw_fns[key]

    def grw_step(self, policy: str = "write-around",
                 gate: DeviceGate | None = None):
        """The jitted sharded gRW-Tx commit (cached per tier + policy +
        gate): ``step(store, cache, ttable, batch, rtable=None) ->
        (store', cache', impacted, route_overflow, store_overflow,
        max_blk_len, max_recent_fill, device_compactions)``. With ``gate``
        the step compacts over-threshold blocks on-device (see
        ``_grw_fn``); ``rtable`` resolves like ``serve_step``'s."""
        return self._grw(policy, gate)

    def run_grw_tx(self, store, cache, ttable, batch, policy: str = "write-around",
                   *, gate: DeviceGate | None = None,
                   occupancy_metrics: bool = True, journal=None,
                   rtable=None):
        """Host wrapper mirroring ``repro.core.engine.run_grw_tx``.

        ``rtable`` threads the routing table through the commit (resolved
        like ``serve_step``'s: explicit table > ``RoutingTableHost`` >
        attached ``rhost`` > identity); when a host table is available its
        ``storage_owner`` lookup also routes the journal's dirty-owner
        bookkeeping, so incremental checkpoints stay consistent with
        migrated placements.

        On the partitioned tier the metrics also surface the post-commit
        capacity signals (max block occupancy / recent fill) that drive
        growth decisions — computed **inside the step** and pmax-reduced
        on-device, so they ride the commit's own transfer (the pre-gate
        runtime re-read block scalars from the host per batch). With
        ``gate`` the step additionally compacts over-threshold blocks
        on-device and reports ``device_compactions``.

        ``journal`` (a ``WriteBehindJournal``) makes the commit durable
        write-behind: the batch is appended with its effective step config
        (policy + gate) and the journal's lag/queue metrics are folded into
        the returned metrics."""
        rhost = rtable if isinstance(rtable, RoutingTableHost) else (
            self.rhost if rtable is None else None
        )
        with self.tracer.span("grw_step"):
            out = self._grw(policy, gate)(
                store, cache, ttable, batch, rtable
            )
            (store2, cache2, impacted, overflow, store_ovf,
             blk_max, rec_max, ncomp) = out
            metrics = {
                "impacted_keys": int(impacted), "op_overflow": int(overflow),
                "store_append_overflow": int(store_ovf),
            }
        if self.pspec is not None:
            b = batch
            self.mutation_rows_since_compact += sum(
                int(x) for x in (b.nv_n, b.ne_n, b.de_n, b.dv_n, b.sv_n, b.se_n)
            )
            if gate is not None:
                ncomp = int(ncomp)
                metrics["device_compactions"] = ncomp
                if ncomp:
                    self.mutation_rows_since_compact = 0
            if occupancy_metrics:
                EB = self.pspec.e_blk_cap
                metrics["store_occupancy_max"] = round(int(blk_max) / EB, 4)
                metrics["store_recent_fill_max"] = int(rec_max)
        if journal is not None:
            journal.append_commit(
                batch, policy=policy, gate=gate,
                commit_version=int(jax.device_get(store2.version)),
                device_compactions=(
                    int(ncomp) if (gate is not None and self.pspec is not None)
                    else 0
                ),
                route=(rhost.storage_owner if rhost is not None else None),
            )
            metrics.update(journal.metrics())
        return store2, cache2, metrics

    # ------------------------------------------------------ CP population
    def populator(self, templates_meta, max_retries: int = 3):
        """A ``CachePopulator`` whose CP transactions execute each miss at
        its owner shard (against owner-local storage on the partitioned
        tier) and insert at the owner's cache block, draining the same
        MissQueue."""
        from repro.core.population import CachePopulator

        return CachePopulator(
            self.espec, templates_meta, max_retries=max_retries,
            step_builder=functools.partial(self._pop, templates_meta),
        )

    def _pop(self, templates_meta, tpl_idx: int, bucket: int):
        # the returned step resolves the compiled program at CALL time:
        # populators cache this thin adapter in their own _jitted dicts, and
        # _pop_fns is keyed by the CURRENT pspec — so the next drain after a
        # capacity swap resolves the new tier's program (precompiled in the
        # background, or compiled lazily) instead of silently reusing a
        # closure over the pre-growth pspec (whose gathers clamp slots to
        # the old e_blk_cap). The adapter also bridges CachePopulator's
        # keyword calls to shard_map's positional-only wrapper.
        def step(store_exec, store_commit, cache, ttable, roots, params,
                 mask, read_versions):
            return self._pop_compiled(templates_meta, tpl_idx, bucket)(
                store_exec, store_commit, cache, ttable, roots, params,
                mask, read_versions, self._resolve_rtable(None),
            )

        return step

    def _pop_compiled(self, templates_meta, tpl_idx: int, bucket: int, *,
                      pspec=None):
        pspec = self.pspec if pspec is None else pspec
        key = (pspec, tpl_idx, bucket)
        if key not in self._pop_fns:
            from repro.core.population import populate_step

            lspec, n, axes = self.lspec, self.n, self.axes
            direction, edge_label = templates_meta[tpl_idx]

            def local_pop(store_exec, store_commit, cache, ttable, roots,
                          params, mask, read_versions, rtable):
                me = jax.lax.axis_index(axes)
                valid = mask & (roots >= 0)
                if pspec is not None:
                    # CP split under the routing table: the miss executes
                    # at the root's STORAGE owner (where its dual-CSR rows
                    # live) and the entry inserts at its CACHE owner; the
                    # computed bundle crosses via a zero-masked psum inside
                    # populate_step. Identity table → exec == commit shard,
                    # byte-identical to the fused path.
                    owned_exec = valid & (
                        storage_owner_of(rtable, roots, n) == me
                    )
                    owned_commit = valid & (
                        cache_owner_of(rtable, roots, n) == me
                    )
                    view = BlockStoreView(
                        pspec, store_exec, me, rtable=rtable
                    )
                    cache2, ok, ab = populate_step(
                        lspec, store_exec, store_commit, cache, ttable,
                        tpl_idx, direction, edge_label, roots, params,
                        owned_exec, read_versions, exec_view=view,
                        commit_mask=owned_commit,
                        allreduce=lambda x: jax.lax.psum(x, axes),
                    )
                else:
                    # replicated snapshot: every shard can execute any
                    # miss, so CP runs whole at the root's cache owner
                    owned = valid & (cache_owner_of(rtable, roots, n) == me)
                    cache2, ok, ab = populate_step(
                        lspec, store_exec, store_commit, cache, ttable,
                        tpl_idx, direction, edge_label, roots, params,
                        owned, read_versions, exec_view=None,
                    )
                ok = jax.lax.psum(ok.astype(jnp.int32), axes) > 0
                ab = jax.lax.psum(ab.astype(jnp.int32), axes) > 0
                cache2 = _replicate_stats(cache, cache2, axes)
                return cache2, ok, ab

            sm = shard_map(
                local_pop,
                mesh=self.mesh,
                in_specs=(
                    self._store_specs(), self._store_specs(),
                    self._cache_specs(), P(), P(), P(), P(), P(), P(),
                ),
                out_specs=(self._cache_specs(), P(), P()),
                check_rep=False,
            )
            self._pop_fns[key] = jax.jit(sm)
        return self._pop_fns[key]


class ShardedMissDrain:
    """Per-shard CP drain loops over ``serve_step``'s per-shard miss records.

    ``serve_step`` already returns one independently-counted miss segment
    per shard; the single host-side ``CachePopulator`` round-trip merged
    them back into one global FIFO, re-deriving ownership at insert time.
    This keeps one ``MissQueue`` + populator per shard instead — each miss
    record lands in its root's owner queue (the shard whose blocks execute
    it and whose cache block receives the insert), and ``drain`` walks the
    shards round-robin so every CP batch is single-owner (the CP-per-shard
    layout of §4's population threads). All populators share the runtime's
    compiled CP steps, so the fan-out costs no extra compilation.
    """

    def __init__(self, rt: ShardedTxnRuntime, templates_meta,
                 max_retries: int = 3):
        self.n = rt.n
        self.rt = rt
        self.pops = [
            rt.populator(templates_meta, max_retries) for _ in range(rt.n)
        ]

    def push(self, misses):
        rhost = self.rt.rhost
        for m in misses:
            # each miss lands at its root's CACHE owner queue — under the
            # routing table that is where the insert commits (and, for an
            # unsplit vertex, where its rows execute)
            owner = (int(rhost.cache_owner(int(m.root))) if rhost is not None
                     else int(base_owner(m.root, self.n)))
            self.pops[owner].queue.push([m])

    def drain(self, store_exec, store_commit, cache, ttable, k: int = 128):
        """Drain up to ``k`` misses per shard queue; returns the new cache."""
        for pop in self.pops:
            cache = pop.drain(store_exec, store_commit, cache, ttable, k)
        return cache

    @property
    def committed(self) -> int:
        return sum(p.committed for p in self.pops)

    @property
    def aborted(self) -> int:
        return sum(p.aborted for p in self.pops)

    def pending(self) -> int:
        return sum(len(p.queue) for p in self.pops)


# ======================================================================
# Capacity planning: the paper's production deployment described as a
# config, lowered onto the runtime for the roofline/dry-run tooling.
# ======================================================================


@dataclass(frozen=True)
class GraphServeConfig:
    name: str = "ecommerce-graph"
    v_total: int = 2**30  # ~1.1B vertices (tens of billions of edges)
    e_per_vertex: int = 8  # average degree for capacity planning
    n_vprops: int = 2
    n_eprops: int = 1
    max_deg: int = 64  # per-hop gather window
    max_leaves: int = 64  # cache value width
    cache_slots_total: int = 2**26  # cache capacity across the fleet
    route_cap_factor: int | tuple | None = DEFAULT_ROUTE_CAP_FACTOR
    recent_cap: int = 1024  # append-region scan window
    # the served template instance (Figure 1): edge prop0 == 1, leaf prop0 == 0
    edge_prop: int = 0
    edge_val: int = 1
    leaf_prop: int = 0
    leaf_val: int = 0

    def e_total(self) -> int:
        return self.v_total * self.e_per_vertex


def config_espec(cfg: GraphServeConfig):
    """Lower a capacity config to an ``EngineSpec`` for the runtime."""
    from repro.core.cache import CacheSpec
    from repro.core.engine import EngineSpec
    from repro.graphstore.store import StoreSpec

    spec = StoreSpec(
        v_cap=cfg.v_total, e_cap=cfg.e_total(), n_vprops=cfg.n_vprops,
        n_eprops=cfg.n_eprops, recent_cap=cfg.recent_cap,
    )
    cspec = CacheSpec(
        capacity=cfg.cache_slots_total, probes=8,
        max_leaves=cfg.max_leaves, max_chunks=1,
    )
    return EngineSpec(
        store=spec, cache=cspec, max_deg=cfg.max_deg, frontier=cfg.max_leaves
    )


def config_plan_and_ttable(cfg: GraphServeConfig):
    """The served SQ1-shape template instance (Figure 1) as a runtime
    ``QueryPlan`` plus its enabled ``TemplateTable``."""
    from repro.core.engine import Hop, QueryPlan
    from repro.core.keys import PARAM_LEN
    from repro.core.lifecycle import GraphQP, ServiceCoordinator
    from repro.core.templates import (
        ANY_LABEL, DIR_OUT, MAX_CONDS, OP_EQ, WILDCARD, Template, make_pred,
        make_template_table,
    )
    from repro.utils import PROP_MISSING

    econd = [(cfg.edge_prop, OP_EQ, WILDCARD)]
    lcond = [(cfg.leaf_prop, OP_EQ, WILDCARD)]
    tpl = Template(
        "SQ1", DIR_OUT, (ANY_LABEL, []), (ANY_LABEL, econd), (ANY_LABEL, lcond)
    )
    ttable = make_template_table([tpl])
    qp = GraphQP("qp0")
    sc = ServiceCoordinator([qp])
    sc.register(0)
    sc.enable(0)
    ttable = qp.ttable_masks(ttable, 1)
    params = np.full(PARAM_LEN, int(PROP_MISSING), np.int32)
    params[0] = cfg.edge_val
    params[MAX_CONDS] = cfg.leaf_val
    hop = Hop(
        DIR_OUT, ANY_LABEL, make_pred(ANY_LABEL, []),
        make_pred(ANY_LABEL, econd), make_pred(ANY_LABEL, lcond), 0, params,
    )
    return QueryPlan(hops=(hop,)), ttable


def config_cell(cfg: GraphServeConfig, mesh: Mesh, *, use_cache: bool = True,
                global_batch: int = 8192, blk_slack: float = 1.0):
    """Build the dry-run cell for a capacity config on the partitioned
    runtime: ``(step_fn, in_shardings, abstract_args, runtime)`` with the
    first three ready for
    ``jax.jit(step_fn, in_shardings=...).lower(*abstract_args)``."""
    espec = config_espec(cfg)
    plan, ttable = config_plan_and_ttable(cfg)
    rt = ShardedTxnRuntime(
        espec, mesh, use_cache=use_cache, store_tier="partitioned",
        route_cap_factor=cfg.route_cap_factor, blk_slack=blk_slack,
    )
    step = rt._gr_fn(plan, global_batch)
    sds = jax.ShapeDtypeStruct
    pstore = abstract_partitioned_store(rt.pspec)
    cache = jax.eval_shape(lambda: empty_cache(espec.cache))
    roots = sds((global_batch,), jnp.int32)
    bvalid = sds((global_batch,), jnp.bool_)
    down = sds((rt.n,), jnp.bool_)
    rtab = jax.eval_shape(lambda: identity_table(rt.n))
    repl = NamedSharding(mesh, P())
    rshard = NamedSharding(mesh, P(tuple(mesh.axis_names)))
    in_shardings = (
        rt.store_sharding(),
        rt.cache_sharding(),
        jax.tree_util.tree_map(lambda _: repl, ttable),
        rshard, rshard, repl,
        jax.tree_util.tree_map(lambda _: repl, rtab),
    )
    return step, in_shardings, (pstore, cache, ttable, roots, bvalid,
                                down, rtab), rt


def config_grw_cell(cfg: GraphServeConfig, mesh: Mesh, *,
                    policy: str = "write-around", blk_slack: float = 1.0,
                    caps: tuple = (8, 32, 32, 8, 32, 32)):
    """Build the dry-run cell for the sharded gRW commit at capacity-config
    scale: ``(step_fn, in_shardings, abstract_args, runtime)``.

    This is the lowering check for the indexed edge-copy location: the
    former O(K × e_blk_cap) broadcast-compare materialized [K, 2^30]
    intermediates at the FULL config's per-shard block capacity, a compile
    cliff the geid→slot ``searchsorted`` probes remove. The cell lowers the
    whole commit — owner-local apply, ownership-masked listener, and the
    routed cache-maintenance phase — at dry-run block capacity.
    """
    espec = config_espec(cfg)
    _, ttable = config_plan_and_ttable(cfg)
    rt = ShardedTxnRuntime(
        espec, mesh, store_tier="partitioned",
        route_cap_factor=cfg.route_cap_factor, blk_slack=blk_slack,
    )
    step = rt._grw_fn(policy)
    batch = jax.eval_shape(
        lambda: make_mutation_batch(espec.store, caps=caps)
    )
    pstore = abstract_partitioned_store(rt.pspec)
    cache = jax.eval_shape(lambda: empty_cache(espec.cache))
    rtab = jax.eval_shape(lambda: identity_table(rt.n))
    repl = NamedSharding(mesh, P())
    in_shardings = (
        rt.store_sharding(),
        rt.cache_sharding(),
        jax.tree_util.tree_map(lambda _: repl, ttable),
        jax.tree_util.tree_map(lambda _: repl, batch),
        jax.tree_util.tree_map(lambda _: repl, rtab),
    )
    return step, in_shardings, (pstore, cache, ttable, batch, rtab), rt
