"""Distributed graph-query serving — the paper's production architecture
mapped onto a TPU mesh with shard_map.

Layout: vertices are range-partitioned over all mesh axes (shard s owns
[s*Vloc, (s+1)*Vloc)); each shard holds its vertices' outgoing edges in a
local CSR block and the *co-partitioned cache shard* for keys rooted at its
vertices (a hop's cache probe is always local to the root's owner).

``serve_step`` processes a global batch of one-hop gR-Txs (one registered
template instance, the paper's SQ1 shape):

  round 1:  route each root to its owner            (all_to_all #1)
            probe the local cache shard; misses run the local CSR gather +
            edge-predicate filter
  round 2:  leaf property fetch — leaf ids route to *their* owners for the
            P^l evaluation                           (all_to_all #2, #3)
  return:   results route back to the querying shard (all_to_all #4)

A cache hit skips rounds 2's traffic entirely, which is exactly the paper's
"n+2 requests -> 2" effect in collective form: the §Roofline collective
term of this step is what the cache attacks. The write/invalidate path
reuses the single-host core (gRW-Txs are batch, throughput-oriented).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils import NULL_ID, hash_rows, sort_dedup_masked


@dataclass(frozen=True)
class GraphServeConfig:
    name: str = "ecommerce-graph"
    v_total: int = 2**30  # ~1.1B vertices (tens of billions of edges)
    e_per_vertex: int = 8  # average degree for capacity planning
    n_vprops: int = 2
    n_eprops: int = 1
    max_deg: int = 64  # per-hop gather window
    max_leaves: int = 64  # cache value width
    cache_slots_total: int = 2**26  # cache capacity across the fleet
    route_cap_factor: int = 4  # per-peer routing capacity multiplier
    # the served template instance (Figure 1): edge prop0 == 1, leaf prop0 == 0
    edge_prop: int = 0
    edge_val: int = 1
    leaf_prop: int = 0
    leaf_val: int = 0
    tpl_id: int = 1
    # §Perf (paper-arch cell): denormalize the leaf predicate property onto
    # the edge record (JanusGraph vertex-centric-index style). Eliminates
    # the entire round-2 remote leaf fetch (all_to_all #2/#3 and the remote
    # vprop reads) at the cost of write amplification: a leaf-prop gRW-Tx
    # must update every in-edge copy (bounded by the leaf's in-degree; the
    # same L factor as Table 2's DeleteKeysForLeaf).
    denormalize_leaf_props: bool = False

    def e_total(self) -> int:
        return self.v_total * self.e_per_vertex


def abstract_state(cfg: GraphServeConfig, n_shards: int):
    """ShapeDtypeStructs for the sharded store + cache (dry-run inputs)."""
    V, E, C = cfg.v_total, cfg.e_total(), cfg.cache_slots_total
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    out_extra = {"ldprop": sds((E,), i32)} if cfg.denormalize_leaf_props else {}
    return dict(
        deg=sds((V,), i32),
        start=sds((V,), i32),  # local offset into the owner's edge block
        dst=sds((E,), i32),
        eprop=sds((E,), i32),  # the predicate property (IsActive)
        vprop=sds((V,), i32),  # the leaf predicate property (Status)
        **out_extra,
        c_root=sds((C,), i32),
        c_fp=sds((C,), jnp.uint32),
        c_len=sds((C,), i32),
        c_vals=sds((C, cfg.max_leaves), i32),
        c_valid=sds((C,), jnp.bool_),
    )


def state_shardings(cfg: GraphServeConfig, mesh: Mesh):
    axes = tuple(mesh.axis_names)
    s1 = NamedSharding(mesh, P(axes))
    extra = {"ldprop": s1} if cfg.denormalize_leaf_props else {}
    return dict(
        deg=s1, start=s1, dst=s1, eprop=s1, vprop=s1,
        c_root=s1, c_fp=s1, c_len=s1,
        c_vals=NamedSharding(mesh, P(axes, None)),
        c_valid=s1, **extra,
    )


def _bucketize(vals, dest, n, cap, fill=NULL_ID):
    """Route ``vals`` into [n, cap] peer buckets (MoE-dispatch style).

    Returns (buckets [n, cap], slot [M] — each input's (peer*cap+rank) or
    OOB when dropped, kept mask)."""
    M = vals.shape[0]
    order = jnp.argsort(dest)
    sd, sv = dest[order], vals[order]
    offs = jnp.searchsorted(sd, jnp.arange(n, dtype=dest.dtype), side="left")
    rank = jnp.arange(M) - offs[jnp.clip(sd, 0, n - 1)]
    keep = (rank < cap) & (sd >= 0) & (sd < n)
    slot_sorted = jnp.where(keep, sd * cap + rank, n * cap)
    buckets = jnp.full((n * cap,), fill, vals.dtype)
    buckets = buckets.at[slot_sorted].set(sv, mode="drop").reshape(n, cap)
    # map back to input order
    slot = jnp.full((M,), n * cap, jnp.int32)
    slot = slot.at[order].set(slot_sorted.astype(jnp.int32), mode="drop")
    return buckets, slot, slot < n * cap


def build_serve_step(cfg: GraphServeConfig, mesh: Mesh, *, use_cache: bool = True,
                     global_batch: int = 8192):
    """Returns a jit-able ``step(state_dict, roots) -> (results, stats)``.

    roots: int32 [global_batch] sharded over all axes; results
    [global_batch, max_leaves] (NULL_ID padded).
    """
    axes = tuple(mesh.axis_names)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    V, E, C = cfg.v_total, cfg.e_total(), cfg.cache_slots_total
    assert V % n == 0 and E % n == 0 and C % n == 0 and global_batch % n == 0
    Vloc, Eloc, Cloc = V // n, E // n, C // n
    Bloc = global_batch // n
    cap = max(1, cfg.route_cap_factor * Bloc // n)
    cap2 = max(1, cfg.route_cap_factor * (cap * cfg.max_deg) // n)
    D = cfg.max_deg

    def local_step(deg, start, dst, eprop, vprop, c_root, c_fp, c_len, c_vals,
                   c_valid, roots, ldprop=None):
        me = jax.lax.axis_index(axes)
        # ---- round 1: route roots to owners --------------------------------
        owner = roots // Vloc
        send, slot1, kept1 = _bucketize(roots, owner, n, cap)
        recv = jax.lax.all_to_all(send, axes, split_axis=0, concat_axis=0, tiled=True)
        q = recv.reshape(-1)  # [n*cap] roots I own (NULL padded)
        qvalid = q >= 0
        local = jnp.clip(q - me * Vloc, 0, Vloc - 1)

        # ---- local cache probe --------------------------------------------
        params = jnp.stack([jnp.full_like(q, cfg.edge_val), jnp.full_like(q, cfg.leaf_val)])
        h = hash_rows([jnp.full_like(q, cfg.tpl_id), q, params[0], params[1]], 0x51ED5EED)
        fp = hash_rows([jnp.full_like(q, cfg.tpl_id), q, params[0], params[1]], 0xF1A9F00D)
        cslot = (h % jnp.uint32(Cloc)).astype(jnp.int32)
        hit = (
            qvalid
            & c_valid[cslot]
            & (c_root[cslot] == q)
            & (c_fp[cslot] == fp)
        ) if use_cache else jnp.zeros_like(qvalid)
        cached_vals = c_vals[cslot]
        cached_len = c_len[cslot]

        # ---- miss execution: local CSR gather + edge filter ----------------
        pos = start[local][:, None] + jnp.arange(D, dtype=jnp.int32)[None, :]
        within = jnp.arange(D)[None, :] < deg[local][:, None]
        pos = jnp.clip(pos, 0, Eloc - 1)
        leaf = dst[pos]  # [n*cap, D] global leaf ids
        e_ok = within & (eprop[pos] == cfg.edge_val) & qvalid[:, None] & ~hit[:, None]

        if ldprop is not None:
            # §Perf: denormalized leaf property rides on the edge record —
            # the remote round-2 fetch disappears entirely.
            l_ok = (ldprop[pos] == cfg.leaf_val) & e_ok
        else:
            # ---- round 2: leaf property fetch at the leaves' owners --------
            lflat = jnp.where(e_ok.reshape(-1), leaf.reshape(-1), -1)
            lowner = jnp.where(lflat >= 0, lflat // Vloc, -1)
            send2, slot2, kept2 = _bucketize(lflat, lowner, n, cap2)
            recv2 = jax.lax.all_to_all(send2, axes, split_axis=0, concat_axis=0, tiled=True)
            rloc = jnp.clip(recv2.reshape(-1) - me * Vloc, 0, Vloc - 1)
            props = jnp.where(recv2.reshape(-1) >= 0, vprop[rloc], NULL_ID)
            back2 = jax.lax.all_to_all(
                props.reshape(n, cap2), axes, split_axis=0, concat_axis=0, tiled=True
            ).reshape(-1)
            leaf_prop = jnp.where(
                kept2, back2[jnp.clip(slot2, 0, n * cap2 - 1)], NULL_ID
            )
            l_ok = ((leaf_prop == cfg.leaf_val) & e_ok.reshape(-1) & kept2).reshape(n * cap, D)

        # dedup + compact executed results to max_leaves with the same
        # sort-based device merge the engine's fused hop pipeline uses
        # (set semantics per Definition 2.1; overflow beyond max_leaves is
        # dropped instead of overwriting the last slot)
        exec_vals, exec_mask = sort_dedup_masked(leaf, l_ok, cfg.max_leaves)

        merged = jnp.where(hit[:, None], cached_vals, exec_vals)
        mlen = jnp.where(hit, cached_len, jnp.sum(exec_mask.astype(jnp.int32), axis=1))
        width = jnp.arange(cfg.max_leaves)[None, :]
        merged = jnp.where(width < mlen[:, None], merged, NULL_ID)

        # ---- route results back to the querying shards ---------------------
        back = jax.lax.all_to_all(
            merged.reshape(n, cap, cfg.max_leaves), axes,
            split_axis=0, concat_axis=0, tiled=True,
        ).reshape(n * cap, cfg.max_leaves)
        results = jnp.where(
            kept1[:, None], back[jnp.clip(slot1, 0, n * cap - 1)], NULL_ID
        )
        stats = dict(
            hits=jax.lax.psum(jnp.sum(hit.astype(jnp.int32)), axes),
            processed=jax.lax.psum(jnp.sum(qvalid.astype(jnp.int32)), axes),
            route_dropped=jax.lax.psum(
                jnp.sum((~kept1).astype(jnp.int32)), axes
            ),
        )
        return results, stats

    spec1 = P(axes)
    denorm = cfg.denormalize_leaf_props
    in_specs = [spec1] * 5 + [spec1, spec1, spec1, P(axes, None), spec1, P(axes)]
    if denorm:
        in_specs.append(spec1)

    sm = shard_map(
        local_step,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(axes, None), dict(hits=P(), processed=P(), route_dropped=P())),
        check_rep=False,
    )

    def step(state, roots):
        args = [
            state["deg"], state["start"], state["dst"], state["eprop"],
            state["vprop"], state["c_root"], state["c_fp"], state["c_len"],
            state["c_vals"], state["c_valid"], roots,
        ]
        if denorm:
            args.append(state["ldprop"])
        return sm(*args)

    return step
