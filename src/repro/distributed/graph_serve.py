"""Distributed graph-query serving — the paper's production architecture
mapped onto a TPU mesh with shard_map.

Two tiers live here:

``ShardedTxnRuntime`` — the sharded instantiation of the shared transaction
runtime (``repro.core.runtime``). Vertex *ownership* is range-partitioned
over the mesh (shard s owns vertex slots [s*Vloc, (s+1)*Vloc)) and the
one-hop result cache is **co-partitioned with it**: the cache shard for a
key lives on the shard owning the key's root vertex, so a probe is always
local to the owner. The storage tier is a replicated read snapshot per
shard (the FDB-storage-replica analogue); a gRW-Tx commit applies the
mutation batch to every replica identically inside the same jitted step.

- gR-Txs (``serve_step`` / ``run_gr_tx_batch``): arbitrary multi-hop
  ``QueryPlan``s — not just the single SQ1 template shape — execute the PR 2
  fused probe→miss-exec→frontier-merge pipeline *inside* ``shard_map``. Per
  hop, frontier roots are routed to their owner shards (all_to_all), the
  owner runs the shared hop kernel (local cache probe + ``lax.cond``-gated
  miss execution), and the left-packed results route back to the querying
  shard for the on-device ``segmented_dedup_merge``. Results, per-hop miss
  arrays, and psum'd metrics come back in one device→host transfer,
  byte-identical to the single-host fused engine.

- gRW-Txs (``run_grw_tx``): the write path is sharded in two phases inside
  one jitted step. Phase A round-robins the mutation batch's change sections
  across shards (``shard_mutation_rows``) and runs the mutation listener
  (Algorithms 1–9) as *op derivation* (``derive_cache_ops``) — each shard
  reverse-traverses only its slice. The resulting impacted-key op stream is
  compacted (only real ops survive, unlike the single-host path which
  probes every masked lane) and routed to the shards owning the roots,
  which apply it against their local cache shard — batched for write-around
  (deletes commute), order-restored sequential for write-through. Root
  sweeps are all_gathered and applied locally. Store and cache post-states
  are logically identical to the single-host commit.

- CP population: ``populator()`` returns the standard ``CachePopulator``
  wired with a shard_map step that inserts each entry at its owner shard.

Every routing round reports an **overflow count** (valid items dropped
because a peer bucket or op-stream capacity filled up) in the step metrics;
an overflow means silently degraded results/maintenance and should alarm.

``build_serve_step`` below is the original fixed-template (SQ1-shape)
serving cell, kept for the capacity-planning/roofline tooling and as the
collective-cost reference; new code should target ``ShardedTxnRuntime``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.cache import CacheState, empty_cache
from repro.core.invalidation import (
    CacheOpStream,
    SweepStream,
    apply_op_stream,
    apply_op_stream_batched,
    apply_sweeps,
    derive_cache_ops,
)
from repro.core.runtime import (
    bucket_for,
    bucketize,
    compact_rows,
    decode_miss_records,
    finalize_frontier,
    make_hop_kernel,
    pad_roots,
    route_plan,
    route_scatter,
    FINAL_VALUES,
)
from repro.graphstore.mutations import apply_mutations, shard_mutation_rows
from repro.utils import NULL_ID, hash_rows, segmented_dedup_merge, sort_dedup_masked

_STAT_FIELDS = ("n_hit", "n_miss", "n_insert", "n_evict", "n_delete", "n_oversize")
_ADDITIVE_METRICS = (
    "requests", "hits", "misses", "truncated", "leaf_fetches",
    "edges_scanned", "cache_reads", "route_overflow",
)


def _plan_key(plan):
    """Structural hash key for a QueryPlan: equal-but-distinct plan objects
    (hops hold numpy params, so plans aren't hashable) share one compiled
    serve step instead of re-tracing per object identity."""
    def pred(p):
        return tuple(np.asarray(getattr(p, f)).tobytes() for f in p._fields)

    hops = tuple(
        (h.direction, h.edge_label, h.tpl_idx,
         np.asarray(h.params, np.int32).tobytes(),
         pred(h.pr), pred(h.pe), pred(h.pl))
        for h in plan.hops
    )
    return (hops, plan.final, plan.final_prop, plan.post_filter, plan.extra_phases)


def _replicate_stats(before: CacheState, after: CacheState, axes):
    """Rebuild the cache's 0-d stats counters as replicated global values:
    input stats are replicated, so each shard adds the psum of all local
    deltas — every shard then stores the same global counter."""
    reps = {}
    for f in _STAT_FIELDS:
        b, a = getattr(before, f), getattr(after, f)
        reps[f] = b + jax.lax.psum(a - b, axes)
    return after._replace(**reps)


class ShardedTxnRuntime:
    """One transaction runtime spread over a device mesh.

    ``espec`` is the *global* spec: ``espec.cache.capacity`` is the fleet
    cache capacity, sharded into ``n`` co-partitioned blocks of
    ``capacity // n`` slots (each a power of two); ``espec.store.v_cap``
    range-partitions vertex ownership. On a 1-device mesh every collective
    degenerates and the runtime is the single-host engine.

    ``route_cap_factor`` / ``ops_route_cap`` bound per-peer routing buckets;
    ``None`` sizes them for the worst case (no overflow possible). Smaller
    values trade memory/traffic for a nonzero ``route_overflow`` risk,
    which the step metrics surface.
    """

    def __init__(self, espec, mesh: Mesh, *, use_cache: bool = True,
                 route_cap_factor: int | None = None,
                 ops_cap: int = 4096, sweep_cap: int = 512,
                 ops_route_cap: int | None = None):
        self.axes = tuple(mesh.axis_names)
        self.n = int(np.prod([mesh.shape[a] for a in self.axes]))
        n = self.n
        assert n & (n - 1) == 0, "shard count must be a power of two"
        C = espec.cache.capacity
        Cloc = C // n
        assert C % n == 0 and Cloc & (Cloc - 1) == 0, (
            "global cache capacity must shard into power-of-two blocks"
        )
        assert espec.store.v_cap % n == 0, "v_cap must divide over shards"
        self.mesh = mesh
        self.espec = espec
        self.lspec = espec._replace(cache=espec.cache._replace(capacity=Cloc))
        self.Vloc = espec.store.v_cap // n
        self.use_cache = use_cache
        self.route_cap_factor = route_cap_factor
        self.ops_cap = ops_cap
        self.sweep_cap = sweep_cap
        self.ops_route_cap = ops_route_cap if ops_route_cap is not None else ops_cap
        self._gr_fns: dict = {}
        self._grw_fns: dict = {}
        self._pop_fns: dict = {}

    # ------------------------------------------------------------ sharding
    def cache_sharding(self):
        s1 = NamedSharding(self.mesh, P(self.axes))
        s2 = NamedSharding(self.mesh, P(self.axes, None))
        s0 = NamedSharding(self.mesh, P())
        return CacheState(
            tpl=s1, root=s1, fp=s1, chunk=s1, total_len=s1, vals=s2,
            version=s1, valid=s1,
            n_hit=s0, n_miss=s0, n_insert=s0, n_evict=s0, n_delete=s0,
            n_oversize=s0,
        )

    def _cache_specs(self):
        a = self.axes
        return CacheState(
            tpl=P(a), root=P(a), fp=P(a), chunk=P(a), total_len=P(a),
            vals=P(a, None), version=P(a), valid=P(a),
            n_hit=P(), n_miss=P(), n_insert=P(), n_evict=P(), n_delete=P(),
            n_oversize=P(),
        )

    def empty_cache(self) -> CacheState:
        """Global-capacity empty cache, device_put over the mesh: block s of
        every slot array is shard s's local cache (all blocks empty)."""
        return jax.device_put(empty_cache(self.espec.cache), self.cache_sharding())

    def shard_cache(self, cache: CacheState) -> CacheState:
        """Lay an existing global CacheState out over the mesh. Note the
        slot *layout* is reinterpreted (each block probes with the local
        capacity), so only caches whose entries were inserted through this
        runtime probe correctly — use ``empty_cache`` + population for new
        state."""
        return jax.device_put(cache, self.cache_sharding())

    # --------------------------------------------------------- gR-Tx path
    def _hop_route_caps(self, plan, Bloc):
        """Per-hop per-peer routing capacity (worst case unless bounded)."""
        caps, A = [], 1
        F, RW = self.espec.frontier, self.espec.result_width
        for _ in plan.hops:
            rows = Bloc * A
            if self.route_cap_factor is None:
                caps.append(max(1, rows))
            else:
                caps.append(max(1, -(-self.route_cap_factor * rows // self.n)))
            A = min(F, A * RW)
        return caps

    def _gr(self, plan, bucket: int):
        key = (_plan_key(plan), bucket)
        if key not in self._gr_fns:
            espec, n, axes, Vloc = self.lspec, self.n, self.axes, self.Vloc
            F, RW = espec.frontier, espec.result_width
            use_cache = self.use_cache
            assert bucket % n == 0, "global batch bucket must divide over shards"
            Bloc = bucket // n
            caps = self._hop_route_caps(plan, Bloc)
            kernels = [make_hop_kernel(espec, hop, use_cache) for hop in plan.hops]

            # NOTE: the metric bookkeeping below mirrors
            # runtime.make_fused_plan_fn line for line (with psums where the
            # single host reads a batch-global quantity); the byte-identity
            # tests pin the two together, so change them in lockstep.
            def local_step(store, cache, ttable, roots, bvalid):
                frontier = jnp.full((Bloc, F), NULL_ID, jnp.int32).at[:, 0].set(roots)
                fmask = jnp.zeros((Bloc, F), bool).at[:, 0].set(bvalid)
                z = jnp.int32(0)
                m = {
                    "phases": jnp.int32(1),  # root index lookup (request 1)
                    "requests": jnp.sum(bvalid.astype(jnp.int32)),
                    "hits": z, "misses": z, "truncated": z,
                    "leaf_fetches": z, "edges_scanned": z, "cache_reads": z,
                    "route_overflow": z,
                }
                miss_roots, miss_counts = [], []
                A = 1
                for hop, kernel, cap in zip(plan.hops, kernels, caps):
                    roots_flat = frontier[:, :A].reshape(-1)
                    rmask_flat = fmask[:, :A].reshape(-1)
                    # ---- route frontier roots to their owner shards ----
                    # ownership clamps to the last shard for ids past v_cap,
                    # so even an out-of-range root is processed (and comes
                    # back empty) exactly like on the single host; negative
                    # ids are indistinguishable from frontier padding
                    rvals = jnp.where(rmask_flat, roots_flat, NULL_ID)
                    owner = jnp.where(
                        rmask_flat & (roots_flat >= 0),
                        jnp.clip(roots_flat // Vloc, 0, n - 1), -1,
                    )
                    send, slot, kept, ovf = bucketize(rvals, owner, n, cap)
                    m["route_overflow"] = m["route_overflow"] + ovf
                    recv = jax.lax.all_to_all(
                        send, axes, split_axis=0, concat_axis=0, tiled=True
                    )
                    q = recv.reshape(-1)  # [n*cap] roots I own (NULL padded)
                    qmask = q != NULL_ID
                    # ---- owner-local probe + cond-gated miss execution ----
                    vals, cnt, mr, nrec, hs = kernel(store, cache, ttable, q, qmask)
                    cacheable = hop.tpl_idx >= 0 and use_cache
                    if cacheable:
                        m["phases"] = m["phases"] + 1  # one cache get round-trip
                        m["requests"] = m["requests"] + hs["n_read"]
                        m["cache_reads"] = m["cache_reads"] + hs["n_read"]
                        m["hits"] = m["hits"] + hs["hits"]
                        miss_roots.append(mr)
                        miss_counts.append(nrec[None])
                    # phases are structural (identical on every shard), so
                    # the miss gate uses the *global* miss count
                    k_g = jax.lax.psum(hs["k"], axes)
                    m["phases"] = m["phases"] + 2 * (k_g > 0)
                    m["requests"] = m["requests"] + hs["k"] + hs["leaves"]
                    m["leaf_fetches"] = m["leaf_fetches"] + hs["leaves"]
                    m["edges_scanned"] = m["edges_scanned"] + hs["edges"]
                    m["misses"] = m["misses"] + hs["k"]
                    m["truncated"] = m["truncated"] + hs["trunc"]
                    # ---- route the left-packed results home ----
                    back_v = jax.lax.all_to_all(
                        vals.reshape(n, cap, RW), axes,
                        split_axis=0, concat_axis=0, tiled=True,
                    ).reshape(n * cap, RW)
                    back_c = jax.lax.all_to_all(
                        cnt.reshape(n, cap), axes,
                        split_axis=0, concat_axis=0, tiled=True,
                    ).reshape(-1)
                    sl = jnp.clip(slot, 0, n * cap - 1)
                    vals_home = jnp.where(kept[:, None], back_v[sl], NULL_ID)
                    cnt_home = jnp.where(kept, back_c[sl], 0)
                    # ---- home-shard frontier merge (identical to 1-host) ----
                    frontier, fmask = segmented_dedup_merge(
                        vals_home.reshape(Bloc, A, RW), cnt_home.reshape(Bloc, A), F
                    )
                    A = min(F, A * RW)

                result = finalize_frontier(plan, store, roots, frontier, fmask)
                if plan.post_filter is not None and plan.post_filter[0] != "id_neq":
                    m["phases"] = m["phases"] + 1  # un-rewritten property fetch
                    m["requests"] = m["requests"] + jnp.sum(fmask.astype(jnp.int32))
                if plan.final == FINAL_VALUES:
                    m["phases"] = m["phases"] + 1  # valueMap fetch
                    m["requests"] = m["requests"] + jnp.sum(fmask.astype(jnp.int32))
                m["phases"] = m["phases"] + plan.extra_phases
                for key_ in _ADDITIVE_METRICS:
                    m[key_] = jax.lax.psum(m[key_], axes)
                return (
                    result, tuple(miss_roots), tuple(miss_counts), m,
                    store.version,
                )

            sm = shard_map(
                local_step,
                mesh=self.mesh,
                in_specs=(P(), self._cache_specs(), P(), P(self.axes), P(self.axes)),
                out_specs=(P(self.axes), P(self.axes), P(self.axes), P(), P()),
                check_rep=False,
            )
            self._gr_fns[key] = jax.jit(sm)
        return self._gr_fns[key]

    def serve_step(self, plan, global_batch: int):
        """The jitted serving step for one ``QueryPlan`` (any hop count) —
        ``step(store, cache, ttable, roots [global_batch], bvalid) ->
        (results, miss_roots, miss_counts, metrics, read_version)``."""
        return self._gr(plan, global_batch)

    def run_gr_tx_batch(self, store, cache, ttable, plan, roots):
        """Host wrapper: pad, execute, decode misses. Same contract as
        ``GraphEngine.run`` — one blocking device→host transfer."""
        B = len(roots)
        bucket = max(bucket_for(B), self.n)
        proots, bvalid = pad_roots(roots, bucket)
        out = self._gr(plan, bucket)(
            store, cache, ttable, jnp.asarray(proots), jnp.asarray(bvalid)
        )
        result, miss_roots, miss_counts, m, version = jax.device_get(out)
        metrics = {k: int(v) for k, v in m.items()}
        metrics["host_syncs"] = 1
        misses = decode_miss_records(
            plan, self.use_cache, miss_roots, miss_counts, int(version)
        )
        return np.asarray(result)[:B], misses, metrics

    # -------------------------------------------------------- gRW-Tx path
    def _grw(self, policy: str):
        if policy not in self._grw_fns:
            espec, lcspec = self.espec, self.lspec.cache
            n, axes, Vloc = self.n, self.axes, self.Vloc
            through = policy != "write-around"
            ops_cap, sweep_cap = self.ops_cap, self.sweep_cap
            ops_route_cap = self.ops_route_cap

            def local_grw(store, cache, ttable, batch):
                me = jax.lax.axis_index(axes)
                # every replica applies the same commit (deterministic)
                store2, applied = apply_mutations(espec.store, store, batch)
                # phase A: derive impacted keys from this shard's slice of
                # the mutation batch (round-robin rows)
                part = shard_mutation_rows(applied, n, me)
                ops, sweeps = derive_cache_ops(
                    espec, store, store2, ttable, part, through=through,
                    row_offset=me, row_stride=n,
                )
                # compact: only real ops are routed/applied — the single-host
                # path instead probes every masked lane of the stream
                (okind, otpl, oroot, oparams, ovid, oorder), _, ovf_c = compact_rows(
                    ops.ok, ops_cap,
                    (ops.kind, ops.tpl, ops.root, ops.params, ops.vid, ops.order),
                    (0, -1, NULL_ID, 0, NULL_ID, 0),
                )
                # phase B: route each op to the shard owning its root, whose
                # local cache block holds the impacted entry
                dest = jnp.where(
                    oroot != NULL_ID, jnp.clip(oroot // Vloc, 0, n - 1), -1
                )
                slot, kept, ovf_r = route_plan(dest, n, ops_route_cap)

                def a2a(x, fill):
                    return jax.lax.all_to_all(
                        route_scatter(x, slot, n, ops_route_cap, fill), axes,
                        split_axis=0, concat_axis=0, tiled=True,
                    ).reshape((n * ops_route_cap,) + x.shape[1:])

                rroot = a2a(oroot, NULL_ID)
                rops = CacheOpStream(
                    kind=a2a(okind, 0), tpl=a2a(otpl, -1), root=rroot,
                    params=a2a(oparams, 0), vid=a2a(ovid, NULL_ID),
                    order=a2a(oorder, 0), ok=rroot != NULL_ID,
                )
                # sweeps: tiny stream; share globally, apply to the local
                # block (a sweep is a mask over the whole shard)
                (stpl, sroot), _, ovf_s = compact_rows(
                    sweeps.ok, sweep_cap, (sweeps.tpl, sweeps.root), (-1, NULL_ID)
                )
                g = jax.lax.all_gather(
                    jnp.stack([stpl, sroot], axis=1), axes, axis=0, tiled=True
                )
                gsw = SweepStream(tpl=g[:, 0], root=g[:, 1], ok=g[:, 1] != NULL_ID)

                # impacted counts *distinct logical keys removed*: chunk-0
                # occupancy delta. Counting raw ops would over-count a key
                # hit by several routed ops (the single-host sequential call
                # sites see it already gone), and counting all slots would
                # over-count multi-chunk chains.
                head = lambda c: jnp.sum((c.valid & (c.chunk == 0)).astype(jnp.int32))
                occ0 = head(cache)
                cache2 = apply_sweeps(lcspec, cache, gsw)
                if through:
                    # value edits are order-sensitive: sorted sequential walk
                    cache2 = apply_op_stream(lcspec, cache2, rops)
                else:
                    # deletes commute: one batched pass
                    cache2 = apply_op_stream_batched(lcspec, cache2, rops)
                occ_delta = occ0 - head(cache2)
                cache2 = cache2._replace(n_delete=cache.n_delete + occ_delta)
                impacted = jax.lax.psum(occ_delta, axes)
                cache2 = _replicate_stats(cache, cache2, axes)
                overflow = jax.lax.psum(ovf_c + ovf_r + ovf_s, axes)
                return store2, cache2, impacted, overflow

            sm = shard_map(
                local_grw,
                mesh=self.mesh,
                in_specs=(P(), self._cache_specs(), P(), P()),
                out_specs=(P(), self._cache_specs(), P(), P()),
                check_rep=False,
            )
            self._grw_fns[policy] = jax.jit(sm)
        return self._grw_fns[policy]

    def grw_step(self, policy: str = "write-around"):
        """The jitted sharded gRW-Tx commit (cached per policy):
        ``step(store, cache, ttable, batch) -> (store', cache', impacted,
        route_overflow)``."""
        return self._grw(policy)

    def run_grw_tx(self, store, cache, ttable, batch, policy: str = "write-around"):
        """Host wrapper mirroring ``repro.core.engine.run_grw_tx``."""
        store2, cache2, impacted, overflow = self._grw(policy)(
            store, cache, ttable, batch
        )
        return store2, cache2, {
            "impacted_keys": int(impacted), "op_overflow": int(overflow),
        }

    # ------------------------------------------------------ CP population
    def populator(self, templates_meta, max_retries: int = 3):
        """A ``CachePopulator`` whose CP transactions insert each entry at
        its owner shard (inside shard_map), draining the same MissQueue."""
        from repro.core.population import CachePopulator

        return CachePopulator(
            self.espec, templates_meta, max_retries=max_retries,
            step_builder=functools.partial(self._pop, templates_meta),
        )

    def _pop(self, templates_meta, tpl_idx: int, bucket: int):
        key = (tpl_idx, bucket)
        if key not in self._pop_fns:
            from repro.core.population import populate_step

            lspec, n, axes, Vloc = self.lspec, self.n, self.axes, self.Vloc
            direction, edge_label = templates_meta[tpl_idx]

            def local_pop(store_exec, store_commit, cache, ttable, roots,
                          params, mask, read_versions):
                me = jax.lax.axis_index(axes)
                owned = mask & (roots >= 0) & (
                    jnp.clip(roots // Vloc, 0, n - 1) == me
                )
                cache2, ok, ab = populate_step(
                    lspec, store_exec, store_commit, cache, ttable, tpl_idx,
                    direction, edge_label, roots, params, owned, read_versions,
                )
                ok = jax.lax.psum(ok.astype(jnp.int32), axes) > 0
                ab = jax.lax.psum(ab.astype(jnp.int32), axes) > 0
                cache2 = _replicate_stats(cache, cache2, axes)
                return cache2, ok, ab

            sm = shard_map(
                local_pop,
                mesh=self.mesh,
                in_specs=(P(), P(), self._cache_specs(), P(), P(), P(), P(), P()),
                out_specs=(self._cache_specs(), P(), P()),
                check_rep=False,
            )
            jitted = jax.jit(sm)

            # shard_map's wrapper is positional-only; CachePopulator.drain
            # calls its step with keyword arguments, so keep this adapter
            def step(store_exec, store_commit, cache, ttable, roots, params,
                     mask, read_versions):
                return jitted(
                    store_exec, store_commit, cache, ttable, roots, params,
                    mask, read_versions,
                )

            self._pop_fns[key] = step
        return self._pop_fns[key]


# ======================================================================
# The original fixed-template serving cell (paper's SQ1 shape), kept for
# capacity planning, the roofline dry-runs, and as the collective-cost
# reference. New serving code should target ``ShardedTxnRuntime``.
# ======================================================================


@dataclass(frozen=True)
class GraphServeConfig:
    name: str = "ecommerce-graph"
    v_total: int = 2**30  # ~1.1B vertices (tens of billions of edges)
    e_per_vertex: int = 8  # average degree for capacity planning
    n_vprops: int = 2
    n_eprops: int = 1
    max_deg: int = 64  # per-hop gather window
    max_leaves: int = 64  # cache value width
    cache_slots_total: int = 2**26  # cache capacity across the fleet
    route_cap_factor: int = 4  # per-peer routing capacity multiplier
    # the served template instance (Figure 1): edge prop0 == 1, leaf prop0 == 0
    edge_prop: int = 0
    edge_val: int = 1
    leaf_prop: int = 0
    leaf_val: int = 0
    tpl_id: int = 1
    # §Perf (paper-arch cell): denormalize the leaf predicate property onto
    # the edge record (JanusGraph vertex-centric-index style). Eliminates
    # the entire round-2 remote leaf fetch (all_to_all #2/#3 and the remote
    # vprop reads) at the cost of write amplification: a leaf-prop gRW-Tx
    # must update every in-edge copy (bounded by the leaf's in-degree; the
    # same L factor as Table 2's DeleteKeysForLeaf).
    denormalize_leaf_props: bool = False

    def e_total(self) -> int:
        return self.v_total * self.e_per_vertex


def abstract_state(cfg: GraphServeConfig, n_shards: int):
    """ShapeDtypeStructs for the sharded store + cache (dry-run inputs)."""
    V, E, C = cfg.v_total, cfg.e_total(), cfg.cache_slots_total
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    out_extra = {"ldprop": sds((E,), i32)} if cfg.denormalize_leaf_props else {}
    return dict(
        deg=sds((V,), i32),
        start=sds((V,), i32),  # local offset into the owner's edge block
        dst=sds((E,), i32),
        eprop=sds((E,), i32),  # the predicate property (IsActive)
        vprop=sds((V,), i32),  # the leaf predicate property (Status)
        **out_extra,
        c_root=sds((C,), i32),
        c_fp=sds((C,), jnp.uint32),
        c_len=sds((C,), i32),
        c_vals=sds((C, cfg.max_leaves), i32),
        c_valid=sds((C,), jnp.bool_),
    )


def state_shardings(cfg: GraphServeConfig, mesh: Mesh):
    axes = tuple(mesh.axis_names)
    s1 = NamedSharding(mesh, P(axes))
    extra = {"ldprop": s1} if cfg.denormalize_leaf_props else {}
    return dict(
        deg=s1, start=s1, dst=s1, eprop=s1, vprop=s1,
        c_root=s1, c_fp=s1, c_len=s1,
        c_vals=NamedSharding(mesh, P(axes, None)),
        c_valid=s1, **extra,
    )


def build_serve_step(cfg: GraphServeConfig, mesh: Mesh, *, use_cache: bool = True,
                     global_batch: int = 8192):
    """Returns a jit-able ``step(state_dict, roots) -> (results, stats)``.

    roots: int32 [global_batch] sharded over all axes; results
    [global_batch, max_leaves] (NULL_ID padded). ``stats["route_overflow"]``
    counts valid items silently dropped by a full routing bucket in either
    round — nonzero means degraded results and should alarm.
    """
    axes = tuple(mesh.axis_names)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    V, E, C = cfg.v_total, cfg.e_total(), cfg.cache_slots_total
    assert V % n == 0 and E % n == 0 and C % n == 0 and global_batch % n == 0
    Vloc, Eloc, Cloc = V // n, E // n, C // n
    Bloc = global_batch // n
    cap = max(1, cfg.route_cap_factor * Bloc // n)
    cap2 = max(1, cfg.route_cap_factor * (cap * cfg.max_deg) // n)
    D = cfg.max_deg

    def local_step(deg, start, dst, eprop, vprop, c_root, c_fp, c_len, c_vals,
                   c_valid, roots, ldprop=None):
        me = jax.lax.axis_index(axes)
        # ---- round 1: route roots to owners --------------------------------
        owner = roots // Vloc
        send, slot1, kept1, ovf1 = bucketize(roots, owner, n, cap)
        recv = jax.lax.all_to_all(send, axes, split_axis=0, concat_axis=0, tiled=True)
        q = recv.reshape(-1)  # [n*cap] roots I own (NULL padded)
        qvalid = q >= 0
        local = jnp.clip(q - me * Vloc, 0, Vloc - 1)

        # ---- local cache probe --------------------------------------------
        params = jnp.stack([jnp.full_like(q, cfg.edge_val), jnp.full_like(q, cfg.leaf_val)])
        h = hash_rows([jnp.full_like(q, cfg.tpl_id), q, params[0], params[1]], 0x51ED5EED)
        fp = hash_rows([jnp.full_like(q, cfg.tpl_id), q, params[0], params[1]], 0xF1A9F00D)
        cslot = (h % jnp.uint32(Cloc)).astype(jnp.int32)
        hit = (
            qvalid
            & c_valid[cslot]
            & (c_root[cslot] == q)
            & (c_fp[cslot] == fp)
        ) if use_cache else jnp.zeros_like(qvalid)
        cached_vals = c_vals[cslot]
        cached_len = c_len[cslot]

        # ---- miss execution: local CSR gather + edge filter ----------------
        pos = start[local][:, None] + jnp.arange(D, dtype=jnp.int32)[None, :]
        within = jnp.arange(D)[None, :] < deg[local][:, None]
        pos = jnp.clip(pos, 0, Eloc - 1)
        leaf = dst[pos]  # [n*cap, D] global leaf ids
        e_ok = within & (eprop[pos] == cfg.edge_val) & qvalid[:, None] & ~hit[:, None]

        ovf2 = jnp.int32(0)
        if ldprop is not None:
            # §Perf: denormalized leaf property rides on the edge record —
            # the remote round-2 fetch disappears entirely.
            l_ok = (ldprop[pos] == cfg.leaf_val) & e_ok
        else:
            # ---- round 2: leaf property fetch at the leaves' owners --------
            lflat = jnp.where(e_ok.reshape(-1), leaf.reshape(-1), -1)
            lowner = jnp.where(lflat >= 0, lflat // Vloc, -1)
            send2, slot2, kept2, ovf2 = bucketize(lflat, lowner, n, cap2)
            recv2 = jax.lax.all_to_all(send2, axes, split_axis=0, concat_axis=0, tiled=True)
            rloc = jnp.clip(recv2.reshape(-1) - me * Vloc, 0, Vloc - 1)
            props = jnp.where(recv2.reshape(-1) >= 0, vprop[rloc], NULL_ID)
            back2 = jax.lax.all_to_all(
                props.reshape(n, cap2), axes, split_axis=0, concat_axis=0, tiled=True
            ).reshape(-1)
            leaf_prop = jnp.where(
                kept2, back2[jnp.clip(slot2, 0, n * cap2 - 1)], NULL_ID
            )
            l_ok = ((leaf_prop == cfg.leaf_val) & e_ok.reshape(-1) & kept2).reshape(n * cap, D)

        # dedup + compact executed results to max_leaves with the same
        # sort-based device merge the engine's fused hop pipeline uses
        # (set semantics per Definition 2.1; overflow beyond max_leaves is
        # dropped instead of overwriting the last slot)
        exec_vals, exec_mask = sort_dedup_masked(leaf, l_ok, cfg.max_leaves)

        merged = jnp.where(hit[:, None], cached_vals, exec_vals)
        mlen = jnp.where(hit, cached_len, jnp.sum(exec_mask.astype(jnp.int32), axis=1))
        width = jnp.arange(cfg.max_leaves)[None, :]
        merged = jnp.where(width < mlen[:, None], merged, NULL_ID)

        # ---- route results back to the querying shards ---------------------
        back = jax.lax.all_to_all(
            merged.reshape(n, cap, cfg.max_leaves), axes,
            split_axis=0, concat_axis=0, tiled=True,
        ).reshape(n * cap, cfg.max_leaves)
        results = jnp.where(
            kept1[:, None], back[jnp.clip(slot1, 0, n * cap - 1)], NULL_ID
        )
        stats = dict(
            hits=jax.lax.psum(jnp.sum(hit.astype(jnp.int32)), axes),
            processed=jax.lax.psum(jnp.sum(qvalid.astype(jnp.int32)), axes),
            route_dropped=jax.lax.psum(
                jnp.sum((~kept1).astype(jnp.int32)), axes
            ),
            route_overflow=jax.lax.psum(ovf1 + ovf2, axes),
        )
        return results, stats

    spec1 = P(axes)
    denorm = cfg.denormalize_leaf_props
    in_specs = [spec1] * 5 + [spec1, spec1, spec1, P(axes, None), spec1, P(axes)]
    if denorm:
        in_specs.append(spec1)

    sm = shard_map(
        local_step,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(
            P(axes, None),
            dict(hits=P(), processed=P(), route_dropped=P(), route_overflow=P()),
        ),
        check_rep=False,
    )

    def step(state, roots):
        args = [
            state["deg"], state["start"], state["dst"], state["eprop"],
            state["vprop"], state["c_root"], state["c_fp"], state["c_len"],
            state["c_vals"], state["c_valid"], roots,
        ]
        if denorm:
            args.append(state["ldprop"])
        return sm(*args)

    return step
