"""Live shard failover: per-batch failure detection, degraded-mode
serving, and recovery-as-migration for the partitioned serve loop.

``FailoverController`` is the thin state machine between the serve loop
and the runtime. Healthy operation adds one branch per batch; under an
injected (or real) owner loss it degrades instead of failing:

- **detect** — each batch probes every owner (``ShardFaultPlan`` scripts
  the outcomes in chaos runs); ``FailureDetector`` turns consecutive
  failures into a ``down`` set. Until detection trips, a gR batch that
  needs the dead owner raises ``NodeFailure`` — those batches ARE the
  unavailability window, and it is bounded by ``fail_threshold`` probes.
- **degrade (reads)** — with the owner marked down, gR executes with the
  down shard's miss segments masked (the ``down`` input of the serving
  step): cache hits — including hits on the dead owner's data, served at
  the *caching* shard per Smart Query Routing's decoupling — and
  surviving-owner misses return normally; masked rows come back flagged
  ``deferred``. Deferred rows emit no miss records, so the cache
  populator cannot manufacture entries from lost blocks.
- **degrade (writes)** — every gRW commit queues in the journal
  (``applied=False``) instead of applying. All of them, not just those
  naming the dead owner: commit id assignment (``e_len + i``) makes
  commits order-dependent, so applying a "safe" commit out of order would
  diverge from the journal's replay order. The staleness of degraded
  reads is therefore bounded by the queued-commit count, which the
  controller surfaces per batch.
- **recover** — ``replay_to_owner`` rebuilds the dead owner's blocks from
  the incremental-checkpoint chain + journal (byte-identical pre-outage
  state), splices them into the live store via the geid index, then
  ``drain_queued`` applies the outage window's commits in journal order
  against the live cache. ``mark_recovered`` + ``revive`` close the loop.

Stragglers (alive but slow) never enter degraded mode: the detector marks
them ``straggling`` and the read path hedges — the full batch races a
degraded call with the straggler's segment masked (``HedgedCalls``), so
tail latency is bounded by the hedge, and the fast path still returns
complete results when the straggler recovers mid-race.
"""

from __future__ import annotations

import time
from typing import Optional

import numpy as np

from repro.distributed.fault import (
    FailureDetector,
    HedgedCalls,
    NodeFailure,
    ShardFaultPlan,
)
from repro.graphstore.journal import (
    WriteBehindJournal,
    drain_queued,
    replay_to_owner,
)


class FailoverController:
    """Per-batch failover state machine over a ``ShardedTxnRuntime``.

    ``plan`` scripts faults for chaos runs (None = probe outcomes are all
    healthy and the controller is pass-through); ``hedge_after`` is the
    straggler hedge deadline in seconds."""

    def __init__(self, rt, journal: WriteBehindJournal, ttable, *,
                 plan: Optional[ShardFaultPlan] = None,
                 detector: Optional[FailureDetector] = None,
                 hedge: Optional[HedgedCalls] = None,
                 hedge_after: float = 0.05,
                 default_policy: str = "write-around",
                 rhost=None):
        self.rt = rt
        self.journal = journal
        self.ttable = ttable
        self.plan = plan
        self.detector = detector if detector is not None else FailureDetector(n=rt.n)
        self.hedge = hedge
        self.hedge_after = hedge_after
        self.default_policy = default_policy
        # the routing-table host mirror (stateful routing tier): explicit,
        # else whatever the runtime has attached — every read/write/recover
        # below threads it so failover composes with migrated placements
        self.rhost = rhost if rhost is not None else getattr(rt, "rhost", None)
        self.failed_batches = 0  # raised NodeFailure pre-detection
        self.degraded_batches = 0
        self.deferred_rows = 0

    # ---------------------------------------------------------------- probe
    def probe(self, batch_idx: int) -> frozenset:
        """One heartbeat round: feed every owner's scripted (or real) probe
        outcome to the detector; returns the post-probe down set.

        With a ``ShardFaultPlan`` the outcomes are scripted (chaos runs);
        without one the heartbeat is the runtime's MEASURED latest step
        latency — per-owner work-attributed when the telemetry tier ran
        (``rt.last_step_owner_seconds``, so one straggling owner trips
        ``straggle_after`` alone), falling back to the collective step
        wall-clock (``rt.last_step_seconds``) fed to every owner when
        attribution is unavailable."""
        if self.plan is None:
            self.detector.observe_step(
                float(getattr(self.rt, "last_step_seconds", 0.0)),
                per_owner=getattr(self.rt, "last_step_owner_seconds", None),
            )
            return self.detector.down()
        crashed = self.plan.crashed_at(batch_idx)
        for s in range(self.rt.n):
            if s in crashed:
                self.detector.observe_failure(s)
            else:
                self.detector.observe_ok(
                    s, latency_s=self.plan.hang_delay(s, batch_idx)
                )
        return self.detector.down()

    # ----------------------------------------------------------------- read
    def run_gr(self, pstore, cache, qplan, roots, batch_idx: int):
        """Serve one gR batch under the current failure state.

        Returns ``(results, deferred, misses, metrics)``. Raises
        ``NodeFailure`` when a crashed owner is needed but not yet marked
        down (the detection gap — callers count it as unavailability)."""
        crashed = (self.plan.crashed_at(batch_idx) if self.plan is not None
                   else frozenset())
        down = self.detector.down()
        unmasked = crashed - down
        if unmasked:
            self.failed_batches += 1
            raise NodeFailure(
                f"owners {sorted(unmasked)} lost storage and are not yet "
                f"marked down (batch {batch_idx})"
            )
        epochs = self.journal.epochs
        mask = self.detector.down_mask()
        straggling = self.detector.straggling() - down

        def call(m):
            with epochs.pin_scope():
                return self.rt.run_gr_tx_batch(
                    pstore, cache, self.ttable, qplan, roots,
                    down=m if m.any() else None, rtable=self.rhost,
                    return_deferred=True,
                )

        from_hedge = False
        if straggling and self.hedge is not None:
            # primary: the full batch, paying the straggler's delay;
            # hedge: the degraded batch with the straggler's segment masked
            delay = max(
                self.plan.hang_delay(s, batch_idx) for s in straggling
            ) if self.plan is not None else 0.0
            hmask = mask.copy()
            for s in straggling:
                hmask[s] = True

            def primary():
                if delay:
                    time.sleep(delay)
                return call(mask)

            out, from_hedge = self.hedge.call(
                primary, lambda: call(hmask), self.hedge_after
            )
        else:
            out = call(mask)
        result, misses, metrics, deferred = out
        ndef = int(np.asarray(deferred).sum())
        self.deferred_rows += ndef
        if mask.any():
            self.degraded_batches += 1
        metrics = dict(metrics)
        metrics.update(
            deferred_rows=ndef,
            hedged=int(from_hedge),
            staleness_bound_commits=self.journal.metrics()["queued_commits"],
        )
        return result, np.asarray(deferred), misses, metrics

    # ---------------------------------------------------------------- write
    def run_grw(self, pstore, cache, batch, *, policy: Optional[str] = None,
                gate=None, occupancy_metrics: bool = True):
        """Commit one gRW batch — or queue it durably when degraded.

        During an outage the batch is journaled with ``applied=False`` and
        the device store is left untouched (see module docstring for why
        ALL commits queue); otherwise this is the normal journaled commit.
        Returns ``(pstore, cache, metrics)`` either way."""
        policy = self.default_policy if policy is None else policy
        if self.detector.down():
            self.journal.append_commit(
                batch, policy=policy, gate=gate, applied=False,
                route=(self.rhost.storage_owner if self.rhost is not None
                       else None),
            )
            metrics = {"queued": 1, **self.journal.metrics()}
            return pstore, cache, metrics
        pstore, cache, metrics = self.rt.run_grw_tx(
            pstore, cache, self.ttable, batch, policy=policy, gate=gate,
            occupancy_metrics=occupancy_metrics, journal=self.journal,
            rtable=self.rhost,
        )
        metrics["queued"] = 0
        return pstore, cache, metrics

    # -------------------------------------------------------------- recover
    def recover(self, pstore, cache, owner: int):
        """Recovery-as-migration for one down owner: replay + splice the
        dead blocks into the live store, drain the queued outage commits,
        mark the owner healthy. Returns ``(pstore, cache, info)``."""
        t0 = time.perf_counter()
        pstore, info = replay_to_owner(
            self.journal, self.rt, self.ttable, live_pstore=pstore,
            owner=owner, default_policy=self.default_policy,
        )
        pstore, cache, dinfo = drain_queued(
            self.journal, self.rt, self.ttable, pstore, cache,
            default_policy=self.default_policy, rhost=self.rhost,
        )
        self.detector.mark_recovered(owner)
        if self.plan is not None:
            self.plan.revive(owner)
        info.update(dinfo)
        info["recovery_seconds"] = time.perf_counter() - t0
        return pstore, cache, info

    # -------------------------------------------------------------- metrics
    def metrics(self) -> dict:
        m = {
            "failed_batches": self.failed_batches,
            "degraded_batches": self.degraded_batches,
            "deferred_rows_total": self.deferred_rows,
            "detections": self.detector.detections,
            "recoveries": self.detector.recoveries,
            "down_shards": len(self.detector.down()),
        }
        if self.hedge is not None:
            m.update(
                hedge_issued=self.hedge.issued, hedged_calls=self.hedge.hedged,
                hedge_wins=self.hedge.hedge_wins,
                hedge_rate=round(self.hedge.hedge_rate, 4),
            )
        return m
