"""two-tower-retrieval: embed_dim=256 tower_mlp=1024-512-256 dot interaction,
sampled-softmax retrieval. [RecSys'19 (YouTube)]"""

from repro.recsys import TwoTowerConfig

FAMILY = "recsys"

FULL = TwoTowerConfig(
    name="two-tower-retrieval", embed_dim=256, tower_mlp=(1024, 512, 256),
    user_fields=8, item_fields=6, bag_size=16,
    user_vocab=100_000_000, item_vocab=10_000_000,
)

SMOKE = TwoTowerConfig(
    name="two-tower-smoke", embed_dim=16, tower_mlp=(32, 16),
    user_fields=3, item_fields=2, bag_size=4,
    user_vocab=1000, item_vocab=500,
)

SHAPES = {
    "train_batch": dict(kind="rec_train", batch=65536),
    "serve_p99": dict(kind="rec_serve", batch=512, n_candidates=256),
    "serve_bulk": dict(kind="rec_serve", batch=262144, n_candidates=16),
    "retrieval_cand": dict(kind="rec_retrieval", batch=1, n_candidates=1_000_000),
}
SKIPS = {}
