"""egnn: 4L d_hidden=64, E(n)-equivariant. [arXiv:2102.09844]"""

from repro.configs.gnn_shapes import GNN_SHAPES
from repro.gnn import GNNConfig

FAMILY = "gnn"

FULL = GNNConfig(
    name="egnn", kind="egnn", n_layers=4, d_hidden=64, d_in=32, n_classes=1,
)

SMOKE = GNNConfig(
    name="egnn-smoke", kind="egnn", n_layers=2, d_hidden=8, d_in=16, n_classes=1,
)

SHAPES = GNN_SHAPES
SKIPS = {}
