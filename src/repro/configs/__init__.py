"""Architecture registry: 10 assigned archs + the paper's own graph engine.

Each config module exposes:
  FAMILY: "lm" | "gnn" | "recsys" | "graph"
  FULL:   the exact published configuration
  SMOKE:  a reduced same-family config for CPU smoke tests
  SHAPES: {shape_name: dict(kind=..., **dims)}
  SKIPS:  {shape_name: reason} — cells excluded per DESIGN.md §Arch-applicability
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "glm4-9b",
    "yi-6b",
    "gemma3-4b",
    "kimi-k2-1t-a32b",
    "grok-1-314b",
    "pna",
    "nequip",
    "gat-cora",
    "egnn",
    "two-tower-retrieval",
    "ecommerce-graph",  # the paper's own architecture
]


def get_arch(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod


def all_cells(include_paper_arch: bool = True):
    """Every (arch, shape) cell incl. skip annotations."""
    cells = []
    for a in ARCH_IDS:
        if a == "ecommerce-graph" and not include_paper_arch:
            continue
        mod = get_arch(a)
        for shape, info in mod.SHAPES.items():
            cells.append(
                dict(
                    arch=a,
                    shape=shape,
                    kind=info["kind"],
                    skip=mod.SKIPS.get(shape),
                )
            )
    return cells
