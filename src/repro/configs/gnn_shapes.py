"""The GNN-family input-shape set (shared by the 4 GNN archs).

minibatch_lg padded dims follow from batch_nodes=1024 with fanout 15-10:
nodes <= 1024*(1+15+150) = 169,984; edges <= 1024*(15+150) = 168,960.
Feature dims: full_graph_sm = Cora (1433), minibatch_lg = Reddit (602),
ogb_products = 100, molecule = 32 (+ positions for equivariant archs).
"""

GNN_SHAPES = {
    "full_graph_sm": dict(
        kind="gnn_train", n_nodes=2708, n_edges=10556, d_feat=1433,
        n_classes=7, n_graphs=1,
    ),
    "minibatch_lg": dict(
        kind="gnn_train", n_nodes=169_984, n_edges=168_960, d_feat=602,
        n_classes=41, n_graphs=1,
    ),
    "ogb_products": dict(
        kind="gnn_train", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
        n_classes=47, n_graphs=1,
    ),
    "molecule": dict(
        kind="gnn_train", n_nodes=30 * 128, n_edges=64 * 128, d_feat=32,
        n_classes=1, n_graphs=128,
    ),
}
