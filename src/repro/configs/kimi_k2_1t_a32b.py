"""kimi-k2-1t-a32b: 61L d_model=7168 64H (GQA kv=8) vocab=163840,
MoE 384 experts top-8 (expert d_ff=2048) + 1 shared expert — trillion-param
MoE. [arXiv:2501.kimi2]

Memory note (recorded in EXPERIMENTS §Dry-run): 1T params do not fit a
single v5e-256 pod with fp32 Adam moments; the train config uses bf16
moments and ZeRO-1, and the honest fit verdict comes from
compiled.memory_analysis()."""

from repro.configs.lm_shapes import FULL_ATTENTION_LONG_SKIP, LM_SHAPES
from repro.lm import LMConfig

FAMILY = "lm"

FULL = LMConfig(
    name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
    n_kv_heads=8, d_ff=2048, vocab=163840, n_experts=384, top_k=8,
    n_shared_experts=1, rope_theta=50_000.0,
)

SMOKE = LMConfig(
    name="kimi-k2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab=512, n_experts=8, top_k=2, n_shared_experts=1,
    attn_q_chunk=16, attn_k_chunk=16, loss_chunk=16,
)

SHAPES = LM_SHAPES
SKIPS = {"long_500k": FULL_ATTENTION_LONG_SKIP}
