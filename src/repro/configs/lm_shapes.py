"""The LM-family input-shape set (shared by all 5 LM archs)."""

LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

FULL_ATTENTION_LONG_SKIP = (
    "long_500k requires sub-quadratic attention; this arch is pure "
    "full-attention (DESIGN.md §Arch-applicability)"
)
