"""glm4-9b: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552, RoPE.
[hf:THUDM/glm-4-9b]"""

from repro.configs.lm_shapes import FULL_ATTENTION_LONG_SKIP, LM_SHAPES
from repro.lm import LMConfig

FAMILY = "lm"

FULL = LMConfig(
    name="glm4-9b", n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=151552, rope_theta=10_000.0,
)

SMOKE = LMConfig(
    name="glm4-9b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, attn_q_chunk=16, attn_k_chunk=16, loss_chunk=16,
)

SHAPES = LM_SHAPES
SKIPS = {"long_500k": FULL_ATTENTION_LONG_SKIP}
