"""ecommerce-graph: the paper's own architecture — production-scale
transactional graph serving with the one-hop sub-query result cache.

~1.1B vertices / ~8.6B edges (the paper's deployment is "tens of billions
of vertices and edges"), vertex-partitioned over the full mesh with the
cache co-partitioned; peak 8k concurrent one-hop gR-Txs per step."""

from repro.distributed.graph_serve import GraphServeConfig

FAMILY = "graph"

FULL = GraphServeConfig(
    name="ecommerce-graph",
    v_total=2**30,
    e_per_vertex=8,
    max_deg=64,
    max_leaves=64,
    cache_slots_total=2**26,
)

SMOKE = GraphServeConfig(
    name="ecommerce-graph-smoke",
    v_total=256,
    e_per_vertex=4,
    max_deg=8,
    max_leaves=8,
    cache_slots_total=256,
)

SHAPES = {
    "serve_peak": dict(kind="graph_serve", batch=8192, use_cache=True),
    "serve_low": dict(kind="graph_serve", batch=1024, use_cache=True),
    "serve_nocache": dict(kind="graph_serve", batch=8192, use_cache=False),
}
SKIPS = {}
