"""nequip: 5L d_hidden=32 l_max=2 n_rbf=8 cutoff=5, E(3)-equivariant
interatomic potentials. [arXiv:2101.03164]

DESIGN.md §2 records the tensor-product restriction: l=2 features are kept
as traceless symmetric 3x3 matrices with a fixed path set instead of the
full Clebsch-Gordan product."""

from repro.configs.gnn_shapes import GNN_SHAPES
from repro.gnn import GNNConfig

FAMILY = "gnn"

FULL = GNNConfig(
    name="nequip", kind="nequip", n_layers=5, d_hidden=32, d_in=32,
    n_classes=1, l_max=2, n_rbf=8, cutoff=5.0,
)

SMOKE = GNNConfig(
    name="nequip-smoke", kind="nequip", n_layers=2, d_hidden=8, d_in=16,
    n_classes=1, l_max=2, n_rbf=4, cutoff=5.0,
)

SHAPES = GNN_SHAPES
SKIPS = {}
