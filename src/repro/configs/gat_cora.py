"""gat-cora: 2L d_hidden=8 n_heads=8 attention aggregator.
[arXiv:1710.10903]"""

from repro.configs.gnn_shapes import GNN_SHAPES
from repro.gnn import GNNConfig

FAMILY = "gnn"

FULL = GNNConfig(
    name="gat-cora", kind="gat", n_layers=2, d_hidden=8, n_heads=8,
    d_in=1433, n_classes=7,
)

SMOKE = GNNConfig(
    name="gat-smoke", kind="gat", n_layers=2, d_hidden=4, n_heads=2,
    d_in=16, n_classes=4,
)

SHAPES = GNN_SHAPES
SKIPS = {}
