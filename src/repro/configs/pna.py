"""pna: 4L d_hidden=75 aggregators=mean-max-min-std scalers=id-amp-atten.
[arXiv:2004.05718]"""

from repro.configs.gnn_shapes import GNN_SHAPES
from repro.gnn import GNNConfig

FAMILY = "gnn"

FULL = GNNConfig(
    name="pna", kind="pna", n_layers=4, d_hidden=75, d_in=128, n_classes=47,
    aggregators=("mean", "max", "min", "std"),
    scalers=("identity", "amplification", "attenuation"),
)

SMOKE = GNNConfig(
    name="pna-smoke", kind="pna", n_layers=2, d_hidden=8, d_in=16, n_classes=4,
)

SHAPES = GNN_SHAPES
SKIPS = {}
