"""yi-6b: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000, llama-arch.
[arXiv:2403.04652]"""

from repro.configs.lm_shapes import FULL_ATTENTION_LONG_SKIP, LM_SHAPES
from repro.lm import LMConfig

FAMILY = "lm"

FULL = LMConfig(
    name="yi-6b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab=64000, rope_theta=5_000_000.0,
)

SMOKE = LMConfig(
    name="yi-6b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=512, attn_q_chunk=16, attn_k_chunk=16, loss_chunk=16,
)

SHAPES = LM_SHAPES
SKIPS = {"long_500k": FULL_ATTENTION_LONG_SKIP}
