"""gemma3-4b: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local:global sliding-window hybrid, 128k context. [hf:google/gemma-3]

Runs long_500k: the 5:1 local layers are sliding-window (sub-quadratic) and
decode with a KV cache is per-token linear; global layers shard KV over
'model' (context parallelism)."""

from repro.configs.lm_shapes import LM_SHAPES
from repro.lm import LMConfig

FAMILY = "lm"

FULL = LMConfig(
    name="gemma3-4b", n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_head=256, d_ff=10240, vocab=262144, rope_theta=1_000_000.0,
    sliding_window=1024, local_global_pattern=5,
)

SMOKE = LMConfig(
    name="gemma3-4b-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab=512, sliding_window=8, local_global_pattern=2,
    attn_q_chunk=16, attn_k_chunk=16, loss_chunk=16,
)

SHAPES = LM_SHAPES
SKIPS = {}
