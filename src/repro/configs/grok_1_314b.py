"""grok-1-314b: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2. [hf:xai-org/grok-1]"""

from repro.configs.lm_shapes import FULL_ATTENTION_LONG_SKIP, LM_SHAPES
from repro.lm import LMConfig

FAMILY = "lm"

FULL = LMConfig(
    name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072, n_experts=8, top_k=2, rope_theta=10_000.0,
)

SMOKE = LMConfig(
    name="grok-1-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, n_experts=4, top_k=2,
    attn_q_chunk=16, attn_k_chunk=16, loss_chunk=16,
)

SHAPES = LM_SHAPES
SKIPS = {"long_500k": FULL_ATTENTION_LONG_SKIP}
