# Pallas TPU kernels for the system's compute hot-spots. Each subpackage is
# kernel.py (pl.pallas_call + explicit BlockSpec VMEM tiling) + ops.py (jit'd
# wrapper with interpret fallback) + ref.py (pure-jnp oracle). Validated via
# interpret=True on CPU; the BlockSpecs are written for TPU v5e VMEM/MXU.
