"""Cache hash-probe, Pallas TPU.

Batched open-addressing lookup for the one-hop result cache: for a block of
(tpl, root, fingerprint, slot-hash) keys, gather the PROBES candidate slots'
metadata and emit (hit, slot). All hash math is uint32 vector ops in VMEM;
the slot-metadata gathers hit the cache shard resident on this chip (the
cache is co-partitioned with its root vertices, DESIGN.md §5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(c_tpl_ref, c_root_ref, c_fp_ref, c_valid_ref,
                  tpl_ref, root_ref, h_ref, fp_ref, hit_ref, slot_ref, *,
                  probes, capacity):
    tpl = tpl_ref[...]
    root = root_ref[...]
    h = h_ref[...]
    fp = fp_ref[...]
    base = (h & jnp.uint32(capacity - 1)).astype(jnp.int32)
    hit = jnp.zeros(tpl.shape, jnp.bool_)
    slot = jnp.full(tpl.shape, -1, jnp.int32)
    for i in range(probes):  # static probe window unroll
        s = (base + i) & (capacity - 1)
        ok = (
            c_valid_ref[s]
            & (c_tpl_ref[s] == tpl)
            & (c_root_ref[s] == root)
            & (c_fp_ref[s] == fp)
        )
        take = ok & ~hit
        slot = jnp.where(take, s, slot)
        hit = hit | ok
    hit_ref[...] = hit
    slot_ref[...] = slot


def cache_probe_pallas(c_tpl, c_root, c_fp, c_valid, tpl, root, h, fp, *,
                       probes=8, block_b=256, interpret=False):
    """Cache arrays [C]; key arrays [B] (h/fp uint32). -> (hit [B], slot [B])."""
    C = c_tpl.shape[0]
    B = tpl.shape[0]
    assert C & (C - 1) == 0
    block_b = min(block_b, B)
    assert B % block_b == 0
    grid = (B // block_b,)
    full = lambda: pl.BlockSpec((C,), lambda i: (0,))
    blk = lambda: pl.BlockSpec((block_b,), lambda i: (i,))
    hit, slot = pl.pallas_call(
        functools.partial(_probe_kernel, probes=probes, capacity=C),
        grid=grid,
        in_specs=[full(), full(), full(), full(), blk(), blk(), blk(), blk()],
        out_specs=[blk(), blk()],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.bool_),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        interpret=interpret,
    )(c_tpl, c_root, c_fp, c_valid, tpl, root, h, fp)
    return hit, slot
