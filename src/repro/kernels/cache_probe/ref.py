"""Pure-jnp oracle for cache_probe."""

from __future__ import annotations

import jax.numpy as jnp


def cache_probe_ref(c_tpl, c_root, c_fp, c_valid, tpl, root, h, fp, *, probes=8):
    C = c_tpl.shape[0]
    base = (h & jnp.uint32(C - 1)).astype(jnp.int32)
    offs = jnp.arange(probes, dtype=jnp.int32)
    slots = (base[:, None] + offs[None, :]) & (C - 1)
    ok = (
        c_valid[slots]
        & (c_tpl[slots] == tpl[:, None])
        & (c_root[slots] == root[:, None])
        & (c_fp[slots] == fp[:, None])
    )
    hit = jnp.any(ok, axis=1)
    first = jnp.argmax(ok, axis=1)
    slot = jnp.where(hit, jnp.take_along_axis(slots, first[:, None], 1)[:, 0], -1)
    return hit, slot
