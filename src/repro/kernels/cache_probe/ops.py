"""jit'd public wrapper for cache_probe."""

from __future__ import annotations

import functools

import jax

from repro.kernels.cache_probe.kernel import cache_probe_pallas


@functools.partial(jax.jit, static_argnames=("probes", "block_b", "interpret"))
def cache_probe(c_tpl, c_root, c_fp, c_valid, tpl, root, h, fp, *, probes=8,
                block_b=256, interpret=True):
    return cache_probe_pallas(
        c_tpl, c_root, c_fp, c_valid, tpl, root, h, fp, probes=probes,
        block_b=block_b, interpret=interpret,
    )
