"""jit'd public wrapper for cache_probe.

Handles arbitrary batch sizes by padding B up to a whole number of kernel
blocks (padded rows probe with an impossible key and are sliced off), so the
engine's fused hop pipeline can probe any frontier width. ``interpret=None``
resolves at trace time: compiled on TPU, interpreter elsewhere (CPU tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cache_probe.kernel import cache_probe_pallas


@functools.partial(jax.jit, static_argnames=("probes", "block_b", "interpret"))
def cache_probe(c_tpl, c_root, c_fp, c_valid, tpl, root, h, fp, *, probes=8,
                block_b=256, interpret=None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B = tpl.shape[0]
    if B <= block_b:
        Bp, blk = B, B
    else:
        Bp = -(-B // block_b) * block_b
        blk = block_b
    if Bp != B:
        pad = Bp - B
        pad_i32 = lambda x: jnp.concatenate([x, jnp.full((pad,), -1, jnp.int32)])
        pad_u32 = lambda x: jnp.concatenate([x, jnp.zeros((pad,), jnp.uint32)])
        tpl, root = pad_i32(tpl), pad_i32(root)
        h, fp = pad_u32(h), pad_u32(fp)
    hit, slot = cache_probe_pallas(
        c_tpl, c_root, c_fp, c_valid, tpl, root, h, fp, probes=probes,
        block_b=blk, interpret=interpret,
    )
    return hit[:B], slot[:B]
