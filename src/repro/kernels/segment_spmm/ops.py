"""Public wrapper for segment_spmm: sorts edges by dst and builds the
per-tile offsets the kernel contract requires."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.segment_spmm.kernel import segment_spmm_pallas


def prepare_edges(src, dst, n_nodes: int, block_n: int):
    """Sort by dst; tile_offsets[t] = first edge whose dst is in tile t."""
    order = jnp.argsort(dst)
    src_s, dst_s = src[order], dst[order]
    T = n_nodes // block_n
    bounds = jnp.arange(T + 1, dtype=jnp.int32) * block_n
    offs = jnp.searchsorted(dst_s, bounds, side="left").astype(jnp.int32)
    return src_s, dst_s, offs


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_e", "max_chunks", "interpret")
)
def segment_spmm(x, src, dst, *, block_n=128, block_e=256, max_chunks=64,
                 interpret=True):
    src_s, dst_s, offs = prepare_edges(src, dst, x.shape[0], block_n)
    return segment_spmm_pallas(
        x, src_s, dst_s, offs, block_n=block_n, block_e=block_e,
        max_chunks=max_chunks, interpret=interpret,
    )
