"""Pure-jnp oracle for segment_spmm."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_spmm_ref(x, src, dst, n_nodes=None):
    n = n_nodes or x.shape[0]
    return jax.ops.segment_sum(x[src], dst, num_segments=n)
