"""Segment-sum SpMM (gather -> scatter-accumulate), Pallas TPU.

GNN message passing: ``out[dst] += x[src]`` over an edge list. Contract:
edges are pre-sorted by dst and ``tile_offsets[t]`` gives the first edge of
each dst tile (rows [t*block_n, (t+1)*block_n)). Grid: (N / block_n,); each
program owns one output tile in VMEM and walks its edge range in
``block_e``-sized chunks: gather the source rows, then accumulate them into
the tile with a one-hot [block_e, block_n] matmul — the MXU-native way to
express a scatter-add (no data-dependent writes inside the kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(x_ref, src_ref, dst_ref, offs_ref, out_ref, *, block_n,
                 block_e, max_chunks, n_edges):
    t = pl.program_id(0)
    lo = offs_ref[t]
    hi = offs_ref[t + 1]
    acc0 = jnp.zeros((block_n, x_ref.shape[1]), jnp.float32)

    def chunk(c, acc):
        e0 = lo + c * block_e
        idx = e0 + jax.lax.broadcasted_iota(jnp.int32, (block_e,), 0)
        valid = idx < hi
        idx = jnp.clip(idx, 0, n_edges - 1)
        rows = x_ref[src_ref[idx]]  # [block_e, D] gather
        local = dst_ref[idx] - t * block_n  # [block_e]
        onehot = (
            jax.lax.broadcasted_iota(jnp.int32, (block_e, block_n), 1)
            == local[:, None]
        ) & valid[:, None]
        # scatter-add as MXU matmul: [block_n, block_e] @ [block_e, D]
        return acc + jax.lax.dot_general(
            onehot.astype(jnp.float32).T, rows.astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )

    acc = jax.lax.fori_loop(0, max_chunks, chunk, acc0)
    out_ref[...] = acc.astype(out_ref.dtype)


def segment_spmm_pallas(x, src, dst, tile_offsets, *, block_n=128,
                        block_e=256, max_chunks=64, interpret=False):
    """x [N, D]; src/dst [E] (sorted by dst); tile_offsets [T+1].

    Returns out [N, D] with out[v] = sum_{e: dst[e]==v} x[src[e]].
    max_chunks bounds any tile's edge count at block_e*max_chunks (assert on
    the host wrapper)."""
    N, D = x.shape
    E = src.shape[0]
    assert N % block_n == 0
    T = N // block_n
    assert tile_offsets.shape[0] == T + 1
    return pl.pallas_call(
        functools.partial(
            _spmm_kernel, block_n=block_n, block_e=block_e,
            max_chunks=max_chunks, n_edges=E,
        ),
        grid=(T,),
        in_specs=[
            pl.BlockSpec((N, D), lambda t: (0, 0)),
            pl.BlockSpec((E,), lambda t: (0,)),
            pl.BlockSpec((E,), lambda t: (0,)),
            pl.BlockSpec((T + 1,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n, D), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        interpret=interpret,
    )(x, src, dst, tile_offsets)
