"""Pure-jnp reference for the block_gather kernel (and the CPU fast path).

One orientation of the partitioned store's owner-local miss execution,
fused: CSR-window scan + recent-region scan + edge-label / edge-predicate /
leaf-predicate filter over one ``BlockGatherOperands`` bundle. The math is
lane-for-lane ``partition.gather_block`` followed by the filter chain of
``runtime.onehop_exec_view``, with the hop's predicates specialized
*statically* (a ``QueryPlan`` hop's ``PredSpec`` holds concrete host
arrays, so the per-condition select of ``templates.evaluate_pred`` unrolls
to the exact comparisons the hop needs — wildcard conditions read the
per-row bound params, everything else is a compile-time constant).

Inputs the caller prepares once per batch (shared by both orientations):

- ``roots``    int32 [B] global root ids (recent-region key compare)
- ``lroot``    int32 [B] clipped local index ``clip(local_of(root), 0, Vloc-1)``
- ``rvalid``   bool  [B] ownership + range gate (table owner == me,
               0 <= root < v_cap) — gates the recent-region scan
- ``cvalid``   bool  [B] CSR-window gate: ``rvalid`` further restricted to
               *native* roots (``v % n == me``) when a routing table is in
               play — a migrated-in root's local index would alias a native
               vertex's CSR rows. Without a table, ``cvalid == rvalid``.
- ``rmask``    bool  [B] request mask (rows this call actually executes)
- ``r_ok``     bool  [B] root-predicate result & rmask
- ``pe_bound`` int32 [B, MAX_CONDS] bound edge-predicate wildcard values
- ``pl_bound`` int32 [B, MAX_CONDS] bound leaf-predicate wildcard values

Outputs, all [B, W] with ``W = max_deg + recent_cap`` (plus trunc [B]):

- ``leaf``  global leaf id per lane
- ``scan``  pre-predicate observed-edge mask (liveness chain & rvalid & rmask)
- ``emask`` after the edge-label + edge-predicate filter (leaf fetches)
- ``qual``  final qualifying mask (leaf predicate & root predicate)
- ``trunc`` adjacency exceeded the ``max_deg`` window (unmasked, as in
  ``gather_block`` — the caller ands with its request mask)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.templates import (
    MAX_CONDS,
    OP_EQ,
    OP_GE,
    OP_GT,
    OP_LE,
    OP_LT,
    OP_NEQ,
)
from repro.utils import PROP_MISSING

# Python-int twin of PROP_MISSING: the jnp scalar would be a captured
# constant inside the Pallas kernel body (weak-typed compare is identical).
_PROP_MISSING = int(PROP_MISSING)


def pred_static(pred) -> tuple:
    """Freeze a ``PredSpec`` of concrete (host) arrays into a hashable
    static form: ``(label, ((lane, prop_id, op, val, wild), ...))`` with
    unused conditions (prop_id < 0) dropped. ``lane`` is the condition's
    original MAX_CONDS index — wildcards bind per-row values by lane."""
    pid = np.asarray(pred.prop_ids)
    ops = np.asarray(pred.ops)
    vals = np.asarray(pred.vals)
    wild = np.asarray(pred.wild)
    conds = tuple(
        (c, int(pid[c]), int(ops[c]), int(vals[c]), bool(wild[c]))
        for c in range(MAX_CONDS)
        if int(pid[c]) >= 0
    )
    return (int(np.asarray(pred.label)), conds)


def _cmp_static(op: int, a, b):
    """``templates._cmp`` with the op code known at trace time."""
    if op == OP_EQ:
        return a == b
    if op == OP_NEQ:
        return a != b
    if op == OP_LT:
        return a < b
    if op == OP_LE:
        return a <= b
    if op == OP_GT:
        return a > b
    if op == OP_GE:
        return a >= b
    return jnp.zeros_like(a, bool)


def eval_pred_static(stat: tuple, labels, props, bound):
    """``templates.evaluate_pred`` with the spec static and wildcards bound.

    ``labels`` int32 [...], ``props`` int32 [..., NP], ``bound`` int32
    [..., MAX_CONDS] (broadcastable). Bit-identical to
    ``evaluate_pred(pred, labels, props, bound_vals=bound)`` for the
    ``pred`` that ``stat`` froze: a wildcard condition compares OP_EQ
    against its bound lane, a literal condition compares its constant, and
    both require presence."""
    label, conds = stat
    if label < 0:
        ok = jnp.ones(labels.shape, bool)
    else:
        ok = labels == label
    for lane, pid, op, val, wild in conds:
        pv = props[..., min(pid, props.shape[-1] - 1)]
        present = pv != _PROP_MISSING
        if wild:
            cond = present & _cmp_static(OP_EQ, pv, bound[..., lane])
        else:
            # plain int, not jnp.int32(val): a concrete scalar would be a
            # captured constant inside the Pallas kernel body
            cond = present & _cmp_static(op, pv, val)
        ok = ok & cond
    return ok


def block_gather_filter_ref(
    indptr, key, other, label, alive, props, vlabel, valive, vprops,
    csr_len, blk_len, roots, lroot, rvalid, cvalid, rmask, r_ok,
    pe_bound, pl_bound,
    *, max_deg: int, recent_cap: int, e_blk_cap: int, edge_label: int,
    pe: tuple, pl: tuple,
):
    """The fused scan + filter, vectorized over the whole batch (the oracle
    the Pallas kernel must match bit-exactly, and the production executor on
    backends without Pallas compile support)."""
    B = roots.shape[0]
    EB, R = e_blk_cap, recent_cap

    # ---- CSR window (the physically sorted block region) ----
    start = indptr[lroot]
    deg = indptr[lroot + 1] - start
    trunc = deg > max_deg
    lane = jnp.arange(max_deg, dtype=jnp.int32)[None, :]
    pos = start[:, None] + lane
    csr_mask = (lane < deg[:, None]) & cvalid[:, None]
    slot_csr = jnp.clip(pos, 0, EB - 1)

    # ---- recent region: [csr_len, blk_len) within a bounded window ----
    roff = jnp.clip(csr_len, 0, EB - R)
    key_r = jax.lax.dynamic_slice(key, (roff,), (R,))
    sid = roff + jnp.arange(R, dtype=jnp.int32)
    in_region = (sid >= csr_len) & (sid < blk_len)
    rec_mask = (key_r[None, :] == roots[:, None]) & in_region[None, :]
    rec_mask &= rvalid[:, None]
    slot_rec = jnp.broadcast_to(sid[None, :], (B, R))

    slots = jnp.concatenate([slot_csr, slot_rec], axis=1)  # [B, W]
    mask = jnp.concatenate([csr_mask, rec_mask], axis=1)
    # liveness chain identical to gather_block: edge alive, both endpoints
    # alive (leaf via the replicated vertex tier)
    mask &= alive[slots]
    leaf = other[slots]
    leaf_c = jnp.clip(leaf, 0, valive.shape[0] - 1)
    mask &= valive[leaf_c]
    root_c = jnp.clip(roots, 0, valive.shape[0] - 1)
    mask &= valive[root_c][:, None]

    # ---- filter chain of onehop_exec_view, statically specialized ----
    scan = mask & rmask[:, None]
    elab = label[slots]
    epv = props[slots]
    if edge_label < 0:
        e_ok = jnp.ones_like(scan)
    else:
        e_ok = elab == edge_label
    e_ok &= eval_pred_static(pe, elab, epv, pe_bound[:, None, :])
    emask = scan & e_ok
    llab = vlabel[leaf_c]
    lpv = vprops[leaf_c]
    l_ok = eval_pred_static(pl, llab, lpv, pl_bound[:, None, :])
    qual = emask & l_ok & r_ok[:, None]
    return leaf, scan, emask, qual, trunc
