"""Owner-local block gather + predicate filter, Pallas TPU.

The partitioned tier's miss-execution hot path, fused into one kernel per
orientation: for a block of routed roots, scan the owner-local CSR window
AND the block's recent append region, chain edge/endpoint liveness, and
apply the hop's edge-label + edge-predicate + leaf-predicate filters — one
pass over VMEM-resident block arrays instead of the former multi-op
gather/take/select chain (see ``ref.block_gather_filter_ref`` for the exact
math and the operand contract; ``partition.BlockGatherOperands`` bundles the
arrays).

Grid: (B / block_b,). Per program the root block's per-row inputs live in
VMEM; the block arrays (indptr, key/other/label/alive/props) and the
replicated vertex tier are streamed as whole-array blocks — like
``onehop_gather`` this validation variant assumes the block partition fits
VMEM (the production variant would DMA each root's CSR window via
scalar-prefetched indptr, same math). Predicates arrive statically frozen
(``ref.pred_static``), so each condition unrolls to its exact comparison
with wildcard lanes read from the per-row bound params.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
# not the usual ``as pl`` alias: the hop's leaf predicate arrives as a
# parameter named ``pl`` (mirroring ``QueryPlan`` field names) and would
# shadow it inside ``block_gather_pallas``
from jax.experimental import pallas

from repro.kernels.block_gather.ref import eval_pred_static


def _block_gather_kernel(
    indptr_ref, key_ref, other_ref, label_ref, alive_ref, props_ref,
    vlabel_ref, valive_ref, vprops_ref, csr_len_ref, blk_len_ref,
    roots_ref, lroot_ref, rvalid_ref, cvalid_ref, rmask_ref, r_ok_ref,
    pe_bound_ref, pl_bound_ref,
    leaf_ref, scan_ref, emask_ref, qual_ref, trunc_ref,
    *, max_deg, recent_cap, e_blk_cap, edge_label, pe, pl,
):
    EB, R = e_blk_cap, recent_cap
    roots = roots_ref[...]          # [bb] global ids
    lroot = lroot_ref[...]          # [bb] clipped local ids
    rvalid = rvalid_ref[...]        # recent-region gate (table owner == me)
    cvalid = cvalid_ref[...]        # CSR-window gate (native roots only)
    rmask = rmask_ref[...]
    r_ok = r_ok_ref[...]
    bb = roots.shape[0]
    csr_len = csr_len_ref[0]
    blk_len = blk_len_ref[0]

    # ---- CSR window ----
    start = indptr_ref[lroot]
    deg = indptr_ref[lroot + 1] - start
    trunc = deg > max_deg
    lane = jax.lax.broadcasted_iota(jnp.int32, (bb, max_deg), 1)
    pos = start[:, None] + lane
    csr_mask = (lane < deg[:, None]) & cvalid[:, None]
    slot_csr = jnp.clip(pos, 0, EB - 1)

    # ---- recent region: [csr_len, blk_len) within a bounded window ----
    roff = jnp.clip(csr_len, 0, EB - R)
    key_r = jax.lax.dynamic_slice(key_ref[...], (roff,), (R,))
    sid = roff + jax.lax.broadcasted_iota(jnp.int32, (R,), 0)
    in_region = (sid >= csr_len) & (sid < blk_len)
    rec_mask = (key_r[None, :] == roots[:, None]) & in_region[None, :]
    rec_mask &= rvalid[:, None]
    slot_rec = jnp.broadcast_to(sid[None, :], (bb, R))

    slots = jnp.concatenate([slot_csr, slot_rec], axis=1)  # [bb, W]
    mask = jnp.concatenate([csr_mask, rec_mask], axis=1)
    mask &= alive_ref[...][slots]
    leaf = other_ref[...][slots]
    v_cap = valive_ref.shape[0]
    leaf_c = jnp.clip(leaf, 0, v_cap - 1)
    valive = valive_ref[...]
    mask &= valive[leaf_c]
    root_c = jnp.clip(roots, 0, v_cap - 1)
    mask &= valive[root_c][:, None]

    # ---- statically specialized filter chain ----
    scan = mask & rmask[:, None]
    elab = label_ref[...][slots]
    epv = props_ref[...][slots]
    if edge_label < 0:
        e_ok = jnp.ones_like(scan)
    else:
        e_ok = elab == edge_label
    e_ok &= eval_pred_static(pe, elab, epv, pe_bound_ref[...][:, None, :])
    emask = scan & e_ok
    llab = vlabel_ref[...][leaf_c]
    lpv = vprops_ref[...][leaf_c]
    l_ok = eval_pred_static(pl, llab, lpv, pl_bound_ref[...][:, None, :])
    qual = emask & l_ok & r_ok[:, None]

    leaf_ref[...] = leaf
    scan_ref[...] = scan
    emask_ref[...] = emask
    qual_ref[...] = qual
    trunc_ref[...] = trunc


def block_gather_pallas(
    indptr, key, other, label, alive, props, vlabel, valive, vprops,
    csr_len, blk_len, roots, lroot, rvalid, cvalid, rmask, r_ok,
    pe_bound, pl_bound,
    *, max_deg, recent_cap, e_blk_cap, edge_label, pe, pl,
    block_b=128, interpret=False,
):
    """Pallas dispatch of ``ref.block_gather_filter_ref`` (same signature,
    same outputs; B must divide into ``block_b`` row blocks — the ops
    wrapper pads)."""
    B = roots.shape[0]
    W = max_deg + recent_cap
    Vp = indptr.shape[0]
    EB = e_blk_cap
    v_cap = vlabel.shape[0]
    NEP, NVP = props.shape[1], vprops.shape[1]
    block_b = min(block_b, B)
    assert B % block_b == 0, (B, block_b)
    grid = (B // block_b,)
    kernel = functools.partial(
        _block_gather_kernel, max_deg=max_deg, recent_cap=recent_cap,
        e_blk_cap=e_blk_cap, edge_label=edge_label, pe=pe, pl=pl,
    )
    full1 = lambda n: pallas.BlockSpec((n,), lambda i: (0,))
    full2 = lambda n, k: pallas.BlockSpec((n, k), lambda i: (0, 0))
    row1 = pallas.BlockSpec((block_b,), lambda i: (i,))
    row2 = lambda k: pallas.BlockSpec((block_b, k), lambda i: (i, 0))
    leaf, scan, emask, qual, trunc = pallas.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            full1(Vp),        # indptr
            full1(EB),        # key
            full1(EB),        # other
            full1(EB),        # label
            full1(EB),        # alive
            full2(EB, NEP),   # props
            full1(v_cap),     # vlabel
            full1(v_cap),     # valive
            full2(v_cap, NVP),  # vprops
            full1(1),         # csr_len
            full1(1),         # blk_len
            row1,             # roots
            row1,             # lroot
            row1,             # rvalid
            row1,             # cvalid
            row1,             # rmask
            row1,             # r_ok
            row2(pe_bound.shape[1]),  # pe_bound
            row2(pl_bound.shape[1]),  # pl_bound
        ],
        out_specs=[
            row2(W), row2(W), row2(W), row2(W), row1,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, W), jnp.int32),
            jax.ShapeDtypeStruct((B, W), jnp.bool_),
            jax.ShapeDtypeStruct((B, W), jnp.bool_),
            jax.ShapeDtypeStruct((B, W), jnp.bool_),
            jax.ShapeDtypeStruct((B,), jnp.bool_),
        ],
        interpret=interpret,
    )(
        indptr, key, other, label, alive, props, vlabel, valive, vprops,
        jnp.reshape(csr_len, (1,)), jnp.reshape(blk_len, (1,)),
        roots, lroot, rvalid, cvalid, rmask, r_ok, pe_bound, pl_bound,
    )
    return leaf, scan, emask, qual, trunc
