"""Public wrappers for block_gather: the raw fused scan+filter and the
drop-in owner-local miss executor for the sharded serve tier.

``block_gather`` pads the batch to whole kernel blocks and dispatches to
the Pallas kernel (compiled on TPU, interpreter elsewhere) or to the
pure-jnp reference. ``use_pallas=None`` resolves at trace time like
``cache.CacheSpec.use_pallas``: the Pallas kernel on TPU, the fully
vectorized reference on CPU/GPU — both are pinned bit-identical by the
tier-1 parity tests, so the choice is a performance knob, not a semantic
one.

``block_onehop_exec`` is the fused replacement for
``runtime.onehop_exec_view`` over a ``partition.BlockStoreView``: same
(leaves, lmask, n_true, truncated, stats) contract, but the per-direction
scan + filter run in one fused pass and the Definition 2.1 set-dedup is the
O(W log W) sort-based first-occurrence keep instead of the O(W^2) pairwise
compare — the dominant cost at production widths (W = max_deg +
recent_blk_cap lanes per orientation). The two are byte-identical on
well-formed stores: a qualifying lane's leaf id is never NULL_ID (alive
edges carry real endpoints), which is the only value where the two dedup
styles could diverge.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.keys import PARAM_LEN
from repro.core.templates import DIR_BOTH, DIR_IN, DIR_OUT, MAX_CONDS, evaluate_pred
from repro.distributed.routing import storage_owner_of
from repro.graphstore.partition import local_of, owner_of
from repro.kernels.block_gather.kernel import block_gather_pallas
from repro.kernels.block_gather.ref import block_gather_filter_ref, pred_static
from repro.utils import NULL_ID, compact_masked, take_along0


def block_gather(
    indptr, key, other, label, alive, props, vlabel, valive, vprops,
    csr_len, blk_len, roots, lroot, rvalid, cvalid, rmask, r_ok,
    pe_bound, pl_bound,
    *, max_deg, recent_cap, e_blk_cap, edge_label, pe, pl,
    block_b=128, use_pallas=None, interpret=None,
):
    """One orientation's fused scan + filter (see ``ref`` for the operand
    and output contract). Handles arbitrary batch sizes by padding B up to
    whole kernel blocks (padded rows are invalid and fully masked)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    statics = dict(
        max_deg=max_deg, recent_cap=recent_cap, e_blk_cap=e_blk_cap,
        edge_label=edge_label, pe=pe, pl=pl,
    )
    if not use_pallas:
        return block_gather_filter_ref(
            indptr, key, other, label, alive, props, vlabel, valive, vprops,
            csr_len, blk_len, roots, lroot, rvalid, cvalid, rmask, r_ok,
            pe_bound, pl_bound, **statics,
        )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B = roots.shape[0]
    if B <= block_b:
        Bp, blk = B, B
    else:
        Bp = -(-B // block_b) * block_b
        blk = block_b
    if Bp != B:
        pad = Bp - B
        pad_i = lambda x: jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
        pad_b = lambda x: jnp.concatenate([x, jnp.zeros((pad,), bool)])
        pad_2 = lambda x: jnp.concatenate(
            [x, jnp.zeros((pad, x.shape[1]), x.dtype)]
        )
        roots, lroot = pad_i(roots), pad_i(lroot)
        rvalid, cvalid = pad_b(rvalid), pad_b(cvalid)
        rmask, r_ok = pad_b(rmask), pad_b(r_ok)
        pe_bound, pl_bound = pad_2(pe_bound), pad_2(pl_bound)
    leaf, scan, emask, qual, trunc = block_gather_pallas(
        indptr, key, other, label, alive, props, vlabel, valive, vprops,
        csr_len, blk_len, roots, lroot, rvalid, cvalid, rmask, r_ok,
        pe_bound, pl_bound, block_b=blk, interpret=interpret, **statics,
    )
    return leaf[:B], scan[:B], emask[:B], qual[:B], trunc[:B]


def first_occurrence_mask(vals, mask):
    """Per-row first-occurrence keep over masked lanes — the O(W log W)
    equivalent of ``utils.dedup_masked`` (stable sort + adjacent compare,
    permutation inverted back to original order). Identical for any row
    where no masked lane carries NULL_ID (guaranteed for liveness-masked
    block lanes)."""
    mask = mask.astype(bool)
    big = jnp.int32(2**31 - 1)  # sorts after every valid id
    keyed = jnp.where(mask, vals, big)
    order = jnp.argsort(keyed, axis=-1, stable=True)
    sv = jnp.take_along_axis(keyed, order, axis=-1)
    first = jnp.concatenate(
        [jnp.ones(sv.shape[:-1] + (1,), bool), sv[..., 1:] != sv[..., :-1]],
        axis=-1,
    )
    keep_sorted = first & (sv != big)
    inv = jnp.argsort(order, axis=-1)  # invert the permutation
    return jnp.take_along_axis(keep_sorted, inv, axis=-1)


def block_onehop_exec(
    espec, view, direction: int, edge_label: int, pr, pe, pl,
    roots, params, rmask, *, use_pallas=None,
):
    """Fused owner-local miss executor over a ``BlockStoreView`` — the
    partitioned tier's ``exec_fn`` hook. Same contract as
    ``runtime.onehop_exec_view`` (leaves [B, RW], lmask, n_true, truncated,
    stats), byte-identical outputs."""
    pspec = view.pspec
    n, v_cap = pspec.n_shards, pspec.base.v_cap
    pe_bound = params[:, :MAX_CONDS]
    pl_bound = params[:, MAX_CONDS:]

    # root-side gates, shared by both orientations (cheap [B] work)
    roots = roots.astype(jnp.int32)
    rlab = take_along0(view.vlabel, roots)
    rprops = take_along0(view.vprops, roots)
    r_ok = evaluate_pred(pr, rlab, rprops) & rmask
    local = local_of(roots, n)
    rtable = getattr(view, "rtable", None)
    in_range = (roots >= 0) & (roots < v_cap)
    rvalid = (storage_owner_of(rtable, roots, n) == view.me) & in_range
    if rtable is None:
        native = None
        cvalid = rvalid
    else:
        # a migrated-in root's local index v//n aliases a *native* vertex's
        # CSR rows — only native roots may open the CSR window (their rows,
        # once migrated in, live in the recent region and match by key)
        native = owner_of(roots, n) == view.me
        cvalid = rvalid & native
    lroot = jnp.clip(local, 0, pspec.v_loc - 1)

    pe_s, pl_s = pred_static(pe), pred_static(pl)
    incs = {DIR_OUT: (False,), DIR_IN: (True,), DIR_BOTH: (False, True)}
    leaf_p, scan_p, em_p, qual_p, trunc = [], [], [], [], jnp.zeros_like(rmask)
    for incoming in incs[direction]:
        o = view.kernel_operands(incoming=incoming)
        leaf, scan, emask, qual, t = block_gather(
            *o, roots, lroot, rvalid, cvalid, rmask, r_ok, pe_bound, pl_bound,
            max_deg=espec.max_deg, recent_cap=pspec.recent_blk_cap,
            e_blk_cap=pspec.e_blk_cap, edge_label=edge_label,
            pe=pe_s, pl=pl_s, use_pallas=use_pallas,
        )
        leaf_p.append(leaf), scan_p.append(scan)
        em_p.append(emask), qual_p.append(qual)
        # a foreign root's CSR deg is an aliased native vertex's — its
        # truncation flag is meaningless (its real rows, in the recent
        # region, are never truncated: migration policy bounds degree)
        trunc |= (t & native) if native is not None else t

    leaf = jnp.concatenate(leaf_p, axis=1)
    scanned_mask = jnp.concatenate(scan_p, axis=1)
    n_edges_scanned = jnp.sum(scanned_mask.astype(jnp.int32))
    emask = jnp.concatenate(em_p, axis=1)
    n_leaf_fetches = jnp.sum(emask.astype(jnp.int32))  # the paper's "n"
    qual = jnp.concatenate(qual_p, axis=1)

    keep = first_occurrence_mask(leaf, qual)  # set semantics (Definition 2.1)
    n_true = jnp.sum(keep.astype(jnp.int32), axis=1)
    leaves, lmask = compact_masked(leaf, keep, espec.result_width)
    stats = {
        "edges_scanned": n_edges_scanned,
        "leaf_fetches": n_leaf_fetches,
        # full read-conflict set for OCC population commits (see
        # onehop_exec_view): every vertex this execution observed
        "scanned": leaf,
        "scanned_mask": scanned_mask,
    }
    return leaves, lmask, n_true, trunc & rmask, stats
