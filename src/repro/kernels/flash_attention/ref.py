"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None):
    """q [B, H, Sq, d]; k/v [B, H, Sk, d] -> [B, H, Sq, d]; naive softmax."""
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (d**-0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None and window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
