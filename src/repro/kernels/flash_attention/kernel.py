"""Flash attention forward, Pallas TPU.

Grid: (batch*kv_heads*q_groups, num_q_blocks). Each program holds one
(q_block x d) query tile in VMEM and streams k/v blocks through VMEM via the
BlockSpec index maps, maintaining the online-softmax (m, l, acc) state in
VMEM scratch. Tile sizes default to (128, 128) — MXU-aligned on v5e.

Causal + sliding-window band masks are applied via block-position iota; the
kernel processes all k blocks (a production version would early-exit fully
masked blocks via the grid's k-range; recorded as a §Perf follow-up).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale, causal, window, block_q, block_k, seq_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale  # [bq, d]
    k = k_ref[0].astype(jnp.float32)  # [bk, d]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [bq, bk]
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None and window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    m_scr[...] = m_new
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ki == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


def flash_attention_pallas(
    q, k, v, *, causal=True, window=None, block_q=128, block_k=128,
    interpret=False,
):
    """q [B, H, Sq, d]; k/v [B, H, Sk, d] (kv heads pre-broadcast).

    Returns [B, H, Sq, d]."""
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    grid = (B * H, Sq // block_q, Sk // block_k)
    qr = q.reshape(B * H, Sq, d)
    kr = k.reshape(B * H, Sk, d)
    vr = v.reshape(B * H, Sk, d)

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, scale=d**-0.5, causal=causal, window=window,
            block_q=block_q, block_k=block_k, seq_k=Sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, d)
