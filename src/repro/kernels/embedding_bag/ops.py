"""jit'd public wrapper for embedding_bag."""

from __future__ import annotations

import functools

import jax

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas


@functools.partial(
    jax.jit, static_argnames=("mode", "block_b", "block_d", "interpret")
)
def embedding_bag(table, ids, mask, *, mode="sum", block_b=128, block_d=128,
                  interpret=True):
    return embedding_bag_pallas(
        table, ids, mask, mode=mode, block_b=block_b, block_d=block_d,
        interpret=interpret,
    )
