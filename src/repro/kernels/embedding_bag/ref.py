"""Oracle: repro.recsys.embedding.embedding_bag."""

from repro.recsys.embedding import embedding_bag as embedding_bag_ref

__all__ = ["embedding_bag_ref"]
