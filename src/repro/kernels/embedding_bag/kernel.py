"""EmbeddingBag (gather + bag-reduce), Pallas TPU.

Grid: (B / block_b, D / block_d). Per program: one bag block's id matrix
[block_b, K] in VMEM plus a [V, block_d] column stripe of the table; the
gather runs as a K-step accumulation so the VMEM working set is
O(block_b*K + V_stripe + block_b*block_d). On a real deployment the table
stripe is the shard owned by this chip (row-sharded tables), so V here is
the per-device vocab slice.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_kernel(table_ref, ids_ref, mask_ref, out_ref, *, mode):
    ids = ids_ref[...]  # [bb, K]
    mask = mask_ref[...]
    K = ids.shape[1]
    acc = jnp.zeros((ids.shape[0], out_ref.shape[1]), jnp.float32)
    for j in range(K):  # static unroll: K gathers of a [bb, bd] stripe
        rows = table_ref[ids[:, j]]  # [bb, bd]
        acc = acc + jnp.where(mask[:, j][:, None], rows.astype(jnp.float32), 0)
    if mode == "mean":
        cnt = jnp.sum(mask.astype(jnp.float32), axis=1, keepdims=True)
        acc = acc / jnp.maximum(cnt, 1.0)
    out_ref[...] = acc.astype(out_ref.dtype)


def embedding_bag_pallas(table, ids, mask, *, mode="sum", block_b=128,
                         block_d=128, interpret=False):
    """table [V, D]; ids/mask [B, K] -> [B, D]."""
    V, D = table.shape
    B, K = ids.shape
    block_b = min(block_b, B)
    block_d = min(block_d, D)
    assert B % block_b == 0 and D % block_d == 0
    grid = (B // block_b, D // block_d)
    return pl.pallas_call(
        functools.partial(_bag_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((V, block_d), lambda i, j: (0, j)),
            pl.BlockSpec((block_b, K), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, K), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(table, ids, mask)
