"""Pure-jnp oracle for the onehop_gather kernel (and the conceptual ref is
repro.core.oracle.onehop_oracle for full predicate generality)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.utils import NULL_ID


def onehop_gather_ref(start, deg, dst, eprop, vprop, roots, *, max_deg,
                      edge_val, leaf_val):
    E = dst.shape[0]
    pos = start[roots][:, None] + jnp.arange(max_deg)[None, :]
    within = jnp.arange(max_deg)[None, :] < deg[roots][:, None]
    pos = jnp.clip(pos, 0, E - 1)
    leaf = dst[pos]
    ok = within & (eprop[pos] == edge_val) & (vprop[leaf] == leaf_val)
    ok &= roots[:, None] >= 0
    return jnp.where(ok, leaf, NULL_ID), ok
