"""One-hop sub-query gather + predicate filter, Pallas TPU.

The storage-manager hot path of the paper's engine (request ① + ② of
Figure 1 fused): for a block of root vertices, gather each root's CSR edge
window, apply the edge predicate (IsActive == edge_val) and the leaf
predicate (Status == leaf_val), and emit the padded qualifying-leaf lists.

Grid: (B / block_b,). Per program the root block's ids live in VMEM; edge
dst/eprop and the leaf-property column are streamed as whole-array blocks
(this validation variant assumes the edge partition fits VMEM — the
production variant DMAs each root's window via scalar-prefetched indptr,
same math). max_deg is the padded adjacency window (multiple of 128 for
lane alignment).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl



def _onehop_kernel(start_ref, deg_ref, dst_ref, eprop_ref, vprop_ref,
                   roots_ref, leaves_ref, mask_ref, *, max_deg, edge_val,
                   leaf_val, e_cap):
    roots = roots_ref[...]  # [bb]
    start = start_ref[roots]  # int32 [bb] (gather from VMEM block)
    deg = deg_ref[roots]
    pos = start[:, None] + jax.lax.broadcasted_iota(
        jnp.int32, (roots.shape[0], max_deg), 1
    )
    within = jax.lax.broadcasted_iota(jnp.int32, pos.shape, 1) < deg[:, None]
    pos = jnp.clip(pos, 0, e_cap - 1)
    leaf = dst_ref[pos]
    e_ok = within & (eprop_ref[pos] == edge_val)
    l_ok = vprop_ref[leaf] == leaf_val
    ok = e_ok & l_ok & (roots[:, None] >= 0)
    leaves_ref[...] = jnp.where(ok, leaf, jnp.int32(-1))
    mask_ref[...] = ok


def onehop_gather_pallas(start, deg, dst, eprop, vprop, roots, *, max_deg,
                         edge_val, leaf_val, block_b=128, interpret=False):
    """start/deg [V]; dst/eprop [E]; vprop [V]; roots [B].

    Returns (leaves [B, max_deg], mask [B, max_deg]) — qualifying leaves of
    the one-hop sub-query instance rooted at each root (unordered, padded).
    """
    B = roots.shape[0]
    V = start.shape[0]
    E = dst.shape[0]
    block_b = min(block_b, B)
    assert B % block_b == 0
    grid = (B // block_b,)
    kernel = functools.partial(
        _onehop_kernel, max_deg=max_deg, edge_val=edge_val, leaf_val=leaf_val,
        e_cap=E,
    )
    full = lambda n: pl.BlockSpec((n,), lambda i: (0,))
    leaves, mask = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            full(V),  # start
            full(V),  # deg
            full(E),  # dst
            full(E),  # eprop
            full(V),  # vprop
            pl.BlockSpec((block_b,), lambda i: (i,)),  # roots
        ],
        out_specs=[
            pl.BlockSpec((block_b, max_deg), lambda i: (i, 0)),
            pl.BlockSpec((block_b, max_deg), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, max_deg), jnp.int32),
            jax.ShapeDtypeStruct((B, max_deg), jnp.bool_),
        ],
        interpret=interpret,
    )(start, deg, dst, eprop, vprop, roots)
    return leaves, mask
