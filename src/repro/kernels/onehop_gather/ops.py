"""jit'd public wrapper for onehop_gather."""

from __future__ import annotations

import functools

import jax

from repro.kernels.onehop_gather.kernel import onehop_gather_pallas


@functools.partial(
    jax.jit,
    static_argnames=("max_deg", "edge_val", "leaf_val", "block_b", "interpret"),
)
def onehop_gather(start, deg, dst, eprop, vprop, roots, *, max_deg,
                  edge_val, leaf_val, block_b=128, interpret=True):
    return onehop_gather_pallas(
        start, deg, dst, eprop, vprop, roots, max_deg=max_deg,
        edge_val=edge_val, leaf_val=leaf_val, block_b=block_b,
        interpret=interpret,
    )
