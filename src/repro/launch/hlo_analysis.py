"""Optimized-HLO structural analysis for the roofline.

XLA's ``cost_analysis()`` counts a while-loop body ONCE regardless of trip
count (scan-over-layers therefore undercounts by ~n_layers). This module
parses the optimized HLO text into computations, extracts each while loop's
static trip count from its condition computation, propagates multipliers
ENTRY -> body (handling nested scans, e.g. flash attention's k-scan inside
the layer scan), and reports collective bytes both raw (cost_analysis
semantics) and trip-weighted (true per-step traffic).
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u32|s8|u8|pred)\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "u32": 4, "s8": 1, "u8": 1, "pred": 1}
_OP_RE = re.compile(
    r"\s((?:all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?)\(%?"
)
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"[su]32\[\]\s+constant\((\d+)\)")


def shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dtype]
    return total


def parse_computations(hlo: str) -> Tuple[Dict[str, List[str]], str]:
    comps: Dict[str, List[str]] = {}
    entry = ""
    cur = None
    for line in hlo.splitlines():
        m = _HDR_RE.match(line.strip())
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line.strip())
    return comps, entry


def analyze(hlo: str) -> dict:
    comps, entry = parse_computations(hlo)

    # while structure: (owner comp, condition, body)
    whiles = []
    for name, lines in comps.items():
        for ln in lines:
            w = _WHILE_RE.search(ln)
            if w:
                whiles.append((name, w.group(1), w.group(2)))

    def trip_count(cond: str) -> int:
        consts = [int(c) for c in _CONST_RE.findall("\n".join(comps.get(cond, [])))]
        consts = [c for c in consts if c > 1]
        return max(consts) if consts else 1

    # propagate multipliers from ENTRY
    mult: Dict[str, int] = {entry: 1}
    changed = True
    guard = 0
    while changed and guard < 50:
        guard += 1
        changed = False
        for owner, cond, body in whiles:
            if owner in mult:
                m = mult[owner] * trip_count(cond)
                if mult.get(body) != m:
                    mult[body] = m
                    changed = True

    raw = {k: 0 for k in _COLLECTIVES}
    weighted = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for name, lines in comps.items():
        m = mult.get(name, 1)
        for ln in lines:
            mm = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", ln)
            if not mm:
                continue
            rhs = mm.group(1)
            op = _OP_RE.search(rhs)
            if not op:
                continue
            kind = op.group(1).replace("-start", "")
            b = shape_bytes(rhs[: op.start(1)])
            raw[kind] += b
            weighted[kind] += b * m
            counts[kind] += 1
    loops = [
        {"body": body, "trip": trip_count(cond), "owner_mult": mult.get(owner, 1)}
        for owner, cond, body in whiles
    ]
    max_mult = max(mult.values()) if mult else 1
    return dict(
        raw=raw, weighted=weighted, counts=counts, loops=loops,
        dominant_trip=max_mult,
    )
