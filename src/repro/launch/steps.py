"""Per-family step builders + abstract input specs for the dry-run.

``build_cell(arch_id, shape_name, mesh)`` returns
``(step_fn, in_shardings, abstract_args)`` ready for
``jax.jit(step_fn, in_shardings=...).lower(*abstract_args)``.

All shapes below are padded to multiples of 512 where the published number
is indivisible (masked padding; recorded in gnn_shapes docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs as configs_pkg
from repro.distributed import add_data_axis, maybe_spec, set_mesh, tree_shardings
from repro.optim import adamw, chain, clip_by_global_norm

BATCH_AXES = ("pod", "data")
ALL_AXES = ("pod", "data", "model")


def _pad512(n: int) -> int:
    return ((n + 511) // 512) * 512


def _ns(mesh, shape, spec):
    return NamedSharding(mesh, maybe_spec(shape, spec, mesh))


def _tok_sharding(mesh, shape):
    return _ns(mesh, shape, (BATCH_AXES, None))


# ---------------------------------------------------------------- LM
def _lm_cell(mod, shape_name, info, mesh):
    from repro.lm import model as lm_model
    from repro.lm import LMConfig

    cfg: LMConfig = mod.FULL
    moment_dtype = jnp.bfloat16 if cfg.param_count() > 3e11 else jnp.float32
    params = lm_model.abstract_params(cfg)
    rule = lm_model.param_spec_rule(cfg)
    pshard = tree_shardings(params, rule, mesh)
    S, B = info["seq_len"], info["global_batch"]

    if info["kind"] == "train":
        opt = chain(clip_by_global_norm(1.0), adamw(3e-4, moment_dtype=moment_dtype))
        opt_state = jax.eval_shape(opt.init, params)
        # ZeRO-1: moments take the param spec + a data axis
        def moment_rule(path, leaf):
            return tuple(rule(path, leaf))

        mshard = jax.tree_util.tree_map(
            lambda l, s: NamedSharding(
                mesh, add_data_axis(s.spec, l.shape, mesh, axes=("data",))
            ),
            opt_state,
            tree_shardings(opt_state, moment_rule, mesh),
        )
        step = lm_model.train_step(cfg, opt)
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        labels = jax.ShapeDtypeStruct((B, S), jnp.int32)
        shardings = (pshard, mshard, _tok_sharding(mesh, (B, S)), _tok_sharding(mesh, (B, S)))
        return step, shardings, (params, opt_state, tokens, labels)

    if info["kind"] == "prefill":
        step = functools.partial(lm_model.prefill_step, cfg)
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return step, (pshard, _tok_sharding(mesh, (B, S))), (params, tokens)

    if info["kind"] == "decode":
        cache = lm_model.init_kv_cache(cfg, B, S, abstract=True)
        crule = lm_model.kv_cache_spec_rule(cfg)
        cshard = tree_shardings(cache, crule, mesh)
        step = functools.partial(lm_model.decode_step, cfg)
        tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        shardings = (pshard, cshard, _tok_sharding(mesh, (B, 1)), NamedSharding(mesh, P()))
        return step, shardings, (params, cache, tokens, pos)

    raise ValueError(info["kind"])


# ---------------------------------------------------------------- GNN
def _gnn_cell(mod, shape_name, info, mesh):
    from repro.gnn.config import GNNConfig
    from repro.gnn.graph import GraphBatch
    from repro.gnn.models import init_params, train_step

    cfg: GNNConfig = mod.FULL
    cfg = type(cfg)(**{**cfg.__dict__, "d_in": info["d_feat"], "n_classes": max(info["n_classes"], 2)})
    N, E = _pad512(info["n_nodes"]), _pad512(info["n_edges"])
    ng = info.get("n_graphs", 1)
    sds = jax.ShapeDtypeStruct
    g = GraphBatch(
        node_feat=sds((N, info["d_feat"]), jnp.float32),
        edge_src=sds((E,), jnp.int32),
        edge_dst=sds((E,), jnp.int32),
        node_mask=sds((N,), jnp.bool_),
        edge_mask=sds((E,), jnp.bool_),
        labels=sds((N,), jnp.int32),
        positions=sds((N, 3), jnp.float32) if cfg.needs_positions else None,
        graph_ids=sds((N,), jnp.int32) if ng > 1 else None,
        n_graphs=ng,
    )
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    opt = adamw(1e-3)
    opt_state = jax.eval_shape(opt.init, params)
    step = train_step(cfg, opt)
    targets = sds((ng,), jnp.float32) if cfg.kind in ("egnn", "nequip") else None

    repl = lambda t: jax.tree_util.tree_map(lambda l: NamedSharding(mesh, P()), t)
    gshard = GraphBatch(
        node_feat=_ns(mesh, (N, info["d_feat"]), (ALL_AXES, None)),
        edge_src=_ns(mesh, (E,), (ALL_AXES,)),
        edge_dst=_ns(mesh, (E,), (ALL_AXES,)),
        node_mask=_ns(mesh, (N,), (ALL_AXES,)),
        edge_mask=_ns(mesh, (E,), (ALL_AXES,)),
        labels=_ns(mesh, (N,), (ALL_AXES,)),
        positions=_ns(mesh, (N, 3), (ALL_AXES, None)) if cfg.needs_positions else None,
        graph_ids=_ns(mesh, (N,), (ALL_AXES,)) if ng > 1 else None,
        n_graphs=ng,
    )
    if targets is not None:
        return (
            step,
            (repl(params), repl(opt_state), gshard, NamedSharding(mesh, P())),
            (params, opt_state, g, targets),
        )
    return step, (repl(params), repl(opt_state), gshard), (params, opt_state, g)


# ---------------------------------------------------------------- recsys
def _rec_cell(mod, shape_name, info, mesh):
    from repro.recsys import twotower as tt
    from repro.recsys.config import TwoTowerConfig

    cfg: TwoTowerConfig = mod.FULL
    params = tt.abstract_params(cfg)
    pshard = tree_shardings(params, tt.param_spec_rule(cfg), mesh)
    sds = jax.ShapeDtypeStruct
    B = info["batch"]
    K, Fu, Fi, D = cfg.bag_size, cfg.user_fields, cfg.item_fields, cfg.embed_dim
    ub = sds((B, Fu, K), jnp.int32)
    um = sds((B, Fu, K), jnp.bool_)
    ubs = _ns(mesh, (B, Fu, K), (BATCH_AXES, None, None))

    if info["kind"] == "rec_train":
        opt = adamw(1e-3)
        opt_state = jax.eval_shape(opt.init, params)
        mshard = jax.tree_util.tree_map(
            lambda l, s: NamedSharding(mesh, add_data_axis(s.spec, l.shape, mesh)),
            opt_state,
            tree_shardings(opt_state, tt.param_spec_rule(cfg), mesh),
        )
        step = tt.train_step(cfg, opt)
        batch = dict(
            user_bags=ub, user_mask=um,
            item_bags=sds((B, Fi, K), jnp.int32),
            item_mask=sds((B, Fi, K), jnp.bool_),
            item_logq=sds((B,), jnp.float32),
        )
        bshard = dict(
            user_bags=ubs, user_mask=ubs,
            item_bags=_ns(mesh, (B, Fi, K), (BATCH_AXES, None, None)),
            item_mask=_ns(mesh, (B, Fi, K), (BATCH_AXES, None, None)),
            item_logq=_ns(mesh, (B,), (BATCH_AXES,)),
        )
        return step, (pshard, mshard, bshard), (params, opt_state, batch)

    if info["kind"] == "rec_serve":
        C = info["n_candidates"]
        item_emb = sds((B, C, D), jnp.float32)
        step = functools.partial(tt.serve_step, cfg)
        shardings = (pshard, ubs, ubs, _ns(mesh, (B, C, D), (BATCH_AXES, None, None)))
        return step, shardings, (params, ub, um, item_emb)

    if info["kind"] == "rec_retrieval":
        Nc = info["n_candidates"]
        corpus = sds((Nc, D), jnp.float32)
        step = functools.partial(tt.retrieval_step, cfg)
        shardings = (pshard, _ns(mesh, (B, Fu, K), ()), _ns(mesh, (B, Fu, K), ()),
                     _ns(mesh, (Nc, D), ("model", None)))
        return step, shardings, (params, ub, um, corpus)

    raise ValueError(info["kind"])


# ---------------------------------------------------------------- graph
def _graph_cell(mod, shape_name, info, mesh):
    from repro.distributed import graph_serve as gs

    cfg = mod.FULL
    step, shardings, args, _rt = gs.config_cell(
        cfg, mesh, use_cache=info["use_cache"], global_batch=info["batch"]
    )
    return step, shardings, args


def build_cell(arch_id: str, shape_name: str, mesh: Mesh):
    """(step_fn, in_shardings, abstract_args) for one dry-run cell."""
    mod = configs_pkg.get_arch(arch_id)
    info = mod.SHAPES[shape_name]
    set_mesh(mesh)
    if mod.FAMILY == "lm":
        return _lm_cell(mod, shape_name, info, mesh)
    if mod.FAMILY == "gnn":
        return _gnn_cell(mod, shape_name, info, mesh)
    if mod.FAMILY == "recsys":
        return _rec_cell(mod, shape_name, info, mesh)
    if mod.FAMILY == "graph":
        return _graph_cell(mod, shape_name, info, mesh)
    raise ValueError(mod.FAMILY)
