"""Serving driver for the paper's architecture: run the sharded transaction
runtime — owner-routed gR-Txs over the partitioned dual-CSR storage tier
with the co-partitioned cache — on a local debug mesh with real data and
report hit/overflow statistics plus the storage-tier memory profile.

  PYTHONPATH=src python -m repro.launch.serve --shards 4 --batches 10

The loop exercises the full serving life-cycle on a host:

- gR-Tx batches through ``ShardedTxnRuntime.serve_step``;
- the **sharded MissQueue drain**: ``serve_step``'s per-shard miss records
  land in per-owner CP queues (``ShardedMissDrain``) and each CP batch
  executes + inserts at a single owner shard — no host-side global-FIFO
  round-trip;
- interleaved gRW-Tx commits (``--write-every``) that fill the block recent
  regions, and **maintenance ticks** between batches: owner-local block
  compaction + capacity growth per ``MaintenancePolicy``, so the loop can
  run indefinitely without a host-side repartition.

On a real fleet the same ``ShardedTxnRuntime.serve_step`` compiles on the
production mesh (``graph_serve.config_cell`` / launch/dryrun.py prove it);
this driver exists so the serving path can be *executed* and validated
end-to-end on a host.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--vertices", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store-tier", default="partitioned",
                    choices=("partitioned", "replicated"))
    ap.add_argument("--write-every", type=int, default=2,
                    help="apply a small gRW commit every N batches "
                         "(0 disables writes; partitioned tier only)")
    ap.add_argument("--no-maintenance", action="store_true",
                    help="disable the between-batch maintenance ticks")
    args = ap.parse_args(argv)

    if args.shards > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.shards}"
        ).strip()
    import jax.numpy as jnp

    from repro.distributed.graph_serve import (
        GraphServeConfig, ShardedMissDrain, ShardedTxnRuntime, config_espec,
        config_plan_and_ttable,
    )
    from repro.distributed.sharding import flat_mesh
    from repro.graphstore import MaintenancePolicy, make_mutation_batch
    from repro.graphstore.store import ingest

    cfg = GraphServeConfig(
        name="serve-local", v_total=args.vertices, e_per_vertex=4,
        max_deg=16, max_leaves=16, cache_slots_total=4096, recent_cap=64,
    )
    espec = config_espec(cfg)
    plan, ttable = config_plan_and_ttable(cfg)
    rng = np.random.default_rng(args.seed)
    V = cfg.v_total
    # random graph matching the capacity profile
    es, ed, ep = [], [], []
    for v in range(V):
        for _ in range(int(rng.integers(0, cfg.max_deg // 2))):
            es.append(v)
            ed.append(int(rng.integers(0, V)))
            ep.append([int(rng.integers(0, 2))])
    vlabels = np.zeros(V, np.int32)
    vprops = rng.integers(0, 2, (V, cfg.n_vprops)).astype(np.int64)
    store = ingest(
        espec.store, vlabels, vprops, es, ed, [0] * len(es), np.array(ep)
    )

    mesh = flat_mesh(args.shards)
    rt = ShardedTxnRuntime(espec, mesh, store_tier=args.store_tier)
    partitioned = args.store_tier == "partitioned"
    if partitioned:
        sstate = rt.partition_store(store, elastic=True)
        rep = rt.store_bytes()
        print(
            f"store tier: {rep['per_shard_bytes']/2**20:.2f} MiB/shard "
            f"partitioned vs {rep['replicated_per_shard_bytes']/2**20:.2f} "
            f"MiB/shard replicated (ratio {rep['ratio']:.3f}, "
            f"ideal 1/n = {rep['ideal_ratio']:.3f})"
        )
    else:
        sstate = store
    cache = rt.empty_cache()
    tpl_meta = {0: (plan.hops[0].direction, plan.hops[0].edge_label)}
    # per-owner CP queues: each shard's miss records drain at that shard
    drain = ShardedMissDrain(rt, tpl_meta)
    policy = MaintenancePolicy(recent_fill_frac=0.5, grow_occupancy_frac=0.85)

    total = dict(requests=0, hits=0, misses=0, route_overflow=0)
    maint = dict(compactions=0, growths=0, commits=0, append_overflow=0)
    t0 = time.time()
    for b in range(args.batches):
        roots = rng.integers(0, V, args.batch).astype(np.int32)
        res, misses, m = rt.run_gr_tx_batch(sstate, cache, ttable, plan, roots)
        for k in total:
            total[k] += int(m[k])
        # CP-per-shard: misses route to their owner's queue and drain there
        drain.push(misses)
        cache = drain.drain(sstate, sstate, cache, ttable, 512)
        wm = None
        if partitioned and args.write_every and (b + 1) % args.write_every == 0:
            # a small upsert burst lands in the block recent regions
            ne = [
                (int(rng.integers(0, V)), int(rng.integers(0, V)), 0,
                 [int(rng.integers(0, 2))])
                for _ in range(8)
            ]
            mb = make_mutation_batch(espec.store, new_edges=ne)
            sstate, cache, wm = rt.run_grw_tx(sstate, cache, ttable, mb)
            # under --no-maintenance this is the degradation signal the
            # flag exists to demonstrate — report it, don't crash on it
            maint["append_overflow"] += wm["store_append_overflow"]
            maint["commits"] += 1
        if partitioned and not args.no_maintenance and wm is not None:
            # occupancy/recent fill only move on commits, so ticks run (and
            # read signals) only on commit batches — reusing the occupancy
            # the commit metrics already carry
            sstate, tick = rt.maintenance_tick(sstate, policy, occupancy=dict(
                max_occupancy=wm["store_occupancy_max"],
                max_recent_fill=wm["store_recent_fill_max"],
            ))
            maint["compactions"] += int(tick["compacted"])
            maint["growths"] += int(tick["grown_to"] is not None)
    dt = time.time() - t0
    assert res.shape == (args.batch, espec.result_width)
    print(
        f"{args.batches} batches x {args.batch} gR-Txs on {args.shards} "
        f"shards [{args.store_tier}]: requests={total['requests']} "
        f"hits={total['hits']} misses={total['misses']} "
        f"populated={drain.committed} route_overflow={total['route_overflow']} "
        f"({dt/args.batches*1e3:.1f} ms/batch after compile)"
    )
    if partitioned:
        occ = rt.store_occupancy(sstate)
        print(
            f"maintenance: {maint['commits']} gRW commits, "
            f"{maint['compactions']} compactions, {maint['growths']} growths, "
            f"{maint['append_overflow']} appends dropped; "
            f"occupancy max {occ['max_occupancy']:.3f}, recent fill max "
            f"{occ['max_recent_fill']}/{occ['recent_blk_cap']}"
        )
    return total


if __name__ == "__main__":
    main()
