"""Serving driver for the paper's architecture: run the distributed
one-hop serve step (shard_map, all_to_all routing, co-partitioned cache)
on a local debug mesh with real data and report hit/drop statistics.

  PYTHONPATH=src python -m repro.launch.serve --shards 4 --batches 10

On a real fleet the same ``build_serve_step`` runs on the production mesh
(launch/dryrun.py proves it compiles there); this driver exists so the
serving path can be *executed* and validated end-to-end on a host.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--vertices", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.shards > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.shards}"
        ).strip()
    import jax
    import jax.numpy as jnp

    from repro.distributed.graph_serve import GraphServeConfig, build_serve_step
    from repro.launch.mesh import make_debug_mesh

    cfg = GraphServeConfig(
        name="serve-local", v_total=args.vertices, e_per_vertex=4,
        max_deg=16, max_leaves=16, cache_slots_total=4096,
    )
    mesh = make_debug_mesh(args.shards, 1)
    rng = np.random.default_rng(args.seed)
    V, E, C = cfg.v_total, cfg.e_total(), cfg.cache_slots_total
    deg = rng.integers(0, cfg.max_deg // 2, V).astype(np.int32)
    n = args.shards
    Vloc, Eloc = V // n, E // n
    start = np.zeros(V, np.int32)
    dst = np.zeros(E, np.int32)
    eprop = np.zeros(E, np.int32)
    for s in range(n):  # per-shard local CSR blocks
        off = 0
        for v in range(s * Vloc, (s + 1) * Vloc):
            start[v] = off
            d = int(deg[v])
            if off + d > Eloc:
                d = Eloc - off
                deg[v] = d
            dst[s * Eloc + off : s * Eloc + off + d] = rng.integers(0, V, d)
            eprop[s * Eloc + off : s * Eloc + off + d] = rng.integers(0, 2, d)
            off += d
    vprop = rng.integers(0, 2, V).astype(np.int32)
    state = dict(
        deg=jnp.asarray(deg), start=jnp.asarray(start), dst=jnp.asarray(dst),
        eprop=jnp.asarray(eprop), vprop=jnp.asarray(vprop),
        c_root=jnp.full((C,), -1, jnp.int32), c_fp=jnp.zeros((C,), jnp.uint32),
        c_len=jnp.zeros((C,), jnp.int32),
        c_vals=jnp.full((C, cfg.max_leaves), -1, jnp.int32),
        c_valid=jnp.zeros((C,), bool),
    )
    step = jax.jit(build_serve_step(cfg, mesh, use_cache=True, global_batch=args.batch))
    total = dict(processed=0, hits=0, route_dropped=0)
    t0 = time.time()
    for b in range(args.batches):
        roots = jnp.asarray(rng.integers(0, V, args.batch).astype(np.int32))
        res, stats = step(state, roots)
        for k in total:
            total[k] += int(stats[k])
    dt = time.time() - t0
    print(
        f"{args.batches} batches x {args.batch} gR-Txs on {n} shards: "
        f"processed={total['processed']} hits={total['hits']} "
        f"route_dropped={total['route_dropped']} "
        f"({dt/args.batches*1e3:.1f} ms/batch after compile)"
    )
    return total


if __name__ == "__main__":
    main()
