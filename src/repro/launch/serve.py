"""Serving driver for the paper's architecture: run the sharded transaction
runtime — owner-routed gR-Txs over the partitioned dual-CSR storage tier
with the co-partitioned cache — on a local debug mesh with real data and
report hit/overflow statistics plus the storage-tier memory profile.

  PYTHONPATH=src python -m repro.launch.serve --shards 4 --batches 10

On a real fleet the same ``ShardedTxnRuntime.serve_step`` compiles on the
production mesh (``graph_serve.config_cell`` / launch/dryrun.py prove it);
this driver exists so the serving path can be *executed* and validated
end-to-end on a host, including the CP population loop draining the served
misses back into the owner shards' cache blocks.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main(argv=None):
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--vertices", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store-tier", default="partitioned",
                    choices=("partitioned", "replicated"))
    args = ap.parse_args(argv)

    if args.shards > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.shards}"
        ).strip()
    import jax.numpy as jnp

    from repro.distributed.graph_serve import (
        GraphServeConfig, ShardedTxnRuntime, config_espec,
        config_plan_and_ttable,
    )
    from repro.distributed.sharding import flat_mesh
    from repro.graphstore.store import ingest

    cfg = GraphServeConfig(
        name="serve-local", v_total=args.vertices, e_per_vertex=4,
        max_deg=16, max_leaves=16, cache_slots_total=4096, recent_cap=64,
    )
    espec = config_espec(cfg)
    plan, ttable = config_plan_and_ttable(cfg)
    rng = np.random.default_rng(args.seed)
    V = cfg.v_total
    # random graph matching the capacity profile
    es, ed, ep = [], [], []
    for v in range(V):
        for _ in range(int(rng.integers(0, cfg.max_deg // 2))):
            es.append(v)
            ed.append(int(rng.integers(0, V)))
            ep.append([int(rng.integers(0, 2))])
    vlabels = np.zeros(V, np.int32)
    vprops = rng.integers(0, 2, (V, cfg.n_vprops)).astype(np.int64)
    store = ingest(
        espec.store, vlabels, vprops, es, ed, [0] * len(es), np.array(ep)
    )

    mesh = flat_mesh(args.shards)
    rt = ShardedTxnRuntime(espec, mesh, store_tier=args.store_tier)
    if args.store_tier == "partitioned":
        sstate = rt.partition_store(store)
        rep = rt.store_bytes()
        print(
            f"store tier: {rep['per_shard_bytes']/2**20:.2f} MiB/shard "
            f"partitioned vs {rep['replicated_per_shard_bytes']/2**20:.2f} "
            f"MiB/shard replicated (ratio {rep['ratio']:.3f}, "
            f"ideal 1/n = {rep['ideal_ratio']:.3f})"
        )
    else:
        sstate = store
    cache = rt.empty_cache()
    pop = rt.populator({0: (plan.hops[0].direction, plan.hops[0].edge_label)})

    total = dict(requests=0, hits=0, misses=0, route_overflow=0)
    t0 = time.time()
    for b in range(args.batches):
        roots = rng.integers(0, V, args.batch).astype(np.int32)
        res, misses, m = rt.run_gr_tx_batch(sstate, cache, ttable, plan, roots)
        for k in total:
            total[k] += int(m[k])
        # CP threads drain the miss queue into the owner shards' blocks
        pop.queue.push(misses)
        cache = pop.drain(sstate, sstate, cache, ttable, 512)
    dt = time.time() - t0
    assert res.shape == (args.batch, espec.result_width)
    print(
        f"{args.batches} batches x {args.batch} gR-Txs on {args.shards} "
        f"shards [{args.store_tier}]: requests={total['requests']} "
        f"hits={total['hits']} misses={total['misses']} "
        f"populated={pop.committed} route_overflow={total['route_overflow']} "
        f"({dt/args.batches*1e3:.1f} ms/batch after compile)"
    )
    return total


if __name__ == "__main__":
    main()
