"""Serving driver for the paper's architecture: run the sharded transaction
runtime — owner-routed gR-Txs over the partitioned dual-CSR storage tier
with the co-partitioned cache — on a local debug mesh with real data and
report hit/overflow statistics plus the storage-tier memory profile.

  PYTHONPATH=src python -m repro.launch.serve --shards 4 --batches 10

The loop exercises the full serving life-cycle on a host:

- gR-Tx batches through ``ShardedTxnRuntime.serve_step``, each pinning its
  read epoch in the journal's ``EpochRegistry`` (the liveness fence that
  makes tombstone purge safe to enable);
- the **sharded MissQueue drain**: ``serve_step``'s per-shard miss records
  land in per-owner CP queues (``ShardedMissDrain``) and each CP batch
  executes + inserts at a single owner shard — no host-side global-FIFO
  round-trip;
- interleaved gRW-Tx commits (``--write-every``) with the **on-device
  maintenance gate**: the commit step itself compacts over-threshold
  blocks inside ``lax.cond`` (no per-batch host round-trip), with purge
  enabled per commit only when ``EpochRegistry.safe_to_purge`` allows;
- **write-behind durability**: every commit is appended to the
  ``WriteBehindJournal`` (async coalescing flusher runs behind the loop)
  and checkpointed every ``--checkpoint-every`` commits, so a crashed
  run restarts via ``journal.replay`` instead of losing the store;
- **hitless capacity growth**: when commit metrics cross the occupancy
  high-water, the next tier's gR/gRW/CP steps compile on a background
  thread (``precompile_next_tier``) while serving continues on the current
  tier; the store hot-swaps at a batch boundary (``swap_to_next_tier``)
  once they are ready — the growth pause is one device pad, not a
  recompile.

On a real fleet the same ``ShardedTxnRuntime.serve_step`` compiles on the
production mesh (``graph_serve.config_cell`` / launch/dryrun.py prove it);
this driver exists so the serving path can be *executed* and validated
end-to-end on a host.
"""

from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np


def main(argv=None):
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--vertices", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store-tier", default="partitioned",
                    choices=("partitioned", "replicated"))
    ap.add_argument("--write-every", type=int, default=2,
                    help="apply a small gRW commit every N batches "
                         "(0 disables writes; partitioned tier only)")
    ap.add_argument("--no-maintenance", action="store_true",
                    help="disable the on-device maintenance gate and "
                         "hitless growth")
    ap.add_argument("--journal-dir", default=None,
                    help="write-behind journal root (default: a tempdir; "
                         "pass a persistent path to make restarts real)")
    ap.add_argument("--no-journal", action="store_true",
                    help="disable write-behind durability")
    ap.add_argument("--checkpoint-every", type=int, default=8,
                    help="checkpoint the store every N commits")
    ap.add_argument("--purge", action="store_true",
                    help="reclaim tombstones at gated compactions when the "
                         "liveness epoch allows")
    ap.add_argument("--inject-crash", default=None, metavar="SHARD:BATCH",
                    help="chaos: lose shard SHARD's storage from batch "
                         "BATCH (serving degrades, writes queue, recovery "
                         "replays — requires the journal)")
    ap.add_argument("--recover-after", type=int, default=4,
                    help="batches of degraded serving before recovery-as-"
                         "migration runs for the crashed shard")
    ap.add_argument("--hedge-after", type=float, default=0.05,
                    help="straggler hedge deadline in seconds for the gR "
                         "read path")
    ap.add_argument("--io-timeout", type=float, default=None,
                    help="wall-clock bound per journal flush / checkpoint "
                         "write attempt (CallTimeout + retry past it)")
    ap.add_argument("--full-checkpoints", action="store_true",
                    help="periodic checkpoints snapshot the whole store "
                         "(default: incremental — dirty owners only)")
    ap.add_argument("--migrate", action="store_true",
                    help="attach the routing table and run the hot-vertex "
                         "migration policy loop at batch boundaries "
                         "(partitioned tier only)")
    ap.add_argument("--hot-frac", type=float, default=0.0,
                    help="fraction of each batch's roots drawn from a hot "
                         "set colliding on one owner (the skew --migrate "
                         "exists to fix; 0 = uniform)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write structured telemetry (span / snapshot / "
                         "report events) as JSONL to PATH; validate with "
                         "`python -m repro.obs.validate PATH`")
    ap.add_argument("--snapshot-every", type=int, default=5,
                    help="emit a telemetry snapshot event every N batches "
                         "(0 disables periodic snapshots; the end-of-run "
                         "report is always emitted)")
    args = ap.parse_args(argv)

    if args.shards > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.shards}"
        ).strip()
    import jax

    from repro.distributed.fault import (
        HedgedCalls, NodeFailure, ShardFaultPlan,
    )
    from repro.distributed.failover import FailoverController
    from repro.distributed.graph_serve import (
        GraphServeConfig, ShardedMissDrain, ShardedTxnRuntime, config_espec,
        config_plan_and_ttable,
    )
    from repro.distributed.sharding import flat_mesh
    from repro.graphstore import (
        DeviceGate, MaintenancePolicy, WriteBehindJournal, make_mutation_batch,
    )
    from repro.distributed.routing import RoutingTableHost
    from repro.graphstore.migration import HotSetTracker, MigrationEngine
    from repro.graphstore.store import ingest
    from repro.obs.metrics import OWNER_STAGE_FIELDS
    from repro.obs.telemetry import ServeTelemetry

    cfg = GraphServeConfig(
        name="serve-local", v_total=args.vertices, e_per_vertex=4,
        max_deg=16, max_leaves=16, cache_slots_total=4096, recent_cap=64,
    )
    espec = config_espec(cfg)
    plan, ttable = config_plan_and_ttable(cfg)
    rng = np.random.default_rng(args.seed)
    V = cfg.v_total
    # random graph matching the capacity profile
    es, ed, ep = [], [], []
    for v in range(V):
        for _ in range(int(rng.integers(0, cfg.max_deg // 2))):
            es.append(v)
            ed.append(int(rng.integers(0, V)))
            ep.append([int(rng.integers(0, 2))])
    vlabels = np.zeros(V, np.int32)
    vprops = rng.integers(0, 2, (V, cfg.n_vprops)).astype(np.int64)
    store = ingest(
        espec.store, vlabels, vprops, es, ed, [0] * len(es), np.array(ep)
    )

    # telemetry: per-owner stage attribution rides the runtime's existing
    # stacked all-reduce; the tracer times the host-side phases. JSONL
    # export only happens under --trace; the histograms + end-of-run
    # report are always on.
    telemetry = ServeTelemetry(args.shards, trace_path=args.trace)
    mesh = flat_mesh(args.shards)
    rt = ShardedTxnRuntime(espec, mesh, store_tier=args.store_tier,
                           tracer=telemetry.tracer)
    partitioned = args.store_tier == "partitioned"
    if partitioned:
        sstate = rt.partition_store(store, elastic=True)
        rep = rt.store_bytes()
        print(
            f"store tier: {rep['per_shard_bytes']/2**20:.2f} MiB/shard "
            f"partitioned vs {rep['replicated_per_shard_bytes']/2**20:.2f} "
            f"MiB/shard replicated (ratio {rep['ratio']:.3f}, "
            f"ideal 1/n = {rep['ideal_ratio']:.3f})"
        )
    else:
        sstate = store
    cache = rt.empty_cache()
    tpl_meta = {0: (plan.hops[0].direction, plan.hops[0].edge_label)}
    # per-owner CP queues: each shard's miss records drain at that shard
    drain = ShardedMissDrain(rt, tpl_meta)
    policy = MaintenancePolicy(recent_fill_frac=0.5, grow_occupancy_frac=0.85)
    maintain = partitioned and not args.no_maintenance
    gate_base = DeviceGate(recent_fill_frac=policy.recent_fill_frac)

    journal = None
    if partitioned and not args.no_journal:
        root = args.journal_dir or os.path.join(
            tempfile.mkdtemp(prefix="serve-journal-"), "journal"
        )
        journal = WriteBehindJournal(root, rt.n, io_timeout=args.io_timeout,
                                     tracer=telemetry.tracer)
        journal.checkpoint(
            sstate, e_blk_cap=rt.pspec.e_blk_cap,
            recent_blk_cap=rt.pspec.recent_blk_cap,
            store_version=int(jax.device_get(sstate.version)),
        )
        journal.start()  # async coalescing flusher behind the loop
        print(f"journal: {root} (checkpoint every "
              f"{args.checkpoint_every} commits)")

    failover = None
    crash_shard = crash_batch = None
    if args.inject_crash is not None:
        if journal is None:
            ap.error("--inject-crash requires the journal (degraded-mode "
                     "writes queue there)")
        crash_shard, crash_batch = (int(x) for x in args.inject_crash.split(":"))
        fault_plan = ShardFaultPlan(crash={crash_shard: crash_batch})
        failover = FailoverController(
            rt, journal, ttable, plan=fault_plan, hedge=HedgedCalls(),
            hedge_after=args.hedge_after,
        )
        print(f"chaos: shard {crash_shard} crashes at batch {crash_batch}, "
              f"recovery after {args.recover_after} degraded batches")

    engine = None
    hot = None
    if args.migrate:
        if not partitioned:
            ap.error("--migrate requires the partitioned store tier")
        # the routing table is a traced input to the already-compiled serve
        # step: attaching it (and every later epoch bump) never recompiles
        rhost = RoutingTableHost(rt.n)
        rt.attach_routing(rhost)
        engine = MigrationEngine(
            rt.pspec, rhost, tracker=HotSetTracker(), journal=journal,
            detector=failover.detector if failover is not None else None,
        )
        print("routing: table attached (epoch 0), migration policy loop on")
    if args.hot_frac > 0:
        # hot roots all land on one owner under the modulo layout
        hot = np.array([v for v in range(V) if v % args.shards == 1][:16],
                       np.int64)
    FR = OWNER_STAGE_FIELDS.index("frontier_rows")

    total = dict(requests=0, hits=0, misses=0, route_overflow=0, deferred=0,
                 locality_routed=0, locality_retry_rows=0)
    avail = dict(unavailable_batches=0, degraded_batches=0, deferred_rows=0,
                 queued_commits=0, recovery_seconds=0.0)
    maint = dict(device_compactions=0, growths=0, commits=0,
                 append_overflow=0, purges=0)
    t0 = time.time()
    for b in range(args.batches):
        # hot-swap at the batch boundary once the background pre-compile
        # of the next capacity tier is ready
        if maintain and rt._next_tier is not None and rt._next_tier.ready.is_set():
            sstate, swap = rt.swap_to_next_tier(sstate)
            if journal is not None:
                journal.append_grow(
                    rt.pspec.e_blk_cap, rt.pspec.recent_blk_cap
                )
            maint["growths"] += 1
            print(f"batch {b}: hot-swapped to e_blk_cap="
                  f"{swap['e_blk_cap']} in {swap['swap_seconds']*1e3:.1f} ms "
                  f"(precompiled {swap['compiled_steps']} steps in "
                  f"{swap['precompile_seconds']:.1f} s off-loop)")
        roots = rng.integers(0, V, args.batch).astype(np.int32)
        if hot is not None:
            pick = rng.random(args.batch) < args.hot_frac
            zipf = np.minimum(rng.zipf(1.2, args.batch) - 1, len(hot) - 1)
            roots = np.where(pick, hot[zipf], roots).astype(np.int32)
        if failover is not None:
            failover.probe(b)
            try:
                res, _deferred, misses, m = failover.run_gr(
                    sstate, cache, plan, roots, b
                )
            except NodeFailure:
                # detection gap: the dead owner is needed but not yet
                # marked down — this batch IS the unavailability window
                avail["unavailable_batches"] += 1
                continue
            avail["deferred_rows"] += m["deferred_rows"]
            avail["degraded_batches"] += int(bool(failover.detector.down()))
        elif journal is not None:
            # pin the gR snapshot's epoch: purge may not reclaim under us;
            # the scope releases on every exit path (no leaked pins)
            with journal.epochs.pin_scope():
                res, misses, m = rt.run_gr_tx_batch(
                    sstate, cache, ttable, plan, roots
                )
        else:
            res, misses, m = rt.run_gr_tx_batch(
                sstate, cache, ttable, plan, roots
            )
        for k in total:
            total[k] += int(m.get(k, 0))
        # fold the batch into the latency histograms + owner attribution
        telemetry.record_gr(rt.last_step_seconds, m,
                            owner_stage=rt.last_owner_stage)
        # CP-per-shard: misses route to their owner's queue and drain there
        tcp = time.perf_counter()
        with telemetry.tracer.span("cp_drain"):
            drain.push(misses)
            cache = drain.drain(sstate, sstate, cache, ttable, 512)
        telemetry.record_cp_drain(time.perf_counter() - tcp)
        if (failover is not None and crash_shard in failover.detector.down()
                and b >= crash_batch + args.recover_after):
            sstate, cache, rinfo = failover.recover(sstate, cache, crash_shard)
            avail["queued_commits"] = rinfo["drained_commits"]
            avail["recovery_seconds"] = round(rinfo["recovery_seconds"], 3)
            print(f"batch {b}: recovered shard {crash_shard} — replayed "
                  f"{rinfo['replayed_commits']} commits to seq "
                  f"{rinfo['replayed_to_seq']}, drained "
                  f"{rinfo['drained_commits']} queued, "
                  f"{rinfo['recovery_seconds']*1e3:.0f} ms")
        if engine is not None:
            # batch boundary: observe root heat, maybe run one journal-first
            # migration round, and install the spliced store + bumped table
            # together so no in-flight batch sees a torn layout
            engine.observe(roots)
            ps2, moves = engine.step(sstate, rt.last_owner_stage[:, FR])
            if moves:
                sstate = jax.device_put(ps2, rt.store_sharding())
                print(f"batch {b}: migrated {moves} "
                      f"(table epoch -> {engine.rhost.epoch})")
        wm = None
        if partitioned and args.write_every and (b + 1) % args.write_every == 0:
            # a small upsert burst lands in the block recent regions
            ne = [
                (int(rng.integers(0, V)), int(rng.integers(0, V)), 0,
                 [int(rng.integers(0, 2))])
                for _ in range(8)
            ]
            mb = make_mutation_batch(espec.store, new_edges=ne)
            gate = None
            if maintain:
                # purge only behind the liveness epoch + journal checkpoint
                purge_ok = args.purge and journal is not None and (
                    journal.epochs.safe_to_purge(
                        journal.epochs.current, journal
                    )
                )
                gate = gate_base._replace(purge=purge_ok)
                maint["purges"] += int(purge_ok)
            tw = time.perf_counter()
            if failover is not None:
                # degraded mode queues the commit durably instead of
                # applying (order-dependent ids; see distributed.failover)
                sstate, cache, wm = failover.run_grw(
                    sstate, cache, mb, gate=gate
                )
            else:
                sstate, cache, wm = rt.run_grw_tx(
                    sstate, cache, ttable, mb, gate=gate, journal=journal
                )
            telemetry.record_grw(time.perf_counter() - tw)
            # under --no-maintenance this is the degradation signal the
            # flag exists to demonstrate — report it, don't crash on it
            maint["append_overflow"] += wm.get("store_append_overflow", 0)
            maint["device_compactions"] += wm.get("device_compactions", 0)
            maint["commits"] += 1
            if (journal is not None and not wm.get("queued", 0)
                    and maint["commits"] % args.checkpoint_every == 0):
                ckpt = (journal.checkpoint if args.full_checkpoints
                        else journal.checkpoint_incremental)
                ckpt(
                    sstate, e_blk_cap=rt.pspec.e_blk_cap,
                    recent_blk_cap=rt.pspec.recent_blk_cap,
                    store_version=int(jax.device_get(sstate.version)),
                )
        if (
            maintain and wm is not None and rt._next_tier is None
            and wm.get("store_occupancy_max", 0) >= policy.grow_occupancy_frac
        ):
            # occupancy high-water: compile the next tier in the background
            # while this tier keeps serving; the swap happens at a later
            # batch boundary
            rt.precompile_next_tier(
                int(np.ceil(rt.pspec.e_blk_cap * policy.growth_factor)),
                ttable,
                gr_plans=[(plan, max(args.batch, rt.n))],
                grw_policies=[("write-around", gate_base),
                              ("write-around",
                               gate_base._replace(purge=True))]
                if args.purge else [("write-around", gate_base)],
                compact_purges=(False,),
                pop_steps=[(tpl_meta, 0, bkt) for bkt in (8, 16, 32)],
            )
            print(f"batch {b}: occupancy "
                  f"{wm['store_occupancy_max']:.2f} crossed high-water — "
                  f"precompiling next tier in the background")
        if args.snapshot_every and (b + 1) % args.snapshot_every == 0:
            telemetry.snapshot(b)
    dt = time.time() - t0
    assert res.shape == (args.batch, espec.result_width)
    print(
        f"{args.batches} batches x {args.batch} gR-Txs on {args.shards} "
        f"shards [{args.store_tier}]: requests={total['requests']} "
        f"hits={total['hits']} misses={total['misses']} "
        f"populated={drain.committed} route_overflow={total['route_overflow']} "
        f"({dt/args.batches*1e3:.1f} ms/batch after compile)"
    )
    if partitioned:
        occ = rt.store_occupancy(sstate)
        print(
            f"maintenance: {maint['commits']} gRW commits, "
            f"{maint['device_compactions']} device compactions "
            f"({maint['purges']} purge-enabled), {maint['growths']} "
            f"hot-swaps, {maint['append_overflow']} appends dropped; "
            f"occupancy max {occ['max_occupancy']:.3f}, recent fill max "
            f"{occ['max_recent_fill']}/{occ['recent_blk_cap']}"
        )
    if journal is not None:
        journal.stop(final_flush=True)
        jm = journal.metrics()
        total.update({k: jm[k] for k in (
            "journal_lag_batches", "flush_queue_depth", "pinned_epoch_min",
            "open_pins", "leaked_pin_releases",
        )})
        total["swap_events"] = rt.swap_events
        print(
            f"durability: journal_lag_batches={jm['journal_lag_batches']} "
            f"flush_queue_depth={jm['flush_queue_depth']} "
            f"flushes={jm['flushes']} flushed_records={jm['flushed_records']} "
            f"checkpoint_seq={jm['checkpoint_seq']} "
            f"pinned_epoch_min={jm['pinned_epoch_min']} "
            f"open_pins={jm['open_pins']} "
            f"leaked_pin_releases={jm['leaked_pin_releases']} "
            f"swap_events={rt.swap_events}"
        )
    if failover is not None:
        fm = failover.metrics()
        total.update(avail)
        total.update({k: fm[k] for k in (
            "detections", "recoveries", "hedge_rate",
        ) if k in fm})
        print(
            f"failover: unavailable_batches={avail['unavailable_batches']} "
            f"degraded_batches={avail['degraded_batches']} "
            f"deferred_rows={avail['deferred_rows']} "
            f"queued_commits_drained={avail['queued_commits']} "
            f"recovery_seconds={avail['recovery_seconds']} "
            f"detections={fm['detections']} recoveries={fm['recoveries']} "
            f"hedge_rate={fm.get('hedge_rate', 0.0)}"
        )
    if engine is not None:
        mm = engine.metrics()
        total.update({k: mm[k] for k in (
            "migration_rounds", "migrated_vertices", "migrated_rows",
            "migration_deferred_rounds", "table_epoch",
        )})
        total["route_cap_retries"] = rt.route_cap_retries
        print(
            f"routing: migration_rounds={mm['migration_rounds']} "
            f"migrated_vertices={mm['migrated_vertices']} "
            f"migrated_rows={mm['migrated_rows']} "
            f"deferred_rounds={mm['migration_deferred_rounds']} "
            f"table_epoch={mm['table_epoch']} "
            f"storage_exceptions={mm['storage_exceptions']} "
            f"cache_exceptions={mm['cache_exceptions']} "
            f"locality_routed={total['locality_routed']} "
            f"locality_retry_rows={total['locality_retry_rows']} "
            f"route_cap_retries={rt.route_cap_retries}"
        )
    # end-of-run telemetry report (emitted after journal.stop so the final
    # flush's span is counted)
    report = telemetry.report()

    def _ms(v):
        return "n/a" if v is None else f"{v * 1e3:.2f}ms"

    for cls in ("gr_cached", "gr_uncached", "grw", "cp_drain"):
        p = report["latency"][cls]
        print(
            f"latency[{cls}]: p50={_ms(p['p50'])} p95={_ms(p['p95'])} "
            f"p99={_ms(p['p99'])} p99.9={_ms(p['p999'])} (n={p['count']})"
        )
    print("hit_locality per shard: "
          + " ".join(f"{v:.2f}" for v in report["hit_locality"]))
    total["trace_events"] = (telemetry.writer.events_written
                             if telemetry.writer is not None else 0)
    if args.trace:
        print(f"trace: {args.trace} ({total['trace_events']} events)")
    telemetry.close()
    return total


if __name__ == "__main__":
    main()
