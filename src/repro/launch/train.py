"""Training driver: real steps on the local device(s), production sharding
when a mesh is active, checkpoint/restart, optional failure injection and
int8 gradient compression.

  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as configs_pkg
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.lm import LMConfig, init_params, train_step
from repro.optim import adamw, chain, clip_by_global_norm, cosine_schedule, int8_compress_grads


def synthetic_batches(vocab: int, batch: int, seq: int, seed: int = 0):
    """Deterministic synthetic LM data pipeline (zipfian unigram stream with
    induced bigram structure so the loss has something to learn)."""
    rng = np.random.default_rng(seed)
    base = rng.zipf(1.5, size=vocab * 4) % vocab
    while True:
        start = rng.integers(0, len(base) - (batch * (seq + 1)) - 1)
        chunk = base[start : start + batch * (seq + 1)].reshape(batch, seq + 1)
        yield jnp.asarray(chunk[:, :-1], jnp.int32), jnp.asarray(chunk[:, 1:], jnp.int32)


def build(cfg: LMConfig, lr: float, total_steps: int, compress: bool):
    opt = chain(
        clip_by_global_norm(1.0),
        adamw(cosine_schedule(lr, warmup=min(100, total_steps // 10 + 1), total=total_steps)),
    )
    base_step = train_step(cfg, opt)

    if not compress:
        return opt, jax.jit(base_step)

    def step_with_compression(params, opt_state, residual, tokens, labels):
        loss_fn_ = lambda p: __import__("repro.lm.model", fromlist=["loss_fn"]).loss_fn(cfg, p, tokens, labels)
        loss, grads = jax.value_and_grad(loss_fn_)(params)
        grads, residual = int8_compress_grads(grads, residual)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)
        return params, opt_state, residual, {"loss": loss}

    return opt, jax.jit(step_with_compression)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    mod = configs_pkg.get_arch(args.arch)
    assert mod.FAMILY == "lm", "train.py drives the LM family"
    cfg: LMConfig = mod.SMOKE if args.smoke else mod.FULL
    if args.seq % cfg.loss_chunk != 0:
        cfg = type(cfg)(**{**cfg.__dict__, "loss_chunk": min(args.seq, 16)})
    print(f"arch={cfg.name} params={cfg.param_count():,} steps={args.steps}")

    opt, step = build(cfg, args.lr, args.steps, args.compress_grads)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt_state = opt.init(params)
    residual = None
    if args.compress_grads:
        residual = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    start = 0
    if args.resume and args.ckpt and (ls := latest_step(args.ckpt)) is not None:
        params, opt_state = restore_checkpoint(args.ckpt, ls, (params, opt_state))
        start = ls
        print(f"resumed from step {ls}")

    data = synthetic_batches(cfg.vocab, args.batch, args.seq)
    losses = []
    t0 = time.time()
    for i in range(start, args.steps):
        tokens, labels = next(data)
        if args.compress_grads:
            params, opt_state, residual, m = step(params, opt_state, residual, tokens, labels)
        else:
            params, opt_state, m = step(params, opt_state, tokens, labels)
        losses.append(float(m["loss"]))
        if (i + 1) % args.log_every == 0:
            dt = (time.time() - t0) / (i + 1 - start)
            print(f"step {i+1}: loss={losses[-1]:.4f} ({dt*1e3:.0f} ms/step)")
        if args.ckpt and (i + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt, i + 1, (params, opt_state))
    if losses:
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    else:
        print("nothing to do (already at target step)")
    return losses


if __name__ == "__main__":
    main()
