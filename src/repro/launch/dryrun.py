import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost analysis and collective bytes.

MUST be run as its own process (the XLA flag above is set before any jax
import). Results accumulate under experiments/dryrun/ as one JSON per cell
so partial progress survives crashes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch gat-cora --mesh multipod
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro import configs as configs_pkg
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u32|s8|u8|pred)\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4, "u32": 4, "s8": 1, "u8": 1, "pred": 1}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dtype]
    return total


_OP_RE = re.compile(
    r"\s((?:all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?)\(%?"
)


def collective_bytes(hlo: str) -> dict:
    """Sum output bytes of every collective op in the optimized HLO.

    Output-shape bytes approximate the data each collective materializes per
    device module; '-done' halves of async pairs never match (no shape
    before them), so nothing double counts."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", stripped)
        if not m:
            continue
        rhs = m.group(1)
        op = _OP_RE.search(rhs)
        if not op:
            continue
        kind = op.group(1).replace("-start", "")
        shape_part = rhs[: op.start(1)]
        out[kind] += _shape_bytes(shape_part)
        counts[kind] += 1
    out["counts"] = counts
    return out


def _loop_analysis(hlo: str) -> dict:
    from repro.launch.hlo_analysis import analyze

    try:
        a = analyze(hlo)
        return dict(
            collectives_weighted=a["weighted"],
            dominant_trip=a["dominant_trip"],
            n_loops=len(a["loops"]),
            trips=sorted({l["trip"] for l in a["loops"]}, reverse=True)[:8],
        )
    except Exception as e:  # noqa: BLE001
        return dict(error=str(e))


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str) -> dict:
    multi = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi)
    t0 = time.time()
    rec = dict(arch=arch, shape=shape, mesh=mesh_kind, ok=False)
    try:
        step, shardings, args = build_cell(arch, shape, mesh)
        with mesh:
            lowered = jax.jit(step, in_shardings=shardings).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        rec.update(
            ok=True,
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=getattr(mem, "argument_size_in_bytes", None),
                output_bytes=getattr(mem, "output_size_in_bytes", None),
                temp_bytes=getattr(mem, "temp_size_in_bytes", None),
                generated_code_bytes=getattr(mem, "generated_code_size_in_bytes", None),
            ),
            cost=dict(
                flops=cost.get("flops", 0.0),
                bytes_accessed=cost.get("bytes accessed", 0.0),
                transcendentals=cost.get("transcendentals", 0.0),
            ),
            collectives=collective_bytes(hlo),
            loop_analysis=_loop_analysis(hlo),
            hlo_lines=len(hlo.splitlines()),
        )
        print(
            f"[OK ] {arch}/{shape}/{mesh_kind}: compile={t_compile:.0f}s "
            f"flops={rec['cost']['flops']:.3e} "
            f"coll={sum(v for k, v in rec['collectives'].items() if k != 'counts'):.3e}B "
            f"temp={rec['memory']['temp_bytes']}"
        )
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[FAIL] {arch}/{shape}/{mesh_kind}: {rec['error'][:200]}")
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}__{shape}__{mesh_kind}.json".replace("/", "_")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    cells = configs_pkg.all_cells()
    if args.arch:
        cells = [c for c in cells if c["arch"] == args.arch]
    if args.shape:
        cells = [c for c in cells if c["shape"] == args.shape]
    meshes = [args.mesh] if args.mesh else ["pod", "multipod"]

    n_ok = n_fail = n_skip = 0
    for c in cells:
        if c["skip"]:
            print(f"[SKIP] {c['arch']}/{c['shape']}: {c['skip'][:90]}")
            n_skip += 1
            rec = dict(arch=c["arch"], shape=c["shape"], mesh="-", ok=True, skipped=c["skip"])
            os.makedirs(args.out, exist_ok=True)
            with open(os.path.join(args.out, f"{c['arch']}__{c['shape']}__skip.json"), "w") as f:
                json.dump(rec, f, indent=1)
            continue
        for mk in meshes:
            fname = os.path.join(args.out, f"{c['arch']}__{c['shape']}__{mk}.json")
            if args.skip_done and os.path.exists(fname):
                with open(fname) as f:
                    if json.load(f).get("ok"):
                        n_ok += 1
                        continue
            rec = run_cell(c["arch"], c["shape"], mk, args.out)
            n_ok += int(rec["ok"])
            n_fail += int(not rec["ok"])
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
