"""repro: One-Hop Sub-Query Result Caches for Graph Database Systems, in JAX.

A production-grade JAX training/serving framework reproducing and extending
Nguyen, Li & Ghandeharizadeh (2024). The paper's contribution — a strongly
consistent cache of one-hop sub-query results inside a transactional graph
store — lives in :mod:`repro.core`, built on the tensorized property-graph
substrate in :mod:`repro.graphstore`. Assigned model families (LM / GNN /
RecSys) live in their own subpackages with configs under
:mod:`repro.configs` and the distributed launchers under
:mod:`repro.launch`.
"""

__version__ = "1.0.0"
