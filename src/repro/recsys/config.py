"""Two-tower retrieval configuration (YouTube RecSys'19 shape)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class TwoTowerConfig:
    name: str
    embed_dim: int = 256
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    # categorical fields per tower; each field is a multi-hot bag
    user_fields: int = 8
    item_fields: int = 6
    bag_size: int = 16  # max ids per bag (padded)
    user_vocab: int = 100_000_000
    item_vocab: int = 10_000_000
    temperature: float = 0.05
    dtype: str = "float32"

    def param_count(self) -> int:
        d = self.embed_dim
        n = (self.user_vocab + self.item_vocab) * d
        for fields in (self.user_fields, self.item_fields):
            last = fields * d
            for h in self.tower_mlp:
                n += last * h + h
                last = h
        return n
