"""Two-tower retrieval: towers, in-batch sampled softmax, serve paths.

Embedding tables row-shard over 'model'; batch shards over (pod, data); the
in-batch logits matrix [B, B] shards (batch, model) so the 64k-batch
training shape never materializes more than a tile per device.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.recsys.config import TwoTowerConfig
from repro.recsys.embedding import embedding_bag

BATCH = ("pod", "data")


def param_shapes(cfg: TwoTowerConfig) -> dict:
    d = cfg.embed_dim
    shapes = {
        "user_table": (cfg.user_vocab, d),
        "item_table": (cfg.item_vocab, d),
    }
    for tower, fields in (("user", cfg.user_fields), ("item", cfg.item_fields)):
        last = fields * d
        for i, h in enumerate(cfg.tower_mlp):
            shapes[f"{tower}_w{i}"] = (last, h)
            shapes[f"{tower}_b{i}"] = (h,)
            last = h
    return shapes


def abstract_params(cfg: TwoTowerConfig):
    dt = jnp.dtype(cfg.dtype)
    return {
        k: jax.ShapeDtypeStruct(s, dt) for k, s in param_shapes(cfg).items()
    }


def init_params(cfg: TwoTowerConfig, key):
    shapes = param_shapes(cfg)
    ks = jax.random.split(key, len(shapes))
    dt = jnp.dtype(cfg.dtype)
    out = {}
    for k, (name, shape) in zip(ks, shapes.items()):
        if name.endswith(tuple("0123456789")) and "_b" in name:
            out[name] = jnp.zeros(shape, dt)
        else:
            fan = shape[0]
            out[name] = (jax.random.normal(k, shape, jnp.float32) * fan**-0.5).astype(dt)
    return out


def param_spec_rule(cfg: TwoTowerConfig):
    def rule(path: str, leaf):
        if "table" in path:
            return ("model", None)  # row-sharded embedding tables
        if "_w" in path:
            return (None, "model")
        return (None,)

    return rule


def _tower(cfg, params, prefix, bags, mask, table):
    d = cfg.embed_dim
    fields = []
    for f in range(bags.shape[1]):
        fields.append(embedding_bag(table, bags[:, f], mask[:, f], mode="mean"))
    h = jnp.concatenate(fields, axis=-1)
    h = constrain(h, BATCH, None)
    i = 0
    while f"{prefix}_w{i}" in params:
        h = h @ params[f"{prefix}_w{i}"] + params[f"{prefix}_b{i}"]
        if f"{prefix}_w{i+1}" in params:
            h = jax.nn.relu(h)
        i += 1
    # L2-normalized embeddings (standard for dot retrieval)
    return h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)


def user_tower(cfg, params, user_bags, user_mask):
    """user_bags [B, F_u, K] int32, user_mask same bool -> [B, D]."""
    return _tower(cfg, params, "user", user_bags, user_mask, params["user_table"])


def item_tower(cfg, params, item_bags, item_mask):
    return _tower(cfg, params, "item", item_bags, item_mask, params["item_table"])


def loss_fn(cfg: TwoTowerConfig, params, batch):
    """In-batch sampled softmax with logQ correction.

    batch: dict(user_bags, user_mask, item_bags, item_mask, item_logq [B]).
    """
    u = user_tower(cfg, params, batch["user_bags"], batch["user_mask"])
    it = item_tower(cfg, params, batch["item_bags"], batch["item_mask"])
    logits = (u @ it.T) / cfg.temperature
    logits = constrain(logits, BATCH, "model").astype(jnp.float32)
    logits = logits - batch["item_logq"][None, :]  # logQ correction
    B = logits.shape[0]
    labels = jnp.arange(B)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    return jnp.mean(logz - gold)


def train_step(cfg: TwoTowerConfig, optimizer):
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)
        return params, opt_state, {"loss": loss}

    return step


def serve_step(cfg: TwoTowerConfig, params, user_bags, user_mask, item_emb):
    """Online scoring: users [B] against their per-request candidate items
    [B, C, D] (pre-embedded); returns top-1 scores + ids. serve_p99 /
    serve_bulk shapes."""
    u = user_tower(cfg, params, user_bags, user_mask)  # [B, D]
    scores = jnp.einsum("bd,bcd->bc", u, item_emb)
    best = jnp.argmax(scores, axis=-1)
    return scores, best


def retrieval_step(cfg: TwoTowerConfig, params, user_bags, user_mask, corpus_emb, k: int = 100):
    """retrieval_cand: one (or few) queries against a 1M-item corpus
    [N, D] — a single batched matmul + top-k, never a loop."""
    u = user_tower(cfg, params, user_bags, user_mask)  # [B, D]
    scores = u @ corpus_emb.T  # [B, N]
    scores = constrain(scores, BATCH, "model")
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx
