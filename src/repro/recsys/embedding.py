"""EmbeddingBag in JAX: gather + segment-reduce.

JAX has no ``nn.EmbeddingBag`` and no CSR sparse — the lookup IS part of the
system (kernel_taxonomy §RecSys). Two APIs:

- ``embedding_bag``: padded bags [B, K] + mask (the model-facing form; maps
  to one big ``jnp.take`` + masked sum — TPU-friendly, fully static).
- ``embedding_bag_flat``: (ids [NNZ], segment_ids [NNZ]) ragged form via
  ``jax.ops.segment_sum`` (the kernel regime; the Pallas embedding_bag
  kernel implements this layout and ref's against it).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table, ids, mask=None, *, mode: str = "sum", weights=None):
    """table [V, D]; ids [B, K] padded; mask [B, K]. Returns [B, D]."""
    emb = jnp.take(table, jnp.clip(ids, 0, table.shape[0] - 1), axis=0)
    if weights is not None:
        emb = emb * weights[..., None]
    if mask is not None:
        emb = jnp.where(mask[..., None], emb, 0)
    out = jnp.sum(emb, axis=-2)
    if mode == "mean":
        cnt = (
            jnp.sum(mask, axis=-1, keepdims=True).astype(out.dtype)
            if mask is not None
            else jnp.full(out.shape[:-1] + (1,), ids.shape[-1], out.dtype)
        )
        out = out / jnp.maximum(cnt, 1)
    return out


def embedding_bag_flat(table, ids, segment_ids, n_bags: int, *, mode: str = "sum", weights=None):
    """Ragged form: ids/segment_ids [NNZ]. Returns [n_bags, D]."""
    emb = jnp.take(table, jnp.clip(ids, 0, table.shape[0] - 1), axis=0)
    if weights is not None:
        emb = emb * weights[:, None]
    out = jax.ops.segment_sum(emb, segment_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(segment_ids, out.dtype), segment_ids, num_segments=n_bags
        )
        out = out / jnp.maximum(cnt, 1)[:, None]
    return out
