"""RecSys substrate: two-tower retrieval with manual EmbeddingBag
(jnp.take + segment_sum — JAX has no native EmbeddingBag) and in-batch
sampled softmax with logQ correction."""

from repro.recsys.config import TwoTowerConfig
from repro.recsys.embedding import embedding_bag, embedding_bag_flat
from repro.recsys.twotower import (
    init_params as tt_init,
    item_tower,
    loss_fn as tt_loss,
    retrieval_step,
    serve_step as tt_serve_step,
    train_step as tt_train_step,
    user_tower,
)

__all__ = [
    "TwoTowerConfig",
    "embedding_bag",
    "embedding_bag_flat",
    "tt_init",
    "tt_loss",
    "tt_train_step",
    "tt_serve_step",
    "retrieval_step",
    "user_tower",
    "item_tower",
]
