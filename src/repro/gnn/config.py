"""GNN architecture configuration."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str  # "pna" | "gat" | "egnn" | "nequip"
    n_layers: int
    d_hidden: int
    d_in: int
    n_classes: int = 16
    # gat
    n_heads: int = 1
    # pna
    aggregators: Tuple[str, ...] = ("mean", "max", "min", "std")
    scalers: Tuple[str, ...] = ("identity", "amplification", "attenuation")
    mean_log_degree: float = 2.0  # PNA's delta, precomputed on train graphs
    # nequip
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    # equivariant models consume positions
    @property
    def needs_positions(self) -> bool:
        return self.kind in ("egnn", "nequip")

    dtype: str = "float32"
