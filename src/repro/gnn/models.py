"""GNN models: init/forward/loss/train_step dispatched on config.kind."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed import constrain
from repro.gnn.config import GNNConfig
from repro.gnn.graph import GraphBatch
from repro.gnn.layers import (
    egnn_layer,
    egnn_layer_init,
    gat_layer,
    gat_layer_init,
    mlp,
    mlp_init,
    nequip_layer,
    nequip_layer_init,
    pna_layer,
    pna_layer_init,
)

NODE_AXES = ("pod", "data")


def init_params(cfg: GNNConfig, key):
    ks = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    if cfg.kind == "pna":
        layers = [pna_layer_init(ks[i], cfg.d_in if i == 0 else d, d, cfg) for i in range(cfg.n_layers)]
        return {"layers": layers, "head": mlp_init(ks[-1], (d, cfg.n_classes))}
    if cfg.kind == "gat":
        h = cfg.n_heads
        layers = [
            gat_layer_init(ks[i], cfg.d_in if i == 0 else d * h, d, h)
            for i in range(cfg.n_layers - 1)
        ]
        layers.append(gat_layer_init(ks[cfg.n_layers - 1], d * h if cfg.n_layers > 1 else cfg.d_in, cfg.n_classes, h))
        return {"layers": layers}
    if cfg.kind == "egnn":
        emb = mlp_init(ks[-2], (cfg.d_in, d))
        layers = [egnn_layer_init(ks[i], d, cfg) for i in range(cfg.n_layers)]
        return {"embed": emb, "layers": layers, "head": mlp_init(ks[-1], (d, d, 1))}
    if cfg.kind == "nequip":
        emb = mlp_init(ks[-2], (cfg.d_in, d))
        layers = [nequip_layer_init(ks[i], d, cfg) for i in range(cfg.n_layers)]
        return {"embed": emb, "layers": layers, "head": mlp_init(ks[-1], (d, d, 1))}
    raise ValueError(cfg.kind)


def forward(cfg: GNNConfig, params, g: GraphBatch):
    """Returns node logits [N, n_classes] (pna/gat) or per-graph energy
    [n_graphs] (egnn/nequip)."""
    src, dst, em, nm = g.edge_src, g.edge_dst, g.edge_mask, g.node_mask
    if cfg.kind == "pna":
        h = constrain(g.node_feat, NODE_AXES, None)
        for lp in params["layers"]:
            h = pna_layer(lp, cfg, h, src, dst, em, nm)
            h = constrain(h, NODE_AXES, None)
        return mlp(params["head"], h)
    if cfg.kind == "gat":
        h = constrain(g.node_feat, NODE_AXES, None)
        for i, lp in enumerate(params["layers"]):
            last = i == len(params["layers"]) - 1
            h = gat_layer(lp, h, src, dst, em, nm, concat=not last)
            h = constrain(h, NODE_AXES, None)
        return h
    if cfg.kind == "egnn":
        h = mlp(params["embed"], g.node_feat)
        x = g.positions
        for lp in params["layers"]:
            h, x = egnn_layer(lp, h, x, src, dst, em, nm)
            h = constrain(h, NODE_AXES, None)
        e_node = mlp(params["head"], h)[:, 0] * nm
        return _graph_pool(e_node, g)
    if cfg.kind == "nequip":
        n = g.node_feat.shape[0]
        c = cfg.d_hidden
        s = mlp(params["embed"], g.node_feat)
        v = jnp.zeros((n, c, 3), s.dtype)
        t = jnp.zeros((n, c, 3, 3), s.dtype)
        for lp in params["layers"]:
            s, v, t = nequip_layer(lp, cfg, s, v, t, g.positions, src, dst, em, nm)
            s = constrain(s, NODE_AXES, None)
        e_node = mlp(params["head"], s)[:, 0] * nm
        return _graph_pool(e_node, g)
    raise ValueError(cfg.kind)


def _graph_pool(e_node, g: GraphBatch):
    if g.graph_ids is None:
        return jnp.sum(e_node)[None]
    return jax.ops.segment_sum(e_node, g.graph_ids, num_segments=g.n_graphs)


def loss_fn(cfg: GNNConfig, params, g: GraphBatch, targets=None):
    out = forward(cfg, params, g)
    if cfg.kind in ("pna", "gat"):
        logits = out.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, g.labels[:, None], -1)[:, 0]
        per = (logz - gold) * g.node_mask
        return jnp.sum(per) / jnp.maximum(jnp.sum(g.node_mask), 1)
    # energy regression
    tgt = targets if targets is not None else jnp.zeros(out.shape, out.dtype)
    return jnp.mean(jnp.square(out - tgt))


def train_step(cfg: GNNConfig, optimizer):
    def step(params, opt_state, g: GraphBatch, targets=None):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, g, targets)
        )(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)
        return params, opt_state, {"loss": loss}

    return step
