"""Neighbor sampling for minibatch GNN training (minibatch_lg shape).

``FanoutSampler`` is the real multi-layer fanout sampler (GraphSAGE-style)
over host CSR arrays. ``CachedNeighborSampler`` is the paper's technique
applied to GNN data loading: the one-hop *neighbor list* of a vertex is
exactly a one-hop sub-query result (empty predicates), so it is cached in
the core cache, served on hits without touching the storage CSR, populated
asynchronously on misses, and write-around-invalidated when gRW-Txs mutate
the graph — giving a *consistent* sampling cache over a dynamic graph.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.gnn.graph import GraphBatch


class CSRGraph(NamedTuple):
    indptr: np.ndarray  # [N+1]
    indices: np.ndarray  # [E]
    feats: np.ndarray  # [N, F]
    labels: np.ndarray  # [N]

    @staticmethod
    def random(rng, n, avg_deg, d_feat, n_classes=16):
        deg = rng.poisson(avg_deg, n).astype(np.int64)
        indptr = np.zeros(n + 1, np.int64)
        indptr[1:] = np.cumsum(deg)
        indices = rng.integers(0, n, indptr[-1]).astype(np.int32)
        return CSRGraph(
            indptr=indptr,
            indices=indices,
            feats=rng.normal(size=(n, d_feat)).astype(np.float32),
            labels=rng.integers(0, n_classes, n).astype(np.int32),
        )


class FanoutSampler:
    """Layer-wise fanout sampling producing a padded GraphBatch."""

    def __init__(self, graph: CSRGraph, fanouts, seed: int = 0):
        self.g = graph
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)

    def neighbors(self, v: int) -> np.ndarray:
        return self.g.indices[self.g.indptr[v] : self.g.indptr[v + 1]]

    def sample(self, seeds: np.ndarray) -> GraphBatch:
        """Returns a padded subgraph: nodes = seeds + sampled frontier(s);
        edges point child -> parent (messages flow to the seed side)."""
        import jax.numpy as jnp

        nodes = list(map(int, seeds))
        node_of = {v: i for i, v in enumerate(nodes)}
        src, dst = [], []
        frontier = list(map(int, seeds))
        cap_nodes = self._cap_nodes(len(seeds))
        cap_edges = self._cap_edges(len(seeds))
        for f in self.fanouts:
            nxt = []
            for v in frontier:
                nb = self.neighbors(v)
                if len(nb) == 0:
                    continue
                take = self.rng.choice(nb, size=min(f, len(nb)), replace=False)
                for u in map(int, take):
                    if u not in node_of:
                        if len(nodes) >= cap_nodes:
                            continue
                        node_of[u] = len(nodes)
                        nodes.append(u)
                    if len(src) < cap_edges:
                        src.append(node_of[u])
                        dst.append(node_of[v])
                        nxt.append(u)
            frontier = nxt
        n, e = cap_nodes, cap_edges
        nf = np.zeros((n, self.g.feats.shape[1]), np.float32)
        nf[: len(nodes)] = self.g.feats[nodes]
        lab = np.zeros(n, np.int32)
        lab[: len(nodes)] = self.g.labels[nodes]
        es = np.zeros(e, np.int32)
        ed = np.zeros(e, np.int32)
        es[: len(src)] = src
        ed[: len(dst)] = dst
        nm = np.zeros(n, bool)
        nm[: len(nodes)] = True
        em = np.zeros(e, bool)
        em[: len(src)] = True
        return GraphBatch(
            node_feat=jnp.asarray(nf),
            edge_src=jnp.asarray(es),
            edge_dst=jnp.asarray(ed),
            node_mask=jnp.asarray(nm),
            edge_mask=jnp.asarray(em),
            labels=jnp.asarray(lab),
        )

    def _cap_nodes(self, b):
        n = b
        layer = b
        for f in self.fanouts:
            layer = layer * f
            n += layer
        return n

    def _cap_edges(self, b):
        e = 0
        layer = b
        for f in self.fanouts:
            layer = layer * f
            e += layer
        return e


class CachedNeighborSampler(FanoutSampler):
    """Fanout sampler whose one-hop neighbor lists are served by the paper's
    cache over a live (mutable) graphstore."""

    def __init__(self, espec, store, cache, ttable, tpl_idx, populator, fanouts, seed=0):
        self.espec = espec
        self.store = store
        self.cache = cache
        self.ttable = ttable
        self.tpl_idx = tpl_idx
        self.pop = populator
        self.fanouts = tuple(fanouts)
        self.rng = np.random.default_rng(seed)
        self.hits = 0
        self.misses = 0
        self._feat_dim = int(store.vprops.shape[1])

    # the CSRGraph-facing bits are replaced by cache-backed lookups
    def neighbors(self, v: int) -> np.ndarray:
        import jax.numpy as jnp

        from repro.core.cache import cache_lookup
        from repro.core.engine import MissRecord
        from repro.core.keys import PARAM_LEN
        from repro.graphstore.store import gather_out
        from repro.utils import PROP_MISSING

        params = np.full((1, PARAM_LEN), int(PROP_MISSING), np.int32)
        hit, vals, lmask, _ = cache_lookup(
            self.espec.cache,
            self.cache,
            jnp.full((1,), self.tpl_idx, jnp.int32),
            jnp.full((1,), v, jnp.int32),
            jnp.asarray(params),
        )
        if bool(hit[0]):
            self.hits += 1
            return np.asarray(vals[0])[np.asarray(lmask[0])]
        self.misses += 1
        _, other, mask, _ = gather_out(
            self.espec.store, self.store, jnp.array([v], jnp.int32), self.espec.max_deg
        )
        self.pop.queue.push(
            [MissRecord(self.tpl_idx, v, params[0], int(self.store.version))]
        )
        return np.unique(np.asarray(other[0])[np.asarray(mask[0])])

    def populate(self):
        self.cache = self.pop.drain(self.store, self.store, self.cache, self.ttable)

    def sample_store(self, seeds: np.ndarray, feats: np.ndarray, labels: np.ndarray):
        """Like ``sample`` but features/labels come from external arrays."""
        self.g = CSRGraph(  # adapter so FanoutSampler.sample works
            indptr=None, indices=None, feats=feats, labels=labels
        )
        return self.sample(seeds)
