"""GNN layers: PNA, GAT, EGNN, NequIP-lite (restricted tensor product).

All layers consume a padded edge list and use segment reductions; no dense
adjacency ever materializes. The NequIP variant keeps its l=2 features as
traceless symmetric 3x3 matrices so E(3)-equivariance is directly testable
(R M R^T under rotation) without a Wigner-D machinery; DESIGN.md records
this restriction of the full irrep tensor product.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.gnn.graph import (
    degrees,
    scatter_max,
    scatter_mean,
    scatter_min,
    scatter_sum,
    segment_softmax,
)


from repro.distributed import constrain

EDGE_AXES = ("pod", "data", "model")  # edge-parallel dim (matches steps.py)


def _epin(t):
    """§Perf (GNN cell): pin edge-wise intermediates to the edge sharding —
    without this GSPMD replicates the [E, ...] message tensors around the
    segment reductions (15.8GB/device on ogb_products)."""
    return constrain(t, *((EDGE_AXES,) + (None,) * (t.ndim - 1)))


def mlp(params, x, act=jax.nn.silu):
    for i, (w, b) in enumerate(params):
        x = x @ w + b
        if i + 1 < len(params):
            x = act(x)
    return x


def mlp_init(key, sizes):
    ks = jax.random.split(key, len(sizes) - 1)
    return [
        (
            jax.random.normal(k, (a, b), jnp.float32) * (a**-0.5),
            jnp.zeros((b,), jnp.float32),
        )
        for k, a, b in zip(ks, sizes[:-1], sizes[1:])
    ]


# ---------------------------------------------------------------- PNA
def pna_layer_init(key, d_in, d, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    n_feats = len(cfg.aggregators) * len(cfg.scalers)
    return {
        "msg": mlp_init(k1, (2 * d_in, d)),
        "upd": mlp_init(k2, (d_in + n_feats * d, d, d)),
    }


def pna_layer(p, cfg, h, src, dst, emask, nmask):
    n = h.shape[0]
    m = _epin(mlp(p["msg"], _epin(jnp.concatenate([h[src], h[dst]], -1))))
    mean, cnt = scatter_mean(m, dst, n, emask)
    mx = scatter_max(m, dst, n, emask)
    mn = scatter_min(m, dst, n, emask)
    sq, _ = scatter_mean(jnp.square(m), dst, n, emask)
    std = jnp.sqrt(jax.nn.relu(sq - jnp.square(mean)) + 1e-8)
    aggs = {"mean": mean, "max": mx, "min": mn, "std": std}
    deg = degrees(dst, n, emask)
    logd = jnp.log1p(deg)[:, None]
    delta = cfg.mean_log_degree
    scal = {
        "identity": jnp.ones_like(logd),
        "amplification": logd / delta,
        "attenuation": delta / jnp.maximum(logd, 1e-3),
    }
    feats = [aggs[a] * scal[s] for a in cfg.aggregators for s in cfg.scalers]
    out = mlp(p["upd"], jnp.concatenate([h] + feats, -1))
    return jnp.where(nmask[:, None], out, 0)


# ---------------------------------------------------------------- GAT
def gat_layer_init(key, d_in, d, heads):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(k1, (d_in, heads, d), jnp.float32) * (d_in**-0.5),
        "a_src": jax.random.normal(k2, (heads, d), jnp.float32) * (d**-0.5),
        "a_dst": jax.random.normal(k3, (heads, d), jnp.float32) * (d**-0.5),
    }


def gat_layer(p, h, src, dst, emask, nmask, concat=True):
    n = h.shape[0]
    hw = jnp.einsum("nf,fhd->nhd", h, p["w"])  # [N, H, d]
    es = _epin(jnp.einsum("nhd,hd->nh", hw, p["a_src"])[src])  # SDDMM scores
    ed = _epin(jnp.einsum("nhd,hd->nh", hw, p["a_dst"])[dst])
    score = jax.nn.leaky_relu(es + ed, 0.2)
    alpha = _epin(segment_softmax(score, dst, n, emask))  # [E, H]
    msg = _epin(hw[src] * alpha[..., None])
    agg = jax.ops.segment_sum(
        jnp.where(emask[:, None, None], msg, 0), dst, num_segments=n
    )
    out = agg.reshape(n, -1) if concat else agg.mean(axis=1)
    return jnp.where(nmask[:, None], jax.nn.elu(out), 0)


# ---------------------------------------------------------------- EGNN
def egnn_layer_init(key, d, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "phi_e": mlp_init(k1, (2 * d + 1, d, d)),
        "phi_x": mlp_init(k2, (d, d, 1)),
        "phi_h": mlp_init(k3, (2 * d, d, d)),
    }


def egnn_layer(p, h, x, src, dst, emask, nmask):
    n = h.shape[0]
    rel = x[src] - x[dst]  # [E, 3]
    d2 = jnp.sum(jnp.square(rel), -1, keepdims=True)
    m = mlp(p["phi_e"], jnp.concatenate([h[src], h[dst], d2], -1))
    # position update (E(n)-equivariant)
    coef = mlp(p["phi_x"], m)  # [E, 1]
    dx = scatter_sum(rel * coef / jnp.maximum(jnp.sqrt(d2), 1.0), dst, n, emask)
    cnt = degrees(dst, n, emask)[:, None]
    x = x + jnp.where(nmask[:, None], dx / jnp.maximum(cnt, 1), 0)
    # feature update
    agg = scatter_sum(m, dst, n, emask)
    h = h + mlp(p["phi_h"], jnp.concatenate([h, agg], -1))
    return jnp.where(nmask[:, None], h, 0), x


# ---------------------------------------------------------------- NequIP-lite
def _bessel(d, n_rbf, cutoff):
    d = jnp.maximum(d, 1e-6)
    k = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rbf = jnp.sin(k[None, :] * jnp.pi * d[:, None] / cutoff) / d[:, None]
    # smooth cosine cutoff envelope
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d / cutoff, 0, 1)) + 1.0)
    return rbf * env[:, None]


N_PATHS = 8  # radial-weighted tensor-product paths (see nequip_layer)


def nequip_layer_init(key, c, cfg):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "radial": mlp_init(k1, (cfg.n_rbf, c, N_PATHS * c)),
        "mix_s": jax.random.normal(k2, (2 * c, c), jnp.float32) * (2 * c) ** -0.5,
        "mix_v": jax.random.normal(k3, (3 * c, c), jnp.float32) * (3 * c) ** -0.5,
        "mix_t": jax.random.normal(k4, (2 * c, c), jnp.float32) * (2 * c) ** -0.5,
        "gate": mlp_init(k5, (c, 2 * c)),
    }


def nequip_layer(p, cfg, s, v, t, x, src, dst, emask, nmask):
    """One interaction block. Features: scalars s [N,C], vectors v [N,C,3],
    traceless-symmetric matrices t [N,C,3,3] (the l=2 stand-in).

    Paths (all radial-weighted, aggregated with segment_sum):
      l0 <- s_j (0x0), v_j.u (1x1), <t_j, uu^T> (2x2)
      l1 <- s_j*u (0x1), v_j (1x0), v_j x u (1x1), t_j u (2x1)
      l2 <- s_j*(uu^T - I/3) (0x2)
    """
    n, c = s.shape
    rel = x[src] - x[dst]
    d = jnp.linalg.norm(rel + 1e-9, axis=-1)
    u = rel / jnp.maximum(d, 1e-6)[:, None]  # [E, 3]
    rbf = _bessel(d, cfg.n_rbf, cfg.cutoff)
    R = mlp(p["radial"], rbf).reshape(-1, N_PATHS, c)  # [E, P, C]

    uu = u[:, None, :, None] * u[:, None, None, :]  # [E,1,3,3]
    eye = jnp.eye(3) / 3.0
    y2 = uu - eye[None, None]  # traceless

    sj, vj, tj = s[src], v[src], t[src]
    m_s = (
        R[:, 0] * sj
        + R[:, 1] * jnp.einsum("eci,ei->ec", vj, u)
        + R[:, 2] * jnp.einsum("ecij,eij->ec", tj, y2[:, 0])
    )
    m_v = (
        R[:, 3, :, None] * sj[..., None] * u[:, None, :]
        + R[:, 4, :, None] * vj
        + R[:, 5, :, None] * jnp.cross(vj, u[:, None, :])
        + R[:, 6, :, None] * jnp.einsum("ecij,ej->eci", tj, u)
    )
    m_t = R[:, 7, :, None, None] * sj[..., None, None] * y2

    agg_s = scatter_sum(m_s, dst, n, emask)
    agg_v = scatter_sum(m_v.reshape(-1, c * 3), dst, n, emask).reshape(n, c, 3)
    agg_t = scatter_sum(m_t.reshape(-1, c * 9), dst, n, emask).reshape(n, c, 3, 3)

    # self-interaction: linear channel mixing (equivariant) + gated nonlin
    s2 = jnp.concatenate([s, agg_s], -1) @ p["mix_s"]
    vcat = jnp.concatenate([v, agg_v, jnp.cross(v, agg_v)], axis=1)  # [N,3C,3]
    v2 = jnp.einsum("nki,kc->nci", vcat, p["mix_v"])
    tcat = jnp.concatenate([t, agg_t], axis=1)  # [N,2C,3,3]
    t2 = jnp.einsum("nkij,kc->ncij", tcat, p["mix_t"])
    gates = mlp(p["gate"], jax.nn.silu(s2))
    gv, gt = jnp.split(jax.nn.sigmoid(gates), 2, axis=-1)
    s = s + jax.nn.silu(s2)
    v = v + v2 * gv[..., None]
    t = t + t2 * gt[..., None, None]
    z = nmask[:, None]
    return s * z, v * z[..., None], t * z[..., None, None]
