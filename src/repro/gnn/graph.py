"""Padded graph batches + segment-op primitives.

JAX sparse is BCOO-only, so message passing is expressed directly over an
edge-index list with ``jax.ops.segment_sum`` / ``segment_max`` — this IS the
SpMM/SDDMM layer of the system (kernel_taxonomy §GNN). All shapes are static
(padded with masks) so everything jits and shards.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


from dataclasses import dataclass, field


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=(
        "node_feat", "edge_src", "edge_dst", "node_mask", "edge_mask",
        "labels", "positions", "graph_ids",
    ),
    meta_fields=("n_graphs",),
)
@dataclass(frozen=True)
class GraphBatch:
    node_feat: jax.Array  # [N, F] float
    edge_src: jax.Array  # [E] int32
    edge_dst: jax.Array  # [E] int32
    node_mask: jax.Array  # [N] bool
    edge_mask: jax.Array  # [E] bool
    labels: jax.Array  # [N] int32 (node classification) or graph targets
    positions: Optional[jax.Array] = None  # [N, 3] for equivariant models
    graph_ids: Optional[jax.Array] = None  # [N] int32 for batched small graphs
    n_graphs: int = 1  # static: segment count for graph pooling

    def _replace(self, **kw):
        from dataclasses import replace

        return replace(self, **kw)


def random_graph_batch(
    key, n_nodes, n_edges, d_feat, n_classes=16, positions=False, n_graphs=1
) -> GraphBatch:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    src = jax.random.randint(k1, (n_edges,), 0, n_nodes, jnp.int32)
    dst = jax.random.randint(k2, (n_edges,), 0, n_nodes, jnp.int32)
    return GraphBatch(
        node_feat=jax.random.normal(k3, (n_nodes, d_feat), jnp.float32),
        edge_src=src,
        edge_dst=dst,
        node_mask=jnp.ones(n_nodes, bool),
        edge_mask=jnp.ones(n_edges, bool),
        labels=jax.random.randint(k4, (n_nodes,), 0, n_classes, jnp.int32),
        positions=jax.random.normal(k5, (n_nodes, 3), jnp.float32) if positions else None,
        graph_ids=(jnp.arange(n_nodes, dtype=jnp.int32) % n_graphs) if n_graphs > 1 else None,
        n_graphs=n_graphs,
    )


NODE_AXES = ("pod", "data", "model")


def _npin(t):
    """§Perf (GNN cell) — REFUTED on XLA-CPU: pinning segment-reduction
    outputs node-sharded was meant to turn the combine into reduce-scatter,
    but this partitioner emits extra all-gathers instead (+3%); kept unused
    pending the shard_map edge-aligned path (EXPERIMENTS.md §Perf)."""
    from repro.distributed import constrain

    return constrain(t, *((NODE_AXES,) + (None,) * (t.ndim - 1)))


def scatter_sum(messages, dst, n_nodes, edge_mask):
    m = jnp.where(edge_mask[:, None], messages, 0)
    return jax.ops.segment_sum(m, dst, num_segments=n_nodes)


def scatter_mean(messages, dst, n_nodes, edge_mask):
    s = scatter_sum(messages, dst, n_nodes, edge_mask)
    cnt = jax.ops.segment_sum(edge_mask.astype(messages.dtype), dst, num_segments=n_nodes)
    return s / jnp.maximum(cnt, 1)[:, None], cnt


def scatter_max(messages, dst, n_nodes, edge_mask):
    m = jnp.where(edge_mask[:, None], messages, -jnp.inf)
    out = jax.ops.segment_max(m, dst, num_segments=n_nodes)
    return jnp.where(jnp.isfinite(out), out, 0)


def scatter_min(messages, dst, n_nodes, edge_mask):
    return -scatter_max(-messages, dst, n_nodes, edge_mask)


def segment_softmax(scores, dst, n_nodes, edge_mask):
    """Edge-softmax normalized over incoming edges of each dst node.

    scores: [E, H]. Returns [E, H] weights (masked edges -> 0).
    """
    s = jnp.where(edge_mask[:, None], scores, -jnp.inf)
    mx = jax.ops.segment_max(s, dst, num_segments=n_nodes)
    mx = jnp.where(jnp.isfinite(mx), mx, 0)
    ex = jnp.where(edge_mask[:, None], jnp.exp(s - mx[dst]), 0)
    den = jax.ops.segment_sum(ex, dst, num_segments=n_nodes)
    return ex / jnp.maximum(den[dst], 1e-16)


def degrees(dst, n_nodes, edge_mask):
    return jax.ops.segment_sum(edge_mask.astype(jnp.float32), dst, num_segments=n_nodes)
