"""GNN substrate: segment-op message passing (SpMM regime), multi-aggregator
PNA, GAT edge-softmax (SDDMM regime), E(n)-equivariant EGNN, and a
NequIP-style restricted tensor-product network — plus the fanout neighbor
sampler and its one-hop-cache-backed variant (the paper's technique applied
to GNN data loading)."""

from repro.gnn.config import GNNConfig
from repro.gnn.graph import GraphBatch, random_graph_batch, segment_softmax
from repro.gnn.models import forward as gnn_forward, loss_fn as gnn_loss, train_step as gnn_train_step, init_params as gnn_init
from repro.gnn.sampler import FanoutSampler, CachedNeighborSampler

__all__ = [
    "GNNConfig",
    "GraphBatch",
    "random_graph_batch",
    "segment_softmax",
    "gnn_forward",
    "gnn_loss",
    "gnn_train_step",
    "gnn_init",
    "FanoutSampler",
    "CachedNeighborSampler",
]
