"""Sub-query template life-cycle (§4.1): the Service Coordinator.

States: ``registered -> installed -> enabled -> installed -> removed``.
Enable is a two-phase workflow across all Graph-QPs:

  Phase 1: every QP starts *write invalidation* for the template (deleting
           possibly-nonexistent entries is safe); only when all QPs ack does
           the state become ``installed``.
  Phase 2: every QP activates *reads* for the template; when all ack, the
           state becomes ``enabled``.

Disable reverses the phases (reads off everywhere first, then writes off,
then one clearRange frees the template's entries). The SC retries failed or
timed-out QP requests until acked — we simulate message loss with a seeded
RNG so tests can drive the retry path deterministically.

The safety invariant (tested): **whenever any QP serves reads from the cache
for a template, every QP is write-invalidating it.**
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.cache import CacheSpec, CacheState, sweep_template
from repro.core.templates import TemplateTable


class TemplateState(enum.Enum):
    REGISTERED = "registered"
    INSTALLED = "installed"
    ENABLED = "enabled"
    REMOVED = "removed"


@dataclass
class GraphQP:
    """One query processor's local view of template activation."""

    name: str
    read_active: set = field(default_factory=set)
    write_active: set = field(default_factory=set)
    reachable: bool = True  # SC marks unreachable QPs bad and removes them

    def ttable_masks(self, ttable: TemplateTable, n_templates: int) -> TemplateTable:
        import jax.numpy as jnp

        r = np.zeros(n_templates, bool)
        w = np.zeros(n_templates, bool)
        for t in self.read_active:
            r[t] = True
        for t in self.write_active:
            w[t] = True
        return ttable._replace(
            read_enabled=jnp.asarray(r), write_enabled=jnp.asarray(w)
        )


class ServiceCoordinator:
    """Deterministic simulation of the SC's two-phase workflows.

    ``drop_prob`` injects request loss; the SC re-sends until each QP acks
    (§4.1 last paragraph). ``max_rounds`` bounds the simulation.
    """

    def __init__(self, qps, seed: int = 0, drop_prob: float = 0.0, max_rounds: int = 100):
        self.qps = list(qps)
        self.states: dict[int, TemplateState] = {}
        self.rng = np.random.default_rng(seed)
        self.drop_prob = drop_prob
        self.max_rounds = max_rounds
        self.audit_log: list = []  # removed templates are tracked for auditing
        self.messages_sent = 0
        self.messages_dropped = 0

    # -- message layer --------------------------------------------------
    def _request_all(self, action: Callable) -> None:
        """Send ``action(qp)`` to every QP, retrying drops until all ack."""
        pending = [qp for qp in self.qps if qp.reachable]
        rounds = 0
        while pending:
            rounds += 1
            if rounds > self.max_rounds:
                raise RuntimeError("SC: retry budget exhausted")
            nxt = []
            for qp in pending:
                self.messages_sent += 1
                if self.rng.random() < self.drop_prob:
                    self.messages_dropped += 1
                    nxt.append(qp)  # no ack; re-send next round
                    continue
                action(qp)
            pending = nxt

    # -- admin API --------------------------------------------------------
    def register(self, tpl_idx: int):
        self.states[tpl_idx] = TemplateState.REGISTERED
        self.audit_log.append(("register", tpl_idx))

    def enable(self, tpl_idx: int):
        assert self.states[tpl_idx] in (TemplateState.REGISTERED, TemplateState.INSTALLED)
        # Phase 1: all QPs begin write invalidation
        self._request_all(lambda qp: qp.write_active.add(tpl_idx))
        self.states[tpl_idx] = TemplateState.INSTALLED
        self.audit_log.append(("installed", tpl_idx))
        # Phase 2: all QPs activate reads
        self._request_all(lambda qp: qp.read_active.add(tpl_idx))
        self.states[tpl_idx] = TemplateState.ENABLED
        self.audit_log.append(("enabled", tpl_idx))

    def disable_and_remove(self, tpl_idx: int, cache: CacheState, cspec: CacheSpec):
        assert self.states[tpl_idx] == TemplateState.ENABLED
        # Phase 1: stop reads everywhere (writes keep invalidating)
        self._request_all(lambda qp: qp.read_active.discard(tpl_idx))
        self.states[tpl_idx] = TemplateState.INSTALLED
        self.audit_log.append(("installed", tpl_idx))
        # Phase 2: stop write invalidation, then reclaim the subspace
        self._request_all(lambda qp: qp.write_active.discard(tpl_idx))
        cache = sweep_template(cspec, cache, tpl_idx)
        self.states[tpl_idx] = TemplateState.REMOVED
        self.audit_log.append(("removed", tpl_idx))
        return cache

    # -- invariants (used by tests) --------------------------------------
    def check_safety(self) -> bool:
        """Any QP reading => all QPs writing, per template."""
        live = [qp for qp in self.qps if qp.reachable]
        for t, s in self.states.items():
            if s == TemplateState.REMOVED:
                continue
            if any(t in qp.read_active for qp in live):
                if not all(t in qp.write_active for qp in live):
                    return False
        return True

    def remove_bad_qp(self, qp: GraphQP):
        qp.reachable = False
        self.audit_log.append(("qp_removed", qp.name))
