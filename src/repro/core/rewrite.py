"""Query re-writing (§4.2, the Q+ knob).

Amdahl's law: the cache only accelerates the one-hop fraction ``f`` of a
gR-Tx; re-writing attacks the ``1-f`` remainder. Rules operate on the
engine's QueryPlan IR and are cost-annotated so benchmarks can report the
phases each rule removes.

Rule 1 (the paper's example): a final filter that compares a *user-defined
unique property* of each leaf against the root's value requires fetching
that property for every leaf (one extra storage phase). When the property is
declared unique-per-vertex, engine-generated vertex ids are an equivalent
filter and cost nothing: ``("prop_neq_root", pid)`` -> ``("id_neq",)``.

Rule 2: a ``FINAL_VALUES`` clause over a property declared derivable from
the id (e.g. user-visible ids that are bijective with vertex ids) becomes
``FINAL_IDS`` — the valueMap fetch phase disappears.

Rule 3 (predicate de-duplication): a hop whose root predicate re-checks
exactly the previous hop's leaf predicate is redundant — the engine already
guarantees it; dropping it saves per-element predicate evaluations (CPU, not
a storage phase).
"""

from __future__ import annotations

import numpy as np

from repro.core.engine import FINAL_IDS, FINAL_VALUES, QueryPlan
from repro.core.templates import PredSpec


def _pred_equal(a: PredSpec, b: PredSpec) -> bool:
    return all(
        np.array_equal(np.asarray(getattr(a, f)), np.asarray(getattr(b, f)))
        for f in PredSpec._fields
    )


def rewrite_plan(plan: QueryPlan, unique_props: frozenset = frozenset()) -> QueryPlan:
    """Apply all applicable rules; returns a new plan (never mutates)."""
    post = plan.post_filter
    # Rule 1
    if post is not None and post[0] == "prop_neq_root" and post[1] in unique_props:
        post = ("id_neq",)
    # Rule 2
    final, final_prop = plan.final, plan.final_prop
    if final == FINAL_VALUES and final_prop in unique_props:
        final, final_prop = FINAL_IDS, -1
    # Rule 3
    hops = list(plan.hops)
    for i in range(1, len(hops)):
        prev, cur = hops[i - 1], hops[i]
        if _pred_equal(prev.pl, cur.pr):
            # the engine's frontier already satisfies this predicate
            from repro.core.templates import make_pred, ANY_LABEL

            hops[i] = cur._replace(pr=make_pred(ANY_LABEL, []))
    return plan._replace(hops=tuple(hops), final=final, final_prop=final_prop, post_filter=post)


def rewrite_savings(plan: QueryPlan, rewritten: QueryPlan) -> dict:
    """Phase savings the rules bought (for benchmark reporting)."""
    saved = 0
    if plan.post_filter != rewritten.post_filter:
        saved += 1
    if plan.final != rewritten.final:
        saved += 1
    return {"phases_saved": saved}
