"""One-hop sub-query templates (Definitions 2.1 / 2.2), tensorized.

A template is ``(direction, P^r, P^e, P^l)``. Each predicate holds a label
test plus up to ``MAX_CONDS`` property conditions; a condition is either a
bound comparison ``prop <op> value`` or a wildcard ``prop = ?`` (matches any
*present* value; the matched value becomes part of the cache key). All
predicates evaluate vectorized over batches of vertices/edges.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils import PROP_MISSING

MAX_CONDS = 3  # paper's production templates use <= 2 conditions

# direction codes (Definition 2.1: incoming, outgoing, or both)
DIR_OUT, DIR_IN, DIR_BOTH = 0, 1, 2
# comparison ops
OP_EQ, OP_NEQ, OP_LT, OP_LE, OP_GT, OP_GE = 0, 1, 2, 3, 4, 5
ANY_LABEL = -1
WILDCARD = object()  # host-side marker in template definitions


class PredSpec(NamedTuple):
    """Tensorized predicate. Stacks to [T, ...] in a TemplateTable."""

    label: jax.Array  # int32 scalar; ANY_LABEL = no label test
    prop_ids: jax.Array  # int32 [MAX_CONDS]; -1 = unused condition
    ops: jax.Array  # int32 [MAX_CONDS]
    vals: jax.Array  # int32 [MAX_CONDS] (ignored when wild)
    wild: jax.Array  # bool  [MAX_CONDS]


@dataclass(frozen=True)
class Template:
    """Host-side template definition (what an admin registers with the SC)."""

    name: str
    direction: int  # DIR_OUT / DIR_IN / DIR_BOTH
    root: tuple  # (label, [(prop_id, op, value|WILDCARD), ...])
    edge: tuple
    leaf: tuple
    edge_label: int = ANY_LABEL


class TemplateTable(NamedTuple):
    """All registered templates stacked for vectorized evaluation.

    ``read_enabled`` / ``write_enabled`` are the lifecycle masks driven by
    the Service Coordinator (§4.1): reads may use the cache only when
    read-enabled; writes must invalidate whenever write-enabled.
    """

    direction: jax.Array  # int32 [T]
    edge_label: jax.Array  # int32 [T]
    pr: PredSpec  # fields shaped [T, ...]
    pe: PredSpec
    pl: PredSpec
    read_enabled: jax.Array  # bool [T]
    write_enabled: jax.Array  # bool [T]


def make_pred(label: int, conds: Sequence[tuple]) -> PredSpec:
    assert len(conds) <= MAX_CONDS
    pid = np.full(MAX_CONDS, -1, np.int32)
    ops = np.zeros(MAX_CONDS, np.int32)
    vals = np.zeros(MAX_CONDS, np.int32)
    wild = np.zeros(MAX_CONDS, bool)
    for i, (p, op, v) in enumerate(conds):
        pid[i] = p
        ops[i] = op
        if v is WILDCARD:
            wild[i] = True
        else:
            vals[i] = v
    return PredSpec(
        label=jnp.int32(label),
        prop_ids=jnp.asarray(pid),
        ops=jnp.asarray(ops),
        vals=jnp.asarray(vals),
        wild=jnp.asarray(wild),
    )


def make_template_table(templates: Sequence[Template]) -> TemplateTable:
    preds = {"pr": [], "pe": [], "pl": []}
    for t in templates:
        preds["pr"].append(make_pred(*t.root))
        preds["pe"].append(make_pred(*t.edge))
        preds["pl"].append(make_pred(*t.leaf))
    stack = lambda ps: jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps)
    return TemplateTable(
        direction=jnp.asarray([t.direction for t in templates], jnp.int32),
        edge_label=jnp.asarray([t.edge_label for t in templates], jnp.int32),
        pr=stack(preds["pr"]),
        pe=stack(preds["pe"]),
        pl=stack(preds["pl"]),
        read_enabled=jnp.zeros(len(templates), bool),
        write_enabled=jnp.zeros(len(templates), bool),
    )


def _cmp(op, a, b):
    return jnp.select(
        [op == OP_EQ, op == OP_NEQ, op == OP_LT, op == OP_LE, op == OP_GT, op == OP_GE],
        [a == b, a != b, a < b, a <= b, a > b, a >= b],
        default=jnp.zeros_like(a, bool),
    )


def evaluate_pred(pred: PredSpec, labels, props, bound_vals=None):
    """Algorithm 5 (Evaluate), vectorized over N graph elements.

    ``labels``: int32 [...], ``props``: int32 [..., NP]. ``bound_vals``
    optionally binds wildcard conditions to concrete values (int32
    [MAX_CONDS]) — used when evaluating a template *instance* (the engine's
    forward path). Unbound wildcards only require presence (Algorithm 7
    line 2: the element must have all wildcard properties).
    """
    ok = (pred.label < 0) | (labels == pred.label)
    for c in range(MAX_CONDS):
        pid = pred.prop_ids[c]
        used = pid >= 0
        pv = jnp.take(props, jnp.clip(pid, 0, props.shape[-1] - 1), axis=-1)
        present = pv != PROP_MISSING
        if bound_vals is None:
            cond = jnp.where(pred.wild[c], present, present & _cmp(pred.ops[c], pv, pred.vals[c]))
        else:
            val = jnp.where(pred.wild[c], bound_vals[..., c], pred.vals[c])
            cond = present & _cmp(jnp.where(pred.wild[c], OP_EQ, pred.ops[c]), pv, val)
        ok = ok & (~used | cond)
    return ok


def extract_wildcards(pred: PredSpec, props):
    """Algorithm 9 (ExtractWildcardValues), vectorized.

    Returns int32 [..., MAX_CONDS]: the element's value for each wildcard
    condition (PROP_MISSING where the condition is unused or bound).
    """
    outs = []
    for c in range(MAX_CONDS):
        pid = pred.prop_ids[c]
        pv = jnp.take(props, jnp.clip(pid, 0, props.shape[-1] - 1), axis=-1)
        take = (pid >= 0) & pred.wild[c]
        outs.append(jnp.where(take, pv, PROP_MISSING))
    return jnp.stack(outs, axis=-1)
