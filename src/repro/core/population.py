"""Asynchronous, transactional cache population (§4's CP threads).

A cache miss enqueues ``(template, root, params, read_version)``. A drain
step re-executes the one-hop sub-query at the *current* committed version
(CP transactions take their own read version), then commits the insert with
an optimistic conflict check: if any vertex the result depends on (root +
produced leaves) was written after the CP read version, the insert aborts —
exactly how FDB's OCC prevents a CP transaction from installing a stale
entry over a concurrent gRW-Tx. Aborted entries are retried a bounded
number of times and then discarded (§4).

Keeping population here — and never on the gR-Tx path — preserves the
paper's separation of read and write paths.
"""

from __future__ import annotations

from collections import deque
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import runtime
from repro.core.cache import CacheSpec, CacheState, cache_insert
from repro.core.engine import EngineSpec, MissRecord
from repro.core.keys import PARAM_LEN
from repro.core.runtime import onehop_exec_view
from repro.core.templates import TemplateTable, PredSpec
from repro.graphstore.store import GlobalStoreView, GraphStore
from repro.graphstore.txn import conflicts
from repro.utils import take_along0


class MissQueue:
    """Host-side FIFO of cache misses with retry accounting."""

    def __init__(self, max_retries: int = 3, maxlen: int = 100_000):
        self.q: deque = deque(maxlen=maxlen)
        self.max_retries = max_retries
        self.discarded = 0
        self.retried = 0
        self._seen_inflight: set = set()

    def push(self, records):
        for r in records:
            key = (r.tpl_idx, r.root, tuple(np.asarray(r.params).tolist()))
            if key in self._seen_inflight:
                continue  # dedupe identical in-flight misses
            self._seen_inflight.add(key)
            self.q.append((r, 0))

    def drain(self, k: int):
        out = []
        while self.q and len(out) < k:
            out.append(self.q.popleft())
        return out

    def requeue(self, rec, attempts):
        if attempts + 1 >= self.max_retries:
            self.discarded += 1
            self._release(rec)
        else:
            self.retried += 1
            self.q.append((rec, attempts + 1))

    def done(self, rec):
        self._release(rec)

    def _release(self, rec):
        key = (rec.tpl_idx, rec.root, tuple(np.asarray(rec.params).tolist()))
        self._seen_inflight.discard(key)

    def __len__(self):
        return len(self.q)


def _tpl_row(stacked: PredSpec, t: int) -> PredSpec:
    return PredSpec(*(getattr(stacked, f)[t] for f in PredSpec._fields))


def populate_step(
    espec: EngineSpec,
    store_exec: GraphStore,
    store_commit: GraphStore,
    cache: CacheState,
    ttable: TemplateTable,
    tpl_idx: int,
    direction: int,
    edge_label: int,
    roots,
    params,
    mask,
    read_versions,
    exec_view=None,
    commit_mask=None,
    allreduce=None,
):
    """One CP transaction batch for one template (jit this with static
    espec/tpl_idx/direction/edge_label via functools.partial).

    Executes against ``store_exec`` (the CP read snapshot) and commits
    against ``store_commit`` (current state at commit time): entries whose
    read set was written in between abort. Returns (cache', committed[B],
    aborted[B]).

    ``exec_view`` overrides the miss-execution storage view (the
    partitioned tier passes a ``BlockStoreView`` over owner-local blocks);
    ``store_exec``/``store_commit`` then only supply ``.version`` /
    ``.vversion`` (a ``PartitionedGraphStore`` satisfies both).

    ``commit_mask`` + ``allreduce`` split the transaction across shards
    (the routing-table tier): ``mask`` selects the rows this shard
    *executes* (its storage owns them) and ``commit_mask`` the rows whose
    cache entry it *inserts* (its cache block owns them). The computed
    bundle — leaves, counts, the post-OCC commit verdict — crosses shards
    through ``allreduce`` (a psum inside shard_map): exactly one shard
    executes each row, every other shard contributes zeros, so the sum
    reconstructs the bundle at the inserting shard. With both defaulted
    (single host, or exec == commit shard) nothing is reduced and the
    path is byte-identical to the fused transaction.
    """
    pr = _tpl_row(ttable.pr, tpl_idx)
    pe = _tpl_row(ttable.pe, tpl_idx)
    pl = _tpl_row(ttable.pl, tpl_idx)
    view = exec_view if exec_view is not None else GlobalStoreView(
        espec.store, store_exec
    )
    leaves, lmask, n_true, trunc, stats = onehop_exec_view(
        espec, view, direction, edge_label, pr, pe, pl, roots, params, mask
    )
    cacheable = mask & ~trunc & (n_true <= espec.result_width)
    cp_read_version = store_exec.version

    # OCC conflict check per entry: the root plus every vertex the execution
    # observed (scanned neighbors, not just qualifying leaves — a write to a
    # filtered-out neighbor can change the result as well)
    read_set = jnp.concatenate([roots[:, None], stats["scanned"]], axis=1)
    read_mask = jnp.concatenate([mask[:, None], stats["scanned_mask"]], axis=1)
    conflict = conflicts(
        espec.store, store_commit, cp_read_version, read_set, read_mask, axis=1
    )
    # the write itself must also be enabled for this template (lifecycle) —
    # reads may only be served for enabled templates, but populating while
    # installed-for-writes is safe and matches §4.1 Phase 2.
    ok = cacheable & ~conflict & ttable.read_enabled[tpl_idx]

    insert_ok = ok
    if commit_mask is not None:
        assert allreduce is not None, "the CP split needs a reducer"
        # ship the executed bundle to the inserting shard: one owner per
        # row contributes, everyone else adds zeros
        leaves = allreduce(jnp.where(ok[:, None], leaves, 0))
        n_true = allreduce(jnp.where(ok, n_true, 0))
        ok_g = allreduce(ok.astype(jnp.int32)) > 0
        insert_ok = ok_g & commit_mask

    cache = cache_insert(
        espec.cache,
        cache,
        jnp.full(roots.shape, tpl_idx, jnp.int32),
        roots,
        params,
        leaves,
        n_true,
        jnp.full(roots.shape, cp_read_version, jnp.int32),
        insert_ok,
    )
    return cache, ok, cacheable & conflict


class CachePopulator:
    """Host orchestrator: drains a MissQueue and runs CP transactions.

    ``templates_meta[t] = (direction, edge_label)`` — static per template.
    ``step_builder(tpl_idx, bucket)`` optionally supplies the jitted CP step
    (same signature as ``populate_step`` minus the static args); the sharded
    runtime uses this to run population inside ``shard_map`` against the
    co-partitioned cache shards while reusing this orchestrator unchanged.
    """

    _BUCKETS = runtime.BUCKETS[:4]

    def __init__(self, espec: EngineSpec, templates_meta, max_retries: int = 3,
                 step_builder=None):
        self.espec = espec
        self.meta = templates_meta
        self.queue = MissQueue(max_retries=max_retries)
        self._jitted = {}
        self._step_builder = step_builder
        self.committed = 0
        self.aborted = 0

    def _fn(self, tpl_idx: int, bucket: int):
        key = (tpl_idx, bucket)
        if key not in self._jitted:
            if self._step_builder is not None:
                self._jitted[key] = self._step_builder(tpl_idx, bucket)
            else:
                espec = self.espec
                direction, edge_label = self.meta[tpl_idx]
                import functools

                self._jitted[key] = jax.jit(
                    functools.partial(
                        populate_step, espec, tpl_idx=tpl_idx, direction=direction,
                        edge_label=edge_label,
                    )
                )
        return self._jitted[key]

    def drain(self, store_exec, store_commit, cache, ttable, k: int = 128):
        """Process up to k queued misses. Returns the new cache.

        CP batches are packed with vectorized numpy slicing (no per-row
        Python re-packing). Batches need no dedup pass: ``MissQueue.push``
        holds each in-flight (tpl, root, params) key exactly once until it
        is done or discarded, and duplicate keys *within* one jitted insert
        are resolved last-writer-wins by the vectorized ``cache_insert``.
        """
        batch = self.queue.drain(k)
        if not batch:
            return cache
        by_tpl: dict = {}
        for rec, attempts in batch:
            by_tpl.setdefault(rec.tpl_idx, []).append((rec, attempts))
        for t, items in by_tpl.items():
            n = len(items)
            roots_all = np.fromiter((rec.root for rec, _ in items), np.int32, n)
            params_all = np.stack(
                [np.asarray(rec.params, np.int32) for rec, _ in items]
            ).reshape(n, PARAM_LEN)
            vers_all = np.fromiter((rec.read_version for rec, _ in items), np.int32, n)
            bucket = runtime.bucket_for(n, self._BUCKETS, clamp=True)
            for lo in range(0, n, bucket):
                chunk = items[lo : lo + bucket]
                nb = len(chunk)
                roots = np.zeros(bucket, np.int32)
                params = np.zeros((bucket, PARAM_LEN), np.int32)
                vers = np.zeros(bucket, np.int32)
                m = np.zeros(bucket, bool)
                roots[:nb] = roots_all[lo : lo + nb]
                params[:nb] = params_all[lo : lo + nb]
                vers[:nb] = vers_all[lo : lo + nb]
                m[:nb] = True
                fn = self._fn(t, bucket)
                cache, ok, conflicted = fn(
                    store_exec=store_exec,
                    store_commit=store_commit,
                    cache=cache,
                    ttable=ttable,
                    roots=jnp.asarray(roots),
                    params=jnp.asarray(params),
                    mask=jnp.asarray(m),
                    read_versions=jnp.asarray(vers),
                )
                ok = np.asarray(ok)
                conflicted = np.asarray(conflicted)
                for j, (rec, attempts) in enumerate(chunk):
                    if conflicted[j]:
                        self.aborted += 1
                        self.queue.requeue(rec, attempts)
                    else:
                        self.committed += int(ok[j])
                        self.queue.done(rec)
        return cache
