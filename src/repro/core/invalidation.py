"""Cache maintenance under gRW-Txs (§3.2 + Appendix A), vectorized.

``invalidate_write_around`` implements Algorithms 1–9 over a *batch* of
mutations × all registered templates, entirely as tensor ops:

- Algorithm 6 (DeleteKeysForRoot / FDB clearRange)  -> ``sweep_root``
- Algorithm 7 (DeleteKeysForLeaf, reverse traversal) -> ``_delete_keys_for_leaf``
- Algorithm 8 (HandleEdgeChange)                     -> ``_handle_edge_change``
- Algorithms 1–4 are the per-change-type drivers below.

``write_through_update`` is the §3 write-through policy (designed but not
implemented in the paper — we implement it as a beyond-paper feature):
instead of deleting impacted entries it appends/removes single vertex ids
in place, falling back to deletion for multi-chunk or full entries.

The drivers are written against a *sink*: the mutation listener derives the
impacted ``(template, root, params)`` keys and hands them to the sink, which
decides what to do with them.

- ``_ApplySink`` applies maintenance immediately to a cache pytree — the
  single-host path, byte-identical to the pre-runtime sequential behaviour.
- ``_CollectSink`` materializes the impacted keys as a flat tensor **op
  stream** instead (``derive_cache_ops``). The sharded runtime derives ops
  from its slice of the mutation batch, compacts the (mostly-masked) stream,
  routes each op to the shard owning its root, and applies it against the
  local cache shard (``repro.distributed.graph_serve``). Each op carries an
  ``order`` key (emission call serial × row-major position) so an
  order-preserving apply can reconstruct the exact sequential semantics
  after cross-shard routing.

Deletes are idempotent and inserts never happen during maintenance, so
exact-key deletes and root sweeps commute freely; only write-through value
edits on the same key are order-sensitive (hence the ``order`` column and
``apply_op_stream``'s sorted sequential walk).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.cache import CacheSpec, CacheState, cache_delete, sweep_root, _probe
from repro.core.keys import PARAM_LEN
from repro.core.templates import (
    DIR_BOTH,
    DIR_IN,
    DIR_OUT,
    MAX_CONDS,
    PredSpec,
    TemplateTable,
    evaluate_pred,
    extract_wildcards,
)
from repro.graphstore.store import GlobalStoreView, GraphStore
from repro.graphstore.mutations import AppliedMutations
from repro.utils import NULL_ID, PROP_MISSING, compact_masked, take_along0

# op kinds of the collected maintenance stream (root sweeps travel in their
# own, much smaller stream — a sweep is a mask over the whole cache shard)
OP_DELETE, OP_VAL_ADD, OP_VAL_REMOVE = 0, 1, 2

# order = serial * _ORDER_STRIDE + *global* row-major position within the
# emission (global mutation row × gather width + lane), so a routed/merged
# stream sorts back into exactly the single-host application order. A policy
# run makes ~16 emissions per template, so int32 holds the product for up to
# 32 registered templates × 4M-position emissions (global section cap ×
# reverse-gather width); both bounds are asserted at trace time via the
# static ``bound`` each emission passes to the sink.
_ORDER_STRIDE = 1 << 22


class CacheOpStream(NamedTuple):
    """Flat tensor stream of exact-key maintenance ops (phase A output)."""

    kind: jax.Array  # int32 [M]  OP_DELETE / OP_VAL_ADD / OP_VAL_REMOVE
    tpl: jax.Array  # int32 [M]
    root: jax.Array  # int32 [M]
    params: jax.Array  # int32 [M, PARAM_LEN]
    vid: jax.Array  # int32 [M]  leaf id for value ops (NULL_ID otherwise)
    order: jax.Array  # int32 [M]  global sequential-application order key
    ok: jax.Array  # bool  [M]


class SweepStream(NamedTuple):
    """Flat tensor stream of (template, root) range sweeps (Algorithm 6)."""

    tpl: jax.Array  # int32 [S]
    root: jax.Array  # int32 [S]
    ok: jax.Array  # bool  [S]


class _ApplySink:
    """Applies maintenance ops to a cache immediately (single-host path).

    Call sites and batching match the pre-runtime code exactly, so the
    resulting cache — including its stats counters — is byte-identical.
    """

    def __init__(self, espec, cache: CacheState):
        self.cspec = espec.cache
        self.cache = cache

    def delete(self, t, root, params, ok, order, bound):
        self.cache = cache_delete(
            self.cspec, self.cache, jnp.full(jnp.shape(root), t), root, params, ok
        )

    def value(self, t, root, params, vid, ok, delta, order, bound):
        self.cache = _value_update(
            self.cspec, self.cache, t, root, params, vid, ok, delta
        )

    def sweep(self, t, roots, ok, order, bound):
        self.cache = sweep_root(
            self.cspec, self.cache, jnp.full(roots.shape, t), roots, ok
        )


class _CollectSink:
    """Collects maintenance ops as flat tensors instead of applying them."""

    def __init__(self):
        self._ops = []
        self._sweeps = []
        self._serial = 0

    def _order(self, pos, bound):
        # ``bound`` is the static maximum position this emission can hold
        # (global section cap × gather width)
        assert bound <= _ORDER_STRIDE, (
            f"emission positions up to {bound} overflow the op-order stride"
        )
        assert (self._serial + 1) * _ORDER_STRIDE < 2**31, (
            "too many emissions for int32 op-order keys"
        )
        o = jnp.int32(self._serial) * _ORDER_STRIDE + pos.astype(jnp.int32)
        self._serial += 1
        return o

    def _push(self, kind, t, root, params, vid, ok, order, bound):
        root = jnp.asarray(root, jnp.int32).reshape(-1)
        self._ops.append((
            jnp.full(root.shape, kind, jnp.int32),
            jnp.full(root.shape, t, jnp.int32),
            root,
            jnp.asarray(params, jnp.int32).reshape(-1, PARAM_LEN),
            jnp.asarray(vid, jnp.int32).reshape(-1),
            self._order(order.reshape(-1), bound),
            jnp.asarray(ok, bool).reshape(-1),
        ))

    def delete(self, t, root, params, ok, order, bound):
        self._push(
            OP_DELETE, t, root, params, jnp.full(jnp.shape(root), NULL_ID), ok,
            order, bound,
        )

    def value(self, t, root, params, vid, ok, delta, order, bound):
        kind = OP_VAL_ADD if delta > 0 else OP_VAL_REMOVE
        self._push(kind, t, root, params, vid, ok, order, bound)

    def sweep(self, t, roots, ok, order, bound):
        self._sweeps.append((
            jnp.full(roots.shape, t, jnp.int32),
            jnp.asarray(roots, jnp.int32),
            jnp.asarray(ok, bool),
        ))
        self._serial += 1

    def streams(self):
        if not self._ops:  # no registered templates: empty streams
            z = lambda *s: jnp.zeros(s, jnp.int32)
            ops = CacheOpStream(
                z(0), z(0), z(0), z(0, PARAM_LEN), z(0), z(0), jnp.zeros((0,), bool)
            )
        else:
            cat = lambda i: jnp.concatenate([op[i] for op in self._ops], axis=0)
            ops = CacheOpStream(*(cat(i) for i in range(7)))
        if not self._sweeps:
            sw = SweepStream(
                jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32),
                jnp.zeros((0,), bool),
            )
        else:
            sw = SweepStream(
                jnp.concatenate([s[0] for s in self._sweeps]),
                jnp.concatenate([s[1] for s in self._sweeps]),
                jnp.concatenate([s[2] for s in self._sweeps]),
            )
        return ops, sw


def _pred_row(stacked: PredSpec, t: int) -> PredSpec:
    return PredSpec(*(getattr(stacked, f)[t] for f in PredSpec._fields))


def _has_all_wildcards(pred: PredSpec, props):
    """Algorithm 7 line 2 / Algorithm 8 line 2: element must carry every
    wildcard property of the predicate."""
    ok = jnp.ones(props.shape[:-1], bool)
    for c in range(MAX_CONDS):
        pid = pred.prop_ids[c]
        need = (pid >= 0) & pred.wild[c]
        pv = jnp.take(props, jnp.clip(pid, 0, props.shape[-1] - 1), axis=-1)
        ok &= ~need | (pv != PROP_MISSING)
    return ok


def _prop_in_pred(pred: PredSpec, pid):
    """'P appears in P^x' test, vectorized over a batch of pids."""
    hit = jnp.zeros(jnp.shape(pid), bool)
    for c in range(MAX_CONDS):
        hit |= (pred.prop_ids[c] >= 0) & (pred.prop_ids[c] == pid)
    return hit


def _handle_edge_change(
    espec,
    sink,
    ttable: TemplateTable,
    t: int,
    view_ep,
    elabel,
    eprops,
    src,
    dst,
    active,
    rows,
    rbound,
    value_delta=None,
):
    """Algorithm 8 over a batch of edges. ``view_ep`` supplies endpoint
    labels/properties (pre- or post-state per the caller's change type).

    ``value_delta``: None -> write-around (delete keys); +1 -> write-through
    append leaf; -1 -> write-through remove leaf. ``rows`` carries the
    *global* mutation-row index of each edge (the sink's ordering key) and
    ``rbound`` its static exclusive upper bound.

    On a sharded view, each emission side is gated to the shard *owning its
    root side* (R = src at the src-owner, R = dst at the dst-owner), so the
    union over shards is exactly the single-host emission set and every
    emitted op already sits at the shard whose cache block holds the key.
    """
    pe = _pred_row(ttable.pe, t)
    pr = _pred_row(ttable.pr, t)
    pl = _pred_row(ttable.pl, t)
    direction = ttable.direction[t]
    elab_t = ttable.edge_label[t]

    e_ok = active & _has_all_wildcards(pe, eprops) & evaluate_pred(pe, elabel, eprops)
    e_ok &= (elab_t < 0) | (elabel == elab_t)
    we = extract_wildcards(pe, eprops)  # [K, MAXC]

    use_rl = (direction == DIR_OUT) | (direction == DIR_BOTH)  # R=src, L=dst
    use_lr = (direction == DIR_IN) | (direction == DIR_BOTH)  # R=dst, L=src
    for R, L, use in ((src, dst, use_rl), (dst, src, use_lr)):
        rlab = take_along0(view_ep.vlabel, R)
        rprops = take_along0(view_ep.vprops, R)
        llab = take_along0(view_ep.vlabel, L)
        lprops = take_along0(view_ep.vprops, L)
        ok = (
            e_ok
            & use
            & _has_all_wildcards(pl, lprops)
            & evaluate_pred(pr, rlab, rprops)
            & evaluate_pred(pl, llab, lprops)
        )
        if view_ep.own is not None:
            ok &= view_ep.own(R)
        wl = extract_wildcards(pl, lprops)
        params = jnp.concatenate([we, wl], axis=-1)
        if value_delta is None:
            sink.delete(t, R, params, ok, rows, rbound)
        else:
            sink.value(t, R, params, L, ok, value_delta, rows, rbound)


def _delete_keys_for_leaf(
    espec,
    sink,
    ttable: TemplateTable,
    t: int,
    view_trav,
    leaf_vid,
    leaf_label,
    leaf_props,
    active,
    rows,
    rbound,
    value_delta=None,
):
    """Algorithm 7 over a batch of leaves: reverse-traverse to each possible
    root and delete (or write-through update) the corresponding keys.

    On a sharded view the reverse traversal runs at the *leaf's owner* —
    the shard whose in/out blocks hold exactly the edges arriving at /
    leaving the leaf — and emissions are gated to it; the produced roots
    belong to arbitrary shards, so these are the ops phase B must route.
    """
    pe = _pred_row(ttable.pe, t)
    pr = _pred_row(ttable.pr, t)
    pl = _pred_row(ttable.pl, t)
    direction = ttable.direction[t]
    elab_t = ttable.edge_label[t]

    act = active & _has_all_wildcards(pl, leaf_props)
    act &= evaluate_pred(pl, leaf_label, leaf_props)
    if view_trav.own is not None:
        act &= view_trav.own(leaf_vid)
    wl = extract_wildcards(pl, leaf_props)  # [K, MAXC]

    # reverse query: template OUT -> roots via the leaf's incoming edges;
    # template IN -> via outgoing; BOTH -> both sides.
    use_in = (direction == DIR_OUT) | (direction == DIR_BOTH)
    use_out = (direction == DIR_IN) | (direction == DIR_BOTH)
    sides = (
        (view_trav.adjacency(leaf_vid, espec.max_deg, incoming=True), use_in),
        (view_trav.adjacency(leaf_vid, espec.max_deg, incoming=False), use_out),
    )
    for (roots, emask, _trunc, elab, ep), use in sides:
        ok = emask & act[:, None] & use
        ok &= (elab_t < 0) | (elab == elab_t)
        ok &= _has_all_wildcards(pe, ep) & evaluate_pred(pe, elab, ep)
        we = extract_wildcards(pe, ep)  # [K, W, MAXC]
        rlab = take_along0(view_trav.vlabel, roots)
        rprops = take_along0(view_trav.vprops, roots)
        ok &= evaluate_pred(pr, rlab, rprops)
        params = jnp.concatenate(
            [we, jnp.broadcast_to(wl[:, None, :], we.shape)], axis=-1
        )
        K, W = roots.shape
        order = rows[:, None] * W + jnp.arange(W, dtype=jnp.int32)[None, :]
        flat = lambda x: x.reshape((K * W,) + x.shape[2:])
        if value_delta is None:
            sink.delete(t, flat(roots), flat(params), flat(ok), flat(order),
                        rbound * W)
        else:
            leaf_b = jnp.broadcast_to(leaf_vid[:, None], (K, W))
            sink.value(
                t, flat(roots), flat(params), flat(leaf_b), flat(ok), value_delta,
                flat(order), rbound * W,
            )


def _value_row(cspec: CacheSpec, cache: CacheState, t, root, params, vid, mask, add: bool):
    """Write-through in-place value edit of one entry: append (add=True) or
    remove ``vid`` from its leaf list. Single-chunk entries only; multi-chunk
    or full entries fall back to write-around deletion."""
    L = cspec.max_leaves
    found, slot, _, _ = _probe(cspec, cache, t, root, params, 0)
    s = jnp.clip(slot, 0)
    tlen = cache.total_len[s]
    single = tlen <= L
    do = mask & found
    row = cache.vals[s]
    present = jnp.any((row == vid) & (jnp.arange(L) < tlen))
    if add:
        new_row = row.at[jnp.clip(tlen, 0, L - 1)].set(vid)
        new_len = tlen + 1
        write = do & single & ~present & (tlen < L)
        # full entry (or multi-chunk chain): fall back to write-around
        kill = do & (~single | ((tlen >= L) & ~present))
    else:
        keep = (row != vid) & (jnp.arange(L) < tlen)
        new_row, _ = compact_masked(row, keep, L)
        new_len = jnp.sum(keep.astype(jnp.int32))
        write = do & single & present
        kill = do & ~single
    tgt = jnp.where(write, s, cspec.capacity)
    cache = cache._replace(
        vals=cache.vals.at[tgt].set(jnp.where(write, new_row, row), mode="drop"),
        total_len=cache.total_len.at[tgt].set(
            jnp.where(write, new_len, tlen), mode="drop"
        ),
    )
    kt = jnp.where(kill, s, cspec.capacity)
    return cache._replace(
        valid=cache.valid.at[kt].set(False, mode="drop"),
        n_delete=cache.n_delete + jnp.where(kill, 1, 0),
    )


def _value_update(cspec: CacheSpec, cache: CacheState, t, root, params, vid, mask, delta):
    """Write-through value edit over a batch, walked sequentially (write
    path). See ``_value_row`` for the per-entry semantics."""
    K = root.shape[0]
    tpl = jnp.full((K,), t, jnp.int32)

    def body(i, cache):
        return _value_row(
            cspec, cache, tpl[i], root[i], params[i], vid[i], mask[i], delta > 0
        )

    return jax.lax.fori_loop(0, K, body, cache)


def apply_op_stream(cspec: CacheSpec, cache: CacheState, ops: CacheOpStream):
    """Order-preserving sequential application of an exact-key op stream.

    Rows are walked in ``order``-sorted sequence, so a routed/merged stream
    reproduces the single-host emission order exactly — required for
    write-through value edits, which do not commute with deletes on the same
    key. Masked rows are no-ops.
    """
    perm = jnp.argsort(jnp.where(ops.ok, ops.order, jnp.int32(2**31 - 1)), stable=True)
    kind, tpl, root = ops.kind[perm], ops.tpl[perm], ops.root[perm]
    params, vid, ok = ops.params[perm], ops.vid[perm], ops.ok[perm]

    def body(i, cache):
        branches = [
            # cache_delete is shape-polymorphic: a 0-d row deletes all
            # chunks and counts exactly like the batched path
            lambda c: cache_delete(cspec, c, tpl[i], root[i], params[i], ok[i]),
            lambda c: _value_row(cspec, c, tpl[i], root[i], params[i], vid[i], ok[i], True),
            lambda c: _value_row(cspec, c, tpl[i], root[i], params[i], vid[i], ok[i], False),
        ]
        return jax.lax.switch(jnp.clip(kind[i], 0, 2), branches, cache)

    return jax.lax.fori_loop(0, root.shape[0], body, cache)


def _value_update_batched(cspec: CacheSpec, cache: CacheState, tpl, root,
                          params, vid, mask, add: bool):
    """Write-through value edit over a batch of *distinct-key* rows.

    Vectorized ``_value_row``: probes every row against the same pre-state,
    then commits all edits in one scatter. Distinct keys touch distinct
    slots (a slot matches exactly one key), so the batched scatters cannot
    collide and each row sees exactly the state its sequential turn would
    have seen. Rows sharing a key must be serialized by the caller
    (``apply_op_stream_segmented``'s rank rounds).
    """
    L = cspec.max_leaves
    found, slot, _, _ = _probe(cspec, cache, tpl, root, params, 0)
    s = jnp.clip(slot, 0)
    tlen = cache.total_len[s]
    single = tlen <= L
    do = mask & found
    row = cache.vals[s]  # [B, L]
    lane = jnp.arange(L, dtype=jnp.int32)[None, :]
    present = jnp.any((row == vid[:, None]) & (lane < tlen[:, None]), axis=1)
    if add:
        new_row = jnp.where(
            lane == jnp.clip(tlen, 0, L - 1)[:, None], vid[:, None], row
        )
        new_len = tlen + 1
        write = do & single & ~present & (tlen < L)
        # full entry (or multi-chunk chain): fall back to write-around
        kill = do & (~single | ((tlen >= L) & ~present))
    else:
        keep = (row != vid[:, None]) & (lane < tlen[:, None])
        new_row, _ = compact_masked(row, keep, L)
        new_len = jnp.sum(keep.astype(jnp.int32), axis=1)
        write = do & single & present
        kill = do & ~single
    tgt = jnp.where(write, s, cspec.capacity)
    cache = cache._replace(
        vals=cache.vals.at[tgt].set(
            jnp.where(write[:, None], new_row, row), mode="drop"
        ),
        total_len=cache.total_len.at[tgt].set(
            jnp.where(write, new_len, tlen), mode="drop"
        ),
    )
    kt = jnp.where(kill, s, cspec.capacity)
    return cache._replace(
        valid=cache.valid.at[kt].set(False, mode="drop"),
        n_delete=cache.n_delete + jnp.sum(kill.astype(jnp.int32)),
    )


def apply_op_stream_segmented(cspec: CacheSpec, cache: CacheState, ops: CacheOpStream):
    """Key-segmented application of an exact-key op stream — byte-identical
    to ``apply_op_stream``'s sequential walk, vectorized across keys.

    Ops on *distinct* keys commute (deletes are idempotent, value edits
    touch only their own entry's slot), so only same-key runs need order.
    The stream is lexicographically sorted by (key, order); round ``r``
    applies the r-th op of every key as three batched passes (deletes,
    value-adds, value-removes — all distinct keys, hence disjoint slots).
    The loop runs ``max ops per key`` rounds instead of ``len(stream)``
    sequential iterations; per-op probe outcomes — and therefore the
    resulting cache, including stats — match the sequential walk exactly,
    because an op's own key's earlier ops are applied in earlier rounds and
    other keys' ops can never change its probe result.
    """
    M = ops.root.shape[0]
    if M == 0:
        return cache
    big = jnp.int32(2**31 - 1)

    # lexicographic stable sort, least-significant key first: order, then
    # params columns, root, tpl, and finally validity (masked rows last)
    idx = jnp.argsort(jnp.where(ops.ok, ops.order, big), stable=True)
    for col in [ops.params[:, c] for c in range(PARAM_LEN - 1, -1, -1)] + [
        ops.root, ops.tpl, (~ops.ok).astype(jnp.int32)
    ]:
        idx = idx[jnp.argsort(col[idx], stable=True)]

    kind, tpl, root = ops.kind[idx], ops.tpl[idx], ops.root[idx]
    params, vid, ok = ops.params[idx], ops.vid[idx], ops.ok[idx]

    same = (
        (tpl[1:] == tpl[:-1])
        & (root[1:] == root[:-1])
        & jnp.all(params[1:] == params[:-1], axis=1)
        & ok[1:] & ok[:-1]
    )
    boundary = jnp.concatenate([jnp.ones((1,), bool), ~same])
    pos = jnp.arange(M, dtype=jnp.int32)
    group_start = jax.lax.cummax(jnp.where(boundary, pos, 0), axis=0)
    rank = pos - group_start
    n_rounds = jnp.max(jnp.where(ok, rank, -1)) + 1

    def body(r, cache):
        sel = ok & (rank == r)
        cache = cache_delete(
            cspec, cache, tpl, root, params, sel & (kind == OP_DELETE)
        )
        cache = _value_update_batched(
            cspec, cache, tpl, root, params, vid, sel & (kind == OP_VAL_ADD), True
        )
        cache = _value_update_batched(
            cspec, cache, tpl, root, params, vid, sel & (kind == OP_VAL_REMOVE),
            False,
        )
        return cache

    return jax.lax.fori_loop(0, n_rounds, body, cache)


def apply_op_stream_batched(cspec: CacheSpec, cache: CacheState, ops: CacheOpStream):
    """Vectorized application of a pure-delete op stream (write-around).

    Deletes are idempotent and commute, so the whole stream collapses into
    one batched ``cache_delete``. Value ops must use ``apply_op_stream``.
    """
    return cache_delete(
        cspec, cache, ops.tpl, ops.root, ops.params,
        ops.ok & (ops.kind == OP_DELETE),
    )


def apply_sweeps(cspec: CacheSpec, cache: CacheState, sweeps: SweepStream):
    """Apply a (template, root) sweep stream (Algorithm 6). Sweeps commute
    with every other maintenance op — no inserts happen during maintenance,
    so a swept entry can never be resurrected."""
    return sweep_root(cspec, cache, sweeps.tpl, sweeps.root, sweeps.ok)


def _sec(mask_len, ids):
    return jnp.arange(ids.shape[0]) < mask_len


def _run_policy(
    espec, view_pre, view_post, sink, ttable, applied: AppliedMutations, *,
    through: bool, row_offset=0, row_stride: int = 1,
):
    """Drive Algorithms 1–4 over every (mutation, template) pair into ``sink``.

    ``view_pre``/``view_post`` are storage views of the pre-/post-commit
    states: the full store on a single host (``GlobalStoreView``), one
    shard's owner-local blocks on the partitioned tier
    (``partition.BlockStoreView``). A sharded view gates every emission by
    ownership — reverse traversals at the leaf's owner, edge-change
    emissions at the root side's owner, sweeps at the swept root's owner —
    so the union over shards reproduces the single-host emission set with
    each op derived where its storage lives.

    ``row_offset``/``row_stride`` recover each section row's *global* batch
    index when the caller hands in a strided slice of the mutation batch
    (the replicated tier's round-robin phase A; ``row_offset`` may be a
    traced ``axis_index`` < ``row_stride``); the default (0, 1) is the
    identity for the single-host and ownership-masked paths. The global
    indices feed the sink's op-ordering keys, so a cross-shard op stream
    sorts back into exactly this loop's sequential application order.
    """
    b = applied.batch
    own = view_post.own
    T = int(ttable.direction.shape[0])
    nv = espec.store.n_vprops

    def rows_of(ids):
        rows = (
            jnp.asarray(row_offset, jnp.int32)
            + row_stride * jnp.arange(ids.shape[0], dtype=jnp.int32)
        )
        return rows, row_stride * ids.shape[0]  # (global rows, static bound)

    ne_m = _sec(b.ne_n, b.ne_src)
    de_m = _sec(b.de_n, b.de_eid)
    se_m = _sec(b.se_n, b.se_eid)
    sv_m = _sec(b.sv_n, b.sv_vid)
    dv_m = _sec(b.dv_n, b.dv_vid)
    ne_r, de_r = rows_of(b.ne_src), rows_of(b.de_eid)
    se_r, sv_r, dv_r = rows_of(b.se_eid), rows_of(b.sv_vid), rows_of(b.dv_vid)

    # edge-prop change = delete old edge + add new edge (Example 5)
    pid_col = jnp.clip(b.se_pid, 0, espec.store.n_eprops - 1)
    se_old_props = applied.se_props.at[
        jnp.arange(b.se_eid.shape[0]), pid_col
    ].set(applied.se_old)

    # vertex-prop pre/post rows
    sv_post = take_along0(view_post.vprops, b.sv_vid)
    vpid_col = jnp.clip(b.sv_pid, 0, nv - 1)
    sv_pre = sv_post.at[jnp.arange(b.sv_vid.shape[0]), vpid_col].set(applied.sv_old)
    sv_lab = take_along0(view_post.vlabel, b.sv_vid)

    dv_lab = take_along0(view_pre.vlabel, b.dv_vid)
    dv_props = take_along0(view_pre.vprops, b.dv_vid)

    sv_own = own(b.sv_vid) if own is not None else True
    dv_own = own(b.dv_vid) if own is not None else True

    add_d = +1 if through else None
    del_d = -1 if through else None

    for t in range(T):
        wen = ttable.write_enabled[t]
        pr = _pred_row(ttable.pr, t)
        pl = _pred_row(ttable.pl, t)

        # --- Algorithm 3: add edges (post state) / delete edges (pre state)
        _handle_edge_change(
            espec, sink, ttable, t, view_post,
            b.ne_label, b.ne_props, b.ne_src, b.ne_dst, ne_m & wen, *ne_r,
            value_delta=add_d,
        )
        _handle_edge_change(
            espec, sink, ttable, t, view_pre,
            applied.de_label, applied.de_props, applied.de_src, applied.de_dst,
            de_m & wen, *de_r, value_delta=del_d,
        )

        # --- Algorithm 4: edge property change (only templates whose P^e
        # references the property)
        in_pe = _prop_in_pred(_pred_row(ttable.pe, t), b.se_pid)
        _handle_edge_change(
            espec, sink, ttable, t, view_pre,
            applied.se_label, se_old_props, applied.se_src, applied.se_dst,
            se_m & wen & in_pe, *se_r, value_delta=del_d,
        )
        _handle_edge_change(
            espec, sink, ttable, t, view_post,
            applied.se_label, applied.se_props, applied.se_src, applied.se_dst,
            se_m & wen & in_pe, *se_r, value_delta=add_d,
        )

        # --- Algorithm 2: vertex property change
        in_pr = _prop_in_pred(pr, b.sv_pid)
        r_hit = evaluate_pred(pr, sv_lab, sv_pre) | evaluate_pred(pr, sv_lab, sv_post)
        # root-side changes clear the whole (template, root) range — both
        # policies delete (write-through has no cheaper option, §3.2).
        # Sweeps are emitted at (and only at) the swept root's owner.
        sink.sweep(t, b.sv_vid, sv_m & wen & in_pr & r_hit & sv_own, *sv_r)
        in_pl = _prop_in_pred(pl, b.sv_pid)
        _delete_keys_for_leaf(
            espec, sink, ttable, t, view_post, b.sv_vid, sv_lab, sv_pre,
            sv_m & wen & in_pl, *sv_r, value_delta=del_d,
        )
        _delete_keys_for_leaf(
            espec, sink, ttable, t, view_post, b.sv_vid, sv_lab, sv_post,
            sv_m & wen & in_pl, *sv_r, value_delta=add_d,
        )

        # --- Algorithm 1: delete vertex (pre state)
        r_ok = evaluate_pred(pr, dv_lab, dv_props)
        sink.sweep(t, b.dv_vid, dv_m & wen & r_ok & dv_own, *dv_r)
        _delete_keys_for_leaf(
            espec, sink, ttable, t, view_pre, b.dv_vid, dv_lab, dv_props,
            dv_m & wen, *dv_r, value_delta=del_d,
        )


def invalidate_write_around(espec, store_pre, store_post, cache, ttable, applied):
    """Write-around policy (§4): delete every impacted cache entry, in the
    same commit as the graph writes."""
    sink = _ApplySink(espec, cache)
    _run_policy(
        espec, GlobalStoreView(espec.store, store_pre),
        GlobalStoreView(espec.store, store_post), sink, ttable, applied,
        through=False,
    )
    return sink.cache


def write_through_update(espec, store_pre, store_post, cache, ttable, applied):
    """Write-through policy (§3.2, lazy variant): update impacted entries in
    place where possible, delete where not."""
    sink = _ApplySink(espec, cache)
    _run_policy(
        espec, GlobalStoreView(espec.store, store_pre),
        GlobalStoreView(espec.store, store_post), sink, ttable, applied,
        through=True,
    )
    return sink.cache


def derive_cache_ops(
    espec, store_pre, store_post, ttable, applied, *, through: bool,
    row_offset=0, row_stride: int = 1,
):
    """Phase A of the sharded write path: run the mutation listener without
    touching any cache, returning the impacted keys as tensor streams
    ``(CacheOpStream, SweepStream)`` ready to be compacted and routed to the
    shards owning their roots. ``row_offset``/``row_stride`` recover global
    mutation-row indices for the op-ordering keys when ``applied`` is a
    round-robin slice (see ``shard_mutation_rows``)."""
    return derive_cache_ops_views(
        espec, GlobalStoreView(espec.store, store_pre),
        GlobalStoreView(espec.store, store_post), ttable, applied,
        through=through, row_offset=row_offset, row_stride=row_stride,
    )


def derive_cache_ops_views(
    espec, view_pre, view_post, ttable, applied, *, through: bool,
    row_offset=0, row_stride: int = 1,
):
    """``derive_cache_ops`` over storage views — the partitioned tier's
    phase A: each shard passes its ``BlockStoreView``s and derives exactly
    the ops whose storage (reverse traversals, root-side ownership) lives
    locally, with globally consistent op-order keys."""
    sink = _CollectSink()
    _run_policy(
        espec, view_pre, view_post, sink, ttable, applied, through=through,
        row_offset=row_offset, row_stride=row_stride,
    )
    return sink.streams()
